"""Repo-root pytest shim: make `pytest python/tests/` work from the root
by putting python/ (the `compile` package home) on sys.path."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
