//! End-to-end validation driver (DESIGN.md §Experiment index, EXPERIMENTS.md
//! §E2E): train the default transformer chain (≈3.2M params; `--preset
//! wide` for the ≈100M-class geometry) for a few hundred SGD steps on
//! synthetic regression data, executing the *optimal checkpointing
//! schedule* under a real memory budget, and log the loss curve. Proves
//! all layers compose: stage kernels → runtime → DP schedule →
//! ledger-enforced execution → SGD — with Python nowhere on the path.
//!
//! Runs on the native backend by default; pass `--backend pjrt
//! --artifacts artifacts/default` to drive AOT-compiled HLO artifacts
//! through the identical generic loop.
//!
//! ```sh
//! cargo run --release --example e2e_train -- \
//!     [--backend native|pjrt] [--preset default] [--artifacts artifacts/default]
//!     [--steps 300] [--memory-frac 0.6] [--lr 0.05] [--out results/e2e_loss.csv]
//! ```

use std::io::Write as _;

use anyhow::{bail, Context, Result};
use chainckpt::api::{ChainSpec, MemBytes, PlanRequest};
use chainckpt::backend::Backend;
use chainckpt::estimator::{measured_chain, EstimatorConfig};
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::store_all_schedule;
use chainckpt::train::{mean_loss, SyntheticData, Trainer};
use chainckpt::util::{fmt_bytes, Args};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.str("backend", "native").as_str() {
        "native" => {
            let preset = args.str("preset", "default");
            let rt = Runtime::native_preset(&preset)?;
            println!("built native preset '{preset}'");
            run(&rt, &args)
        }
        "pjrt" => {
            let dir = args.str("artifacts", "artifacts/default");
            let rt = Runtime::load(&dir).context("run `make artifacts` first")?;
            println!("loaded artifacts from {dir}");
            run(&rt, &args)
        }
        other => bail!("--backend {other}: use native|pjrt"),
    }
}

fn run<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let steps = args.usize("steps", 300);
    let frac = args.f64("memory-frac", 0.6);
    let lr = args.f64("lr", 0.05) as f32;
    let out = args.str("out", "results/e2e_loss.csv");

    println!(
        "chain: {} stages, {} params, input {:?}",
        rt.manifest.stages.len(),
        rt.manifest.param_count,
        rt.manifest.input_shape
    );

    let chain = measured_chain(rt, EstimatorConfig::default())?;
    let store_all = chain.store_all_memory();
    let budget = (store_all as f64 * frac) as u64;
    println!(
        "measured ideal iter: {:.1} ms | store-all {} | budget {} ({:.0}%)",
        chain.ideal_time() / 1e3,
        fmt_bytes(store_all),
        fmt_bytes(budget),
        100.0 * frac
    );

    // the facade pipeline: measured chain → plan → verified schedule
    let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes::new(budget))
        .plan()
        .map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let schedule = plan.schedule().map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let sim = plan.verify(&schedule).map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let base = simulate(&chain, &store_all_schedule(&chain)).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "optimal schedule: {} ops (+{} recomputed fwds), predicted {:.1} ms/iter \
         (store-all would be {:.1} ms at {})",
        sim.ops,
        sim.recomputed_forwards,
        sim.makespan / 1e3,
        base.makespan / 1e3,
        fmt_bytes(base.peak_bytes)
    );

    let data = SyntheticData::generate(&rt.manifest, 16, 7)?;
    let mut trainer = Trainer::new(rt, schedule, lr, Some(budget), 42)?;
    if B::SUPPORTS_LOWERED {
        // compile the schedule once into a slot-addressed ExecPlan; the
        // training loop then replays it zero-allocation over one arena
        trainer.lower()?;
        let plan = trainer.lowered_plan().expect("just lowered");
        println!(
            "lowered plan: {} values → {} slots, arena {} (plan-time peak {})",
            plan.values.len(),
            plan.slots.len(),
            fmt_bytes(plan.arena_bytes),
            fmt_bytes(plan.peak_bytes)
        );
    }
    let t0 = std::time::Instant::now();
    let logs = trainer.train(&data, steps, steps.div_euclid(20).max(1), |log| {
        println!(
            "step {:>5}  loss {:.6}  {:>7.1} ms/step  peak {}",
            log.step,
            log.loss,
            log.step_time_s * 1e3,
            fmt_bytes(log.peak_bytes)
        );
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let first = logs[0].loss;
    let last = mean_loss(&logs, 20);
    let imgs = steps as u64 * rt.manifest.input_shape[0] as u64;
    println!("────────────────────────────────────────────");
    println!("steps            : {steps} ({:.1} s wall)", wall);
    println!("loss             : {first:.6} → {last:.6}");
    println!("throughput       : {:.2} sequences/s", imgs as f64 / wall);
    println!(
        "peak activations : {} (budget {}, store-all {})",
        fmt_bytes(logs.iter().map(|l| l.peak_bytes).max().unwrap()),
        fmt_bytes(budget),
        fmt_bytes(store_all)
    );

    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(&out)?;
    writeln!(f, "step,loss,step_time_s,peak_bytes")?;
    for l in &logs {
        writeln!(f, "{},{},{},{}", l.step, l.loss, l.step_time_s, l.peak_bytes)?;
    }
    println!("loss curve → {out}");
    anyhow::ensure!(last < first, "loss did not decrease");
    Ok(())
}
