//! The paper's motivating scenario (§1, Fig. 4): a model that does not
//! fit on the device at all under store-all becomes trainable — at a
//! small recompute cost — with optimal checkpointing, and bigger batches
//! buy back GPU efficiency.
//!
//! Sweeps batch sizes of ResNet-1001 @ 224 px on the analytic V100
//! profile and prints, per batch: store-all memory (vs the 15.75 GiB
//! device), whether each strategy fits, and the achieved throughput.
//!
//! ```sh
//! cargo run --release --example memory_wall -- [--image 224] [--depth 1001]
//! ```

use chainckpt::api::{ChainSpec, MemBytes, PlanRequest, Result, SlotCount};
use chainckpt::chain::profiles;
use chainckpt::figures::DEVICE_MEMORY;
use chainckpt::simulator::simulate;
use chainckpt::solver::{paper_segment_sweep, periodic_schedule};
use chainckpt::util::{fmt_bytes, Args};

fn main() -> Result<()> {
    let args = Args::from_env();
    let image = args.u64("image", 224);
    let depth = args.u32("depth", 1001);

    println!(
        "ResNet-{depth} @ {image}px on a V100-like device ({}):",
        fmt_bytes(DEVICE_MEMORY)
    );
    println!(
        "{:>5} {:>14} {:>10} {:>22} {:>22}",
        "batch", "store-all", "pytorch", "best sequential", "optimal"
    );

    for bs in [1u64, 2, 4, 8, 16] {
        let chain = profiles::resnet(depth, image, bs);
        let need = chain.store_all_memory();
        let pytorch = if need <= DEVICE_MEMORY { "fits" } else { "OOM" };

        // best sequential point that fits on the device
        let mut best_seq: Option<f64> = None;
        for k in paper_segment_sweep(chain.len() - 1) {
            if let Ok(rep) = simulate(&chain, &periodic_schedule(&chain, k)) {
                if rep.peak_bytes <= DEVICE_MEMORY {
                    let thr = bs as f64 / (rep.makespan * 1e-3);
                    best_seq = Some(best_seq.map_or(thr, |b: f64| b.max(thr)));
                }
            }
        }
        // optimal at the full device memory (one facade plan per chain)
        let device = MemBytes::new(DEVICE_MEMORY);
        let optimal = PlanRequest::new(ChainSpec::inline(chain.clone()), device)
            .slots(SlotCount::new(150))
            .plan()?
            .schedule_at(device)
            .map(|s| bs as f64 / (s.predicted_time * 1e-3));

        let fmt_opt = |v: Option<f64>| {
            v.map(|t| format!("{t:.2} img/s")).unwrap_or_else(|| "infeasible".into())
        };
        println!(
            "{:>5} {:>14} {:>10} {:>22} {:>22}",
            bs,
            fmt_bytes(need),
            pytorch,
            fmt_opt(best_seq),
            fmt_opt(optimal)
        );
    }
    println!(
        "\n(the paper's Fig. 4 phenomenon: store-all hits the memory wall as batch grows,\n\
         while optimal keeps training and beats sequential's best point throughout)"
    );
    Ok(())
}
