//! Quickstart: the whole system in ~60 lines — no artifacts needed.
//!
//! 1. Build the quickstart chain in-process on the native backend (the
//!    PJRT path over AOT artifacts is the same code, generic over the
//!    engine — see `--backend pjrt` on the CLI).
//! 2. Measure per-stage costs (paper §5.1).
//! 3. Solve for the optimal checkpointing schedule under a memory budget
//!    (paper §4.2, Theorem 1).
//! 4. Train a few SGD steps executing that schedule — real forward and
//!    backward math, Python never runs.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use chainckpt::api::{ChainSpec, MemBytes, PlanRequest};
use chainckpt::estimator::{measured_chain, EstimatorConfig};
use chainckpt::runtime::Runtime;
use chainckpt::train::{SyntheticData, Trainer};
use chainckpt::util::fmt_bytes;

fn main() -> Result<()> {
    // 1. in-process chain → compiled native stages
    let rt = Runtime::native_preset("quickstart")?;
    println!(
        "chain: {} stages, {} params",
        rt.manifest.stages.len(),
        rt.manifest.param_count
    );

    // 2. parameter estimation: measure u_f, u_b per stage
    let chain = measured_chain(&rt, EstimatorConfig::default())?;
    println!(
        "measured: ideal iter {:.0} µs, store-all memory {}",
        chain.ideal_time(),
        fmt_bytes(chain.store_all_memory())
    );

    // 3. optimal persistent schedule for 70% of the store-all footprint,
    //    via the facade: spec → plan → simulator-verified schedule
    let budget = MemBytes::new(chain.store_all_memory() * 7 / 10);
    let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), budget)
        .plan()
        .map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let schedule = plan.schedule().map_err(|e| anyhow::anyhow!("{e:#}"))?;
    let sim = plan.verify(&schedule).map_err(|e| anyhow::anyhow!("{e:#}"))?;
    println!(
        "schedule @ {budget}: {} ops, {} recomputed forwards, predicted {:.0} µs (+{:.1}% vs ideal)",
        sim.ops,
        sim.recomputed_forwards,
        sim.makespan,
        100.0 * (sim.makespan / chain.ideal_time() - 1.0),
    );
    println!("ops: {}", schedule.compact());

    // 4. lower the schedule (liveness → arena slots, peak known ahead of
    //    time) and train a few steps — the loop runs over one pooled
    //    arena with zero steady-state allocations
    let data = SyntheticData::generate(&rt.manifest, 4, 7)?;
    let mut trainer = Trainer::new(&rt, schedule, 0.1, Some(budget.get()), 42)?;
    trainer.lower()?;
    let plan = trainer.lowered_plan().expect("just lowered");
    println!(
        "lowered: {} values in {} arena slots, arena {}, plan-time peak {}",
        plan.values.len(),
        plan.slots.len(),
        fmt_bytes(plan.arena_bytes),
        fmt_bytes(plan.peak_bytes)
    );
    trainer.train(&data, 20, 5, |log| {
        println!(
            "step {:>3}  loss {:.5}  peak {}",
            log.step,
            log.loss,
            fmt_bytes(log.peak_bytes)
        );
    })?;
    Ok(())
}
