//! Quickstart: the whole system in ~60 lines.
//!
//! 1. Load the AOT-compiled chain (`make artifacts` builds it once).
//! 2. Measure per-stage costs (paper §5.1).
//! 3. Solve for the optimal checkpointing schedule under a memory budget
//!    (paper §4.2, Theorem 1).
//! 4. Train a few SGD steps executing that schedule — Python never runs.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use chainckpt::estimator::{measured_chain, EstimatorConfig};
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::optimal_schedule;
use chainckpt::train::{SyntheticData, Trainer};
use chainckpt::util::fmt_bytes;

fn main() -> Result<()> {
    // 1. compiled artifacts → PJRT executables
    let rt = Runtime::load("artifacts/quickstart")?;
    println!(
        "chain: {} stages, {} params",
        rt.manifest.stages.len(),
        rt.manifest.param_count
    );

    // 2. parameter estimation: measure u_f, u_b per stage
    let chain = measured_chain(&rt, EstimatorConfig::default())?;
    println!(
        "measured: ideal iter {:.0} µs, store-all memory {}",
        chain.ideal_time(),
        fmt_bytes(chain.store_all_memory())
    );

    // 3. optimal persistent schedule for 70% of the store-all footprint
    let budget = chain.store_all_memory() * 7 / 10;
    let schedule = optimal_schedule(&chain, budget)
        .expect("no schedule fits this budget");
    let sim = simulate(&chain, &schedule)?;
    println!(
        "schedule @ {}: {} ops, {} recomputed forwards, predicted {:.0} µs (+{:.1}% vs ideal)",
        fmt_bytes(budget),
        sim.ops,
        sim.recomputed_forwards,
        sim.makespan,
        100.0 * (sim.makespan / chain.ideal_time() - 1.0),
    );
    println!("ops: {}", schedule.compact());

    // 4. train a few steps under the memory ledger
    let data = SyntheticData::generate(&rt, 4, 7)?;
    let mut trainer = Trainer::new(&rt, schedule, 0.1, Some(budget), 42)?;
    trainer.train(&data, 20, 5, |log| {
        println!(
            "step {:>3}  loss {:.5}  peak {}",
            log.step,
            log.loss,
            fmt_bytes(log.peak_bytes)
        );
    })?;
    Ok(())
}
