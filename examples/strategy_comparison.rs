//! Measured counterpart of the paper's Figure 3: run all four strategies
//! on a *real* executing chain and report wall-clock throughput against
//! ledger peak memory. (The figure harness `chainckpt figures` uses the
//! V100 roofline simulator; this example uses actual execution — the
//! native engine by default, CPU-PJRT with `--backend pjrt`.)
//!
//! ```sh
//! cargo run --release --example strategy_comparison -- \
//!     [--backend native|pjrt] [--preset default] [--artifacts artifacts/default]
//!     [--points 5] [--reps 3] [--out results/measured_fig3.csv]
//! ```

use std::io::Write as _;

use anyhow::{bail, Context, Result};
use chainckpt::backend::{Backend, Tensor};
use chainckpt::estimator::{measured_chain, EstimatorConfig};
use chainckpt::executor::Executor;
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::{
    paper_segment_sweep, periodic_schedule, solve, store_all_schedule, Mode, Schedule,
};
use chainckpt::util::{fmt_bytes, median, Args, Rng};

struct Row {
    strategy: &'static str,
    param: String,
    peak: u64,
    predicted_us: f64,
    measured_ms: f64,
    throughput: f64,
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.str("backend", "native").as_str() {
        "native" => {
            let preset = args.str("preset", "default");
            run(&Runtime::native_preset(&preset)?, &args)
        }
        "pjrt" => {
            let dir = args.str("artifacts", "artifacts/default");
            run(&Runtime::load(&dir).context("run `make artifacts` first")?, &args)
        }
        other => bail!("--backend {other}: use native|pjrt"),
    }
}

fn run<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let points = args.usize("points", 5);
    let reps = args.usize("reps", 3);
    let out = args.str("out", "results/measured_fig3.csv");

    let chain = measured_chain(rt, EstimatorConfig::default())?;
    let batch = rt.manifest.input_shape[0] as u64;
    let n = rt.manifest.stages.len();

    let mut rng = Rng::new(17);
    let numel: usize = rt.manifest.input_shape.iter().product();
    let input = B::Tensor::from_vec(&rng.normal_vec(numel), &rt.manifest.input_shape)?;
    let target = rng.normal_vec(rt.manifest.sig_of(n - 1).params[0].nelem());

    let mut rows: Vec<Row> = Vec::new();
    let mut measure = |strategy: &'static str, param: String, sched: &Schedule| -> Result<()> {
        let sim = simulate(&chain, sched).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut ex = Executor::new(rt, 1)?;
        ex.set_data_param(n - 1, &target)?;
        let mut times = Vec::new();
        for r in 0..=reps {
            let res = ex.run(sched, &input, None)?;
            if r > 0 {
                times.push(res.elapsed_s);
            }
        }
        let t = median(&mut times);
        println!(
            "{strategy:<12} {param:>12}  peak {:>12}  {:>8.1} ms/iter  {:>7.2} seq/s",
            fmt_bytes(sim.peak_bytes),
            t * 1e3,
            batch as f64 / t
        );
        rows.push(Row {
            strategy,
            param,
            peak: sim.peak_bytes,
            predicted_us: sim.makespan,
            measured_ms: t * 1e3,
            throughput: batch as f64 / t,
        });
        Ok(())
    };

    println!("strategy            param          peak        time         throughput");
    measure("pytorch", "-".into(), &store_all_schedule(&chain))?;
    for k in paper_segment_sweep(chain.len() - 1).into_iter().take(points) {
        measure("sequential", format!("{k}seg"), &periodic_schedule(&chain, k))?;
    }
    let lo = chain.min_memory_hint();
    let hi = chain.store_all_memory();
    for i in 1..=points as u64 {
        let m = lo + (hi - lo) * i / points as u64;
        if let Some(s) = solve(&chain, m, 300, Mode::Full) {
            measure("optimal", fmt_bytes(m), &s)?;
        }
        if let Some(s) = solve(&chain, m, 300, Mode::AdRevolve) {
            measure("revolve", fmt_bytes(m), &s)?;
        }
    }

    // paper §5.3 model-accuracy check: predicted (estimator × schedule)
    // vs measured throughput, like the paper's 7.8 % MAPE claim
    let mape: f64 = rows
        .iter()
        .map(|r| ((r.predicted_us / 1e3 - r.measured_ms) / r.measured_ms).abs())
        .sum::<f64>()
        / rows.len() as f64;
    println!("\ncost-model MAPE vs measured iteration time: {:.1} % (paper: 7.8 %)", 100.0 * mape);

    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(&out)?;
    writeln!(f, "strategy,param,peak_bytes,predicted_us,measured_ms,throughput_seq_s")?;
    for r in &rows {
        writeln!(
            f,
            "{},{},{},{:.1},{:.3},{:.3}",
            r.strategy, r.param, r.peak, r.predicted_us, r.measured_ms, r.throughput
        )?;
    }
    println!("wrote {out}");
    Ok(())
}
