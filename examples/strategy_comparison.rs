//! Measured counterpart of the paper's Figure 3: run all four strategies
//! on a *real* executing chain and report wall-clock throughput against
//! ledger peak memory. (The figure harness `chainckpt figures` uses the
//! V100 roofline simulator; this example uses actual execution — the
//! native engine by default, CPU-PJRT with `--backend pjrt`.)
//!
//! The DP strategies come from one `api::Plan` per mode (one DP table
//! serving the whole budget sweep) and every row is measured with
//! `api::execute_schedule` — the same facade pipeline the CLI `compare`
//! subcommand uses.
//!
//! ```sh
//! cargo run --release --example strategy_comparison -- \
//!     [--backend native|pjrt] [--preset default] [--artifacts artifacts/default]
//!     [--points 5] [--reps 3] [--out results/measured_fig3.csv]
//! ```

use std::io::Write as _;

use chainckpt::api::{
    execute_schedule, ChainSpec, Context as _, Error, ErrorKind, ExecuteOptions, MemBytes,
    Mode, PlanRequest, Result, Schedule, SlotCount,
};
use chainckpt::backend::Backend;
use chainckpt::estimator::{measured_chain, EstimatorConfig};
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::{paper_segment_sweep, periodic_schedule, store_all_schedule};
use chainckpt::train::SyntheticData;
use chainckpt::util::{fmt_bytes, Args};

struct Row {
    strategy: &'static str,
    param: String,
    peak: u64,
    predicted_us: f64,
    measured_ms: f64,
    throughput: f64,
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.str("backend", "native").as_str() {
        "native" => {
            let preset = args.str("preset", "default");
            run(&Runtime::native_preset(&preset).kind(ErrorKind::Backend)?, &args)
        }
        "pjrt" => {
            let dir = args.str("artifacts", "artifacts/default");
            run(
                &Runtime::load(&dir)
                    .context("run `make artifacts` first")
                    .kind(ErrorKind::Backend)?,
                &args,
            )
        }
        other => Err(Error::invalid(format!("--backend {other}: use native|pjrt"))),
    }
}

fn run<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let points = args.usize("points", 5);
    let reps = args.usize("reps", 3);
    let out = args.str("out", "results/measured_fig3.csv");

    let chain = measured_chain(rt, EstimatorConfig::default()).kind(ErrorKind::Backend)?;
    let batch = rt.manifest.input_shape[0] as u64;
    let data = SyntheticData::generate(&rt.manifest, 1, 17).kind(ErrorKind::Backend)?;
    // lowered execution (the default): each schedule compiles once to an
    // ExecPlan and replays over the pooled arena — zero steady-state
    // allocations on the native engine
    let opts = ExecuteOptions { reps, seed: 1, ..ExecuteOptions::default() };

    let mut rows: Vec<Row> = Vec::new();
    let mut measure = |strategy: &'static str, param: String, sched: &Schedule| -> Result<()> {
        let sim = simulate(&chain, sched)
            .map_err(|e| Error::internal(format!("invalid schedule: {e}")))?;
        let rep = execute_schedule(rt, sched, &data, &opts)?;
        println!(
            "{strategy:<12} {param:>12}  peak {:>12}  {:>8.1} ms/iter  {:>7.2} seq/s",
            fmt_bytes(sim.peak_bytes),
            rep.elapsed_s * 1e3,
            rep.throughput
        );
        rows.push(Row {
            strategy,
            param,
            peak: sim.peak_bytes,
            predicted_us: sim.makespan,
            measured_ms: rep.elapsed_s * 1e3,
            throughput: rep.throughput,
        });
        Ok(())
    };

    println!("strategy            param          peak        time         throughput");
    measure("pytorch", "-".into(), &store_all_schedule(&chain))?;
    for k in paper_segment_sweep(chain.len() - 1).into_iter().take(points) {
        measure("sequential", format!("{k}seg"), &periodic_schedule(&chain, k))?;
    }
    let lo = chain.min_memory_hint();
    let hi = chain.store_all_memory();
    let budgets: Vec<MemBytes> =
        (1..=points as u64).map(|i| MemBytes::new(lo + (hi - lo) * i / points as u64)).collect();
    for (label, mode) in [("optimal", Mode::Full), ("revolve", Mode::AdRevolve)] {
        // one shared table discretizes against `hi`, so a low-budget
        // point only sees ~S·m/hi of the grid — double the old
        // per-budget S=300 to keep those rows at least as precise
        let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes::new(hi))
            .slots(SlotCount::new(600))
            .mode(mode)
            .plan()?;
        for (&m, sched) in budgets.iter().zip(plan.sweep(&budgets)) {
            if let Some(s) = sched {
                measure(label, fmt_bytes(m.get()), &s)?;
            }
        }
    }

    // paper §5.3 model-accuracy check: predicted (estimator × schedule)
    // vs measured throughput, like the paper's 7.8 % MAPE claim
    let mape: f64 = rows
        .iter()
        .map(|r| ((r.predicted_us / 1e3 - r.measured_ms) / r.measured_ms).abs())
        .sum::<f64>()
        / rows.len() as f64;
    println!("\ncost-model MAPE vs measured iteration time: {:.1} % (paper: 7.8 %)", 100.0 * mape);

    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(&out)?;
    writeln!(f, "strategy,param,peak_bytes,predicted_us,measured_ms,throughput_seq_s")?;
    for r in &rows {
        writeln!(
            f,
            "{},{},{},{:.1},{:.3},{:.3}",
            r.strategy, r.param, r.peak, r.predicted_us, r.measured_ms, r.throughput
        )?;
    }
    println!("wrote {out}");
    Ok(())
}
