"""L1 structural performance analysis (DESIGN.md §Perf).

``interpret=True`` Pallas gives CPU-numpy execution, so wall-clock here is
*not* a TPU proxy. What we can and do optimize/verify is kernel
*structure*: per-grid-step VMEM footprint (must fit the ~16 MiB/core
budget with double-buffering headroom) and the MXU utilization profile of
each matmul tile (how close tile shapes are to the 128×128 systolic
array). This module computes both for every kernel instantiation the
chain presets actually use, and is asserted by
``python/tests/test_analyze.py`` + reported in EXPERIMENTS.md §Perf.

Run:  python -m compile.analyze [--preset default]
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass

from .kernels.fused_dense import TILE_M, TILE_N, pick_block
from .model import build_chain
from .stages import Attn, Dense, Loss, Mlp

VMEM_BUDGET = 16 * 1024 * 1024  # bytes per TPU core (v4-ish)
BYTES = 4
MXU = 128


@dataclass
class KernelReport:
    name: str
    grid: tuple
    vmem_bytes: int
    mxu_util: float  # 0..1, min over the matmul dims vs the 128x128 array
    notes: str

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BUDGET


def _mxu_util(m: int, k: int, n: int) -> float:
    """Utilization of a 128×128 systolic pass for an (m×k)·(k×n) tile:
    limited by how fully the tile fills the array's two spatial dims."""
    fill = lambda d: min(d, MXU) / MXU if d % MXU != 0 else 1.0
    return min(fill(m), fill(n))


def dense_report(name: str, m: int, k: int, n: int, save: bool) -> KernelReport:
    bm, bn = pick_block(m, TILE_M), pick_block(n, TILE_N)
    # x tile (bm, K) + w tile (K, bn) + bias (bn) + out tile(s) (bm, bn)
    outs = 2 if save else 1
    vmem = BYTES * (bm * k + k * bn + bn + outs * bm * bn)
    return KernelReport(
        name=name,
        grid=(m // bm, n // bn),
        vmem_bytes=vmem,
        mxu_util=_mxu_util(bm, k, bn),
        notes=f"tiles ({bm}×{k})·({k}×{bn})" + (" +preact store" if save else ""),
    )


def layernorm_report(name: str, m: int, d: int) -> KernelReport:
    bm = pick_block(m, 128)
    vmem = BYTES * (bm * d * 2 + bm)  # in tile + xhat tile + rstd
    return KernelReport(
        name=name,
        grid=(m // bm,),
        vmem_bytes=vmem,
        mxu_util=0.0,  # VPU-only kernel (reductions), MXU not used
        notes=f"row tile ({bm}×{d}), VPU reductions",
    )


def attention_report(name: str, bh: int, t: int, dh: int) -> KernelReport:
    # q,k,v (t,dh) + scores/probs (t,t) + ctx (t,dh) resident per step
    vmem = BYTES * (3 * t * dh + 2 * t * t + t * dh)
    return KernelReport(
        name=name,
        grid=(bh,),
        vmem_bytes=vmem,
        mxu_util=_mxu_util(t, dh, t),
        notes=f"per-(batch·head) slice: qkv ({t}×{dh}), probs ({t}×{t})",
    )


def analyze_chain(preset: str) -> list[KernelReport]:
    chain = build_chain(preset)
    reports: list[KernelReport] = []
    seen = set()
    for st in chain.stages:
        if st.sig in seen:
            continue
        seen.add(st.sig)
        m = st.batch * st.seq
        if isinstance(st, Dense):
            reports.append(
                dense_report(f"{st.sig}/fused_dense", m, st.d_in, st.d_out, save=False)
            )
            if st.activation != "none":
                reports.append(
                    dense_report(f"{st.sig}/fused_dense_save", m, st.d_in, st.d_out, True)
                )
        elif isinstance(st, Mlp):
            reports.append(layernorm_report(f"{st.sig}/layernorm", m, st.d))
            reports.append(dense_report(f"{st.sig}/ffn_in", m, st.d, st.f, True))
            reports.append(dense_report(f"{st.sig}/ffn_out", m, st.f, st.d, False))
        elif isinstance(st, Attn):
            reports.append(layernorm_report(f"{st.sig}/layernorm", m, st.d))
            reports.append(
                attention_report(
                    f"{st.sig}/attention", st.batch * st.heads, st.seq, st.dh
                )
            )
        elif isinstance(st, Loss):
            pass  # elementwise, no kernel
    return reports


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="default")
    args = ap.parse_args()
    reports = analyze_chain(args.preset)
    print(f"{'kernel':<44} {'grid':>10} {'VMEM':>10} {'%bud':>6} {'MXU':>5}  notes")
    for r in reports:
        print(
            f"{r.name:<44} {str(r.grid):>10} {r.vmem_bytes:>10} "
            f"{100 * r.vmem_frac:>5.1f}% {100 * r.mxu_util:>4.0f}%  {r.notes}"
        )
    worst = max(reports, key=lambda r: r.vmem_frac)
    print(
        f"\nworst VMEM: {worst.name} at {100 * worst.vmem_frac:.1f}% of "
        f"{VMEM_BUDGET >> 20} MiB budget"
    )


if __name__ == "__main__":
    main()
