"""AOT pipeline: lower every stage entry point to HLO *text* + manifest.

This is the only place Python touches the system; it runs once at build
time (``make artifacts``).  For each unique stage signature we lower three
jitted functions (fwd / fwd_all / bwd) to StableHLO and convert to XLA HLO
text, which the Rust runtime parses with ``HloModuleProto::from_text_file``.

HLO **text** — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Calling convention (recorded in manifest.json, relied on by rust/src/runtime):
  fwd      inputs  [θ_0..θ_{P-1}, a_in]            outputs (a_out,)
  fwd_all  inputs  [θ_0..θ_{P-1}, a_in]            outputs (a_out, ā_1..ā_E)
  bwd      inputs  [θ_0..θ_{P-1}, a_in, a_out, ā_1..ā_E, δ_out]
           outputs (δ_in, ∂θ_0..∂θ_{G-1})           (G = non-data params)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ChainSpec, build_chain
from .stages import Stage


def to_hlo_text(lowered) -> str:
    """StableHLO → XLA HLO text (the 64-bit-id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_stage(stage: Stage) -> dict[str, str]:
    """Returns {entry_point: hlo_text} for one stage signature."""
    p_specs = [_spec(p.shape) for p in stage.params]
    x_spec = _spec(stage.in_shape)
    abar_specs = [_spec(stage.out_shape)] + [_spec(t.shape) for t in stage.abar_extras]
    dy_spec = _spec(stage.delta_out_shape)
    n_p = len(p_specs)

    def fwd_fn(*args):
        return (stage.fwd(list(args[:n_p]), args[n_p]),)

    def fwd_all_fn(*args):
        return tuple(stage.fwd_all(list(args[:n_p]), args[n_p]))

    def bwd_fn(*args):
        params = list(args[:n_p])
        x = args[n_p]
        abar = tuple(args[n_p + 1 : n_p + 1 + len(abar_specs)])
        dy = args[-1]
        return tuple(stage.bwd(params, x, abar, dy))

    # keep_unused=True: the Rust executor passes every manifest-declared
    # input positionally, so unused ones (e.g. a_out for a stage whose
    # backward doesn't need it) must stay in the HLO entry signature.
    jit = lambda f: jax.jit(f, keep_unused=True)
    return {
        "fwd": to_hlo_text(jit(fwd_fn).lower(*p_specs, x_spec)),
        "fwd_all": to_hlo_text(jit(fwd_all_fn).lower(*p_specs, x_spec)),
        "bwd": to_hlo_text(jit(bwd_fn).lower(*p_specs, x_spec, *abar_specs, dy_spec)),
    }


def build_manifest(chain: ChainSpec, files: dict[str, dict[str, str]]) -> dict:
    sigs = {}
    for stage in chain.stages:
        if stage.sig in sigs:
            continue
        sigs[stage.sig] = {
            "kind": stage.kind,
            "files": files[stage.sig],
            "params": [
                {"name": p.name, "shape": list(p.shape), "init": p.init}
                for p in stage.params
            ],
            "in_shape": list(stage.in_shape),
            "out_shape": list(stage.out_shape),
            "abar_extras": [
                {"name": t.name, "shape": list(t.shape)} for t in stage.abar_extras
            ],
            "w_a": stage.w_a,
            "w_abar": stage.w_abar,
            "flops_fwd": stage.flops_fwd(),
            "flops_bwd": stage.flops_bwd(),
            "n_grads": sum(1 for p in stage.params if p.init != "data"),
        }
    return {
        "preset": chain.name,
        "dtype": "f32",
        "input_shape": list(chain.input_shape),
        "param_count": chain.param_count(),
        "stages": [
            {"name": f"stage_{i}_{st.kind}", "kind": st.kind, "sig": st.sig}
            for i, st in enumerate(chain.stages)
        ],
        "signatures": sigs,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="default")
    ap.add_argument("--out-dir", default=None, help="default: ../artifacts/<preset>")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--blocks", type=int, default=None)
    args = ap.parse_args()

    overrides = {
        k: v
        for k, v in dict(batch=args.batch, seq=args.seq, blocks=args.blocks).items()
        if v is not None
    }
    chain = build_chain(args.preset, **overrides)
    out_dir = args.out_dir or os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", args.preset
    )
    os.makedirs(out_dir, exist_ok=True)

    files: dict[str, dict[str, str]] = {}
    total = 0
    for stage in chain.stages:
        if stage.sig in files:
            continue
        hlos = lower_stage(stage)
        entry_files = {}
        for entry, text in hlos.items():
            fname = f"{stage.sig}_{entry}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry_files[entry] = fname
            total += len(text)
        files[stage.sig] = entry_files
        print(f"lowered {stage.sig}: fwd/fwd_all/bwd")

    manifest = build_manifest(chain, files)
    manifest["content_hash"] = hashlib.sha256(
        json.dumps(manifest, sort_keys=True).encode()
    ).hexdigest()[:16]
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"wrote {len(files)} signatures ({total} HLO chars), "
        f"manifest for L+1={chain.length} stages, "
        f"{manifest['param_count']} params → {out_dir}"
    )


if __name__ == "__main__":
    main()
