"""L1: Pallas kernels for the chain's stage hot-spots + pure-jnp oracles."""

from .attention import attention
from .fused_dense import fused_dense, fused_dense_save, pick_block
from .layernorm import layernorm
from . import ref

__all__ = [
    "attention",
    "fused_dense",
    "fused_dense_save",
    "layernorm",
    "pick_block",
    "ref",
]
