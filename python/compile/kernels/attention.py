"""L1 Pallas kernel: scaled-dot-product attention per (batch*head) slice.

Grid = one program per (batch, head). Each step holds q/k/v slices
(T, dh) in VMEM, forms the (T, T) score tile, softmaxes it in-register and
writes back both the context (T, dh) and the probability matrix (T, T).

TPU mapping of the paper's GPU framing: where a CUDA implementation would
assign the (T, T) score tile to a threadblock in shared memory, here the
BlockSpec pins it to VMEM and the two matmuls (q·kᵀ and p·v) hit the MXU.
The probs output exists *because of the paper's model*: F_all checkpoints
ā ⊇ {probs} so B never recomputes the softmax; F∅/Fck would simply drop it.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, c_ref, p_ref):
    q = q_ref[0].astype(jnp.float32)  # (T, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (T, T)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    c = jnp.dot(p, v, preferred_element_type=jnp.float32)  # (T, dh)
    c_ref[0] = c.astype(c_ref.dtype)
    p_ref[0] = p.astype(p_ref.dtype)


@jax.jit
def attention(q, k, v):
    """q, k, v: (BH, T, dh) → (ctx: (BH, T, dh), probs: (BH, T, T)).

    Callers with (B, H, T, dh) tensors flatten the leading two axes; the
    kernel treats each (batch, head) slice independently.
    """
    bh, t, dh = q.shape
    grid = (bh,)
    return pl.pallas_call(
        _attention_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
            jax.ShapeDtypeStruct((bh, t, t), q.dtype),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, t), lambda i: (i, 0, 0)),
        ),
        interpret=True,
    )(q, k, v)
