"""L1 Pallas kernel: fused dense layer  ``y = act(x @ w + b)``.

The stage hot-spot of the heterogeneous chain. On a real TPU the BlockSpecs
below express the HBM→VMEM schedule: ``x`` is streamed in (bm, K) row tiles,
``w`` in (K, bn) column tiles, and each grid step produces one MXU-shaped
(bm, bn) output tile with the bias-add and GELU fused into the epilogue —
the standard "one pass over HBM" fusion that the paper's F-operations assume
when they charge a single ``u_f`` per stage.

Lowered with ``interpret=True`` so the emitted HLO runs on the CPU PJRT
client (real-TPU Mosaic custom-calls cannot). Structure — tile shapes, VMEM
footprint, fusion — is what we optimize; see DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu

# MXU-friendly tile targets. 128 matches both the MXU systolic array edge
# and the lane dimension of VMEM tiles.
TILE_M = 128
TILE_N = 128


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (prefers ``target``)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    # x_ref: (bm, K) VMEM tile; w_ref: (K, bn); b_ref: (bn,); o_ref: (bm, bn)
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    # MXU matmul with f32 accumulation regardless of input dtype.
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "gelu":
        z = gelu(z)
    o_ref[...] = z.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation",))
def fused_dense(x2d, w, b, activation: str = "gelu"):
    """``act(x2d @ w + b)`` for x2d: (M, K), w: (K, N), b: (N,).

    Callers with (B, T, D) inputs reshape to (B*T, D) first (see
    ``compile.stages``); the kernel itself is purely 2-D.
    """
    m, k = x2d.shape
    k2, n = w.shape
    assert k == k2, (x2d.shape, w.shape)
    bm = pick_block(m, TILE_M)
    bn = pick_block(n, TILE_N)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((m, n), x2d.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x2d, w, b)


def _dense_preact_kernel(x_ref, w_ref, b_ref, z_ref, y_ref, *, activation: str):
    # Variant used by fwd_all: also materializes the pre-activation z, the
    # tensor the backward pass needs (ā = {y, z}).
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    z_ref[...] = z.astype(z_ref.dtype)
    y = gelu(z) if activation == "gelu" else z
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation",))
def fused_dense_save(x2d, w, b, activation: str = "gelu"):
    """Like :func:`fused_dense` but returns ``(y, z)`` with z = x@w+b.

    This is the F_all form: one extra VMEM→HBM store per tile buys the
    backward pass out of recomputing the matmul.
    """
    m, k = x2d.shape
    _, n = w.shape
    bm = pick_block(m, TILE_M)
    bn = pick_block(n, TILE_N)
    grid = (m // bm, n // bn)
    z, y = pl.pallas_call(
        functools.partial(_dense_preact_kernel, activation=activation),
        out_shape=(
            jax.ShapeDtypeStruct((m, n), x2d.dtype),
            jax.ShapeDtypeStruct((m, n), x2d.dtype),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ),
        interpret=True,
    )(x2d, w, b)
    return y, z
