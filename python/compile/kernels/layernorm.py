"""L1 Pallas kernel: row-wise layernorm producing (xhat, rstd).

Each grid step normalizes a (bm, D) tile of rows entirely inside VMEM — one
HBM read of the tile, two HBM writes (xhat and the per-row rstd). The
affine scale/shift is applied by the caller (``compile.stages``) so the
same kernel serves both fwd and fwd_all, and the backward consumes exactly
the two tensors this kernel emits.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_dense import pick_block

EPS = 1e-5


def _layernorm_kernel(x_ref, xhat_ref, rstd_ref):
    x = x_ref[...].astype(jnp.float32)  # (bm, D)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + EPS)
    xhat_ref[...] = ((x - mu) * rstd).astype(xhat_ref.dtype)
    rstd_ref[...] = rstd[:, 0].astype(rstd_ref.dtype)


@jax.jit
def layernorm(x2d):
    """x2d: (M, D) → (xhat: (M, D), rstd: (M,))."""
    m, d = x2d.shape
    bm = pick_block(m, 128)
    grid = (m // bm,)
    return pl.pallas_call(
        _layernorm_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m, d), x2d.dtype),
            jax.ShapeDtypeStruct((m,), x2d.dtype),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ),
        interpret=True,
    )(x2d)
