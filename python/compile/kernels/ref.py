"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package must agree with the corresponding function here
to ~1e-5 (f32) across the shape sweeps in ``python/tests/test_kernels.py``.
These are also the building blocks of the hand-derived stage backwards in
``compile.stages`` — keeping a single gelu/softmax definition guarantees the
kernel, the forward artifact and the backward artifact all use the *same*
nonlinearity.
"""

import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654  # sqrt(2/pi)
GELU_C = 0.044715


def gelu(z):
    """tanh-approximation GELU (used consistently in kernels and backwards)."""
    return 0.5 * z * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (z + GELU_C * z**3)))


def gelu_grad(z):
    """d gelu / dz for the tanh approximation."""
    inner = SQRT_2_OVER_PI * (z + GELU_C * z**3)
    t = jnp.tanh(inner)
    dinner = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * z**2)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * dinner


def dense_ref(x, w, b, activation="gelu"):
    """x: (..., K) @ w: (K, N) + b: (N,), optional GELU."""
    z = jnp.einsum("...k,kn->...n", x, w) + b
    if activation == "gelu":
        return gelu(z)
    if activation == "none":
        return z
    raise ValueError(f"unknown activation {activation!r}")


def dense_preact_ref(x, w, b):
    """Pre-activation z = x @ w + b (what fwd_all checkpoints)."""
    return jnp.einsum("...k,kn->...n", x, w) + b


def layernorm_ref(x, eps=1e-5):
    """Row-wise layernorm over the last axis.

    Returns (xhat, rstd): the normalized rows and the reciprocal stddev,
    exactly the tensors the backward pass consumes.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + eps)
    xhat = (x - mu) * rstd
    return xhat, rstd


def softmax_ref(s):
    """Numerically-stable softmax over the last axis."""
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v):
    """Scaled-dot-product attention.

    q, k, v: (B, H, T, dh). Returns (ctx, probs) where probs is the softmax
    attention matrix (B, H, T, T) — checkpointed by fwd_all because the
    backward needs it.
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    p = softmax_ref(s)
    c = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    return c, p
