"""L2: the heterogeneous chain model (paper Fig. 1a) and its presets.

A ``ChainSpec`` is an ordered list of stages (the last one is always the
loss stage F^{L+1}/B^{L+1}).  This module also provides reference
*composed* forward/backward execution in pure JAX, used by the tests to
check that chaining the per-stage hand-derived backwards reproduces
``jax.grad`` of the end-to-end loss — the correctness contract the Rust
executor relies on.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .stages import Attn, Dense, Loss, Mlp, Stage

PRESETS = {
    # Tiny chain for smoke tests and the quickstart example.
    "quickstart": dict(batch=2, seq=16, d=64, heads=4, ffn=128, blocks=1),
    # Default chain for the end-to-end training example: a GPT-style
    # trunk. ~3.2M parameters at d=256.
    "default": dict(batch=8, seq=64, d=256, heads=4, ffn=1024, blocks=4),
    # Wide chain: 100M-class stage shapes (d=768, ffn=3072 — GPT-2 base
    # geometry); used to exercise realistic activation/parameter ratios.
    "wide": dict(batch=4, seq=128, d=768, heads=12, ffn=3072, blocks=6),
}


@dataclass
class ChainSpec:
    name: str
    stages: list  # [Stage], last is Loss

    @property
    def length(self) -> int:
        """L+1 in the paper's notation (compute stages + loss)."""
        return len(self.stages)

    @property
    def input_shape(self) -> tuple:
        return self.stages[0].in_shape

    def param_count(self) -> int:
        return sum(
            int(np.prod(p.shape))
            for st in self.stages
            for p in st.params
            if p.init != "data"
        )


def build_chain(preset: str = "default", **overrides) -> ChainSpec:
    cfg = dict(PRESETS[preset])
    cfg.update(overrides)
    b, t, d = cfg["batch"], cfg["seq"], cfg["d"]
    stages: list[Stage] = [Dense(b, t, d, d, activation="gelu")]
    for _ in range(cfg["blocks"]):
        stages.append(Attn(b, t, d, cfg["heads"]))
        stages.append(Mlp(b, t, d, cfg["ffn"]))
    stages.append(Dense(b, t, d, d, activation="none"))  # output head
    stages.append(Loss(b, t, d))
    return ChainSpec(name=preset, stages=stages)


# ---------------------------------------------------------------------------
# Parameter init (mirrors the Rust executor's initializer; tests use this)
# ---------------------------------------------------------------------------


def init_stage_params(stage: Stage, key) -> list:
    params = []
    for spec in stage.params:
        key, sub = jax.random.split(key)
        if spec.init == "xavier":
            fan_in, fan_out = spec.shape[0], spec.shape[-1]
            lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
            params.append(jax.random.uniform(sub, spec.shape, jnp.float32, -lim, lim))
        elif spec.init == "zeros":
            params.append(jnp.zeros(spec.shape, jnp.float32))
        elif spec.init == "ones":
            params.append(jnp.ones(spec.shape, jnp.float32))
        elif spec.init == "data":
            params.append(jax.random.normal(sub, spec.shape, jnp.float32))
        else:
            raise ValueError(spec.init)
    return params


def init_chain_params(chain: ChainSpec, seed: int = 0) -> list[list]:
    key = jax.random.PRNGKey(seed)
    out = []
    for stage in chain.stages:
        key, sub = jax.random.split(key)
        out.append(init_stage_params(stage, sub))
    return out


# ---------------------------------------------------------------------------
# Composed reference execution (ground truth for tests)
# ---------------------------------------------------------------------------


def chain_forward(chain: ChainSpec, all_params: list, x):
    """End-to-end forward; returns the scalar loss."""
    a = x
    for stage, params in zip(chain.stages, all_params):
        a = stage.fwd(params, a)
    return a


def chain_forward_ref(chain: ChainSpec, all_params: list, x):
    """Pure-jnp end-to-end forward (differentiable; no Pallas)."""
    a = x
    for stage, params in zip(chain.stages, all_params):
        a = stage.fwd_ref(params, a)
    return a


def chain_backward_manual(chain: ChainSpec, all_params: list, x):
    """Runs the store-all schedule in pure JAX: Fall everywhere, then B
    right-to-left.  Returns (loss, dx, grads-per-stage) — the values the
    Rust executor must reproduce for *any* valid schedule."""
    acts = [x]
    abars = []
    for stage, params in zip(chain.stages, all_params):
        abar = stage.fwd_all(params, acts[-1])
        abars.append(abar)
        acts.append(abar[0])
    loss = acts[-1]
    delta = jnp.ones((), jnp.float32)
    grads = [None] * len(chain.stages)
    for i in reversed(range(len(chain.stages))):
        out = chain.stages[i].bwd(all_params[i], acts[i], abars[i], delta)
        delta, grads[i] = out[0], list(out[1:])
    return loss, delta, grads
