"""L2: stage definitions for the heterogeneous chain (paper §3.1).

Each stage ℓ is an opaque block with parameters θℓ and three lowered entry
points, exactly matching the paper's operation set (Table 1):

  ``fwd``     : (θ…, a_in)            → (a_out,)             — F∅ / Fck
  ``fwd_all`` : (θ…, a_in)            → (a_out, ā-extras…)   — F_all
  ``bwd``     : (θ…, a_in, ā…, δ_out) → (δ_in, ∂θ…)          — B

with ā ≡ (a_out, *extras): following the paper, ā^ℓ *includes* a^ℓ but not
a^{ℓ-1}.  The backward passes are hand-derived (no autodiff inside the
artifact) so that B really consumes the checkpointed ā rather than silently
re-running the forward — this is what makes u_b independent of the schedule,
the property the DP cost model relies on.  Every bwd is validated against
``jax.vjp`` in ``python/tests/test_stages.py``.

All tensors are positional and flat (no pytrees) so the Rust executor can
feed Literals by index; the ordering contract is recorded in manifest.json.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp

from .kernels import attention, fused_dense, fused_dense_save, layernorm
from .kernels.ref import attention_ref, dense_ref, gelu_grad, layernorm_ref

DTYPE = jnp.float32
BYTES = 4  # f32


def _nelem(shape) -> int:
    return int(math.prod(shape)) if shape else 1


@dataclass(frozen=True)
class ParamSpec:
    """One parameter tensor of a stage.

    ``init`` tells the Rust side how to initialize it:
      * ``xavier`` — U(±sqrt(6/(fan_in+fan_out))) for weight matrices
      * ``zeros`` / ``ones`` — biases / layernorm gains
      * ``data``  — not a parameter at all: per-batch data fed by the
        executor (the loss stage's regression target).
    """

    name: str
    shape: tuple
    init: str


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple

    @property
    def bytes(self) -> int:
        return _nelem(self.shape) * BYTES


class Stage:
    """Base class; concrete stages fill in the forward/backward callables."""

    kind: str = "?"

    def __init__(self, batch: int, seq: int):
        self.batch = batch
        self.seq = seq

    # --- signature / manifest plumbing -----------------------------------
    @property
    def sig(self) -> str:
        raise NotImplementedError

    @property
    def params(self) -> list[ParamSpec]:
        raise NotImplementedError

    @property
    def in_shape(self) -> tuple:
        raise NotImplementedError

    @property
    def out_shape(self) -> tuple:
        raise NotImplementedError

    @property
    def abar_extras(self) -> list[TensorSpec]:
        """Checkpointed intermediates beyond a_out itself."""
        raise NotImplementedError

    @property
    def delta_in_shape(self) -> tuple:
        return self.in_shape

    @property
    def delta_out_shape(self) -> tuple:
        return self.out_shape

    # Sizes the DP consumes (paper: ω_a, ω_ā; ω_δ == ω_a).
    @property
    def w_a(self) -> int:
        return _nelem(self.out_shape) * BYTES

    @property
    def w_abar(self) -> int:
        return self.w_a + sum(t.bytes for t in self.abar_extras)

    def flops_fwd(self) -> int:
        raise NotImplementedError

    def flops_bwd(self) -> int:
        # Rule of thumb: backward does ~2x the forward matmul work.
        return 2 * self.flops_fwd()

    # --- compute ----------------------------------------------------------
    def fwd(self, params, x):
        raise NotImplementedError

    def fwd_all(self, params, x):
        raise NotImplementedError

    def bwd(self, params, x, abar, dy):
        """Returns (dx, *param_grads) — grads ordered like ``self.params``."""
        raise NotImplementedError

    def fwd_ref(self, params, x):
        """Pure-jnp forward (no Pallas) — differentiable; used by the tests
        to cross-check the hand-derived ``bwd`` against ``jax.vjp``."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# helpers shared by backward passes
# ---------------------------------------------------------------------------


def _ln_bwd(dh2d, xhat, rstd, g):
    """Backward of h = xhat*g + b given grad dh (all 2-D, rstd (M,))."""
    dxhat = dh2d * g
    gg = jnp.sum(dh2d * xhat, axis=0)
    gb = jnp.sum(dh2d, axis=0)
    mean1 = jnp.mean(dxhat, axis=-1, keepdims=True)
    mean2 = jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    dx = rstd[:, None] * (dxhat - mean1 - xhat * mean2)
    return dx, gg, gb


# ---------------------------------------------------------------------------
# Dense: y = act(x @ W + b)
# ---------------------------------------------------------------------------


class Dense(Stage):
    kind = "dense"

    def __init__(self, batch, seq, d_in, d_out, activation="gelu"):
        super().__init__(batch, seq)
        self.d_in, self.d_out, self.activation = d_in, d_out, activation

    @property
    def sig(self):
        return f"dense_b{self.batch}t{self.seq}_{self.d_in}x{self.d_out}_{self.activation}"

    @property
    def params(self):
        return [
            ParamSpec("w", (self.d_in, self.d_out), "xavier"),
            ParamSpec("b", (self.d_out,), "zeros"),
        ]

    @property
    def in_shape(self):
        return (self.batch, self.seq, self.d_in)

    @property
    def out_shape(self):
        return (self.batch, self.seq, self.d_out)

    @property
    def abar_extras(self):
        if self.activation == "none":
            return []  # linear backward needs only x and δ
        m = self.batch * self.seq
        return [TensorSpec("z", (m, self.d_out))]

    def flops_fwd(self):
        return 2 * self.batch * self.seq * self.d_in * self.d_out

    def _x2d(self, x):
        return x.reshape(self.batch * self.seq, self.d_in)

    def fwd(self, params, x):
        w, b = params
        y = fused_dense(self._x2d(x), w, b, activation=self.activation)
        return y.reshape(self.out_shape)

    def fwd_ref(self, params, x):
        w, b = params
        return dense_ref(x, w, b, self.activation)

    def fwd_all(self, params, x):
        w, b = params
        if self.activation == "none":
            return (self.fwd(params, x),)
        y, z = fused_dense_save(self._x2d(x), w, b, activation=self.activation)
        return (y.reshape(self.out_shape), z)

    def bwd(self, params, x, abar, dy):
        w, b = params
        x2d = self._x2d(x)
        dy2d = dy.reshape(self.batch * self.seq, self.d_out)
        if self.activation == "none":
            dz = dy2d
        else:
            (_, z) = abar
            dz = dy2d * gelu_grad(z)
        dx = (dz @ w.T).reshape(self.in_shape)
        gw = x2d.T @ dz
        gb = jnp.sum(dz, axis=0)
        return dx, gw, gb


# ---------------------------------------------------------------------------
# Mlp: pre-LN feed-forward block with residual
#   y = x + W2·gelu(W1·LN(x)+c1)+c2
# ---------------------------------------------------------------------------


class Mlp(Stage):
    kind = "mlp"

    def __init__(self, batch, seq, d, f):
        super().__init__(batch, seq)
        self.d, self.f = d, f

    @property
    def sig(self):
        return f"mlp_b{self.batch}t{self.seq}_{self.d}x{self.f}"

    @property
    def params(self):
        return [
            ParamSpec("g", (self.d,), "ones"),
            ParamSpec("beta", (self.d,), "zeros"),
            ParamSpec("w1", (self.d, self.f), "xavier"),
            ParamSpec("c1", (self.f,), "zeros"),
            ParamSpec("w2", (self.f, self.d), "xavier"),
            ParamSpec("c2", (self.d,), "zeros"),
        ]

    @property
    def in_shape(self):
        return (self.batch, self.seq, self.d)

    out_shape = in_shape

    @property
    def abar_extras(self):
        m = self.batch * self.seq
        return [
            TensorSpec("xhat", (m, self.d)),
            TensorSpec("rstd", (m,)),
            TensorSpec("z1", (m, self.f)),
            TensorSpec("u", (m, self.f)),
        ]

    def flops_fwd(self):
        return 4 * self.batch * self.seq * self.d * self.f

    def _pieces(self, params, x):
        g, beta, w1, c1, w2, c2 = params
        x2d = x.reshape(self.batch * self.seq, self.d)
        xhat, rstd = layernorm(x2d)
        h = xhat * g + beta
        u, z1 = fused_dense_save(h, w1, c1, activation="gelu")  # u = gelu(z1)
        z2 = fused_dense(u, w2, c2, activation="none")
        y = x + z2.reshape(self.in_shape)
        return y, xhat, rstd, z1, u

    def fwd(self, params, x):
        return self._pieces(params, x)[0]

    def fwd_ref(self, params, x):
        g, beta, w1, c1, w2, c2 = params
        x2d = x.reshape(self.batch * self.seq, self.d)
        xhat, rstd = layernorm_ref(x2d)
        h = xhat * g + beta
        u = dense_ref(h, w1, c1, "gelu")
        z2 = dense_ref(u, w2, c2, "none")
        return x + z2.reshape(self.in_shape)

    def fwd_all(self, params, x):
        y, xhat, rstd, z1, u = self._pieces(params, x)
        return (y, xhat, rstd, z1, u)

    def bwd(self, params, x, abar, dy):
        g, beta, w1, c1, w2, c2 = params
        (_, xhat, rstd, z1, u) = abar
        m = self.batch * self.seq
        dy2d = dy.reshape(m, self.d)
        # residual: y = x + z2  → dz2 = dy
        gw2 = u.T @ dy2d
        gc2 = jnp.sum(dy2d, axis=0)
        du = dy2d @ w2.T
        dz1 = du * gelu_grad(z1)
        h = xhat * g + beta  # cheap recompute from checkpointed xhat
        gw1 = h.T @ dz1
        gc1 = jnp.sum(dz1, axis=0)
        dh = dz1 @ w1.T
        dx_ln, gg, gbeta = _ln_bwd(dh, xhat, rstd, g)
        dx = dy + dx_ln.reshape(self.in_shape)
        return dx, gg, gbeta, gw1, gc1, gw2, gc2


# ---------------------------------------------------------------------------
# Attn: pre-LN multi-head self-attention block with residual
# ---------------------------------------------------------------------------


class Attn(Stage):
    kind = "attn"

    def __init__(self, batch, seq, d, heads):
        super().__init__(batch, seq)
        assert d % heads == 0
        self.d, self.heads = d, heads
        self.dh = d // heads

    @property
    def sig(self):
        return f"attn_b{self.batch}t{self.seq}_{self.d}h{self.heads}"

    @property
    def params(self):
        d = self.d
        return [
            ParamSpec("g", (d,), "ones"),
            ParamSpec("beta", (d,), "zeros"),
            ParamSpec("wq", (d, d), "xavier"),
            ParamSpec("wk", (d, d), "xavier"),
            ParamSpec("wv", (d, d), "xavier"),
            ParamSpec("wo", (d, d), "xavier"),
        ]

    @property
    def in_shape(self):
        return (self.batch, self.seq, self.d)

    out_shape = in_shape

    @property
    def abar_extras(self):
        m = self.batch * self.seq
        bh, t, dh = self.batch * self.heads, self.seq, self.dh
        return [
            TensorSpec("xhat", (m, self.d)),
            TensorSpec("rstd", (m,)),
            TensorSpec("q", (bh, t, dh)),
            TensorSpec("k", (bh, t, dh)),
            TensorSpec("v", (bh, t, dh)),
            TensorSpec("p", (bh, t, t)),  # the big one: O(T²) attention probs
            TensorSpec("c", (bh, t, dh)),
        ]

    def flops_fwd(self):
        m = self.batch * self.seq
        proj = 4 * 2 * m * self.d * self.d
        scores = 2 * 2 * self.batch * self.heads * self.seq * self.seq * self.dh
        return proj + scores

    def _split(self, t2d):
        # (M, D) → (B·H, T, dh)
        return (
            t2d.reshape(self.batch, self.seq, self.heads, self.dh)
            .transpose(0, 2, 1, 3)
            .reshape(self.batch * self.heads, self.seq, self.dh)
        )

    def _merge(self, t3d):
        # (B·H, T, dh) → (M, D)
        return (
            t3d.reshape(self.batch, self.heads, self.seq, self.dh)
            .transpose(0, 2, 1, 3)
            .reshape(self.batch * self.seq, self.d)
        )

    def _pieces(self, params, x):
        g, beta, wq, wk, wv, wo = params
        m = self.batch * self.seq
        x2d = x.reshape(m, self.d)
        xhat, rstd = layernorm(x2d)
        h = xhat * g + beta
        q = self._split(h @ wq)
        k = self._split(h @ wk)
        v = self._split(h @ wv)
        c, p = attention(q, k, v)
        o = self._merge(c) @ wo
        y = x + o.reshape(self.in_shape)
        return y, xhat, rstd, q, k, v, p, c

    def fwd(self, params, x):
        return self._pieces(params, x)[0]

    def fwd_ref(self, params, x):
        g, beta, wq, wk, wv, wo = params
        m = self.batch * self.seq
        x2d = x.reshape(m, self.d)
        xhat, rstd = layernorm_ref(x2d)
        h = xhat * g + beta
        q = self._split(h @ wq).reshape(self.batch, self.heads, self.seq, self.dh)
        k = self._split(h @ wk).reshape(self.batch, self.heads, self.seq, self.dh)
        v = self._split(h @ wv).reshape(self.batch, self.heads, self.seq, self.dh)
        c, _ = attention_ref(q, k, v)
        o = self._merge(c.reshape(self.batch * self.heads, self.seq, self.dh)) @ wo
        return x + o.reshape(self.in_shape)

    def fwd_all(self, params, x):
        return self._pieces(params, x)

    def bwd(self, params, x, abar, dy):
        g, beta, wq, wk, wv, wo = params
        (_, xhat, rstd, q, k, v, p, c) = abar
        m = self.batch * self.seq
        dy2d = dy.reshape(m, self.d)
        cf = self._merge(c)
        # output projection
        gwo = cf.T @ dy2d
        dc = self._split(dy2d @ wo.T)
        # attention: c = p @ v
        dp = jnp.einsum("btd,bsd->bts", dc, v)
        dv = jnp.einsum("bts,btd->bsd", p, dc)
        # softmax backward
        ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
        scale = 1.0 / jnp.sqrt(jnp.asarray(self.dh, DTYPE))
        dq = jnp.einsum("bts,bsd->btd", ds, k) * scale
        dk = jnp.einsum("bts,btd->bsd", ds, q) * scale
        # projections back to h
        dq2d, dk2d, dv2d = self._merge(dq), self._merge(dk), self._merge(dv)
        h = xhat * g + beta
        gwq = h.T @ dq2d
        gwk = h.T @ dk2d
        gwv = h.T @ dv2d
        dh = dq2d @ wq.T + dk2d @ wk.T + dv2d @ wv.T
        dx_ln, gg, gbeta = _ln_bwd(dh, xhat, rstd, g)
        dx = dy + dx_ln.reshape(self.in_shape)
        return dx, gg, gbeta, gwq, gwk, gwv, gwo


# ---------------------------------------------------------------------------
# Loss (stage L+1 in the paper): mean-squared error against a per-batch
# target fed by the executor as a "data" parameter. δ^{L+1} is the scalar 1.
# ---------------------------------------------------------------------------


class Loss(Stage):
    kind = "loss"

    def __init__(self, batch, seq, d):
        super().__init__(batch, seq)
        self.d = d

    @property
    def sig(self):
        return f"loss_b{self.batch}t{self.seq}_{self.d}"

    @property
    def params(self):
        return [ParamSpec("target", (self.batch, self.seq, self.d), "data")]

    @property
    def in_shape(self):
        return (self.batch, self.seq, self.d)

    @property
    def out_shape(self):
        return ()  # scalar loss

    @property
    def abar_extras(self):
        return []

    @property
    def delta_out_shape(self):
        return ()

    def flops_fwd(self):
        return 3 * self.batch * self.seq * self.d

    def fwd(self, params, x):
        (t,) = params
        return jnp.mean((x - t) ** 2)

    fwd_ref = fwd

    def fwd_all(self, params, x):
        return (self.fwd(params, x),)

    def bwd(self, params, x, abar, dy):
        (t,) = params
        n = _nelem(self.in_shape)
        dx = dy * 2.0 * (x - t) / n
        # the target is data, not a parameter: no gradient emitted
        return (dx,)
