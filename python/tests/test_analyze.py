"""§Perf structural targets for the Pallas kernels, asserted.

See compile/analyze.py: interpret-mode wall-clock is meaningless for TPU,
so the perf contract for L1 is structural — every kernel instantiation
used by the shipped presets must (1) fit its per-step working set in the
VMEM budget with double-buffering headroom, and (2) keep matmul tiles
MXU-shaped wherever a matmul exists.
"""

import pytest

from compile.analyze import VMEM_BUDGET, analyze_chain, dense_report

PRESETS = ["quickstart", "default", "wide"]


@pytest.mark.parametrize("preset", PRESETS)
def test_vmem_budget_with_double_buffering(preset):
    for r in analyze_chain(preset):
        assert r.vmem_bytes * 2 <= VMEM_BUDGET, (
            f"{r.name}: {r.vmem_bytes}B x2 (double-buffered) exceeds VMEM"
        )


@pytest.mark.parametrize("preset", ["default", "wide"])
def test_matmul_tiles_are_mxu_shaped(preset):
    # (the `quickstart` preset is deliberately tiny for smoke tests and
    # exempt — its 16-token attention can't fill a 128-wide array)
    for r in analyze_chain(preset):
        if r.mxu_util > 0.0:  # kernels that use the MXU at all
            assert r.mxu_util >= 0.5, f"{r.name}: MXU util {r.mxu_util:.0%}"


def test_wide_preset_hits_full_mxu_tiles():
    # d=768, ffn=3072, seq*batch = 512: every matmul tile dimension is a
    # multiple of 128 → 100% fill of the systolic array
    for r in analyze_chain("wide"):
        if "dense" in r.name or "ffn" in r.name:
            assert r.mxu_util == 1.0, f"{r.name}: {r.mxu_util:.0%}"


def test_grid_covers_whole_problem():
    r = dense_report("probe", m=512, k=256, n=256, save=False)
    gm, gn = r.grid
    assert gm * min(512, 128) == 512
    assert gn * min(256, 128) == 256


def test_report_notes_mention_tiling():
    r = dense_report("probe", m=512, k=256, n=256, save=True)
    assert "128×256" in r.notes and "preact" in r.notes
