"""AOT pipeline: HLO text artifacts + manifest consistency.

Checks that lowering produces parseable HLO text with the calling
convention the Rust runtime expects (parameter arity, tuple outputs), and
that the manifest's byte/shape arithmetic agrees with the stage specs.
"""

import json

import numpy as np
import pytest

from compile.aot import build_manifest, lower_stage
from compile.model import build_chain
from compile.stages import Dense, Loss


@pytest.fixture(scope="module")
def lowered_dense():
    return Dense(2, 8, 16, 16, activation="gelu"), lower_stage(
        Dense(2, 8, 16, 16, activation="gelu")
    )


def test_hlo_text_has_entry(lowered_dense):
    _, hlos = lowered_dense
    for entry, text in hlos.items():
        assert "ENTRY" in text, entry
        assert "HloModule" in text, entry


def _entry_param_count(text: str) -> int:
    """Number of parameters of the ENTRY computation (ignores the parameter
    instructions of nested fused/mapped computations)."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    count = 0
    for line in lines[start:]:
        if "parameter(" in line:
            count += 1
        if line.strip() == "}":
            break
    return count


def test_hlo_parameter_arity(lowered_dense):
    stage, hlos = lowered_dense
    n_params = len(stage.params)
    # fwd/fwd_all take θ… + a_in
    for entry in ("fwd", "fwd_all"):
        count = _entry_param_count(hlos[entry])
        assert count == n_params + 1, (entry, count)
    # bwd takes θ… + a_in + ā(1+extras) + δ
    n_abar = 1 + len(stage.abar_extras)
    assert _entry_param_count(hlos["bwd"]) == n_params + 1 + n_abar + 1


def test_hlo_output_is_tuple(lowered_dense):
    # return_tuple=True: the ROOT of every entry computation is a tuple,
    # which the Rust side unwraps positionally.
    _, hlos = lowered_dense
    for entry, text in hlos.items():
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        assert any("tuple" in l or "(" in l for l in root_lines), entry


def test_loss_stage_lowered_shapes():
    stage = Loss(2, 8, 16)
    hlos = lower_stage(stage)
    # loss fwd output is a scalar f32
    assert "f32[]" in hlos["fwd"]
    # bwd emits only δ_in (no grads for the data param)
    assert "f32[2,8,16]" in hlos["bwd"]


def test_manifest_consistency():
    chain = build_chain("quickstart")
    files = {
        st.sig: {e: f"{st.sig}_{e}.hlo.txt" for e in ("fwd", "fwd_all", "bwd")}
        for st in chain.stages
    }
    m = build_manifest(chain, files)
    assert m["preset"] == "quickstart"
    assert len(m["stages"]) == chain.length
    # every referenced signature exists
    for entry in m["stages"]:
        assert entry["sig"] in m["signatures"]
    # shape chaining recorded correctly
    sigs = m["signatures"]
    seq = [sigs[s["sig"]] for s in m["stages"]]
    for a, b in zip(seq, seq[1:]):
        assert a["out_shape"] == b["in_shape"]
    # byte accounting matches the stage objects
    for st in chain.stages:
        rec = sigs[st.sig]
        assert rec["w_a"] == st.w_a
        assert rec["w_abar"] == st.w_abar
        assert rec["w_abar"] >= rec["w_a"]
        n_extras = len(rec["abar_extras"])
        expected = rec["w_a"] + sum(
            int(np.prod(t["shape"])) * 4 for t in rec["abar_extras"]
        )
        assert rec["w_abar"] == expected, (st.sig, n_extras)
    # manifest is JSON-serializable as written
    json.dumps(m)


def test_signature_dedup():
    """Two stages with the same signature must share one artifact set."""
    chain = build_chain("default")
    sig_list = [s.sig for s in chain.stages]
    m = build_manifest(
        chain,
        {
            s.sig: {e: "x" for e in ("fwd", "fwd_all", "bwd")}
            for s in chain.stages
        },
    )
    assert len(m["signatures"]) == len(set(sig_list))
    assert len(m["signatures"]) < len(sig_list)  # default preset repeats blocks
