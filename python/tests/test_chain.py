"""Composed-chain correctness: the store-all schedule in pure JAX must
reproduce jax.grad of the end-to-end loss, and the chain presets must have
the shape/accounting structure the Rust side assumes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    PRESETS,
    build_chain,
    chain_backward_manual,
    chain_forward,
    chain_forward_ref,
    init_chain_params,
)
from compile.stages import Loss


@pytest.fixture(scope="module")
def quickstart():
    chain = build_chain("quickstart")
    params = init_chain_params(chain, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(7), chain.input_shape, jnp.float32)
    return chain, params, x


def test_forward_matches_ref(quickstart):
    chain, params, x = quickstart
    np.testing.assert_allclose(
        chain_forward(chain, params, x),
        chain_forward_ref(chain, params, x),
        atol=1e-4,
        rtol=1e-4,
    )


def test_manual_backward_matches_autodiff(quickstart):
    chain, params, x = quickstart
    loss, dx, grads = chain_backward_manual(chain, params, x)

    def loss_fn(ps, xx):
        return chain_forward_ref(chain, ps, xx)

    g_auto, dx_auto = jax.grad(loss_fn, argnums=(0, 1))(params, x)
    np.testing.assert_allclose(dx, dx_auto, atol=2e-4, rtol=2e-4)
    for i, (stage, gm, ga) in enumerate(zip(chain.stages, grads, g_auto)):
        trainable = [p for p in stage.params if p.init != "data"]
        assert len(gm) == len(trainable), stage.sig
        for j in range(len(gm)):
            np.testing.assert_allclose(
                gm[j], ga[j], atol=2e-4, rtol=2e-4, err_msg=f"stage {i} grad {j}"
            )


def test_loss_is_finite_scalar(quickstart):
    chain, params, x = quickstart
    loss = chain_forward(chain, params, x)
    assert loss.shape == ()
    assert np.isfinite(float(loss))


def test_gradient_step_decreases_loss(quickstart):
    """One SGD step along the manual gradients must reduce the loss —
    the end-to-end signal the Rust trainer reproduces."""
    chain, params, x = quickstart
    loss0, _, grads = chain_backward_manual(chain, params, x)
    lr = 0.05
    new_params = []
    for stage, ps, gs in zip(chain.stages, params, grads):
        trainable = iter(gs)
        updated = []
        for spec, p in zip(stage.params, ps):
            if spec.init == "data":
                updated.append(p)
            else:
                updated.append(p - lr * next(trainable))
        new_params.append(updated)
    loss1 = chain_forward(chain, new_params, x)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_preset_structure(preset):
    chain = build_chain(preset)
    # last stage is the loss, shapes chain up correctly
    assert isinstance(chain.stages[-1], Loss)
    for a, b in zip(chain.stages, chain.stages[1:]):
        assert a.out_shape == b.in_shape, (a.sig, b.sig)
    assert chain.param_count() > 0
    # ω_ā ≥ ω_a everywhere (ā includes a) — the DP relies on this
    for st in chain.stages:
        assert st.w_abar >= st.w_a


def test_heterogeneity_is_real():
    """The paper's whole point: stages must differ in ω_ā/ω_a ratios.
    attention (checkpoints the T×T probs) must be far heavier relative to
    its output than the linear head (ratio exactly 1)."""
    chain = build_chain("default")
    ratios = {st.kind: st.w_abar / max(st.w_a, 1) for st in chain.stages}
    assert ratios["attn"] > 2.0
    assert any(
        st.kind == "dense" and st.w_abar == st.w_a for st in chain.stages
    ), "expected a linear stage with ā == {a}"


def test_override_plumbs_through():
    chain = build_chain("quickstart", batch=3, seq=8, blocks=2)
    assert chain.input_shape[0] == 3 and chain.input_shape[1] == 8
    # 2 transformer blocks → 2·(attn+mlp) + dense head/tail + loss
    assert chain.length == 2 * 2 + 2 + 1
