"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the activation switch) so the BlockSpec
tiling logic is exercised across divisible/non-divisible, tiny and
MXU-sized dimensions.  THE core correctness signal for layer 1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_dense, fused_dense_save, layernorm, pick_block
from compile.kernels.ref import (
    attention_ref,
    dense_ref,
    dense_preact_ref,
    gelu,
    gelu_grad,
    layernorm_ref,
    softmax_ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------


@given(dim=st.integers(1, 4096), target=st.integers(1, 256))
@settings(**SETTINGS)
def test_pick_block_divides(dim, target):
    b = pick_block(dim, target)
    assert dim % b == 0
    assert 1 <= b <= max(dim, target)


def test_pick_block_prefers_target():
    assert pick_block(256, 128) == 128
    assert pick_block(512, 128) == 128
    assert pick_block(100, 128) == 100  # whole dim when smaller than target
    assert pick_block(96, 128) == 96


# ---------------------------------------------------------------------------
# fused dense
# ---------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 4, 32, 128, 160, 256]),
    k=st.sampled_from([8, 48, 64]),
    n=st.sampled_from([8, 24, 64, 128]),
    act=st.sampled_from(["gelu", "none"]),
)
@settings(**SETTINGS)
def test_fused_dense_matches_ref(m, k, n, act):
    x = _rand(0, (m, k))
    w = _rand(1, (k, n), 0.3)
    b = _rand(2, (n,))
    got = fused_dense(x, w, b, activation=act)
    want = dense_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@given(
    m=st.sampled_from([4, 64, 256]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([16, 128]),
)
@settings(**SETTINGS)
def test_fused_dense_save_matches_ref(m, k, n):
    x = _rand(3, (m, k))
    w = _rand(4, (k, n), 0.3)
    b = _rand(5, (n,))
    y, z = fused_dense_save(x, w, b, activation="gelu")
    np.testing.assert_allclose(z, dense_preact_ref(x, w, b), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(y, gelu(jnp.asarray(z)), atol=1e-5, rtol=1e-5)
    # the two entry points must agree exactly on y
    np.testing.assert_allclose(y, fused_dense(x, w, b, activation="gelu"), atol=1e-6)


def test_fused_dense_save_linear_identity():
    # activation="none": y == z (stored pre-activation is the output)
    x, w, b = _rand(0, (8, 8)), _rand(1, (8, 8)), _rand(2, (8,))
    y, z = fused_dense_save(x, w, b, activation="none")
    np.testing.assert_allclose(y, z, atol=0)


# ---------------------------------------------------------------------------
# gelu derivative (consumed by every hand-derived backward)
# ---------------------------------------------------------------------------


@given(scale=st.sampled_from([0.1, 1.0, 3.0]))
@settings(**SETTINGS)
def test_gelu_grad_matches_autodiff(scale):
    z = _rand(7, (64,), scale)
    auto = jax.vmap(jax.grad(gelu))(z)
    np.testing.assert_allclose(gelu_grad(z), auto, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 8, 128, 192]),
    d=st.sampled_from([4, 64, 256]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
@settings(**SETTINGS)
def test_layernorm_matches_ref(m, d, scale):
    x = _rand(8, (m, d), scale)
    xhat, rstd = layernorm(x)
    xhat_ref, rstd_ref = layernorm_ref(x)
    np.testing.assert_allclose(xhat, xhat_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(rstd, rstd_ref[:, 0], atol=1e-4, rtol=1e-4)


def test_layernorm_rows_normalized():
    x = _rand(9, (32, 128), 5.0)
    xhat, _ = layernorm(x)
    np.testing.assert_allclose(np.mean(xhat, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(np.asarray(xhat), -1), 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@given(
    bh=st.sampled_from([1, 2, 8]),
    t=st.sampled_from([4, 16, 64]),
    dh=st.sampled_from([8, 16, 64]),
)
@settings(**SETTINGS)
def test_attention_matches_ref(bh, t, dh):
    q = _rand(10, (bh, t, dh))
    k = _rand(11, (bh, t, dh))
    v = _rand(12, (bh, t, dh))
    c, p = attention(q, k, v)
    cr, pr = attention_ref(q[:, None], k[:, None], v[:, None])
    np.testing.assert_allclose(c, cr[:, 0], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(p, pr[:, 0], atol=1e-5, rtol=1e-5)


def test_attention_probs_are_distributions():
    q = _rand(13, (4, 32, 16), 2.0)
    k = _rand(14, (4, 32, 16), 2.0)
    v = _rand(15, (4, 32, 16))
    _, p = attention(q, k, v)
    assert np.all(np.asarray(p) >= 0)
    np.testing.assert_allclose(np.sum(p, -1), 1.0, atol=1e-5)


def test_softmax_ref_stable_at_large_logits():
    s = jnp.array([[1e4, 1e4 + 1.0, 0.0]])
    p = softmax_ref(s)
    assert np.all(np.isfinite(np.asarray(p)))
    np.testing.assert_allclose(np.sum(p, -1), 1.0, atol=1e-6)


def test_attention_uniform_probs_for_equal_keys():
    # identical keys → uniform attention → ctx is the mean of v rows
    q = _rand(16, (2, 8, 4))
    k = jnp.ones((2, 8, 4), jnp.float32)
    v = _rand(17, (2, 8, 4))
    c, p = attention(q, k, v)
    np.testing.assert_allclose(p, 1.0 / 8, atol=1e-6)
    np.testing.assert_allclose(c, jnp.mean(v, 1, keepdims=True).repeat(8, 1), atol=1e-5)
