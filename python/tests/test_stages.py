"""L2 correctness: per-stage hand-derived backwards vs jax.vjp.

For every stage kind we check, on randomized inputs:
  1. fwd == fwd_ref (the Pallas path equals the pure-jnp path),
  2. fwd_all[0] == fwd (F_all and F∅ compute the same a_out),
  3. fwd_all extras have exactly the manifest shapes,
  4. bwd(δ) == jax.vjp(fwd_ref)(δ) for both δ_in and every parameter grad,
  5. the ω_a / ω_ā byte arithmetic matches the actual tensors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import init_stage_params
from compile.stages import Attn, Dense, Loss, Mlp

B, T = 2, 16


def stage_cases():
    return [
        Dense(B, T, 32, 48, activation="gelu"),
        Dense(B, T, 48, 32, activation="none"),
        Mlp(B, T, 32, 64),
        Attn(B, T, 32, 4),
        Loss(B, T, 32),
    ]


@pytest.fixture(params=stage_cases(), ids=lambda s: s.sig)
def stage(request):
    return request.param


def _inputs(stage, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_stage_params(stage, key)
    # perturb zero-initialized params so grads are informative
    params = [
        p + 0.01 * jax.random.normal(jax.random.PRNGKey(i + 100), p.shape)
        for i, p in enumerate(params)
    ]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), stage.in_shape, jnp.float32)
    dy = jax.random.normal(
        jax.random.PRNGKey(seed + 2), stage.delta_out_shape, jnp.float32
    )
    return params, x, dy


def test_fwd_matches_ref(stage):
    params, x, _ = _inputs(stage)
    np.testing.assert_allclose(
        stage.fwd(params, x), stage.fwd_ref(params, x), atol=1e-4, rtol=1e-4
    )


def test_fwd_all_head_is_fwd(stage):
    params, x, _ = _inputs(stage)
    abar = stage.fwd_all(params, x)
    np.testing.assert_allclose(abar[0], stage.fwd(params, x), atol=1e-6, rtol=1e-6)


def test_abar_shapes_match_spec(stage):
    params, x, _ = _inputs(stage)
    abar = stage.fwd_all(params, x)
    assert len(abar) == 1 + len(stage.abar_extras)
    assert abar[0].shape == stage.out_shape
    for tensor, spec in zip(abar[1:], stage.abar_extras):
        assert tensor.shape == spec.shape, spec.name


def test_memory_sizes_match_tensors(stage):
    params, x, _ = _inputs(stage)
    abar = stage.fwd_all(params, x)
    actual_abar_bytes = sum(int(np.prod(t.shape)) * 4 for t in abar)
    assert stage.w_abar == actual_abar_bytes
    assert stage.w_a == int(np.prod(stage.out_shape)) * 4


def test_bwd_matches_vjp(stage):
    params, x, dy = _inputs(stage)
    abar = stage.fwd_all(params, x)
    out = stage.bwd(params, x, abar, dy)
    dx_manual, grads_manual = out[0], out[1:]

    y_ref, vjp = jax.vjp(lambda p, xx: stage.fwd_ref(p, xx), params, x)
    grads_auto, dx_auto = vjp(dy)

    np.testing.assert_allclose(dx_manual, dx_auto, atol=2e-4, rtol=2e-4)
    trainable = [p for p in stage.params if p.init != "data"]
    assert len(grads_manual) == len(trainable)
    for gm, ga, spec in zip(grads_manual, grads_auto, stage.params):
        np.testing.assert_allclose(
            gm, ga, atol=2e-4, rtol=2e-4, err_msg=f"grad {spec.name}"
        )


def test_bwd_linearity_in_delta(stage):
    """B is linear in δ: bwd(2δ) == 2·bwd(δ) — a structural invariant the
    executor exploits when seeding δ^{L+1} = 1."""
    params, x, dy = _inputs(stage)
    abar = stage.fwd_all(params, x)
    out1 = stage.bwd(params, x, abar, dy)
    out2 = stage.bwd(params, x, abar, 2.0 * dy)
    for a, b in zip(out1, out2):
        np.testing.assert_allclose(2.0 * a, b, atol=1e-4, rtol=1e-4)


def test_loss_gradient_direction():
    """MSE loss: δ_in must point from target toward prediction."""
    stage = Loss(B, T, 8)
    t = jnp.zeros(stage.in_shape)
    x = jnp.ones(stage.in_shape)
    (dx,) = stage.bwd([t], x, (stage.fwd([t], x),), jnp.ones(()))
    n = float(np.prod(stage.in_shape))
    np.testing.assert_allclose(dx, 2.0 / n, atol=1e-6)


def test_dense_linear_has_empty_abar():
    """A pure linear stage needs no extra checkpoint: ā == {a} exactly, so
    the DP should see ω_ā == ω_a (the F_all-dominates-Fck corner)."""
    st = Dense(B, T, 16, 16, activation="none")
    assert st.abar_extras == []
    assert st.w_abar == st.w_a
