//! Executor benchmark: end-to-end iteration throughput of a really
//! executing chain per strategy, plus the L3 replay *overhead* — the time
//! the coordinator spends outside stage compute (value store, ledger,
//! tensor plumbing). DESIGN.md §Perf targets replay overhead < 5 % of
//! step time.
//!
//! Runs the native engine by default (a real hot path on any machine);
//! `--backend pjrt --artifacts DIR` measures the PJRT build instead.
//!
//! ```sh
//! cargo bench --bench bench_executor -- [--preset quickstart] [--reps 5]
//! ```

use std::time::Instant;

use chainckpt::backend::{Backend, Tensor};
use chainckpt::estimator::{estimate, measured_chain, EstimatorConfig};
use chainckpt::executor::Executor;
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::{periodic_schedule, solve, store_all_schedule, Mode, Schedule};
use chainckpt::util::{fmt_bytes, median, Args, Rng};

fn main() {
    let args = Args::from_env();
    match args.str("backend", "native").as_str() {
        "native" => {
            let preset = args.str("preset", "quickstart");
            let rt = Runtime::native_preset(&preset).expect("building native preset");
            bench(&rt, &args);
        }
        "pjrt" => {
            let dir = args.str("artifacts", "artifacts/quickstart");
            match Runtime::load(&dir) {
                Ok(rt) => bench(&rt, &args),
                Err(e) => eprintln!("skipping pjrt executor bench: {e:#} (run `make artifacts`)"),
            }
        }
        other => {
            eprintln!("--backend {other}: use native|pjrt");
            std::process::exit(2);
        }
    }
}

fn bench<B: Backend>(rt: &Runtime<B>, args: &Args) {
    let reps = args.usize("reps", 5);
    let cfg = EstimatorConfig::default();
    let chain = measured_chain(rt, cfg).unwrap();
    let n = rt.manifest.stages.len();
    let batch = rt.manifest.input_shape[0] as u64;

    let mut rng = Rng::new(9);
    let numel: usize = rt.manifest.input_shape.iter().product();
    let input = B::Tensor::from_vec(&rng.normal_vec(numel), &rt.manifest.input_shape).unwrap();
    let target = rng.normal_vec(rt.manifest.sig_of(n - 1).params[0].nelem());

    // pure-compute floor: Σ median entry times (what the stages alone cost)
    let timings = estimate(rt, cfg).unwrap();
    let compute_floor_ms: f64 = timings.iter().map(|t| (t.uf_us + t.ub_us) / 1e3).sum();

    let run = |name: &str, sched: &Schedule| {
        let sim = simulate(&chain, sched).unwrap();
        let mut ex = Executor::new(rt, 1).unwrap();
        ex.set_data_param(n - 1, &target).unwrap();
        let mut times = Vec::new();
        for r in 0..=reps {
            let t0 = Instant::now();
            ex.run(sched, &input, None).unwrap();
            if r > 0 {
                times.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        let t = median(&mut times);
        // overhead proxy: measured minus the per-op compute floor scaled
        // by the actual op multiset of this schedule
        let sched_floor: f64 = sched
            .ops
            .iter()
            .map(|op| {
                let l = op.stage() as usize;
                if l == 0 {
                    return 0.0;
                }
                match op {
                    chainckpt::solver::Op::Bwd(_) => timings[l - 1].ub_us / 1e3,
                    chainckpt::solver::Op::DropA(_) => 0.0,
                    _ => timings[l - 1].uf_us / 1e3,
                }
            })
            .sum();
        let overhead_pct = 100.0 * (t - sched_floor).max(0.0) / t;
        println!(
            "{name:<14} {:>4} ops  peak {:>12}  {:>8.2} ms/iter  {:>7.2} seq/s  L3 overhead ~{:>4.1}%",
            sched.ops.len(),
            fmt_bytes(sim.peak_bytes),
            t,
            batch as f64 * 1e3 / t,
            overhead_pct
        );
        (t, overhead_pct)
    };

    println!(
        "[{}] chain {} — compute floor {compute_floor_ms:.2} ms/iter",
        rt.backend.name(),
        chain.name
    );
    let (_, ov1) = run("pytorch", &store_all_schedule(&chain));
    run("sequential-2", &periodic_schedule(&chain, 2));
    run("sequential-4", &periodic_schedule(&chain, 4));
    let tight = chain.store_all_memory() * 3 / 4;
    if let Some(s) = solve(&chain, tight, 300, Mode::Full) {
        run("optimal-75%", &s);
    }
    if let Some(s) = solve(&chain, tight, 300, Mode::AdRevolve) {
        run("revolve-75%", &s);
    }
    println!(
        "\nDESIGN.md §Perf target: L3 replay overhead < 5 % of step time (store-all: {ov1:.1} %)"
    );
}
