//! Executor benchmark: end-to-end iteration throughput of a really
//! executing chain per strategy, plus the L3 replay *overhead* — the time
//! the coordinator spends outside stage compute (value store, ledger,
//! tensor plumbing). DESIGN.md §Perf targets replay overhead < 5 % of
//! step time.
//!
//! Every row is one `api::execute_schedule` measurement (fresh executor,
//! warmup + timed median) — the same execution path `chainckpt compare`
//! and `Plan::execute` use — and the DP rows come from one `api::Plan`
//! per mode.
//!
//! Runs the native engine by default (a real hot path on any machine);
//! `--backend pjrt --artifacts DIR` measures the PJRT build instead.
//!
//! ```sh
//! cargo bench --bench bench_executor -- [--preset quickstart] [--reps 5]
//! ```

use chainckpt::api::{
    execute_schedule, ChainSpec, ExecuteOptions, MemBytes, Mode, PlanRequest, SlotCount,
};
use chainckpt::backend::Backend;
use chainckpt::estimator::{estimate, measured_chain, EstimatorConfig};
use chainckpt::runtime::Runtime;
use chainckpt::solver::{periodic_schedule, store_all_schedule, Schedule};
use chainckpt::train::SyntheticData;
use chainckpt::util::{fmt_bytes, Args};

fn main() {
    let args = Args::from_env();
    match args.str("backend", "native").as_str() {
        "native" => {
            let preset = args.str("preset", "quickstart");
            let rt = Runtime::native_preset(&preset).expect("building native preset");
            bench(&rt, &args);
        }
        "pjrt" => {
            let dir = args.str("artifacts", "artifacts/quickstart");
            match Runtime::load(&dir) {
                Ok(rt) => bench(&rt, &args),
                Err(e) => eprintln!("skipping pjrt executor bench: {e:#} (run `make artifacts`)"),
            }
        }
        other => {
            eprintln!("--backend {other}: use native|pjrt");
            std::process::exit(2);
        }
    }
}

fn bench<B: Backend>(rt: &Runtime<B>, args: &Args) {
    let reps = args.usize("reps", 5);
    let cfg = EstimatorConfig::default();
    let chain = measured_chain(rt, cfg).unwrap();
    let batch = rt.manifest.input_shape[0] as u64;
    let data = SyntheticData::generate(&rt.manifest, 1, 9).expect("synthetic batch");
    let opts = ExecuteOptions { reps, ..ExecuteOptions::default() };

    // pure-compute floor: Σ median entry times (what the stages alone cost)
    let timings = estimate(rt, cfg).unwrap();
    let compute_floor_ms: f64 = timings.iter().map(|t| (t.uf_us + t.ub_us) / 1e3).sum();

    let run = |name: &str, sched: &Schedule| {
        let rep = execute_schedule(rt, sched, &data, &opts).unwrap();
        let t = rep.elapsed_s * 1e3;
        // overhead proxy: measured minus the per-op compute floor scaled
        // by the actual op multiset of this schedule
        let sched_floor: f64 = sched
            .ops
            .iter()
            .map(|op| {
                let l = op.stage() as usize;
                if l == 0 {
                    return 0.0;
                }
                match op {
                    chainckpt::solver::Op::Bwd(_) => timings[l - 1].ub_us / 1e3,
                    chainckpt::solver::Op::DropA(_) => 0.0,
                    _ => timings[l - 1].uf_us / 1e3,
                }
            })
            .sum();
        let overhead_pct = 100.0 * (t - sched_floor).max(0.0) / t;
        println!(
            "{name:<14} {:>4} ops  peak {:>12}  {:>8.2} ms/iter  {:>7.2} seq/s  L3 overhead ~{:>4.1}%",
            rep.ops,
            fmt_bytes(rep.peak.get()),
            t,
            batch as f64 * 1e3 / t,
            overhead_pct
        );
        (t, overhead_pct)
    };

    println!(
        "[{}] chain {} — compute floor {compute_floor_ms:.2} ms/iter",
        rt.backend.name(),
        chain.name
    );
    let (_, ov1) = run("pytorch", &store_all_schedule(&chain));
    run("sequential-2", &periodic_schedule(&chain, 2));
    run("sequential-4", &periodic_schedule(&chain, 4));
    let tight = MemBytes::new(chain.store_all_memory() * 3 / 4);
    for (label, mode) in [("optimal-75%", Mode::Full), ("revolve-75%", Mode::AdRevolve)] {
        let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), tight)
            .slots(SlotCount::new(300))
            .mode(mode)
            .plan()
            .expect("inline chain spec resolves");
        if let Some(s) = plan.schedule_at(tight) {
            run(label, &s);
        }
    }
    println!(
        "\nDESIGN.md §Perf target: L3 replay overhead < 5 % of step time (store-all: {ov1:.1} %)"
    );
}
