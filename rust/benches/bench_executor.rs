//! Executor benchmark: lowered (pooled, zero-alloc) vs legacy per-op
//! replay, per strategy, on a really executing chain.
//!
//! For every strategy the paper evaluates this measures, on the same
//! executor/params/data:
//!
//! * **step-time p50** of both replay paths (median of `--reps` timed
//!   iterations after warmup), and
//! * **steady-state allocations/iteration** of both paths, counted by a
//!   wrapping global allocator around one post-warmup iteration.
//!
//! Hard gates (process exits non-zero on failure, so CI catches
//! regressions):
//!
//! * the lowered path performs **0 steady-state allocations/iteration**
//!   on the default (quickstart) preset — bigger presets cross the
//!   matmul parallelism threshold, whose `thread::scope` spawns allocate
//!   by design;
//! * the lowered p50 shows no step-time regression vs legacy
//!   (≤ 1.25× slack for timer noise; in practice it is faster);
//! * span tracing costs ≤ 1.05× the untraced lowered p50 — the telemetry
//!   hot path (relaxed counters + a preallocated ring) must stay cheap
//!   enough to leave armed in production runs.
//!
//! Results land in `BENCH_executor.json` (with an embedded telemetry
//! registry snapshot and a measured-vs-predicted drift report), and the
//! traced replay's Chrome trace goes to `results/trace_quickstart.json`
//! (both uploaded as CI artifacts).
//!
//! ```sh
//! cargo bench --bench bench_executor -- [--preset quickstart] [--reps 7] [--quick]
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use chainckpt::api::{ChainSpec, MemBytes, Mode, PlanRequest, SlotCount};
use chainckpt::backend::{Backend, NativeTensor, Tensor};
use chainckpt::estimator::{measured_chain, EstimatorConfig};
use chainckpt::executor::Executor;
use chainckpt::runtime::Runtime;
use chainckpt::solver::{periodic_schedule, store_all_schedule, Schedule};
use chainckpt::telemetry;
use chainckpt::util::json::{obj, Value};
use chainckpt::util::{fmt_bytes, median, Args, Rng};

/// Counts every heap allocation (alloc / alloc_zeroed / realloc) so the
/// bench can prove the lowered hot path touches the allocator zero times
/// in steady state.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

struct Row {
    strategy: String,
    ops: usize,
    peak_bytes: u64,
    legacy_ms_p50: f64,
    lowered_ms_p50: f64,
    legacy_allocs: u64,
    lowered_allocs: u64,
}

fn main() {
    let args = Args::from_env();
    let preset = args.str("preset", "quickstart");
    let quick = args.has("quick");
    let reps = args.usize("reps", if quick { 3 } else { 7 });
    let rt = Runtime::native_preset(&preset).expect("building native preset");
    let chain = measured_chain(&rt, EstimatorConfig { reps: 1, warmup: 1 }).unwrap();

    // fixed input/target shared by every measurement
    let mut rng = Rng::new(9);
    let numel: usize = rt.manifest.input_shape.iter().product();
    let input =
        NativeTensor::from_vec(&rng.normal_vec(numel), &rt.manifest.input_shape).unwrap();
    let n_stages = rt.manifest.stages.len();
    let target = rng.normal_vec(rt.manifest.sig_of(n_stages - 1).params[0].nelem());

    let mut schedules: Vec<(String, Schedule)> = vec![
        ("pytorch".into(), store_all_schedule(&chain)),
        ("sequential-2".into(), periodic_schedule(&chain, 2)),
        ("sequential-4".into(), periodic_schedule(&chain, 4)),
    ];
    let tight = MemBytes::new(chain.store_all_memory() * 3 / 4);
    for (label, mode) in [("optimal-75%", Mode::Full), ("revolve-75%", Mode::AdRevolve)] {
        let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), tight)
            .slots(SlotCount::new(300))
            .mode(mode)
            .plan()
            .expect("inline chain spec resolves");
        if let Some(s) = plan.schedule_at(tight) {
            schedules.push((label.into(), s));
        }
    }

    println!(
        "[{}] chain {} — {} strategies × (legacy | lowered), {reps} reps",
        rt.backend.name(),
        chain.name,
        schedules.len()
    );
    println!(
        "{:<14} {:>5} {:>12} {:>14} {:>14} {:>13} {:>13}",
        "strategy", "ops", "peak", "legacy p50", "lowered p50", "legacy allocs", "lowered allocs"
    );

    let mut rows = Vec::new();
    for (name, sched) in &schedules {
        let row = measure(&rt, sched, &input, &target, n_stages - 1, reps, name);
        println!(
            "{:<14} {:>5} {:>12} {:>11.2} ms {:>11.2} ms {:>11}/it {:>11}/it",
            row.strategy,
            row.ops,
            fmt_bytes(row.peak_bytes),
            row.legacy_ms_p50,
            row.lowered_ms_p50,
            row.legacy_allocs,
            row.lowered_allocs
        );
        rows.push(row);
    }

    // traced replay of the first (store-all) schedule: the overhead gate
    // plus a sample Chrome trace artifact. The alloc-count iterations
    // above all ran untraced, so the zero-alloc gate is unaffected.
    let (_, trace_sched) = &schedules[0];
    let untraced_p50 = rows[0].lowered_ms_p50;
    let (traced_p50, drift) = measure_traced(
        &rt,
        &chain,
        trace_sched,
        &input,
        &target,
        n_stages - 1,
        reps,
        "results/trace_quickstart.json",
    );
    let trace_overhead = if untraced_p50 > 0.0 { traced_p50 / untraced_p50 } else { 1.0 };
    println!(
        "traced lowered p50: {traced_p50:.2} ms vs untraced {untraced_p50:.2} ms \
         (×{trace_overhead:.3})"
    );
    if let Some(d) = &drift {
        println!("{}", d.summary());
    }

    // gates
    let zero_alloc_gate_applies = preset == "quickstart";
    let zero_alloc_ok =
        !zero_alloc_gate_applies || rows.iter().all(|r| r.lowered_allocs == 0);
    let no_regression = rows
        .iter()
        .all(|r| r.lowered_ms_p50 <= r.legacy_ms_p50 * 1.25 + 0.05);
    let trace_overhead_ok = traced_p50 <= untraced_p50 * 1.05 + 0.05;
    println!();
    println!(
        "GATE lowered zero-alloc steady state: {}",
        if zero_alloc_ok { "PASS" } else { "FAIL" }
    );
    println!(
        "GATE lowered step-time no-regression (≤1.25× legacy p50): {}",
        if no_regression { "PASS" } else { "FAIL" }
    );
    println!(
        "GATE tracing overhead (≤1.05× untraced lowered p50): {}",
        if trace_overhead_ok { "PASS" } else { "FAIL" }
    );

    let json_rows: Vec<Value> = rows
        .iter()
        .map(|r| {
            obj([
                ("strategy", Value::from(r.strategy.clone())),
                ("ops", Value::from(r.ops)),
                ("peak_bytes", Value::from(r.peak_bytes)),
                ("legacy_ms_p50", Value::from(r.legacy_ms_p50)),
                ("lowered_ms_p50", Value::from(r.lowered_ms_p50)),
                ("legacy_allocs_per_iter", Value::from(r.legacy_allocs)),
                ("lowered_allocs_per_iter", Value::from(r.lowered_allocs)),
                (
                    "lowered_speedup",
                    Value::from(if r.lowered_ms_p50 > 0.0 {
                        r.legacy_ms_p50 / r.lowered_ms_p50
                    } else {
                        0.0
                    }),
                ),
            ])
        })
        .collect();
    let doc = obj([
        ("bench", Value::from("executor")),
        ("preset", Value::from(preset.clone())),
        ("reps", Value::from(reps)),
        ("rows", Value::Arr(json_rows)),
        (
            "tracing",
            obj([
                ("traced_ms_p50", Value::from(traced_p50)),
                ("untraced_ms_p50", Value::from(untraced_p50)),
                ("overhead_ratio", Value::from(trace_overhead)),
            ]),
        ),
        (
            "drift",
            drift
                .as_ref()
                .map(chainckpt::service::wire::drift_to_json)
                .unwrap_or(Value::Null),
        ),
        ("telemetry", telemetry::registry().snapshot()),
        (
            "gates",
            obj([
                ("lowered_zero_alloc", Value::Bool(zero_alloc_ok)),
                ("zero_alloc_gate_applies", Value::Bool(zero_alloc_gate_applies)),
                ("no_step_time_regression", Value::Bool(no_regression)),
                ("trace_overhead_ok", Value::Bool(trace_overhead_ok)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_executor.json", doc.to_json_string()).expect("writing bench json");
    println!("wrote BENCH_executor.json");

    if !zero_alloc_ok || !no_regression || !trace_overhead_ok {
        std::process::exit(1);
    }
}

/// Replay one schedule through the lowered path with the span tracer
/// armed: p50 of `reps` traced iterations, the Chrome trace written to
/// `trace_path`, and a drift report joining the traced iterations'
/// per-kind measurements against the chain's predictions.
#[allow(clippy::too_many_arguments)]
fn measure_traced(
    rt: &Runtime<chainckpt::backend::NativeBackend>,
    chain: &chainckpt::chain::Chain,
    sched: &Schedule,
    input: &NativeTensor,
    target: &[f32],
    loss_stage: usize,
    reps: usize,
    trace_path: &str,
) -> (f64, Option<telemetry::DriftReport>) {
    let mut ex = Executor::new(rt, 77).unwrap();
    ex.set_data_param(loss_stage, target).unwrap();
    let mut low = ex.lower(sched).unwrap();
    ex.run_lowered(&mut low, input, None).unwrap();
    ex.run_lowered(&mut low, input, None).unwrap();
    telemetry::trace_start(telemetry::DEFAULT_TRACE_CAPACITY);
    let (ops_t0, ns_t0) = telemetry::registry().kind_totals();
    let mut times = Vec::with_capacity(reps);
    let mut peak = 0u64;
    for _ in 0..reps {
        let res = ex.run_lowered(&mut low, input, None).unwrap();
        times.push(res.elapsed_s * 1e3);
        peak = res.peak_bytes;
    }
    let (ops_t1, ns_t1) = telemetry::registry().kind_totals();
    let (events, dropped) = telemetry::trace_stop();
    std::fs::create_dir_all("results").expect("creating results dir");
    std::fs::write(trace_path, telemetry::chrome_trace_json(&events))
        .expect("writing trace json");
    println!(
        "wrote {trace_path} ({} span events{})",
        events.len(),
        if dropped > 0 { format!(", {dropped} dropped") } else { String::new() }
    );
    let n = telemetry::OpKind::COUNT;
    let mut ops_avg = [0u64; 5];
    let mut ns_avg = [0u64; 5];
    for k in 0..n {
        ops_avg[k] = (ops_t1[k] - ops_t0[k]) / reps.max(1) as u64;
        ns_avg[k] = (ns_t1[k] - ns_t0[k]) / reps.max(1) as u64;
    }
    let drift = telemetry::drift_report(chain, sched, ops_avg, ns_avg, peak);
    (median(&mut times), drift)
}

/// Measure both replay paths for one schedule on one fresh executor per
/// path (fixed seed ⇒ identical params), returning p50 step times and
/// steady-state allocation counts.
fn measure(
    rt: &Runtime<chainckpt::backend::NativeBackend>,
    sched: &Schedule,
    input: &NativeTensor,
    target: &[f32],
    loss_stage: usize,
    reps: usize,
    name: &str,
) -> Row {
    // legacy path
    let mut ex = Executor::new(rt, 77).unwrap();
    ex.set_data_param(loss_stage, target).unwrap();
    ex.run(sched, input, None).unwrap(); // warmup
    let mut legacy_times = Vec::with_capacity(reps);
    let mut last_peak = 0;
    let mut last_ops = 0;
    for _ in 0..reps {
        let res = ex.run(sched, input, None).unwrap();
        legacy_times.push(res.elapsed_s * 1e3);
        last_peak = res.peak_bytes;
        last_ops = res.ops;
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    ex.run(sched, input, None).unwrap();
    let legacy_allocs = ALLOCS.load(Ordering::Relaxed) - before;

    // lowered path: same seed, schedule compiled once, pool persists
    let mut ex = Executor::new(rt, 77).unwrap();
    ex.set_data_param(loss_stage, target).unwrap();
    let mut low = ex.lower(sched).unwrap();
    // two warmups: the first grows the scratch pool to its high-water mark
    ex.run_lowered(&mut low, input, None).unwrap();
    ex.run_lowered(&mut low, input, None).unwrap();
    let mut lowered_times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let res = ex.run_lowered(&mut low, input, None).unwrap();
        lowered_times.push(res.elapsed_s * 1e3);
        assert_eq!(res.peak_bytes, last_peak, "{name}: lowered peak != legacy ledger peak");
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    ex.run_lowered(&mut low, input, None).unwrap();
    let lowered_allocs = ALLOCS.load(Ordering::Relaxed) - before;

    Row {
        strategy: name.to_string(),
        ops: last_ops,
        peak_bytes: last_peak,
        legacy_ms_p50: median(&mut legacy_times),
        lowered_ms_p50: median(&mut lowered_times),
        legacy_allocs,
        lowered_allocs,
    }
}
