//! Figure-regeneration benchmark: produces the data behind **every table
//! and figure of the paper's evaluation (§5.4, Figures 3–13)** plus the
//! headline summary table (optimal vs best-sequential average gain —
//! the paper reports **+17.2 %**), and times each figure.
//!
//! Output: `results/figure{3..13}.csv`, `results/summary.csv`, and a
//! printed per-figure gain table (this is `chainckpt figures` with
//! timing assertions wrapped around it).
//!
//! ```sh
//! cargo bench --bench bench_figures            # headline subset (3,5,6,9,12)
//! cargo bench --bench bench_figures -- --full  # every figure incl. the
//!                                              # ResNet-1001 sweeps (~25 min
//!                                              # on one core)
//! cargo bench --bench bench_figures -- --quick # figs 3 and 5 only
//! ```

use std::time::Instant;

use chainckpt::figures::{figure, optimal_vs_sequential, summary_gain, to_csv};
use chainckpt::solver::{cache_stats, clear_cache};
use chainckpt::util::Args;

fn main() {
    let args = Args::from_env();
    clear_cache();
    let figs: Vec<u32> = if args.has("quick") {
        vec![3, 5]
    } else if args.has("full") {
        (3..=13).collect()
    } else {
        vec![3, 5, 6, 9, 12] // one per family + the headline case
    };

    std::fs::create_dir_all("results").ok();
    let mut all = Vec::new();
    println!("{:>6} {:>8} {:>10} {:>18}", "figure", "panels", "time (s)", "avg gain vs seq");
    for f in figs {
        let t0 = Instant::now();
        let panels = figure(f);
        let dt = t0.elapsed().as_secs_f64();
        std::fs::write(format!("results/figure{f}.csv"), to_csv(&panels)).unwrap();
        let gain = summary_gain(&panels);
        println!(
            "{:>6} {:>8} {:>10.1} {:>17}",
            f,
            panels.len(),
            dt,
            gain.map(|g| format!("+{:.1} %", 100.0 * g)).unwrap_or_else(|| "-".into()),
        );
        all.extend(panels);
    }

    // headline summary table (paper: +17.2 % average)
    let mut csv = String::from("chain,batch,gain_pct,seq_img_s,opt_img_s\n");
    for p in &all {
        if let Ok((g, seq, opt)) = optimal_vs_sequential(p) {
            csv.push_str(&format!(
                "{},{},{:.2},{:.3},{:.3}\n",
                p.chain_name, p.batch, 100.0 * g, seq, opt
            ));
        }
    }
    std::fs::write("results/summary.csv", csv).unwrap();

    if let Some(g) = summary_gain(&all) {
        println!(
            "\nSUMMARY: optimal beats best sequential by +{:.1} % on average over {} panels \
             (paper §5.4: +17.2 %)",
            100.0 * g,
            all.len()
        );
        assert!(g > 0.0, "optimal must win on average");
    }

    // the planner contract: each panel's 10-budget sweep costs one table
    // lookup per (chain, mode) — 2 per panel — and repeated chains across
    // figures are served from the cache instead of re-running the DP
    let stats = cache_stats();
    println!(
        "planner cache: {} lookups for {} panels ({} DP builds, {} hits, {:.1} MiB resident)",
        stats.lookups,
        all.len(),
        stats.builds,
        stats.hits,
        stats.bytes as f64 / (1 << 20) as f64
    );
    assert_eq!(
        stats.lookups,
        2 * all.len() as u64,
        "a panel sweep must cost exactly one table lookup per (chain, mode)"
    );
    // solver fill internals from the process registry — the work behind
    // those builds, one line for the CI log
    let reg = chainckpt::telemetry::registry();
    println!(
        "solver fill: {} cells, {} runs, {} prune hits over {} diagonals ({:.2} s total)",
        reg.solver_cells_filled.get(),
        reg.solver_runs_emitted.get(),
        reg.solver_prune_hits.get(),
        reg.solver_diagonals.get(),
        reg.solver_fill_ns.get() as f64 / 1e9
    );
    println!("→ results/figure*.csv, results/summary.csv");
}
