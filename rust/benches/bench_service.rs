//! Planning-service loopback load benchmark, in two phases:
//!
//! 1. **Cold-start vs warm-start** — with a `table_dir` configured, the
//!    first answer for a chain costs a DP fill on a cold store but only
//!    a file load on a warm one. Both times are measured
//!    (`cold_start_us`, `warm_start_us`) and warm must beat cold.
//! 2. **Concurrent keep-alive scale** — 1024 simultaneously-open
//!    keep-alive connections (128× the 8 the old thread-per-connection
//!    bench could field) driven round-robin by a small fixed set of
//!    client threads, measuring end-to-end latency (p50/p99),
//!    throughput, and the cache hit rate. The process thread count is
//!    read from `/proc/self/status` *while all connections are open* to
//!    prove connections no longer cost threads.
//!
//! Custom harness (no criterion offline), same contract as the other
//! benches: human-readable table on stdout, machine-readable
//! `BENCH_service.json` (emitted with the crate's own JSON writer) plus
//! `results/bench_service.csv`.
//!
//! ```sh
//! cargo bench --bench bench_service            # full load
//! cargo bench --bench bench_service -- --quick # CI-sized subset
//! ```

use std::path::PathBuf;
use std::time::{Duration, Instant};

use chainckpt::service::http::Client;
use chainckpt::service::{serve, Server, ServiceConfig};
use chainckpt::solver::clear_cache;
use chainckpt::util::json::{obj, Value};
use chainckpt::util::Args;

fn percentile(sorted: &[u64], q: f64) -> u64 {
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// The kernel's view of how many threads this process is running
/// (`Threads:` in `/proc/self/status`); 0 if unreadable (non-Linux).
fn process_threads() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("Threads:"))
                .and_then(|l| l.split_ascii_whitespace().nth(1))
                .and_then(|n| n.parse().ok())
        })
        .unwrap_or(0)
}

fn start_server(workers: usize, table_dir: Option<PathBuf>) -> Server {
    serve(ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        read_timeout: Duration::from_secs(30),
        table_dir,
        ..ServiceConfig::default()
    })
    .expect("bind the loopback daemon")
}

/// One `/solve` round-trip; returns the latency in µs.
fn solve_once(client: &mut Client, body: &str) -> u64 {
    let t0 = Instant::now();
    let (status, resp) = client.request("POST", "/solve", Some(body)).expect("solve round-trip");
    let us = t0.elapsed().as_micros() as u64;
    assert_eq!(status, 200, "{resp}");
    assert!(resp.contains("\"feasible\":true"), "{resp}");
    us
}

fn cache_counters(addr: std::net::SocketAddr) -> (u64, u64, u64) {
    let mut probe = Client::connect(addr).unwrap();
    let (status, stats_body) = probe.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    let stats = Value::parse(&stats_body).expect("stats JSON");
    let cache = stats.get("planner_cache").expect("planner_cache in /stats");
    (
        cache.get("lookups").unwrap().as_u64().unwrap(),
        cache.get("hits").unwrap().as_u64().unwrap(),
        cache.get("builds").unwrap().as_u64().unwrap(),
    )
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    // the scale phase: a fixed, small driver-thread count fans out over
    // many keep-alive connections — conns no longer imply threads
    let driver_threads: usize = 16;
    let conns_per_thread: usize = 64; // 16 × 64 = 1024 concurrent connections
    let rounds: usize = if quick { 2 } else { 3 };

    // a mid-size profile: big enough that a cache miss is visible, small
    // enough that the cold fill stays in milliseconds; budget = half of
    // store-all, feasible for every ResNet (cf. the solver property tests)
    let chain = chainckpt::chain::profiles::resnet(50, 224, 16);
    let body = format!(
        r#"{{"chain": {{"profile": {{"family": "resnet", "depth": 50,
           "image": 224, "batch": 16}}}}, "memory": {}, "slots": 300}}"#,
        chain.store_all_memory() / 2
    );
    let body = body.as_str(); // scoped threads below borrow it

    let table_dir =
        std::env::temp_dir().join(format!("chainckpt-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&table_dir);

    // --- phase 1: cold start vs warm start through the table store ---
    let server = start_server(4, Some(table_dir.clone()));
    let addr = server.addr();

    let reg = chainckpt::telemetry::registry();

    clear_cache(); // empty LRU, empty dir: the genuine cold path
    let mut probe = Client::connect(addr).expect("connect");
    let cold_start_us = solve_once(&mut probe, body);
    let (_, _, cold_builds) = cache_counters(addr);
    let (store_misses, store_writes) = (reg.store_misses.get(), reg.store_writes.get());
    assert_eq!(cold_builds, 1, "cold start must be exactly one DP fill");
    assert_eq!(store_writes, 1, "the cold fill must be written to the store");

    clear_cache(); // empty LRU again (counters reset) — the table file survives
    let warm_start_us = solve_once(&mut probe, body);
    let (_, _, warm_builds) = cache_counters(addr);
    let (store_hits, store_errors) = (reg.store_hits.get(), reg.store_errors.get());
    assert_eq!(warm_builds, 0, "warm start must load from disk, not re-run the DP");
    assert_eq!(store_hits, 1, "warm start is a store hit");
    assert_eq!(store_errors, 0, "a clean store file must load without errors");
    assert!(
        warm_start_us < cold_start_us,
        "loading the stored table ({warm_start_us} µs) must beat re-filling the DP \
         ({cold_start_us} µs)"
    );
    drop(probe);
    server.stop();

    // --- phase 2: concurrent keep-alive scale ---
    // fresh daemon, same store: the one table is loaded once from disk
    clear_cache();
    let server = start_server(driver_threads, Some(table_dir.clone()));
    let addr = server.addr();
    let threads_idle = process_threads();

    let t0 = Instant::now();
    let (mut latencies, threads_under_load): (Vec<u64>, u64) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..driver_threads)
            .map(|_| {
                scope.spawn(move || {
                    // open this thread's share of connections *first* so
                    // all 1024 are simultaneously established…
                    let mut clients: Vec<Client> = (0..conns_per_thread)
                        .map(|_| Client::connect(addr).expect("connect keep-alive conn"))
                        .collect();
                    // …then drive them round-robin
                    let mut lats = Vec::with_capacity(conns_per_thread * rounds);
                    for _ in 0..rounds {
                        for client in &mut clients {
                            lats.push(solve_once(client, body));
                        }
                    }
                    lats
                })
            })
            .collect();
        // sample the thread count while every connection is open and busy
        std::thread::sleep(Duration::from_millis(50));
        let under_load = process_threads();
        (handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect(), under_load)
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let concurrent_connections = driver_threads * conns_per_thread;
    let total_reqs = concurrent_connections * rounds;

    let (lookups, hits, builds) = cache_counters(addr);
    // the Prometheus endpoint must hold up under the same load path
    let mut probe = Client::connect(addr).unwrap();
    let (status, metrics_body) = probe.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(
        metrics_body.contains("# TYPE chainckpt_service_requests_total counter"),
        "/metrics is missing the service request family"
    );
    assert!(
        metrics_body.contains("chainckpt_table_store_hits_total"),
        "/metrics is missing the table store family"
    );
    drop(probe);

    latencies.sort_unstable();
    let (p50, p90, p99) = (
        percentile(&latencies, 0.50),
        percentile(&latencies, 0.90),
        percentile(&latencies, 0.99),
    );
    let req_per_s = total_reqs as f64 / elapsed;
    let hit_rate = hits as f64 / lookups as f64;

    println!(
        "{:<26} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "load", "req/s", "p50 (µs)", "p90 (µs)", "p99 (µs)", "hit rate"
    );
    println!(
        "{:<26} {:>8.0} {:>10} {:>10} {:>10} {:>9.1}%",
        format!("{concurrent_connections} conns × {rounds} solve"),
        req_per_s,
        p50,
        p90,
        p99,
        100.0 * hit_rate
    );
    println!(
        "cache: {lookups} lookups, {hits} hits, {builds} builds ({} total requests in {:.2} s)",
        total_reqs, elapsed
    );
    println!(
        "store: cold start {cold_start_us} µs, warm start {warm_start_us} µs \
         ({store_hits} hits, {store_misses} misses, {store_writes} writes, {store_errors} errors)"
    );
    println!(
        "threads: {threads_idle} idle, {threads_under_load} under {concurrent_connections} \
         open connections"
    );

    // warm traffic for one chain must be served from the shared table —
    // and this daemon's first answer came off disk, so *zero* DP builds
    assert_eq!(builds, 0, "the scale phase must be answered by the stored table");
    assert!(
        hit_rate > 0.9,
        "hit rate {hit_rate:.3} too low for single-chain traffic"
    );
    assert!(p50 > 0, "sub-microsecond p50 means the clock did not advance");
    // the point of the event loop: connections do not cost threads. The
    // budget is drivers + workers + event loop + slack — far below the
    // old one-thread-per-connection floor of `concurrent_connections`.
    if threads_under_load > 0 {
        assert!(
            threads_under_load < (concurrent_connections / 8) as u64,
            "{threads_under_load} threads for {concurrent_connections} connections: \
             connection handling is scaling with conns again"
        );
    }

    let json = obj([
        ("bench", Value::from("bench_service")),
        ("quick", Value::from(quick)),
        ("threads", Value::from(driver_threads)),
        ("concurrent_connections", Value::from(concurrent_connections)),
        ("requests_per_thread", Value::from(conns_per_thread * rounds)),
        ("total_requests", Value::from(total_reqs)),
        ("elapsed_s", Value::from(elapsed)),
        ("req_per_s", Value::from(req_per_s)),
        ("cold_start_us", Value::from(cold_start_us)),
        ("warm_start_us", Value::from(warm_start_us)),
        ("process_threads_under_load", Value::from(threads_under_load)),
        (
            "latency_us",
            obj([
                ("p50", Value::from(p50)),
                ("p90", Value::from(p90)),
                ("p99", Value::from(p99)),
            ]),
        ),
        (
            "cache",
            obj([
                ("lookups", Value::from(lookups)),
                ("hits", Value::from(hits)),
                ("builds", Value::from(builds)),
                ("hit_rate", Value::from(hit_rate)),
            ]),
        ),
        (
            "table_store",
            obj([
                ("hits", Value::from(store_hits)),
                ("misses", Value::from(store_misses)),
                ("writes", Value::from(store_writes)),
                ("errors", Value::from(store_errors)),
            ]),
        ),
        ("telemetry", chainckpt::telemetry::registry().snapshot()),
    ]);
    std::fs::create_dir_all("results").ok();
    let csv = format!(
        "conns,rounds,req_per_s,p50_us,p90_us,p99_us,hit_rate,cold_start_us,warm_start_us,threads_under_load\n\
         {},{},{:.1},{},{},{},{:.4},{},{},{}\n",
        concurrent_connections,
        rounds,
        req_per_s,
        p50,
        p90,
        p99,
        hit_rate,
        cold_start_us,
        warm_start_us,
        threads_under_load
    );
    std::fs::write("results/bench_service.csv", csv).ok();
    std::fs::write("BENCH_service.json", json.to_json_string()).ok();
    println!("→ results/bench_service.csv, BENCH_service.json");

    server.stop();
    let _ = std::fs::remove_dir_all(&table_dir);
}
