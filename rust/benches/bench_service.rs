//! Planning-service loopback load benchmark: N client threads × M
//! requests against an in-process `serve` daemon on an ephemeral port,
//! measuring end-to-end request latency (p50/p99), throughput, and the
//! planner table-cache hit rate that makes warm traffic cheap.
//!
//! Custom harness (no criterion offline), same contract as the other
//! benches: human-readable table on stdout, machine-readable
//! `BENCH_service.json` (emitted with the crate's own JSON writer) plus
//! `results/bench_service.csv`.
//!
//! ```sh
//! cargo bench --bench bench_service            # full load
//! cargo bench --bench bench_service -- --quick # CI-sized subset
//! ```

use std::time::{Duration, Instant};

use chainckpt::service::http::Client;
use chainckpt::service::{serve, ServiceConfig};
use chainckpt::solver::clear_cache;
use chainckpt::util::json::{obj, Value};
use chainckpt::util::Args;

/// One client worker: `reqs` solve requests on a keep-alive connection,
/// returning per-request latencies in microseconds.
fn client_worker(addr: std::net::SocketAddr, reqs: usize, body: &str) -> Vec<u64> {
    let mut client = Client::connect(addr).expect("connect to the loopback daemon");
    let mut latencies = Vec::with_capacity(reqs);
    for i in 0..reqs {
        let t0 = Instant::now();
        let (status, resp) =
            client.request("POST", "/solve", Some(body)).expect("solve round-trip");
        latencies.push(t0.elapsed().as_micros() as u64);
        assert_eq!(status, 200, "request {i}: {resp}");
        assert!(resp.contains("\"feasible\":true"), "request {i}: {resp}");
    }
    latencies
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let threads: usize = if quick { 4 } else { 8 };
    let reqs_per_thread: usize = if quick { 50 } else { 200 };

    // a mid-size profile: big enough that a cache miss is visible, small
    // enough that the cold fill stays in milliseconds; budget = half of
    // store-all, feasible for every ResNet (cf. the solver property tests)
    let chain = chainckpt::chain::profiles::resnet(50, 224, 16);
    let body = format!(
        r#"{{"chain": {{"profile": {{"family": "resnet", "depth": 50,
           "image": 224, "batch": 16}}}}, "memory": {}, "slots": 300}}"#,
        chain.store_all_memory() / 2
    );
    let body = body.as_str(); // scoped threads below borrow it

    clear_cache(); // charge the benchmark its own cold build
    let server = serve(ServiceConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: threads,
        read_timeout: Duration::from_secs(10),
        ..ServiceConfig::default()
    })
    .expect("bind the loopback daemon");
    let addr = server.addr();

    let t0 = Instant::now();
    let mut latencies: Vec<u64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| scope.spawn(move || client_worker(addr, reqs_per_thread, body)))
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    // cache + request counters over the real wire, like a client would
    let mut probe = Client::connect(addr).unwrap();
    let (status, stats_body) = probe.request("GET", "/stats", None).unwrap();
    assert_eq!(status, 200);
    let stats = Value::parse(&stats_body).expect("stats JSON");
    let cache = stats.get("planner_cache").expect("planner_cache in /stats");
    let (lookups, hits, builds) = (
        cache.get("lookups").unwrap().as_u64().unwrap(),
        cache.get("hits").unwrap().as_u64().unwrap(),
        cache.get("builds").unwrap().as_u64().unwrap(),
    );
    // the Prometheus endpoint must hold up under the same load path
    let (status, metrics_body) = probe.request("GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(
        metrics_body.contains("# TYPE chainckpt_service_requests_total counter"),
        "/metrics is missing the service request family"
    );
    assert!(
        metrics_body.contains("chainckpt_planner_cache_lookups_total"),
        "/metrics is missing the planner cache family"
    );
    drop(probe);

    let total_reqs = threads * reqs_per_thread;
    latencies.sort_unstable();
    let (p50, p90, p99) = (
        percentile(&latencies, 0.50),
        percentile(&latencies, 0.90),
        percentile(&latencies, 0.99),
    );
    let req_per_s = total_reqs as f64 / elapsed;
    let hit_rate = hits as f64 / lookups as f64;

    println!(
        "{:<26} {:>8} {:>10} {:>10} {:>10} {:>10}",
        "load", "req/s", "p50 (µs)", "p90 (µs)", "p99 (µs)", "hit rate"
    );
    println!(
        "{:<26} {:>8.0} {:>10} {:>10} {:>10} {:>9.1}%",
        format!("{threads}x{reqs_per_thread} solve"),
        req_per_s,
        p50,
        p90,
        p99,
        100.0 * hit_rate
    );
    println!(
        "cache: {lookups} lookups, {hits} hits, {builds} builds ({} total requests in {:.2} s)",
        total_reqs, elapsed
    );

    // warm traffic for one chain must be served from the shared table:
    // one cold DP fill (give a little slack for a cold/warm boundary
    // race where the discretization differs — there is exactly one
    // (chain, budget, slots) here, so in practice builds == 1)
    assert!(
        builds <= 2,
        "{builds} DP builds for one repeated (chain, budget): the cache is not working"
    );
    assert!(
        hit_rate > 0.9,
        "hit rate {hit_rate:.3} too low for single-chain traffic"
    );
    assert!(p50 > 0, "sub-microsecond p50 means the clock did not advance");

    let json = obj([
        ("bench", Value::from("bench_service")),
        ("quick", Value::from(quick)),
        ("threads", Value::from(threads)),
        ("requests_per_thread", Value::from(reqs_per_thread)),
        ("total_requests", Value::from(total_reqs)),
        ("elapsed_s", Value::from(elapsed)),
        ("req_per_s", Value::from(req_per_s)),
        (
            "latency_us",
            obj([
                ("p50", Value::from(p50)),
                ("p90", Value::from(p90)),
                ("p99", Value::from(p99)),
            ]),
        ),
        (
            "cache",
            obj([
                ("lookups", Value::from(lookups)),
                ("hits", Value::from(hits)),
                ("builds", Value::from(builds)),
                ("hit_rate", Value::from(hit_rate)),
            ]),
        ),
        ("telemetry", chainckpt::telemetry::registry().snapshot()),
    ]);
    std::fs::create_dir_all("results").ok();
    let csv = format!(
        "threads,reqs_per_thread,req_per_s,p50_us,p90_us,p99_us,hit_rate\n{},{},{:.1},{},{},{},{:.4}\n",
        threads, reqs_per_thread, req_per_s, p50, p90, p99, hit_rate
    );
    std::fs::write("results/bench_service.csv", csv).ok();
    std::fs::write("BENCH_service.json", json.to_json_string()).ok();
    println!("→ results/bench_service.csv, BENCH_service.json");

    server.stop();
}
