//! Solver benchmark — reproduces the paper's §5.2 cost claims:
//! "running time below 1 second on most networks; the longest was
//! ResNet-1001 (chain length 339): below 20 seconds at S = 500" — and
//! measures the Planner's amortization: one DP table serving a whole
//! budget sweep vs a fresh `solve` per budget.
//!
//! The scaling section drives the frontier-compressed fill up the depth
//! axis (L = 100 / 1 000 / 10 000 on `profiles::deep_chain`), recording
//! fill time, compressed table bytes, stored runs, and schedule
//! reconstruction time — with a dense-reference arm at L ≤ 1 000 that
//! gates the ≥ 4× fill-time win and would catch a pruning regression.
//!
//! Custom harness (the offline build has no criterion): median-of-N
//! wall-clock per configuration, printed as a table and written to
//! `results/bench_solver.csv` plus machine-readable `BENCH_solver.json`.
//!
//! ```sh
//! cargo bench --bench bench_solver            # full sweep
//! cargo bench --bench bench_solver -- --quick # CI-sized subset
//! ```

use std::fmt::Write as _;
use std::time::Instant;

use chainckpt::chain::{profiles, Chain, DiscreteChain};
use chainckpt::solver::{
    cache_stats, clear_cache, solve, solve_table, solve_table_dense, Mode, Planner,
};
use chainckpt::util::{median, Args};

struct Case {
    name: &'static str,
    chain: Chain,
    slots: usize,
}

fn time_solve(chain: &Chain, slots: usize, reps: usize) -> (f64, f64) {
    let memory = chain.store_all_memory() / 2;
    let mut samples = Vec::new();
    let mut cost = f64::NAN;
    for _ in 0..reps {
        clear_cache(); // measure the DP fill, not a table-cache hit
        let t0 = Instant::now();
        let s = solve(chain, memory, slots, Mode::Full);
        samples.push(t0.elapsed().as_secs_f64());
        cost = s.map(|s| s.predicted_time).unwrap_or(f64::INFINITY);
    }
    (median(&mut samples), cost)
}

struct SweepResult {
    name: &'static str,
    chain_len: usize,
    slots: usize,
    n_budgets: usize,
    per_budget_s: f64,
    planner_s: f64,
    speedup: f64,
}

/// Budget sweep two ways: a fresh `solve` per budget (the pre-Planner
/// call pattern) vs one `Planner` built at the top budget answering every
/// budget by reconstruction. The cache is cleared before each arm so the
/// baseline pays one DP per budget and the planner arm pays exactly one.
fn bench_sweep(
    name: &'static str,
    chain: &Chain,
    slots: usize,
    n_budgets: usize,
    reps: usize,
) -> SweepResult {
    let hi = chain.store_all_memory() + chain.wa0;
    let lo = chain.min_memory_hint();
    let budgets: Vec<u64> =
        (1..=n_budgets as u64).map(|i| lo + (hi - lo) * i / n_budgets as u64).collect();

    let mut per_budget = Vec::new();
    for _ in 0..reps {
        clear_cache();
        let t0 = Instant::now();
        let feasible = budgets
            .iter()
            .filter(|&&m| solve(chain, m, slots, Mode::Full).is_some())
            .count();
        per_budget.push(t0.elapsed().as_secs_f64());
        assert!(feasible > 0, "{name}: sweep produced no feasible schedule");
    }

    let mut planned = Vec::new();
    for _ in 0..reps {
        clear_cache(); // charge the planner arm its single table build
        let t0 = Instant::now();
        let planner = Planner::new(chain, hi, slots, Mode::Full);
        let scheds = planner.sweep(&budgets);
        planned.push(t0.elapsed().as_secs_f64());
        assert!(
            scheds.last().is_some_and(|s| s.is_some()),
            "{name}: top budget must be feasible"
        );
        let stats = cache_stats();
        assert_eq!(stats.builds, 1, "{name}: a sweep must build exactly one DP table");
    }

    let per_budget_s = median(&mut per_budget);
    let planner_s = median(&mut planned);
    SweepResult {
        name,
        chain_len: chain.len(),
        slots,
        n_budgets,
        per_budget_s,
        planner_s,
        speedup: per_budget_s / planner_s,
    }
}

struct ScalingResult {
    depth: usize,
    chain_len: usize,
    slots: usize,
    mode: Mode,
    fill_s: f64,
    dense_fill_s: Option<f64>,
    table_bytes: usize,
    dense_table_bytes: Option<usize>,
    run_count: usize,
    schedule_at_s: f64,
    ops: usize,
}

/// One point on the depth-scaling curve: fill the frontier table for a
/// `deep_chain(depth)` at `slots`, reconstruct the schedule at the top
/// budget, and (optionally) fill the retained dense reference on the
/// same inputs — the pre-PR baseline the ≥ 4× gate compares against.
fn bench_scaling(
    depth: usize,
    slots: usize,
    mode: Mode,
    with_dense: bool,
    reps: usize,
) -> ScalingResult {
    let chain = profiles::deep_chain(depth);
    let memory = chain.store_all_memory() / 2;
    let dc = DiscreteChain::new(&chain, memory, slots);

    let mut fills = Vec::new();
    let mut tab = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        tab = Some(solve_table(&dc, mode));
        fills.push(t0.elapsed().as_secs_f64());
    }
    let tab = tab.expect("at least one fill");

    let dense = if with_dense {
        let mut times = Vec::new();
        let mut dt = None;
        for _ in 0..reps {
            let t0 = Instant::now();
            dt = Some(solve_table_dense(&dc, mode));
            times.push(t0.elapsed().as_secs_f64());
        }
        Some((median(&mut times), dt.expect("dense fill ran").mem_bytes()))
    } else {
        None
    };

    let top = dc.top_budget().expect("deep_chain input fits its own budget");
    let mut recon = Vec::new();
    let mut ops_len = 0usize;
    for _ in 0..reps {
        let t0 = Instant::now();
        let ops = tab.ops_at(&dc, top).expect("half of store-all must be feasible");
        recon.push(t0.elapsed().as_secs_f64());
        ops_len = ops.len();
    }
    assert!(ops_len > chain.len(), "a schedule visits every stage at least once");

    ScalingResult {
        depth,
        chain_len: chain.len(),
        slots,
        mode,
        fill_s: median(&mut fills),
        dense_fill_s: dense.map(|(t, _)| t),
        table_bytes: tab.mem_bytes(),
        dense_table_bytes: dense.map(|(_, b)| b),
        run_count: tab.run_count(),
        schedule_at_s: median(&mut recon),
        ops: ops_len,
    }
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let reps = if quick { 2 } else { 3 };

    let mut cases = vec![
        Case { name: "resnet18-224", chain: profiles::resnet(18, 224, 16), slots: 500 },
        Case { name: "resnet50-224", chain: profiles::resnet(50, 224, 16), slots: 500 },
        Case { name: "resnet101-1000", chain: profiles::resnet(101, 1000, 8), slots: 500 },
        Case { name: "densenet201-224", chain: profiles::densenet(201, 224, 16), slots: 500 },
        Case { name: "inception-500", chain: profiles::inception_v3(500, 8), slots: 500 },
    ];
    if !quick {
        // the paper's worst case: L = 336, S = 500 (§5.2: < 20 s in C)
        cases.push(Case {
            name: "resnet1001-224-S150",
            chain: profiles::resnet(1001, 224, 1),
            slots: 150,
        });
        cases.push(Case {
            name: "resnet1001-224-S500",
            chain: profiles::resnet(1001, 224, 1),
            slots: 500,
        });
    }

    println!("{:<22} {:>6} {:>7} {:>12} {:>14}", "case", "L+1", "S", "solve (s)", "cost (ms)");
    let mut csv = String::from("case,chain_len,slots,solve_s,cost_ms\n");
    let mut json_cases = String::new();
    for c in &cases {
        let (t, cost) = time_solve(&c.chain, c.slots, reps);
        println!(
            "{:<22} {:>6} {:>7} {:>12.3} {:>14.2}",
            c.name,
            c.chain.len(),
            c.slots,
            t,
            cost
        );
        csv.push_str(&format!("{},{},{},{:.4},{:.3}\n", c.name, c.chain.len(), c.slots, t, cost));
        if !json_cases.is_empty() {
            json_cases.push(',');
        }
        let _ = write!(
            json_cases,
            r#"{{"case":"{}","chain_len":{},"slots":{},"solve_s":{:.4},"cost_ms":{:.3}}}"#,
            c.name,
            c.chain.len(),
            c.slots,
            t,
            cost
        );
        // paper budget checks (generous ×2 headroom for the CI machine)
        if c.chain.len() < 150 {
            assert!(t < 2.0, "{}: small chains must solve in ~1 s (paper §5.2)", c.name);
        } else if c.slots >= 500 {
            assert!(t < 40.0, "{}: ResNet-1001 must solve in ~20 s (paper §5.2)", c.name);
        }
    }

    // budget sweep: per-budget solve vs one Planner (the PR's acceptance
    // case is a 20-budget ResNet sweep at ≥ 5×; in practice the speedup
    // tracks the budget count)
    let sweeps = if quick {
        vec![bench_sweep("resnet50-224", &profiles::resnet(50, 224, 16), 500, 20, reps)]
    } else {
        vec![
            bench_sweep("resnet50-224", &profiles::resnet(50, 224, 16), 500, 20, reps),
            bench_sweep("resnet101-1000", &profiles::resnet(101, 1000, 8), 500, 20, reps),
        ]
    };
    println!(
        "\n{:<22} {:>8} {:>16} {:>14} {:>9}",
        "sweep", "budgets", "per-budget (s)", "planner (s)", "speedup"
    );
    let mut json_sweeps = String::new();
    for s in &sweeps {
        println!(
            "{:<22} {:>8} {:>16.3} {:>14.3} {:>8.1}x",
            s.name, s.n_budgets, s.per_budget_s, s.planner_s, s.speedup
        );
        csv.push_str(&format!(
            "sweep-{},{},{},{:.4},{:.4}\n",
            s.name, s.chain_len, s.slots, s.per_budget_s, s.planner_s
        ));
        if !json_sweeps.is_empty() {
            json_sweeps.push(',');
        }
        let _ = write!(
            json_sweeps,
            r#"{{"chain":"{}","chain_len":{},"slots":{},"budgets":{},"per_budget_solve_s":{:.4},"planner_sweep_s":{:.4},"speedup":{:.2}}}"#,
            s.name, s.chain_len, s.slots, s.n_budgets, s.per_budget_s, s.planner_s, s.speedup
        );
        assert!(
            s.speedup >= 5.0,
            "{}: planner must amortize a {}-budget sweep ≥ 5x (got {:.1}x)",
            s.name,
            s.n_budgets,
            s.speedup
        );
    }

    // depth-scaling curve for the frontier-compressed fill. The dense
    // arm stops at L = 1 000 (a dense L = 10⁴ table would need hundreds
    // of GB — the point of the compressed layout); the depth-10⁴ case
    // uses a coarse slot axis so its worst-case admission bound fits the
    // solver ceiling, and runs in both modes to pin the acceptance
    // criterion end to end.
    let scaling_cases: Vec<(usize, usize, Mode, bool)> = if quick {
        vec![(100, 150, Mode::Full, true)]
    } else {
        vec![
            (100, 150, Mode::Full, true),
            (1000, 150, Mode::Full, true),
            (10_000, 16, Mode::Full, false),
            (10_000, 16, Mode::AdRevolve, false),
        ]
    };
    println!(
        "\n{:<20} {:>7} {:>5} {:>10} {:>10} {:>8} {:>12} {:>12}",
        "scaling", "L", "S", "fill (s)", "dense (s)", "speedup", "table (B)", "sched (s)"
    );
    let mut json_scaling = String::new();
    for &(depth, slots, mode, with_dense) in &scaling_cases {
        // the depth-10⁴ fill is minutes of wall-clock — one rep is the curve
        let case_reps = if depth >= 10_000 { 1 } else { reps.min(2) };
        let r = bench_scaling(depth, slots, mode, with_dense, case_reps);
        let speedup = r.dense_fill_s.map(|d| d / r.fill_s);
        let label = format!(
            "deep-{depth}{}",
            if r.mode == Mode::AdRevolve { "-revolve" } else { "" }
        );
        println!(
            "{:<20} {:>7} {:>5} {:>10.3} {:>10} {:>8} {:>12} {:>12.4}",
            label,
            r.depth,
            r.slots,
            r.fill_s,
            r.dense_fill_s.map_or("-".into(), |d| format!("{d:.3}")),
            speedup.map_or("-".into(), |x| format!("{x:.1}x")),
            r.table_bytes,
            r.schedule_at_s
        );
        csv.push_str(&format!(
            "scaling-{label},{},{},{:.4},{:.4}\n",
            r.chain_len, r.slots, r.fill_s, r.schedule_at_s
        ));
        if !json_scaling.is_empty() {
            json_scaling.push(',');
        }
        let _ = write!(
            json_scaling,
            r#"{{"depth":{},"chain_len":{},"slots":{},"mode":"{}","fill_s":{:.4},"dense_fill_s":{},"speedup_vs_dense":{},"table_bytes":{},"dense_table_bytes":{},"run_count":{},"schedule_at_s":{:.5},"ops":{}}}"#,
            r.depth,
            r.chain_len,
            r.slots,
            if r.mode == Mode::AdRevolve { "ad_revolve" } else { "full" },
            r.fill_s,
            r.dense_fill_s.map_or("null".into(), |d| format!("{d:.4}")),
            speedup.map_or("null".into(), |x| format!("{x:.2}")),
            r.table_bytes,
            r.dense_table_bytes.map_or("null".into(), |b| b.to_string()),
            r.run_count,
            r.schedule_at_s,
            r.ops
        );
        // the PR's acceptance gate: ≥ 4× fill-time win over the dense
        // reference at L = 1 000 (full runs only — quick mode stays CI-sized)
        if !quick && depth == 1000 {
            let x = speedup.expect("the L=1000 case carries the dense arm");
            assert!(
                x >= 4.0,
                "deep-1000: compressed fill must beat dense ≥ 4x (got {x:.1}x)"
            );
        }
        // compression is the thing that makes depth 10⁴ representable:
        // the table must land under the admission ceiling with headroom
        // (the fixed 20 B/cell row overhead alone is ~1 GB at 5·10⁷
        // cells, so single-digit GB is the expected landing zone)
        if depth == 10_000 {
            assert!(
                r.table_bytes < (12usize << 30),
                "deep-10000: compressed table unexpectedly large ({} B)",
                r.table_bytes
            );
        }
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_solver.csv", csv).ok();
    // the registry snapshot rides along: cells filled, prune hits,
    // per-diagonal fill histogram — the fill internals behind the numbers
    let telemetry = chainckpt::telemetry::registry().snapshot().to_json_string();
    let json = format!(
        r#"{{"bench":"bench_solver","quick":{},"cases":[{}],"sweeps":[{}],"scaling":[{}],"telemetry":{}}}"#,
        quick, json_cases, json_sweeps, json_scaling, telemetry
    );
    std::fs::write("BENCH_solver.json", &json).ok();
    println!("→ results/bench_solver.csv, BENCH_solver.json");
}
