//! Solver benchmark — reproduces the paper's §5.2 cost claims:
//! "running time below 1 second on most networks; the longest was
//! ResNet-1001 (chain length 339): below 20 seconds at S = 500".
//!
//! Custom harness (the offline build has no criterion): median-of-N
//! wall-clock per configuration, printed as a table and written to
//! `results/bench_solver.csv`.
//!
//! ```sh
//! cargo bench --bench bench_solver            # full sweep
//! cargo bench --bench bench_solver -- --quick # CI-sized subset
//! ```

use std::time::Instant;

use chainckpt::chain::{profiles, Chain};
use chainckpt::solver::{solve, Mode};
use chainckpt::util::{median, Args};


struct Case {
    name: &'static str,
    chain: Chain,
    slots: usize,
}

fn time_solve(chain: &Chain, slots: usize, reps: usize) -> (f64, f64) {
    let memory = chain.store_all_memory() / 2;
    let mut samples = Vec::new();
    let mut cost = f64::NAN;
    for _ in 0..reps {
        let t0 = Instant::now();
        let s = solve(chain, memory, slots, Mode::Full);
        samples.push(t0.elapsed().as_secs_f64());
        cost = s.map(|s| s.predicted_time).unwrap_or(f64::INFINITY);
    }
    (median(&mut samples), cost)
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let reps = if quick { 2 } else { 3 };

    let mut cases = vec![
        Case { name: "resnet18-224", chain: profiles::resnet(18, 224, 16), slots: 500 },
        Case { name: "resnet50-224", chain: profiles::resnet(50, 224, 16), slots: 500 },
        Case { name: "resnet101-1000", chain: profiles::resnet(101, 1000, 8), slots: 500 },
        Case { name: "densenet201-224", chain: profiles::densenet(201, 224, 16), slots: 500 },
        Case { name: "inception-500", chain: profiles::inception_v3(500, 8), slots: 500 },
    ];
    if !quick {
        // the paper's worst case: L = 336, S = 500 (§5.2: < 20 s in C)
        cases.push(Case {
            name: "resnet1001-224-S150",
            chain: profiles::resnet(1001, 224, 1),
            slots: 150,
        });
        cases.push(Case {
            name: "resnet1001-224-S500",
            chain: profiles::resnet(1001, 224, 1),
            slots: 500,
        });
    }

    println!("{:<22} {:>6} {:>7} {:>12} {:>14}", "case", "L+1", "S", "solve (s)", "cost (ms)");
    let mut csv = String::from("case,chain_len,slots,solve_s,cost_ms\n");
    for c in &cases {
        let (t, cost) = time_solve(&c.chain, c.slots, reps);
        println!(
            "{:<22} {:>6} {:>7} {:>12.3} {:>14.2}",
            c.name,
            c.chain.len(),
            c.slots,
            t,
            cost
        );
        csv.push_str(&format!("{},{},{},{:.4},{:.3}\n", c.name, c.chain.len(), c.slots, t, cost));
        // paper budget checks (generous ×2 headroom for the CI machine)
        if c.chain.len() < 150 {
            assert!(t < 2.0, "{}: small chains must solve in ~1 s (paper §5.2)", c.name);
        } else if c.slots >= 500 {
            assert!(t < 40.0, "{}: ResNet-1001 must solve in ~20 s (paper §5.2)", c.name);
        }
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_solver.csv", csv).ok();
    println!("→ results/bench_solver.csv");
}
