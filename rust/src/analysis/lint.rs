//! Rule-driven architectural lints over the crate's own source tree.
//!
//! `tests/api_surface.rs` used to hand-roll one source scan ("only the
//! facade constructs a `Planner` or parses memory suffixes"). This
//! module generalizes that into a deterministic, std-only engine: a
//! fixed rule set walks `rust/src/**`, every finding is attributed to a
//! file and line, and each rule carries a checked-in **allowlist** under
//! `rust/lints/<rule>.allow` that turns the existing debt into a
//! ratchet — a file may never exceed its allowlisted count (new
//! violations fail `tests/lints.rs`), while counts *below* the allowance
//! are reported as available burn-down so the allowlist only ever
//! shrinks.
//!
//! The scan is intentionally textual and grep-replicable, with two
//! normalizations applied everywhere:
//!
//! * **production only** — each file is truncated at its first
//!   `#[cfg(test)]` line, so in-module tests may use `unwrap()` freely;
//! * **comments stripped** — everything from the first `//` on a line
//!   (doc comments included) is ignored, so prose mentioning
//!   `crate::api` is not an import edge.
//!
//! The rules:
//!
//! | rule | scope | forbids |
//! |------|-------|---------|
//! | `layering` | planning-math layers | `crate::` imports that point up the stack (see [`allowed_imports`]) |
//! | `no-panics` | `service/`, `api/` | `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!` |
//! | `relaxed-atomics` | everything but `telemetry/` | `Ordering::Relaxed` |
//! | `truncating-casts` | `solver/`, `service/wire.rs` | `as u8/u16/u32/i8/i16/i32` |
//! | `facade-planner` | everything but `api/`, `solver/` | `Planner::new` |
//! | `facade-suffix` | everything but `api/` | `parse_size`, `fn parse_suffix` |

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Every rule the engine knows, in report order.
pub const RULES: [&str; 6] = [
    "layering",
    "no-panics",
    "relaxed-atomics",
    "truncating-casts",
    "facade-planner",
    "facade-suffix",
];

/// Where to scan and where the allowlists live.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Root of the source tree (normally `rust/src`).
    pub src_root: PathBuf,
    /// Directory holding `<rule>.allow` files (normally `rust/lints`).
    pub allow_root: PathBuf,
}

/// One attributed violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuleFinding {
    pub rule: &'static str,
    /// Path relative to the source root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending token or import edge.
    pub excerpt: String,
}

impl fmt::Display for RuleFinding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.excerpt)
    }
}

/// One rule's findings evaluated against its allowlist.
#[derive(Debug, Clone)]
pub struct LintOutcome {
    pub rule: &'static str,
    pub findings: Vec<RuleFinding>,
    /// Files over their allowance — these fail the lint test.
    pub failures: Vec<String>,
    /// Files under their allowance — the allowlist can shrink.
    pub burn_down: Vec<String>,
    /// Allowlist entries naming files with no findings at all.
    pub stale: Vec<String>,
}

/// The whole engine run.
#[derive(Debug, Clone)]
pub struct LintReport {
    pub outcomes: Vec<LintOutcome>,
    pub files_scanned: usize,
}

impl LintReport {
    /// All over-allowance messages across rules; empty means the ratchet
    /// holds.
    pub fn failures(&self) -> Vec<String> {
        self.outcomes.iter().flat_map(|o| o.failures.iter().cloned()).collect()
    }

    pub fn is_clean(&self) -> bool {
        self.outcomes.iter().all(|o| o.failures.is_empty())
    }

    /// Non-fatal notes: burn-down opportunities and stale entries.
    pub fn notes(&self) -> Vec<String> {
        self.outcomes
            .iter()
            .flat_map(|o| o.burn_down.iter().chain(o.stale.iter()).cloned())
            .collect()
    }
}

impl fmt::Display for LintReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "lint scan over {} files", self.files_scanned)?;
        for o in &self.outcomes {
            writeln!(
                f,
                "  {}: {} finding(s), {} over allowance",
                o.rule,
                o.findings.len(),
                o.failures.len()
            )?;
            for msg in &o.failures {
                writeln!(f, "    FAIL {msg}")?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Source normalization
// ---------------------------------------------------------------------------

/// The production view of a file: (1-based line number, comment-stripped
/// text) pairs, truncated at the first `#[cfg(test)]` line.
fn production_lines(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        let stripped = match raw.find("//") {
            Some(pos) => &raw[..pos],
            None => raw,
        };
        out.push((i + 1, stripped.to_string()));
    }
    out
}

/// Is the byte *after* `end` an identifier continuation? Used to keep
/// `as u32` from matching inside `as u320` or `as usize`.
fn ident_continues(line: &str, end: usize) -> bool {
    line[end..].chars().next().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// All occurrences of `needle` in `line`; with `boundary`, matches
/// followed by an identifier character are skipped.
fn occurrences(line: &str, needle: &str, boundary: bool) -> usize {
    let mut count = 0;
    let mut from = 0;
    while let Some(pos) = line[from..].find(needle) {
        let end = from + pos + needle.len();
        if !boundary || !ident_continues(line, end) {
            count += 1;
        }
        from = from + pos + needle.len().max(1);
    }
    count
}

/// The module identifiers following every `crate::` on the line.
fn crate_imports(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = line[from..].find("crate::") {
        let start = from + pos + "crate::".len();
        let ident: String = line[start..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !ident.is_empty() {
            out.push(ident);
        }
        from = start;
    }
    out
}

// ---------------------------------------------------------------------------
// The rule set
// ---------------------------------------------------------------------------

/// The intended layering DAG, bottom-up. A constrained layer may only
/// `crate::`-import the listed modules; everything else (api, service,
/// backend, executor, runtime, …) is deliberately unconstrained — those
/// layers *should* reach down. Debt against this map (e.g. the solver's
/// kind-tagged `crate::api` preflight errors) lives in
/// `rust/lints/layering.allow` until inverted.
fn allowed_imports(layer: &str) -> Option<&'static [&'static str]> {
    const UTIL: &[&str] = &["util"];
    const TELEMETRY: &[&str] = &["util", "telemetry"];
    const CHAIN: &[&str] = &["util", "telemetry", "chain"];
    const SIMULATOR: &[&str] = &["util", "telemetry", "chain", "simulator"];
    const SOLVER: &[&str] = &["util", "telemetry", "chain", "simulator", "solver"];
    const GRAPH: &[&str] = &["util", "telemetry", "chain", "simulator", "solver", "graph"];
    const PLAN: &[&str] =
        &["util", "telemetry", "chain", "simulator", "solver", "graph", "plan"];
    const ANALYSIS: &[&str] =
        &["util", "telemetry", "chain", "simulator", "solver", "graph", "plan", "analysis"];
    match layer {
        "util" => Some(UTIL),
        "telemetry" => Some(TELEMETRY),
        "chain" => Some(CHAIN),
        "simulator" => Some(SIMULATOR),
        "solver" => Some(SOLVER),
        "graph" => Some(GRAPH),
        "plan" => Some(PLAN),
        "analysis" => Some(ANALYSIS),
        _ => None,
    }
}

const PANIC_TOKENS: [&str; 6] =
    [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

const CAST_TOKENS: [&str; 6] =
    [" as u8", " as u16", " as u32", " as i8", " as i16", " as i32"];

/// Apply every rule to one file. `rel` is the `/`-separated path below
/// the source root; `text` the raw file contents.
pub fn scan_file(rel: &str, text: &str) -> Vec<RuleFinding> {
    let lines = production_lines(text);
    let mut out = Vec::new();
    let layer = rel.split('/').next().unwrap_or("");
    // the engine's own rule table necessarily spells out the forbidden
    // tokens — that is data, not usage, so this file is exempt from the
    // token-matching rules (the layering rule still applies to it)
    let self_scan = rel == "analysis/lint.rs";
    let push = |out: &mut Vec<RuleFinding>, rule, line, excerpt: String| {
        out.push(RuleFinding { rule, file: rel.to_string(), line, excerpt });
    };

    for (line_no, line) in &lines {
        if let Some(allowed) = allowed_imports(layer) {
            for import in crate_imports(line) {
                if !allowed.contains(&import.as_str()) {
                    push(
                        &mut out,
                        "layering",
                        *line_no,
                        format!("{layer}/ imports crate::{import}"),
                    );
                }
            }
        }

        if layer == "service" || layer == "api" {
            for tok in PANIC_TOKENS {
                for _ in 0..occurrences(line, tok, false) {
                    push(&mut out, "no-panics", *line_no, tok.to_string());
                }
            }
        }

        if layer != "telemetry" && !self_scan {
            for _ in 0..occurrences(line, "Ordering::Relaxed", true) {
                push(&mut out, "relaxed-atomics", *line_no, "Ordering::Relaxed".to_string());
            }
        }

        if layer == "solver" || rel == "service/wire.rs" {
            for tok in CAST_TOKENS {
                for _ in 0..occurrences(line, tok, true) {
                    push(&mut out, "truncating-casts", *line_no, tok.trim().to_string());
                }
            }
        }

        if layer != "api" && layer != "solver" && !self_scan {
            for _ in 0..occurrences(line, "Planner::new", true) {
                push(&mut out, "facade-planner", *line_no, "Planner::new".to_string());
            }
        }

        if layer != "api" && !self_scan {
            for tok in ["parse_size", "fn parse_suffix"] {
                for _ in 0..occurrences(line, tok, true) {
                    push(&mut out, "facade-suffix", *line_no, tok.to_string());
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Allowlists and the ratchet
// ---------------------------------------------------------------------------

/// Parse a `<rule>.allow` file: one `path count` pair per line, `#`
/// comments and blank lines ignored. Malformed lines are reported as
/// failures rather than silently dropped.
fn parse_allowlist(text: &str) -> (BTreeMap<String, usize>, Vec<String>) {
    let mut map = BTreeMap::new();
    let mut errors = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match line.rsplit_once(char::is_whitespace) {
            Some((path, count)) => match count.trim().parse::<usize>() {
                Ok(n) => {
                    map.insert(path.trim().to_string(), n);
                }
                Err(_) => errors.push(format!("allowlist line {}: bad count {line:?}", i + 1)),
            },
            None => errors.push(format!("allowlist line {}: expected 'path count'", i + 1)),
        }
    }
    (map, errors)
}

/// Evaluate one rule's findings against its allowlist: per-file counts
/// over the allowance fail; counts under it are burn-down notes.
fn evaluate(
    rule: &'static str,
    findings: Vec<RuleFinding>,
    allow: &BTreeMap<String, usize>,
    allow_errors: Vec<String>,
) -> LintOutcome {
    let mut per_file: BTreeMap<&str, usize> = BTreeMap::new();
    for f in &findings {
        *per_file.entry(f.file.as_str()).or_default() += 1;
    }
    let mut failures = allow_errors;
    let mut burn_down = Vec::new();
    for (file, &count) in &per_file {
        let budget = allow.get(*file).copied().unwrap_or(0);
        if count > budget {
            let detail: Vec<String> = findings
                .iter()
                .filter(|f| f.file == *file)
                .map(|f| format!("{}:{} {}", f.file, f.line, f.excerpt))
                .collect();
            failures.push(format!(
                "[{rule}] {file}: {count} finding(s), allowance {budget}\n      {}",
                detail.join("\n      ")
            ));
        } else if count < budget {
            burn_down.push(format!(
                "[{rule}] {file}: {count} < allowance {budget} — shrink {rule}.allow"
            ));
        }
    }
    let stale = allow
        .keys()
        .filter(|path| !per_file.contains_key(path.as_str()))
        .map(|path| format!("[{rule}] {path}: allowlisted but clean — remove the entry"))
        .collect();
    LintOutcome { rule, findings, failures, burn_down, stale }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rust_sources(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the whole rule set over `cfg.src_root` and ratchet every rule
/// against `cfg.allow_root/<rule>.allow`.
pub fn run(cfg: &LintConfig) -> io::Result<LintReport> {
    let mut files = Vec::new();
    rust_sources(&cfg.src_root, &mut files)?;
    let mut findings: Vec<RuleFinding> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&cfg.src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(path)?;
        findings.extend(scan_file(&rel, &text));
    }

    let mut outcomes = Vec::new();
    for rule in RULES {
        let rule_findings: Vec<RuleFinding> =
            findings.iter().filter(|f| f.rule == rule).cloned().collect();
        let allow_path = cfg.allow_root.join(format!("{rule}.allow"));
        let (allow, allow_errors) = match fs::read_to_string(&allow_path) {
            Ok(text) => parse_allowlist(&text),
            Err(e) if e.kind() == io::ErrorKind::NotFound => (BTreeMap::new(), Vec::new()),
            Err(e) => return Err(e),
        };
        outcomes.push(evaluate(rule, rule_findings, &allow, allow_errors));
    }
    Ok(LintReport { outcomes, files_scanned: files.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn production_view_truncates_at_cfg_test_and_strips_comments() {
        let text = "fn a() {} // .unwrap() in a comment\nlet x = 1;\n#[cfg(test)]\nmod tests { fn b() { x.unwrap(); } }\n";
        let lines = production_lines(text);
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], (1, "fn a() {} ".to_string()));
        assert_eq!(lines[1], (2, "let x = 1;".to_string()));
    }

    #[test]
    fn occurrence_matcher_respects_identifier_boundaries() {
        assert_eq!(occurrences("let x = y as u32;", " as u32", true), 1);
        assert_eq!(occurrences("let x = y as u32z;", " as u32", true), 0);
        assert_eq!(occurrences("a as u8 + b as u8", " as u8", true), 2);
        assert_eq!(occurrences("y as usize", " as u8", true), 0);
        assert_eq!(occurrences("v.unwrap().unwrap()", ".unwrap()", false), 2);
    }

    #[test]
    fn crate_import_extraction_reads_the_module_identifier() {
        assert_eq!(
            crate_imports("use crate::api::{Error}; crate::service::serve();"),
            vec!["api".to_string(), "service".to_string()]
        );
        assert!(crate_imports("no imports here").is_empty());
    }

    #[test]
    fn layering_rule_flags_upward_imports_only() {
        let up = scan_file("solver/bad.rs", "use crate::service::serve;\n");
        assert_eq!(up.len(), 1);
        assert_eq!(up[0].rule, "layering");
        assert_eq!(up[0].line, 1);
        let down = scan_file("solver/good.rs", "use crate::chain::Chain;\n");
        assert!(down.iter().all(|f| f.rule != "layering"), "{down:?}");
        // api/service are unconstrained layers
        let api = scan_file("api/plan.rs", "use crate::service::serve;\n");
        assert!(api.iter().all(|f| f.rule != "layering"));
        // prose in comments is not an import edge
        let doc = scan_file("plan/mod.rs", "//! see crate::api for the facade\n");
        assert!(doc.iter().all(|f| f.rule != "layering"), "{doc:?}");
    }

    #[test]
    fn panic_rule_covers_service_and_api_production_code_only() {
        let hit = scan_file("service/x.rs", "let v = body.get(0).unwrap();\n");
        assert!(hit.iter().any(|f| f.rule == "no-panics"));
        let test_only =
            scan_file("service/x.rs", "fn ok() {}\n#[cfg(test)]\nmod t { fn f() { x.unwrap(); } }\n");
        assert!(test_only.iter().all(|f| f.rule != "no-panics"), "{test_only:?}");
        let solver = scan_file("solver/x.rs", "let v = body.get(0).unwrap();\n");
        assert!(solver.iter().all(|f| f.rule != "no-panics"));
    }

    #[test]
    fn relaxed_atomics_allowed_in_telemetry_only() {
        let t = scan_file("telemetry/mod.rs", "c.fetch_add(1, Ordering::Relaxed);\n");
        assert!(t.iter().all(|f| f.rule != "relaxed-atomics"));
        let s = scan_file("service/routes.rs", "c.fetch_add(1, Ordering::Relaxed);\n");
        assert!(s.iter().any(|f| f.rule == "relaxed-atomics"));
    }

    #[test]
    fn cast_rule_scopes_to_solver_and_wire() {
        let s = scan_file("solver/optimal.rs", "let m = big as u32;\n");
        assert!(s.iter().any(|f| f.rule == "truncating-casts"));
        let w = scan_file("service/wire.rs", "let m = big as u16;\n");
        assert!(w.iter().any(|f| f.rule == "truncating-casts"));
        let widening = scan_file("service/wire.rs", "let m = small as u64;\n");
        assert!(widening.iter().all(|f| f.rule != "truncating-casts"));
        let elsewhere = scan_file("chain/mod.rs", "let m = big as u32;\n");
        assert!(elsewhere.iter().all(|f| f.rule != "truncating-casts"));
    }

    #[test]
    fn facade_rules_reproduce_the_api_surface_scan() {
        let g = scan_file("graph/mod.rs", "let p = Planner::new(&chain, m, s, mode);\n");
        assert!(g.iter().any(|f| f.rule == "facade-planner"));
        let s = scan_file("solver/planner.rs", "let p = Planner::new(&chain, m, s, mode);\n");
        assert!(s.iter().all(|f| f.rule != "facade-planner"));
        let u = scan_file("util/cli.rs", "fn parse_suffix(s: &str) {}\n");
        assert!(u.iter().any(|f| f.rule == "facade-suffix"));
        let a = scan_file("api/units.rs", "fn parse_suffix(s: &str) {}\n");
        assert!(a.iter().all(|f| f.rule != "facade-suffix"));
    }

    #[test]
    fn ratchet_fails_over_allowance_and_notes_burn_down() {
        let findings = vec![
            RuleFinding {
                rule: "no-panics",
                file: "service/a.rs".into(),
                line: 3,
                excerpt: ".unwrap()".into(),
            },
            RuleFinding {
                rule: "no-panics",
                file: "service/a.rs".into(),
                line: 9,
                excerpt: ".expect(".into(),
            },
            RuleFinding {
                rule: "no-panics",
                file: "service/b.rs".into(),
                line: 1,
                excerpt: ".unwrap()".into(),
            },
        ];
        let (allow, errs) =
            parse_allowlist("# budgets\nservice/a.rs 1\nservice/b.rs 5\nservice/gone.rs 2\n");
        assert!(errs.is_empty());
        let outcome = evaluate("no-panics", findings, &allow, errs);
        // a.rs is over (2 > 1) → failure naming both sites
        assert_eq!(outcome.failures.len(), 1, "{:?}", outcome.failures);
        assert!(outcome.failures[0].contains("service/a.rs"));
        assert!(outcome.failures[0].contains("a.rs:3"));
        // b.rs is under (1 < 5) → burn-down note
        assert_eq!(outcome.burn_down.len(), 1);
        // gone.rs has no findings → stale entry note
        assert_eq!(outcome.stale.len(), 1);
    }

    #[test]
    fn malformed_allowlists_fail_rather_than_pass_silently() {
        let (_, errs) = parse_allowlist("service/a.rs notanumber\njustonepath\n");
        assert_eq!(errs.len(), 2);
        let outcome = evaluate("no-panics", Vec::new(), &BTreeMap::new(), errs);
        assert_eq!(outcome.failures.len(), 2);
    }
}
