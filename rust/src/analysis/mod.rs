//! Static analysis: independent safety proofs for lowered plans and
//! rule-driven architectural lints over the crate's own sources.
//!
//! Two pillars, both *checkers* rather than *builders* — they re-derive
//! facts with different algorithms than the code under test and compare:
//!
//! * [`verify`] — the plan borrow-checker. Given an
//!   [`ExecPlan`](crate::plan::ExecPlan) it re-proves, from the step and
//!   value tables alone, that every read is of a defined and still-live
//!   value, every value is freed exactly once, no two simultaneously-live
//!   values share arena bytes, no kernel reads a range it is writing,
//!   every free is performed by a consumer of the value (Table 1's
//!   refcount discipline), and that the plan-claimed `peak_bytes` equals
//!   an independent recomputation byte-for-byte. The findings come back
//!   as a structured [`Verdict`] in the paper's notation
//!   (`a^ℓ`/`ā^ℓ`/`δ^ℓ`).
//! * [`lint`] — the architectural lint engine. A deterministic,
//!   std-only scan of `rust/src/**` driven by a fixed rule set
//!   (module-layering DAG, no panicking APIs in request-serving paths,
//!   `Ordering::Relaxed` confined to `telemetry/`, no truncating `as`
//!   casts in the solver and wire layers, facade ownership of
//!   `Planner::new` and suffix parsing) with per-file allowlist files
//!   under `rust/lints/` acting as a ratchet: new violations fail,
//!   burn-down is reported so the allowlist can shrink.

pub mod lint;
pub mod verify;

pub use lint::{LintConfig, LintOutcome, LintReport, RuleFinding};
pub use verify::{verify, verify_counted, Verdict, Violation, ViolationKind};
