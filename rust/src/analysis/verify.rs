//! The plan borrow-checker: an independent static verifier for
//! [`ExecPlan`]s.
//!
//! [`crate::plan::lower`] *builds* a plan by replaying the simulator's
//! own transition function, so a bug in that shared machinery produces a
//! plan that is wrong and self-consistent at the same time — exactly the
//! failure mode of the PR-6 graph lowering, where a double-freed
//! predecessor tape compiled into bogus slot reuse that nothing
//! downstream could see. This module re-derives every safety fact from
//! the finished plan's tables alone, with algorithms disjoint from the
//! builder's:
//!
//! 1. **Dataflow** — a forward walk over [`Step`]s with a per-value
//!    `Undefined → Live → Freed` state machine: def-before-use,
//!    exactly-once-free, birth/death metadata conformance, and the
//!    Table-1 refcount rule that every free (except `drop a^ℓ` and the
//!    op's own transient) is performed by a step that *reads* the value.
//! 2. **Arena geometry** — every value fits its slot, and slot byte
//!    ranges tile `[0, arena_bytes)` with no gap or overlap.
//! 3. **Lifetime ⊗ byte-range overlap** — no two values live at the same
//!    step may share a single arena byte (lifetimes are inclusive: a
//!    value freed *at* step `i` still occupies storage during `i`).
//! 4. **Read/write disjointness** — per step, no input range intersects
//!    an output or transient range (the "δ replaces a" ledger convention
//!    is byte counting, never aliasing).
//! 5. **Peak recomputation** — an independent sweep of the Table-1
//!    charge order (forwards touch `current + writes + transient`,
//!    backwards `max(current + transient, current − frees + writes)`,
//!    `drop` touches nothing) whose result must equal the plan's claimed
//!    [`ExecPlan::peak_bytes`] byte-for-byte, and be covered by
//!    [`ExecPlan::arena_bytes`].
//!
//! The checker never panics on malformed input — out-of-range ids are
//! themselves violations — so it can sit in front of untrusted or
//! deliberately mutated plans (see `tests/plan_verifier.rs`).

use std::fmt;

use crate::plan::{ExecPlan, ValueId};
use crate::solver::Op;

/// What a [`Violation`] is about. Each seeded mutation class in the
/// harness maps to exactly one primary kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ViolationKind {
    /// A step reads a value no earlier step (or the initial set) defined.
    UseBeforeDef,
    /// A step reads a value a previous step already freed.
    UseAfterFree,
    /// A value is freed twice.
    DoubleFree,
    /// A step frees a value that was never defined.
    FreeWithoutDef,
    /// A value with a recorded death is never freed by any step.
    MissingFree,
    /// A value's recorded `death` disagrees with the step that frees it
    /// (or a value without a death is freed anyway).
    DeathMismatch,
    /// A value's recorded `birth` disagrees with the step that writes it.
    BirthMismatch,
    /// A value is written twice, or a step writes an `initial` value.
    DoubleDefine,
    /// A non-initial value no step ever writes.
    OrphanValue,
    /// A step frees a value it does not read — the Table-1 refcount
    /// discipline (last *consumer* frees) is broken. `drop a^ℓ` and the
    /// step's own transient are the two sanctioned exceptions.
    FreeWithoutRead,
    /// Two simultaneously-live values share at least one arena byte.
    SlotOverlap,
    /// A value references a slot out of range or larger than its slot.
    SlotBounds,
    /// Slot byte ranges do not tile `[0, arena_bytes)` exactly.
    ArenaTiling,
    /// A step's input range intersects one of its output/transient ranges.
    ReadWriteOverlap,
    /// The independent peak recomputation disagrees with the plan's
    /// claimed `peak_bytes`.
    PeakMismatch,
    /// The arena is smaller than the recomputed peak.
    ArenaBelowPeak,
}

impl ViolationKind {
    /// Stable label used in CLI/JSON output.
    pub fn label(&self) -> &'static str {
        match self {
            ViolationKind::UseBeforeDef => "use-before-def",
            ViolationKind::UseAfterFree => "use-after-free",
            ViolationKind::DoubleFree => "double-free",
            ViolationKind::FreeWithoutDef => "free-without-def",
            ViolationKind::MissingFree => "missing-free",
            ViolationKind::DeathMismatch => "death-mismatch",
            ViolationKind::BirthMismatch => "birth-mismatch",
            ViolationKind::DoubleDefine => "double-define",
            ViolationKind::OrphanValue => "orphan-value",
            ViolationKind::FreeWithoutRead => "free-without-read",
            ViolationKind::SlotOverlap => "slot-overlap",
            ViolationKind::SlotBounds => "slot-bounds",
            ViolationKind::ArenaTiling => "arena-tiling",
            ViolationKind::ReadWriteOverlap => "read-write-overlap",
            ViolationKind::PeakMismatch => "peak-mismatch",
            ViolationKind::ArenaBelowPeak => "arena-below-peak",
        }
    }
}

impl fmt::Display for ViolationKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One finding: kind, where (step / value), and a human-readable detail
/// in the paper's notation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub kind: ViolationKind,
    /// Step index the violation is anchored to, when step-local.
    pub step: Option<usize>,
    /// Primary value involved, when value-local.
    pub value: Option<ValueId>,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}]", self.kind.label())?;
        if let Some(s) = self.step {
            write!(f, " step {s}")?;
        }
        write!(f, ": {}", self.detail)
    }
}

/// The verifier's structured answer: all violations found, plus the two
/// numbers the peak sweep derived (useful even on clean plans — the
/// mutation harness uses `peak_step` to aim its byte-shrink mutation).
#[derive(Debug, Clone)]
pub struct Verdict {
    pub violations: Vec<Violation>,
    /// Independently recomputed Table-1 peak.
    pub recomputed_peak: u64,
    /// Step at which the recomputed peak is first attained (`None` when
    /// the initial resident set is already the peak).
    pub peak_step: Option<usize>,
    pub steps_checked: usize,
    pub values_checked: usize,
}

impl Verdict {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Whether any violation of `kind` was found.
    pub fn has(&self, kind: ViolationKind) -> bool {
        self.violations.iter().any(|v| v.kind == kind)
    }

    /// Distinct kinds present, in first-seen order.
    pub fn kinds(&self) -> Vec<ViolationKind> {
        let mut out: Vec<ViolationKind> = Vec::new();
        for v in &self.violations {
            if !out.contains(&v.kind) {
                out.push(v.kind);
            }
        }
        out
    }
}

impl fmt::Display for Verdict {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            write!(
                f,
                "plan verified: {} steps, {} values, peak {} B (recomputed independently)",
                self.steps_checked, self.values_checked, self.recomputed_peak
            )
        } else {
            writeln!(f, "plan REJECTED: {} violation(s)", self.violations.len())?;
            for v in &self.violations {
                writeln!(f, "  {v}")?;
            }
            write!(f, "  recomputed peak {} B", self.recomputed_peak)
        }
    }
}

/// Paper-notation label for a value id, tolerant of out-of-range ids.
fn label(plan: &ExecPlan, id: ValueId) -> String {
    match plan.values.get(id) {
        Some(v) => format!("{} (value {id})", v.item.label()),
        None => format!("value {id} (out of range)"),
    }
}

/// Byte range `[start, end)` a value occupies inside the arena, when its
/// slot reference is valid.
fn byte_range(plan: &ExecPlan, id: ValueId) -> Option<(u64, u64)> {
    let v = plan.values.get(id)?;
    let slot = plan.slots.get(v.slot)?;
    (v.bytes > 0).then(|| (slot.offset, slot.offset + v.bytes))
}

/// Inclusive lifetime `[start, end]` in step indices (initial values are
/// live from before step 0; deathless values to the end of time).
fn lifetime(plan: &ExecPlan, id: ValueId) -> (usize, usize) {
    let v = &plan.values[id];
    let start = if v.initial { 0 } else { v.birth };
    (start, v.death.unwrap_or(usize::MAX))
}

/// Verify `plan` end to end. Pure and total: never panics, touches no
/// global state, and always returns a full [`Verdict`].
pub fn verify(plan: &ExecPlan) -> Verdict {
    let mut out: Vec<Violation> = Vec::new();
    dataflow(plan, &mut out);
    geometry(plan, &mut out);
    overlap(plan, &mut out);
    read_write_disjoint(plan, &mut out);
    let (recomputed_peak, peak_step) = recompute_peak(plan, &mut out);
    Verdict {
        violations: out,
        recomputed_peak,
        peak_step,
        steps_checked: plan.steps.len(),
        values_checked: plan.values.len(),
    }
}

/// [`verify`], plus bookkeeping in the process-global metrics registry:
/// bumps `verifier.runs` and either `verifier.clean` or
/// `verifier.violations` (by the violation count).
pub fn verify_counted(plan: &ExecPlan) -> Verdict {
    let verdict = verify(plan);
    let t = crate::telemetry::registry();
    t.verifier_runs.inc();
    if verdict.is_clean() {
        t.verifier_clean.inc();
    } else {
        t.verifier_violations.add(verdict.violations.len() as u64);
    }
    verdict
}

// ---------------------------------------------------------------------------
// 1. Dataflow: def-before-use, exactly-once-free, refcount conformance
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Undefined,
    Live,
    Freed,
}

fn dataflow(plan: &ExecPlan, out: &mut Vec<Violation>) {
    let mut state = vec![State::Undefined; plan.values.len()];
    for (id, v) in plan.values.iter().enumerate() {
        if v.initial {
            state[id] = State::Live;
            if v.birth != 0 {
                out.push(Violation {
                    kind: ViolationKind::BirthMismatch,
                    step: None,
                    value: Some(id),
                    detail: format!(
                        "initial {} records birth {}, expected 0",
                        label(plan, id),
                        v.birth
                    ),
                });
            }
        }
    }

    for (i, step) in plan.steps.iter().enumerate() {
        for &r in &step.reads {
            match state.get(r) {
                None | Some(State::Undefined) => out.push(Violation {
                    kind: ViolationKind::UseBeforeDef,
                    step: Some(i),
                    value: Some(r),
                    detail: format!("{} reads undefined {}", step.op, label(plan, r)),
                }),
                Some(State::Freed) => out.push(Violation {
                    kind: ViolationKind::UseAfterFree,
                    step: Some(i),
                    value: Some(r),
                    detail: format!("{} reads freed {}", step.op, label(plan, r)),
                }),
                Some(State::Live) => {}
            }
        }

        for &w in step.writes.iter().chain(step.transient.iter()) {
            match state.get(w).copied() {
                None => out.push(Violation {
                    kind: ViolationKind::BirthMismatch,
                    step: Some(i),
                    value: Some(w),
                    detail: format!("{} writes {}", step.op, label(plan, w)),
                }),
                Some(State::Undefined) => {
                    state[w] = State::Live;
                    if plan.values[w].birth != i {
                        out.push(Violation {
                            kind: ViolationKind::BirthMismatch,
                            step: Some(i),
                            value: Some(w),
                            detail: format!(
                                "{} written at step {i} but records birth {}",
                                label(plan, w),
                                plan.values[w].birth
                            ),
                        });
                    }
                }
                Some(State::Live) | Some(State::Freed) => out.push(Violation {
                    kind: ViolationKind::DoubleDefine,
                    step: Some(i),
                    value: Some(w),
                    detail: format!("{} redefines {}", step.op, label(plan, w)),
                }),
            }
        }

        for &fid in &step.frees {
            match state.get(fid).copied() {
                None | Some(State::Undefined) => out.push(Violation {
                    kind: ViolationKind::FreeWithoutDef,
                    step: Some(i),
                    value: Some(fid),
                    detail: format!("{} frees undefined {}", step.op, label(plan, fid)),
                }),
                Some(State::Freed) => out.push(Violation {
                    kind: ViolationKind::DoubleFree,
                    step: Some(i),
                    value: Some(fid),
                    detail: format!("{} frees {} a second time", step.op, label(plan, fid)),
                }),
                Some(State::Live) => {
                    state[fid] = State::Freed;
                    if plan.values[fid].death != Some(i) {
                        out.push(Violation {
                            kind: ViolationKind::DeathMismatch,
                            step: Some(i),
                            value: Some(fid),
                            detail: format!(
                                "{} freed at step {i} but records death {:?}",
                                label(plan, fid),
                                plan.values[fid].death
                            ),
                        });
                    }
                    // Table-1 refcount discipline: a free is the freeing
                    // step's *last read* of the value — except the pure
                    // `drop a^ℓ` op and the step's own transient
                    let sanctioned = step.transient == Some(fid)
                        || matches!(step.op, Op::DropA(_))
                        || step.reads.contains(&fid);
                    if !sanctioned {
                        out.push(Violation {
                            kind: ViolationKind::FreeWithoutRead,
                            step: Some(i),
                            value: Some(fid),
                            detail: format!(
                                "{} frees {} without reading it",
                                step.op,
                                label(plan, fid)
                            ),
                        });
                    }
                }
            }
        }
    }

    for (id, v) in plan.values.iter().enumerate() {
        match state[id] {
            State::Undefined => out.push(Violation {
                kind: ViolationKind::OrphanValue,
                step: None,
                value: Some(id),
                detail: format!("{} is never written by any step", label(plan, id)),
            }),
            State::Live if v.death.is_some() => out.push(Violation {
                kind: ViolationKind::MissingFree,
                step: v.death,
                value: Some(id),
                detail: format!(
                    "{} records death {:?} but no step frees it",
                    label(plan, id),
                    v.death
                ),
            }),
            State::Live | State::Freed => {}
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Arena geometry: slot fit + exact tiling of [0, arena_bytes)
// ---------------------------------------------------------------------------

fn geometry(plan: &ExecPlan, out: &mut Vec<Violation>) {
    for (id, v) in plan.values.iter().enumerate() {
        match plan.slots.get(v.slot) {
            None => out.push(Violation {
                kind: ViolationKind::SlotBounds,
                step: None,
                value: Some(id),
                detail: format!(
                    "{} references slot {} of {}",
                    label(plan, id),
                    v.slot,
                    plan.slots.len()
                ),
            }),
            Some(slot) if v.bytes > slot.bytes => out.push(Violation {
                kind: ViolationKind::SlotBounds,
                step: None,
                value: Some(id),
                detail: format!(
                    "{} ({} B) exceeds slot {} ({} B)",
                    label(plan, id),
                    v.bytes,
                    v.slot,
                    slot.bytes
                ),
            }),
            Some(_) => {}
        }
    }

    let mut order: Vec<usize> = (0..plan.slots.len()).collect();
    order.sort_by_key(|&s| plan.slots[s].offset);
    let mut end = 0u64;
    for &s in &order {
        let slot = &plan.slots[s];
        if slot.offset != end {
            out.push(Violation {
                kind: ViolationKind::ArenaTiling,
                step: None,
                value: None,
                detail: format!(
                    "slot {s} starts at offset {} where {} was expected ({})",
                    slot.offset,
                    end,
                    if slot.offset < end { "overlap" } else { "gap" }
                ),
            });
        }
        end = end.max(slot.offset + slot.bytes);
    }
    if end != plan.arena_bytes {
        out.push(Violation {
            kind: ViolationKind::ArenaTiling,
            step: None,
            value: None,
            detail: format!(
                "slots cover [0, {end}) but the plan claims arena_bytes = {}",
                plan.arena_bytes
            ),
        });
    }
}

// ---------------------------------------------------------------------------
// 3. Lifetime ⊗ byte-range overlap
// ---------------------------------------------------------------------------

/// Inclusive lifetimes overlap unless one ends strictly before the other
/// starts (frees release storage only *after* their step).
fn lifetimes_overlap(a: (usize, usize), b: (usize, usize)) -> bool {
    !(a.1 < b.0 || b.1 < a.0)
}

fn overlap(plan: &ExecPlan, out: &mut Vec<Violation>) {
    // Values grouped by slot: same-slot values always share bytes, so a
    // per-slot sweep over lifetime-sorted occupants finds temporal
    // clashes in O(V log V) instead of O(V²).
    let mut by_slot: Vec<Vec<ValueId>> = vec![Vec::new(); plan.slots.len()];
    for (id, v) in plan.values.iter().enumerate() {
        if v.slot < plan.slots.len() && v.bytes > 0 {
            by_slot[v.slot].push(id);
        }
    }

    for ids in &mut by_slot {
        ids.sort_by_key(|&id| lifetime(plan, id).0);
        let mut latest: Option<(usize, ValueId)> = None; // (end, id)
        for &id in ids.iter() {
            let (start, e) = lifetime(plan, id);
            if let Some((prev_end, prev)) = latest {
                if start <= prev_end {
                    out.push(Violation {
                        kind: ViolationKind::SlotOverlap,
                        step: Some(start),
                        value: Some(id),
                        detail: format!(
                            "{} and {} are both live at step {start} and share slot {}",
                            label(plan, prev),
                            label(plan, id),
                            plan.values[id].slot
                        ),
                    });
                }
                if e > prev_end {
                    latest = Some((e, id));
                }
            } else {
                latest = Some((e, id));
            }
        }
    }

    // Cross-slot byte overlaps exist only when the tiling is broken; the
    // slot pairs whose ranges intersect are few, so a pairwise pass over
    // just those occupants is cheap.
    for s1 in 0..plan.slots.len() {
        for s2 in s1 + 1..plan.slots.len() {
            let (a, b) = (&plan.slots[s1], &plan.slots[s2]);
            if a.bytes == 0 || b.bytes == 0 {
                continue;
            }
            if a.offset + a.bytes <= b.offset || b.offset + b.bytes <= a.offset {
                continue;
            }
            for &v in &by_slot[s1] {
                for &w in &by_slot[s2] {
                    let (Some(rv), Some(rw)) = (byte_range(plan, v), byte_range(plan, w))
                    else {
                        continue;
                    };
                    if rv.1 <= rw.0 || rw.1 <= rv.0 {
                        continue;
                    }
                    if lifetimes_overlap(lifetime(plan, v), lifetime(plan, w)) {
                        out.push(Violation {
                            kind: ViolationKind::SlotOverlap,
                            step: None,
                            value: Some(v),
                            detail: format!(
                                "{} (slot {s1}) and {} (slot {s2}) are live together \
                                 over overlapping byte ranges [{}, {}) and [{}, {})",
                                label(plan, v),
                                label(plan, w),
                                rv.0,
                                rv.1,
                                rw.0,
                                rw.1
                            ),
                        });
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 4. Per-step read/write disjointness
// ---------------------------------------------------------------------------

fn read_write_disjoint(plan: &ExecPlan, out: &mut Vec<Violation>) {
    for (i, step) in plan.steps.iter().enumerate() {
        for &r in &step.reads {
            for &w in step.writes.iter().chain(step.transient.iter()) {
                let (Some(rr), Some(rw)) = (byte_range(plan, r), byte_range(plan, w)) else {
                    continue;
                };
                if rr.1 <= rw.0 || rw.1 <= rr.0 {
                    continue;
                }
                out.push(Violation {
                    kind: ViolationKind::ReadWriteOverlap,
                    step: Some(i),
                    value: Some(r),
                    detail: format!(
                        "{} reads {} over bytes [{}, {}) while writing {} over [{}, {})",
                        step.op,
                        label(plan, r),
                        rr.0,
                        rr.1,
                        label(plan, w),
                        rw.0,
                        rw.1
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 5. Independent peak recomputation
// ---------------------------------------------------------------------------

/// Re-derive the Table-1 peak from the step tables alone, mirroring the
/// ledger's charge order without sharing any code with it:
///
/// * initial residency is `Σ` initial value bytes;
/// * a forward's high-water candidate is `current + writes + transient`
///   (outputs and the transient coexist with every input — frees land
///   after);
/// * a backward's is `max(current + transient, current − frees + writes)`
///   (the transient peaks first, then δ stores land after the frees; a
///   graph backward's several δ stores grow monotonically toward the
///   post-step residency, so the final store dominates);
/// * `drop a^ℓ` only releases.
///
/// Signed 128-bit arithmetic keeps the sweep total on mutated plans
/// whose frees exceed their residency.
fn recompute_peak(plan: &ExecPlan, out: &mut Vec<Violation>) -> (u64, Option<usize>) {
    let bytes =
        |id: ValueId| plan.values.get(id).map(|v| v.bytes as i128).unwrap_or(0);
    let mut cur: i128 =
        plan.values.iter().filter(|v| v.initial).map(|v| v.bytes as i128).sum();
    let mut peak = cur;
    let mut peak_step: Option<usize> = None;

    for (i, step) in plan.steps.iter().enumerate() {
        let w: i128 = step.writes.iter().map(|&id| bytes(id)).sum();
        let t: i128 = step.transient.map(bytes).unwrap_or(0);
        let f: i128 = step
            .frees
            .iter()
            .filter(|&&id| step.transient != Some(id))
            .map(|&id| bytes(id))
            .sum();
        let candidate = match step.op {
            Op::FwdNoSave(_) | Op::FwdCk(_) | Op::FwdAll(_) => Some(cur + w + t),
            Op::Bwd(_) => Some((cur + t).max(cur - f + w)),
            Op::DropA(_) => None,
        };
        if let Some(c) = candidate {
            if c > peak {
                peak = c;
                peak_step = Some(i);
            }
        }
        cur += w - f;
    }

    let recomputed = u64::try_from(peak.max(0)).unwrap_or(u64::MAX);
    if recomputed != plan.peak_bytes {
        out.push(Violation {
            kind: ViolationKind::PeakMismatch,
            step: peak_step,
            value: None,
            detail: format!(
                "plan claims peak_bytes = {} but the independent sweep finds {}",
                plan.peak_bytes, recomputed
            ),
        });
    }
    if plan.arena_bytes < recomputed {
        out.push(Violation {
            kind: ViolationKind::ArenaBelowPeak,
            step: peak_step,
            value: None,
            detail: format!(
                "arena_bytes = {} cannot cover the recomputed peak {}",
                plan.arena_bytes, recomputed
            ),
        });
    }
    (recomputed, peak_step)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::{Chain, Stage};
    use crate::plan::lower;
    use crate::solver::{periodic_schedule, solve, store_all_schedule, Mode, Schedule, StrategyKind};

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300).with_overheads(16, 24))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    #[test]
    fn clean_plans_verify_clean_with_byte_exact_peak() {
        let c = toy(6);
        let mut schedules = vec![store_all_schedule(&c), periodic_schedule(&c, 3)];
        let hi = c.store_all_memory() + c.wa0;
        for m in [hi / 2, hi] {
            if let Some(s) = solve(&c, m, 200, Mode::Full) {
                schedules.push(s);
            }
        }
        for sched in &schedules {
            let plan = lower(&c, sched).unwrap();
            let verdict = verify(&plan);
            assert!(verdict.is_clean(), "{}: {verdict}", sched.strategy);
            assert_eq!(verdict.recomputed_peak, plan.peak_bytes, "{}", sched.strategy);
            assert_eq!(verdict.steps_checked, plan.op_count());
        }
    }

    #[test]
    fn drop_a_schedules_verify_clean() {
        let c = toy(2);
        let ops = vec![
            Op::FwdCk(1),
            Op::DropA(1),
            Op::FwdAll(1),
            Op::FwdAll(2),
            Op::FwdAll(3),
            Op::Bwd(3),
            Op::Bwd(2),
            Op::Bwd(1),
        ];
        let sched = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        let plan = lower(&c, &sched).unwrap();
        let verdict = verify(&plan);
        assert!(verdict.is_clean(), "{verdict}");
    }

    #[test]
    fn a_dropped_free_is_flagged_missing_free() {
        let c = toy(4);
        let mut plan = lower(&c, &store_all_schedule(&c)).unwrap();
        // drop the last backward's first free — leaves a dead value alive
        let victim = plan
            .steps
            .iter()
            .rposition(|s| !s.frees.is_empty())
            .expect("some step frees");
        plan.steps[victim].frees.remove(0);
        let verdict = verify(&plan);
        assert!(verdict.has(ViolationKind::MissingFree), "{verdict}");
    }

    #[test]
    fn verdict_display_names_values_in_paper_notation() {
        let c = toy(3);
        let mut plan = lower(&c, &store_all_schedule(&c)).unwrap();
        let bwd = plan.steps.iter().position(|s| matches!(s.op, Op::Bwd(_))).unwrap();
        let freed = plan.steps[bwd].frees[0];
        plan.steps[bwd].frees.push(freed); // same step, second free
        let verdict = verify(&plan);
        assert!(verdict.has(ViolationKind::DoubleFree), "{verdict}");
        let text = verdict.to_string();
        assert!(text.contains("double-free"), "{text}");
        // the freed item is named in the paper's alphabet
        let name = plan.values[freed].item.label();
        assert!(text.contains(&name), "{text} lacks {name}");
    }

    #[test]
    fn verifier_never_panics_on_garbage_ids() {
        let c = toy(3);
        let mut plan = lower(&c, &store_all_schedule(&c)).unwrap();
        let huge = plan.values.len() + 100;
        plan.steps[0].reads.push(huge);
        plan.steps[0].frees.push(huge);
        plan.values[0].slot = plan.slots.len() + 7;
        let verdict = verify(&plan);
        assert!(verdict.has(ViolationKind::UseBeforeDef));
        assert!(verdict.has(ViolationKind::FreeWithoutDef));
        assert!(verdict.has(ViolationKind::SlotBounds));
    }
}
