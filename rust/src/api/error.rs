//! Structured errors of the public facade.
//!
//! Every fallible facade call returns [`Error`], which carries an
//! [`ErrorKind`] next to an anyhow-style context chain. The kind is what
//! the two user-facing surfaces key their behavior on, each through one
//! table instead of string matching:
//!
//! * the planning service maps it to an HTTP status
//!   ([`ErrorKind::http_status`]) — previously `routes.rs` tagged
//!   server-side failures by message *prefix* because the vendored
//!   anyhow has no downcasting;
//! * the CLI maps it to a process exit code ([`ErrorKind::exit_code`]):
//!   usage error = 2, infeasible budget = 3, backend/internal = 1.

use std::fmt::{self, Debug, Display};

/// `Result<T, api::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// What went wrong, at the granularity callers dispatch on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorKind {
    /// The chain spec / request is syntactically or semantically invalid
    /// (bad field, out-of-range value, unparsable size string).
    InvalidSpec,
    /// The chain is valid but no persistent schedule fits the budget.
    InfeasibleBudget,
    /// The spec names a profile family, depth, or preset that does not
    /// exist in the catalog.
    UnknownChain,
    /// The tensor backend failed (compilation, execution, missing
    /// artifacts / real `xla` bindings).
    Backend,
    /// An internal invariant broke — a bug in this crate, not in the
    /// request. Page the operator, don't blame the client.
    Internal,
}

impl ErrorKind {
    /// The single `ErrorKind → HTTP status` table of the planning
    /// service. Spec-shaped problems blame the request (`422`); backend
    /// and invariant failures blame the server (`500`).
    pub fn http_status(self) -> u16 {
        match self {
            ErrorKind::InvalidSpec | ErrorKind::UnknownChain | ErrorKind::InfeasibleBudget => 422,
            ErrorKind::Backend | ErrorKind::Internal => 500,
        }
    }

    /// The single `ErrorKind → CLI exit code` table (documented in the
    /// binary's USAGE): usage/spec errors exit 2, an infeasible budget
    /// exits 3, backend/internal failures exit 1.
    pub fn exit_code(self) -> i32 {
        match self {
            ErrorKind::InvalidSpec | ErrorKind::UnknownChain => 2,
            ErrorKind::InfeasibleBudget => 3,
            ErrorKind::Backend | ErrorKind::Internal => 1,
        }
    }

    /// Stable snake_case name, used as the `"kind"` field of the
    /// service's `{"error": {...}}` envelope.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::InvalidSpec => "invalid_spec",
            ErrorKind::InfeasibleBudget => "infeasible_budget",
            ErrorKind::UnknownChain => "unknown_chain",
            ErrorKind::Backend => "backend",
            ErrorKind::Internal => "internal",
        }
    }
}

impl Display for ErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A kind-tagged error with a context chain, outermost message first.
///
/// Formatting mirrors anyhow: `{}` shows the outermost message, `{:#}`
/// the whole chain joined by `": "`, `{:?}` a `Caused by:` list.
pub struct Error {
    kind: ErrorKind,
    chain: Vec<String>,
}

impl Error {
    /// Construct from a kind and a displayable message.
    pub fn new(kind: ErrorKind, msg: impl Display) -> Error {
        Error { kind, chain: vec![msg.to_string()] }
    }

    /// Shorthand for [`ErrorKind::InvalidSpec`].
    pub fn invalid(msg: impl Display) -> Error {
        Error::new(ErrorKind::InvalidSpec, msg)
    }

    /// Shorthand for [`ErrorKind::InfeasibleBudget`].
    pub fn infeasible(msg: impl Display) -> Error {
        Error::new(ErrorKind::InfeasibleBudget, msg)
    }

    /// Shorthand for [`ErrorKind::UnknownChain`].
    pub fn unknown_chain(msg: impl Display) -> Error {
        Error::new(ErrorKind::UnknownChain, msg)
    }

    /// Shorthand for [`ErrorKind::Backend`].
    pub fn backend(msg: impl Display) -> Error {
        Error::new(ErrorKind::Backend, msg)
    }

    /// Shorthand for [`ErrorKind::Internal`].
    pub fn internal(msg: impl Display) -> Error {
        Error::new(ErrorKind::Internal, msg)
    }

    /// The kind this error is tagged with.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Retag the error (e.g. a generic conversion that defaulted to
    /// [`ErrorKind::Internal`] but is really a backend failure).
    pub fn with_kind(mut self, kind: ErrorKind) -> Error {
        self.kind = kind;
        self
    }

    /// Wrap with an outer context message, keeping the kind.
    pub fn context(mut self, context: impl Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.kind, self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

fn from_std_error(err: &(dyn std::error::Error + 'static)) -> Error {
    let mut chain = vec![err.to_string()];
    let mut source = err.source();
    while let Some(cause) = source {
        chain.push(cause.to_string());
        source = cause.source();
    }
    Error { kind: ErrorKind::Internal, chain }
}

/// The error types that convert into [`Error`] with `?` (all tagged
/// [`ErrorKind::Internal`]; retag with [`Error::with_kind`] /
/// [`Context::kind`] where a more specific kind applies). An explicit
/// list rather than a blanket impl: a blanket over
/// `E: std::error::Error` would conflict with the `anyhow::Error`
/// conversion under coherence (anyhow's error deliberately is not a std
/// error, but the compiler cannot rely on that for a foreign type).
macro_rules! convert_std_error {
    ($($ty:ty),* $(,)?) => {$(
        impl From<$ty> for Error {
            fn from(err: $ty) -> Error {
                from_std_error(&err)
            }
        }
        impl private::IntoApiError for $ty {
            fn into_api_error(self) -> Error {
                Error::from(self)
            }
        }
    )*};
}

convert_std_error!(
    std::io::Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::str::Utf8Error,
    crate::util::json::ParseError,
);

/// Lossless adoption of an anyhow context chain, tagged
/// [`ErrorKind::Internal`] (retag at the call site where appropriate).
impl From<anyhow::Error> for Error {
    fn from(err: anyhow::Error) -> Error {
        Error { kind: ErrorKind::Internal, chain: err.chain().map(String::from).collect() }
    }
}

mod private {
    /// Sealed conversion, mirroring the vendored anyhow's `IntoError`:
    /// implemented for the std errors listed above, `anyhow::Error`, and
    /// [`crate::api::Error`] itself, so [`super::Context`] works on all
    /// three `Result` flavors.
    pub trait IntoApiError {
        fn into_api_error(self) -> super::Error;
    }

    impl IntoApiError for anyhow::Error {
        fn into_api_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoApiError for super::Error {
        fn into_api_error(self) -> super::Error {
            self
        }
    }
}

/// `.context(...)` / `.with_context(...)` / `.kind(...)` on fallible
/// values, converting into [`Error`] as needed.
///
/// On `Option`, a missing value is treated as [`ErrorKind::InvalidSpec`]
/// (the overwhelmingly common case: a required request field is absent);
/// chain `.kind(...)` to retag.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: Display>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;

    /// Convert and (re)tag the error with `kind`.
    fn kind(self, kind: ErrorKind) -> Result<T>;
}

impl<T, E: private::IntoApiError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_api_error().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_api_error().context(f()))
    }

    fn kind(self, kind: ErrorKind) -> Result<T> {
        self.map_err(|e| e.into_api_error().with_kind(kind))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::invalid(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::invalid(f()))
    }

    fn kind(self, kind: ErrorKind) -> Result<T> {
        self.ok_or_else(|| Error::new(kind, "required value missing"))
    }
}

/// Return early with an [`Error`] of the given kind (module-internal
/// counterpart of `anyhow::bail!`): `fail!(InvalidSpec, "bad {x}")`.
macro_rules! fail {
    ($kind:ident, $($arg:tt)*) => {
        return Err($crate::api::Error::new(
            $crate::api::ErrorKind::$kind,
            format!($($arg)*),
        ))
    };
}
pub(crate) use fail;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_tables_are_total_and_distinct() {
        let kinds = [
            ErrorKind::InvalidSpec,
            ErrorKind::InfeasibleBudget,
            ErrorKind::UnknownChain,
            ErrorKind::Backend,
            ErrorKind::Internal,
        ];
        for k in kinds {
            assert!(matches!(k.http_status(), 422 | 500), "{k}");
            assert!(matches!(k.exit_code(), 1 | 2 | 3), "{k}");
            assert!(!k.as_str().is_empty());
        }
        // the satellite contract: usage 2, infeasible 3, backend/internal 1
        assert_eq!(ErrorKind::InvalidSpec.exit_code(), 2);
        assert_eq!(ErrorKind::UnknownChain.exit_code(), 2);
        assert_eq!(ErrorKind::InfeasibleBudget.exit_code(), 3);
        assert_eq!(ErrorKind::Backend.exit_code(), 1);
        assert_eq!(ErrorKind::Internal.exit_code(), 1);
        // the service contract: spec errors 422, server errors 500
        assert_eq!(ErrorKind::InvalidSpec.http_status(), 422);
        assert_eq!(ErrorKind::UnknownChain.http_status(), 422);
        assert_eq!(ErrorKind::Internal.http_status(), 500);
    }

    #[test]
    fn context_preserves_kind_and_chain() {
        let e = Error::infeasible("no schedule fits 1 KiB").context("solving resnet18");
        assert_eq!(e.kind(), ErrorKind::InfeasibleBudget);
        assert_eq!(format!("{e}"), "solving resnet18");
        assert_eq!(format!("{e:#}"), "solving resnet18: no schedule fits 1 KiB");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn conversions_default_to_internal() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = Error::from(io);
        assert_eq!(e.kind(), ErrorKind::Internal);

        let any = anyhow::anyhow!("inner");
        let e = Error::from(any).with_kind(ErrorKind::Backend);
        assert_eq!(e.kind(), ErrorKind::Backend);
        assert_eq!(format!("{e}"), "inner");
    }

    #[test]
    fn context_trait_works_on_all_result_flavors() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io"));
        assert_eq!(r.context("ctx").unwrap_err().kind(), ErrorKind::Internal);

        let r: anyhow::Result<()> = Err(anyhow::anyhow!("any"));
        let e = r.kind(ErrorKind::Backend).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Backend);

        let r: Result<()> = Err(Error::invalid("bad"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.kind(), ErrorKind::InvalidSpec);
        assert_eq!(format!("{e:#}"), "outer: bad");

        let o: Option<u32> = None;
        assert_eq!(o.context("missing 'x'").unwrap_err().kind(), ErrorKind::InvalidSpec);
    }
}
