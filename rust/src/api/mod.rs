//! The public facade: one typed pipeline from chain spec to executed
//! schedule.
//!
//! The paper's tool is a single pipeline — describe a chain, compute the
//! optimal checkpointing strategy for a memory limit, execute it. Before
//! this module the crate exposed that pipeline three times (CLI glue,
//! service wire, test/bench hand-wiring), each with its own chain
//! construction, raw-`u64` budgets, and stringly-typed errors. `api` is
//! now the one entry point everything routes through:
//!
//! * [`ChainSpec`] — the four chain sources (built-in profile, native
//!   preset, inline stages, on-disk manifest), normalized and validated
//!   in one place.
//! * [`MemBytes`] / [`SlotCount`] — typed units with the single
//!   human-suffix parser ([`MemBytes::parse`]), shared by CLI flags and
//!   the JSON wire.
//! * [`PlanRequest`] → [`Plan`] — solve the DP once (table-cached),
//!   answer any budget: schedule, sweep, feasibility range, simulator
//!   verification, lowering to a slot-addressed [`ExecPlan`]
//!   ([`Plan::lower`]), and really-executing replay ([`Plan::execute`],
//!   lowered by default).
//! * [`Error`] / [`ErrorKind`] — structured errors; the service's HTTP
//!   statuses and the CLI's exit codes each come from one table
//!   ([`ErrorKind::http_status`], [`ErrorKind::exit_code`]).
//!
//! # Quickstart
//!
//! ```
//! use chainckpt::api::{ChainSpec, MemBytes, PlanRequest};
//!
//! // spec → plan: one DP solve, fingerprint-cached process-wide
//! let plan = PlanRequest::new(
//!     ChainSpec::profile("resnet", 18, 224, 4),
//!     MemBytes::parse("4G")?,
//! )
//! .plan()?;
//!
//! // plan → schedule, simulator-verified, at any budget ≤ 4 GiB
//! let schedule = plan.schedule()?;
//! let report = plan.verify(&schedule)?;
//! assert!(report.peak_bytes <= plan.budget().get());
//! # Ok::<(), chainckpt::api::Error>(())
//! ```
//!
//! Sweeps reuse the same table (`plan.sweep(&budgets)`), and
//! [`Plan::execute`] / [`execute_schedule`] replay a schedule against a
//! compiled [`crate::runtime::Runtime`] on either tensor backend.

mod error;
mod plan;
mod spec;
mod units;

pub use error::{Context, Error, ErrorKind, Result};
pub use plan::{execute_schedule, ExecuteOptions, ExecutionReport, Plan, PlanRequest};
pub use spec::{ChainSpec, MAX_STAGES, PRESET_FLOPS_PER_US};
pub use units::{MemBytes, SlotCount};

// Re-exported so facade callers never need to reach into `solver` (or
// `plan`) for the types that appear in the facade's own signatures.
pub use crate::plan::ExecPlan;
pub use crate::solver::{Mode, Schedule};
pub use crate::telemetry::{DriftReport, KindDrift};
