//! [`PlanRequest`] → [`Plan`]: the spec→plan→execute pipeline as one
//! typed handle.
//!
//! A [`Plan`] wraps a resolved [`Chain`] plus the solver's
//! [`Planner`] (one DP table, fingerprint-cached process-wide) and
//! answers every question the consumers used to ask the solver layer
//! directly: the optimal schedule at any budget ≤ the planned top
//! ([`Plan::schedule_at`]), whole budget sweeps ([`Plan::sweep`]), the
//! feasibility frontier ([`Plan::feasible_range`]), simulator
//! verification ([`Plan::verify`]), and really-executing replay against a
//! compiled [`Runtime`] ([`Plan::execute`] / [`execute_schedule`]).

use super::error::{Context, Error, ErrorKind, Result};
use super::spec::ChainSpec;
use super::units::{MemBytes, SlotCount};
use crate::backend::Backend;
use crate::chain::Chain;
use crate::executor::Executor;
use crate::plan::ExecPlan;
use crate::runtime::Runtime;
use crate::simulator::{simulate, SimReport};
use crate::solver::{Mode, Planner, Schedule};
use crate::telemetry::{self, DriftReport};
use crate::train::SyntheticData;
use crate::util::median;

/// Everything needed to plan a chain: the spec, the top memory budget the
/// DP is discretized against, the slot axis, and the solver mode.
///
/// Budgets above the request's `budget` cannot be answered by the
/// resulting [`Plan`] (they clamp); budgets below come free — build the
/// request at the largest budget you intend to ask about.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    spec: ChainSpec,
    budget: MemBytes,
    slots: SlotCount,
    mode: Mode,
}

impl PlanRequest {
    /// A request with the default discretization (the paper's S = 500)
    /// and the optimal-persistent mode ([`Mode::Full`]).
    pub fn new(spec: ChainSpec, budget: MemBytes) -> PlanRequest {
        PlanRequest { spec, budget, slots: SlotCount::default(), mode: Mode::Full }
    }

    /// Override the DP slot axis.
    pub fn slots(mut self, slots: impl Into<SlotCount>) -> PlanRequest {
        self.slots = slots.into();
        self
    }

    /// Override the solver mode (`Mode::AdRevolve` = the revolve
    /// baseline's model).
    pub fn mode(mut self, mode: Mode) -> PlanRequest {
        self.mode = mode;
        self
    }

    /// Resolve the spec and solve (or fetch from the shared table cache)
    /// the DP — the one expensive step of the pipeline. Everything on the
    /// returned [`Plan`] is at most O(L) per query.
    pub fn plan(&self) -> Result<Plan> {
        if self.budget.get() == 0 {
            return Err(Error::invalid("memory budget must be ≥ 1 byte"));
        }
        if self.slots.get() == 0 {
            return Err(Error::invalid("slot count must be ≥ 1"));
        }
        let chain = self
            .spec
            .resolve()
            .with_context(|| format!("resolving chain spec ({})", self.spec))?;
        let planner = Planner::try_new(&chain, self.budget.get(), self.slots.get(), self.mode)
            .with_context(|| format!("planning chain spec ({})", self.spec))?;
        Ok(Plan { chain, planner, budget: self.budget })
    }
}

/// A chain's DP solved once, able to answer any budget ≤ the planned top
/// (see [`PlanRequest`]). Construction is [`PlanRequest::plan`].
pub struct Plan {
    chain: Chain,
    planner: Planner,
    budget: MemBytes,
}

impl Plan {
    /// The resolved chain this plan answers for.
    pub fn chain(&self) -> &Chain {
        &self.chain
    }

    /// The top budget the DP was discretized against.
    pub fn budget(&self) -> MemBytes {
        self.budget
    }

    /// The solver mode the table was filled for.
    pub fn mode(&self) -> Mode {
        self.planner.mode()
    }

    /// Bytes per memory slot — the granularity at which budgets are
    /// distinguished.
    pub fn slot_bytes(&self) -> f64 {
        self.planner.slot_bytes()
    }

    /// Optimal predicted time at `memory`, without reconstructing the
    /// schedule. `None` if no persistent schedule fits.
    pub fn cost_at(&self, memory: MemBytes) -> Option<f64> {
        self.planner.cost_at(memory.get())
    }

    /// The optimal persistent schedule within `memory` (O(L)
    /// reconstruction from the shared table). `None` if infeasible.
    pub fn schedule_at(&self, memory: MemBytes) -> Option<Schedule> {
        self.planner.schedule_at(memory.get())
    }

    /// The schedule at the plan's own top budget, or an
    /// [`ErrorKind::InfeasibleBudget`] error naming the budget and (when
    /// one exists) the smallest budget that would work.
    pub fn schedule(&self) -> Result<Schedule> {
        self.schedule_at(self.budget).ok_or_else(|| {
            let hint = match self.feasible_range() {
                Some((lo, _)) => format!(" (smallest feasible budget: {lo})"),
                None => " (no persistent schedule exists at any budget this plan covers)".into(),
            };
            Error::infeasible(format!(
                "no feasible persistent schedule for chain '{}' within {}{hint}",
                self.chain.name, self.budget
            ))
        })
    }

    /// Schedules for a whole budget sweep, reconstructed in parallel from
    /// the shared table; `out[i]` equals `schedule_at(budgets[i])`.
    pub fn sweep(&self, budgets: &[MemBytes]) -> Vec<Option<Schedule>> {
        let raw: Vec<u64> = budgets.iter().map(|m| m.get()).collect();
        self.planner.sweep(&raw)
    }

    /// The byte-budget feasibility interval `[min, top]` this plan can
    /// serve; `None` when even the top budget is infeasible.
    pub fn feasible_range(&self) -> Option<(MemBytes, MemBytes)> {
        self.planner
            .feasible_range()
            .map(|(lo, hi)| (MemBytes::new(lo), MemBytes::new(hi)))
    }

    /// Independently verify a schedule in the byte-accurate simulator.
    /// The schedule does not have to come from this plan (baselines
    /// verify the same way). An invalid sequence is an
    /// [`ErrorKind::Internal`] error: every schedule this crate hands out
    /// is supposed to replay cleanly, so a failure here is a solver bug,
    /// not a bad request.
    pub fn verify(&self, schedule: &Schedule) -> Result<SimReport> {
        simulate(&self.chain, schedule)
            .map_err(|e| Error::internal(format!("solver produced an invalid schedule: {e}")))
    }

    /// Lower this plan's optimal schedule into an [`ExecPlan`]: per-value
    /// liveness (explicit free points), arena slot assignment with byte
    /// offsets, and a plan-time peak byte-identical to [`Plan::verify`]'s
    /// simulator verdict. **The one lowering entry** — the CLI's
    /// `--lowered` paths, the service's `POST /lower`, and
    /// [`execute_schedule`]'s pooled replay all come through here or
    /// [`Plan::lower_schedule`].
    pub fn lower(&self) -> Result<ExecPlan> {
        let schedule = self.schedule()?;
        self.lower_schedule(&schedule)
    }

    /// Lower any schedule (the baselines included) against this plan's
    /// chain. An invalid sequence is an [`ErrorKind::Internal`] error,
    /// like [`Plan::verify`].
    pub fn lower_schedule(&self, schedule: &Schedule) -> Result<ExecPlan> {
        let plan = crate::plan::lower(&self.chain, schedule)
            .map_err(|e| Error::internal(format!("schedule does not lower: {e}")))?;
        // In debug builds every lowered plan passes through the static
        // verifier (analysis/verify.rs) — an independent re-proof of
        // liveness, slot disjointness, and the claimed peak.
        #[cfg(debug_assertions)]
        {
            let verdict = crate::analysis::verify_counted(&plan);
            debug_assert!(verdict.is_clean(), "lowered plan failed static verification: {verdict}");
        }
        Ok(plan)
    }

    /// Plan → really execute: replay this plan's optimal schedule against
    /// compiled stages (see [`execute_schedule`] for the measurement
    /// contract). Fails with [`ErrorKind::InfeasibleBudget`] if the top
    /// budget admits no schedule.
    pub fn execute<B: Backend>(
        &self,
        rt: &Runtime<B>,
        data: &SyntheticData<B::Tensor>,
        opts: &ExecuteOptions,
    ) -> Result<ExecutionReport> {
        let schedule = self.schedule()?;
        if opts.chain.is_none() {
            // The plan knows its own chain — join the drift report against
            // it without making the caller thread it through.
            let opts = ExecuteOptions { chain: Some(self.chain.clone()), ..opts.clone() };
            return execute_schedule(rt, &schedule, data, &opts);
        }
        execute_schedule(rt, &schedule, data, opts)
    }
}

/// Measurement contract for [`execute_schedule`].
#[derive(Debug, Clone)]
pub struct ExecuteOptions {
    /// Timed repetitions (median taken); one untimed warmup run precedes
    /// them.
    pub reps: usize,
    /// Parameter-init seed for the fresh [`Executor`].
    pub seed: u64,
    /// Byte budget enforced by the executor's ledger each replay
    /// (`None` = measure only, don't enforce).
    pub memory_limit: Option<MemBytes>,
    /// Replay through the lowered path (schedule compiled once to an
    /// [`ExecPlan`], replayed over a persistent buffer pool with zero
    /// steady-state allocations). **Default: on.** Ignored — with a
    /// legacy-replay fallback — on backends without in-place kernels
    /// ([`Backend::SUPPORTS_LOWERED`] is `false`, i.e. pjrt).
    pub lowered: bool,
    /// Cost-model chain for the schedule being executed. When set, the
    /// report carries a [`DriftReport`] joining measured per-op-kind
    /// timings and peak bytes against the simulator's predictions.
    /// [`Plan::execute`] fills this from its own chain automatically.
    pub chain: Option<Chain>,
}

impl Default for ExecuteOptions {
    fn default() -> Self {
        ExecuteOptions { reps: 3, seed: 1, memory_limit: None, lowered: true, chain: None }
    }
}

/// One really-executed measurement of a schedule.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    /// Loss captured by the final timed replay.
    pub loss: f32,
    /// Peak bytes charged to the executor's memory ledger.
    pub peak: MemBytes,
    /// Median wall-clock of one replay, seconds.
    pub elapsed_s: f64,
    /// Items per second at the manifest's batch size.
    pub throughput: f64,
    /// Ops in the replayed schedule.
    pub ops: usize,
    /// Measured-vs-predicted drift, when [`ExecuteOptions::chain`] gave
    /// the cost model to join against (`None` otherwise).
    pub drift: Option<DriftReport>,
}

/// Execute `schedule` against really-computing stages: a fresh
/// [`Executor`] (so repeated measurements are independent and
/// deterministic per seed), the loss target from `data.targets[0]`, one
/// warmup replay, then `opts.reps` timed replays (median reported).
/// With `opts.lowered` (the default, on backends that support it) the
/// schedule is compiled once to an [`ExecPlan`] and every replay runs
/// over the persistent pool; otherwise the legacy per-op replay runs.
/// Both paths produce bit-identical losses and gradients — only memory
/// behavior and speed differ.
///
/// This is the one execution path behind `chainckpt train`/`compare`, the
/// executor benchmark, and [`Plan::execute`] — any [`Schedule`] works,
/// including the store-all / periodic baselines.
pub fn execute_schedule<B: Backend>(
    rt: &Runtime<B>,
    schedule: &Schedule,
    data: &SyntheticData<B::Tensor>,
    opts: &ExecuteOptions,
) -> Result<ExecutionReport> {
    if data.is_empty() {
        return Err(Error::invalid("execute_schedule needs at least one data batch"));
    }
    let mut ex = Executor::new(rt, opts.seed).kind(ErrorKind::Backend)?;
    let loss_stage = rt.manifest.stages.len() - 1;
    ex.set_data_param(loss_stage, &data.targets[0]).kind(ErrorKind::Backend)?;
    let limit = opts.memory_limit.map(MemBytes::get);
    let mut lowered = if opts.lowered && B::SUPPORTS_LOWERED {
        Some(ex.lower(schedule).kind(ErrorKind::Backend)?)
    } else {
        None
    };
    let mut times = Vec::with_capacity(opts.reps);
    let mut last = None;
    // Per-op-kind registry totals bracketing the timed reps (the warmup
    // replay at r == 0 is excluded, like the wall-clock measurements).
    let mut kinds_t0 = ([0u64; telemetry::OpKind::COUNT], [0u64; telemetry::OpKind::COUNT]);
    for r in 0..opts.reps.max(1) + 1 {
        if r == 1 {
            kinds_t0 = telemetry::registry().kind_totals();
        }
        let res = match &mut lowered {
            Some(low) => ex.run_lowered(low, &data.inputs[0], limit),
            None => ex.run(schedule, &data.inputs[0], limit),
        }
        .with_context(|| format!("replaying a {} schedule", schedule.strategy))
        .kind(ErrorKind::Backend)?;
        if r > 0 {
            times.push(res.elapsed_s);
        }
        last = Some(res);
    }
    let res = last.ok_or_else(|| Error::internal("no replay ran"))?;
    let elapsed_s = median(&mut times);
    let batch = rt.manifest.input_shape[0] as f64;
    let drift = opts.chain.as_ref().and_then(|chain| {
        let (ops_t1, ns_t1) = telemetry::registry().kind_totals();
        let reps = opts.reps.max(1) as u64;
        let mut ops_avg = [0u64; telemetry::OpKind::COUNT];
        let mut ns_avg = [0u64; telemetry::OpKind::COUNT];
        for k in 0..telemetry::OpKind::COUNT {
            ops_avg[k] = ops_t1[k].saturating_sub(kinds_t0.0[k]) / reps;
            ns_avg[k] = ns_t1[k].saturating_sub(kinds_t0.1[k]) / reps;
        }
        telemetry::drift_report(chain, schedule, ops_avg, ns_avg, res.peak_bytes)
    });
    Ok(ExecutionReport {
        loss: res.loss,
        peak: MemBytes::new(res.peak_bytes),
        elapsed_s,
        throughput: batch / elapsed_s,
        ops: res.ops,
        drift,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::solver::solve;

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    #[test]
    fn plan_matches_the_raw_planner_surface() {
        let chain = toy(7);
        let top = chain.store_all_memory() + chain.wa0;
        let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes(top))
            .slots(SlotCount(140))
            .plan()
            .unwrap();
        let raw = Planner::new(&chain, top, 140, Mode::Full);
        for m in [top / 3, top / 2, top] {
            assert_eq!(
                plan.schedule_at(MemBytes(m)).map(|s| s.ops),
                raw.schedule_at(m).map(|s| s.ops),
                "budget {m}"
            );
            assert_eq!(plan.cost_at(MemBytes(m)), raw.cost_at(m));
        }
        assert_eq!(
            plan.feasible_range().map(|(a, b)| (a.get(), b.get())),
            raw.feasible_range()
        );
        let budgets: Vec<MemBytes> = (1..=6).map(|i| MemBytes(top * i / 6)).collect();
        let raw_budgets: Vec<u64> = budgets.iter().map(|m| m.get()).collect();
        assert_eq!(
            plan.sweep(&budgets).into_iter().map(|s| s.map(|x| x.ops)).collect::<Vec<_>>(),
            raw.sweep(&raw_budgets).into_iter().map(|s| s.map(|x| x.ops)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn plan_is_bit_identical_to_solve_at_its_own_budget() {
        let chain = toy(9);
        let m = chain.store_all_memory() / 2;
        let via_api = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes(m))
            .slots(SlotCount(150))
            .plan()
            .unwrap()
            .schedule()
            .unwrap();
        let via_solve = solve(&chain, m, 150, Mode::Full).unwrap();
        assert_eq!(via_api.ops, via_solve.ops);
        assert_eq!(via_api.predicted_time, via_solve.predicted_time);
    }

    #[test]
    fn infeasible_budget_is_kind_tagged_with_a_hint() {
        let chain = toy(5);
        let err = PlanRequest::new(ChainSpec::inline(chain), MemBytes(64))
            .slots(SlotCount(60))
            .plan()
            .unwrap()
            .schedule()
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InfeasibleBudget);
        assert!(format!("{err:#}").contains("64 B"), "{err:#}");
    }

    #[test]
    fn zero_budget_and_zero_slots_are_invalid_not_panics() {
        let err =
            PlanRequest::new(ChainSpec::inline(toy(3)), MemBytes(0)).plan().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        let err = PlanRequest::new(ChainSpec::inline(toy(3)), MemBytes(1024))
            .slots(SlotCount(0))
            .plan()
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    }

    #[test]
    fn over_capacity_table_requests_are_invalid_spec_not_aborts() {
        // 10⁴ stages at the default S = 500 would need a worst-case table
        // beyond MAX_TABLE_BYTES; the preflight rejects it before any
        // allocation, kind-tagged so the service answers 422.
        let stages: Vec<Stage> = (1..=10_000)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300))
            .collect();
        let big = Chain::new("deep", stages, 100);
        let err = PlanRequest::new(ChainSpec::inline(big), MemBytes(1 << 34))
            .slots(SlotCount(500))
            .plan()
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        assert_eq!(err.kind().http_status(), 422);
        let msg = format!("{err:#}");
        assert!(msg.contains("500"), "names the slot axis: {msg}");
    }

    #[test]
    fn lower_agrees_with_verify_and_kind_tags_garbage() {
        let chain = toy(6);
        let top = chain.store_all_memory() + chain.wa0;
        let plan = PlanRequest::new(ChainSpec::inline(chain), MemBytes(top))
            .slots(SlotCount(100))
            .plan()
            .unwrap();
        let sched = plan.schedule().unwrap();
        let lowered = plan.lower().unwrap();
        assert_eq!(lowered.peak_bytes, plan.verify(&sched).unwrap().peak_bytes);
        assert!(lowered.arena_bytes >= lowered.peak_bytes);
        assert_eq!(lowered.op_count(), sched.ops.len());

        use crate::solver::{Op, StrategyKind};
        let bogus = Schedule::new(vec![Op::Bwd(3)], StrategyKind::Optimal, 0.0);
        assert_eq!(plan.lower_schedule(&bogus).unwrap_err().kind(), ErrorKind::Internal);
    }

    #[test]
    fn verify_accepts_solver_output_and_flags_garbage() {
        let chain = toy(6);
        let top = chain.store_all_memory() + chain.wa0;
        let plan = PlanRequest::new(ChainSpec::inline(chain), MemBytes(top))
            .slots(SlotCount(100))
            .plan()
            .unwrap();
        let sched = plan.schedule().unwrap();
        let rep = plan.verify(&sched).unwrap();
        assert!(rep.peak_bytes <= top);

        use crate::solver::{Op, StrategyKind};
        let bogus = Schedule::new(vec![Op::Bwd(3)], StrategyKind::Optimal, 0.0);
        let err = plan.verify(&bogus).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Internal);
    }
}
