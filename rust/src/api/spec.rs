//! [`ChainSpec`] — one description of "which chain", whatever the source.
//!
//! Before the facade, each consumer hand-wired its own chain
//! construction: `main.rs` built profile chains from CLI flags,
//! `service/wire.rs` parsed profile/preset/inline JSON specs with its own
//! validation, and the figure harness called [`crate::chain::profiles`]
//! directly. `ChainSpec` owns all of that: the five sources (built-in
//! **profile**, native **preset**, **graph** DAG, **inline** stages,
//! on-disk **manifest**) normalize and validate in exactly one place, so
//! the CLI, the service wire, and library callers cannot drift apart.

use super::error::{fail, Context, ErrorKind, Result};
use crate::backend::native::presets;
use crate::chain::manifest::Manifest;
use crate::chain::{profiles, Chain, Stage};
use crate::graph::{self, GraphSpec};
use crate::util::json::Value;

/// Stage cap for inline chains: bounds DP time (O(L²·S) per table) so one
/// request cannot pin a service worker for minutes.
pub const MAX_STAGES: usize = 2048;

/// FLOP/µs assumed when deriving analytic timings for `preset` and
/// `manifest` chains (a mid-range single-core rate for the native engine;
/// only the *relative* stage durations shape the schedule).
pub const PRESET_FLOPS_PER_US: f64 = 5.0e3;

/// Where a chain comes from. Build one with the [`ChainSpec`]
/// constructors or parse the service wire form with
/// [`ChainSpec::from_json`]; turn it into a solver [`Chain`] with
/// [`ChainSpec::resolve`] (or hand it straight to
/// [`super::PlanRequest`]).
#[derive(Debug, Clone, PartialEq)]
enum Source {
    /// An analytic profile of the paper's benchmark networks
    /// ([`crate::chain::profiles`]).
    Profile { family: String, depth: u32, image: u64, batch: u64 },
    /// A native-backend transformer preset
    /// ([`crate::backend::native::presets`]) with analytic roofline
    /// timings.
    Preset(String),
    /// A validated DAG ([`crate::graph`]), solved by frontier fusion:
    /// resolves to its fused chain ([`GraphSpec::to_chain`]).
    Graph(GraphSpec),
    /// An already-built chain (e.g. measured by the estimator, or parsed
    /// from an inline `"stages"` wire spec).
    Inline(Chain),
    /// A stage manifest directory on disk (`manifest.json` as written by
    /// `python/compile/aot.py`), with analytic timings.
    Manifest(std::path::PathBuf),
}

/// A validated-on-resolve description of a chain (see [module docs](self)).
#[derive(Debug, Clone, PartialEq)]
pub struct ChainSpec {
    source: Source,
}

impl ChainSpec {
    /// A built-in analytic profile: `family` ∈
    /// resnet/densenet/inception/vgg, `image`/`batch` within the
    /// catalog's supported ranges (checked at [`resolve`](Self::resolve)).
    pub fn profile(
        family: impl Into<String>,
        depth: u32,
        image: u64,
        batch: u64,
    ) -> ChainSpec {
        ChainSpec { source: Source::Profile { family: family.into(), depth, image, batch } }
    }

    /// A native-backend preset chain (`quickstart` / `default` / `wide`).
    pub fn preset(name: impl Into<String>) -> ChainSpec {
        ChainSpec { source: Source::Preset(name.into()) }
    }

    /// A validated DAG, solved through its frontier-fused chain.
    pub fn graph(g: GraphSpec) -> ChainSpec {
        ChainSpec { source: Source::Graph(g) }
    }

    /// An already-built chain, used as-is.
    pub fn inline(chain: Chain) -> ChainSpec {
        ChainSpec { source: Source::Inline(chain) }
    }

    /// A manifest directory on disk, timed analytically.
    pub fn manifest(dir: impl Into<std::path::PathBuf>) -> ChainSpec {
        ChainSpec { source: Source::Manifest(dir.into()) }
    }

    /// Parse the **untrusted** wire form — the `"chain"` field of
    /// `/solve`, `/sweep`, `/simulate`:
    ///
    /// * `{"profile": {"family": "resnet", "depth": 101, "image": 1000,
    ///   "batch": 8}}` — depth defaults to the family's first supported
    ///   depth, image to 224, batch to 4.
    /// * `{"preset": "default"}`
    /// * `{"graph": "residual"}` (a named graph preset,
    ///   [`crate::graph::NAMES`]) or `{"graph": {"input_bytes": …,
    ///   "nodes": […], "edges": [[0,1], …]}}` — a DAG, validated and
    ///   frontier-fused into a chain ([`crate::graph::GraphSpec`]).
    /// * `{"stages": [{"uf": …, "ub": …, "wa": …, "wabar": …}, …],
    ///   "input_bytes": …}` — an inline measured profile (e.g. from
    ///   `estimate` output on the caller's own hardware).
    ///
    /// The filesystem-touching `{"manifest": "DIR"}` source is
    /// deliberately **rejected** here: this parser fronts the network
    /// daemon, and resolving a client-supplied path would let a remote
    /// caller probe (and attempt to parse) arbitrary server files. Local
    /// callers that own their input use [`ChainSpec::from_json_local`].
    pub fn from_json(spec: &Value) -> Result<ChainSpec> {
        if spec.get("manifest").is_some() {
            fail!(
                InvalidSpec,
                "the 'manifest' chain source reads the local filesystem and is only \
                 available to local callers (CLI --chain / ChainSpec::manifest); \
                 send 'profile', 'preset', 'graph', or inline 'stages' instead"
            );
        }
        Self::from_json_local(spec)
    }

    /// Parse the wire form *plus* the local-only `{"manifest": "DIR"}`
    /// source (an on-disk manifest directory, timed analytically). Used
    /// by the CLI's `--chain FILE`, where the spec file is the
    /// operator's own input — never by the network service.
    pub fn from_json_local(spec: &Value) -> Result<ChainSpec> {
        if let Some(profile) = spec.get("profile") {
            return profile_from_json(profile);
        }
        if let Some(preset) = spec.get("preset") {
            let name = preset.as_str().context("'preset' must be a string")?;
            return Ok(ChainSpec::preset(name));
        }
        if let Some(gv) = spec.get("graph") {
            if let Some(name) = gv.as_str() {
                return match graph::preset(name) {
                    Some(g) => Ok(ChainSpec::graph(g)),
                    None => fail!(
                        UnknownChain,
                        "unknown graph preset '{name}' (graph presets: {})",
                        graph::NAMES.join("/")
                    ),
                };
            }
            return match GraphSpec::from_json(gv) {
                Ok(g) => Ok(ChainSpec::graph(g)),
                Err(e) => fail!(InvalidSpec, "invalid graph spec: {e}"),
            };
        }
        if spec.get("stages").is_some() {
            return Ok(ChainSpec::inline(chain_from_stages(spec)?));
        }
        if let Some(dir) = spec.get("manifest") {
            let dir = dir.as_str().context("'manifest' must be a directory path string")?;
            return Ok(ChainSpec::manifest(dir));
        }
        fail!(
            InvalidSpec,
            "chain spec needs one of 'profile', 'preset', 'graph', 'stages', or 'manifest'"
        )
    }

    /// The batch size this spec implies, when it names one: the
    /// profile's `batch`, or the preset/manifest input shape's leading
    /// dimension. `None` for inline chains (a solver [`Chain`] carries
    /// no batch) and for sources that fail to resolve. Re-reads cheap
    /// geometry metadata for preset/manifest sources — use it once, next
    /// to [`resolve`](Self::resolve).
    pub fn batch_hint(&self) -> Option<u64> {
        match &self.source {
            Source::Profile { batch, .. } => Some(*batch),
            Source::Preset(name) => presets::preset(name)
                .ok()
                .and_then(|m| m.input_shape.first().map(|&b| b as u64)),
            // a GraphSpec carries byte sizes, not tensor shapes
            Source::Graph(_) | Source::Inline(_) => None,
            Source::Manifest(dir) => Manifest::load(dir)
                .ok()
                .and_then(|m| m.input_shape.first().map(|&b| b as u64)),
        }
    }

    /// Normalize and validate into a solver [`Chain`]. This is the *only*
    /// place chain-source validation lives; every entry path (CLI flags,
    /// JSON wire, library builders) funnels through it.
    pub fn resolve(&self) -> Result<Chain> {
        match &self.source {
            Source::Profile { family, depth, image, batch } => {
                if !(32..=4096).contains(image) {
                    fail!(InvalidSpec, "'image' = {image} out of range (32..=4096)");
                }
                if !(1..=1024).contains(batch) {
                    fail!(InvalidSpec, "'batch' = {batch} out of range (1..=1024)");
                }
                profiles::try_by_name(family, *depth, *image, *batch)
                    .with_context(|| {
                        format!(
                            "unknown profile family '{family}' or unsupported depth {depth} \
                             (families: {}; e.g. resnet depths {:?})",
                            profiles::FAMILIES.join("/"),
                            profiles::supported_depths("resnet"),
                        )
                    })
                    .kind(ErrorKind::UnknownChain)
            }
            Source::Preset(name) => {
                let manifest = presets::preset(name).kind(ErrorKind::UnknownChain)?;
                Ok(manifest.to_chain_analytic(PRESET_FLOPS_PER_US))
            }
            Source::Graph(g) => Ok(g.to_chain()),
            Source::Inline(chain) => Ok(chain.clone()),
            Source::Manifest(dir) => {
                let manifest = Manifest::load(dir).kind(ErrorKind::InvalidSpec)?;
                Ok(manifest.to_chain_analytic(PRESET_FLOPS_PER_US))
            }
        }
    }
}

impl std::fmt::Display for ChainSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.source {
            Source::Profile { family, depth, image, batch } => {
                write!(f, "profile {family}-{depth} (image {image}, batch {batch})")
            }
            Source::Preset(name) => write!(f, "preset '{name}'"),
            Source::Graph(g) => write!(f, "{g}"),
            Source::Inline(chain) => write!(f, "inline chain '{}'", chain.name),
            Source::Manifest(dir) => write!(f, "manifest {}", dir.display()),
        }
    }
}

fn profile_from_json(p: &Value) -> Result<ChainSpec> {
    let family = p
        .get("family")
        .and_then(|v| v.as_str())
        .context("profile needs a string 'family' (resnet/densenet/inception/vgg)")?
        .to_string();
    let depth = match p.get("depth") {
        None => *profiles::supported_depths(&family).first().unwrap_or(&0),
        Some(v) => {
            let d = v.as_u64().context("'depth' must be a non-negative integer")?;
            // no silent u32 wrap: 2^32+18 must not alias depth 18
            u32::try_from(d).ok().with_context(|| format!("'depth' = {d} out of range"))?
        }
    };
    let image = p.get("image").map_or(Ok(224), |v| {
        v.as_u64().context("'image' must be a non-negative integer")
    })?;
    let batch = p.get("batch").map_or(Ok(4), |v| {
        v.as_u64().context("'batch' must be a non-negative integer")
    })?;
    Ok(ChainSpec::profile(family, depth, image, batch))
}

fn chain_from_stages(spec: &Value) -> Result<Chain> {
    let stages_json = spec
        .get("stages")
        .and_then(|v| v.as_arr())
        .context("'stages' must be an array")?;
    if stages_json.is_empty() {
        fail!(InvalidSpec, "'stages' must not be empty");
    }
    if stages_json.len() > MAX_STAGES {
        fail!(InvalidSpec, "{} stages exceed the {MAX_STAGES}-stage cap", stages_json.len());
    }
    let wa0 = spec
        .get("input_bytes")
        .context("inline chains need 'input_bytes' (bytes of the chain input a^0)")?
        .as_u64()
        .context("'input_bytes' must be a non-negative integer")?;
    let name = spec
        .get("name")
        .and_then(|v| v.as_str())
        .unwrap_or("inline")
        .to_string();

    let mut stages = Vec::with_capacity(stages_json.len());
    for (i, s) in stages_json.iter().enumerate() {
        let num = |key: &str| -> Result<f64> {
            let v = s
                .get(key)
                .with_context(|| format!("stage {i}: missing '{key}'"))?
                .as_f64()
                .with_context(|| format!("stage {i}: '{key}' must be a number"))?;
            if !v.is_finite() || v < 0.0 {
                fail!(InvalidSpec, "stage {i}: '{key}' = {v} must be finite and ≥ 0");
            }
            Ok(v)
        };
        let bytes = |key: &str| -> Result<u64> {
            s.get(key)
                .with_context(|| format!("stage {i}: missing '{key}'"))?
                .as_u64()
                .with_context(|| format!("stage {i}: '{key}' must be a non-negative integer"))
        };
        let opt_bytes = |key: &str, default: u64| -> Result<u64> {
            match s.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .with_context(|| format!("stage {i}: '{key}' must be a non-negative integer")),
            }
        };
        let (uf, ub) = (num("uf")?, num("ub")?);
        let (wa, wabar) = (bytes("wa")?, bytes("wabar")?);
        if wabar < wa {
            fail!(InvalidSpec, "stage {i}: wabar = {wabar} < wa = {wa} (ā must include a)");
        }
        let stage_name = s
            .get("name")
            .and_then(|v| v.as_str())
            .map(String::from)
            .unwrap_or_else(|| format!("s{}", i + 1));
        let stage = Stage::new(stage_name, uf, ub, wa, wabar)
            .with_overheads(opt_bytes("of", 0)?, opt_bytes("ob", 0)?)
            .with_delta_size(opt_bytes("wd", wa)?);
        stages.push(stage);
    }
    Ok(Chain::new(name, stages, wa0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_chain(body: &str) -> Result<Chain> {
        ChainSpec::from_json(&Value::parse(body).unwrap())?.resolve()
    }

    #[test]
    fn profile_spec_round_trips_to_a_chain() {
        let chain = parse_chain(
            r#"{"profile": {"family": "resnet", "depth": 18, "image": 224, "batch": 8}}"#,
        )
        .unwrap();
        assert_eq!(chain.name, "resnet18-i224-b8");
        assert_eq!(chain.len(), profiles::resnet(18, 224, 8).len());
    }

    #[test]
    fn profile_defaults_fill_in() {
        assert!(parse_chain(r#"{"profile": {"family": "vgg"}}"#).is_ok());
    }

    #[test]
    fn builder_and_json_paths_agree() {
        let via_json = parse_chain(
            r#"{"profile": {"family": "densenet", "depth": 121, "image": 224, "batch": 8}}"#,
        )
        .unwrap();
        let via_builder = ChainSpec::profile("densenet", 121, 224, 8).resolve().unwrap();
        assert_eq!(via_json, via_builder);
    }

    #[test]
    fn bad_profiles_are_kind_tagged_errors_not_panics() {
        for (body, kind) in [
            (r#"{"profile": {"family": "alexnet"}}"#, ErrorKind::UnknownChain),
            (r#"{"profile": {"family": "resnet", "depth": 51}}"#, ErrorKind::UnknownChain),
            // 2^32 + 18: a u32 wrap would alias depth 18
            (
                r#"{"profile": {"family": "resnet", "depth": 4294967314}}"#,
                ErrorKind::InvalidSpec,
            ),
            (
                r#"{"profile": {"family": "resnet", "depth": 50, "image": 4}}"#,
                ErrorKind::InvalidSpec,
            ),
            (
                r#"{"profile": {"family": "resnet", "depth": 50, "batch": 0}}"#,
                ErrorKind::InvalidSpec,
            ),
            (r#"{"preset": "nope"}"#, ErrorKind::UnknownChain),
            (r#"{}"#, ErrorKind::InvalidSpec),
        ] {
            let err = parse_chain(body).unwrap_err();
            assert_eq!(err.kind(), kind, "{body}: {err:#}");
        }
    }

    #[test]
    fn preset_spec_builds_the_native_geometry() {
        let chain = parse_chain(r#"{"preset": "quickstart"}"#).unwrap();
        assert_eq!(chain.len(), 5); // dense + attn + mlp + dense + loss
    }

    #[test]
    fn inline_stages_spec() {
        let chain = parse_chain(
            r#"{"name": "mini", "input_bytes": 400,
                "stages": [
                  {"uf": 1.0, "ub": 2.0, "wa": 100, "wabar": 250},
                  {"name": "loss", "uf": 0.5, "ub": 0.5, "wa": 4, "wabar": 4, "of": 8}
                ]}"#,
        )
        .unwrap();
        assert_eq!(chain.name, "mini");
        assert_eq!(chain.len(), 2);
        assert_eq!(chain.wa0, 400);
        assert_eq!(chain.wabar(1), 250);
        assert_eq!(chain.of(2), 8);
        assert_eq!(chain.stages[1].name, "loss");
    }

    #[test]
    fn inline_stage_validation() {
        // wabar < wa must be a structured error, not Stage::new's panic
        let err = parse_chain(
            r#"{"input_bytes": 1, "stages": [{"uf": 1, "ub": 1, "wa": 10, "wabar": 5}]}"#,
        )
        .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        assert!(format!("{err:#}").contains("wabar"), "{err:#}");
    }

    #[test]
    fn missing_manifest_is_invalid_spec() {
        let err = ChainSpec::manifest("/nonexistent/artifacts").resolve().unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
    }

    #[test]
    fn batch_hint_tracks_the_source() {
        assert_eq!(ChainSpec::profile("resnet", 18, 224, 8).batch_hint(), Some(8));
        let preset_batch =
            presets::preset("quickstart").unwrap().input_shape.first().map(|&b| b as u64);
        assert_eq!(ChainSpec::preset("quickstart").batch_hint(), preset_batch);
        assert!(preset_batch.is_some());
        let inline = ChainSpec::inline(profiles::resnet(18, 224, 8));
        assert_eq!(inline.batch_hint(), None);
        assert_eq!(ChainSpec::preset("nope").batch_hint(), None);
    }

    #[test]
    fn graph_preset_resolves_to_the_fused_chain() {
        let spec = ChainSpec::from_json(&Value::parse(r#"{"graph": "residual"}"#).unwrap())
            .unwrap();
        let chain = spec.resolve().unwrap();
        let g = graph::preset("residual").unwrap();
        assert_eq!(chain, g.to_chain());
        assert_eq!(chain.len(), 7);
        assert_eq!(spec.batch_hint(), None);
        assert!(format!("{spec}").contains("residual"), "{spec}");
    }

    #[test]
    fn inline_graph_object_round_trips() {
        let chain = parse_chain(
            r#"{"graph": {"name": "d", "input_bytes": 32,
                "nodes": [
                  {"uf": 1, "ub": 2, "wa": 100, "wabar": 120},
                  {"uf": 1, "ub": 2, "wa": 80, "wabar": 90},
                  {"uf": 1, "ub": 2, "wa": 60, "wabar": 60},
                  {"name": "loss", "uf": 0.5, "ub": 0.5, "wa": 4, "wabar": 4}
                ],
                "edges": [[0,1],[0,2],[1,2],[2,3]]}}"#,
        )
        .unwrap();
        assert_eq!(chain.len(), 4);
        // the skip value a^1 is carried across stage 2 by fusion
        assert_eq!(chain.wa(2), 80 + 100);
    }

    #[test]
    fn bad_graphs_are_kind_tagged_errors() {
        for (body, kind) in [
            (r#"{"graph": "nope"}"#, ErrorKind::UnknownChain),
            // a cycle
            (
                r#"{"graph": {"input_bytes": 1, "nodes": [
                    {"uf": 1, "ub": 1, "wa": 4, "wabar": 4},
                    {"uf": 1, "ub": 1, "wa": 4, "wabar": 4}],
                    "edges": [[0,1],[1,0]]}}"#,
                ErrorKind::InvalidSpec,
            ),
            // a dangling edge
            (
                r#"{"graph": {"input_bytes": 1, "nodes": [
                    {"uf": 1, "ub": 1, "wa": 4, "wabar": 4}],
                    "edges": [[0,5]]}}"#,
                ErrorKind::InvalidSpec,
            ),
        ] {
            let err = parse_chain(body).unwrap_err();
            assert_eq!(err.kind(), kind, "{body}: {err:#}");
        }
    }

    #[test]
    fn wire_form_rejects_the_filesystem_manifest_source() {
        // the untrusted parser must never turn a network request into a
        // local file read — only from_json_local (CLI --chain) may
        let spec = Value::parse(r#"{"manifest": "/etc"}"#).unwrap();
        let err = ChainSpec::from_json(&spec).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidSpec);
        assert!(format!("{err:#}").contains("local callers"), "{err:#}");
        assert!(ChainSpec::from_json_local(&spec).is_ok());
    }
}
