//! Typed units of the facade: byte budgets and DP slot counts.
//!
//! Before this module, memory budgets travelled as raw `u64` and DP
//! discretizations as raw `usize` — and both the CLI (`util::parse_size`)
//! and the service wire (`wire::parse_bytes`) carried their own copy of
//! the human-suffix grammar. [`MemBytes::parse`] is now the *single*
//! parser for `"512M"` / `"512MB"` / `"1.5GiB"`-style strings, and
//! [`MemBytes`] / [`SlotCount`] make a bytes-vs-slots mixup a type error
//! instead of a latent bug.

use std::fmt;

use super::error::{fail, Error, Result};
use crate::chain::DEFAULT_SLOTS;

/// A byte count (memory budget, activation size, peak usage).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct MemBytes(pub u64);

impl MemBytes {
    /// Wrap a raw byte count.
    pub const fn new(bytes: u64) -> MemBytes {
        MemBytes(bytes)
    }

    /// The raw byte count.
    pub const fn get(self) -> u64 {
        self.0
    }

    /// Parse a human byte size: a plain integer (`"1048576"`) or a
    /// decimal with a 1024-based suffix — `K`/`M`/`G`/`T`, optionally
    /// followed by `B` or `iB`, any case, optional space before the
    /// suffix. `"512M"`, `"512MB"`, `"512 MiB"`, and `"1.5g"` all parse;
    /// fractional values are allowed only with a suffix (`"1.5"` bytes
    /// is rejected, `"1.5K"` is 1536). This is the one suffix parser in
    /// the crate: CLI flags and JSON wire strings both go through it.
    pub fn parse(s: &str) -> Result<MemBytes> {
        let t = s.trim();
        let split = t
            .find(|c: char| !(c.is_ascii_digit() || c == '.'))
            .unwrap_or(t.len());
        let (num, suffix) = t.split_at(split);
        if num.is_empty() {
            fail!(InvalidSpec, "bad size string '{s}': no leading number");
        }
        let mult: u64 = match suffix.trim().to_ascii_lowercase().as_str() {
            "" | "b" => 1,
            "k" | "kb" | "kib" => 1 << 10,
            "m" | "mb" | "mib" => 1 << 20,
            "g" | "gb" | "gib" => 1 << 30,
            "t" | "tb" | "tib" => 1u64 << 40,
            other => fail!(
                InvalidSpec,
                "bad size string '{s}': unknown suffix '{other}' (use K/M/G/T, optionally +B/iB)"
            ),
        };
        // plain integers (no suffix multiplier, no fraction) parse
        // exactly as u64 — the f64 path below would round above 2^53 and
        // reject u64::MAX (which rounds up to 2^64)
        if mult == 1 && !num.contains('.') {
            return num
                .parse()
                .map(MemBytes)
                .map_err(|_| Error::invalid(format!("bad size string '{s}': unparsable number '{num}'")));
        }
        let base: f64 = num
            .parse()
            .map_err(|_| Error::invalid(format!("bad size string '{s}': unparsable number '{num}'")))?;
        if !base.is_finite() || base < 0.0 {
            fail!(InvalidSpec, "bad size string '{s}': size must be finite and >= 0");
        }
        if mult == 1 && base.fract() != 0.0 {
            fail!(InvalidSpec, "bad size string '{s}': fractional bytes need a suffix");
        }
        let bytes = base * mult as f64;
        // `u64::MAX as f64` rounds up to exactly 2^64, so `>=` is needed
        // to reject 2^64 itself instead of silently saturating the cast
        if bytes >= u64::MAX as f64 {
            fail!(InvalidSpec, "bad size string '{s}': exceeds the u64 byte range");
        }
        Ok(MemBytes(bytes as u64))
    }
}

impl fmt::Display for MemBytes {
    /// Human form, parseable back by [`MemBytes::parse`] (within the
    /// two-decimal rounding): `512 B`, `2.0 KiB`, `3.00 MiB`, `5.00 GiB`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&crate::util::fmt_bytes(self.0))
    }
}

impl From<u64> for MemBytes {
    fn from(bytes: u64) -> MemBytes {
        MemBytes(bytes)
    }
}

impl From<MemBytes> for u64 {
    fn from(m: MemBytes) -> u64 {
        m.0
    }
}

/// A DP discretization: how many memory slots the slot axis has
/// (the paper's `S`; granularity, **not** bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SlotCount(pub usize);

impl SlotCount {
    /// Wrap a raw slot count.
    pub const fn new(slots: usize) -> SlotCount {
        SlotCount(slots)
    }

    /// The raw slot count.
    pub const fn get(self) -> usize {
        self.0
    }
}

impl Default for SlotCount {
    /// The paper's S = 500.
    fn default() -> SlotCount {
        SlotCount(DEFAULT_SLOTS)
    }
}

impl fmt::Display for SlotCount {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} slots", self.0)
    }
}

impl From<usize> for SlotCount {
    fn from(slots: usize) -> SlotCount {
        SlotCount(slots)
    }
}

impl From<SlotCount> for usize {
    fn from(s: SlotCount) -> usize {
        s.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_legacy_cli_grammar() {
        // exactly what the old util::parse_size accepted
        assert_eq!(MemBytes::parse("1024").unwrap(), MemBytes(1024));
        assert_eq!(MemBytes::parse("1K").unwrap(), MemBytes(1024));
        assert_eq!(MemBytes::parse("1.5G").unwrap(), MemBytes(3 * (1u64 << 29)));
        assert_eq!(MemBytes::parse("2m").unwrap(), MemBytes(2 << 20));
        assert_eq!(MemBytes::parse(" 512M ").unwrap(), MemBytes(512 << 20));
    }

    #[test]
    fn parses_the_extended_suffix_forms() {
        assert_eq!(MemBytes::parse("512MB").unwrap(), MemBytes(512 << 20));
        assert_eq!(MemBytes::parse("512MiB").unwrap(), MemBytes(512 << 20));
        assert_eq!(MemBytes::parse("512 MiB").unwrap(), MemBytes(512 << 20));
        assert_eq!(MemBytes::parse("1.5GB").unwrap(), MemBytes(3 * (1u64 << 29)));
        assert_eq!(MemBytes::parse("4gib").unwrap(), MemBytes(4 << 30));
        assert_eq!(MemBytes::parse("2T").unwrap(), MemBytes(2u64 << 40));
        assert_eq!(MemBytes::parse("100B").unwrap(), MemBytes(100));
        assert_eq!(MemBytes::parse("0").unwrap(), MemBytes(0));
    }

    #[test]
    fn plain_integers_are_exact_up_to_u64_max() {
        // the f64 path would round these; integers must not lose a byte
        let odd = (1u64 << 53) + 1;
        assert_eq!(MemBytes::parse(&odd.to_string()).unwrap(), MemBytes(odd));
        assert_eq!(
            MemBytes::parse(&u64::MAX.to_string()).unwrap(),
            MemBytes(u64::MAX)
        );
        assert!(MemBytes::parse("18446744073709551616").is_err()); // 2^64
    }

    #[test]
    fn display_round_trips_through_parse() {
        for bytes in [0u64, 512, 2048, 3 << 20, 5 << 30, (15.75 * (1u64 << 30) as f64) as u64] {
            let shown = MemBytes(bytes).to_string();
            let back = MemBytes::parse(&shown).unwrap().get();
            // Display rounds to 1–2 decimals; round-trip within 1 %
            let tol = (bytes / 100).max(1);
            assert!(
                back.abs_diff(bytes) <= tol,
                "{bytes} → '{shown}' → {back}"
            );
        }
        // exact values round-trip exactly
        assert_eq!(MemBytes::parse(&MemBytes(512).to_string()).unwrap(), MemBytes(512));
        assert_eq!(
            MemBytes::parse(&MemBytes(3 << 20).to_string()).unwrap(),
            MemBytes(3 << 20)
        );
    }

    #[test]
    fn rejections_are_invalid_spec_errors() {
        // "16777216T" is exactly 2^64 — the saturating f64→u64 cast must
        // not silently clamp it to u64::MAX
        for bad in
            ["", "x", "-5", "1.5", "1..5K", "12Q", "1e309G", "nanG", "K", "12 34", "16777216T"]
        {
            let err = MemBytes::parse(bad).unwrap_err();
            assert_eq!(
                err.kind(),
                crate::api::ErrorKind::InvalidSpec,
                "'{bad}' must be InvalidSpec"
            );
            assert!(format!("{err:#}").contains("bad size string"), "'{bad}': {err:#}");
        }
    }

    #[test]
    fn slot_count_default_is_the_papers_s() {
        assert_eq!(SlotCount::default().get(), DEFAULT_SLOTS);
        assert_eq!(SlotCount::from(300usize).get(), 300);
        assert_eq!(SlotCount(150).to_string(), "150 slots");
    }
}
