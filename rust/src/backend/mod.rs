//! Tensor-engine abstraction: the seam between schedule replay and the
//! backend that actually computes forward/backward math.
//!
//! The paper's processing phase is engine-agnostic: it replays an op
//! sequence (`F∅`/`Fck`/`Fall`/`B`, Table 1) against *some* store of live
//! tensors. This module captures exactly what that replay needs from an
//! engine — nothing more — so [`crate::runtime`], [`crate::executor`] and
//! [`crate::train`] are generic over the backend and never name a concrete
//! tensor type:
//!
//! * [`Tensor`] — a host-visible f32 tensor: shaped construction from and
//!   extraction to flat `Vec<f32>` (what parameter init, data generation
//!   and gradient collection need).
//! * [`StageExecutable`] — one compiled stage signature with the three
//!   entry points of the manifest contract (`fwd`, `fwd_all`, `bwd`),
//!   taking positional arguments in manifest order and returning the
//!   decomposed output tuple.
//! * [`Backend`] — compiles a manifest signature into a
//!   [`StageExecutable`]; one value of this type is the engine handle the
//!   [`crate::runtime::Runtime`] owns.
//!
//! Two implementations ship:
//!
//! * [`native`] — a pure-Rust f32 engine with hand-written forward and
//!   backward kernels for the manifest's stage kinds (`dense`,
//!   `layernorm`, `mlp`, `attn`, `loss`). Runs everywhere, no artifacts
//!   or external toolchain needed; manifests can be generated in-process
//!   by [`native::presets`].
//! * [`pjrt`] — the original XLA/PJRT path over AOT-compiled HLO-text
//!   artifacts (`python/compile/aot.py`). Everything `xla`-typed lives
//!   under this module; with the vendored stub crate it fails fast with
//!   an explanatory error.

pub mod native;
pub mod pjrt;

pub use native::{NativeBackend, NativeTensor};
pub use pjrt::PjrtBackend;

use anyhow::Result;

use crate::chain::manifest::Manifest;

/// Entry points every stage signature exposes (the manifest contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Entry {
    /// `(θ…, a_in) → (a_out,)` — used by both `F∅` and `Fck`.
    Fwd,
    /// `(θ…, a_in) → (a_out, ā-extras…)` — `Fall`.
    FwdAll,
    /// `(θ…, a_in, ā…, δ_out) → (δ_in, ∂θ…)` — `B`.
    Bwd,
}

impl Entry {
    pub fn name(&self) -> &'static str {
        match self {
            Entry::Fwd => "fwd",
            Entry::FwdAll => "fwd_all",
            Entry::Bwd => "bwd",
        }
    }
}

/// A host-visible f32 tensor owned by a backend.
///
/// The replay loop passes `&T` references and never inspects elements;
/// the flat-vector conversions exist for the edges of the system
/// (parameter init, synthetic data, gradient collection, loss readout).
pub trait Tensor: Clone + std::fmt::Debug + Sized {
    /// Shaped construction from a flat row-major vector. An empty shape
    /// means a rank-0 scalar (one element).
    fn from_vec(data: &[f32], shape: &[usize]) -> Result<Self>;

    /// Rank-0 scalar.
    fn scalar(x: f32) -> Self;

    /// Zero-filled tensor of the given shape.
    fn zeros(shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product::<usize>().max(1);
        Self::from_vec(&vec![0.0; n], shape)
    }

    /// Extract the contents as a flat row-major vector.
    fn to_vec(&self) -> Result<Vec<f32>>;

    /// Number of elements.
    fn element_count(&self) -> usize;
}

/// One compiled stage signature: the three manifest entry points over the
/// backend's tensor type. Arguments are positional in manifest order; the
/// returned vector is the decomposed output tuple.
pub trait StageExecutable<T: Tensor> {
    /// `(θ…, a_in) → [a_out]`.
    fn fwd(&self, args: &[&T]) -> Result<Vec<T>>;

    /// `(θ…, a_in) → [a_out, ā-extras…]`.
    fn fwd_all(&self, args: &[&T]) -> Result<Vec<T>>;

    /// `(θ…, a_in, ā…, δ_out) → [δ_in, ∂θ…]`.
    fn bwd(&self, args: &[&T]) -> Result<Vec<T>>;

    /// Dispatch by [`Entry`] (estimator / generic callers).
    fn entry(&self, entry: Entry, args: &[&T]) -> Result<Vec<T>> {
        match entry {
            Entry::Fwd => self.fwd(args),
            Entry::FwdAll => self.fwd_all(args),
            Entry::Bwd => self.bwd(args),
        }
    }
}

/// A tensor engine: compiles manifest signatures into executables.
pub trait Backend {
    type Tensor: Tensor;
    type Stage: StageExecutable<Self::Tensor>;

    /// Short identifier (`"native"`, `"pjrt"`) for logs and errors.
    fn name(&self) -> &'static str;

    /// Compile one signature of the manifest. Called once per distinct
    /// signature by [`crate::runtime::Runtime`] — the paper's "computed
    /// once before training" phase.
    fn compile(&self, manifest: &Manifest, sig: &str) -> Result<Self::Stage>;
}
