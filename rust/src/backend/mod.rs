//! Tensor-engine abstraction: the seam between schedule replay and the
//! backend that actually computes forward/backward math.
//!
//! The paper's processing phase is engine-agnostic: it replays an op
//! sequence (`F∅`/`Fck`/`Fall`/`B`, Table 1) against *some* store of live
//! tensors. This module captures exactly what that replay needs from an
//! engine — nothing more — so [`crate::runtime`], [`crate::executor`] and
//! [`crate::train`] are generic over the backend and never name a concrete
//! tensor type:
//!
//! * [`Tensor`] — a host-visible f32 tensor: shaped construction from and
//!   extraction to flat `Vec<f32>` (what parameter init, data generation
//!   and gradient collection need).
//! * [`StageExecutable`] — one compiled stage signature with the three
//!   entry points of the manifest contract (`fwd`, `fwd_all`, `bwd`),
//!   taking positional arguments in manifest order and returning the
//!   decomposed output tuple.
//! * [`Backend`] — compiles a manifest signature into a
//!   [`StageExecutable`]; one value of this type is the engine handle the
//!   [`crate::runtime::Runtime`] owns.
//!
//! Two implementations ship:
//!
//! * [`native`] — a pure-Rust f32 engine with hand-written forward and
//!   backward kernels for the manifest's stage kinds (`dense`,
//!   `layernorm`, `mlp`, `attn`, `loss`). Runs everywhere, no artifacts
//!   or external toolchain needed; manifests can be generated in-process
//!   by [`native::presets`].
//! * [`pjrt`] — the original XLA/PJRT path over AOT-compiled HLO-text
//!   artifacts (`python/compile/aot.py`). Everything `xla`-typed lives
//!   under this module; with the vendored stub crate it fails fast with
//!   an explanatory error.

pub mod native;
pub mod pjrt;

pub use native::{NativeBackend, NativeTensor};
pub use pjrt::PjrtBackend;

use anyhow::{Context, Result};

use crate::chain::manifest::Manifest;

/// Entry points every stage signature exposes (the manifest contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Entry {
    /// `(θ…, a_in) → (a_out,)` — used by both `F∅` and `Fck`.
    Fwd,
    /// `(θ…, a_in) → (a_out, ā-extras…)` — `Fall`.
    FwdAll,
    /// `(θ…, a_in, ā…, δ_out) → (δ_in, ∂θ…)` — `B`.
    Bwd,
}

impl Entry {
    pub fn name(&self) -> &'static str {
        match self {
            Entry::Fwd => "fwd",
            Entry::FwdAll => "fwd_all",
            Entry::Bwd => "bwd",
        }
    }
}

/// A host-visible f32 tensor owned by a backend.
///
/// The replay loop passes `&T` references and never inspects elements;
/// the flat-vector conversions exist for the edges of the system
/// (parameter init, synthetic data, gradient collection, loss readout).
pub trait Tensor: Clone + std::fmt::Debug + Sized {
    /// Shaped construction from a flat row-major vector. An empty shape
    /// means a rank-0 scalar (one element).
    fn from_vec(data: &[f32], shape: &[usize]) -> Result<Self>;

    /// Rank-0 scalar.
    fn scalar(x: f32) -> Self;

    /// Zero-filled tensor of the given shape.
    fn zeros(shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product::<usize>().max(1);
        Self::from_vec(&vec![0.0; n], shape)
    }

    /// Extract the contents as a flat row-major vector.
    fn to_vec(&self) -> Result<Vec<f32>>;

    /// Copy the contents into a caller-provided buffer of exactly
    /// [`Tensor::element_count`] elements. The default round-trips
    /// through [`Tensor::to_vec`]; backends with host-resident storage
    /// override it allocation-free (the lowered executor copies the batch
    /// input into its pooled arena through this each iteration).
    fn read_into(&self, out: &mut [f32]) -> Result<()> {
        let v = self.to_vec()?;
        anyhow::ensure!(
            v.len() == out.len(),
            "read_into: tensor has {} elements, buffer {}",
            v.len(),
            out.len()
        );
        out.copy_from_slice(&v);
        Ok(())
    }

    /// Number of elements.
    fn element_count(&self) -> usize;
}

/// Recycled temporary buffers for in-place kernels.
///
/// `take(n)` hands out a zeroed length-`n` buffer (reusing a previously
/// returned one when available), `give` returns it. Because a lowered
/// replay performs the identical take/give sequence every iteration, each
/// physical buffer is resized to the same length every time — capacities
/// ratchet up during the first iteration and **steady-state iterations
/// perform zero heap allocations**.
#[derive(Debug, Default)]
pub struct Scratch {
    free: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch::default()
    }

    /// A zero-filled buffer of `n` elements (matching the `vec![0.0; n]`
    /// the allocating kernels start from) — for accumulation targets
    /// (`matmul_acc` and friends).
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// A length-`n` buffer with **unspecified contents** — for
    /// temporaries the kernel fully overwrites before reading
    /// (transpose/split/merge/affine/layernorm targets, element-wise
    /// maps). Skips `take`'s per-call memset; in steady state this
    /// neither writes nor allocates.
    pub fn take_dirty(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        if v.len() > n {
            v.truncate(n);
        } else {
            v.resize(n, 0.0); // zeros only the grown tail
        }
        v
    }

    /// Return a buffer taken with [`Scratch::take`]. Buffers that are not
    /// given back are simply dropped — correct, but re-allocated next
    /// iteration.
    pub fn give(&mut self, v: Vec<f32>) {
        self.free.push(v);
    }
}

/// The output buffers of one in-place entry call, in the entry's output
/// order. Each buffer is taken at most once and must be completely
/// overwritten by the kernel (pooled storage carries stale bytes from the
/// slot's previous occupant).
pub struct Outs<'s, 'a> {
    bufs: &'s mut [Option<&'a mut [f32]>],
}

impl<'s, 'a> Outs<'s, 'a> {
    pub fn new(bufs: &'s mut [Option<&'a mut [f32]>]) -> Self {
        Outs { bufs }
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Claim output `i`, checking the expected element count.
    pub fn take(&mut self, i: usize, nelem: usize, what: &str) -> Result<&'a mut [f32]> {
        let buf = self
            .bufs
            .get_mut(i)
            .and_then(Option::take)
            .with_context(|| format!("{what}: output #{i} missing or taken twice"))?;
        anyhow::ensure!(
            buf.len() == nelem,
            "{what}: output #{i} has {} elements, expected {nelem}",
            buf.len()
        );
        Ok(buf)
    }
}

/// One compiled stage signature: the three manifest entry points over the
/// backend's tensor type. Arguments are positional in manifest order; the
/// returned vector is the decomposed output tuple.
pub trait StageExecutable<T: Tensor> {
    /// `(θ…, a_in) → [a_out]`.
    fn fwd(&self, args: &[&T]) -> Result<Vec<T>>;

    /// `(θ…, a_in) → [a_out, ā-extras…]`.
    fn fwd_all(&self, args: &[&T]) -> Result<Vec<T>>;

    /// `(θ…, a_in, ā…, δ_out) → [δ_in, ∂θ…]`.
    fn bwd(&self, args: &[&T]) -> Result<Vec<T>>;

    /// Dispatch by [`Entry`] (estimator / generic callers).
    fn entry(&self, entry: Entry, args: &[&T]) -> Result<Vec<T>> {
        match entry {
            Entry::Fwd => self.fwd(args),
            Entry::FwdAll => self.fwd_all(args),
            Entry::Bwd => self.bwd(args),
        }
    }

    /// In-place entry point over raw f32 storage: read positional `args`
    /// (flat row-major slices in manifest order), write each output of
    /// the entry's tuple into the pre-sized buffers of `outs`, using
    /// `scratch` for temporaries. Argument and output buffers are
    /// guaranteed disjoint by the caller (the lowered executor's slot
    /// assignment), and results must be **bit-identical** to the
    /// allocating entry points.
    ///
    /// The default rejects — only backends advertising
    /// [`Backend::SUPPORTS_LOWERED`] implement it (the native engine's
    /// zero-allocation kernels live in `backend::native`'s in-place
    /// module).
    fn entry_into(
        &self,
        entry: Entry,
        args: &[&[f32]],
        outs: &mut Outs<'_, '_>,
        scratch: &mut Scratch,
    ) -> Result<()> {
        let _ = (entry, args, outs, scratch);
        anyhow::bail!("this backend has no in-place kernels (lowered execution is native-only)")
    }
}

/// A tensor engine: compiles manifest signatures into executables.
pub trait Backend {
    type Tensor: Tensor;
    type Stage: StageExecutable<Self::Tensor>;

    /// Whether this engine implements [`StageExecutable::entry_into`] —
    /// i.e. whether the lowered (pooled, zero-allocation) executor path
    /// can run on it. [`api::execute_schedule`](crate::api) falls back to
    /// the legacy per-op replay when this is `false`.
    const SUPPORTS_LOWERED: bool = false;

    /// Short identifier (`"native"`, `"pjrt"`) for logs and errors.
    fn name(&self) -> &'static str;

    /// Compile one signature of the manifest. Called once per distinct
    /// signature by [`crate::runtime::Runtime`] — the paper's "computed
    /// once before training" phase.
    fn compile(&self, manifest: &Manifest, sig: &str) -> Result<Self::Stage>;
}
