//! In-place entry points for the native stages: the zero-allocation
//! twins of `stages.rs`, writing results straight into pooled storage.
//!
//! Contract (enforced by [`Executor::run_lowered`]'s slot assignment):
//! argument and output buffers are disjoint; output buffers arrive
//! pre-sized but **dirty** (a pooled slot carries its previous occupant's
//! bytes), so every kernel fully overwrites — or zero-fills before
//! accumulating into — each output it claims. Temporaries come from a
//! [`Scratch`] pool; because a lowered replay takes and gives the same
//! buffer sequence every iteration, steady-state iterations allocate
//! nothing.
//!
//! **Bit-identity.** Every output here is computed by the same kernels in
//! the same per-element accumulation order as the allocating entries
//! (`matmul_into` = `vec![0.0; ..]` + the shared blocked loop, etc.), so
//! a lowered replay's loss and gradients match the legacy replay bit for
//! bit — which `tests/plan_parity.rs` asserts.
//!
//! [`Executor::run_lowered`]: crate::executor::Executor::run_lowered

use anyhow::{ensure, Result};

use super::kernels::{
    add_bias, col_sum_into, gelu, gelu_grad, layernorm_bwd_into, layernorm_into, matmul_acc,
    matmul_into, softmax_rows, softmax_rows_bwd_into, transpose_into,
};
use super::stages::{affine_into, Attn, Dense, LayerNorm, Loss, Mlp};
use super::NativeStage;
use crate::backend::{Entry, Outs, Scratch};

/// Dispatch one in-place entry (see [`crate::backend::StageExecutable::entry_into`]).
pub(super) fn entry_into(
    stage: &NativeStage,
    entry: Entry,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
) -> Result<()> {
    match stage {
        NativeStage::Dense(s) => match entry {
            Entry::Fwd => dense_fwd(s, args, outs, scratch, false),
            Entry::FwdAll => dense_fwd(s, args, outs, scratch, true),
            Entry::Bwd => dense_bwd(s, args, outs, scratch),
        },
        NativeStage::LayerNorm(s) => match entry {
            Entry::Fwd => layernorm_fwd(s, args, outs, scratch, false),
            Entry::FwdAll => layernorm_fwd(s, args, outs, scratch, true),
            Entry::Bwd => layernorm_bwd_entry(s, args, outs),
        },
        NativeStage::Mlp(s) => match entry {
            Entry::Fwd => mlp_fwd(s, args, outs, scratch, false),
            Entry::FwdAll => mlp_fwd(s, args, outs, scratch, true),
            Entry::Bwd => mlp_bwd(s, args, outs, scratch),
        },
        NativeStage::Attn(s) => match entry {
            Entry::Fwd => attn_fwd(s, args, outs, scratch, false),
            Entry::FwdAll => attn_fwd(s, args, outs, scratch, true),
            Entry::Bwd => attn_bwd(s, args, outs, scratch),
        },
        NativeStage::Loss(s) => match entry {
            // the loss stage tapes nothing: fwd_all ≡ fwd
            Entry::Fwd | Entry::FwdAll => loss_fwd(s, args, outs),
            Entry::Bwd => loss_bwd(s, args, outs),
        },
    }
}

fn arity(args: &[&[f32]], want: usize, what: &str) -> Result<()> {
    ensure!(args.len() == want, "{what}: expected {want} args, got {}", args.len());
    Ok(())
}

/// Argument `i`, checked against an expected element count.
fn arg<'a>(args: &[&'a [f32]], i: usize, nelem: usize, what: &str) -> Result<&'a [f32]> {
    let d = args[i];
    ensure!(
        d.len() == nelem,
        "{what}: arg #{i} has {} elements, expected {nelem}",
        d.len()
    );
    Ok(d)
}

/// Bind `$slice` to output `$i` when `$all`, else to a scratch buffer
/// remembered in `$buf` (give it back with `give_back!`).
macro_rules! out_or_scratch {
    ($buf:ident, $slice:ident, $all:expr, $outs:ident, $i:expr, $n:expr, $scratch:ident, $what:expr) => {
        let mut $buf: Option<Vec<f32>> = None;
        let $slice: &mut [f32] = if $all {
            $outs.take($i, $n, $what)?
        } else {
            $buf = Some($scratch.take_dirty($n));
            $buf.as_mut().expect("just set").as_mut_slice()
        };
    };
}

macro_rules! give_back {
    ($scratch:ident, $($buf:ident),+ $(,)?) => {
        $(if let Some(b) = $buf { $scratch.give(b); })+
    };
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

fn dense_fwd(
    s: &Dense,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
    all: bool,
) -> Result<()> {
    let what = "dense/fwd_into";
    arity(args, 3, what)?;
    let m = s.m();
    let w = arg(args, 0, s.d_in * s.d_out, what)?;
    let bias = arg(args, 1, s.d_out, what)?;
    let x = arg(args, 2, m * s.d_in, what)?;
    if !s.gelu {
        // linear head: z is the output itself (no ā extras either way)
        let y = outs.take(0, m * s.d_out, what)?;
        matmul_into(x, w, y, m, s.d_in, s.d_out);
        add_bias(y, bias, m, s.d_out);
        return Ok(());
    }
    out_or_scratch!(z_buf, z, all, outs, 1, m * s.d_out, scratch, what);
    matmul_into(x, w, z, m, s.d_in, s.d_out);
    add_bias(z, bias, m, s.d_out);
    let y = outs.take(0, m * s.d_out, what)?;
    for (yo, &zv) in y.iter_mut().zip(z.iter()) {
        *yo = gelu(zv);
    }
    give_back!(scratch, z_buf);
    Ok(())
}

fn dense_bwd(
    s: &Dense,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
) -> Result<()> {
    let what = "dense/bwd_into";
    // (w, b, x, ā…, δ): ā = (y,) for linear, (y, z) with a gelu
    let n_abar = if s.gelu { 2 } else { 1 };
    arity(args, 3 + n_abar + 1, what)?;
    let m = s.m();
    let w = arg(args, 0, s.d_in * s.d_out, what)?;
    let x = arg(args, 2, m * s.d_in, what)?;
    let dy = arg(args, 3 + n_abar, m * s.d_out, what)?;
    let mut dz_buf: Option<Vec<f32>> = None;
    let dz: &[f32] = if s.gelu {
        let z = arg(args, 4, m * s.d_out, what)?;
        let mut t = scratch.take_dirty(m * s.d_out);
        for ((o, &g), &zv) in t.iter_mut().zip(dy).zip(z) {
            *o = g * gelu_grad(zv);
        }
        dz_buf = Some(t);
        dz_buf.as_deref().expect("just set")
    } else {
        dy
    };
    let mut wt = scratch.take_dirty(s.d_in * s.d_out);
    transpose_into(w, &mut wt, s.d_in, s.d_out);
    let dx = outs.take(0, m * s.d_in, what)?;
    matmul_into(dz, &wt, dx, m, s.d_out, s.d_in);
    let mut xt = scratch.take_dirty(m * s.d_in);
    transpose_into(x, &mut xt, m, s.d_in);
    let gw = outs.take(1, s.d_in * s.d_out, what)?;
    matmul_into(&xt, dz, gw, s.d_in, m, s.d_out);
    let gb = outs.take(2, s.d_out, what)?;
    col_sum_into(dz, gb, m, s.d_out);
    scratch.give(xt);
    scratch.give(wt);
    give_back!(scratch, dz_buf);
    Ok(())
}

// ---------------------------------------------------------------------------
// LayerNorm
// ---------------------------------------------------------------------------

fn layernorm_fwd(
    s: &LayerNorm,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
    all: bool,
) -> Result<()> {
    let what = "layernorm/fwd_into";
    arity(args, 3, what)?;
    let (m, d) = (s.b * s.t, s.d);
    let g = arg(args, 0, d, what)?;
    let beta = arg(args, 1, d, what)?;
    let x = arg(args, 2, m * d, what)?;
    out_or_scratch!(xhat_buf, xhat, all, outs, 1, m * d, scratch, what);
    out_or_scratch!(rstd_buf, rstd, all, outs, 2, m, scratch, what);
    layernorm_into(x, xhat, rstd, m, d);
    let y = outs.take(0, m * d, what)?;
    affine_into(xhat, g, beta, y, m, d);
    give_back!(scratch, rstd_buf, xhat_buf);
    Ok(())
}

fn layernorm_bwd_entry(s: &LayerNorm, args: &[&[f32]], outs: &mut Outs<'_, '_>) -> Result<()> {
    let what = "layernorm/bwd_into";
    // (g, beta, x, y, xhat, rstd, δ)
    arity(args, 7, what)?;
    let (m, d) = (s.b * s.t, s.d);
    let g = arg(args, 0, d, what)?;
    let xhat = arg(args, 4, m * d, what)?;
    let rstd = arg(args, 5, m, what)?;
    let dy = arg(args, 6, m * d, what)?;
    let dx = outs.take(0, m * d, what)?;
    let gg = outs.take(1, d, what)?;
    let gb = outs.take(2, d, what)?;
    layernorm_bwd_into(dy, xhat, rstd, g, dx, gg, gb, m, d);
    Ok(())
}

// ---------------------------------------------------------------------------
// Mlp
// ---------------------------------------------------------------------------

fn mlp_fwd(
    s: &Mlp,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
    all: bool,
) -> Result<()> {
    let what = "mlp/fwd_into";
    arity(args, 7, what)?;
    let (m, d, f) = (s.b * s.t, s.d, s.f);
    let g = arg(args, 0, d, what)?;
    let beta = arg(args, 1, d, what)?;
    let w1 = arg(args, 2, d * f, what)?;
    let c1 = arg(args, 3, f, what)?;
    let w2 = arg(args, 4, f * d, what)?;
    let c2 = arg(args, 5, d, what)?;
    let x = arg(args, 6, m * d, what)?;
    out_or_scratch!(xhat_buf, xhat, all, outs, 1, m * d, scratch, what);
    out_or_scratch!(rstd_buf, rstd, all, outs, 2, m, scratch, what);
    out_or_scratch!(z1_buf, z1, all, outs, 3, m * f, scratch, what);
    out_or_scratch!(u_buf, u, all, outs, 4, m * f, scratch, what);
    layernorm_into(x, xhat, rstd, m, d);
    let mut h = scratch.take_dirty(m * d);
    affine_into(xhat, g, beta, &mut h, m, d);
    matmul_into(&h, w1, z1, m, d, f);
    add_bias(z1, c1, m, f);
    for (o, &zv) in u.iter_mut().zip(z1.iter()) {
        *o = gelu(zv);
    }
    let mut z2 = scratch.take(m * d);
    matmul_acc(u, w2, &mut z2, m, f, d);
    add_bias(&mut z2, c2, m, d);
    let y = outs.take(0, m * d, what)?;
    for ((o, &xv), &zv) in y.iter_mut().zip(x).zip(&z2) {
        *o = xv + zv;
    }
    scratch.give(z2);
    scratch.give(h);
    give_back!(scratch, u_buf, z1_buf, rstd_buf, xhat_buf);
    Ok(())
}

fn mlp_bwd(
    s: &Mlp,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
) -> Result<()> {
    let what = "mlp/bwd_into";
    // (g, beta, w1, c1, w2, c2, x, y, xhat, rstd, z1, u, δ)
    arity(args, 13, what)?;
    let (m, d, f) = (s.b * s.t, s.d, s.f);
    let g = arg(args, 0, d, what)?;
    let beta = arg(args, 1, d, what)?;
    let w1 = arg(args, 2, d * f, what)?;
    let w2 = arg(args, 4, f * d, what)?;
    let xhat = arg(args, 8, m * d, what)?;
    let rstd = arg(args, 9, m, what)?;
    let z1 = arg(args, 10, m * f, what)?;
    let u = arg(args, 11, m * f, what)?;
    let dy = arg(args, 12, m * d, what)?;
    // residual: y = x + z2 ⇒ dz2 = dy
    let mut ut = scratch.take_dirty(m * f);
    transpose_into(u, &mut ut, m, f);
    let gw2 = outs.take(5, f * d, what)?;
    matmul_into(&ut, dy, gw2, f, m, d);
    let gc2 = outs.take(6, d, what)?;
    col_sum_into(dy, gc2, m, d);
    let mut w2t = scratch.take_dirty(f * d);
    transpose_into(w2, &mut w2t, f, d);
    let mut du = scratch.take(m * f);
    matmul_acc(dy, &w2t, &mut du, m, d, f);
    let mut dz1 = scratch.take_dirty(m * f);
    for ((o, &g_), &zv) in dz1.iter_mut().zip(&du).zip(z1) {
        *o = g_ * gelu_grad(zv);
    }
    // h is cheap to recompute from the checkpointed x̂
    let mut h = scratch.take_dirty(m * d);
    affine_into(xhat, g, beta, &mut h, m, d);
    let mut ht = scratch.take_dirty(m * d);
    transpose_into(&h, &mut ht, m, d);
    let gw1 = outs.take(3, d * f, what)?;
    matmul_into(&ht, &dz1, gw1, d, m, f);
    let gc1 = outs.take(4, f, what)?;
    col_sum_into(&dz1, gc1, m, f);
    let mut w1t = scratch.take_dirty(d * f);
    transpose_into(w1, &mut w1t, d, f);
    let mut dh = scratch.take(m * d);
    matmul_acc(&dz1, &w1t, &mut dh, m, f, d);
    let mut dx_ln = scratch.take_dirty(m * d);
    let gg = outs.take(1, d, what)?;
    let gbeta = outs.take(2, d, what)?;
    layernorm_bwd_into(&dh, xhat, rstd, g, &mut dx_ln, gg, gbeta, m, d);
    let dx = outs.take(0, m * d, what)?;
    for ((o, &a), &b) in dx.iter_mut().zip(dy).zip(&dx_ln) {
        *o = a + b;
    }
    scratch.give(dx_ln);
    scratch.give(dh);
    scratch.give(w1t);
    scratch.give(ht);
    scratch.give(h);
    scratch.give(dz1);
    scratch.give(du);
    scratch.give(w2t);
    scratch.give(ut);
    Ok(())
}

// ---------------------------------------------------------------------------
// Attn
// ---------------------------------------------------------------------------

fn attn_fwd(
    s: &Attn,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
    all: bool,
) -> Result<()> {
    let what = "attn/fwd_into";
    arity(args, 7, what)?;
    let (m, d, t, dh) = (s.b * s.t, s.d, s.t, s.dh());
    let bh = s.b * s.heads;
    let g = arg(args, 0, d, what)?;
    let beta = arg(args, 1, d, what)?;
    let wq = arg(args, 2, d * d, what)?;
    let wk = arg(args, 3, d * d, what)?;
    let wv = arg(args, 4, d * d, what)?;
    let wo = arg(args, 5, d * d, what)?;
    let x = arg(args, 6, m * d, what)?;
    out_or_scratch!(xhat_buf, xhat, all, outs, 1, m * d, scratch, what);
    out_or_scratch!(rstd_buf, rstd, all, outs, 2, m, scratch, what);
    out_or_scratch!(q_buf, q, all, outs, 3, bh * t * dh, scratch, what);
    out_or_scratch!(k_buf, k, all, outs, 4, bh * t * dh, scratch, what);
    out_or_scratch!(v_buf, v, all, outs, 5, bh * t * dh, scratch, what);
    out_or_scratch!(p_buf, p, all, outs, 6, bh * t * t, scratch, what);
    out_or_scratch!(c_buf, c, all, outs, 7, bh * t * dh, scratch, what);
    layernorm_into(x, xhat, rstd, m, d);
    let mut h = scratch.take_dirty(m * d);
    affine_into(xhat, g, beta, &mut h, m, d);
    let mut proj = scratch.take_dirty(m * d);
    matmul_into(&h, wq, &mut proj, m, d, d);
    s.split_into(&proj, q);
    matmul_into(&h, wk, &mut proj, m, d, d);
    s.split_into(&proj, k);
    matmul_into(&h, wv, &mut proj, m, d, d);
    s.split_into(&proj, v);
    let scale = 1.0 / (dh as f32).sqrt();
    for i in 0..bh {
        let qb = &q[i * t * dh..(i + 1) * t * dh];
        let kb = &k[i * t * dh..(i + 1) * t * dh];
        let vb = &v[i * t * dh..(i + 1) * t * dh];
        let mut kt = scratch.take_dirty(t * dh);
        transpose_into(kb, &mut kt, t, dh);
        let mut sblk = scratch.take(t * t);
        matmul_acc(qb, &kt, &mut sblk, t, dh, t);
        for sv in sblk.iter_mut() {
            *sv *= scale;
        }
        softmax_rows(&mut sblk, t, t);
        let mut cb = scratch.take(t * dh);
        matmul_acc(&sblk, vb, &mut cb, t, t, dh);
        p[i * t * t..(i + 1) * t * t].copy_from_slice(&sblk);
        c[i * t * dh..(i + 1) * t * dh].copy_from_slice(&cb);
        scratch.give(cb);
        scratch.give(sblk);
        scratch.give(kt);
    }
    // output projection + residual: y = x + merge(c)·wo
    let mut cm = scratch.take_dirty(m * d);
    s.merge_into(c, &mut cm);
    let mut o = scratch.take(m * d);
    matmul_acc(&cm, wo, &mut o, m, d, d);
    let y = outs.take(0, m * d, what)?;
    for ((yo, &xv), &ov) in y.iter_mut().zip(x).zip(&o) {
        *yo = xv + ov;
    }
    scratch.give(o);
    scratch.give(cm);
    scratch.give(proj);
    scratch.give(h);
    give_back!(scratch, c_buf, p_buf, v_buf, k_buf, q_buf, rstd_buf, xhat_buf);
    Ok(())
}

fn attn_bwd(
    s: &Attn,
    args: &[&[f32]],
    outs: &mut Outs<'_, '_>,
    scratch: &mut Scratch,
) -> Result<()> {
    let what = "attn/bwd_into";
    // (g, beta, wq, wk, wv, wo, x, y, xhat, rstd, q, k, v, p, c, δ)
    arity(args, 16, what)?;
    let (m, d, t, dh) = (s.b * s.t, s.d, s.t, s.dh());
    let bh = s.b * s.heads;
    let g = arg(args, 0, d, what)?;
    let beta = arg(args, 1, d, what)?;
    let wq = arg(args, 2, d * d, what)?;
    let wk = arg(args, 3, d * d, what)?;
    let wv = arg(args, 4, d * d, what)?;
    let wo = arg(args, 5, d * d, what)?;
    let xhat = arg(args, 8, m * d, what)?;
    let rstd = arg(args, 9, m, what)?;
    let q = arg(args, 10, bh * t * dh, what)?;
    let k = arg(args, 11, bh * t * dh, what)?;
    let v = arg(args, 12, bh * t * dh, what)?;
    let p = arg(args, 13, bh * t * t, what)?;
    let c = arg(args, 14, bh * t * dh, what)?;
    let dy = arg(args, 15, m * d, what)?;
    // output projection: o = merge(c)·wo, y = x + o
    let mut cf = scratch.take_dirty(m * d);
    s.merge_into(c, &mut cf);
    let mut cft = scratch.take_dirty(m * d);
    transpose_into(&cf, &mut cft, m, d);
    let gwo = outs.take(6, d * d, what)?;
    matmul_into(&cft, dy, gwo, d, m, d);
    let mut wot = scratch.take_dirty(d * d);
    transpose_into(wo, &mut wot, d, d);
    let mut dcm = scratch.take(m * d);
    matmul_acc(dy, &wot, &mut dcm, m, d, d);
    let mut dc = scratch.take_dirty(bh * t * dh);
    s.split_into(&dcm, &mut dc);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = scratch.take_dirty(bh * t * dh);
    let mut dk = scratch.take_dirty(bh * t * dh);
    let mut dv = scratch.take_dirty(bh * t * dh);
    for i in 0..bh {
        let pb = &p[i * t * t..(i + 1) * t * t];
        let qb = &q[i * t * dh..(i + 1) * t * dh];
        let kb = &k[i * t * dh..(i + 1) * t * dh];
        let vb = &v[i * t * dh..(i + 1) * t * dh];
        let dcb = &dc[i * t * dh..(i + 1) * t * dh];
        // c = p·v
        let mut vbt = scratch.take_dirty(t * dh);
        transpose_into(vb, &mut vbt, t, dh);
        let mut dp = scratch.take(t * t);
        matmul_acc(dcb, &vbt, &mut dp, t, dh, t);
        let mut pbt = scratch.take_dirty(t * t);
        transpose_into(pb, &mut pbt, t, t);
        let mut dvb = scratch.take(t * dh);
        matmul_acc(&pbt, dcb, &mut dvb, t, t, dh);
        // softmax backward, then the scaled score products
        let mut ds = scratch.take_dirty(t * t);
        softmax_rows_bwd_into(pb, &dp, &mut ds, t, t);
        let mut dqb = scratch.take(t * dh);
        matmul_acc(&ds, kb, &mut dqb, t, t, dh);
        let mut dst = scratch.take_dirty(t * t);
        transpose_into(&ds, &mut dst, t, t);
        let mut dkb = scratch.take(t * dh);
        matmul_acc(&dst, qb, &mut dkb, t, t, dh);
        for x_ in dqb.iter_mut() {
            *x_ *= scale;
        }
        for x_ in dkb.iter_mut() {
            *x_ *= scale;
        }
        dq[i * t * dh..(i + 1) * t * dh].copy_from_slice(&dqb);
        dk[i * t * dh..(i + 1) * t * dh].copy_from_slice(&dkb);
        dv[i * t * dh..(i + 1) * t * dh].copy_from_slice(&dvb);
        scratch.give(dkb);
        scratch.give(dst);
        scratch.give(dqb);
        scratch.give(ds);
        scratch.give(dvb);
        scratch.give(pbt);
        scratch.give(dp);
        scratch.give(vbt);
    }
    // projections back to h
    let mut dq2d = scratch.take_dirty(m * d);
    s.merge_into(&dq, &mut dq2d);
    let mut dk2d = scratch.take_dirty(m * d);
    s.merge_into(&dk, &mut dk2d);
    let mut dv2d = scratch.take_dirty(m * d);
    s.merge_into(&dv, &mut dv2d);
    let mut h = scratch.take_dirty(m * d);
    affine_into(xhat, g, beta, &mut h, m, d);
    let mut ht = scratch.take_dirty(m * d);
    transpose_into(&h, &mut ht, m, d);
    let gwq = outs.take(3, d * d, what)?;
    matmul_into(&ht, &dq2d, gwq, d, m, d);
    let gwk = outs.take(4, d * d, what)?;
    matmul_into(&ht, &dk2d, gwk, d, m, d);
    let gwv = outs.take(5, d * d, what)?;
    matmul_into(&ht, &dv2d, gwv, d, m, d);
    // dh = dq2d·wqᵀ + dk2d·wkᵀ + dv2d·wvᵀ — each product computed into a
    // fresh-zeroed buffer then added, mirroring the allocating path's
    // `matmul` + axpy order so the floats round identically
    let mut wt = scratch.take_dirty(d * d);
    let mut dh_ = scratch.take(m * d);
    transpose_into(wq, &mut wt, d, d);
    matmul_acc(&dq2d, &wt, &mut dh_, m, d, d);
    let mut tmp = scratch.take(m * d);
    transpose_into(wk, &mut wt, d, d);
    matmul_acc(&dk2d, &wt, &mut tmp, m, d, d);
    for (a, &b) in dh_.iter_mut().zip(&tmp) {
        *a += b;
    }
    tmp.fill(0.0);
    transpose_into(wv, &mut wt, d, d);
    matmul_acc(&dv2d, &wt, &mut tmp, m, d, d);
    for (a, &b) in dh_.iter_mut().zip(&tmp) {
        *a += b;
    }
    let mut dx_ln = scratch.take_dirty(m * d);
    let gg = outs.take(1, d, what)?;
    let gbeta = outs.take(2, d, what)?;
    layernorm_bwd_into(&dh_, xhat, rstd, g, &mut dx_ln, gg, gbeta, m, d);
    let dx = outs.take(0, m * d, what)?;
    for ((o, &a), &b) in dx.iter_mut().zip(dy).zip(&dx_ln) {
        *o = a + b;
    }
    scratch.give(dx_ln);
    scratch.give(tmp);
    scratch.give(dh_);
    scratch.give(wt);
    scratch.give(ht);
    scratch.give(h);
    scratch.give(dv2d);
    scratch.give(dk2d);
    scratch.give(dq2d);
    scratch.give(dv);
    scratch.give(dk);
    scratch.give(dq);
    scratch.give(dc);
    scratch.give(dcm);
    scratch.give(wot);
    scratch.give(cft);
    scratch.give(cf);
    Ok(())
}

// ---------------------------------------------------------------------------
// Loss
// ---------------------------------------------------------------------------

fn loss_fwd(s: &Loss, args: &[&[f32]], outs: &mut Outs<'_, '_>) -> Result<()> {
    let what = "loss/fwd_into";
    arity(args, 2, what)?;
    let n = s.n();
    let target = arg(args, 0, n, what)?;
    let x = arg(args, 1, n, what)?;
    let sum: f32 = x.iter().zip(target).map(|(&a, &b)| (a - b) * (a - b)).sum();
    let out = outs.take(0, 1, what)?;
    out[0] = sum / n as f32;
    Ok(())
}

fn loss_bwd(s: &Loss, args: &[&[f32]], outs: &mut Outs<'_, '_>) -> Result<()> {
    let what = "loss/bwd_into";
    // (target, x, loss, δ): the target is data, not a parameter
    arity(args, 4, what)?;
    let n = s.n();
    let target = arg(args, 0, n, what)?;
    let x = arg(args, 1, n, what)?;
    let dy = arg(args, 3, 1, what)?[0];
    let dx = outs.take(0, n, what)?;
    for ((o, &a), &b) in dx.iter_mut().zip(x).zip(target) {
        *o = dy * 2.0 * (a - b) / n as f32;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::presets;
    use crate::backend::{Backend, NativeBackend, StageExecutable, Tensor};
    use crate::backend::NativeTensor;
    use crate::util::Rng;

    /// Run one entry both ways on random inputs and demand bit-equality.
    fn check_entry(stage: &NativeStage, entry: Entry, args: &[&NativeTensor]) {
        let want = stage.entry(entry, args).expect("allocating entry");
        let flat: Vec<&[f32]> = args.iter().map(|t| t.data()).collect();
        let mut store: Vec<Option<Vec<f32>>> =
            want.iter().map(|t| Some(vec![7.5f32; t.element_count()])).collect();
        let mut slices: Vec<Option<&mut [f32]>> =
            store.iter_mut().map(|o| o.as_mut().map(|v| v.as_mut_slice())).collect();
        let mut outs = Outs::new(&mut slices);
        let mut scratch = Scratch::new();
        entry_into(stage, entry, &flat, &mut outs, &mut scratch).expect("in-place entry");
        for (i, (w, got)) in want.iter().zip(&store).enumerate() {
            let got = got.as_ref().expect("untouched storage");
            for (j, (a, b)) in w.data().iter().zip(got.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "out {i}[{j}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn inplace_entries_are_bit_identical_for_every_preset_stage() {
        // quickstart covers dense(gelu)/attn/mlp/dense(none)/loss; the
        // probe adds layernorm — all five kinds, all three entries
        let mut manifests = vec![presets::preset("quickstart").unwrap()];
        manifests.push(presets::layernorm_probe(2, 4, 16).unwrap());
        let be = NativeBackend;
        let mut rng = Rng::new(42);
        for manifest in &manifests {
            for (sig, spec) in &manifest.signatures {
                let stage = be.compile(manifest, sig).unwrap();
                // θ… then a_in, random but shared between both paths
                let mut owned: Vec<NativeTensor> = spec
                    .params
                    .iter()
                    .map(|p| {
                        NativeTensor::from_vec(&rng.normal_vec(p.nelem()), &p.shape).unwrap()
                    })
                    .collect();
                let nin = spec.in_shape.iter().product::<usize>().max(1);
                owned.push(
                    NativeTensor::from_vec(&rng.normal_vec(nin), &spec.in_shape).unwrap(),
                );
                let fwd_args: Vec<&NativeTensor> = owned.iter().collect();
                check_entry(&stage, Entry::Fwd, &fwd_args);
                check_entry(&stage, Entry::FwdAll, &fwd_args);
                // bwd: (θ…, a_in, ā…, δ_out) with ā from the real fwd_all
                let abar = stage.fwd_all(&fwd_args).unwrap();
                let nout = spec.out_shape.iter().product::<usize>().max(1);
                let delta = if spec.out_shape.is_empty() {
                    NativeTensor::scalar(1.0)
                } else {
                    NativeTensor::from_vec(&rng.normal_vec(nout), &spec.out_shape).unwrap()
                };
                let mut bwd_args: Vec<&NativeTensor> = owned.iter().collect();
                bwd_args.extend(abar.iter());
                bwd_args.push(&delta);
                check_entry(&stage, Entry::Bwd, &bwd_args);
            }
        }
    }

    #[test]
    fn scratch_reaches_steady_state() {
        // after one warm pass the take/give cycle reuses every buffer
        let mut s = Scratch::new();
        let a = s.take(64);
        let b = s.take(128);
        s.give(b);
        s.give(a);
        let a2 = s.take(64);
        assert_eq!(a2.len(), 64);
        assert!(a2.iter().all(|&v| v == 0.0), "reused buffers are re-zeroed");
        s.give(a2);
    }
}
