//! Hand-written f32 CPU kernels backing the native backend's stages.
//!
//! These are the numeric twins of `python/compile/kernels/ref.py`: the
//! same tanh-approximation GELU, the same ε = 1e-5 layernorm returning
//! `(x̂, rstd)`, the same numerically-stable softmax — so a manifest
//! executes to the same values on either backend (up to f32 accumulation
//! order). Everything operates on flat row-major slices with explicit
//! dimensions; shapes are the caller's contract.
//!
//! The matmul is cache-blocked over the inner (k) dimension: a 64-row
//! panel of `B` stays hot in L2 while rows of `A`/`C` stream through it.
//! Above [`MM_PAR_MIN_FLOPS`] the row dimension is additionally split
//! across `std::thread::scope` workers (each row's accumulation order is
//! unchanged, so serial and parallel results are bit-identical); small
//! stages stay serial — spawn overhead would swamp them. [`matmul_acc`]
//! is shared by the dense/attention stage kernels *and* the
//! synthetic-data teacher in [`crate::train`].
//!
//! Every kernel has an `*_into` variant writing caller-provided buffers;
//! the allocating versions are thin wrappers over them, so the in-place
//! (lowered-executor) path and the legacy path compute through the same
//! loops and produce bit-identical floats.

/// Panel height of the blocked matmul (rows of `B` kept hot per pass).
pub const MM_BLOCK: usize = 64;

/// Flop threshold (2·m·k·n) above which [`matmul_acc`] fans rows out
/// across threads. Chosen so the quickstart-sized stages (≲1 MFLOP) stay
/// serial — and therefore allocation-free — while default/wide matmuls
/// (tens to hundreds of MFLOPs) parallelize.
pub const MM_PAR_MIN_FLOPS: usize = 1 << 23;

/// `C = A·B` with `A: (m, k)`, `B: (k, n)`, both row-major.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_acc(a, b, &mut out, m, k, n);
    out
}

/// `C = A·B` into a caller-provided buffer (zeroed first — same starting
/// point as [`matmul`]'s fresh vector, so the results are bit-identical).
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    out.fill(0.0);
    matmul_acc(a, b, out, m, k, n);
}

/// Worker count for one matmul of `flops = 2·m·k·n` over `m` rows.
fn matmul_threads(flops: usize, m: usize) -> usize {
    if flops < MM_PAR_MIN_FLOPS || m < 2 {
        return 1;
    }
    use std::sync::OnceLock;
    static CORES: OnceLock<usize> = OnceLock::new();
    let cores =
        *CORES.get_or_init(|| std::thread::available_parallelism().map_or(1, |n| n.get()));
    cores.min(m)
}

/// `C += A·B` — the cache-blocked inner loop, row-parallel for large
/// shapes. Panels of `MM_BLOCK` rows of `B` are reused across every row
/// of `A`; the innermost loop is a unit-stride axpy over a row of `C`,
/// which the compiler vectorizes. Each output row accumulates in the
/// same order regardless of the thread count.
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul: A is not (m, k)");
    assert_eq!(b.len(), k * n, "matmul: B is not (k, n)");
    assert_eq!(out.len(), m * n, "matmul: C is not (m, n)");
    let threads = matmul_threads(2usize.saturating_mul(m * k).saturating_mul(n), m);
    if threads <= 1 || k == 0 || n == 0 {
        matmul_acc_rows(a, b, out, k, n);
        return;
    }
    let chunk = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (ac, oc) in a.chunks(chunk * k).zip(out.chunks_mut(chunk * n)) {
            s.spawn(move || matmul_acc_rows(ac, b, oc, k, n));
        }
    });
}

/// The serial kernel over a contiguous row block (`a: (rows, k)`,
/// `out: (rows, n)` with `rows = a.len() / k`).
fn matmul_acc_rows(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    let rows = if k > 0 { a.len() / k } else { 0 };
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + MM_BLOCK).min(k);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                let brow = &b[kk * n..kk * n + n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        }
        k0 = k1;
    }
}

/// Row-major transpose: `x: (rows, cols)` → `(cols, rows)`.
///
/// The gradient matmuls (`Aᵀ·B`, `A·Bᵀ`) are expressed as an explicit
/// transpose followed by [`matmul`], so every contraction goes through
/// the one blocked kernel.
pub fn transpose(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    transpose_into(x, &mut out, rows, cols);
    out
}

/// [`transpose`] into a caller-provided buffer (fully overwritten).
pub fn transpose_into(x: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols, "transpose: bad shape");
    assert_eq!(out.len(), rows * cols, "transpose: bad out shape");
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = x[r * cols + c];
        }
    }
}

/// Add a broadcast row bias in place: `x: (m, n) += bias: (n,)`.
pub fn add_bias(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    assert_eq!(bias.len(), n);
    for r in 0..m {
        for (v, &b) in x[r * n..(r + 1) * n].iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums: `x: (m, n)` → `(n,)` (bias gradients).
pub fn col_sum(x: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    col_sum_into(x, &mut out, m, n);
    out
}

/// [`col_sum`] into a caller-provided buffer (zeroed, then accumulated).
pub fn col_sum_into(x: &[f32], out: &mut [f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    assert_eq!(out.len(), n);
    out.fill(0.0);
    for r in 0..m {
        for (o, &v) in out.iter_mut().zip(&x[r * n..(r + 1) * n]) {
            *o += v;
        }
    }
}

const SQRT_2_OVER_PI: f32 = 0.797_884_56;
const GELU_C: f32 = 0.044_715;

/// tanh-approximation GELU (identical to the Pallas/jnp reference).
pub fn gelu(z: f32) -> f32 {
    0.5 * z * (1.0 + (SQRT_2_OVER_PI * (z + GELU_C * z * z * z)).tanh())
}

/// d gelu / dz for the tanh approximation.
pub fn gelu_grad(z: f32) -> f32 {
    let inner = SQRT_2_OVER_PI * (z + GELU_C * z * z * z);
    let t = inner.tanh();
    let dinner = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * dinner
}

/// Layernorm ε (matches `layernorm_ref`).
pub const LN_EPS: f32 = 1e-5;

/// Row-wise layernorm over the last axis of `x: (m, d)`.
///
/// Returns `(x̂, rstd)` — the normalized rows and reciprocal stddev,
/// exactly the tensors the backward pass consumes (and what `fwd_all`
/// checkpoints).
pub fn layernorm(x: &[f32], m: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut xhat = vec![0.0f32; m * d];
    let mut rstd = vec![0.0f32; m];
    layernorm_into(x, &mut xhat, &mut rstd, m, d);
    (xhat, rstd)
}

/// [`layernorm`] into caller-provided `x̂`/`rstd` buffers (overwritten).
pub fn layernorm_into(x: &[f32], xhat: &mut [f32], rstd: &mut [f32], m: usize, d: usize) {
    assert_eq!(x.len(), m * d);
    assert_eq!(xhat.len(), m * d);
    assert_eq!(rstd.len(), m);
    for r in 0..m {
        let row = &x[r * d..(r + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        for (o, &v) in xhat[r * d..(r + 1) * d].iter_mut().zip(row) {
            *o = (v - mu) * rs;
        }
    }
}

/// Backward of `h = x̂·g + β` given `dh: (m, d)`.
///
/// Returns `(dx, dg, dβ)` with the same formulas as the hand-derived
/// `_ln_bwd` in `python/compile/stages.py`.
pub fn layernorm_bwd(
    dh: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    m: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; m * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    layernorm_bwd_into(dh, xhat, rstd, g, &mut dx, &mut dg, &mut db, m, d);
    (dx, dg, db)
}

/// [`layernorm_bwd`] into caller-provided buffers (`dx` overwritten,
/// `dg`/`db` zeroed then accumulated across rows).
#[allow(clippy::too_many_arguments)]
pub fn layernorm_bwd_into(
    dh: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
    m: usize,
    d: usize,
) {
    assert_eq!(dh.len(), m * d);
    assert_eq!(xhat.len(), m * d);
    assert_eq!(rstd.len(), m);
    assert_eq!(g.len(), d);
    assert_eq!((dx.len(), dg.len(), db.len()), (m * d, d, d));
    dg.fill(0.0);
    db.fill(0.0);
    for r in 0..m {
        let dhr = &dh[r * d..(r + 1) * d];
        let xr = &xhat[r * d..(r + 1) * d];
        let mut mean1 = 0.0f32;
        let mut mean2 = 0.0f32;
        for j in 0..d {
            let dxhat = dhr[j] * g[j];
            dg[j] += dhr[j] * xr[j];
            db[j] += dhr[j];
            mean1 += dxhat;
            mean2 += dxhat * xr[j];
        }
        mean1 /= d as f32;
        mean2 /= d as f32;
        let rs = rstd[r];
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let dxhat = dhr[j] * g[j];
            dxr[j] = rs * (dxhat - mean1 - xr[j] * mean2);
        }
    }
}

/// In-place numerically-stable softmax over each row of `s: (rows, cols)`.
pub fn softmax_rows(s: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(s.len(), rows * cols);
    for r in 0..rows {
        let row = &mut s[r * cols..(r + 1) * cols];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax backward over rows: given probs `p` and upstream `dp`, returns
/// `ds = p ⊙ (dp − Σ_col(dp ⊙ p))` (per row).
pub fn softmax_rows_bwd(p: &[f32], dp: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut ds = vec![0.0f32; rows * cols];
    softmax_rows_bwd_into(p, dp, &mut ds, rows, cols);
    ds
}

/// [`softmax_rows_bwd`] into a caller-provided buffer (overwritten).
pub fn softmax_rows_bwd_into(p: &[f32], dp: &[f32], ds: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(p.len(), rows * cols);
    assert_eq!(dp.len(), rows * cols);
    assert_eq!(ds.len(), rows * cols);
    for r in 0..rows {
        let pr = &p[r * cols..(r + 1) * cols];
        let dpr = &dp[r * cols..(r + 1) * cols];
        let dot: f32 = pr.iter().zip(dpr).map(|(&a, &b)| a * b).sum();
        let dsr = &mut ds[r * cols..(r + 1) * cols];
        for j in 0..cols {
            dsr[j] = pr[j] * (dpr[j] - dot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = crate::util::Rng::new(5);
        // sizes straddling the block boundary
        for (m, k, n) in [(3, 7, 5), (1, 64, 1), (9, 65, 33), (2, 130, 70)] {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let got = matmul(&a, &b, m, k, n);
            let want = naive_matmul(&a, &b, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn parallel_matmul_is_bit_identical_to_serial() {
        // above MM_PAR_MIN_FLOPS the row-parallel path engages; each row
        // accumulates in the same order, so the floats must match bit
        // for bit (the lowered-vs-legacy parity tests depend on this)
        let (m, k, n) = (128usize, 192, 192);
        assert!(2 * m * k * n >= MM_PAR_MIN_FLOPS, "shape must cross the threshold");
        let mut rng = crate::util::Rng::new(11);
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let mut serial = vec![0.0f32; m * n];
        matmul_acc_rows(&a, &b, &mut serial, k, n);
        let par = matmul(&a, &b, m, k, n);
        for (i, (s, p)) in serial.iter().zip(&par).enumerate() {
            assert_eq!(s.to_bits(), p.to_bits(), "elem {i}: {s} vs {p}");
        }
    }

    #[test]
    fn small_matmuls_stay_serial() {
        assert_eq!(matmul_threads(1 << 20, 64), 1);
        assert_eq!(matmul_threads(1 << 30, 1), 1); // one row cannot split
        assert!(matmul_threads(1 << 30, 4096) >= 1);
    }

    #[test]
    fn into_variants_match_allocating_kernels() {
        let mut rng = crate::util::Rng::new(3);
        let (m, d) = (6, 32);
        let x = rng.normal_vec(m * d);
        let g = rng.normal_vec(d);
        let dh = rng.normal_vec(m * d);
        let (xhat, rstd) = layernorm(&x, m, d);
        let mut xhat2 = vec![9.0f32; m * d]; // dirty buffers, like a pooled slot
        let mut rstd2 = vec![9.0f32; m];
        layernorm_into(&x, &mut xhat2, &mut rstd2, m, d);
        assert_eq!(xhat, xhat2);
        assert_eq!(rstd, rstd2);
        let (dx, dg, db) = layernorm_bwd(&dh, &xhat, &rstd, &g, m, d);
        let (mut dx2, mut dg2, mut db2) = (vec![9.0; m * d], vec![9.0; d], vec![9.0; d]);
        layernorm_bwd_into(&dh, &xhat, &rstd, &g, &mut dx2, &mut dg2, &mut db2, m, d);
        assert_eq!((dx, dg, db), (dx2, dg2, db2));
        let mut t = vec![9.0f32; m * d];
        transpose_into(&x, &mut t, m, d);
        assert_eq!(t, transpose(&x, m, d));
        let mut cs = vec![9.0f32; d];
        col_sum_into(&x, &mut cs, m, d);
        assert_eq!(cs, col_sum(&x, m, d));
    }

    #[test]
    fn transpose_round_trips() {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let t = transpose(&x, 3, 4);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // x[1][0]
        assert_eq!(transpose(&t, 4, 3), x);
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        for z in [-3.0f32, -0.7, 0.0, 0.4, 2.5] {
            let eps = 1e-3;
            let fd = (gelu(z + eps) - gelu(z - eps)) / (2.0 * eps);
            let g = gelu_grad(z);
            assert!((fd - g).abs() < 1e-3, "z={z}: fd {fd} vs {g}");
        }
    }

    #[test]
    fn layernorm_rows_are_standardized() {
        let mut rng = crate::util::Rng::new(9);
        let (m, d) = (4, 32);
        let x = rng.normal_vec(m * d);
        let (xhat, rstd) = layernorm(&x, m, d);
        for r in 0..m {
            let row = &xhat[r * d..(r + 1) * d];
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
            assert!(rstd[r] > 0.0);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut s = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut s, 2, 3);
        for r in 0..2 {
            let sum: f32 = s[r * 3..(r + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(s[r * 3..(r + 1) * 3].iter().all(|&v| v > 0.0));
        }
        // monotone in the logits
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn col_sum_and_bias() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0], 2, 2);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(col_sum(&x, 2, 2), vec![24.0, 46.0]);
    }
}
