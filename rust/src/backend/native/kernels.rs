//! Hand-written f32 CPU kernels backing the native backend's stages.
//!
//! These are the numeric twins of `python/compile/kernels/ref.py`: the
//! same tanh-approximation GELU, the same ε = 1e-5 layernorm returning
//! `(x̂, rstd)`, the same numerically-stable softmax — so a manifest
//! executes to the same values on either backend (up to f32 accumulation
//! order). Everything operates on flat row-major slices with explicit
//! dimensions; shapes are the caller's contract.
//!
//! The matmul is cache-blocked over the inner (k) dimension: a 64-row
//! panel of `B` stays hot in L2 while rows of `A`/`C` stream through it.
//! [`matmul_acc`] is shared by the dense/attention stage kernels *and*
//! the synthetic-data teacher in [`crate::train`].

/// Panel height of the blocked matmul (rows of `B` kept hot per pass).
pub const MM_BLOCK: usize = 64;

/// `C = A·B` with `A: (m, k)`, `B: (k, n)`, both row-major.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_acc(a, b, &mut out, m, k, n);
    out
}

/// `C += A·B` — the cache-blocked inner loop. Panels of `MM_BLOCK` rows
/// of `B` are reused across every row of `A`; the innermost loop is a
/// unit-stride axpy over a row of `C`, which the compiler vectorizes.
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul: A is not (m, k)");
    assert_eq!(b.len(), k * n, "matmul: B is not (k, n)");
    assert_eq!(out.len(), m * n, "matmul: C is not (m, n)");
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + MM_BLOCK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = arow[kk];
                let brow = &b[kk * n..kk * n + n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        }
        k0 = k1;
    }
}

/// Row-major transpose: `x: (rows, cols)` → `(cols, rows)`.
///
/// The gradient matmuls (`Aᵀ·B`, `A·Bᵀ`) are expressed as an explicit
/// transpose followed by [`matmul`], so every contraction goes through
/// the one blocked kernel.
pub fn transpose(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(x.len(), rows * cols, "transpose: bad shape");
    let mut out = vec![0.0f32; x.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = x[r * cols + c];
        }
    }
    out
}

/// Add a broadcast row bias in place: `x: (m, n) += bias: (n,)`.
pub fn add_bias(x: &mut [f32], bias: &[f32], m: usize, n: usize) {
    assert_eq!(x.len(), m * n);
    assert_eq!(bias.len(), n);
    for r in 0..m {
        for (v, &b) in x[r * n..(r + 1) * n].iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums: `x: (m, n)` → `(n,)` (bias gradients).
pub fn col_sum(x: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * n);
    let mut out = vec![0.0f32; n];
    for r in 0..m {
        for (o, &v) in out.iter_mut().zip(&x[r * n..(r + 1) * n]) {
            *o += v;
        }
    }
    out
}

const SQRT_2_OVER_PI: f32 = 0.797_884_56;
const GELU_C: f32 = 0.044_715;

/// tanh-approximation GELU (identical to the Pallas/jnp reference).
pub fn gelu(z: f32) -> f32 {
    0.5 * z * (1.0 + (SQRT_2_OVER_PI * (z + GELU_C * z * z * z)).tanh())
}

/// d gelu / dz for the tanh approximation.
pub fn gelu_grad(z: f32) -> f32 {
    let inner = SQRT_2_OVER_PI * (z + GELU_C * z * z * z);
    let t = inner.tanh();
    let dinner = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * dinner
}

/// Layernorm ε (matches `layernorm_ref`).
pub const LN_EPS: f32 = 1e-5;

/// Row-wise layernorm over the last axis of `x: (m, d)`.
///
/// Returns `(x̂, rstd)` — the normalized rows and reciprocal stddev,
/// exactly the tensors the backward pass consumes (and what `fwd_all`
/// checkpoints).
pub fn layernorm(x: &[f32], m: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), m * d);
    let mut xhat = vec![0.0f32; m * d];
    let mut rstd = vec![0.0f32; m];
    for r in 0..m {
        let row = &x[r * d..(r + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        for (o, &v) in xhat[r * d..(r + 1) * d].iter_mut().zip(row) {
            *o = (v - mu) * rs;
        }
    }
    (xhat, rstd)
}

/// Backward of `h = x̂·g + β` given `dh: (m, d)`.
///
/// Returns `(dx, dg, dβ)` with the same formulas as the hand-derived
/// `_ln_bwd` in `python/compile/stages.py`.
pub fn layernorm_bwd(
    dh: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    m: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(dh.len(), m * d);
    assert_eq!(xhat.len(), m * d);
    assert_eq!(rstd.len(), m);
    assert_eq!(g.len(), d);
    let mut dx = vec![0.0f32; m * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    for r in 0..m {
        let dhr = &dh[r * d..(r + 1) * d];
        let xr = &xhat[r * d..(r + 1) * d];
        let mut mean1 = 0.0f32;
        let mut mean2 = 0.0f32;
        for j in 0..d {
            let dxhat = dhr[j] * g[j];
            dg[j] += dhr[j] * xr[j];
            db[j] += dhr[j];
            mean1 += dxhat;
            mean2 += dxhat * xr[j];
        }
        mean1 /= d as f32;
        mean2 /= d as f32;
        let rs = rstd[r];
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let dxhat = dhr[j] * g[j];
            dxr[j] = rs * (dxhat - mean1 - xr[j] * mean2);
        }
    }
    (dx, dg, db)
}

/// In-place numerically-stable softmax over each row of `s: (rows, cols)`.
pub fn softmax_rows(s: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(s.len(), rows * cols);
    for r in 0..rows {
        let row = &mut s[r * cols..(r + 1) * cols];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax backward over rows: given probs `p` and upstream `dp`, returns
/// `ds = p ⊙ (dp − Σ_col(dp ⊙ p))` (per row).
pub fn softmax_rows_bwd(p: &[f32], dp: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(p.len(), rows * cols);
    assert_eq!(dp.len(), rows * cols);
    let mut ds = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let pr = &p[r * cols..(r + 1) * cols];
        let dpr = &dp[r * cols..(r + 1) * cols];
        let dot: f32 = pr.iter().zip(dpr).map(|(&a, &b)| a * b).sum();
        let dsr = &mut ds[r * cols..(r + 1) * cols];
        for j in 0..cols {
            dsr[j] = pr[j] * (dpr[j] - dot);
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = crate::util::Rng::new(5);
        // sizes straddling the block boundary
        for (m, k, n) in [(3, 7, 5), (1, 64, 1), (9, 65, 33), (2, 130, 70)] {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let got = matmul(&a, &b, m, k, n);
            let want = naive_matmul(&a, &b, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn transpose_round_trips() {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let t = transpose(&x, 3, 4);
        assert_eq!(t[0], 0.0);
        assert_eq!(t[1], 4.0); // x[1][0]
        assert_eq!(transpose(&t, 4, 3), x);
    }

    #[test]
    fn gelu_grad_matches_finite_differences() {
        for z in [-3.0f32, -0.7, 0.0, 0.4, 2.5] {
            let eps = 1e-3;
            let fd = (gelu(z + eps) - gelu(z - eps)) / (2.0 * eps);
            let g = gelu_grad(z);
            assert!((fd - g).abs() < 1e-3, "z={z}: fd {fd} vs {g}");
        }
    }

    #[test]
    fn layernorm_rows_are_standardized() {
        let mut rng = crate::util::Rng::new(9);
        let (m, d) = (4, 32);
        let x = rng.normal_vec(m * d);
        let (xhat, rstd) = layernorm(&x, m, d);
        for r in 0..m {
            let row = &xhat[r * d..(r + 1) * d];
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            assert!(mu.abs() < 1e-5, "row {r} mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
            assert!(rstd[r] > 0.0);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut s = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut s, 2, 3);
        for r in 0..2 {
            let sum: f32 = s[r * 3..(r + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
            assert!(s[r * 3..(r + 1) * 3].iter().all(|&v| v > 0.0));
        }
        // monotone in the logits
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn col_sum_and_bias() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0], 2, 2);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(col_sum(&x, 2, 2), vec![24.0, 46.0]);
    }
}
