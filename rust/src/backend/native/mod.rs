//! Pure-Rust tensor engine: executes manifest chains with hand-written
//! f32 forward/backward kernels — no PJRT, no Python, no AOT artifacts.
//!
//! The engine's unit of compilation is a manifest signature:
//! [`Backend::compile`] resolves a [`SignatureSpec`] of kind `dense` /
//! `layernorm` / `mlp` / `attn` / `loss` into a [`NativeStage`] with all shape
//! parameters baked in; execution is then pure slice arithmetic over
//! [`NativeTensor`]s (flat row-major `Vec<f32>` + shape). Numerics mirror
//! `python/compile/kernels/ref.py` (same GELU, layernorm, softmax), so
//! PJRT artifacts and the native engine are drop-in replacements for one
//! another per manifest.
//!
//! Manifests don't have to come from Python: [`presets`] generates the
//! same transformer chains as `python/compile/model.py` entirely
//! in-process, which is what makes the `train` / `estimate` / `compare`
//! subcommands and the integration tests runnable on a bare container.
//!
//! [`SignatureSpec`]: crate::chain::manifest::SignatureSpec

mod inplace;
pub mod kernels;
pub mod presets;
mod stages;

pub use stages::NativeStage;

use anyhow::{ensure, Context, Result};

use super::{Backend, Tensor};
use crate::chain::manifest::Manifest;

/// A host tensor: flat row-major f32 data plus its shape.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeTensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl NativeTensor {
    pub(crate) fn from_parts(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>().max(1));
        crate::telemetry::registry().native_tensor_allocs.inc();
        NativeTensor { data, shape }
    }

    /// Flat element data (row-major).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Dimensions (empty = rank-0 scalar).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
}

impl Tensor for NativeTensor {
    fn from_vec(data: &[f32], shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product::<usize>().max(1);
        ensure!(
            data.len() == n,
            "shape {:?} needs {} elems, got {}",
            shape,
            n,
            data.len()
        );
        Ok(NativeTensor::from_parts(data.to_vec(), shape.to_vec()))
    }

    fn scalar(x: f32) -> Self {
        NativeTensor::from_parts(vec![x], Vec::new())
    }

    fn to_vec(&self) -> Result<Vec<f32>> {
        Ok(self.data.clone())
    }

    fn read_into(&self, out: &mut [f32]) -> Result<()> {
        // host-resident storage: a straight copy, no allocation (the
        // lowered executor stages batch inputs through this every
        // iteration)
        ensure!(
            self.data.len() == out.len(),
            "read_into: tensor has {} elements, buffer {}",
            self.data.len(),
            out.len()
        );
        out.copy_from_slice(&self.data);
        Ok(())
    }

    fn element_count(&self) -> usize {
        self.data.len()
    }
}

/// The native engine handle (stateless: all state lives in the compiled
/// stages and the caller's tensors).
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    type Tensor = NativeTensor;
    type Stage = NativeStage;

    /// The native stages implement the in-place entry points
    /// (`inplace.rs`), so the lowered zero-allocation executor runs here.
    const SUPPORTS_LOWERED: bool = true;

    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(&self, manifest: &Manifest, sig: &str) -> Result<NativeStage> {
        let spec = manifest
            .signatures
            .get(sig)
            .with_context(|| format!("native compile: unknown signature '{sig}'"))?;
        NativeStage::from_spec(sig, spec)
    }
}
