//! In-process manifest generation for the native backend.
//!
//! Mirrors `python/compile/model.py` exactly — same presets, same stage
//! order (`dense(gelu) → [attn, mlp]×blocks → dense(none) → loss`), same
//! signature naming, same `ā`-extras layout and byte/FLOP accounting — so
//! a native preset chain and a Python-compiled artifact chain of the same
//! geometry produce identical [`Manifest`]s up to the `files` table
//! (empty here: the native backend compiles from the spec, not from HLO).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::chain::manifest::{Manifest, ParamSpec, SignatureSpec, StageRef, TensorSpec};

const BYTES: u64 = 4; // f32

fn nelem(shape: &[usize]) -> u64 {
    shape.iter().product::<usize>().max(1) as u64
}

fn param(name: &str, shape: &[usize], init: &str) -> ParamSpec {
    ParamSpec { name: name.to_string(), shape: shape.to_vec(), init: init.to_string() }
}

fn extra(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec() }
}

/// Assemble a [`SignatureSpec`] from its parts, deriving the byte and
/// gradient counts the way `python/compile/aot.py` does.
fn sig_spec(
    kind: &str,
    activation: Option<&str>,
    params: Vec<ParamSpec>,
    in_shape: Vec<usize>,
    out_shape: Vec<usize>,
    abar_extras: Vec<TensorSpec>,
    flops_fwd: u64,
) -> SignatureSpec {
    let w_a = BYTES * nelem(&out_shape);
    let w_abar = w_a + abar_extras.iter().map(|e| BYTES * nelem(&e.shape)).sum::<u64>();
    let n_grads = params.iter().filter(|p| !p.is_data()).count();
    SignatureSpec {
        kind: kind.to_string(),
        files: HashMap::new(),
        activation: activation.map(String::from),
        params,
        in_shape,
        out_shape,
        abar_extras,
        w_a,
        w_abar,
        flops_fwd,
        flops_bwd: 2 * flops_fwd,
        n_grads,
    }
}

fn dense_sig(b: usize, t: usize, d_in: usize, d_out: usize, act: &str) -> (String, SignatureSpec) {
    let m = b * t;
    let extras = if act == "none" {
        Vec::new()
    } else {
        vec![extra("z", &[m, d_out])]
    };
    let spec = sig_spec(
        "dense",
        Some(act),
        vec![param("w", &[d_in, d_out], "xavier"), param("b", &[d_out], "zeros")],
        vec![b, t, d_in],
        vec![b, t, d_out],
        extras,
        (2 * m * d_in * d_out) as u64,
    );
    (format!("dense_b{b}t{t}_{d_in}x{d_out}_{act}"), spec)
}

fn layernorm_sig(b: usize, t: usize, d: usize) -> (String, SignatureSpec) {
    let m = b * t;
    let spec = sig_spec(
        "layernorm",
        None,
        vec![param("g", &[d], "ones"), param("beta", &[d], "zeros")],
        vec![b, t, d],
        vec![b, t, d],
        vec![extra("xhat", &[m, d]), extra("rstd", &[m])],
        (8 * m * d) as u64,
    );
    (format!("layernorm_b{b}t{t}_{d}"), spec)
}

fn mlp_sig(b: usize, t: usize, d: usize, f: usize) -> (String, SignatureSpec) {
    let m = b * t;
    let spec = sig_spec(
        "mlp",
        None,
        vec![
            param("g", &[d], "ones"),
            param("beta", &[d], "zeros"),
            param("w1", &[d, f], "xavier"),
            param("c1", &[f], "zeros"),
            param("w2", &[f, d], "xavier"),
            param("c2", &[d], "zeros"),
        ],
        vec![b, t, d],
        vec![b, t, d],
        vec![
            extra("xhat", &[m, d]),
            extra("rstd", &[m]),
            extra("z1", &[m, f]),
            extra("u", &[m, f]),
        ],
        (4 * m * d * f) as u64,
    );
    (format!("mlp_b{b}t{t}_{d}x{f}"), spec)
}

fn attn_sig(b: usize, t: usize, d: usize, heads: usize) -> (String, SignatureSpec) {
    let m = b * t;
    let (bh, dh) = (b * heads, d / heads);
    let proj = (4 * 2 * m * d * d) as u64;
    let scores = (2 * 2 * bh * t * t * dh) as u64;
    let spec = sig_spec(
        "attn",
        None,
        vec![
            param("g", &[d], "ones"),
            param("beta", &[d], "zeros"),
            param("wq", &[d, d], "xavier"),
            param("wk", &[d, d], "xavier"),
            param("wv", &[d, d], "xavier"),
            param("wo", &[d, d], "xavier"),
        ],
        vec![b, t, d],
        vec![b, t, d],
        vec![
            extra("xhat", &[m, d]),
            extra("rstd", &[m]),
            extra("q", &[bh, t, dh]),
            extra("k", &[bh, t, dh]),
            extra("v", &[bh, t, dh]),
            extra("p", &[bh, t, t]), // the big one: O(T²) attention probs
            extra("c", &[bh, t, dh]),
        ],
        proj + scores,
    );
    (format!("attn_b{b}t{t}_{d}h{heads}"), spec)
}

fn loss_sig(b: usize, t: usize, d: usize) -> (String, SignatureSpec) {
    let spec = sig_spec(
        "loss",
        None,
        vec![param("target", &[b, t, d], "data")],
        vec![b, t, d],
        Vec::new(),
        Vec::new(),
        (3 * b * t * d) as u64,
    );
    (format!("loss_b{b}t{t}_{d}"), spec)
}

/// Assemble a manifest from `(sig_name, spec)` pairs in stage order.
/// Repeated signatures (the transformer trunk) are deduplicated, exactly
/// like aot.py's signature table.
fn assemble(preset: &str, stage_sigs: Vec<(String, SignatureSpec)>) -> Result<Manifest> {
    let mut signatures: HashMap<String, SignatureSpec> = HashMap::new();
    let mut stages = Vec::with_capacity(stage_sigs.len());
    for (i, (sig, spec)) in stage_sigs.into_iter().enumerate() {
        stages.push(StageRef {
            name: format!("stage_{i}_{}", spec.kind),
            kind: spec.kind.clone(),
            sig: sig.clone(),
        });
        signatures.entry(sig).or_insert(spec);
    }
    let input_shape = signatures[&stages[0].sig].in_shape.clone();
    let param_count: u64 = stages
        .iter()
        .map(|st| {
            signatures[&st.sig]
                .params
                .iter()
                .filter(|p| !p.is_data())
                .map(|p| p.nelem() as u64)
                .sum::<u64>()
        })
        .sum();
    let m = Manifest {
        preset: preset.to_string(),
        dtype: "f32".to_string(),
        input_shape,
        param_count,
        stages,
        signatures,
        content_hash: format!("native:{preset}"),
        dir: PathBuf::new(),
    };
    m.validate()?;
    Ok(m)
}

/// GPT-style transformer chain, the geometry `python/compile/model.py`
/// builds: `dense(gelu) → [attn, mlp]×blocks → dense(none) → loss`.
pub fn transformer(
    preset: &str,
    batch: usize,
    seq: usize,
    d: usize,
    heads: usize,
    ffn: usize,
    blocks: usize,
) -> Result<Manifest> {
    if d % heads != 0 {
        bail!("transformer preset: d = {d} not divisible by {heads} heads");
    }
    let mut sigs = vec![dense_sig(batch, seq, d, d, "gelu")];
    for _ in 0..blocks {
        sigs.push(attn_sig(batch, seq, d, heads));
        sigs.push(mlp_sig(batch, seq, d, ffn));
    }
    sigs.push(dense_sig(batch, seq, d, d, "none")); // output head
    sigs.push(loss_sig(batch, seq, d));
    assemble(preset, sigs)
}

/// A minimal chain exercising the native-only `layernorm` stage kind:
/// `dense(none) → layernorm → loss` (used by the integration tests).
pub fn layernorm_probe(batch: usize, seq: usize, d: usize) -> Result<Manifest> {
    assemble(
        "lnprobe",
        vec![
            dense_sig(batch, seq, d, d, "none"),
            layernorm_sig(batch, seq, d),
            loss_sig(batch, seq, d),
        ],
    )
}

/// A U-Net-style hourglass over the dense/layernorm kernels: two encoder
/// stages halve the width, a layernorm bottleneck, two decoder stages
/// restore it. The *executed* chain is sequential (the native kernels
/// fuse each stage's work); the matching [`crate::graph`] preset overlays
/// the encoder→decoder skip edges for the planning-side model.
pub fn unet(batch: usize, seq: usize, d: usize) -> Result<Manifest> {
    if d % 4 != 0 {
        bail!("unet preset: d = {d} must be divisible by 4");
    }
    assemble(
        "unet",
        vec![
            dense_sig(batch, seq, d, d / 2, "gelu"),  // encoder 1
            dense_sig(batch, seq, d / 2, d / 4, "gelu"), // encoder 2
            layernorm_sig(batch, seq, d / 4),         // bottleneck
            dense_sig(batch, seq, d / 4, d / 2, "gelu"), // decoder 1
            dense_sig(batch, seq, d / 2, d, "none"),  // decoder 2
            loss_sig(batch, seq, d),
        ],
    )
}

/// Every named preset [`preset`] accepts (service discovery, CLI docs).
pub const NAMES: &[&str] = &["quickstart", "default", "wide", "residual", "unet"];

/// Named presets. The first three mirror `python/compile/model.py::PRESETS`;
/// `residual` and `unet` are native-only geometries paired with graph
/// presets ([`crate::graph::preset`]) that add their skip edges.
///
/// * `quickstart` — tiny smoke chain (b2 t16 d64 h4 f128, 1 block).
/// * `default`    — GPT-style trunk, ~3.2M params (b8 t64 d256 h4 f1024, 4 blocks).
/// * `wide`       — GPT-2-base geometry (b4 t128 d768 h12 f3072, 6 blocks).
/// * `residual`   — 2-block transformer sized for end-to-end tests
///   (b2 t16 d64 h4 f128); its graph preset models the residual skips.
/// * `unet`       — dense hourglass d→d/2→d/4→d/2→d (b2 t16 d64); its
///   graph preset models the encoder→decoder skips.
pub fn preset(name: &str) -> Result<Manifest> {
    match name {
        "quickstart" => transformer(name, 2, 16, 64, 4, 128, 1),
        "default" => transformer(name, 8, 64, 256, 4, 1024, 4),
        "wide" => transformer(name, 4, 128, 768, 12, 3072, 6),
        "residual" => transformer(name, 2, 16, 64, 4, 128, 2),
        "unet" => unet(2, 16, 64),
        other => bail!("unknown native preset '{other}' ({})", NAMES.join("/")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate_and_match_python_geometry() {
        let m = preset("quickstart").unwrap();
        // dense + (attn + mlp) + dense + loss
        assert_eq!(m.stages.len(), 5);
        assert_eq!(m.input_shape, vec![2, 16, 64]);
        assert_eq!(m.stages.last().unwrap().kind, "loss");

        let d = preset("default").unwrap();
        assert_eq!(d.stages.len(), 1 + 2 * 4 + 1 + 1);
        // ~3.2M parameters at d=256 (model.py's comment)
        assert!((3_000_000..3_500_000).contains(&d.param_count), "{}", d.param_count);
    }

    #[test]
    fn signatures_are_shared_across_repeated_blocks() {
        let m = preset("default").unwrap();
        // 4 attn stages and 4 mlp stages share one signature each
        assert_eq!(m.signatures.len(), 5); // dense-gelu, attn, mlp, dense-none, loss
    }

    #[test]
    fn abar_accounting_matches_stage_contract() {
        let m = preset("quickstart").unwrap();
        for spec in m.signatures.values() {
            assert!(spec.w_abar >= spec.w_a);
            let extras: u64 = spec.abar_extras.iter().map(|e| 4 * e.nelem() as u64).sum();
            assert_eq!(spec.w_abar, spec.w_a + extras);
        }
        // the attention signature checkpoints the O(T²) probs
        let attn = m.signatures.values().find(|s| s.kind == "attn").unwrap();
        assert!(attn.abar_extras.iter().any(|e| e.name == "p"));
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(preset("nope").is_err());
        for name in NAMES {
            assert!(preset(name).is_ok(), "{name}");
        }
    }

    #[test]
    fn layernorm_probe_builds() {
        let m = layernorm_probe(2, 4, 16).unwrap();
        assert_eq!(m.stages.len(), 3);
        assert_eq!(m.stages[1].kind, "layernorm");
    }

    #[test]
    fn residual_and_unet_presets_build() {
        let r = preset("residual").unwrap();
        assert_eq!(r.stages.len(), 7); // dense + (attn,mlp)×2 + dense + loss
        assert_eq!(r.input_shape, vec![2, 16, 64]);

        let u = preset("unet").unwrap();
        assert_eq!(u.stages.len(), 6);
        assert_eq!(u.stages[2].kind, "layernorm");
        // hourglass: encoder outputs shrink, decoder outputs grow back
        let w_a: Vec<u64> = u.stages.iter().map(|s| u.signatures[&s.sig].w_a).collect();
        assert!(w_a[0] > w_a[1], "encoder halves width");
        assert!(w_a[3] > w_a[2] || w_a[4] > w_a[3], "decoder restores width");
    }
}
