//! Small [`xla::Literal`] helpers: shaped f32 construction / extraction.
//! (PJRT-only plumbing; generic code goes through [`crate::backend::Tensor`].)

use anyhow::{ensure, Context, Result};
use xla::Literal;

/// Build an f32 literal of the given shape from a flat vector
/// (row-major, matching jax's default layout).
pub fn lit_from_vec(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    ensure!(data.len() == n, "shape {:?} needs {} elems, got {}", shape, n, data.len());
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data).reshape(&dims).context("reshaping literal")
}

/// Scalar f32 literal.
pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Zero-filled f32 literal of the given shape.
pub fn lit_zeros(shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    lit_from_vec(&vec![0.0; n], shape)
}

/// Extract a literal's contents as a flat f32 vector.
pub fn lit_to_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("extracting f32 data")
}
