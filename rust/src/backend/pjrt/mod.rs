//! XLA/PJRT backend: executes the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`.
//!
//! This is the original execution path, now isolated behind the
//! [`Backend`] trait — the only module tree that names `xla` types.
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids. See python/compile/aot.py.
//!
//! With the vendored stub `xla` crate (no real PJRT toolchain), client
//! construction succeeds but compilation fails fast with an explanatory
//! error — use [`crate::backend::NativeBackend`] instead on such hosts.

mod literal;

pub use literal::{lit_from_vec, lit_scalar, lit_to_vec, lit_zeros};

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::{Backend, Entry, StageExecutable, Tensor};
use crate::chain::manifest::Manifest;

impl Tensor for Literal {
    fn from_vec(data: &[f32], shape: &[usize]) -> Result<Self> {
        lit_from_vec(data, shape)
    }

    fn scalar(x: f32) -> Self {
        lit_scalar(x)
    }

    fn to_vec(&self) -> Result<Vec<f32>> {
        lit_to_vec(self)
    }

    fn element_count(&self) -> usize {
        Literal::element_count(self)
    }
}

/// The PJRT engine handle: owns the CPU client executables compile on.
pub struct PjrtBackend {
    pub client: PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client })
    }
}

/// One compiled signature: a loaded executable per entry point.
pub struct PjrtStage {
    sig: String,
    fwd: PjRtLoadedExecutable,
    fwd_all: PjRtLoadedExecutable,
    bwd: PjRtLoadedExecutable,
}

/// Execute a loaded executable and decompose its tuple output.
/// (aot.py lowers with `return_tuple=True`: always a tuple root.)
fn run(exe: &PjRtLoadedExecutable, args: &[&Literal], what: &str) -> Result<Vec<Literal>> {
    let outs = exe
        .execute::<&Literal>(args)
        .with_context(|| format!("executing {what}"))?;
    let mut result = outs[0][0]
        .to_literal_sync()
        .with_context(|| format!("fetching result of {what}"))?;
    result.decompose_tuple().context("decomposing result tuple")
}

impl StageExecutable<Literal> for PjrtStage {
    fn fwd(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        run(&self.fwd, args, &format!("{}/fwd", self.sig))
    }

    fn fwd_all(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        run(&self.fwd_all, args, &format!("{}/fwd_all", self.sig))
    }

    fn bwd(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        run(&self.bwd, args, &format!("{}/bwd", self.sig))
    }
}

impl Backend for PjrtBackend {
    type Tensor = Literal;
    type Stage = PjrtStage;

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, manifest: &Manifest, sig: &str) -> Result<PjrtStage> {
        let compile_entry = |entry: Entry| -> Result<PjRtLoadedExecutable> {
            let path = manifest.hlo_path(sig, entry.name())?;
            let proto = HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {sig}/{}", entry.name()))
        };
        Ok(PjrtStage {
            sig: sig.to_string(),
            fwd: compile_entry(Entry::Fwd)?,
            fwd_all: compile_entry(Entry::FwdAll)?,
            bwd: compile_entry(Entry::Bwd)?,
        })
    }
}
