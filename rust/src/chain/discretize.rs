//! Memory discretization (paper §5.2).
//!
//! The DP table is indexed by an integer number of *memory slots*. Given a
//! budget `M` and a slot count `S` (the paper uses `S = 500`), every byte
//! size is expressed as `ceil(bytes / (M/S))` slots. Rounding *up* keeps
//! the schedule conservative: a schedule feasible in slot space is
//! feasible in bytes (at the cost of ≤ `1 + 1/S` size overestimation).
//!
//! The discretization is *budget-independent* below its top: sizes depend
//! only on the slot width `M/S`, so one [`DiscreteChain`] (and one DP
//! table over its `0..=S` slot axis) answers **every** byte budget
//! `m ≤ M` via [`DiscreteChain::budget_slots`], which rounds the budget
//! *down* to whole slots (conservative in the same direction as the size
//! round-up). This is what lets [`crate::solver::Planner`] solve the DP
//! once per chain and reconstruct schedules for a whole budget sweep.

use super::Chain;

/// Paper's default number of memory slots.
pub const DEFAULT_SLOTS: usize = 500;

/// A chain with all sizes pre-converted to memory slots for a specific
/// budget. This is the solver's input.
#[derive(Debug, Clone)]
pub struct DiscreteChain {
    /// `wa[ℓ]` for `ℓ ∈ 0..=L+1`, in slots.
    pub wa: Vec<u32>,
    /// `wd[ℓ]` (`ω_δ`) for `ℓ ∈ 0..=L+1`, in slots.
    pub wd: Vec<u32>,
    /// `wabar[ℓ-1]` for `ℓ ∈ 1..=L+1`, in slots.
    pub wabar: Vec<u32>,
    pub of: Vec<u32>,
    pub ob: Vec<u32>,
    pub uf: Vec<f64>,
    pub ub: Vec<f64>,
    /// Total budget in slots (the table's m-axis upper bound).
    pub slots: usize,
    /// Bytes per slot (`M / S`).
    pub slot_bytes: f64,
    /// The byte budget `M` this chain was discretized against (the top of
    /// the representable budget range).
    pub top_bytes: u64,
}

impl DiscreteChain {
    /// Size cap in slots. Pathological ratios (a multi-GiB stage against a
    /// one-byte budget — reachable through the planning service's inline
    /// chains) saturate here instead of wrapping the `u32`: the DP adds up
    /// to four sizes at once, so the cap leaves headroom below `u32::MAX`,
    /// and anything this far above a real slot axis (≤ thousands) is
    /// equally infeasible.
    const SLOT_CAP: u32 = u32::MAX / 8;

    /// Discretize `chain` against a byte budget `memory` with `slots` slots.
    pub fn new(chain: &Chain, memory: u64, slots: usize) -> Self {
        assert!(slots > 0 && memory > 0);
        let slot_bytes = memory as f64 / slots as f64;
        let to_slots = |bytes: u64| -> u32 {
            if bytes == 0 {
                0
            } else {
                let slots = (bytes as f64 / slot_bytes).ceil().max(1.0);
                if slots >= Self::SLOT_CAP as f64 {
                    Self::SLOT_CAP
                } else {
                    slots as u32
                }
            }
        };
        let l1 = chain.len();
        DiscreteChain {
            wa: (0..=l1).map(|l| to_slots(chain.wa(l))).collect(),
            wd: (0..=l1).map(|l| to_slots(chain.wdelta(l))).collect(),
            wabar: (1..=l1).map(|l| to_slots(chain.wabar(l))).collect(),
            of: (1..=l1).map(|l| to_slots(chain.of(l))).collect(),
            ob: (1..=l1).map(|l| to_slots(chain.ob(l))).collect(),
            uf: (1..=l1).map(|l| chain.uf(l)).collect(),
            ub: (1..=l1).map(|l| chain.ub(l)).collect(),
            slots,
            slot_bytes,
            top_bytes: memory,
        }
    }

    /// Whole slots available within a byte budget `bytes ≤ top_bytes`:
    /// `floor(bytes / slot_bytes)`, clamped to the axis. Rounding *down*
    /// keeps budgets conservative (a schedule feasible in `k` slots peaks
    /// at ≤ `k · slot_bytes ≤ bytes`); budgets at or above `top_bytes` map
    /// to the full axis exactly, so a solve at the discretization budget
    /// is never off by float rounding.
    pub fn budget_slots(&self, bytes: u64) -> u32 {
        if bytes >= self.top_bytes {
            return self.slots as u32;
        }
        let mut k = ((bytes as f64 / self.slot_bytes) as u32).min(self.slots as u32);
        // guard the floor against upward float rounding at slot boundaries
        while k > 0 && k as f64 * self.slot_bytes > bytes as f64 {
            k -= 1;
        }
        k
    }

    /// Number of stages `L+1`.
    pub fn len(&self) -> usize {
        self.wabar.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wabar.is_empty()
    }

    /// Budget available to the top-level DP call: `M - ω_a^0` in slots
    /// (Algorithm 1 line 12 — the chain input is resident throughout but
    /// charged outside the recursion's limit).
    pub fn top_budget(&self) -> Option<u32> {
        (self.slots as u32).checked_sub(self.wa[0])
    }

    /// Build the O(1) range-max oracle for the DP's memory thresholds
    /// `m∅(s,t)` / `m_all(s,t)` (one O(L log L) precompute per solve).
    pub fn peaks(&self) -> PeakOracle<'_> {
        PeakOracle::new(self)
    }

    // 1-based accessors mirroring `Chain`.
    pub fn wa_s(&self, l: usize) -> u32 {
        self.wa[l]
    }
    pub fn wd_s(&self, l: usize) -> u32 {
        self.wd[l]
    }
    pub fn wabar_s(&self, l: usize) -> u32 {
        self.wabar[l - 1]
    }
    pub fn of_s(&self, l: usize) -> u32 {
        self.of[l - 1]
    }
    pub fn ob_s(&self, l: usize) -> u32 {
        self.ob[l - 1]
    }
    pub fn uf_s(&self, l: usize) -> f64 {
        self.uf[l - 1]
    }
    pub fn ub_s(&self, l: usize) -> f64 {
        self.ub[l - 1]
    }
}

/// O(1) queries for the solver's per-cell memory thresholds.
///
/// Both thresholds of §4.2 are range maxima over the chain:
///
/// * `m∅(s,t) = ω_δ^t + max(ω_a^s + o_f^s, max_{j=s+1..t-1} g_j)` with
///   `g_j = ω_a^{j-1} + ω_a^j + o_f^j` — the peak of an `F∅` sweep;
/// * `m_all(s,t) = max(ω_δ^t + ω_ā^s + o_f^s, ω_δ^s + ω_ā^s + o_b^s)` —
///   already O(1).
///
/// The dense reference fill recomputes `m∅` with an O(t−s) scan per cell
/// (O(L³) total); this oracle precomputes a binary-lifting sparse table
/// over `g_j` once (O(L log L) time and space) so every cell query is two
/// lookups. All sums stay far below `u32::MAX` because every discretized
/// size is capped at [`DiscreteChain::SLOT_CAP`] (`u32::MAX / 8`) and at
/// most four sizes are ever added.
pub struct PeakOracle<'a> {
    dc: &'a DiscreteChain,
    /// `levels[k][i] = max g over j ∈ [i+2, i+2 + 2^k)` (indices are
    /// `j - 2`; `g_j` is defined for `j ∈ 2..=L+1`).
    levels: Vec<Vec<u32>>,
}

impl<'a> PeakOracle<'a> {
    fn new(dc: &'a DiscreteChain) -> Self {
        let n = dc.len();
        let m = n.saturating_sub(1);
        let mut base = Vec::with_capacity(m);
        for j in 2..=n {
            base.push(dc.wa_s(j - 1) + dc.wa_s(j) + dc.of_s(j));
        }
        let mut levels = vec![base];
        let mut k = 0usize;
        while m > 0 && (1usize << (k + 1)) <= m {
            let half = 1usize << k;
            let prev = &levels[k];
            let next: Vec<u32> =
                (0..prev.len() - half).map(|i| prev[i].max(prev[i + half])).collect();
            levels.push(next);
            k += 1;
        }
        PeakOracle { dc, levels }
    }

    /// `max g_j` over `j ∈ lo..=hi` (requires `2 ≤ lo ≤ hi ≤ L+1`).
    fn gmax(&self, lo: usize, hi: usize) -> u32 {
        let (a, b) = (lo - 2, hi - 2);
        let len = b - a + 1;
        let k = usize::BITS as usize - 1 - len.leading_zeros() as usize;
        let row = &self.levels[k];
        row[a].max(row[b + 1 - (1usize << k)])
    }

    /// `m∅(s,t)`: slots needed to sweep `F∅` from `s` to just before `t`
    /// with `δ^t` resident. Bit-for-bit equal to the reference scan.
    pub fn m_empty(&self, s: usize, t: usize) -> u32 {
        let mut peak = self.dc.wa_s(s) + self.dc.of_s(s);
        if t >= s + 2 {
            peak = peak.max(self.gmax(s + 1, t - 1));
        }
        self.dc.wd_s(t) + peak
    }

    /// `m_all(s,t)`: slots needed to run `Fall^s` (with `δ^t` resident)
    /// and later `B^s` (with `δ^s` resident).
    pub fn m_all(&self, s: usize, t: usize) -> u32 {
        let fwd = self.dc.wd_s(t) + self.dc.wabar_s(s) + self.dc.of_s(s);
        let bwd = self.dc.wd_s(s) + self.dc.wabar_s(s) + self.dc.ob_s(s);
        fwd.max(bwd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;

    fn toy() -> Chain {
        Chain::new(
            "toy",
            vec![Stage::new("s1", 1.0, 2.0, 100, 250), Stage::new("s2", 1.0, 1.0, 50, 50)],
            400,
        )
    }

    #[test]
    fn rounds_up() {
        let d = DiscreteChain::new(&toy(), 1000, 10); // slot = 100 bytes
        assert_eq!(d.wa_s(0), 4); // 400 → 4 slots
        assert_eq!(d.wa_s(1), 1); // 100 → 1
        assert_eq!(d.wabar_s(1), 3); // 250 → ceil(2.5) = 3
        assert_eq!(d.wa_s(2), 1); // 50 → 1 (rounded up)
    }

    #[test]
    fn zero_stays_zero_nonzero_at_least_one() {
        let d = DiscreteChain::new(&toy(), 1_000_000, 10);
        assert_eq!(d.of_s(1), 0);
        assert!(d.wa_s(2) >= 1, "tiny sizes must still occupy a slot");
    }

    #[test]
    fn slot_feasibility_implies_byte_feasibility() {
        // Σ slots ≤ S  ⇒  Σ bytes ≤ M, because each item's bytes ≤ slots·slot_bytes.
        let c = toy();
        let m = 777u64;
        let d = DiscreteChain::new(&c, m, DEFAULT_SLOTS);
        let items = [c.wa(0), c.wa(1), c.wabar(1), c.wa(2)];
        let slot_items = [d.wa_s(0), d.wa_s(1), d.wabar_s(1), d.wa_s(2)];
        let bytes: u64 = items.iter().sum();
        let slots: u32 = slot_items.iter().sum();
        if (slots as usize) <= DEFAULT_SLOTS {
            assert!(bytes <= m);
        }
    }

    #[test]
    fn budget_slots_rounds_down_and_clamps() {
        let d = DiscreteChain::new(&toy(), 1000, 10); // slot = 100 bytes
        assert_eq!(d.budget_slots(1000), 10); // the exact top maps to the full axis
        assert_eq!(d.budget_slots(5000), 10); // above-top budgets clamp to it
        assert_eq!(d.budget_slots(999), 9);
        assert_eq!(d.budget_slots(100), 1);
        assert_eq!(d.budget_slots(99), 0);
        assert_eq!(d.budget_slots(0), 0);
    }

    #[test]
    fn pathological_ratios_saturate_instead_of_wrapping() {
        // a stage vastly larger than the whole budget must stay huge in
        // slot space (u32 wrap would make it look tiny → "feasible")
        let huge = Chain::new(
            "huge",
            vec![Stage::new("s1", 1.0, 1.0, 8_589_935_000, 8_589_935_000)],
            1,
        );
        let d = DiscreteChain::new(&huge, 1, 10); // slot_bytes = 0.1
        assert_eq!(d.wa_s(1), DiscreteChain::SLOT_CAP);
        assert_eq!(d.wabar_s(1), DiscreteChain::SLOT_CAP);
    }

    #[test]
    fn peak_oracle_matches_reference_scans() {
        // heterogeneous sizes, including zero overheads and a tiny loss
        let stages: Vec<Stage> = (0..17)
            .map(|i| {
                let wa = 40 + 37 * ((i * i + 3) % 11) as u64;
                let wabar = wa * (1 + (i % 4) as u64);
                let mut st = Stage::new(format!("s{i}"), 1.0, 2.0, wa, wabar);
                if i % 3 == 0 {
                    st = st.with_overheads(wa / 2, wa / 3);
                }
                st
            })
            .chain(std::iter::once(Stage::new("loss", 0.1, 0.1, 4, 4)))
            .collect();
        let c = Chain::new("hetero", stages, 123);
        let dc = DiscreteChain::new(&c, 2048, 64);
        let peaks = dc.peaks();
        let n = dc.len();
        for t in 1..=n {
            for s in 1..=t {
                // reference m∅: the dense fill's O(t−s) scan
                let wd_t = dc.wd_s(t);
                let mut want = wd_t + dc.wa_s(s) + dc.of_s(s);
                for j in (s + 1)..t {
                    want = want.max(wd_t + dc.wa_s(j - 1) + dc.wa_s(j) + dc.of_s(j));
                }
                assert_eq!(peaks.m_empty(s, t), want, "m_empty({s},{t})");
                let fwd = dc.wd_s(t) + dc.wabar_s(s) + dc.of_s(s);
                let bwd = dc.wd_s(s) + dc.wabar_s(s) + dc.ob_s(s);
                assert_eq!(peaks.m_all(s, t), fwd.max(bwd), "m_all({s},{t})");
            }
        }
    }

    #[test]
    fn top_budget_subtracts_input() {
        let d = DiscreteChain::new(&toy(), 1000, 10);
        assert_eq!(d.top_budget(), Some(6)); // 10 - 4
        let d2 = DiscreteChain::new(&toy(), 100, 10); // input alone needs 40 slots
        assert_eq!(d2.top_budget(), None);
    }
}
