//! Deserialization of `artifacts/<preset>/manifest.json` — the contract
//! between `python/compile/aot.py` (build time) and the Rust runtime.
//! Parsed with the in-tree JSON substrate ([`crate::util::json`]).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::{Chain, Stage};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// `xavier` | `zeros` | `ones` | `data` (per-batch input, e.g. the
    /// loss stage's regression target — never updated by SGD).
    pub init: String,
}

impl ParamSpec {
    pub fn nelem(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn is_data(&self) -> bool {
        self.init == "data"
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn nelem(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct SignatureSpec {
    pub kind: String,
    /// entry point → HLO text filename: `fwd`, `fwd_all`, `bwd`.
    pub files: HashMap<String, String>,
    /// Activation of a `dense` signature (`gelu` / `none`), when the
    /// manifest declares it explicitly. Older artifact manifests omit it;
    /// the native backend then falls back to the aot.py naming convention
    /// plus the checkpoint layout.
    pub activation: Option<String>,
    pub params: Vec<ParamSpec>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub abar_extras: Vec<TensorSpec>,
    pub w_a: u64,
    pub w_abar: u64,
    pub flops_fwd: u64,
    pub flops_bwd: u64,
    pub n_grads: usize,
}

#[derive(Debug, Clone)]
pub struct StageRef {
    pub name: String,
    pub kind: String,
    pub sig: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub dtype: String,
    pub input_shape: Vec<usize>,
    pub param_count: u64,
    pub stages: Vec<StageRef>,
    pub signatures: HashMap<String, SignatureSpec>,
    pub content_hash: String,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).with_context(|| format!("manifest: missing field '{key}'"))
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    Ok(field(v, key)?
        .as_str()
        .with_context(|| format!("manifest: '{key}' not a string"))?
        .to_string())
}

fn shape_field(v: &Json, key: &str) -> Result<Vec<usize>> {
    field(v, key)?.shape().with_context(|| format!("manifest: '{key}' not a shape"))
}

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    field(v, key)?.as_u64().with_context(|| format!("manifest: '{key}' not an integer"))
}

impl Manifest {
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = Json::parse(text).context("parsing manifest.json")?;

        let stages = field(&root, "stages")?
            .as_arr()
            .context("'stages' not an array")?
            .iter()
            .map(|s| {
                Ok(StageRef {
                    name: str_field(s, "name")?,
                    kind: str_field(s, "kind")?,
                    sig: str_field(s, "sig")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut signatures = HashMap::new();
        for (name, s) in field(&root, "signatures")?.as_obj().context("'signatures' not an object")? {
            let files = field(s, "files")?
                .as_obj()
                .context("'files' not an object")?
                .iter()
                .map(|(k, v)| {
                    Ok((k.clone(), v.as_str().context("file not a string")?.to_string()))
                })
                .collect::<Result<HashMap<_, _>>>()?;
            let params = field(s, "params")?
                .as_arr()
                .context("'params' not an array")?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: str_field(p, "name")?,
                        shape: shape_field(p, "shape")?,
                        init: str_field(p, "init")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let abar_extras = field(s, "abar_extras")?
                .as_arr()
                .context("'abar_extras' not an array")?
                .iter()
                .map(|t| {
                    Ok(TensorSpec { name: str_field(t, "name")?, shape: shape_field(t, "shape")? })
                })
                .collect::<Result<Vec<_>>>()?;
            signatures.insert(
                name.clone(),
                SignatureSpec {
                    kind: str_field(s, "kind")?,
                    files,
                    activation: s
                        .get("activation")
                        .and_then(|v| v.as_str())
                        .map(|v| v.to_string()),
                    params,
                    in_shape: shape_field(s, "in_shape")?,
                    out_shape: shape_field(s, "out_shape")?,
                    abar_extras,
                    w_a: u64_field(s, "w_a")?,
                    w_abar: u64_field(s, "w_abar")?,
                    flops_fwd: u64_field(s, "flops_fwd")?,
                    flops_bwd: u64_field(s, "flops_bwd")?,
                    n_grads: u64_field(s, "n_grads")? as usize,
                },
            );
        }

        let m = Manifest {
            preset: str_field(&root, "preset")?,
            dtype: str_field(&root, "dtype")?,
            input_shape: shape_field(&root, "input_shape")?,
            param_count: u64_field(&root, "param_count")?,
            stages,
            signatures,
            content_hash: root
                .get("content_hash")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            dir,
        };
        m.validate()?;
        Ok(m)
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text, dir.to_path_buf())
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.dtype == "f32", "only f32 manifests supported");
        ensure!(!self.stages.is_empty(), "empty chain");
        for st in &self.stages {
            if !self.signatures.contains_key(&st.sig) {
                bail!("stage {} references missing signature {}", st.name, st.sig);
            }
        }
        let sig = |s: &StageRef| &self.signatures[&s.sig];
        ensure!(sig(&self.stages[0]).in_shape == self.input_shape, "first stage input mismatch");
        for w in self.stages.windows(2) {
            ensure!(
                sig(&w[0]).out_shape == sig(&w[1]).in_shape,
                "shape break between {} and {}",
                w[0].name,
                w[1].name
            );
        }
        for (name, s) in &self.signatures {
            ensure!(s.w_abar >= s.w_a, "signature {name}: ω_ā < ω_a");
            // An empty file table is a backend-agnostic manifest (e.g. one
            // generated in-process for the native backend); a *partial*
            // table is always a broken artifact set.
            if !s.files.is_empty() {
                for entry in ["fwd", "fwd_all", "bwd"] {
                    ensure!(s.files.contains_key(entry), "signature {name}: missing {entry}");
                }
            }
        }
        Ok(())
    }

    pub fn sig_of(&self, stage_index: usize) -> &SignatureSpec {
        &self.signatures[&self.stages[stage_index].sig]
    }

    /// Bytes of the chain input `a^0`.
    pub fn input_bytes(&self) -> u64 {
        4 * self.input_shape.iter().product::<usize>() as u64
    }

    /// Path of one HLO artifact. Errors (instead of panicking) when the
    /// signature is unknown or has no file for `entry` — e.g. an
    /// in-process manifest fed to the PJRT backend.
    pub fn hlo_path(&self, sig: &str, entry: &str) -> Result<PathBuf> {
        let spec = self
            .signatures
            .get(sig)
            .with_context(|| format!("manifest: unknown signature '{sig}'"))?;
        let file = spec.files.get(entry).with_context(|| {
            format!(
                "manifest: signature '{sig}' has no HLO file for entry '{entry}' \
                 (in-process manifests carry no artifacts — use the native backend)"
            )
        })?;
        Ok(self.dir.join(file))
    }

    /// Build the solver's [`Chain`] from manifest sizes and *measured*
    /// per-stage timings (`uf[i]`, `ub[i]` for stage `i+1`; from the
    /// [`crate::estimator`]).
    pub fn to_chain(&self, uf: &[f64], ub: &[f64]) -> Chain {
        assert_eq!(uf.len(), self.stages.len());
        assert_eq!(ub.len(), self.stages.len());
        let stages = self
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let s = self.sig_of(i);
                Stage::new(st.name.clone(), uf[i], ub[i], s.w_a, s.w_abar)
            })
            .collect();
        Chain::new(format!("manifest:{}", self.preset), stages, self.input_bytes())
    }

    /// A chain with *analytic* timings (FLOPs / device rate) — usable
    /// without running the estimator, e.g. for solver-only workflows.
    pub fn to_chain_analytic(&self, flops_per_us: f64) -> Chain {
        let uf: Vec<f64> = (0..self.stages.len())
            .map(|i| (self.sig_of(i).flops_fwd as f64 / flops_per_us).max(1.0))
            .collect();
        let ub: Vec<f64> = (0..self.stages.len())
            .map(|i| (self.sig_of(i).flops_bwd as f64 / flops_per_us).max(1.0))
            .collect();
        self.to_chain(&uf, &ub)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
          "preset": "test", "dtype": "f32", "input_shape": [2, 4, 8],
          "param_count": 100,
          "stages": [
            {"name": "stage_0_dense", "kind": "dense", "sig": "d"},
            {"name": "stage_1_loss", "kind": "loss", "sig": "l"}
          ],
          "signatures": {
            "d": {"kind": "dense",
                  "files": {"fwd": "d_fwd.hlo.txt", "fwd_all": "d_fa.hlo.txt", "bwd": "d_bwd.hlo.txt"},
                  "params": [{"name": "w", "shape": [8, 8], "init": "xavier"}],
                  "in_shape": [2, 4, 8], "out_shape": [2, 4, 8],
                  "abar_extras": [{"name": "z", "shape": [8, 8]}],
                  "w_a": 256, "w_abar": 512, "flops_fwd": 1024, "flops_bwd": 2048,
                  "n_grads": 1},
            "l": {"kind": "loss",
                  "files": {"fwd": "l_fwd.hlo.txt", "fwd_all": "l_fa.hlo.txt", "bwd": "l_bwd.hlo.txt"},
                  "params": [{"name": "target", "shape": [2, 4, 8], "init": "data"}],
                  "in_shape": [2, 4, 8], "out_shape": [],
                  "abar_extras": [],
                  "w_a": 4, "w_abar": 4, "flops_fwd": 10, "flops_bwd": 20,
                  "n_grads": 0}
          }
        }"#
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::parse(manifest_json(), PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.input_bytes(), 2 * 4 * 8 * 4);
        assert!(m.sig_of(1).params[0].is_data());
        assert_eq!(m.hlo_path("d", "fwd").unwrap(), PathBuf::from("/tmp/d_fwd.hlo.txt"));
        assert!(m.hlo_path("nope", "fwd").is_err());
        assert!(m.hlo_path("d", "nope").is_err());
    }

    #[test]
    fn to_chain_uses_measured_times() {
        let m = Manifest::parse(manifest_json(), PathBuf::from("/tmp")).unwrap();
        let c = m.to_chain(&[5.0, 1.0], &[10.0, 2.0]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.uf(1), 5.0);
        assert_eq!(c.ub(2), 2.0);
        assert_eq!(c.wa(1), 256);
        assert_eq!(c.wabar(1), 512);
        assert_eq!(c.wa0, 256);
    }

    #[test]
    fn shape_break_rejected() {
        let bad = manifest_json().replace("\"out_shape\": [2, 4, 8]", "\"out_shape\": [9, 9]");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn missing_entry_rejected() {
        let bad = manifest_json().replace("\"bwd\": \"d_bwd.hlo.txt\"", "\"x\": \"y\"");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }
}
