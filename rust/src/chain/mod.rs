//! The heterogeneous-chain cost model (paper §3.1).
//!
//! A [`Chain`] is the sequence of stages `1..=L+1` (the last stage is the
//! loss, `F^{L+1}/B^{L+1}` in the paper's notation) plus the size of the
//! chain input `a^0`. Each [`Stage`] carries everything the dynamic
//! program consumes: forward/backward durations `u_f`, `u_b`, the
//! activation byte counts `ω_a` (output) and `ω_ā` (full checkpoint — by
//! the paper's convention `ā^ℓ ⊇ a^ℓ`, so `ω_ā ≥ ω_a`), and the transient
//! per-op memory overheads `o_f`, `o_b`. `ω_δ = ω_a` (a gradient has the
//! shape of its activation), matching the paper's "in practice" remark.

mod discretize;
pub mod manifest;
pub mod profiles;

pub use discretize::{DiscreteChain, PeakOracle, DEFAULT_SLOTS};

/// One stage of the chain (a layer or an arbitrarily complex block).
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    /// Human-readable name (e.g. `stage_3_attn` or `layer2.block1`).
    pub name: String,
    /// Forward duration `u_f^ℓ` (any consistent unit; the executor uses µs,
    /// the profiles use ms — the solver only compares sums).
    pub uf: f64,
    /// Backward duration `u_b^ℓ`.
    pub ub: f64,
    /// Bytes of the stage output `a^ℓ`.
    pub wa: u64,
    /// Bytes of the full checkpoint `ā^ℓ` (includes `a^ℓ`).
    pub wabar: u64,
    /// Bytes of the gradient `ω_δ^ℓ`. In practice equal to `wa` (the
    /// paper's remark) — kept separate because the §4.1 counterexample
    /// and the DP's formulas treat it independently.
    pub wd: u64,
    /// Transient peak overhead of the forward op, in bytes.
    pub of: u64,
    /// Transient peak overhead of the backward op, in bytes.
    pub ob: u64,
}

impl Stage {
    /// Convenience constructor used by tests and profiles (`ω_δ = ω_a`).
    pub fn new(name: impl Into<String>, uf: f64, ub: f64, wa: u64, wabar: u64) -> Self {
        assert!(wabar >= wa, "ā must include a (ω_ā ≥ ω_a)");
        Stage { name: name.into(), uf, ub, wa, wabar, wd: wa, of: 0, ob: 0 }
    }

    pub fn with_overheads(mut self, of: u64, ob: u64) -> Self {
        self.of = of;
        self.ob = ob;
        self
    }

    /// Override the gradient size `ω_δ^ℓ` (§4.1-style constructions).
    pub fn with_delta_size(mut self, wd: u64) -> Self {
        self.wd = wd;
        self
    }
}

/// A heterogeneous chain: stages `1..=L+1` plus the input size `ω_a^0`.
#[derive(Debug, Clone, PartialEq)]
pub struct Chain {
    pub name: String,
    /// `stages[ℓ-1]` is stage `ℓ` for `ℓ ∈ 1..=L+1`. The final entry is
    /// the loss stage; its `wa` is the (tiny) loss scalar.
    pub stages: Vec<Stage>,
    /// Bytes of the chain input `a^0` (= `ω_a^0`, also `ω_δ^0`).
    pub wa0: u64,
}

impl Chain {
    pub fn new(name: impl Into<String>, stages: Vec<Stage>, wa0: u64) -> Self {
        assert!(!stages.is_empty(), "a chain needs at least one stage");
        Chain { name: name.into(), stages, wa0 }
    }

    /// Number of stages including the loss stage (`L+1`).
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// `ω_a^ℓ` for `ℓ ∈ 0..=L+1` (bytes).
    pub fn wa(&self, l: usize) -> u64 {
        if l == 0 {
            self.wa0
        } else {
            self.stages[l - 1].wa
        }
    }

    /// `ω_ā^ℓ` for `ℓ ∈ 1..=L+1` (bytes).
    pub fn wabar(&self, l: usize) -> u64 {
        self.stages[l - 1].wabar
    }

    /// `ω_δ^ℓ` for `ℓ ∈ 0..=L+1` (bytes). `ω_δ^0 = ω_a^0` by convention
    /// (the input gradient replaces the input).
    pub fn wdelta(&self, l: usize) -> u64 {
        if l == 0 {
            self.wa0
        } else {
            self.stages[l - 1].wd
        }
    }

    pub fn uf(&self, l: usize) -> f64 {
        self.stages[l - 1].uf
    }

    pub fn ub(&self, l: usize) -> f64 {
        self.stages[l - 1].ub
    }

    pub fn of(&self, l: usize) -> u64 {
        self.stages[l - 1].of
    }

    pub fn ob(&self, l: usize) -> u64 {
        self.stages[l - 1].ob
    }

    /// Lower bound on any schedule's makespan: every forward and backward
    /// runs at least once (this is exactly the store-all time).
    pub fn ideal_time(&self) -> f64 {
        self.stages.iter().map(|s| s.uf + s.ub).sum()
    }

    /// Memory needed by the store-all (plain PyTorch) strategy: all `ā`
    /// resident at the end of the forward sweep, plus input and the widest
    /// transient. A cheap upper bound used to pick sweep ranges.
    pub fn store_all_memory(&self) -> u64 {
        let abar_total: u64 = self.stages.iter().map(|s| s.wabar).sum();
        let max_transient = self
            .stages
            .iter()
            .map(|s| s.of.max(s.ob) + s.wa)
            .max()
            .unwrap_or(0);
        self.wa0 + abar_total + max_transient
    }

    /// Smallest memory for which *some* schedule might exist — used as the
    /// low end of figure sweeps. (Not tight; the DP decides feasibility.)
    pub fn min_memory_hint(&self) -> u64 {
        let max_pair = (1..=self.len())
            .map(|l| self.wa(l - 1) + self.wa(l) + self.of(l))
            .max()
            .unwrap_or(0);
        let max_bwd = (1..=self.len())
            .map(|l| self.wa(l - 1) + self.wabar(l) + self.wdelta(l) + self.ob(l))
            .max()
            .unwrap_or(0);
        max_pair.max(max_bwd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Chain {
        Chain::new(
            "toy",
            vec![
                Stage::new("s1", 1.0, 2.0, 100, 250),
                Stage::new("s2", 3.0, 4.0, 50, 60),
                Stage::new("loss", 0.5, 0.5, 4, 4),
            ],
            400,
        )
    }

    #[test]
    fn indexing_is_one_based() {
        let c = toy();
        assert_eq!(c.wa(0), 400);
        assert_eq!(c.wa(1), 100);
        assert_eq!(c.wa(3), 4);
        assert_eq!(c.wabar(1), 250);
        assert_eq!(c.uf(2), 3.0);
        assert_eq!(c.ub(3), 0.5);
        assert_eq!(c.wdelta(2), c.wa(2));
    }

    #[test]
    fn ideal_time_sums_everything() {
        assert_eq!(toy().ideal_time(), 1.0 + 2.0 + 3.0 + 4.0 + 0.5 + 0.5);
    }

    #[test]
    fn store_all_memory_dominates_min_hint() {
        let c = toy();
        assert!(c.store_all_memory() >= c.min_memory_hint());
    }

    #[test]
    #[should_panic]
    fn abar_must_include_a() {
        Stage::new("bad", 1.0, 1.0, 100, 50);
    }
}
