//! Analytic per-layer profiles of the paper's benchmark networks.
//!
//! The paper measures `(u_f, u_b, ω_a, ω_ā)` for every layer of
//! torchvision's ResNet / DenseNet / Inception v3 (plus the ResNet-200 /
//! ResNet-1001 variants of He et al.) on a V100, then feeds those vectors
//! to the DP. We regenerate the vectors *analytically* from the published
//! layer shape math: FLOP counts and activation byte counts follow
//! directly from (depth, image size, batch size), and a V100-like roofline
//! [`DeviceModel`] converts FLOPs/bytes to durations. What the figures
//! depend on — the heterogeneity *structure* (early layers: huge
//! activations, cheap math; late layers: the reverse; DenseNet's growing
//! concatenations; Inception's mixed blocks) — is preserved exactly.
//! See DESIGN.md §Hardware-adaptation.

use super::{Chain, Stage};

/// Roofline device model used to turn FLOPs and bytes into durations (ms).
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// Effective FP32 throughput, FLOP/s.
    pub flops_per_s: f64,
    /// Effective memory bandwidth, bytes/s.
    pub bytes_per_s: f64,
    /// Fixed per-stage launch overhead, seconds.
    pub overhead_s: f64,
}

impl DeviceModel {
    /// V100-PCIE-ish effective numbers (15.7 TFLOP/s peak × ~45% conv
    /// efficiency; 900 GB/s × ~70%).
    pub const V100: DeviceModel = DeviceModel {
        flops_per_s: 7.0e12,
        bytes_per_s: 6.3e11,
        overhead_s: 3.0e-5,
    };

    /// Duration in milliseconds of a stage moving `bytes` and computing
    /// `flops` (roofline: bound by the slower of compute and memory).
    pub fn time_ms(&self, flops: f64, bytes: f64) -> f64 {
        let t = (flops / self.flops_per_s).max(bytes / self.bytes_per_s) + self.overhead_s;
        t * 1e3
    }
}

/// Accumulates stages while tracking the running tensor shape.
struct Builder {
    dev: DeviceModel,
    batch: u64,
    stages: Vec<Stage>,
}

const B4: u64 = 4; // f32 bytes

impl Builder {
    fn new(dev: DeviceModel, batch: u64) -> Self {
        Builder { dev, batch, stages: Vec::new() }
    }

    /// Push one stage. `flops`: forward FLOPs. `out_elems`: elements of
    /// `a^ℓ` per batch item. `saved_elems`: *extra* per-item elements in
    /// `ā^ℓ` beyond the output itself (conv/bn/relu intermediates).
    fn stage(&mut self, name: String, flops: f64, out_elems: u64, saved_elems: u64) {
        let wa = B4 * self.batch * out_elems;
        let wabar = wa + B4 * self.batch * saved_elems;
        // forward traffic ≈ read input (~output-sized) + write ā
        let uf = self.dev.time_ms(flops, (wa + wabar) as f64);
        // backward: ~2× FLOPs, reads ā + δ, writes δ
        let ub = self.dev.time_ms(2.0 * flops, (wabar + 2 * wa) as f64);
        self.stages.push(Stage::new(name, uf, ub, wa, wabar));
    }

    /// Final classifier + loss stage (small, closes the chain).
    fn head_and_loss(&mut self, in_elems: u64, classes: u64) {
        let flops = 2.0 * (self.batch * in_elems * classes) as f64;
        self.stage("fc".into(), flops, classes, 0);
        let loss_flops = 4.0 * (self.batch * classes) as f64;
        let wa = B4; // scalar loss
        let uf = self.dev.time_ms(loss_flops, (B4 * self.batch * classes) as f64);
        self.stages.push(Stage::new("loss", uf, uf, wa, wa));
    }
}

fn conv_flops(b: u64, h_out: u64, w_out: u64, cin: u64, cout: u64, k: u64) -> f64 {
    2.0 * (b * h_out * w_out * cin * cout * k * k) as f64
}

// ---------------------------------------------------------------------------
// ResNet
// ---------------------------------------------------------------------------

/// Bottleneck block counts per torchvision / He et al.
fn resnet_blocks(depth: u32) -> (&'static [u64], bool) {
    // (layer block counts, is_bottleneck)
    match depth {
        18 => (&[2, 2, 2, 2], false),
        34 => (&[3, 4, 6, 3], false),
        50 => (&[3, 4, 6, 3], true),
        101 => (&[3, 4, 23, 3], true),
        152 => (&[3, 8, 36, 3], true),
        200 => (&[3, 24, 36, 3], true),
        d => panic!("unsupported resnet depth {d} (use 18/34/50/101/152/200/1001)"),
    }
}

/// ImageNet-style ResNet: stem (conv7 s2 + maxpool s2), 4 layers, head.
/// One chain stage per residual block — the paper's sequentialization.
pub fn resnet(depth: u32, image: u64, batch: u64) -> Chain {
    if depth == 1001 {
        return resnet1001(image, batch);
    }
    let dev = DeviceModel::V100;
    let (blocks, bottleneck) = resnet_blocks(depth);
    let expansion: u64 = if bottleneck { 4 } else { 1 };
    let mut b = Builder::new(dev, batch);

    // stem: conv7x7/2 (64ch) + bn/relu + maxpool/2
    let h1 = image / 2;
    let h2 = image / 4;
    b.stage(
        "stem".into(),
        conv_flops(batch, h1, h1, 3, 64, 7),
        64 * h2 * h2,
        64 * h1 * h1, // pre-pool feature map checkpointed
    );

    let mut cin = 64u64;
    let mut h = h2;
    for (li, &n) in blocks.iter().enumerate() {
        let mid = 64 << li; // 64,128,256,512
        let cout = mid * expansion;
        for bi in 0..n {
            let stride = if li > 0 && bi == 0 { 2 } else { 1 };
            let h_out = h / stride;
            let (flops, saved) = if bottleneck {
                let f = conv_flops(batch, h, h, cin, mid, 1)
                    + conv_flops(batch, h_out, h_out, mid, mid, 3)
                    + conv_flops(batch, h_out, h_out, mid, cout, 1)
                    + if stride == 2 || cin != cout {
                        conv_flops(batch, h_out, h_out, cin, cout, 1)
                    } else {
                        0.0
                    };
                // saved: conv1 out (+bn/relu copy), conv2 out (+copy), conv3 pre-add
                let s = 2 * mid * h * h + 2 * mid * h_out * h_out + cout * h_out * h_out;
                (f, s)
            } else {
                let f = conv_flops(batch, h_out, h_out, cin, cout, 3)
                    + conv_flops(batch, h_out, h_out, cout, cout, 3)
                    + if stride == 2 || cin != cout {
                        conv_flops(batch, h_out, h_out, cin, cout, 1)
                    } else {
                        0.0
                    };
                let s = 2 * cout * h_out * h_out + cout * h_out * h_out;
                (f, s)
            };
            b.stage(
                format!("layer{}.{}", li + 1, bi),
                flops,
                cout * h_out * h_out,
                saved,
            );
            cin = cout;
            h = h_out;
        }
    }
    b.head_and_loss(cin, 1000);
    let input_bytes = B4 * batch * 3 * image * image;
    Chain::new(format!("resnet{depth}-i{image}-b{batch}"), b.stages, input_bytes)
}

/// CIFAR-style pre-activation ResNet-1001 (He et al. 2016): 3 groups of
/// 111 bottleneck blocks at channels (64, 128, 256), evaluated by the
/// paper at ImageNet image sizes. Chain length = 333 blocks + stem + head,
/// matching the paper's "chain of length 339" within a few stages.
fn resnet1001(image: u64, batch: u64) -> Chain {
    let dev = DeviceModel::V100;
    let mut b = Builder::new(dev, batch);
    // stem: conv3x3 16ch, stride 1 (CIFAR style) — huge at image 224+
    b.stage(
        "stem".into(),
        conv_flops(batch, image, image, 3, 16, 3),
        16 * image * image,
        16 * image * image,
    );
    let mut cin = 16u64;
    let mut h = image;
    for (gi, mid) in [16u64, 32, 64].into_iter().enumerate() {
        let cout = mid * 4;
        for bi in 0..111u64 {
            let stride = if gi > 0 && bi == 0 { 2 } else { 1 };
            let h_out = h / stride;
            let flops = conv_flops(batch, h, h, cin, mid, 1)
                + conv_flops(batch, h_out, h_out, mid, mid, 3)
                + conv_flops(batch, h_out, h_out, mid, cout, 1)
                + if cin != cout { conv_flops(batch, h_out, h_out, cin, cout, 1) } else { 0.0 };
            let saved = 2 * mid * h * h + 2 * mid * h_out * h_out + cout * h_out * h_out;
            b.stage(format!("g{}.{}", gi + 1, bi), flops, cout * h_out * h_out, saved);
            cin = cout;
            h = h_out;
        }
    }
    b.head_and_loss(cin, 1000);
    let input_bytes = B4 * batch * 3 * image * image;
    Chain::new(format!("resnet1001-i{image}-b{batch}"), b.stages, input_bytes)
}

// ---------------------------------------------------------------------------
// DenseNet
// ---------------------------------------------------------------------------

fn densenet_config(depth: u32) -> (u64, &'static [u64], u64) {
    // (growth rate, block layer counts, stem channels)
    match depth {
        121 => (32, &[6, 12, 24, 16], 64),
        161 => (48, &[6, 12, 36, 24], 96),
        169 => (32, &[6, 12, 32, 32], 64),
        201 => (32, &[6, 12, 48, 32], 64),
        d => panic!("unsupported densenet depth {d} (use 121/161/169/201)"),
    }
}

/// DenseNet: one chain stage per dense layer; the stage output is the
/// running concatenation (this is what makes DenseNet memory-quadratic
/// and the paper's motivating case [18]).
pub fn densenet(depth: u32, image: u64, batch: u64) -> Chain {
    let dev = DeviceModel::V100;
    let (g, blocks, stem_c) = densenet_config(depth);
    let mut b = Builder::new(dev, batch);

    let h1 = image / 2;
    let mut h = image / 4;
    b.stage(
        "stem".into(),
        conv_flops(batch, h1, h1, 3, stem_c, 7),
        stem_c * h * h,
        stem_c * h1 * h1,
    );

    let mut c = stem_c;
    for (bi, &layers) in blocks.iter().enumerate() {
        for li in 0..layers {
            // bn-relu-conv1x1(4g) then bn-relu-conv3x3(g), concat output
            let flops = conv_flops(batch, h, h, c, 4 * g, 1) + conv_flops(batch, h, h, 4 * g, g, 3);
            let out = (c + g) * h * h; // concatenated features
            let saved = 2 * 4 * g * h * h + g * h * h; // bottleneck intermediates
            b.stage(format!("dense{}.{}", bi + 1, li), flops, out, saved);
            c += g;
        }
        if bi + 1 < blocks.len() {
            // transition: conv1x1 halving channels + avgpool/2
            let c2 = c / 2;
            let flops = conv_flops(batch, h, h, c, c2, 1);
            let h2 = h / 2;
            b.stage(format!("trans{}", bi + 1), flops, c2 * h2 * h2, c2 * h * h);
            c = c2;
            h = h2;
        }
    }
    b.head_and_loss(c, 1000);
    let input_bytes = B4 * batch * 3 * image * image;
    Chain::new(format!("densenet{depth}-i{image}-b{batch}"), b.stages, input_bytes)
}

// ---------------------------------------------------------------------------
// Inception v3
// ---------------------------------------------------------------------------

/// Inception v3 as a sequential chain of its mixed modules (torchvision's
/// `Mixed_5b..Mixed_7c`). Each module is modeled as its aggregate branch
/// convolutions at the published channel configuration.
pub fn inception_v3(image: u64, batch: u64) -> Chain {
    let dev = DeviceModel::V100;
    let mut b = Builder::new(dev, batch);

    // stem: 3 convs /2 + pool + 2 convs + pool  → H/8 roughly, 192ch
    let h2 = image / 2;
    let h4 = image / 4;
    let h8 = image / 8;
    b.stage("stem.a".into(), conv_flops(batch, h2, h2, 3, 32, 3), 32 * h2 * h2, 32 * h2 * h2);
    b.stage("stem.b".into(), conv_flops(batch, h2, h2, 32, 64, 3), 64 * h4 * h4, 64 * h2 * h2);
    b.stage("stem.c".into(), conv_flops(batch, h4, h4, 64, 192, 3), 192 * h8 * h8, 192 * h4 * h4 / 2);

    // (name, H divisor, Cin, Cout, equivalent conv3x3 pairs)
    let modules: &[(&str, u64, u64, u64, f64)] = &[
        ("mixed5b", 8, 192, 256, 1.6),
        ("mixed5c", 8, 256, 288, 1.6),
        ("mixed5d", 8, 288, 288, 1.6),
        ("mixed6a", 16, 288, 768, 1.8), // reduction
        ("mixed6b", 16, 768, 768, 2.2),
        ("mixed6c", 16, 768, 768, 2.2),
        ("mixed6d", 16, 768, 768, 2.2),
        ("mixed6e", 16, 768, 768, 2.2),
        ("mixed7a", 32, 768, 1280, 1.8), // reduction
        ("mixed7b", 32, 1280, 2048, 2.4),
        ("mixed7c", 32, 2048, 2048, 2.4),
    ];
    for &(name, div, cin, cout, pairs) in modules {
        let h = (image / div).max(1);
        let flops = pairs * conv_flops(batch, h, h, cin, cout, 3) / 2.0;
        // branches keep several intermediate maps alive
        let saved = (3 * cout / 2) * h * h;
        b.stage(name.into(), flops, cout * h * h, saved);
    }
    b.head_and_loss(2048, 1000);
    let input_bytes = B4 * batch * 3 * image * image;
    Chain::new(format!("inception3-i{image}-b{batch}"), b.stages, input_bytes)
}

// ---------------------------------------------------------------------------
// VGG
// ---------------------------------------------------------------------------

/// VGG-19: the classic heavyweight — enormous early activations with
/// modest FLOPs, the opposite end of the heterogeneity spectrum.
pub fn vgg19(image: u64, batch: u64) -> Chain {
    let dev = DeviceModel::V100;
    let cfg: &[(u64, u64)] = &[
        // (channels, convs in the block before pooling)
        (64, 2),
        (128, 2),
        (256, 4),
        (512, 4),
        (512, 4),
    ];
    let mut b = Builder::new(dev, batch);
    let mut cin = 3u64;
    let mut h = image;
    for (bi, &(c, n)) in cfg.iter().enumerate() {
        for ci in 0..n {
            let flops = conv_flops(batch, h, h, cin, c, 3);
            let last = ci == n - 1;
            let h_out = if last { h / 2 } else { h };
            b.stage(
                format!("conv{}_{}", bi + 1, ci + 1),
                flops,
                c * h_out * h_out,
                if last { c * h * h } else { c * h * h / 2 },
            );
            cin = c;
            if last {
                h = h_out;
            }
        }
    }
    // two FC layers then head
    let fc_in = cin * h * h;
    b.stage("fc6".into(), 2.0 * (batch * fc_in * 4096) as f64, 4096, 4096);
    b.stage("fc7".into(), 2.0 * (batch * 4096 * 4096) as f64, 4096, 4096);
    b.head_and_loss(4096, 1000);
    let input_bytes = B4 * batch * 3 * image * image;
    Chain::new(format!("vgg19-i{image}-b{batch}"), b.stages, input_bytes)
}

// ---------------------------------------------------------------------------
// Synthetic deep chains (solver scaling)
// ---------------------------------------------------------------------------

/// Deterministic heterogeneous chain of `depth` compute stages plus a
/// loss stage — the solver-scaling workload (`bench_solver`'s L = 10⁴
/// case and the deep parity tests). Not a network profile and not in
/// [`FAMILIES`]: the stage costs cycle through four "phases" (memory-heavy
/// / balanced / compute-heavy / checkpoint-cheap) so every slice of the
/// chain is heterogeneous the way the DP cares about, at any depth.
pub fn deep_chain(depth: usize) -> Chain {
    assert!(depth >= 1, "deep_chain needs at least one compute stage");
    let mut stages = Vec::with_capacity(depth + 1);
    for i in 0..depth {
        // phase-cycling costs; the ×(1 + i%7) wobble keeps neighboring
        // cells from sharing thresholds, which is the hard case for the
        // compressed rows
        let (uf, ub, wa, wabar) = match i % 4 {
            0 => (0.8, 1.9, 96, 320),  // memory-heavy, cheap math
            1 => (1.6, 3.1, 48, 128),  // balanced
            2 => (3.2, 6.5, 24, 64),   // compute-heavy, small tensors
            _ => (1.1, 2.3, 16, 192),  // cheap checkpoint, fat tape
        };
        let j = (1 + i % 7) as u64;
        stages.push(Stage::new(
            format!("d{i}"),
            uf * j as f64,
            ub * j as f64,
            wa * j,
            wabar * j,
        ));
    }
    stages.push(Stage::new("loss", 0.2, 0.2, 8, 8));
    Chain::new(format!("deep-{depth}"), stages, 96)
}

/// Every profile family this module can generate (service discovery and
/// CLI validation).
pub const FAMILIES: &[&str] = &["resnet", "densenet", "inception", "vgg"];

/// The depths a family supports. Depth-less families (`inception`, `vgg`)
/// report `[0]` — any depth argument is ignored for them.
pub fn supported_depths(family: &str) -> &'static [u32] {
    match family {
        "resnet" => &[18, 34, 50, 101, 152, 200, 1001],
        "densenet" => &[121, 161, 169, 201],
        "inception" | "vgg" => &[0],
        _ => &[],
    }
}

/// Non-panicking profile lookup: `None` for an unknown family or an
/// unsupported depth (the planning service turns this into a structured
/// 4xx instead of a worker panic). Depth is ignored for `inception`/`vgg`.
pub fn try_by_name(family: &str, depth: u32, image: u64, batch: u64) -> Option<Chain> {
    match family {
        "resnet" | "densenet" if !supported_depths(family).contains(&depth) => None,
        "resnet" => Some(resnet(depth, image, batch)),
        "densenet" => Some(densenet(depth, image, batch)),
        "inception" => Some(inception_v3(image, batch)),
        "vgg" => Some(vgg19(image, batch)),
        _ => None,
    }
}

/// Look up a profile by family name (CLI surface; panics on unknown
/// input — use [`try_by_name`] where the caller must survive bad names).
pub fn by_name(family: &str, depth: u32, image: u64, batch: u64) -> Chain {
    match family {
        "resnet" => resnet(depth, image, batch),
        "densenet" => densenet(depth, image, batch),
        "inception" => inception_v3(image, batch),
        "vgg" => vgg19(image, batch),
        f => panic!("unknown network family {f} (resnet/densenet/inception/vgg)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_chain_lengths() {
        // stem + Σblocks + fc + loss
        assert_eq!(resnet(18, 224, 1).len(), 1 + 8 + 2);
        assert_eq!(resnet(50, 224, 1).len(), 1 + 16 + 2);
        assert_eq!(resnet(101, 224, 1).len(), 1 + 33 + 2);
        assert_eq!(resnet(152, 224, 1).len(), 1 + 50 + 2);
        // paper: ResNet-1001 → chain of length 339; ours: 333 + 3 = 336
        let n = resnet(1001, 224, 1).len();
        assert!((330..=345).contains(&n), "got {n}");
    }

    #[test]
    fn densenet_chain_lengths() {
        // stem + Σlayers + 3 transitions + fc + loss
        assert_eq!(densenet(121, 224, 1).len(), 1 + 58 + 3 + 2);
        assert_eq!(densenet(201, 224, 1).len(), 1 + 98 + 3 + 2);
    }

    #[test]
    fn batch_scales_activations_linearly() {
        let c1 = resnet(50, 224, 1);
        let c8 = resnet(50, 224, 8);
        // (the loss stage outputs a batch-independent scalar — skip it)
        for l in 1..c1.len() {
            assert_eq!(8 * c1.wa(l), c8.wa(l));
            assert_eq!(8 * c1.wabar(l), c8.wabar(l));
        }
    }

    #[test]
    fn early_layers_are_memory_heavy_late_layers_compute_heavy() {
        // the heterogeneity the paper exploits
        let c = resnet(101, 1000, 4);
        let first_block = &c.stages[1];
        let late_block = &c.stages[c.len() - 5];
        let early_ratio = first_block.wabar as f64 / first_block.uf;
        let late_ratio = late_block.wabar as f64 / late_block.uf;
        assert!(
            early_ratio > 2.0 * late_ratio,
            "early {early_ratio:.0} vs late {late_ratio:.0}"
        );
    }

    #[test]
    fn densenet_outputs_grow() {
        let c = densenet(121, 224, 1);
        // within the first dense block, wa grows monotonically (concat)
        let was: Vec<u64> = (2..=6).map(|l| c.wa(l)).collect();
        assert!(was.windows(2).all(|w| w[1] > w[0]), "{was:?}");
    }

    #[test]
    fn paper_scale_sanity_resnet101_img1000() {
        // Fig. 3: PyTorch at bs1 uses ~2.8 GiB for activations; our
        // store-all accounting should land within the same order.
        let c = resnet(101, 1000, 1);
        let gib = c.store_all_memory() as f64 / (1u64 << 30) as f64;
        assert!((0.8..12.0).contains(&gib), "store-all = {gib:.2} GiB");
        // and a V100-ish forward+backward should take tens–hundreds of ms
        assert!((10.0..5000.0).contains(&c.ideal_time()), "{}", c.ideal_time());
    }

    #[test]
    fn try_by_name_rejects_instead_of_panicking() {
        assert!(try_by_name("resnet", 50, 224, 4).is_some());
        assert!(try_by_name("resnet", 51, 224, 4).is_none());
        assert!(try_by_name("densenet", 169, 224, 4).is_some());
        assert!(try_by_name("densenet", 50, 224, 4).is_none());
        assert!(try_by_name("alexnet", 8, 224, 4).is_none());
        // depth ignored for the depth-less families
        assert!(try_by_name("vgg", 999, 224, 4).is_some());
        assert!(try_by_name("inception", 0, 299, 4).is_some());
        for f in FAMILIES {
            assert!(!supported_depths(f).is_empty(), "{f}");
        }
    }

    #[test]
    fn deep_chain_is_deterministic_and_heterogeneous() {
        let a = deep_chain(200);
        let b = deep_chain(200);
        assert_eq!(a.len(), 201);
        for l in 1..=a.len() {
            assert_eq!(a.wa(l), b.wa(l));
            assert_eq!(a.wabar(l), b.wabar(l));
        }
        // genuinely heterogeneous: many distinct checkpoint sizes
        let mut sizes: Vec<u64> = (1..a.len()).map(|l| a.wa(l)).collect();
        sizes.sort_unstable();
        sizes.dedup();
        assert!(sizes.len() >= 10, "only {} distinct sizes", sizes.len());
    }

    #[test]
    fn all_families_build() {
        for image in [224, 500] {
            let _ = resnet(34, image, 2);
            let _ = densenet(169, image, 2);
            let _ = inception_v3(image, 2);
            let _ = vgg19(image, 2);
        }
    }
}
