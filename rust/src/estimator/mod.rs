//! Parameter estimation (paper §5.1): measure `u_f^ℓ` and `u_b^ℓ` of
//! every stage by timing its compiled entry points on dummy tensors.
//!
//! Like the paper's tool, this runs once before training: each stage's
//! `fwd_all` is executed to materialize a realistic `ā^ℓ`, then `fwd` and
//! `bwd` are timed over several repetitions (median of wall-clock). The
//! measured vectors plus the manifest's byte counts give the solver's
//! [`Chain`]. The assumption (also the paper's): stage compute does not
//! depend on tensor *values*, so zero tensors time identically to real
//! activations. Works on any [`Backend`] — the native engine is timed
//! the same way the PJRT executables are.

use anyhow::{Context, Result};

use crate::backend::{Backend, Tensor};
use crate::chain::Chain;
use crate::runtime::{Entry, Runtime};
use crate::util::median;

/// Measured timings for one stage (microseconds).
#[derive(Debug, Clone)]
pub struct StageTiming {
    /// Stage name from the manifest (e.g. `stage_3_attn`).
    pub name: String,
    /// Median forward duration `u_f^ℓ`, microseconds.
    pub uf_us: f64,
    /// Median backward duration `u_b^ℓ`, microseconds.
    pub ub_us: f64,
}

/// Measurement configuration.
#[derive(Debug, Clone, Copy)]
pub struct EstimatorConfig {
    /// Timed repetitions per entry (median taken).
    pub reps: usize,
    /// Untimed warmup executions per entry.
    pub warmup: usize,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig { reps: 5, warmup: 2 }
    }
}

/// Time every stage of the runtime's chain; returns per-stage timings in
/// stage order.
pub fn estimate<B: Backend>(rt: &Runtime<B>, cfg: EstimatorConfig) -> Result<Vec<StageTiming>> {
    let manifest = &rt.manifest;
    let mut out = Vec::with_capacity(manifest.stages.len());
    for (i, st) in manifest.stages.iter().enumerate() {
        let sig = manifest.sig_of(i);
        // dummy parameters & input (values don't affect timing)
        let params: Vec<B::Tensor> = sig
            .params
            .iter()
            .map(|p| B::Tensor::zeros(&p.shape))
            .collect::<Result<Vec<_>>>()?;
        let a_in = B::Tensor::zeros(&sig.in_shape)?;
        let delta_out = if sig.out_shape.is_empty() {
            B::Tensor::scalar(1.0)
        } else {
            B::Tensor::zeros(&sig.out_shape)?
        };

        let fwd_args: Vec<&B::Tensor> = params.iter().chain(std::iter::once(&a_in)).collect();

        // materialize ā once for the backward's inputs
        let abar = rt
            .execute(&st.sig, Entry::FwdAll, &fwd_args)
            .with_context(|| format!("estimating {}", st.name))?;
        let mut bwd_args: Vec<&B::Tensor> = params.iter().collect();
        bwd_args.push(&a_in);
        bwd_args.extend(abar.iter());
        bwd_args.push(&delta_out);

        let time_entry = |entry: Entry, args: &[&B::Tensor]| -> Result<f64> {
            for _ in 0..cfg.warmup {
                rt.execute(&st.sig, entry, args)?;
            }
            let mut samples = Vec::with_capacity(cfg.reps);
            for _ in 0..cfg.reps.max(1) {
                let t0 = std::time::Instant::now();
                rt.execute(&st.sig, entry, args)?;
                samples.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            Ok(median(&mut samples))
        };

        // u_f: the forward op (F∅/Fck/Fall all cost u_f in the model; we
        // time fwd_all since that is what the optimal schedule mostly runs
        // and the difference is one extra store)
        let uf_us = time_entry(Entry::FwdAll, &fwd_args)?;
        let ub_us = time_entry(Entry::Bwd, &bwd_args)?;
        out.push(StageTiming { name: st.name.clone(), uf_us, ub_us });
    }
    Ok(out)
}

/// Assemble the solver's [`Chain`] from already-measured timings (byte
/// counts from the manifest, durations from the estimator).
pub fn chain_from_timings(
    manifest: &crate::chain::manifest::Manifest,
    timings: &[StageTiming],
) -> Chain {
    let uf: Vec<f64> = timings.iter().map(|t| t.uf_us).collect();
    let ub: Vec<f64> = timings.iter().map(|t| t.ub_us).collect();
    manifest.to_chain(&uf, &ub)
}

/// Convenience: estimate and assemble the solver's [`Chain`].
pub fn measured_chain<B: Backend>(rt: &Runtime<B>, cfg: EstimatorConfig) -> Result<Chain> {
    let timings = estimate(rt, cfg)?;
    Ok(chain_from_timings(&rt.manifest, &timings))
}

/// Render timings as an aligned table for the CLI.
pub fn format_table(timings: &[StageTiming], chain: &Chain) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>10} {:>10} {:>12} {:>12}\n",
        "stage", "u_f (µs)", "u_b (µs)", "ω_a", "ω_ā"
    ));
    for (i, t) in timings.iter().enumerate() {
        let l = i + 1;
        s.push_str(&format!(
            "{:<20} {:>10.1} {:>10.1} {:>12} {:>12}\n",
            t.name,
            t.uf_us,
            t.ub_us,
            chain.wa(l),
            chain.wabar(l)
        ));
    }
    s
}
