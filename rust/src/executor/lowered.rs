//! The lowered replay path: bind an [`ExecPlan`] to compiled stages and
//! replay it over one persistent f32 arena with zero steady-state heap
//! allocations.
//!
//! [`Executor::lower`] runs once per `(executor, schedule)`: it lowers
//! the schedule against the executor's manifest-derived size model
//! ([`crate::plan::lower`]), translates the plan's byte slots into
//! element ranges of a pooled arena (sub-ranges for the `ā` components,
//! positional argument/output bindings per op), and preallocates
//! everything — arena, gradient buffers, binding tables.
//! [`Executor::run_lowered`] then replays the steps through the
//! backend's in-place entry points: the hot loop touches no allocator,
//! no string-keyed registry, and no per-op ledger — the plan's
//! `peak_bytes` (byte-identical to the simulator, and to what the legacy
//! replay's ledger would have reported) is checked against the memory
//! limit once, up front.
//!
//! Safety of the binding step: an op's argument and output ranges are
//! disjoint by slot-assignment construction (frees happen only after the
//! step), so one pass of `split_at_mut` over the arena hands out all the
//! borrows — no `unsafe`, no copies.

use std::ops::Range;

use anyhow::{ensure, Context, Result};

use super::{Executor, StepResult};
use crate::backend::{Backend, Entry, Outs, Scratch, StageExecutable, Tensor};
use crate::plan::{self, ExecPlan, Item, ValueId};
use crate::solver::{Op, Schedule};
use crate::telemetry::{self, drift::op_kind, OpKind};

/// Max positional args of any entry (attn/bwd has 16).
const MAX_ARGS: usize = 24;
/// Max outputs of any entry (attn/fwd_all has 8).
const MAX_OUTS: usize = 12;

/// The persistent storage of a lowered executor: one arena holding every
/// slot, plus the kernels' recycled temporaries.
struct BufferPool {
    data: Vec<f32>,
    /// Reusable sort buffer for the per-op borrow walk
    /// (start, end, is_out, position).
    walk: Vec<(usize, usize, bool, usize)>,
}

/// One op with all its bindings pre-resolved to arena element ranges.
struct RtStep {
    /// 0-based stage index (`ℓ-1`).
    stage: usize,
    entry: Entry,
    /// Leading args `0..n_params` come from the stage's parameter store.
    n_params: usize,
    /// Remaining args: (position, arena range).
    pool_args: Vec<(usize, Range<usize>)>,
    n_args: usize,
    /// Pool outputs: (position, arena range).
    pool_outs: Vec<(usize, Range<usize>)>,
    n_outs: usize,
    /// Outputs `1..` are the stage's gradient buffers (backward ops of
    /// stages with trainable params).
    grads: bool,
    /// Read the loss scalar at this arena index after the step
    /// (`Fall^{L+1}`).
    read_loss: Option<usize>,
    /// Telemetry, resolved at lower time so the hot loop only copies:
    /// schedule-op kind, 1-based stage, bytes the output materializes.
    kind: OpKind,
    op_stage: u32,
    out_bytes: u64,
}

/// A schedule lowered against one executor: the [`ExecPlan`], the pooled
/// arena it addresses, and the per-op runtime bindings. Owned by the
/// caller and reused across iterations — that persistence is where the
/// zero-allocation property comes from.
pub struct Lowered {
    plan: ExecPlan,
    pool: BufferPool,
    scratch: Scratch,
    steps: Vec<RtStep>,
    input_range: Range<usize>,
    seed_range: Range<usize>,
    delta0_range: Range<usize>,
    /// Forward steps beyond the minimum `L+1` (plan-time constant; added
    /// to the registry once per replay).
    recomputed_forwards: u64,
}

impl Lowered {
    /// The compiled plan (slot table, liveness, plan-time peak).
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Arena size in f32 elements (the one allocation the pool owns).
    pub fn arena_elems(&self) -> usize {
        self.pool.data.len()
    }

    /// `δ^0` of the last replay (gradient w.r.t. the chain input).
    /// Allocates the returned vector — not a hot-path call.
    pub fn input_gradient(&self) -> Vec<f32> {
        self.pool.data[self.delta0_range.clone()].to_vec()
    }
}

/// Hand out the borrows one op needs from the arena: `pool_args` as
/// shared slices, `pool_outs` as mutable slices — all in one ordered
/// `split_at_mut` walk (ranges are disjoint by plan construction; an
/// overlap is an internal error, not UB).
fn bind<'a>(
    data: &'a mut [f32],
    walk: &mut Vec<(usize, usize, bool, usize)>,
    step: &RtStep,
    args: &mut [&'a [f32]],
    outs: &mut [Option<&'a mut [f32]>],
) -> Result<()> {
    walk.clear();
    for (pos, r) in &step.pool_args {
        walk.push((r.start, r.end, false, *pos));
    }
    for (pos, r) in &step.pool_outs {
        walk.push((r.start, r.end, true, *pos));
    }
    walk.sort_unstable();
    let mut rest = data;
    let mut base = 0usize;
    for &(s, e, is_out, pos) in walk.iter() {
        ensure!(
            s >= base,
            "lowered plan bound overlapping arena ranges ({s}..{e} after {base}) — internal error"
        );
        let tail = std::mem::take(&mut rest);
        let (_, r) = tail.split_at_mut(s - base);
        let (seg, r2) = r.split_at_mut(e - s);
        rest = r2;
        base = e;
        if is_out {
            outs[pos] = Some(seg);
        } else {
            args[pos] = seg;
        }
    }
    Ok(())
}

/// The binding loop below assumes the *chain* lowering's read layout:
/// forwards read exactly `[a^{ℓ-1}]`, backwards exactly `[a^{ℓ-1}, ā^ℓ,
/// δ^ℓ]`. [`plan::lower_graph`] emits variable-arity `[preds…, ā, δ]`
/// rows instead — a multi-predecessor backward would put a second
/// activation where this executor expects the tape, silently corrupting
/// the replay. Executing those needs multi-input kernels no backend has,
/// so graph-shaped plans are rejected here with a clear error; graph
/// presets execute through their fused chain (see `plan_parity.rs`).
fn ensure_chain_read_layout(plan: &ExecPlan) -> Result<()> {
    for (i, s) in plan.steps.iter().enumerate() {
        match s.op {
            Op::FwdNoSave(_) | Op::FwdCk(_) | Op::FwdAll(_) => ensure!(
                s.reads.len() == 1,
                "step {i} ({}): {} activation reads — not a chain-lowered plan; \
                 graph plans do not execute, solve the fused chain instead",
                s.op,
                s.reads.len()
            ),
            Op::Bwd(_) => ensure!(
                s.reads.len() == 3
                    && matches!(plan.values[s.reads[1]].item, Item::Abar(_))
                    && matches!(plan.values[s.reads[2]].item, Item::Delta(_)),
                "step {i} ({}): backward reads are not [a, ā, δ] — not a \
                 chain-lowered plan; graph plans do not execute, solve the \
                 fused chain instead",
                s.op
            ),
            Op::DropA(_) => {}
        }
    }
    Ok(())
}

impl<'rt, B: Backend> Executor<'rt, B> {
    /// Compile `schedule` into a [`Lowered`] replay bound to this
    /// executor's stages: plan lowering (liveness + slots + plan-time
    /// peak), arena layout from the manifest's real tensor shapes, and
    /// per-op argument bindings. Requires a backend with in-place
    /// kernels ([`Backend::SUPPORTS_LOWERED`]).
    pub fn lower(&mut self, schedule: &Schedule) -> Result<Lowered> {
        ensure!(
            B::SUPPORTS_LOWERED,
            "the {} backend has no in-place kernels — lowered execution runs on `native`",
            self.rt.backend.name()
        );
        let plan = plan::lower(&self.chain_sizes, schedule)
            .map_err(|e| anyhow::anyhow!("schedule does not lower: {e}"))?;
        ensure_chain_read_layout(&plan)?;
        let mf = &self.rt.manifest;
        let n = mf.stages.len();
        debug_assert_eq!(plan.chain_len, n);
        let input_elems: usize = mf.input_shape.iter().product::<usize>().max(1);
        let a_elems = |l: usize| -> usize {
            if l == 0 {
                input_elems
            } else {
                mf.sig_of(l - 1).out_shape.iter().product::<usize>().max(1)
            }
        };
        let abar_elems = |l: usize| -> usize {
            a_elems(l) + mf.sig_of(l - 1).abar_extras.iter().map(|e| e.nelem()).sum::<usize>()
        };
        let item_elems = |item: Item| -> usize {
            match item {
                // δ^ℓ has its activation's shape (δ^{L+1} = the scalar
                // loss seed, one element, like a^{L+1})
                Item::A(l) | Item::Delta(l) => a_elems(l as usize),
                Item::Abar(l) => abar_elems(l as usize),
                // transients are the kernels' Scratch, not arena slots
                Item::Transient(_) => 0,
            }
        };

        // slot → element range: a slot is as big as its largest occupant
        let mut slot_elems = vec![0usize; plan.slots.len()];
        for v in &plan.values {
            slot_elems[v.slot] = slot_elems[v.slot].max(item_elems(v.item));
        }
        let mut slot_off = vec![0usize; plan.slots.len()];
        let mut total = 0usize;
        for (s, &e) in slot_elems.iter().enumerate() {
            slot_off[s] = total;
            total += e;
        }
        let value_ranges: Vec<Range<usize>> = plan
            .values
            .iter()
            .map(|v| {
                let o = slot_off[v.slot];
                o..o + item_elems(v.item)
            })
            .collect();
        // reading a^ℓ out of a taped ā^ℓ means its leading component
        let read_a_range = |vid: ValueId| -> Range<usize> {
            let v = &plan.values[vid];
            match v.item {
                Item::Abar(l) => {
                    let st = value_ranges[vid].start;
                    st..st + a_elems(l as usize)
                }
                _ => value_ranges[vid].clone(),
            }
        };

        let mut steps = Vec::with_capacity(plan.steps.len());
        for pstep in &plan.steps {
            let kind = op_kind(pstep.op);
            let op_stage = super::op_stage(pstep.op);
            let out_bytes = super::op_bytes(&self.chain_sizes, pstep.op);
            match pstep.op {
                // drops are pure liveness events — nothing to execute
                Op::DropA(_) => {}
                Op::FwdNoSave(l) | Op::FwdCk(l) => {
                    let l = l as usize;
                    let n_params = mf.sig_of(l - 1).params.len();
                    steps.push(RtStep {
                        stage: l - 1,
                        entry: Entry::Fwd,
                        n_params,
                        pool_args: vec![(n_params, read_a_range(pstep.reads[0]))],
                        n_args: n_params + 1,
                        pool_outs: vec![(0, value_ranges[pstep.writes[0]].clone())],
                        n_outs: 1,
                        grads: false,
                        read_loss: None,
                        kind,
                        op_stage,
                        out_bytes,
                    });
                }
                Op::FwdAll(l) => {
                    let l = l as usize;
                    let sig = mf.sig_of(l - 1);
                    let n_params = sig.params.len();
                    // the ā slot holds (a_out, extras…) back to back —
                    // each fwd_all output lands in its own sub-range
                    let vr = value_ranges[pstep.writes[0]].clone();
                    let mut pool_outs = Vec::with_capacity(1 + sig.abar_extras.len());
                    let mut off = vr.start;
                    pool_outs.push((0, off..off + a_elems(l)));
                    off += a_elems(l);
                    for (j, e) in sig.abar_extras.iter().enumerate() {
                        pool_outs.push((j + 1, off..off + e.nelem()));
                        off += e.nelem();
                    }
                    debug_assert_eq!(off, vr.end, "ā layout mismatch for stage {l}");
                    let read_loss = if l == n { Some(vr.start) } else { None };
                    steps.push(RtStep {
                        stage: l - 1,
                        entry: Entry::FwdAll,
                        n_params,
                        pool_args: vec![(n_params, read_a_range(pstep.reads[0]))],
                        n_args: n_params + 1,
                        n_outs: pool_outs.len(),
                        pool_outs,
                        grads: false,
                        read_loss,
                        kind,
                        op_stage,
                        out_bytes,
                    });
                }
                Op::Bwd(l) => {
                    let l = l as usize;
                    let sig = mf.sig_of(l - 1);
                    let n_params = sig.params.len();
                    // (θ…, a_in, ā…, δ_out) — reads are [a^{ℓ-1}, ā^ℓ, δ^ℓ]
                    let mut pool_args = Vec::with_capacity(3 + sig.abar_extras.len());
                    pool_args.push((n_params, read_a_range(pstep.reads[0])));
                    let abar_vr = value_ranges[pstep.reads[1]].clone();
                    let mut pos = n_params + 1;
                    let mut off = abar_vr.start;
                    pool_args.push((pos, off..off + a_elems(l)));
                    pos += 1;
                    off += a_elems(l);
                    for e in &sig.abar_extras {
                        pool_args.push((pos, off..off + e.nelem()));
                        pos += 1;
                        off += e.nelem();
                    }
                    debug_assert_eq!(off, abar_vr.end, "ā layout mismatch for stage {l}");
                    pool_args.push((pos, value_ranges[pstep.reads[2]].clone()));
                    pos += 1;
                    steps.push(RtStep {
                        stage: l - 1,
                        entry: Entry::Bwd,
                        n_params,
                        pool_args,
                        n_args: pos,
                        pool_outs: vec![(0, value_ranges[pstep.writes[0]].clone())],
                        n_outs: 1 + sig.n_grads,
                        grads: sig.n_grads > 0,
                        read_loss: None,
                        kind,
                        op_stage,
                        out_bytes,
                    });
                }
            }
        }
        debug_assert_eq!(
            steps.len(),
            plan.steps.iter().filter(|s| s.op.is_compute()).count(),
            "every compute op binds exactly one runtime step"
        );
        ensure!(
            steps.iter().any(|s| s.read_loss.is_some()),
            "schedule never tapes the loss stage (no Fall^{n})"
        );
        for s in &steps {
            ensure!(
                s.n_args <= MAX_ARGS && s.n_outs <= MAX_OUTS,
                "stage {} entry exceeds the binding arrays ({} args / {} outs)",
                s.stage + 1,
                s.n_args,
                s.n_outs
            );
        }
        self.ensure_grad_buffers();
        let fwd_steps = steps.iter().filter(|s| s.kind.is_forward()).count() as u64;
        telemetry::registry()
            .exec_arena_high_watermark_bytes
            .record_max((total * std::mem::size_of::<f32>()) as u64);
        Ok(Lowered {
            input_range: value_ranges[plan.input].clone(),
            seed_range: value_ranges[plan.seed].clone(),
            delta0_range: value_ranges[plan.delta0].clone(),
            plan,
            pool: BufferPool { data: vec![0.0; total], walk: Vec::new() },
            scratch: Scratch::new(),
            steps,
            recomputed_forwards: fwd_steps.saturating_sub(n as u64),
        })
    }

    /// Size the per-stage gradient buffers so backward kernels write
    /// them in place (only allocates when shapes are wrong — i.e. on the
    /// first call or after an interleaved legacy replay).
    fn ensure_grad_buffers(&mut self) {
        for (i, p) in self.params.iter().enumerate() {
            let g = &mut self.grads[i];
            if g.len() != p.trainable.len() {
                *g = p.trainable.iter().map(|&pi| vec![0.0; p.values[pi].len()]).collect();
                continue;
            }
            for (j, &pi) in p.trainable.iter().enumerate() {
                if g[j].len() != p.values[pi].len() {
                    g[j] = vec![0.0; p.values[pi].len()];
                }
            }
        }
    }

    /// One training iteration over the lowered plan: stage the input and
    /// the δ^{L+1} seed into the arena, replay every step through the
    /// backend's in-place entries, and read the loss out of the `ā^{L+1}`
    /// slot. The steady-state hot path performs **zero heap
    /// allocations** — everything it touches (arena, scratch, gradient
    /// buffers, binding tables) persists inside `low` and `self`.
    ///
    /// The reported peak is the plan's — byte-identical to both the
    /// simulator and the legacy replay's ledger; `memory_limit` is
    /// enforced against it up front.
    pub fn run_lowered(
        &mut self,
        low: &mut Lowered,
        input: &B::Tensor,
        memory_limit: Option<u64>,
    ) -> Result<StepResult> {
        let start = std::time::Instant::now();
        if let Some(limit) = memory_limit {
            ensure!(
                low.plan.peak_bytes <= limit,
                "memory limit exceeded (peak {} > budget {limit})",
                low.plan.peak_bytes
            );
        }
        self.ensure_grad_buffers();
        self.grads_valid = false;
        input
            .read_into(&mut low.pool.data[low.input_range.clone()])
            .context("staging a^0 into the arena")?;
        low.pool.data[low.seed_range.clone()].fill(1.0); // δ^{L+1} = 1

        let mut loss = f32::NAN;
        let reg = telemetry::registry();
        let Executor { exes, params, grads, .. } = self;
        for st in low.steps.iter() {
            let op_t0 = std::time::Instant::now();
            {
                let mut args_store: [&[f32]; MAX_ARGS] = [&[]; MAX_ARGS];
                let mut outs_store: [Option<&mut [f32]>; MAX_OUTS] =
                    std::array::from_fn(|_| None);
                let BufferPool { data, walk } = &mut low.pool;
                bind(data, walk, st, &mut args_store[..st.n_args], &mut outs_store[..st.n_outs])?;
                for (i, v) in params[st.stage].values.iter().enumerate().take(st.n_params) {
                    args_store[i] = v.as_slice();
                }
                if st.grads {
                    for (j, gbuf) in grads[st.stage].iter_mut().enumerate() {
                        outs_store[1 + j] = Some(gbuf.as_mut_slice());
                    }
                }
                let mut outs = Outs::new(&mut outs_store[..st.n_outs]);
                exes[st.stage]
                    .entry_into(st.entry, &args_store[..st.n_args], &mut outs, &mut low.scratch)
                    .with_context(|| {
                        format!("lowered {:?} on stage {}", st.entry, st.stage + 1)
                    })?;
            }
            if let Some(ix) = st.read_loss {
                loss = low.pool.data[ix];
            }
            // Instrumentation stays allocation-free: two Instant reads
            // plus relaxed atomic adds; a disabled tracer costs one
            // relaxed load (the executor bench gates this at ≤1.05×).
            let op_t1 = std::time::Instant::now();
            reg.record_op(st.kind, op_t1.duration_since(op_t0).as_nanos() as u64);
            if telemetry::trace_enabled() {
                telemetry::trace_record(
                    st.kind.label(),
                    st.op_stage,
                    op_t0,
                    op_t1,
                    st.out_bytes,
                );
            }
        }
        ensure!(loss.is_finite(), "loss stage produced a non-finite loss");
        self.grads_valid = true;
        reg.exec_runs.inc();
        reg.exec_recomputed_forwards.add(low.recomputed_forwards);
        reg.exec_peak_bytes.record_max(low.plan.peak_bytes);
        Ok(StepResult {
            loss,
            peak_bytes: low.plan.peak_bytes,
            elapsed_s: start.elapsed().as_secs_f64(),
            ops: low.plan.steps.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphSpec, Node};
    use crate::plan::lower_graph;
    use crate::solver::store_all_schedule;

    #[test]
    fn chain_layout_check_rejects_multi_predecessor_graph_plans() {
        let g = GraphSpec::new(
            "diamond",
            vec![
                Node::new("a", 1.0, 2.0, 100, 120),
                Node::new("b", 1.0, 2.0, 80, 90),
                Node::new("c", 1.0, 2.0, 60, 60),
                Node::new("loss", 0.5, 0.5, 4, 4),
            ],
            vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            32,
        )
        .unwrap();
        // node c's backward reads two predecessors → 4 reads, not [a, ā, δ]
        let plan = lower_graph(&g, &store_all_schedule(&g.to_chain())).unwrap();
        let err = ensure_chain_read_layout(&plan).unwrap_err();
        assert!(err.to_string().contains("not a chain-lowered plan"), "{err}");
        // …while every chain lowering passes the same gate
        let chain = g.node_chain();
        let chain_plan = plan::lower(&chain, &store_all_schedule(&chain)).unwrap();
        ensure_chain_read_layout(&chain_plan).unwrap();
    }
}
