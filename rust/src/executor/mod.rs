//! Real schedule execution over any tensor backend.
//!
//! The executor replays a [`Schedule`] with *exactly* the simulator's
//! Table 1 semantics, but against live tensors: it holds the value store
//! (`a^ℓ` / `ā^ℓ` / `δ^ℓ` tensors), charges every allocation to a logical
//! [`MemState`] ledger (enforcing the byte budget the schedule was solved
//! for — a CPU host has no GPU-style OOM to do it for us), collects the
//! per-stage gradients produced by the `B^ℓ` ops and captures the loss.
//!
//! One [`Executor::run`] call = one training iteration of the paper's
//! processing phase. The replay loop is generic over [`Backend`] and
//! passes `&B::Tensor` references throughout — no tensor copies besides
//! what the engine itself does; which engine (pure-Rust [`native`],
//! PJRT [`pjrt`]) is a type parameter resolved at compile time.
//!
//! Two replay paths share this executor:
//!
//! * [`Executor::run`] — the legacy per-op replay: tensors allocated op
//!   by op over `Vec<Option<Tensor>>` stores, a [`MemState`] ledger
//!   walked alongside. Runs on any backend; the reference for parity.
//! * [`Executor::lower`] + [`Executor::run_lowered`] — the lowered path:
//!   the schedule is compiled once into a [`crate::plan::ExecPlan`]
//!   (liveness → explicit frees → arena slots), then replayed over a
//!   persistent [`Lowered`] buffer pool through the backend's in-place
//!   kernels — **zero heap allocations** in the steady-state loop, and
//!   the plan-time peak replaces the per-iteration ledger walk.
//!
//! For one measured replay (fresh executor, warmup + timed median) use
//! the facade's [`crate::api::execute_schedule`] / `Plan::execute` —
//! that is the path `chainckpt compare` and the executor bench drive.
//!
//! [`native`]: crate::backend::native
//! [`pjrt`]: crate::backend::pjrt

mod lowered;
mod params;

pub use lowered::Lowered;
pub use params::StageParams;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::{Backend, StageExecutable, Tensor};
use crate::chain::Chain;
use crate::runtime::Runtime;
use crate::simulator::MemState;
use crate::solver::{Op, Schedule};
use crate::telemetry::{self, drift::op_kind};
use crate::util::Rng;

/// The 1-based stage an op addresses.
fn op_stage(op: Op) -> u32 {
    match op {
        Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) | Op::Bwd(l) | Op::DropA(l) => l,
    }
}

/// Bytes the op materializes (its output value) per the chain size
/// model — what a trace span reports as `args.bytes`.
fn op_bytes(chain: &Chain, op: Op) -> u64 {
    match op {
        Op::FwdNoSave(l) | Op::FwdCk(l) => chain.wa(l as usize),
        Op::FwdAll(l) => chain.wabar(l as usize),
        Op::Bwd(l) => chain.wdelta(l as usize - 1),
        Op::DropA(_) => 0,
    }
}

/// Outcome of one executed iteration.
#[derive(Debug)]
pub struct StepResult {
    pub loss: f32,
    /// Peak bytes charged to the ledger (activations + transients).
    pub peak_bytes: u64,
    /// Wall-clock of the schedule replay, seconds.
    pub elapsed_s: f64,
    /// Ops executed.
    pub ops: usize,
}

pub struct Executor<'rt, B: Backend> {
    rt: &'rt Runtime<B>,
    /// Pre-resolved executable per stage — the hot loop never touches the
    /// string-keyed registry.
    exes: Vec<&'rt B::Stage>,
    /// Per-stage parameters (stage order; independent even when stages
    /// share a signature).
    pub params: Vec<StageParams<B::Tensor>>,
    /// Size model used by the ledger (timings unused here).
    pub chain_sizes: Chain,
    /// Gradients from the last iteration, per stage (trainable order).
    /// The lowered path writes these in place (buffers persist across
    /// iterations); `grads_valid` gates [`Executor::sgd_step`].
    grads: Vec<Vec<Vec<f32>>>,
    grads_valid: bool,
    // value store, 1-based stage indexing like the simulator
    a: Vec<Option<B::Tensor>>,
    abar: Vec<Option<Vec<B::Tensor>>>,
    delta: Vec<Option<B::Tensor>>,
}

/// Borrow `a^ℓ`: standalone tensor preferred, else the head of `ā^ℓ`.
fn read_a<'s, T>(a: &'s [Option<T>], abar: &'s [Option<Vec<T>>], l: usize) -> Option<&'s T> {
    if let Some(t) = a[l].as_ref() {
        return Some(t);
    }
    if l >= 1 {
        if let Some(vals) = abar[l - 1].as_ref() {
            return Some(&vals[0]);
        }
    }
    None
}

impl<'rt, B: Backend> Executor<'rt, B> {
    pub fn new(rt: &'rt Runtime<B>, seed: u64) -> Result<Self> {
        let mut rng = Rng::new(seed);
        let mut params = Vec::new();
        for (i, _st) in rt.manifest.stages.iter().enumerate() {
            let mut stream = rng.split(i as u64);
            params.push(StageParams::init(rt.manifest.sig_of(i), &mut stream)?);
        }
        let n = rt.manifest.stages.len();
        let mut exes = Vec::with_capacity(n);
        for i in 0..n {
            exes.push(rt.executable(&rt.manifest.stages[i].sig)?);
        }
        // ledger sizes from the manifest; timings are irrelevant here
        let uf = vec![0.0; n];
        let chain_sizes = rt.manifest.to_chain(&uf, &uf);
        Ok(Executor {
            rt,
            exes,
            params,
            chain_sizes,
            grads: vec![Vec::new(); n],
            grads_valid: false,
            a: vec![None; n + 1],
            abar: vec![None; n],
            delta: vec![None; n + 1],
        })
    }

    /// Number of stages `L+1`.
    pub fn n_stages(&self) -> usize {
        self.rt.manifest.stages.len()
    }

    /// Set a `data` param (the loss stage's target) before an iteration.
    pub fn set_data_param(&mut self, stage: usize, data: &[f32]) -> Result<()> {
        let sig = self.rt.manifest.sig_of(stage);
        let idx = sig
            .params
            .iter()
            .position(|p| p.is_data())
            .with_context(|| format!("stage {stage} has no data param"))?;
        self.params[stage].set_data(idx, data)
    }

    /// Gradients of the last iteration for stage `i` (0-based), in the
    /// bwd entry's output order (trainable params only).
    pub fn grads(&self, stage: usize) -> &[Vec<f32>] {
        &self.grads[stage]
    }

    /// Apply SGD to every stage with the last iteration's gradients.
    /// The gradient buffers stay allocated (the lowered path rewrites
    /// them in place next iteration); `grads_valid` prevents applying
    /// the same gradients twice.
    pub fn sgd_step(&mut self, lr: f32) -> Result<()> {
        if !self.grads_valid {
            bail!("no fresh gradients recorded — run an iteration first");
        }
        for (i, params) in self.params.iter_mut().enumerate() {
            let grads = &self.grads[i];
            if grads.len() != params.trainable.len() {
                bail!(
                    "stage {i}: {} gradients recorded, expected {} — run an iteration first",
                    grads.len(),
                    params.trainable.len()
                );
            }
            params.sgd_step(grads, lr)?;
        }
        self.grads_valid = false;
        Ok(())
    }

    /// Run one iteration: places `input` as `a^0`, seeds `δ^{L+1} = 1`,
    /// replays the schedule, enforces `memory_limit` (if any) on the
    /// ledger, and returns the loss.
    pub fn run(
        &mut self,
        schedule: &Schedule,
        input: &B::Tensor,
        memory_limit: Option<u64>,
    ) -> Result<StepResult> {
        let n = self.n_stages();
        let start = std::time::Instant::now();

        // reset the value store and ledger
        self.a.iter_mut().for_each(|x| *x = None);
        self.abar.iter_mut().for_each(|x| *x = None);
        self.delta.iter_mut().for_each(|x| *x = None);
        for g in &mut self.grads {
            g.clear();
        }
        self.grads_valid = false;
        self.a[0] = Some(input.clone());
        self.delta[n] = Some(B::Tensor::scalar(1.0));
        let mut ledger = MemState::initial(&self.chain_sizes);
        let mut loss = f32::NAN;

        let reg = telemetry::registry();
        let mut fwd_ops = 0u64;

        for (oi, &op) in schedule.ops.iter().enumerate() {
            let op_t0 = std::time::Instant::now();
            match op {
                Op::FwdNoSave(l) | Op::FwdCk(l) => {
                    let l = l as usize;
                    let mut out = {
                        let a_in = read_a(&self.a, &self.abar, l - 1)
                            .with_context(|| format!("op #{oi} {op}: a^{} missing", l - 1))?;
                        let mut args: Vec<&B::Tensor> =
                            self.params[l - 1].tensors.iter().collect();
                        args.push(a_in);
                        self.exes[l - 1]
                            .fwd(&args)
                            .with_context(|| format!("op #{oi} {op}"))?
                    };
                    ledger.touch_peak(self.chain_sizes.wa(l) + self.chain_sizes.of(l));
                    ensure!(self.a[l].is_none(), "op #{oi} {op}: a^{l} already stored");
                    self.a[l] = Some(out.swap_remove(0));
                    ledger.store_a(l).map_err(anyhow::Error::msg)?;
                    if matches!(op, Op::FwdNoSave(_)) {
                        self.a[l - 1] = None;
                        ledger.free_a_if_standalone(l - 1);
                    }
                    self.check_limit(&ledger, memory_limit, oi)?;
                }
                Op::FwdAll(l) => {
                    let l = l as usize;
                    let out = {
                        let a_in = read_a(&self.a, &self.abar, l - 1)
                            .with_context(|| format!("op #{oi} {op}: a^{} missing", l - 1))?;
                        let mut args: Vec<&B::Tensor> =
                            self.params[l - 1].tensors.iter().collect();
                        args.push(a_in);
                        self.exes[l - 1]
                            .fwd_all(&args)
                            .with_context(|| format!("op #{oi} {op}"))?
                    };
                    ledger.touch_peak(self.chain_sizes.wabar(l) + self.chain_sizes.of(l));
                    ensure!(self.abar[l - 1].is_none(), "op #{oi} {op}: ā^{l} already stored");
                    if l == n {
                        // the loss stage's a_out is the loss scalar
                        loss = out[0].to_vec()?[0];
                    }
                    self.abar[l - 1] = Some(out);
                    ledger.store_abar(l).map_err(anyhow::Error::msg)?;
                    self.check_limit(&ledger, memory_limit, oi)?;
                }
                Op::Bwd(l) => {
                    let l = l as usize;
                    let delta_out = self.delta[l]
                        .take()
                        .with_context(|| format!("op #{oi} {op}: δ^{l} missing"))?;
                    let abar = self.abar[l - 1]
                        .take()
                        .with_context(|| format!("op #{oi} {op}: ā^{l} missing"))?;
                    let mut out = {
                        let a_in = read_a(&self.a, &self.abar, l - 1)
                            .with_context(|| format!("op #{oi} {op}: a^{} missing", l - 1))?;
                        let mut args: Vec<&B::Tensor> =
                            self.params[l - 1].tensors.iter().collect();
                        args.push(a_in);
                        args.extend(abar.iter());
                        args.push(&delta_out);
                        self.exes[l - 1]
                            .bwd(&args)
                            .with_context(|| format!("op #{oi} {op}"))?
                    };
                    // ledger: δ^{ℓ-1} replaces a^{ℓ-1} (see simulator::Bwd)
                    ledger.touch_peak(self.chain_sizes.ob(l));
                    ensure!(
                        self.delta[l - 1].is_none(),
                        "op #{oi} {op}: δ^{} already stored",
                        l - 1
                    );
                    let delta_in = out.remove(0);
                    self.grads[l - 1] = out
                        .iter()
                        .map(Tensor::to_vec)
                        .collect::<Result<Vec<_>>>()
                        .with_context(|| format!("op #{oi} {op}: extracting grads"))?;
                    self.delta[l - 1] = Some(delta_in);
                    ledger.free_delta(l);
                    ledger.free_abar(l);
                    self.a[l - 1] = None;
                    ledger.free_a_if_standalone(l - 1);
                    ledger.store_delta(l - 1).map_err(anyhow::Error::msg)?;
                    self.check_limit(&ledger, memory_limit, oi)?;
                }
                Op::DropA(l) => {
                    let l = l as usize;
                    ensure!(self.a[l].is_some(), "op #{oi} {op}: a^{l} not resident");
                    self.a[l] = None;
                    ledger.free_a_if_standalone(l);
                }
            }
            let kind = op_kind(op);
            let op_t1 = std::time::Instant::now();
            reg.record_op(kind, op_t1.duration_since(op_t0).as_nanos() as u64);
            if kind.is_forward() {
                fwd_ops += 1;
            }
            if telemetry::trace_enabled() {
                telemetry::trace_record(
                    kind.label(),
                    op_stage(op),
                    op_t0,
                    op_t1,
                    op_bytes(&self.chain_sizes, op),
                );
            }
        }

        ensure!(self.delta[0].is_some(), "schedule ended without δ^0");
        ensure!(loss.is_finite(), "loss stage never taped (no Fall^{n})");
        self.grads_valid = true;
        reg.exec_runs.inc();
        reg.exec_recomputed_forwards.add(fwd_ops.saturating_sub(n as u64));
        reg.exec_peak_bytes.record_max(ledger.peak);
        Ok(StepResult {
            loss,
            peak_bytes: ledger.peak,
            elapsed_s: start.elapsed().as_secs_f64(),
            ops: schedule.ops.len(),
        })
    }

    fn check_limit(&self, ledger: &MemState, limit: Option<u64>, oi: usize) -> Result<()> {
        if let Some(limit) = limit {
            ensure!(
                ledger.peak <= limit,
                "op #{oi}: memory limit exceeded (peak {} > budget {})",
                ledger.peak,
                limit
            );
        }
        Ok(())
    }

    /// `δ^0` from the last iteration (gradient w.r.t. the chain input).
    pub fn input_gradient(&self) -> Option<Vec<f32>> {
        self.delta[0].as_ref().and_then(|t| t.to_vec().ok())
    }
}
