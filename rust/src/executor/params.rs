//! Per-stage parameter storage: master f32 copies + cached device tensors.
//!
//! Parameters are initialized Rust-side from the manifest's init specs
//! (`xavier`/`zeros`/`ones`), so Python stays out of the runtime path.
//! `data` params (the loss stage's target) are per-batch inputs set by the
//! trainer before each iteration. The tensor cache means the hot loop
//! never re-encodes parameters; it is invalidated by [`StageParams::sgd_step`].

use anyhow::{ensure, Result};

use crate::backend::Tensor;
use crate::chain::manifest::SignatureSpec;
use crate::util::Rng;

pub struct StageParams<T: Tensor> {
    /// Master copies, one per manifest param (data params stay zeroed
    /// until [`StageParams::set_data`]).
    pub values: Vec<Vec<f32>>,
    /// Cached backend tensors fed to every execute call (manifest order).
    pub tensors: Vec<T>,
    /// Indices of trainable (non-data) params, in gradient order.
    pub trainable: Vec<usize>,
    shapes: Vec<Vec<usize>>,
}

impl<T: Tensor> StageParams<T> {
    /// Initialize from the signature's specs with a per-stage RNG stream.
    pub fn init(sig: &SignatureSpec, rng: &mut Rng) -> Result<Self> {
        let mut values = Vec::new();
        let mut tensors = Vec::new();
        let mut trainable = Vec::new();
        let mut shapes = Vec::new();
        for (i, p) in sig.params.iter().enumerate() {
            let n = p.nelem();
            let v: Vec<f32> = match p.init.as_str() {
                "xavier" => {
                    let fan_in = *p.shape.first().unwrap_or(&1);
                    let fan_out = *p.shape.last().unwrap_or(&1);
                    rng.xavier(fan_in, fan_out, n)
                }
                "zeros" => vec![0.0; n],
                "ones" => vec![1.0; n],
                "data" => vec![0.0; n], // placeholder until set_data
                other => anyhow::bail!("unknown init '{other}' for param {}", p.name),
            };
            tensors.push(T::from_vec(&v, &p.shape)?);
            if !p.is_data() {
                trainable.push(i);
            }
            shapes.push(p.shape.clone());
            values.push(v);
        }
        ensure!(trainable.len() == sig.n_grads, "n_grads mismatch vs manifest");
        Ok(StageParams { values, tensors, trainable, shapes })
    }

    /// Replace a `data` param (e.g. the loss target) for this iteration.
    pub fn set_data(&mut self, index: usize, data: &[f32]) -> Result<()> {
        ensure!(
            data.len() == self.values[index].len(),
            "data size mismatch: {} vs {}",
            data.len(),
            self.values[index].len()
        );
        self.values[index].copy_from_slice(data);
        self.tensors[index] = T::from_vec(data, &self.shapes[index])?;
        Ok(())
    }

    /// Plain SGD over the trainable params. `grads[j]` corresponds to
    /// `trainable[j]` (the bwd artifact's output order).
    pub fn sgd_step(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<()> {
        ensure!(grads.len() == self.trainable.len(), "gradient count mismatch");
        for (j, &pi) in self.trainable.iter().enumerate() {
            let p = &mut self.values[pi];
            let g = &grads[j];
            ensure!(g.len() == p.len(), "gradient size mismatch for param {pi}");
            for (w, gi) in p.iter_mut().zip(g) {
                *w -= lr * gi;
            }
            self.tensors[pi] = T::from_vec(p, &self.shapes[pi])?;
        }
        Ok(())
    }

    /// Total trainable scalar count.
    pub fn n_trainable_scalars(&self) -> usize {
        self.trainable.iter().map(|&i| self.values[i].len()).sum()
    }
}
