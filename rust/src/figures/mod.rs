//! Figure/table harness: regenerates every evaluation artifact of the
//! paper's §5.4 (Figures 3–13 + the 17.2 % summary) as CSV series.
//!
//! Each figure is a set of panels (network, image size, batch size); each
//! panel holds throughput-vs-peak-memory points for the four strategies:
//!
//! * `pytorch`    — store-all; one point (absent if it exceeds the device).
//! * `sequential` — `checkpoint_sequential` over the paper's segment sweep.
//! * `revolve`    — heterogeneous-AD optimum, 10 memory limits.
//! * `optimal`    — this paper's DP, the same 10 memory limits.
//!
//! Timings come from the [`profiles`] V100 roofline; every point is
//! produced by *simulating the actual schedule* (never the solver's claim
//! alone), so the plots inherit the simulator's validity guarantees.
//!
//! DP cost: each panel's 10-budget optimal/revolve sweep is served by one
//! [`api::Plan`](crate::api::Plan) per mode — one table fill per
//! `(chain, mode)` instead of one per budget, and chains repeated across
//! figures hit the planner's table cache underneath the facade.

use std::fmt::Write as _;

use anyhow::{Context, Result};

use crate::api::{ChainSpec, MemBytes, PlanRequest, SlotCount};
use crate::chain::{profiles, Chain};
use crate::simulator::simulate;
use crate::solver::{
    paper_segment_sweep, periodic_schedule, store_all_schedule, Mode, StrategyKind,
};
use crate::util::fmt_bytes;

/// Memory of the paper's evaluation GPU (V100 16 GB, minus framework
/// overhead — the paper reports 15.75 GB usable).
pub const DEVICE_MEMORY: u64 = (15.75 * (1u64 << 30) as f64) as u64;

/// One plotted point.
#[derive(Debug, Clone)]
pub struct Point {
    /// Which strategy produced the schedule behind this point.
    pub strategy: StrategyKind,
    /// Sweep parameter: segment count (sequential) or memory budget bytes.
    pub param: u64,
    /// Simulated peak memory of the schedule (x axis).
    pub peak_bytes: u64,
    /// Simulated makespan of one iteration, milliseconds.
    pub makespan_ms: f64,
    /// Images per second at the panel's batch size (y axis).
    pub throughput: f64,
}

/// One panel = one (network, image, batch) plot of the paper.
#[derive(Debug, Clone)]
pub struct Panel {
    /// Profile name, e.g. `resnet101-i1000-b8`.
    pub chain_name: String,
    /// Batch size the throughput numbers are computed at.
    pub batch: u64,
    /// All strategy curves, in generation order.
    pub points: Vec<Point>,
    /// Chain length L+1 (reported in the CSV header).
    pub chain_len: usize,
}

/// Discretization used for figure generation. The paper uses S=500 *per
/// budget*; the Planner discretizes once against the sweep's top budget,
/// so a sub-budget point at `hi·i/10` only sees `S·i/10` of the grid.
/// Since one table now serves all 10 budgets (instead of 10 tables), we
/// spend part of that saving on a finer axis — S=800 gives the matched-
/// memory points (the upper half of the sweep, where the §5.4 comparison
/// happens) at least the seed's 400-slot resolution, while the whole
/// panel still costs ~5× less DP time than per-budget solves. Long
/// chains (ResNet-1001) get a coarser axis to keep the full-figure run
/// in CPU-minutes (the schedules stay valid — rounding is conservative).
fn slots_for(chain: &Chain) -> usize {
    if chain.len() > 150 {
        300
    } else {
        800
    }
}

/// Compute the four strategy curves for one chain. `device_memory` bounds
/// which points are *feasible on the paper's GPU* (points above it are
/// dropped, mirroring the paper's OOM squares).
pub fn panel(chain: &Chain, batch: u64, device_memory: u64) -> Panel {
    let mut points = Vec::new();
    let slots = slots_for(chain);

    // pytorch (store-all): a single point, if it fits
    let sa = store_all_schedule(chain);
    if let Ok(rep) = simulate(chain, &sa) {
        if rep.peak_bytes <= device_memory {
            points.push(Point {
                strategy: StrategyKind::StoreAll,
                param: 0,
                peak_bytes: rep.peak_bytes,
                makespan_ms: rep.makespan,
                throughput: batch as f64 / (rep.makespan * 1e-3),
            });
        }
    }

    // sequential: the paper's segment sweep (needs a compute stage
    // before the loss — a 1-stage inline chain has nothing to segment)
    for k in if chain.len() >= 2 { paper_segment_sweep(chain.len() - 1) } else { Vec::new() } {
        let sched = periodic_schedule(chain, k);
        if let Ok(rep) = simulate(chain, &sched) {
            if rep.peak_bytes <= device_memory {
                points.push(Point {
                    strategy: StrategyKind::Periodic,
                    param: k as u64,
                    peak_bytes: rep.peak_bytes,
                    makespan_ms: rep.makespan,
                    throughput: batch as f64 / (rep.makespan * 1e-3),
                });
            }
        }
    }

    // optimal & revolve: 10 memory limits equally spaced up to store-all
    // memory (paper §5.3), clamped to the device. One api::Plan (one DP
    // table) per mode serves the whole sweep: the discretization is taken
    // against the top budget `hi`, so the sub-budget points share its
    // slot grid instead of re-running the DP per budget.
    // a degenerate all-zero-size chain (reachable via inline specs) has
    // hi == 0: no DP point exists, and PlanRequest rejects a 0 budget
    let hi = chain.store_all_memory().min(device_memory);
    let budgets: Vec<MemBytes> = (1..=10u64).map(|i| MemBytes::new(hi * i / 10)).collect();
    for mode in [Mode::Full, Mode::AdRevolve] {
        if hi == 0 {
            break;
        }
        let strategy = match mode {
            Mode::Full => StrategyKind::Optimal,
            Mode::AdRevolve => StrategyKind::Revolve,
        };
        let plan = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes::new(hi))
            .slots(SlotCount::new(slots))
            .mode(mode)
            .plan()
            .expect("an inline chain spec always resolves");
        for (&m, sched) in budgets.iter().zip(plan.sweep(&budgets)) {
            let Some(sched) = sched else { continue };
            let Ok(rep) = simulate(chain, &sched) else { continue };
            debug_assert!(rep.peak_bytes <= m.get(), "{strategy}: sim peak exceeds budget");
            points.push(Point {
                strategy,
                param: m.get(),
                peak_bytes: rep.peak_bytes,
                makespan_ms: rep.makespan,
                throughput: batch as f64 / (rep.makespan * 1e-3),
            });
        }
    }

    Panel { chain_name: chain.name.clone(), batch, points, chain_len: chain.len() }
}

/// Panel spec: (family, depth, image, batch).
pub type PanelSpec = (&'static str, u32, u64, u64);

/// Every figure of the paper, as panel specs. Batch-size grids follow the
/// paper's "powers of two from the smallest with reasonable throughput".
pub fn figure_specs(fig: u32) -> Vec<PanelSpec> {
    let mut v = Vec::new();
    match fig {
        3 => {
            for bs in [1, 2, 4, 8] {
                v.push(("resnet", 101, 1000, bs));
            }
        }
        4 => {
            for bs in [1, 2, 4, 8] {
                v.push(("resnet", 1001, 224, bs));
            }
        }
        5 => {
            // "several situations": representative mixed selection
            v.push(("resnet", 152, 500, 4));
            v.push(("resnet", 50, 500, 16));
            v.push(("densenet", 169, 224, 16));
            v.push(("densenet", 121, 500, 8));
            v.push(("inception", 0, 500, 8));
            v.push(("vgg", 0, 500, 8));
        }
        6 => {
            for d in [18, 34, 50, 101, 152, 200] {
                for bs in [16, 32] {
                    v.push(("resnet", d, 224, bs));
                }
            }
        }
        7 => {
            for d in [18, 34, 50, 101, 152, 200] {
                for bs in [4, 8] {
                    v.push(("resnet", d, 500, bs));
                }
            }
        }
        8 => {
            for d in [18, 34, 50, 101, 152] {
                for bs in [1, 2, 4] {
                    v.push(("resnet", d, 1000, bs));
                }
            }
        }
        9 => {
            for d in [121, 161, 169, 201] {
                for bs in [16, 32] {
                    v.push(("densenet", d, 224, bs));
                }
            }
        }
        10 => {
            for d in [121, 161, 169, 201] {
                for bs in [4, 8] {
                    v.push(("densenet", d, 500, bs));
                }
            }
        }
        11 => {
            for d in [121, 161, 169, 201] {
                for bs in [1, 2] {
                    v.push(("densenet", d, 1000, bs));
                }
            }
        }
        12 => {
            for (img, bss) in [(224u64, [16u64, 32]), (500, [4, 8]), (1000, [1, 2])] {
                for bs in bss {
                    v.push(("inception", 0, img, bs));
                }
            }
        }
        13 => {
            let grids: [(u64, &[u64]); 3] =
                [(224, &[1, 2, 4, 8]), (500, &[1, 2]), (1000, &[1, 2])];
            for (img, bss) in grids {
                for &bs in bss {
                    v.push(("resnet", 1001, img, bs));
                }
            }
        }
        f => panic!("unknown figure {f} (paper has figures 3..=13)"),
    }
    v
}

/// Generate all panels of one figure.
pub fn figure(fig: u32) -> Vec<Panel> {
    figure_specs(fig)
        .into_iter()
        .map(|(family, depth, image, batch)| {
            let chain = profiles::by_name(family, depth, image, batch);
            panel(&chain, batch, DEVICE_MEMORY)
        })
        .collect()
}

/// CSV serialization of panels (one file per figure).
pub fn to_csv(panels: &[Panel]) -> String {
    let mut s = String::from("chain,chain_len,batch,strategy,param,peak_bytes,peak_gib,makespan_ms,throughput_img_s\n");
    for p in panels {
        for pt in &p.points {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{},{:.4},{:.3},{:.3}",
                p.chain_name,
                p.chain_len,
                p.batch,
                pt.strategy,
                pt.param,
                pt.peak_bytes,
                pt.peak_bytes as f64 / (1u64 << 30) as f64,
                pt.makespan_ms,
                pt.throughput
            );
        }
    }
    s
}

/// The paper's §5.4 headline: ratio of `optimal` throughput to the *best*
/// `sequential` throughput, with optimal restricted to at most the memory
/// the best sequential point used. Returns `(gain, best_seq, matched_opt)`;
/// when a curve is missing (every point of a strategy was infeasible on
/// the device) the error names the panel and the budget that failed, so a
/// sweep over many panels can report *which* configuration fell off the
/// figure instead of panicking.
pub fn optimal_vs_sequential(panel: &Panel) -> Result<(f64, f64, f64)> {
    let best_seq = panel
        .points
        .iter()
        .filter(|p| p.strategy == StrategyKind::Periodic)
        .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
        .with_context(|| {
            format!(
                "panel {} (batch {}): no feasible sequential point — every segment count \
                 exceeded the device memory ({})",
                panel.chain_name,
                panel.batch,
                fmt_bytes(DEVICE_MEMORY)
            )
        })?;
    let opt = panel
        .points
        .iter()
        .filter(|p| p.strategy == StrategyKind::Optimal)
        .filter(|p| p.peak_bytes <= best_seq.peak_bytes)
        .max_by(|a, b| a.throughput.total_cmp(&b.throughput))
        .with_context(|| {
            format!(
                "panel {} (batch {}): no optimal point within the best sequential peak \
                 ({}) — every optimal budget at or below it was infeasible",
                panel.chain_name,
                panel.batch,
                fmt_bytes(best_seq.peak_bytes)
            )
        })?;
    Ok((
        opt.throughput / best_seq.throughput - 1.0,
        best_seq.throughput,
        opt.throughput,
    ))
}

/// Summary over a set of panels: average percentage gain (paper: 17.2 %).
/// Panels with a missing curve are skipped (their per-panel reason is
/// available via [`optimal_vs_sequential`]); `None` if no panel compares.
pub fn summary_gain(panels: &[Panel]) -> Option<f64> {
    let gains: Vec<f64> =
        panels.iter().filter_map(|p| optimal_vs_sequential(p).ok()).map(|g| g.0).collect();
    if gains.is_empty() {
        return None;
    }
    Some(gains.iter().sum::<f64>() / gains.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_all_figures() {
        for f in 3..=13 {
            assert!(!figure_specs(f).is_empty(), "figure {f}");
        }
    }

    #[test]
    fn small_panel_has_all_strategies() {
        let chain = profiles::resnet(18, 224, 16);
        let p = panel(&chain, 16, DEVICE_MEMORY);
        for strat in [
            StrategyKind::StoreAll,
            StrategyKind::Periodic,
            StrategyKind::Revolve,
            StrategyKind::Optimal,
        ] {
            assert!(
                p.points.iter().any(|pt| pt.strategy == strat),
                "missing {strat} in {:?}",
                p.points.iter().map(|x| x.strategy).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn optimal_dominates_sequential_on_small_panel() {
        let chain = profiles::resnet(34, 224, 16);
        let p = panel(&chain, 16, DEVICE_MEMORY);
        let (gain, _, _) = optimal_vs_sequential(&p).unwrap_or_else(|e| panic!("{e:#}"));
        assert!(gain >= -1e-9, "optimal must not lose at equal memory: gain={gain}");
    }

    #[test]
    fn degenerate_inline_chains_do_not_panic() {
        // reachable via `simulate --chain`: a single zero-size stage has
        // nothing to segment (sequential) and a 0-byte store-all top (DP)
        use crate::chain::Stage;
        let zero = Chain::new("zero", vec![Stage::new("loss", 0.0, 0.0, 0, 0)], 0);
        let p = panel(&zero, 1, DEVICE_MEMORY);
        assert!(p.points.iter().all(|pt| pt.strategy == StrategyKind::StoreAll));
    }

    #[test]
    fn csv_has_rows() {
        let chain = profiles::resnet(18, 224, 8);
        let p = panel(&chain, 8, DEVICE_MEMORY);
        let csv = to_csv(&[p]);
        assert!(csv.lines().count() > 10);
        assert!(csv.starts_with("chain,"));
    }
}
