//! Decomposition: articulation cuts, segment structure, and frontier
//! fusion of a DAG into an equivalent heterogeneous [`Chain`].
//!
//! The key observation (Feng & Huang's graph-division idea adapted to the
//! Table-1 model): sweep the nodes in topological order and watch the
//! **frontier** — the set of already-computed outputs still awaiting a
//! consumer. A topo position where the frontier collapses to the node
//! just computed is an *articulation cut*: no value crosses it, so any
//! schedule decomposes there and the chain DP's segment structure is
//! exact. Between cuts lies an *irreducible core* (capped at
//! [`MAX_CORE`](super::MAX_CORE) nodes) whose spanning values the fusion
//! conservatively pins into every chain stage they span: fused stage `j`
//! carries `ω_a` = the node's own output **plus** every earlier output
//! whose last consumer lies beyond `j`. Running the ordinary chain DP on
//! the fused chain therefore yields a schedule that is valid on the graph
//! and whose true (multi-consumer) footprint never exceeds the fused
//! chain's accounting — see [`super::sim`].
//!
//! On a chain-shaped graph every position is a cut, every frontier is the
//! singleton `{j}`, and the fused chain equals the node chain verbatim —
//! so graph solving degenerates to exactly the paper's DP, bit for bit.

use crate::chain::{Chain, Stage};

use super::spec::GraphSpec;

/// Whether a segment is a plain chain link or an irreducible core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// A single node separated from its neighbours by articulation cuts.
    Linear,
    /// A maximal run of nodes crossed by at least one spanning value.
    Core,
}

/// A maximal run of topo positions `start..=end` between articulation
/// cuts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub start: usize,
    /// Inclusive.
    pub end: usize,
    pub kind: SegmentKind,
}

impl Segment {
    /// Node count of the run. Segments are non-empty by construction
    /// (`start ≤ end`), so there is deliberately no `is_empty`.
    #[allow(clippy::len_without_is_empty)]
    pub fn len(&self) -> usize {
        self.end - self.start + 1
    }
}

impl GraphSpec {
    /// `true` iff no edge (and no pending consumer) spans past topo
    /// position `j` — the frontier after `j` is exactly `{j}`.
    pub fn is_cut(&self, j: usize) -> bool {
        (0..j).all(|u| self.last_use(u) <= j)
    }

    /// Split the topo order into maximal segments between articulation
    /// cuts. Single-node segments are [`SegmentKind::Linear`]; anything
    /// longer is an irreducible [`SegmentKind::Core`]. A chain-shaped
    /// graph yields `len()` Linear segments.
    pub fn segments(&self) -> Vec<Segment> {
        let n = self.len();
        let mut out = Vec::new();
        let mut start = 0usize;
        for j in 0..n {
            if j + 1 == n || self.is_cut(j) {
                let kind = if j == start { SegmentKind::Linear } else { SegmentKind::Core };
                out.push(Segment { start, end: j, kind });
                start = j + 1;
            }
        }
        out
    }

    /// The chain of the nodes' **own** sizes in topo order, ignoring
    /// spanning values — the per-node cost model the graph simulator
    /// accounts against. For a chain-shaped graph this *is* the graph.
    pub fn node_chain(&self) -> Chain {
        let stages = self
            .nodes()
            .iter()
            .map(|nd| {
                Stage::new(nd.name.clone(), nd.uf, nd.ub, nd.wa, nd.wabar)
                    .with_overheads(nd.of, nd.ob)
            })
            .collect();
        Chain::new(self.name.clone(), stages, self.input_bytes)
    }

    /// Frontier fusion: linearize the DAG into a [`Chain`] whose stage `j`
    /// output is the whole frontier after position `j` (the node's own
    /// output plus every spanning value). The chain DP on this chain is
    /// the decomposed graph solver; on chain-shaped graphs the result is
    /// identical to [`Self::node_chain`].
    pub fn to_chain(&self) -> Chain {
        let n = self.len();
        let mut stages = Vec::with_capacity(n);
        for (j, nd) in self.nodes().iter().enumerate() {
            // fused ω_a^j: node j's output + every u < j still live past j
            let carried: u64 = (0..j)
                .filter(|&u| self.last_use(u) > j)
                .map(|u| self.nodes()[u].wa)
                .sum();
            let wa = nd.wa + carried;
            // the tape extra (ā − a) is node-local; the carried values are
            // plain activations, stored once whether checkpointed or not
            let wabar = wa + (nd.wabar - nd.wa);
            stages.push(
                Stage::new(nd.name.clone(), nd.uf, nd.ub, wa, wabar)
                    .with_overheads(nd.of, nd.ob),
            );
        }
        Chain::new(self.name.clone(), stages, self.input_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::super::spec::Node;
    use super::*;

    fn nd(name: &str, wa: u64) -> Node {
        Node::new(name, 1.0, 2.0, wa, wa + 50)
    }

    fn chain4() -> GraphSpec {
        GraphSpec::new(
            "c4",
            vec![nd("a", 100), nd("b", 200), nd("c", 50), nd("loss", 4)],
            vec![(0, 1), (1, 2), (2, 3)],
            64,
        )
        .unwrap()
    }

    #[test]
    fn chain_graph_fuses_to_its_own_node_chain() {
        let g = chain4();
        assert_eq!(g.to_chain(), g.node_chain());
        let segs = g.segments();
        assert_eq!(segs.len(), 4);
        assert!(segs.iter().all(|s| s.kind == SegmentKind::Linear && s.len() == 1));
    }

    #[test]
    fn skip_edge_carries_bytes_and_opens_a_core() {
        // diamond: a feeds both b and c; c also reads b
        let g = GraphSpec::new(
            "skip",
            vec![nd("a", 100), nd("b", 200), nd("c", 50), nd("loss", 4)],
            vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            64,
        )
        .unwrap();
        let fused = g.to_chain();
        // a's output (100) is pinned across position 1
        assert_eq!(fused.wa(1), 100);
        assert_eq!(fused.wa(2), 200 + 100);
        assert_eq!(fused.wabar(2), 200 + 100 + 50);
        assert_eq!(fused.wa(3), 50);
        assert_eq!(fused.wa(4), 4);
        let segs = g.segments();
        assert_eq!(
            segs,
            vec![
                Segment { start: 0, end: 2, kind: SegmentKind::Core },
                Segment { start: 3, end: 3, kind: SegmentKind::Linear },
            ]
        );
        assert!(!g.is_cut(0));
        assert!(!g.is_cut(1));
        assert!(g.is_cut(2));
    }

    #[test]
    fn fused_sizes_dominate_node_sizes() {
        let g = GraphSpec::new(
            "wide",
            vec![nd("a", 10), nd("b", 20), nd("c", 30), nd("d", 40), nd("loss", 4)],
            vec![(0, 1), (1, 2), (2, 3), (3, 4), (0, 3), (1, 3)],
            8,
        )
        .unwrap();
        let fused = g.to_chain();
        let local = g.node_chain();
        for l in 1..=g.len() {
            assert!(fused.wa(l) >= local.wa(l));
            assert!(fused.wabar(l) >= local.wabar(l));
            assert_eq!(fused.wabar(l) - fused.wa(l), local.wabar(l) - local.wa(l));
        }
        // position 2 carries both a (10) and b (20)
        assert_eq!(fused.wa(3), 30 + 10 + 20);
    }
}
