//! Beyond chains: checkpointing for DAGs via segment decomposition.
//!
//! The paper's Table-1 model and Theorem-1 DP assume a pure sequential
//! chain; real networks add residual/skip connections and branches. This
//! module extends the solver to single-entry/single-exit DAGs in three
//! steps:
//!
//! 1. **Spec** ([`GraphSpec`]) — nodes are Table-1 stages, edges are data
//!    dependencies; construction validates everything (cycles, dangling
//!    edges, entry/exit structure, core size).
//! 2. **Decomposition** ([`GraphSpec::segments`] / [`GraphSpec::to_chain`])
//!    — split the topo order at articulation cuts (positions no value
//!    crosses) and *fuse* each irreducible core's spanning values into the
//!    stage sizes, producing an ordinary heterogeneous [`Chain`] the
//!    existing DP solves. On chain-shaped graphs the fused chain is the
//!    node chain verbatim, so the solver degenerates to the paper's DP
//!    exactly.
//! 3. **Verification** ([`simulate_graph`]) — replay the schedule under
//!    multi-consumer liveness (a value lives until its *last* consumer,
//!    via the refcounted [`MemState`](crate::simulator::MemState)): the
//!    true peak is never above the fused chain's conservative accounting,
//!    and equals it when the graph is a chain.
//!
//! For small graphs (fused length ≤ [`EXHAUSTIVE_MAX`]) the exhaustive
//! oracle [`exhaustive_optimal`](crate::solver::exhaustive_optimal)
//! provides a lower bound on the achievable cost, which the randomized
//! test harness cross-checks on hundreds of seeded DAGs.
//!
//! ```
//! use chainckpt::graph::{solve_graph, GraphSpec, Node};
//! use chainckpt::solver::Mode;
//!
//! // a diamond: a feeds both b and c, c reads both
//! let g = GraphSpec::new(
//!     "diamond",
//!     vec![
//!         Node::new("a", 1.0, 2.0, 100, 120),
//!         Node::new("b", 1.0, 2.0, 80, 90),
//!         Node::new("c", 1.0, 2.0, 60, 60),
//!         Node::new("loss", 0.5, 0.5, 4, 4),
//!     ],
//!     vec![(0, 1), (0, 2), (1, 2), (2, 3)],
//!     32,
//! )
//! .unwrap();
//! let budget = g.to_chain().store_all_memory() + g.input_bytes;
//! let sol = solve_graph(&g, budget, 300, Mode::Full).expect("roomy budget is feasible");
//! assert!(sol.graph_peak <= sol.fused_peak);
//! assert!(sol.fused_peak <= budget);
//! // the exhaustive oracle never beats the decomposed DP by more than rounding
//! let bound = sol.exhaustive_bound.expect("4 fused stages ≤ EXHAUSTIVE_MAX");
//! assert!(sol.schedule.predicted_time >= bound - 1e-9);
//! ```

mod decompose;
mod presets;
mod sim;
mod spec;

pub use decompose::{Segment, SegmentKind};
pub use presets::{preset, NAMES};
pub use sim::{bind, simulate_graph, Bindings, GraphReport, Mat, MatKind, OpBind};
pub use spec::{GraphError, GraphSpec, Node, MAX_CORE, MAX_NODES};

use crate::chain::Chain;
use crate::solver::planner::Planner;
use crate::solver::{exhaustive_optimal, Mode, Schedule};

/// Largest fused-chain length for which [`solve_graph`] cross-checks the
/// DP against the exhaustive oracle (whose state space is exponential).
pub const EXHAUSTIVE_MAX: usize = 8;

/// A solved graph: the fused chain, its segment structure, the DP
/// schedule over fused stages (stage `ℓ` = topo node `ℓ-1`), and both
/// peak accountings.
#[derive(Debug, Clone)]
pub struct GraphSolution {
    /// The frontier-fused chain the DP ran on.
    pub chain: Chain,
    /// Articulation-cut segment structure of the topo order.
    pub segments: Vec<Segment>,
    /// The schedule, in fused-chain stage indices.
    pub schedule: Schedule,
    /// Peak bytes under the fused chain's conservative accounting.
    pub fused_peak: u64,
    /// Peak bytes under multi-consumer liveness (`≤ fused_peak`).
    pub graph_peak: u64,
    /// The exhaustive oracle's true-optimum cost on the fused chain, when
    /// it is small enough to search (`len ≤` [`EXHAUSTIVE_MAX`]) and
    /// feasible. A lower bound: the DP's `predicted_time` is never below
    /// it (beyond discretization rounding).
    pub exhaustive_bound: Option<f64>,
}

impl GraphSolution {
    /// The schedule's ops labelled with the node each one touches.
    pub fn node_sequence<'g>(&self, g: &'g GraphSpec) -> Vec<(crate::solver::Op, &'g str)> {
        self.schedule
            .ops
            .iter()
            .map(|&op| (op, g.nodes()[op.stage() as usize - 1].name.as_str()))
            .collect()
    }
}

/// Solve a graph under `memory` bytes: fuse ([`GraphSpec::to_chain`]),
/// run the chain DP ([`Planner`]), verify the schedule under both the
/// fused and the multi-consumer accounting, and attach the exhaustive
/// bound when the fused chain is small enough. `None` if no schedule
/// fits.
pub fn solve_graph(g: &GraphSpec, memory: u64, slots: usize, mode: Mode) -> Option<GraphSolution> {
    let chain = g.to_chain();
    let planner = Planner::new(&chain, memory, slots, mode);
    let schedule = planner.schedule_at(memory)?;
    let bound = (chain.len() <= EXHAUSTIVE_MAX)
        .then(|| exhaustive_optimal(&chain, memory))
        .flatten();
    let rep = simulate_graph(g, &schedule)
        .unwrap_or_else(|e| panic!("DP emitted an invalid graph schedule: {e}"));
    assert!(
        rep.graph_peak <= rep.fused.peak_bytes,
        "multi-consumer peak {} above the fused bound {}",
        rep.graph_peak,
        rep.fused.peak_bytes
    );
    Some(GraphSolution {
        segments: g.segments(),
        chain,
        schedule,
        fused_peak: rep.fused.peak_bytes,
        graph_peak: rep.graph_peak,
        exhaustive_bound: bound,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> GraphSpec {
        GraphSpec::new(
            "diamond",
            vec![
                Node::new("a", 1.0, 2.0, 100, 120),
                Node::new("b", 1.0, 2.0, 80, 90),
                Node::new("c", 1.0, 2.0, 60, 60),
                Node::new("loss", 0.5, 0.5, 4, 4),
            ],
            vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            32,
        )
        .unwrap()
    }

    #[test]
    fn chain_shaped_graph_solves_exactly_like_the_chain_dp() {
        let g = GraphSpec::new(
            "c",
            vec![
                Node::new("a", 1.0, 2.0, 100, 250),
                Node::new("b", 3.0, 4.0, 50, 60),
                Node::new("loss", 0.5, 0.5, 4, 4),
            ],
            vec![(0, 1), (1, 2)],
            64,
        )
        .unwrap();
        let chain = g.node_chain();
        let m = chain.store_all_memory() / 2 + chain.wa0;
        let sol = solve_graph(&g, m, 300, Mode::Full);
        let plain = crate::solver::solve(&chain, m, 300, Mode::Full);
        match (sol, plain) {
            (Some(s), Some(p)) => {
                assert_eq!(s.schedule.ops, p.ops);
                assert_eq!(s.schedule.predicted_time.to_bits(), p.predicted_time.to_bits());
                assert_eq!(s.graph_peak, s.fused_peak);
            }
            (None, None) => {}
            (s, p) => panic!("feasibility mismatch: graph={} chain={}", s.is_some(), p.is_some()),
        }
    }

    #[test]
    fn diamond_solution_carries_both_accountings() {
        let g = diamond();
        let budget = g.to_chain().store_all_memory() + g.input_bytes;
        let sol = solve_graph(&g, budget, 300, Mode::Full).unwrap();
        assert!(sol.graph_peak < sol.fused_peak, "skip values billed once");
        assert_eq!(sol.segments.len(), 2);
        let bound = sol.exhaustive_bound.unwrap();
        assert!(sol.schedule.predicted_time >= bound - 1e-9);
        // node labels line up with fused stages
        let seq = sol.node_sequence(&g);
        assert_eq!(seq.len(), sol.schedule.ops.len());
        assert!(seq.iter().any(|(_, name)| *name == "b"));
    }

    #[test]
    fn starved_graph_is_infeasible() {
        let g = diamond();
        assert!(solve_graph(&g, 64, 300, Mode::Full).is_none());
    }
}
