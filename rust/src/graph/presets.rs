//! Graph presets: the native backend's `residual` and `unet` manifests
//! with their skip edges made explicit.
//!
//! The native executor runs strictly sequential chains — each kernel
//! absorbs its block's residual add — so the *executed* model is the
//! fused chain. These presets are the planning-side view: the same
//! per-stage costs ([`crate::backend::native::presets`] geometry run
//! through the analytic FLOP model), plus the data-dependency edges the
//! sequential chain hides. Solving the graph preset and executing the
//! matching native preset therefore agree on cost while the graph side
//! additionally knows which values fan out.

use crate::api::PRESET_FLOPS_PER_US;
use crate::backend::native::presets as native;
use crate::chain::Chain;

use super::spec::{GraphSpec, Node};

/// Every named graph preset [`preset`] accepts.
pub const NAMES: &[&str] = &["residual", "unet"];

/// Named graph presets, or `None` for unknown names.
///
/// * `residual` — the native `residual` transformer (2 blocks) with a
///   skip edge around every attn/mlp stage: edges `(i-1, i+1)` for each
///   block stage, chaining into one 6-node irreducible core.
/// * `unet` — the native `unet` hourglass with encoder→decoder skips
///   `(enc1, dec2)` and `(enc2, dec1)`: a 5-node core plus the loss.
pub fn preset(name: &str) -> Option<GraphSpec> {
    let manifest = native::preset(name).ok()?;
    let chain = manifest.to_chain_analytic(PRESET_FLOPS_PER_US);
    let n = chain.len();
    let mut edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    match name {
        "residual" => {
            // stages: dense, [attn, mlp]×2, dense, loss — skip around
            // every block stage (the residual stream)
            for i in 1..=n - 3 {
                edges.push((i - 1, i + 1));
            }
        }
        "unet" => {
            // stages: enc1, enc2, ln, dec1, dec2, loss — concat skips
            edges.push((0, 4));
            edges.push((1, 3));
        }
        _ => return None,
    }
    Some(from_chain(name, &chain, edges))
}

/// Build a graph from a chain's per-stage costs and an explicit edge set.
fn from_chain(name: &str, chain: &Chain, edges: Vec<(usize, usize)>) -> GraphSpec {
    let nodes: Vec<Node> = (1..=chain.len())
        .map(|l| {
            Node::new(chain.stages[l - 1].name.clone(), chain.uf(l), chain.ub(l), chain.wa(l), chain.wabar(l))
                .with_overheads(chain.of(l), chain.ob(l))
        })
        .collect();
    GraphSpec::new(name, nodes, edges, chain.wa0)
        .expect("preset geometry is a valid DAG")
}

#[cfg(test)]
mod tests {
    use super::super::decompose::SegmentKind;
    use super::*;

    #[test]
    fn residual_preset_is_one_core_plus_loss() {
        let g = preset("residual").unwrap();
        assert_eq!(g.len(), 7);
        assert!(!g.is_chain());
        let segs = g.segments();
        assert_eq!(segs[0].kind, SegmentKind::Core);
        assert_eq!(segs[0].len(), 6); // dense through output head
        assert_eq!(segs.last().unwrap().kind, SegmentKind::Linear);
        // node costs match the native chain verbatim
        let native_chain =
            native::preset("residual").unwrap().to_chain_analytic(PRESET_FLOPS_PER_US);
        assert_eq!(g.node_chain().stages, native_chain.stages);
        assert_eq!(g.input_bytes, native_chain.wa0);
    }

    #[test]
    fn unet_preset_has_encoder_decoder_skips() {
        let g = preset("unet").unwrap();
        assert_eq!(g.len(), 6);
        assert!(g.edges().contains(&(0, 4)));
        assert!(g.edges().contains(&(1, 3)));
        let segs = g.segments();
        assert_eq!(segs[0], super::super::decompose::Segment {
            start: 0,
            end: 4,
            kind: SegmentKind::Core,
        });
        // the fused chain pins the skip sources across the hourglass
        let fused = g.to_chain();
        let local = g.node_chain();
        assert!(fused.wa(3) > local.wa(3), "bottleneck carries both skips");
    }

    #[test]
    fn unknown_names_are_none() {
        assert!(preset("quickstart").is_none()); // chain preset, not a graph
        assert!(preset("nope").is_none());
        for name in NAMES {
            assert!(preset(name).is_some(), "{name}");
        }
    }
}
