//! Multi-consumer replay: verify a fused-chain schedule against the
//! graph's true value lifetimes.
//!
//! The fused chain ([`GraphSpec::to_chain`]) charges every spanning value
//! into each stage it crosses, so its accounting is conservative: a value
//! carried across `k` resident checkpoints is billed `k` times. The graph
//! replay here bills it once, from the op that materializes it to its
//! **last consumer** — the multi-consumer generalization of Table 1's
//! replace-on-read rule, driven through the refcounted
//! [`MemState`](crate::simulator::MemState) (`store_a_counted` /
//! `consume_a`).
//!
//! Two passes:
//! 1. **Binding** — walk the (already fused-validated) op sequence and
//!    bind every read to the latest materialization of the value it
//!    names, counting consuming reads per materialization. Gradients
//!    follow the backward sweep: `δ` for a node is born at its first
//!    executed successor-backward and consumed by the node's own `B`.
//! 2. **Accounting** — replay the sequence against the node-local sizes
//!    ([`GraphSpec::node_chain`]), storing each activation with its true
//!    fan-out and freeing it exactly at its last read.
//!
//! On a chain-shaped graph every value has one consumer and the replay's
//! peak equals the chain simulator's byte for byte; with skip edges it is
//! never above the fused chain's peak (each live value is covered by at
//! least one resident fused checkpoint that the fused accounting bills
//! in full).
//!
//! `DropA` (never emitted by the solvers) acts node-locally: it frees the
//! named node's standalone output if resident, mirroring the chain op.

use crate::simulator::{simulate, MemState, SimError, SimReport};
use crate::solver::{Op, Schedule};

use super::spec::GraphSpec;

/// Which value a materialization holds. Node indices are topo positions
/// (`0`-based; the fused chain's stage `ℓ` is node `ℓ-1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatKind {
    /// The graph input `a^0`.
    Input,
    /// Standalone output of a node.
    A(usize),
    /// Full tape `ā` of a node.
    Abar(usize),
    /// Gradient w.r.t. a node's output (the exit node's is the seed).
    Delta(usize),
    /// Gradient w.r.t. the graph input (`δ^0`, the walk's result).
    DeltaInput,
}

/// One materialization: a value brought into memory by one op (or live at
/// entry) and freed at a known point.
#[derive(Debug, Clone)]
pub struct Mat {
    pub kind: MatKind,
    pub bytes: u64,
    /// Op index that created it; `None` for entry-live values (the input
    /// and the `δ` seed).
    pub birth: Option<usize>,
    /// Op index at which it is freed; `None` if still live at exit.
    pub death: Option<usize>,
    /// Consuming reads bound to this materialization (`A`/`Input` kinds;
    /// tape and gradient lifetimes are fixed by their `B` ops instead).
    pub reads: u32,
}

/// Read/write/free sets of one op, as materialization ids — the graph
/// analogue of the chain lowering's step table.
#[derive(Debug, Clone, Default)]
pub struct OpBind {
    pub reads: Vec<usize>,
    pub writes: Vec<usize>,
    pub frees: Vec<usize>,
}

/// Peak verdicts of both accountings.
#[derive(Debug, Clone)]
pub struct GraphReport {
    /// The fused chain's report (validity, makespan, conservative peak).
    pub fused: SimReport,
    /// Peak bytes under multi-consumer liveness — `≤ fused.peak_bytes`,
    /// equal on chain-shaped graphs.
    pub graph_peak: u64,
}

/// A fused-chain schedule fully bound onto the graph: every value
/// materialization with its birth/death, and per-op read/write/free
/// sets. This is what [`crate::plan::lower_graph`] turns into slot IR.
#[derive(Debug, Clone)]
pub struct Bindings {
    pub mats: Vec<Mat>,
    /// One entry per schedule op, same order.
    pub ops: Vec<OpBind>,
    /// Mat id of the graph input.
    pub input: usize,
    /// Mat id of the `δ` seed (gradient of the exit node's output).
    pub seed: usize,
    /// Mat id of `δ^0`, produced by the entry node's backward.
    pub delta0: usize,
    pub report: GraphReport,
}

/// Replay `schedule` (an op sequence over the fused chain's stages)
/// against the graph: first validate and account it on the fused chain,
/// then bind and re-account it under multi-consumer liveness. Errors are
/// the fused chain simulator's.
pub fn simulate_graph(g: &GraphSpec, schedule: &Schedule) -> Result<GraphReport, SimError> {
    bind(g, schedule).map(|b| b.report)
}

/// Full two-pass binding (see [module docs](self)). Intended for
/// solver-emitted (persistent) schedules; hand-written sequences that
/// redundantly store `a^ℓ` while `ā^ℓ` is resident bind their late reads
/// to the standalone copy and account it until then.
pub fn bind(g: &GraphSpec, schedule: &Schedule) -> Result<Bindings, SimError> {
    let fused = simulate(&g.to_chain(), schedule)?;
    let n = g.len();
    let node_chain = g.node_chain();

    let mut mats: Vec<Mat> = Vec::new();
    let mut ops: Vec<OpBind> = vec![OpBind::default(); schedule.ops.len()];
    let entry = |kind, bytes| Mat { kind, bytes, birth: None, death: None, reads: 0 };
    let input = 0usize;
    mats.push(entry(MatKind::Input, g.input_bytes));
    let seed = 1usize;
    mats.push(entry(MatKind::Delta(n - 1), node_chain.wdelta(n)));

    // ---- pass 1: bind reads to the latest materialization ----
    // per node: latest A or Abar mat of its output (fused validity plus
    // the decreasing-backward invariant guarantee any read hits the
    // generation that is still, or again, live)
    let mut latest: Vec<Option<usize>> = vec![None; n];
    // gradient residency in chain indexing: 0 = δ^0, u+1 = node u
    let mut cur_delta: Vec<Option<usize>> = vec![None; n + 1];
    cur_delta[n] = Some(seed);

    // resolve the activations node j0 reads (the graph input for the
    // entry node), count consuming reads, and record them on the op
    fn read_inputs(
        g: &GraphSpec,
        latest: &[Option<usize>],
        input: usize,
        j0: usize,
        i: usize,
        mats: &mut [Mat],
        ops: &mut [OpBind],
    ) -> Result<(), SimError> {
        let resolved = if j0 == 0 {
            vec![input]
        } else {
            g.preds(j0)
                .iter()
                .map(|&u| {
                    latest[u].ok_or(SimError::MissingActivation { op_index: i, l: u as u32 + 1 })
                })
                .collect::<Result<Vec<_>, _>>()?
        };
        for id in resolved {
            if matches!(mats[id].kind, MatKind::A(_) | MatKind::Input) {
                mats[id].reads += 1;
            }
            ops[i].reads.push(id);
        }
        Ok(())
    }

    for (i, &op) in schedule.ops.iter().enumerate() {
        let l = op.stage() as usize;
        let j0 = l - 1;
        match op {
            Op::FwdNoSave(_) | Op::FwdCk(_) | Op::FwdAll(_) => {
                read_inputs(g, &latest, input, j0, i, &mut mats, &mut ops)?;
                let (kind, bytes) = if matches!(op, Op::FwdAll(_)) {
                    (MatKind::Abar(j0), node_chain.wabar(l))
                } else {
                    (MatKind::A(j0), node_chain.wa(l))
                };
                let id = mats.len();
                mats.push(Mat { kind, bytes, birth: Some(i), death: None, reads: 0 });
                ops[i].writes.push(id);
                latest[j0] = Some(id);
            }
            Op::Bwd(_) => {
                let d = cur_delta[l].ok_or(SimError::MissingBackwardInput {
                    op_index: i,
                    l: l as u32,
                    what: "δ",
                })?;
                ops[i].reads.push(d);
                let abar = latest[j0]
                    .filter(|&m| matches!(mats[m].kind, MatKind::Abar(_)))
                    .ok_or(SimError::MissingBackwardInput { op_index: i, l: l as u32, what: "ā" })?;
                ops[i].reads.push(abar);
                read_inputs(g, &latest, input, j0, i, &mut mats, &mut ops)?;
                cur_delta[l] = None;
                // gradient contributions: one δ per predecessor output
                // (δ^0 for the entry), born at its first contributor
                let grads: Vec<(usize, MatKind, u64)> = if j0 == 0 {
                    vec![(0, MatKind::DeltaInput, g.input_bytes)]
                } else {
                    g.preds(j0)
                        .iter()
                        .map(|&u| (u + 1, MatKind::Delta(u), node_chain.wdelta(u + 1)))
                        .collect()
                };
                for (slot, kind, bytes) in grads {
                    if cur_delta[slot].is_none() {
                        let id = mats.len();
                        mats.push(Mat { kind, bytes, birth: Some(i), death: None, reads: 0 });
                        cur_delta[slot] = Some(id);
                        ops[i].writes.push(id);
                    }
                }
            }
            Op::DropA(_) => {} // resolved in pass 2 (needs residency)
        }
    }
    let delta0 = cur_delta[0].expect("fused simulate checked completeness");

    // ---- pass 2: refcounted accounting over node-local sizes ----
    let mut st = MemState::initial(&node_chain);
    st.set_consumers(0, mats[input].reads);
    // currently-resident standalone A mat per node (for DropA targets)
    let mut live_a: Vec<Option<usize>> = vec![None; n];
    let slot_of = |kind: MatKind| match kind {
        MatKind::Input => 0,
        MatKind::A(u) => u + 1,
        _ => unreachable!("only activations have a-slots"),
    };
    for (i, &op) in schedule.ops.iter().enumerate() {
        let l = op.stage() as usize;
        let j0 = l - 1;
        // consuming reads decrement; the last one frees (recorded below)
        macro_rules! consume_reads {
            () => {
                for r in 0..ops[i].reads.len() {
                    let id = ops[i].reads[r];
                    let kind = mats[id].kind;
                    if matches!(kind, MatKind::A(_) | MatKind::Input) && st.consume_a(slot_of(kind))
                    {
                        mats[id].death = Some(i);
                        ops[i].frees.push(id);
                        if let MatKind::A(u) = kind {
                            live_a[u] = None;
                        }
                    }
                }
            };
        }
        match op {
            Op::FwdNoSave(_) | Op::FwdCk(_) => {
                st.touch_peak(node_chain.wa(l) + node_chain.of(l));
                let id = ops[i].writes[0];
                st.store_a_counted(l, mats[id].reads)
                    .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                live_a[j0] = Some(id);
                consume_reads!();
            }
            Op::FwdAll(_) => {
                st.touch_peak(node_chain.wabar(l) + node_chain.of(l));
                st.store_abar(l)
                    .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                consume_reads!();
            }
            Op::Bwd(_) => {
                st.touch_peak(node_chain.ob(l));
                // frees mirror the chain transition: δ^ℓ and ā^ℓ retire
                // here. Only the op's *own* tape — a predecessor read may
                // also bind to an Abar mat (pred stored via Fall), but
                // that tape retires at the pred's own backward.
                for r in 0..ops[i].reads.len() {
                    let id = ops[i].reads[r];
                    match mats[id].kind {
                        MatKind::Delta(u) if u == j0 => {
                            st.free_delta(l);
                            mats[id].death = Some(i);
                            ops[i].frees.push(id);
                        }
                        MatKind::Abar(u) if u == j0 => {
                            st.free_abar(l);
                            mats[id].death = Some(i);
                            ops[i].frees.push(id);
                        }
                        _ => {}
                    }
                }
                consume_reads!();
                for w in 0..ops[i].writes.len() {
                    let id = ops[i].writes[w];
                    let slot = match mats[id].kind {
                        MatKind::DeltaInput => 0,
                        MatKind::Delta(u) => u + 1,
                        _ => unreachable!("backward writes are gradients"),
                    };
                    st.store_delta(slot)
                        .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                }
            }
            Op::DropA(_) => {
                if st.free_a_if_standalone(l) {
                    let id = live_a[j0].take().expect("resident a tracked");
                    mats[id].death = Some(i);
                    ops[i].frees.push(id);
                }
            }
        }
    }
    let graph_peak = st.peak;
    debug_assert!(
        graph_peak <= fused.peak_bytes,
        "multi-consumer accounting above the fused bound: {graph_peak} > {}",
        fused.peak_bytes
    );
    Ok(Bindings {
        mats,
        ops,
        input,
        seed,
        delta0,
        report: GraphReport { fused, graph_peak },
    })
}

#[cfg(test)]
mod tests {
    use super::super::spec::{GraphSpec, Node};
    use super::*;
    use crate::solver::store_all_schedule;

    fn nd(name: &str, wa: u64, wabar: u64) -> Node {
        Node::new(name, 1.0, 2.0, wa, wabar)
    }

    fn diamond() -> GraphSpec {
        GraphSpec::new(
            "diamond",
            vec![nd("a", 100, 120), nd("b", 80, 90), nd("c", 60, 60), nd("loss", 4, 4)],
            vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            32,
        )
        .unwrap()
    }

    #[test]
    fn chain_graph_replay_matches_chain_simulator_exactly() {
        let g = GraphSpec::new(
            "c",
            vec![nd("a", 100, 120), nd("b", 80, 90), nd("loss", 4, 4)],
            vec![(0, 1), (1, 2)],
            32,
        )
        .unwrap();
        let sched = store_all_schedule(&g.node_chain());
        let rep = simulate_graph(&g, &sched).unwrap();
        assert_eq!(rep.graph_peak, rep.fused.peak_bytes);
    }

    #[test]
    fn skip_connection_is_billed_once_not_per_checkpoint() {
        let g = diamond();
        let sched = store_all_schedule(&g.to_chain());
        let rep = simulate_graph(&g, &sched).unwrap();
        // the fused chain carries a's 100 bytes inside both ā^2 and ā^3;
        // the graph replay holds the single materialization
        assert!(
            rep.graph_peak < rep.fused.peak_bytes,
            "graph {} vs fused {}",
            rep.graph_peak,
            rep.fused.peak_bytes
        );
    }

    #[test]
    fn bindings_track_births_deaths_and_fanout() {
        let g = diamond();
        let sched = store_all_schedule(&g.to_chain());
        let b = bind(&g, &sched).unwrap();
        // node a's tape is read by b, c, and B^1's input resolution…
        let a_tape = b
            .mats
            .iter()
            .find(|m| m.kind == MatKind::Abar(0))
            .expect("store-all tapes a");
        // …and freed exactly at B^1 (the last op)
        assert_eq!(a_tape.death, Some(sched.ops.len() - 1));
        // δ for node a is born at its first executed successor backward
        // (B^3, node c) and consumed by B^1
        let delta_a = b.mats.iter().find(|m| m.kind == MatKind::Delta(0)).unwrap();
        let b3 = sched.ops.iter().position(|o| *o == crate::solver::Op::Bwd(3)).unwrap();
        let b1 = sched.ops.iter().position(|o| *o == crate::solver::Op::Bwd(1)).unwrap();
        assert_eq!(delta_a.birth, Some(b3));
        assert_eq!(delta_a.death, Some(b1));
        // δ^0 exists and is live at exit
        assert!(b.mats[b.delta0].death.is_none());
        assert_eq!(b.mats[b.delta0].kind, MatKind::DeltaInput);
        // every op's frees point at mats that die there
        for (i, ob) in b.ops.iter().enumerate() {
            for &id in &ob.frees {
                assert_eq!(b.mats[id].death, Some(i));
            }
        }
    }
}
