//! [`GraphSpec`] — a validated DAG of Table-1 stages.
//!
//! Nodes carry the same cost model as chain [`Stage`]s (forward/backward
//! times, activation and tape sizes, transient overheads); edges are data
//! dependencies. Construction is the one validation point: dangling
//! edges, self-loops, cycles, multiple entries/exits, disconnected nodes
//! and oversize graphs are all rejected with a structured [`GraphError`]
//! before a spec exists — so every `GraphSpec` the rest of the stack sees
//! is solvable. Nodes are stored in a deterministic topological order
//! (stable across re-parses of the same graph), which is also the
//! linearization order the decomposition pass sweeps.

use crate::util::json::Value;

/// Node-count cap, matching the inline-chain cap of the facade
/// ([`crate::api::MAX_STAGES`]): bounds DP time for untrusted wire specs.
pub const MAX_NODES: usize = 2048;

/// Largest irreducible core (a maximal run of topo positions not
/// separated by an articulation cut) the decomposition accepts. Beyond
/// this the exhaustive cross-check oracle is unavailable and the fused
/// stage sizes grow multiplicatively, so the spec is rejected up front.
pub const MAX_CORE: usize = 8;

/// One stage of the DAG, with the chain cost model's per-stage fields.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub name: String,
    /// Forward / backward durations (`u_f`, `u_b`).
    pub uf: f64,
    pub ub: f64,
    /// Output activation bytes `ω_a` and full-tape bytes `ω_ā ≥ ω_a`.
    pub wa: u64,
    pub wabar: u64,
    /// Transient working-set overheads (`o_f`, `o_b`).
    pub of: u64,
    pub ob: u64,
}

impl Node {
    pub fn new(name: impl Into<String>, uf: f64, ub: f64, wa: u64, wabar: u64) -> Node {
        Node { name: name.into(), uf, ub, wa, wabar, of: 0, ob: 0 }
    }

    pub fn with_overheads(mut self, of: u64, ob: u64) -> Node {
        self.of = of;
        self.ob = ob;
        self
    }
}

/// Why a graph failed validation. Every variant maps to a kind-tagged
/// `InvalidSpec` facade error (HTTP 422, CLI exit 2) at the API boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// The JSON wire form was structurally wrong (missing/mistyped field).
    Malformed(String),
    Empty,
    /// More than [`MAX_NODES`] nodes.
    TooManyNodes(usize),
    /// An edge endpoint named a node index outside `0..len`.
    DanglingEdge { from: usize, to: usize },
    SelfLoop(usize),
    /// The edge relation has a cycle through the named node.
    Cycle(String),
    /// Not exactly one entry node (in-degree 0) — the graph input feeds
    /// exactly one node.
    MultipleEntries(Vec<String>),
    /// Not exactly one exit node (out-degree 0) — the loss.
    MultipleExits(Vec<String>),
    /// A node neither reaches the exit nor is reached from the entry.
    Disconnected(String),
    /// A node declared `ω_ā < ω_a` (the tape must contain the output).
    BadTape { node: String, wa: u64, wabar: u64 },
    /// An irreducible core spans more than [`MAX_CORE`] nodes.
    CoreTooLarge { start: String, len: usize },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::Malformed(m) => write!(f, "malformed graph spec: {m}"),
            GraphError::Empty => write!(f, "graph has no nodes"),
            GraphError::TooManyNodes(n) => {
                write!(f, "{n} nodes exceed the {MAX_NODES}-node cap")
            }
            GraphError::DanglingEdge { from, to } => {
                write!(f, "edge [{from}, {to}] names a node outside the graph")
            }
            GraphError::SelfLoop(i) => write!(f, "node {i} has a self-loop"),
            GraphError::Cycle(n) => write!(f, "graph has a cycle through node '{n}'"),
            GraphError::MultipleEntries(ns) => {
                write!(f, "graph needs exactly one entry node, found {}: {}", ns.len(), ns.join(", "))
            }
            GraphError::MultipleExits(ns) => {
                write!(f, "graph needs exactly one exit (loss) node, found {}: {}", ns.len(), ns.join(", "))
            }
            GraphError::Disconnected(n) => {
                write!(f, "node '{n}' is not on any entry→exit path")
            }
            GraphError::BadTape { node, wa, wabar } => {
                write!(f, "node '{node}': wabar = {wabar} < wa = {wa} (ā must include a)")
            }
            GraphError::CoreTooLarge { start, len } => write!(
                f,
                "irreducible core starting at '{start}' spans {len} nodes \
                 (max {MAX_CORE}; add an articulation point or pre-fuse the block)"
            ),
        }
    }
}

impl std::error::Error for GraphError {}

/// A validated DAG (see [module docs](self)). Nodes are in topological
/// order; node `0` is the entry (reads the graph input), the last node
/// is the exit (the loss).
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    pub name: String,
    /// Bytes of the graph input `a^0`, consumed by the entry node.
    pub input_bytes: u64,
    nodes: Vec<Node>,
    /// Edges in topo indices, sorted and deduplicated.
    edges: Vec<(usize, usize)>,
    preds: Vec<Vec<usize>>,
    succs: Vec<Vec<usize>>,
}

impl GraphSpec {
    /// Validate and build. `edges` are `(from, to)` indices into `nodes`
    /// (any order — construction topo-sorts, deterministically).
    pub fn new(
        name: impl Into<String>,
        nodes: Vec<Node>,
        edges: Vec<(usize, usize)>,
        input_bytes: u64,
    ) -> Result<GraphSpec, GraphError> {
        let n = nodes.len();
        if n == 0 {
            return Err(GraphError::Empty);
        }
        if n > MAX_NODES {
            return Err(GraphError::TooManyNodes(n));
        }
        for node in &nodes {
            if node.wabar < node.wa {
                return Err(GraphError::BadTape {
                    node: node.name.clone(),
                    wa: node.wa,
                    wabar: node.wabar,
                });
            }
        }
        let mut edge_set: Vec<(usize, usize)> = Vec::with_capacity(edges.len());
        for &(from, to) in &edges {
            if from >= n || to >= n {
                return Err(GraphError::DanglingEdge { from, to });
            }
            if from == to {
                return Err(GraphError::SelfLoop(from));
            }
            edge_set.push((from, to));
        }
        edge_set.sort_unstable();
        edge_set.dedup();

        // Deterministic Kahn topo sort: among ready nodes, lowest
        // original index first — the same input graph always linearizes
        // the same way.
        let mut indeg = vec![0usize; n];
        let mut succs0: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(f, t) in &edge_set {
            indeg[t] += 1;
            succs0[f].push(t);
        }
        let mut ready: std::collections::BinaryHeap<std::cmp::Reverse<usize>> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| std::cmp::Reverse(i))
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(std::cmp::Reverse(i)) = ready.pop() {
            order.push(i);
            for &s in &succs0[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(std::cmp::Reverse(s));
                }
            }
        }
        if order.len() < n {
            let stuck = indeg.iter().position(|&d| d > 0).expect("cycle leaves indegree");
            return Err(GraphError::Cycle(nodes[stuck].name.clone()));
        }

        // Renumber into topo space.
        let mut pos = vec![0usize; n];
        for (p, &i) in order.iter().enumerate() {
            pos[i] = p;
        }
        let nodes: Vec<Node> = order.iter().map(|&i| nodes[i].clone()).collect();
        let mut edges: Vec<(usize, usize)> =
            edge_set.iter().map(|&(f, t)| (pos[f], pos[t])).collect();
        edges.sort_unstable();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(f, t) in &edges {
            preds[t].push(f);
            succs[f].push(t);
        }

        // Exactly one entry and one exit; everything on an entry→exit path.
        let entries: Vec<String> = (0..n)
            .filter(|&i| preds[i].is_empty())
            .map(|i| nodes[i].name.clone())
            .collect();
        if entries.len() != 1 {
            return Err(GraphError::MultipleEntries(entries));
        }
        let exits: Vec<String> = (0..n)
            .filter(|&i| succs[i].is_empty())
            .map(|i| nodes[i].name.clone())
            .collect();
        if exits.len() != 1 && n > 1 {
            return Err(GraphError::MultipleExits(exits));
        }
        // with one entry and one exit, any disconnected node would be a
        // second entry or exit — but check reachability anyway to reject
        // separate components that happen to pair up
        let mut reach = vec![false; n];
        let mut stack = vec![0usize];
        reach[0] = true;
        while let Some(i) = stack.pop() {
            for &s in &succs[i] {
                if !reach[s] {
                    reach[s] = true;
                    stack.push(s);
                }
            }
        }
        if let Some(i) = reach.iter().position(|&r| !r) {
            return Err(GraphError::Disconnected(nodes[i].name.clone()));
        }

        let g = GraphSpec { name: name.into(), input_bytes, nodes, edges, preds, succs };
        // every accepted spec must decompose within the core cap
        for seg in g.segments() {
            if seg.len() > MAX_CORE {
                return Err(GraphError::CoreTooLarge {
                    start: g.nodes[seg.start].name.clone(),
                    len: seg.len(),
                });
            }
        }
        Ok(g)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    /// Predecessors of node `i` in topo indices (sorted ascending).
    pub fn preds(&self, i: usize) -> &[usize] {
        &self.preds[i]
    }

    /// Successors of node `i` in topo indices (sorted ascending).
    pub fn succs(&self, i: usize) -> &[usize] {
        &self.succs[i]
    }

    /// Topo position of the last consumer of node `i`'s output, or `i`
    /// itself for the exit node.
    pub fn last_use(&self, i: usize) -> usize {
        self.succs[i].last().copied().unwrap_or(i)
    }

    /// `true` iff the edge set is exactly the chain `0→1→…→n-1`.
    pub fn is_chain(&self) -> bool {
        self.edges.len() == self.nodes.len() - 1
            && self.edges.iter().enumerate().all(|(i, &e)| e == (i, i + 1))
    }

    /// Parse the wire form:
    ///
    /// ```json
    /// {"name": "g", "input_bytes": 512,
    ///  "nodes": [{"name": "s1", "uf": 1.0, "ub": 2.0, "wa": 100, "wabar": 250}, …],
    ///  "edges": [[0, 1], [0, 2], [1, 2]]}
    /// ```
    ///
    /// `of`/`ob` are optional per node (default 0). Structure errors come
    /// back as [`GraphError::Malformed`]; graph-shape errors as their
    /// specific variants.
    pub fn from_json(v: &Value) -> Result<GraphSpec, GraphError> {
        let mal = |m: String| GraphError::Malformed(m);
        let name = v.get("name").and_then(|s| s.as_str()).unwrap_or("graph").to_string();
        let input_bytes = v
            .get("input_bytes")
            .ok_or_else(|| mal("missing 'input_bytes' (bytes of the graph input)".into()))?
            .as_u64()
            .ok_or_else(|| mal("'input_bytes' must be a non-negative integer".into()))?;
        let nodes_json = v
            .get("nodes")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| mal("'nodes' must be an array".into()))?;
        let mut nodes = Vec::with_capacity(nodes_json.len());
        for (i, nd) in nodes_json.iter().enumerate() {
            let num = |key: &str| -> Result<f64, GraphError> {
                let x = nd
                    .get(key)
                    .ok_or_else(|| mal(format!("node {i}: missing '{key}'")))?
                    .as_f64()
                    .ok_or_else(|| mal(format!("node {i}: '{key}' must be a number")))?;
                if !x.is_finite() || x < 0.0 {
                    return Err(mal(format!("node {i}: '{key}' = {x} must be finite and ≥ 0")));
                }
                Ok(x)
            };
            let bytes = |key: &str, required: bool| -> Result<u64, GraphError> {
                match nd.get(key) {
                    None if !required => Ok(0),
                    None => Err(mal(format!("node {i}: missing '{key}'"))),
                    Some(x) => x
                        .as_u64()
                        .ok_or_else(|| mal(format!("node {i}: '{key}' must be a non-negative integer"))),
                }
            };
            let name = nd
                .get("name")
                .and_then(|s| s.as_str())
                .map(String::from)
                .unwrap_or_else(|| format!("n{i}"));
            nodes.push(
                Node::new(name, num("uf")?, num("ub")?, bytes("wa", true)?, bytes("wabar", true)?)
                    .with_overheads(bytes("of", false)?, bytes("ob", false)?),
            );
        }
        let edges_json = v
            .get("edges")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| mal("'edges' must be an array of [from, to] pairs".into()))?;
        let mut edges = Vec::with_capacity(edges_json.len());
        for (i, e) in edges_json.iter().enumerate() {
            let pair = e
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| mal(format!("edges[{i}] must be a [from, to] pair")))?;
            let idx = |j: usize| -> Result<usize, GraphError> {
                pair[j]
                    .as_usize()
                    .ok_or_else(|| mal(format!("edges[{i}][{j}] must be a node index")))
            };
            edges.push((idx(0)?, idx(1)?));
        }
        GraphSpec::new(name, nodes, edges, input_bytes)
    }
}

impl std::fmt::Display for GraphSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "graph '{}' ({} nodes, {} edges)", self.name, self.nodes.len(), self.edges.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nd(name: &str) -> Node {
        Node::new(name, 1.0, 2.0, 100, 250)
    }

    fn chain3() -> GraphSpec {
        GraphSpec::new(
            "c3",
            vec![nd("a"), nd("b"), nd("loss")],
            vec![(0, 1), (1, 2)],
            64,
        )
        .unwrap()
    }

    #[test]
    fn chain_graph_validates_and_is_chain() {
        let g = chain3();
        assert!(g.is_chain());
        assert_eq!(g.preds(1), &[0]);
        assert_eq!(g.succs(0), &[1]);
        assert_eq!(g.last_use(0), 1);
        assert_eq!(g.last_use(2), 2);
    }

    #[test]
    fn skip_edges_are_kept_and_sorted() {
        let g = GraphSpec::new(
            "skip",
            vec![nd("a"), nd("b"), nd("c"), nd("loss")],
            vec![(2, 3), (0, 1), (1, 2), (0, 2)],
            64,
        )
        .unwrap();
        assert!(!g.is_chain());
        assert_eq!(g.edges(), &[(0, 1), (0, 2), (1, 2), (2, 3)]);
        assert_eq!(g.preds(2), &[0, 1]);
        assert_eq!(g.last_use(0), 2);
    }

    #[test]
    fn topo_sort_is_deterministic_under_reordering() {
        // same graph, nodes given in reverse: must linearize identically
        let fwd = GraphSpec::new(
            "g",
            vec![nd("a"), nd("b"), nd("c"), nd("loss")],
            vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            64,
        )
        .unwrap();
        let rev = GraphSpec::new(
            "g",
            vec![nd("loss"), nd("c"), nd("b"), nd("a")],
            vec![(3, 2), (3, 1), (2, 1), (1, 0)],
            64,
        )
        .unwrap();
        let names = |g: &GraphSpec| -> Vec<&str> {
            g.nodes().iter().map(|n| n.name.as_str()).collect()
        };
        assert_eq!(names(&fwd), names(&rev));
        assert_eq!(fwd.edges(), rev.edges());
    }

    #[test]
    fn structural_errors_are_specific() {
        let e = GraphSpec::new("g", vec![], vec![], 1).unwrap_err();
        assert_eq!(e, GraphError::Empty);
        let e = GraphSpec::new("g", vec![nd("a"), nd("b")], vec![(0, 5)], 1).unwrap_err();
        assert_eq!(e, GraphError::DanglingEdge { from: 0, to: 5 });
        let e = GraphSpec::new("g", vec![nd("a"), nd("b")], vec![(0, 0), (0, 1)], 1).unwrap_err();
        assert_eq!(e, GraphError::SelfLoop(0));
        let e = GraphSpec::new(
            "g",
            vec![nd("a"), nd("b"), nd("c")],
            vec![(0, 1), (1, 2), (2, 1)],
            1,
        )
        .unwrap_err();
        assert!(matches!(e, GraphError::Cycle(_)), "{e}");
        // two entries (b has no preds)
        let e = GraphSpec::new("g", vec![nd("a"), nd("b"), nd("c")], vec![(0, 2), (1, 2)], 1)
            .unwrap_err();
        assert!(matches!(e, GraphError::MultipleEntries(ref ns) if ns.len() == 2), "{e}");
        // two exits
        let e = GraphSpec::new("g", vec![nd("a"), nd("b"), nd("c")], vec![(0, 1), (0, 2)], 1)
            .unwrap_err();
        assert!(matches!(e, GraphError::MultipleExits(ref ns) if ns.len() == 2), "{e}");
        // bad tape
        let mut bad = nd("b");
        bad.wabar = 10;
        let e = GraphSpec::new("g", vec![nd("a"), bad, nd("c")], vec![(0, 1), (1, 2)], 1)
            .unwrap_err();
        assert!(matches!(e, GraphError::BadTape { .. }), "{e}");
    }

    #[test]
    fn oversize_core_is_rejected() {
        // one skip spanning 10 nodes keeps every interior cut open
        let n = 12;
        let nodes: Vec<Node> = (0..n).map(|i| nd(&format!("n{i}"))).collect();
        let mut edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        edges.push((0, 10));
        let e = GraphSpec::new("g", nodes, edges, 1).unwrap_err();
        assert!(matches!(e, GraphError::CoreTooLarge { len: 11, .. }), "{e}");
    }

    #[test]
    fn json_round_trip_and_malformed_rejection() {
        let g = GraphSpec::from_json(
            &Value::parse(
                r#"{"name": "j", "input_bytes": 64,
                    "nodes": [
                      {"name": "a", "uf": 1.0, "ub": 2.0, "wa": 100, "wabar": 250},
                      {"uf": 1.0, "ub": 2.0, "wa": 50, "wabar": 50, "of": 8},
                      {"name": "loss", "uf": 0.5, "ub": 0.5, "wa": 4, "wabar": 4}
                    ],
                    "edges": [[0, 1], [0, 2], [1, 2]]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.nodes()[1].name, "n1");
        assert_eq!(g.nodes()[1].of, 8);
        assert!(!g.is_chain());

        for bad in [
            r#"{"nodes": [], "edges": []}"#,                           // missing input_bytes
            r#"{"input_bytes": 1, "nodes": 3, "edges": []}"#,          // nodes not array
            r#"{"input_bytes": 1, "nodes": [{"uf": 1}], "edges": []}"#, // node missing fields
            r#"{"input_bytes": 1,
                "nodes": [{"uf": 1, "ub": 1, "wa": 4, "wabar": 4}], "edges": [[0]]}"#,
        ] {
            let e = GraphSpec::from_json(&Value::parse(bad).unwrap()).unwrap_err();
            assert!(matches!(e, GraphError::Malformed(_)), "{bad}: {e}");
        }
    }
}
