//! # chainckpt — optimal checkpointing for heterogeneous chains
//!
//! Reproduction of Beaumont, Eyraud-Dubois, Herrmann, Joly, Shilova,
//! *"Optimal checkpointing for heterogeneous chains: how to train deep
//! neural networks with limited memory"* (Inria RR-9302, 2019).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * [`chain`] — the heterogeneous-chain cost model (per-stage forward /
//!   backward times, activation sizes `ω_a`, `ω_ā`, overheads) plus
//!   analytic profiles of the paper's benchmark networks (ResNet,
//!   DenseNet, Inception v3, VGG) and the memory-slot discretization.
//! * [`solver`] — schedule computation: the paper's optimal persistent
//!   dynamic program (Theorem 1, Algorithms 1–2), the [`solver::Planner`]
//!   that solves that DP once per chain and answers every memory budget
//!   (with a fingerprint-keyed table cache), and the three baselines the
//!   paper evaluates against (`store-all` ≡ plain PyTorch, `sequential` ≡
//!   `torch.utils.checkpoint_sequential`, `revolve` ≡ the Automatic
//!   Differentiation adaptation).
//! * [`simulator`] — a byte-accurate replay of any operation sequence
//!   (Table 1 semantics): validity, peak memory, makespan. Ground truth
//!   for every property test and for figure generation.
//! * [`graph`] — beyond chains: a validated DAG spec
//!   ([`graph::GraphSpec`]) decomposed at articulation cuts and
//!   frontier-fused into an ordinary chain the DP solves, verified by a
//!   multi-consumer replay ([`graph::simulate_graph`]) in which a value
//!   lives until its *last* consumer. Residual and U-Net presets pair
//!   with the native backend's executable geometries.
//! * [`plan`] — the lowering layer: compiles a solved schedule into an
//!   [`plan::ExecPlan`] — per-value liveness (explicit free points,
//!   subsuming `drop`), arena slot assignment with fixed byte offsets,
//!   and a plan-time peak that byte-matches the simulator. What the
//!   zero-allocation executor replays.
//! * [`backend`] — the tensor-engine seam: `Backend` / `Tensor` /
//!   `StageExecutable` traits with two implementations:
//!   [`backend::native`], a pure-Rust f32 engine with hand-written
//!   forward/backward kernels (runs anywhere, generates its chains
//!   in-process), and [`backend::pjrt`], the XLA path over AOT-compiled
//!   HLO-text artifacts from `python/compile/aot.py`.
//! * [`runtime`] — backend-generic registry: compiles every manifest
//!   signature once and serves executables to the replay loop.
//! * [`executor`] — runs a schedule against real compiled stages with
//!   a logical memory ledger, collecting gradients and the loss.
//! * [`estimator`] — the paper's §5.1 parameter-estimation phase: measures
//!   `u_f`, `u_b` per stage from the real executables.
//! * [`train`] — SGD training driver (synthetic data, loss logging).
//! * [`figures`] — regenerates every figure/table of the paper's §5.4
//!   evaluation as CSV series.
//! * [`service`] — the planning daemon: a std-only HTTP/1.1 JSON server
//!   (`chainckpt serve`) answering `/solve`, `/sweep`, `/simulate`,
//!   `/chains`, `/stats`, `/metrics` from a bounded thread pool, with
//!   the planner's fingerprint-keyed table cache shared across all
//!   connections.
//! * [`telemetry`] — crate-wide observability: the process-global
//!   metrics registry (atomic counters/gauges/histograms absorbing the
//!   planner-cache stats, DP-fill internals, and executor replay
//!   timings), the span tracer behind `--trace FILE` (Chrome
//!   trace-event JSON), and the predicted-vs-measured
//!   [`telemetry::DriftReport`].
//! * [`analysis`] — static analysis: [`analysis::verify`] independently
//!   re-proves a lowered [`plan::ExecPlan`] safe (def-before-use,
//!   exactly-once-free, no arena-byte sharing between live values,
//!   read/write disjointness, and a byte-exact independent peak
//!   recomputation) with algorithms disjoint from the lowering that
//!   built it; [`analysis::lint`] is the rule-driven architectural lint
//!   engine ratcheted by the allowlists under `rust/lints/`.
//! * [`api`] — **the public facade** over all of the above: [`api::ChainSpec`]
//!   (one description of "which chain"), [`api::MemBytes`] /
//!   [`api::SlotCount`] (typed units with the single human-suffix
//!   parser), [`api::PlanRequest`] → [`api::Plan`] (spec → plan →
//!   executed schedule), and [`api::Error`] with an [`api::ErrorKind`]
//!   that maps to HTTP statuses and CLI exit codes through one table
//!   each. The CLI, the service routes, the figure harness, and the
//!   benches all go through it — start here.

pub mod analysis;
pub mod api;
pub mod backend;
pub mod chain;
pub mod estimator;
pub mod executor;
pub mod figures;
pub mod graph;
pub mod plan;
pub mod runtime;
pub mod service;
pub mod simulator;
pub mod solver;
pub mod telemetry;
pub mod train;
pub mod util;

pub use chain::{Chain, Stage};
pub use simulator::{simulate, SimReport};
pub use solver::{
    optimal_schedule, periodic_schedule, revolve_schedule, store_all_schedule, Op, Planner,
    Schedule,
};
