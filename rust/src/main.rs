//! `chainckpt` CLI — the L3 coordinator binary.
//!
//! Subcommands:
//!   solve     compute a schedule for a chain spec and a memory budget
//!   simulate  replay all four strategies on a chain spec
//!   estimate  measure per-stage timings of compiled stages (§5.1)
//!   train     run SGD with a checkpointing schedule over real stages
//!   compare   measured throughput-vs-memory of all strategies (real run)
//!   figures   regenerate the paper's Figures 3–13 + summary as CSV
//!   serve     run the HTTP planning daemon (schedules as a service)
//!
//! Every subcommand goes through [`chainckpt::api`] — the same
//! `ChainSpec → PlanRequest → Plan` pipeline the planning service and
//! library callers use, so a chain spec means exactly the same thing on
//! every surface. Chain specs come from `--family/--depth/--image/--batch`
//! (built-in profile), `--preset NAME` (native-backend chain),
//! `--graph NAME|FILE` (a DAG, frontier-fused into a chain), or
//! `--chain FILE` (a JSON spec file in the service wire form, including
//! inline `"stages"` and on-disk `"manifest"` sources).
//!
//! Exit codes are keyed off [`chainckpt::api::ErrorKind`]: usage/spec
//! errors exit 2, an infeasible budget exits 3, backend/internal
//! failures exit 1.
//!
//! Run `chainckpt help` for flags.

use std::io::Write as _;
use std::path::PathBuf;

use chainckpt::api::{
    self, ChainSpec, Context as _, Error, ErrorKind, ExecuteOptions, MemBytes, Mode,
    PlanRequest, Result, Schedule, SlotCount,
};
use chainckpt::backend::Backend;
use chainckpt::chain::{Chain, DEFAULT_SLOTS};
use chainckpt::estimator::{
    chain_from_timings, estimate, format_table, measured_chain, EstimatorConfig,
};
use chainckpt::figures;
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::{paper_segment_sweep, periodic_schedule, store_all_schedule};
use chainckpt::telemetry;
use chainckpt::train::{mean_loss, SyntheticData, Trainer};
use chainckpt::util::json::Value;
use chainckpt::util::{fmt_bytes, Args, FLAG_SET};

const USAGE: &str = "\
chainckpt — optimal checkpointing for heterogeneous chains (RR-9302)

USAGE:
  chainckpt solve    [CHAIN SPEC] --memory 4G
                     [--slots 500] [--strategy optimal|revolve] [--show-ops]
                     [--verify-plan]
  chainckpt simulate [CHAIN SPEC]
  chainckpt estimate [--backend native|pjrt] [--preset default] [--artifacts DIR]
                     [--reps 5] [--warmup 2]
  chainckpt train    [--backend native|pjrt] [--preset default] [--artifacts DIR]
                     [--memory 8M | --memory-frac 0.75] [--steps 100] [--lr 0.05]
                     [--strategy optimal|sequential|revolve|pytorch]
                     [--segments 4] [--batches 8] [--log-every 10] [--out loss.csv]
                     [--lowered | --legacy] [--trace trace.json] [--verify-plan]
  chainckpt compare  [--backend native|pjrt] [--preset default] [--artifacts DIR]
                     [--points 6] [--out compare.csv] [--lowered | --legacy]
                     [--trace trace.json] [--verify-plan]
  chainckpt figures  [--fig 3|all] [--out results]
  chainckpt serve    [--addr 127.0.0.1] [--port 8080] [--threads N]
                     [--slots 500] [--queue 64] [--table-dir DIR]

CHAIN SPEC (solve/simulate; one pipeline with the service and library):
  --family resnet|densenet|inception|vgg  --depth N  --image N  --batch N
  --preset quickstart|default|wide|residual|unet
                                       a native-backend chain, planned
                                       with analytic roofline timings
  --graph residual|unet|FILE           a DAG: a named graph preset (the
                                       native geometry plus its skip
                                       edges) or a JSON file holding a
                                       graph object ({\"input_bytes\":…,
                                       \"nodes\":[…], \"edges\":[[0,1],…]},
                                       bare or wrapped as {\"graph\":…});
                                       validated, then frontier-fused
                                       into a chain the DP solves
  --chain FILE                         a JSON chain-spec file in the
                                       service wire form: {\"profile\":…},
                                       {\"preset\":…}, {\"graph\":…}, inline
                                       {\"stages\":…}, or {\"manifest\": \"DIR\"}

Execution path: train/compare replay through the *lowered* pipeline by
default — the schedule is compiled once into a slot-addressed ExecPlan
(liveness analysis + arena slot assignment, see the `plan` module) and
replayed over a persistent buffer pool with zero steady-state heap
allocations. --legacy forces the old per-op replay (the parity
reference); --lowered states the default explicitly. Lowered execution
needs the native engine's in-place kernels — on pjrt both flags fall
back to the legacy replay.

--verify-plan (solve/train/compare) lowers the chosen schedule(s) and
runs the static plan verifier over the result: an independent re-proof
of def-before-use, exactly-once frees, arena-slot disjointness, and a
byte-exact peak recomputation (see the `analysis` module). A rejected
plan aborts with exit 1 and prints every violation in the paper's
notation.

Observability: --trace FILE (train/compare) records every executed op
as a span — (op kind, stage, start, end, bytes) — into a bounded ring
and writes Chrome trace-event JSON on exit (open in Perfetto or
chrome://tracing). compare also prints a measured-vs-predicted drift
line per strategy: per-op-kind time ratios against the cost model and
the executor's peak against the simulator's byte-exact prediction.

The planning service answers POST /solve, /sweep, /simulate, /lower,
/prewarm and GET /chains, /stats, /healthz with JSON; repeated requests
for a chain hit the planner's shared DP-table cache. --port 0 picks a
free port. A single poll(2) event loop multiplexes every connection, so
thousands of idle keep-alive clients cost file descriptors, not threads.
--table-dir DIR persists solved DP tables to disk (versioned,
fingerprint-keyed, checksummed): a restarted daemon reloads them instead
of re-running the DP, and POST /prewarm fills cache + store up front.
POST /lower returns the lowered plan for a chain + budget (or explicit
\"ops\"): slot table with byte offsets, arena size, plan-time peak.
GET /metrics exposes the process-wide telemetry registry (planner
cache, solver fill, executor replay, service latency) in the
Prometheus text exposition format, ready to scrape.

Backends: --backend native (pure-Rust engine, chains generated in-process
from --preset quickstart|default|wide — the default) or --backend pjrt
(AOT HLO artifacts from --artifacts, requires the real xla bindings).

Sizes accept K/M/G/T suffixes, optionally with B/iB (1024-based):
512M, 512MiB, 1.5GB.

EXIT CODES (from api::ErrorKind, one table):
  0  success
  1  backend or internal failure
  2  usage error (bad flag, unknown chain/strategy, bad size string)
  3  valid request, but no schedule fits the memory budget
";

// ---------------------------------------------------------------------------
// Checked flag parsing: a malformed value is a *usage error* (exit 2 via
// ErrorKind::InvalidSpec), never a panic — `Args`' panicking getters are
// for benches, not for the documented CLI contract.
// ---------------------------------------------------------------------------

fn uint_flag(args: &Args, key: &str, default: u64) -> Result<u64> {
    match args.opt_str(key) {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| Error::invalid(format!("--{key}: bad integer '{s}'"))),
    }
}

fn usize_flag(args: &Args, key: &str, default: usize) -> Result<usize> {
    Ok(uint_flag(args, key, default as u64)? as usize)
}

fn f64_flag(args: &Args, key: &str, default: f64) -> Result<f64> {
    match args.opt_str(key) {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| Error::invalid(format!("--{key}: bad number '{s}'"))),
    }
}

/// A byte-size flag through the facade's one suffix parser.
fn mem_flag(args: &Args, key: &str) -> Result<Option<MemBytes>> {
    match args.opt_str(key) {
        None => Ok(None),
        Some(s) => Ok(Some(
            MemBytes::parse(s).with_context(|| format!("--{key}"))?,
        )),
    }
}

/// The `--graph ARG` source: a named graph preset
/// ([`chainckpt::graph::NAMES`]) or a JSON file holding a graph spec
/// (a bare graph object, or one wrapped as `{"graph": {…}}` in the
/// service wire form). Bad input is a usage error (exit 2).
fn graph_spec_arg(arg: &str) -> Result<ChainSpec> {
    if let Some(g) = chainckpt::graph::preset(arg) {
        return Ok(ChainSpec::graph(g));
    }
    let text = std::fs::read_to_string(arg)
        .with_context(|| {
            format!(
                "--graph '{arg}': not a graph preset ({}) and not a readable file",
                chainckpt::graph::NAMES.join("/")
            )
        })
        .kind(ErrorKind::InvalidSpec)?;
    let v = Value::parse(&text)
        .with_context(|| format!("parsing graph file '{arg}'"))
        .kind(ErrorKind::InvalidSpec)?;
    let body = v.get("graph").unwrap_or(&v);
    match chainckpt::graph::GraphSpec::from_json(body) {
        Ok(g) => Ok(ChainSpec::graph(g)),
        Err(e) => Err(Error::invalid(format!("--graph '{arg}': {e}"))),
    }
}

/// The unified chain spec of `solve`/`simulate`: `--preset`, `--graph`,
/// `--chain FILE`, or the profile flags (`--family/--depth/--image/--batch`).
fn chain_spec(args: &Args) -> Result<ChainSpec> {
    if let Some(name) = args.opt_str("preset") {
        return Ok(ChainSpec::preset(name));
    }
    if let Some(arg) = args.opt_str("graph") {
        return graph_spec_arg(arg);
    }
    if let Some(path) = args.opt_str("chain") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading chain spec file '{path}'"))
            .kind(ErrorKind::InvalidSpec)?;
        let v = Value::parse(&text)
            .with_context(|| format!("parsing chain spec file '{path}'"))
            .kind(ErrorKind::InvalidSpec)?;
        // the *local* parser: a CLI-supplied spec file may also name an
        // on-disk {"manifest": DIR} (the service's wire parser rejects it)
        return ChainSpec::from_json_local(&v);
    }
    // checked u32 for --depth: `as u32` would wrap 2^32+18 to depth 18,
    // the exact aliasing the JSON spec path rejects
    let depth64 = uint_flag(args, "depth", 101)?;
    let depth = u32::try_from(depth64)
        .map_err(|_| Error::invalid(format!("--depth {depth64} out of range")))?;
    Ok(ChainSpec::profile(
        args.str("family", "resnet"),
        depth,
        uint_flag(args, "image", 1000)?,
        uint_flag(args, "batch", 8)?,
    ))
}

fn describe(chain: &Chain, sched: &Schedule, budget: Option<MemBytes>, unit: &str) -> Result<()> {
    let rep = simulate(chain, sched)
        .map_err(|e| Error::internal(format!("invalid schedule: {e}")))?;
    println!("strategy        : {}", sched.strategy);
    println!("ops             : {}", rep.ops);
    println!("recomputed fwds : {}", rep.recomputed_forwards);
    println!("makespan        : {:.3} {unit}", rep.makespan);
    println!("ideal (no ckpt) : {:.3} {unit}", chain.ideal_time());
    println!("overhead        : {:.1} %", 100.0 * (rep.makespan / chain.ideal_time() - 1.0));
    println!("peak memory     : {}", fmt_bytes(rep.peak_bytes));
    if let Some(m) = budget {
        println!("budget          : {m} (fits: {})", rep.peak_bytes <= m.get());
    }
    Ok(())
}

fn solve_mode(args: &Args) -> Result<Mode> {
    match args.str("strategy", "optimal").as_str() {
        "optimal" => Ok(Mode::Full),
        "revolve" => Ok(Mode::AdRevolve),
        s => Err(Error::invalid(format!("--strategy {s}: solve supports optimal|revolve"))),
    }
}

/// `--verify-plan`: run the static verifier (analysis/verify.rs) over a
/// lowered plan and print the one-line verdict. A rejected plan is an
/// internal error (exit 1) with every violation listed.
fn print_verdict(plan: &chainckpt::plan::ExecPlan) -> Result<()> {
    let verdict = chainckpt::analysis::verify_counted(plan);
    println!("static verify   : {verdict}");
    if !verdict.is_clean() {
        for v in &verdict.violations {
            println!("  {v}");
        }
        return Err(Error::internal("lowered plan failed static verification"));
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let spec = chain_spec(args)?;
    let memory = mem_flag(args, "memory")?.unwrap_or(MemBytes::new(4 << 30));
    let slots = SlotCount::new(usize_flag(args, "slots", DEFAULT_SLOTS)?);
    let mode = solve_mode(args)?;
    let t0 = std::time::Instant::now();
    let plan = PlanRequest::new(spec, memory).slots(slots).mode(mode).plan()?;
    println!("chain {} (L+1 = {}), budget {memory}", plan.chain().name, plan.chain().len());
    println!(
        "plan time       : {:.2} s (S = {}; one DP table answers every budget ≤ {memory})",
        t0.elapsed().as_secs_f64(),
        slots.get(),
    );
    if let Some((flo, fhi)) = plan.feasible_range() {
        println!("feasible range  : {flo} – {fhi}");
    }
    let sched = plan.schedule()?; // ErrorKind::InfeasibleBudget → exit 3
    describe(plan.chain(), &sched, Some(memory), "ms")?;
    if args.has("verify-plan") {
        let lowered = plan.lower_schedule(&sched)?;
        print_verdict(&lowered)?;
    }
    if args.has("show-ops") {
        println!("{}", sched.compact());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = chain_spec(args)?;
    let chain = spec.resolve()?;
    // the batch the throughput column divides by: an explicit --batch
    // wins; otherwise it must match the chain actually built — the
    // spec's own batch hint (profile batch / preset or manifest input
    // shape), falling back to 1 when the source names none (inline)
    let batch = match args.opt_str("batch") {
        Some(_) => uint_flag(args, "batch", 8)?,
        None => spec.batch_hint().unwrap_or(1).max(1),
    };
    println!(
        "chain {} (L+1 = {}), store-all memory {}",
        chain.name,
        chain.len(),
        fmt_bytes(chain.store_all_memory())
    );
    let p = figures::panel(&chain, batch, figures::DEVICE_MEMORY);
    println!(
        "{:<12} {:>14} {:>12} {:>12} {:>14}",
        "strategy", "param", "peak", "makespan", "throughput"
    );
    for pt in &p.points {
        println!(
            "{:<12} {:>14} {:>12} {:>9.2} ms {:>10.2} im/s",
            pt.strategy.to_string(),
            if pt.strategy == chainckpt::solver::StrategyKind::Periodic {
                format!("{} segs", pt.param)
            } else if pt.param > 0 {
                fmt_bytes(pt.param)
            } else {
                "-".into()
            },
            fmt_bytes(pt.peak_bytes),
            pt.makespan_ms,
            pt.throughput
        );
    }
    match figures::optimal_vs_sequential(&p) {
        Ok((gain, seq, opt)) => println!(
            "optimal vs best sequential: {:.2} vs {:.2} im/s → +{:.1} %",
            opt,
            seq,
            100.0 * gain
        ),
        Err(e) => println!("optimal vs best sequential: n/a ({e:#})"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Backend selection for the execution subcommands
// ---------------------------------------------------------------------------

fn announce<B: Backend>(rt: &Runtime<B>) {
    println!(
        "[{}] compiled {} signatures for {} stages ({} params)",
        rt.backend.name(),
        rt.executable_count(),
        rt.manifest.stages.len(),
        rt.manifest.param_count
    );
}

fn load_native(args: &Args) -> Result<Runtime<chainckpt::backend::NativeBackend>> {
    let preset = args.str("preset", "default");
    println!("building native preset '{preset}' …");
    // unknown preset name = usage error (exit 2, like `solve --preset`);
    // a failure compiling a *known* preset is a backend fault (exit 1)
    let manifest = chainckpt::backend::native::presets::preset(&preset)
        .kind(ErrorKind::UnknownChain)?;
    let rt = Runtime::native(manifest).kind(ErrorKind::Backend)?;
    announce(&rt);
    Ok(rt)
}

fn load_pjrt(args: &Args) -> Result<Runtime<chainckpt::backend::PjrtBackend>> {
    let dir = args.str("artifacts", "artifacts/default");
    println!("loading artifacts from {dir} …");
    let rt = Runtime::load(&dir)
        .with_context(|| format!("loading {dir} (run `make artifacts` first?)"))
        .kind(ErrorKind::Backend)?;
    announce(&rt);
    Ok(rt)
}

/// The `--lowered | --legacy` pair of `train`/`compare`. Lowered is the
/// default on engines with in-place kernels; `--legacy` opts out, and
/// backends without the kernels (pjrt) always run legacy. Passing both
/// flags is a usage error.
fn lowered_flag<B: Backend>(args: &Args) -> Result<bool> {
    if args.has("lowered") && args.has("legacy") {
        return Err(Error::invalid("--lowered and --legacy are mutually exclusive"));
    }
    Ok(B::SUPPORTS_LOWERED && !args.has("legacy"))
}

/// Run `f` on the runtime of the selected backend (monomorphized per
/// engine — no trait objects on the hot path).
macro_rules! with_backend {
    ($args:expr, $f:ident) => {
        match $args.str("backend", "native").as_str() {
            "native" => $f(&load_native($args)?, $args),
            "pjrt" => $f(&load_pjrt($args)?, $args),
            other => Err(Error::invalid(format!("--backend {other}: use native|pjrt"))),
        }
    };
}

fn cmd_estimate(args: &Args) -> Result<()> {
    with_backend!(args, estimate_on)
}

fn estimate_on<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let defaults = EstimatorConfig::default();
    let cfg = EstimatorConfig {
        reps: usize_flag(args, "reps", defaults.reps)?,
        warmup: usize_flag(args, "warmup", defaults.warmup)?,
    };
    println!(
        "estimator config: reps = {} (median taken), warmup = {} (untimed)",
        cfg.reps, cfg.warmup
    );
    let timings = estimate(rt, cfg).kind(ErrorKind::Backend)?;
    // assemble from the timings already in hand (measured_chain would
    // re-run the whole timing loop)
    let chain = chain_from_timings(&rt.manifest, &timings);
    print!("{}", format_table(&timings, &chain));
    println!(
        "ideal iteration: {:.1} µs; store-all memory: {}",
        chain.ideal_time(),
        fmt_bytes(chain.store_all_memory())
    );
    Ok(())
}

/// The `--trace FILE` flag of `train`/`compare`: arm the process-wide
/// span tracer (bounded ring — memory stays flat under any run length)
/// before the first replay. Returns the dump path when armed.
fn trace_arm(args: &Args) -> Option<String> {
    let path = args.opt_str("trace")?;
    telemetry::trace_start(telemetry::DEFAULT_TRACE_CAPACITY);
    Some(path.to_string())
}

/// Stop the tracer and write what it captured as Chrome trace-event
/// JSON (Perfetto / chrome://tracing open it directly).
fn trace_dump(path: &str) -> Result<()> {
    let (events, dropped) = telemetry::trace_stop();
    std::fs::write(path, telemetry::chrome_trace_json(&events))?;
    if dropped > 0 {
        println!("wrote {path} ({} span events; {dropped} older ones dropped by the ring)",
            events.len());
    } else {
        println!("wrote {path} ({} span events)", events.len());
    }
    Ok(())
}

fn pick_schedule(args: &Args, chain: &Chain, memory: MemBytes) -> Result<Schedule> {
    // The DP strategies go through one api::Plan at the requested budget:
    // repeated picks for the same measured chain (e.g. train restarts)
    // hit the planner's shared table cache underneath the facade.
    let dp = |mode: Mode| {
        PlanRequest::new(ChainSpec::inline(chain.clone()), memory).mode(mode).plan()?.schedule()
    };
    match args.str("strategy", "optimal").as_str() {
        "optimal" => dp(Mode::Full).with_context(|| format!("no optimal schedule fits {memory}")),
        "revolve" => {
            dp(Mode::AdRevolve).with_context(|| format!("no revolve schedule fits {memory}"))
        }
        "sequential" => Ok(periodic_schedule(chain, usize_flag(args, "segments", 4)?)),
        "pytorch" => Ok(store_all_schedule(chain)),
        s => Err(Error::invalid(format!("unknown --strategy {s}"))),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    with_backend!(args, train_on)
}

fn train_on<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let cfg = EstimatorConfig::default();
    let chain = measured_chain(rt, cfg).kind(ErrorKind::Backend)?;
    let store_all_mem = chain.store_all_memory();
    // default budget: 75% of store-all (short chains — quickstart is 5
    // stages — have no feasible persistent schedule much below that;
    // --memory or --memory-frac override)
    let frac = f64_flag(args, "memory-frac", 0.75)?;
    let memory = mem_flag(args, "memory")?
        .unwrap_or(MemBytes::new((store_all_mem as f64 * frac) as u64));
    println!(
        "measured chain: ideal {:.1} µs/iter, store-all {}, budget {memory}",
        chain.ideal_time(),
        fmt_bytes(store_all_mem),
    );
    let sched = pick_schedule(args, &chain, memory)?;
    describe(&chain, &sched, Some(memory), "µs")?;
    if args.has("verify-plan") {
        let plan = chainckpt::plan::lower(&chain, &sched)
            .map_err(|e| Error::internal(format!("schedule does not lower: {e}")))?;
        print_verdict(&plan)?;
    }
    let lowered = lowered_flag::<B>(args)?;

    let steps = usize_flag(args, "steps", 100)?;
    let lr = f64_flag(args, "lr", 0.05)? as f32;
    let n_batches = usize_flag(args, "batches", 8)?;
    let log_every = usize_flag(args, "log-every", 10)?;
    let data = SyntheticData::generate(&rt.manifest, n_batches, 7).kind(ErrorKind::Backend)?;
    let mut trainer =
        Trainer::new(rt, sched, lr, Some(memory.get()), 42).kind(ErrorKind::Backend)?;
    if lowered {
        trainer.lower().kind(ErrorKind::Backend)?;
        let plan = trainer.lowered_plan().expect("just lowered");
        println!(
            "lowered: {} values → {} arena slots, arena {}, plan-time peak {}",
            plan.values.len(),
            plan.slots.len(),
            fmt_bytes(plan.arena_bytes),
            fmt_bytes(plan.peak_bytes)
        );
    }
    let trace_path = trace_arm(args);
    let logs = trainer
        .train(&data, steps, log_every, |log| {
            println!(
                "step {:>5}  loss {:.6}  {:.1} ms/step  peak {}",
                log.step,
                log.loss,
                log.step_time_s * 1e3,
                fmt_bytes(log.peak_bytes)
            );
        })
        .kind(ErrorKind::Backend)?;
    if let Some(path) = &trace_path {
        trace_dump(path)?;
    }
    let first = logs.first().map(|l| l.loss).unwrap_or(f32::NAN);
    let last = mean_loss(&logs, 10);
    println!("final loss (mean of last 10): {last:.6} (from {first:.6})");
    let peak = logs.iter().map(|l| l.peak_bytes).max().unwrap_or(0);
    println!(
        "peak memory {} within budget {memory} (ledger-enforced); loss decreased: {}",
        fmt_bytes(peak),
        last < first
    );
    if let Some(out) = args.opt_str("out") {
        let mut f = std::fs::File::create(out)?;
        writeln!(f, "step,loss,step_time_s,peak_bytes")?;
        for l in &logs {
            writeln!(f, "{},{},{},{}", l.step, l.loss, l.step_time_s, l.peak_bytes)?;
        }
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    with_backend!(args, compare_on)
}

fn compare_on<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let cfg = EstimatorConfig::default();
    let chain = measured_chain(rt, cfg).kind(ErrorKind::Backend)?;
    let points = usize_flag(args, "points", 6)?;
    let reps = usize_flag(args, "reps", 3)?;
    let data =
        SyntheticData::<B::Tensor>::generate(&rt.manifest, 2, 7).kind(ErrorKind::Backend)?;
    let hi = chain.store_all_memory();
    let lo = chain.min_memory_hint();
    let lowered = lowered_flag::<B>(args)?;
    println!(
        "execution path: {}",
        if lowered { "lowered (pooled arena, zero-alloc steady state)" } else { "legacy per-op replay" }
    );
    // the measured chain is the executor's own cost model (µs units), so
    // the drift report's time ratios are meaningful, not unit-skewed
    let opts = ExecuteOptions {
        reps,
        lowered,
        chain: Some(chain.clone()),
        ..ExecuteOptions::default()
    };
    let trace_path = trace_arm(args);
    let mut rows: Vec<(String, String, u64, f64)> = Vec::new();

    // every row — baselines and DP strategies alike — is one
    // api::execute_schedule measurement (fresh executor, warmup + timed
    // median), the same path Plan::execute and the executor bench use
    let verify_plan = args.has("verify-plan");
    let mut run_measured = |name: String, param: String, sched: &Schedule| -> Result<()> {
        if verify_plan {
            let plan = chainckpt::plan::lower(&chain, sched)
                .map_err(|e| Error::internal(format!("schedule does not lower: {e}")))?;
            print_verdict(&plan)?;
        }
        let rep = api::execute_schedule(rt, sched, &data, &opts)?;
        println!(
            "{:<12} {:>12} peak {:>12} {:>8.1} ms/iter {:>8.2} im/s",
            name,
            param,
            fmt_bytes(rep.peak.get()),
            rep.elapsed_s * 1e3,
            rep.throughput
        );
        if let Some(d) = &rep.drift {
            println!("{:<12} {:>12} {}", "", "", d.summary());
        }
        rows.push((name, param, rep.peak.get(), rep.throughput));
        Ok(())
    };

    run_measured("pytorch".into(), "-".into(), &store_all_schedule(&chain))?;
    for k in paper_segment_sweep(chain.len() - 1).into_iter().take(points) {
        run_measured("sequential".into(), format!("{k} segs"), &periodic_schedule(&chain, k))?;
    }
    // One DP table per mode serves the whole budget sweep. The plan
    // discretizes against the top budget, so a sub-budget point only sees
    // `S·m/hi` of the grid — double the paper's S=500 to keep low-budget
    // rows at least as precise as the old per-budget solves were at
    // mid-sweep (still ≥3× less DP work than per-budget tables).
    let budgets: Vec<MemBytes> = (1..=points as u64)
        .map(|i| MemBytes::new(lo + (hi - lo) * i / points as u64))
        .collect();
    let sweep_slots = SlotCount::new(2 * DEFAULT_SLOTS);
    let t0 = std::time::Instant::now();
    let opt_plan = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes::new(hi))
        .slots(sweep_slots)
        .plan()?;
    let rev_plan = PlanRequest::new(ChainSpec::inline(chain.clone()), MemBytes::new(hi))
        .slots(sweep_slots)
        .mode(Mode::AdRevolve)
        .plan()?;
    let opt_scheds = opt_plan.sweep(&budgets);
    let rev_scheds = rev_plan.sweep(&budgets);
    println!(
        "planned {} budgets from 2 DP tables in {:.2} s",
        budgets.len(),
        t0.elapsed().as_secs_f64()
    );
    for ((&m, s_opt), s_rev) in budgets.iter().zip(opt_scheds).zip(rev_scheds) {
        if let Some(s) = s_opt {
            run_measured("optimal".into(), fmt_bytes(m.get()), &s)?;
        }
        if let Some(s) = s_rev {
            run_measured("revolve".into(), fmt_bytes(m.get()), &s)?;
        }
    }
    if let Some(path) = &trace_path {
        trace_dump(path)?;
    }
    if let Some(out) = args.opt_str("out") {
        let mut f = std::fs::File::create(out)?;
        writeln!(f, "strategy,param,peak_bytes,throughput_img_s")?;
        for (n, p, peak, thr) in &rows {
            writeln!(f, "{n},{p},{peak},{thr}")?;
        }
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.str("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let which = args.str("fig", "all");
    let figs: Vec<u32> = if which == "all" || which == FLAG_SET {
        (3..=13).collect()
    } else {
        let f: u32 = which
            .parse()
            .context("--fig must be 3..13 or 'all'")
            .kind(ErrorKind::InvalidSpec)?;
        if !(3..=13).contains(&f) {
            return Err(Error::invalid(format!("--fig {f}: the paper has figures 3..13")));
        }
        vec![f]
    };
    let mut all_panels = Vec::new();
    for f in figs {
        let t0 = std::time::Instant::now();
        let panels = figures::figure(f);
        let path = out_dir.join(format!("figure{f}.csv"));
        std::fs::write(&path, figures::to_csv(&panels))?;
        let gain = figures::summary_gain(&panels);
        println!(
            "figure {f}: {} panels → {} ({:.1} s){}",
            panels.len(),
            path.display(),
            t0.elapsed().as_secs_f64(),
            gain.map(|g| format!("  avg optimal-vs-sequential gain: +{:.1} %", 100.0 * g))
                .unwrap_or_default()
        );
        all_panels.extend(panels);
    }
    if let Some(g) = figures::summary_gain(&all_panels) {
        println!(
            "SUMMARY over {} panels: optimal beats best sequential by {:.1} % on average (paper: 17.2 %)",
            all_panels.len(),
            100.0 * g
        );
        let path = out_dir.join("summary.csv");
        let mut s = String::from("chain,batch,gain_pct,seq_img_s,opt_img_s\n");
        for p in &all_panels {
            if let Ok((gain, seq, opt)) = figures::optimal_vs_sequential(p) {
                s.push_str(&format!(
                    "{},{},{:.2},{:.3},{:.3}\n",
                    p.chain_name,
                    p.batch,
                    100.0 * gain,
                    seq,
                    opt
                ));
            }
        }
        std::fs::write(&path, s)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = chainckpt::service::ServiceConfig {
        addr: format!("{}:{}", args.str("addr", "127.0.0.1"), uint_flag(args, "port", 8080)?),
        workers: usize_flag(args, "threads", 0)?, // 0 = one per core
        queue_depth: usize_flag(args, "queue", 64)?,
        slots: usize_flag(args, "slots", DEFAULT_SLOTS)?,
        table_dir: args.opt_str("table-dir").map(PathBuf::from),
        ..Default::default()
    };
    let server = chainckpt::service::serve(cfg)?;
    println!("planning service listening on http://{}", server.addr());
    println!(
        "endpoints: POST /solve /sweep /simulate /lower /prewarm · GET /chains /stats /metrics /healthz"
    );
    if let Some(dir) = chainckpt::solver::table_dir() {
        println!("persistent table store: {}", dir.display());
    }
    println!("try: curl -s http://{}/chains", server.addr());
    server.join();
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "solve" => cmd_solve(&args),
        "simulate" => cmd_simulate(&args),
        "estimate" => cmd_estimate(&args),
        "train" => cmd_train(&args),
        "compare" => cmd_compare(&args),
        "figures" => cmd_figures(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(ErrorKind::InvalidSpec.exit_code());
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        // the one ErrorKind → exit-code table (documented in USAGE)
        std::process::exit(e.kind().exit_code());
    }
}
