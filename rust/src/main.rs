//! `chainckpt` CLI — the L3 coordinator binary.
//!
//! Subcommands:
//!   solve     compute a schedule for a profile chain and a memory budget
//!   simulate  replay all four strategies on a profile chain
//!   estimate  measure per-stage timings of compiled stages (§5.1)
//!   train     run SGD with a checkpointing schedule over real stages
//!   compare   measured throughput-vs-memory of all strategies (real run)
//!   figures   regenerate the paper's Figures 3–13 + summary as CSV
//!   serve     run the HTTP planning daemon (schedules as a service)
//!
//! The execution subcommands (`estimate`/`train`/`compare`) take
//! `--backend native|pjrt`: `native` (the default) runs the pure-Rust
//! engine on an in-process preset chain (`--preset quickstart|default|
//! wide`); `pjrt` loads AOT artifacts from `--artifacts <dir>`.
//!
//! Run `chainckpt help` for flags.

use std::io::Write as _;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};
use chainckpt::backend::Backend;
use chainckpt::chain::{profiles, Chain, DEFAULT_SLOTS};
use chainckpt::estimator::{
    chain_from_timings, estimate, format_table, measured_chain, EstimatorConfig,
};
use chainckpt::figures;
use chainckpt::runtime::Runtime;
use chainckpt::simulator::simulate;
use chainckpt::solver::{
    paper_segment_sweep, periodic_schedule, solve, store_all_schedule, Mode, Planner, Schedule,
};
use chainckpt::train::{mean_loss, SyntheticData, Trainer};
use chainckpt::util::{fmt_bytes, Args, FLAG_SET};

const USAGE: &str = "\
chainckpt — optimal checkpointing for heterogeneous chains (RR-9302)

USAGE:
  chainckpt solve    --family resnet --depth 101 --image 1000 --batch 8 --memory 4G
                     [--slots 500] [--strategy optimal|revolve] [--show-ops]
  chainckpt simulate --family resnet --depth 101 --image 1000 --batch 8
  chainckpt estimate [--backend native|pjrt] [--preset default] [--artifacts DIR]
                     [--reps 5] [--warmup 2]
  chainckpt train    [--backend native|pjrt] [--preset default] [--artifacts DIR]
                     [--memory 8M | --memory-frac 0.75] [--steps 100] [--lr 0.05]
                     [--strategy optimal|sequential|revolve|pytorch]
                     [--segments 4] [--batches 8] [--log-every 10] [--out loss.csv]
  chainckpt compare  [--backend native|pjrt] [--preset default] [--artifacts DIR]
                     [--points 6] [--out compare.csv]
  chainckpt figures  [--fig 3|all] [--out results]
  chainckpt serve    [--addr 127.0.0.1] [--port 8080] [--threads N]
                     [--slots 500] [--queue 64]

The planning service answers POST /solve, /sweep, /simulate and
GET /chains, /stats, /healthz with JSON; repeated requests for a chain
hit the planner's shared DP-table cache. --port 0 picks a free port.

Backends: --backend native (pure-Rust engine, chains generated in-process
from --preset quickstart|default|wide — the default) or --backend pjrt
(AOT HLO artifacts from --artifacts, requires the real xla bindings).

Profile flags: --family resnet|densenet|inception|vgg  --depth N  --image N  --batch N
Sizes accept K/M/G suffixes (1024-based).
";

fn profile_chain(args: &Args) -> Chain {
    let family = args.str("family", "resnet");
    let depth = args.u32("depth", 101);
    let image = args.u64("image", 1000);
    let batch = args.u64("batch", 8);
    profiles::by_name(&family, depth, image, batch)
}

fn describe(chain: &Chain, sched: &Schedule, budget: Option<u64>, unit: &str) -> Result<()> {
    let rep = simulate(chain, sched).map_err(|e| anyhow::anyhow!("invalid schedule: {e}"))?;
    println!("strategy        : {}", sched.strategy);
    println!("ops             : {}", rep.ops);
    println!("recomputed fwds : {}", rep.recomputed_forwards);
    println!("makespan        : {:.3} {unit}", rep.makespan);
    println!("ideal (no ckpt) : {:.3} {unit}", chain.ideal_time());
    println!("overhead        : {:.1} %", 100.0 * (rep.makespan / chain.ideal_time() - 1.0));
    println!("peak memory     : {}", fmt_bytes(rep.peak_bytes));
    if let Some(m) = budget {
        println!("budget          : {} (fits: {})", fmt_bytes(m), rep.peak_bytes <= m);
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let chain = profile_chain(args);
    let memory = args.u64("memory", 4 << 30);
    let slots = args.usize("slots", DEFAULT_SLOTS);
    let mode = match args.str("strategy", "optimal").as_str() {
        "optimal" => Mode::Full,
        "revolve" => Mode::AdRevolve,
        s => bail!("--strategy {s}: solve supports optimal|revolve"),
    };
    println!("chain {} (L+1 = {}), budget {}", chain.name, chain.len(), fmt_bytes(memory));
    let t0 = std::time::Instant::now();
    let planner = Planner::new(&chain, memory, slots, mode);
    println!(
        "plan time       : {:.2} s (S = {slots}; one DP table answers every budget ≤ {})",
        t0.elapsed().as_secs_f64(),
        fmt_bytes(memory)
    );
    if let Some((flo, fhi)) = planner.feasible_range() {
        println!("feasible range  : {} – {}", fmt_bytes(flo), fmt_bytes(fhi));
    }
    let Some(sched) = planner.schedule_at(memory) else {
        bail!("no feasible persistent schedule within {}", fmt_bytes(memory));
    };
    describe(&chain, &sched, Some(memory), "ms")?;
    if args.has("show-ops") {
        println!("{}", sched.compact());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let chain = profile_chain(args);
    let batch = args.u64("batch", 8);
    println!(
        "chain {} (L+1 = {}), store-all memory {}",
        chain.name,
        chain.len(),
        fmt_bytes(chain.store_all_memory())
    );
    let p = figures::panel(&chain, batch, figures::DEVICE_MEMORY);
    println!(
        "{:<12} {:>14} {:>12} {:>12} {:>14}",
        "strategy", "param", "peak", "makespan", "throughput"
    );
    for pt in &p.points {
        println!(
            "{:<12} {:>14} {:>12} {:>9.2} ms {:>10.2} im/s",
            pt.strategy.to_string(),
            if pt.strategy == chainckpt::solver::StrategyKind::Periodic {
                format!("{} segs", pt.param)
            } else if pt.param > 0 {
                fmt_bytes(pt.param)
            } else {
                "-".into()
            },
            fmt_bytes(pt.peak_bytes),
            pt.makespan_ms,
            pt.throughput
        );
    }
    match figures::optimal_vs_sequential(&p) {
        Ok((gain, seq, opt)) => println!(
            "optimal vs best sequential: {:.2} vs {:.2} im/s → +{:.1} %",
            opt,
            seq,
            100.0 * gain
        ),
        Err(e) => println!("optimal vs best sequential: n/a ({e:#})"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Backend selection for the execution subcommands
// ---------------------------------------------------------------------------

fn announce<B: Backend>(rt: &Runtime<B>) {
    println!(
        "[{}] compiled {} signatures for {} stages ({} params)",
        rt.backend.name(),
        rt.executable_count(),
        rt.manifest.stages.len(),
        rt.manifest.param_count
    );
}

fn load_native(args: &Args) -> Result<Runtime<chainckpt::backend::NativeBackend>> {
    let preset = args.str("preset", "default");
    println!("building native preset '{preset}' …");
    let rt = Runtime::native_preset(&preset)?;
    announce(&rt);
    Ok(rt)
}

fn load_pjrt(args: &Args) -> Result<Runtime<chainckpt::backend::PjrtBackend>> {
    let dir = args.str("artifacts", "artifacts/default");
    println!("loading artifacts from {dir} …");
    let rt = Runtime::load(&dir)
        .with_context(|| format!("loading {dir} (run `make artifacts` first?)"))?;
    announce(&rt);
    Ok(rt)
}

/// Run `f` on the runtime of the selected backend (monomorphized per
/// engine — no trait objects on the hot path).
macro_rules! with_backend {
    ($args:expr, $f:ident) => {
        match $args.str("backend", "native").as_str() {
            "native" => $f(&load_native($args)?, $args),
            "pjrt" => $f(&load_pjrt($args)?, $args),
            other => bail!("--backend {other}: use native|pjrt"),
        }
    };
}

fn cmd_estimate(args: &Args) -> Result<()> {
    with_backend!(args, estimate_on)
}

fn estimate_on<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let defaults = EstimatorConfig::default();
    let cfg = EstimatorConfig {
        reps: args.usize("reps", defaults.reps),
        warmup: args.usize("warmup", defaults.warmup),
    };
    println!(
        "estimator config: reps = {} (median taken), warmup = {} (untimed)",
        cfg.reps, cfg.warmup
    );
    let timings = estimate(rt, cfg)?;
    // assemble from the timings already in hand (measured_chain would
    // re-run the whole timing loop)
    let chain = chain_from_timings(&rt.manifest, &timings);
    print!("{}", format_table(&timings, &chain));
    println!(
        "ideal iteration: {:.1} µs; store-all memory: {}",
        chain.ideal_time(),
        fmt_bytes(chain.store_all_memory())
    );
    Ok(())
}

fn pick_schedule(args: &Args, chain: &Chain, memory: u64) -> Result<Schedule> {
    // The DP strategies go through `solve` (a Planner at its own budget):
    // repeated picks for the same measured chain (e.g. train restarts)
    // hit the shared table cache.
    match args.str("strategy", "optimal").as_str() {
        "optimal" => solve(chain, memory, DEFAULT_SLOTS, Mode::Full)
            .with_context(|| format!("no optimal schedule fits {}", fmt_bytes(memory))),
        "revolve" => solve(chain, memory, DEFAULT_SLOTS, Mode::AdRevolve)
            .with_context(|| format!("no revolve schedule fits {}", fmt_bytes(memory))),
        "sequential" => Ok(periodic_schedule(chain, args.usize("segments", 4))),
        "pytorch" => Ok(store_all_schedule(chain)),
        s => bail!("unknown --strategy {s}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    with_backend!(args, train_on)
}

fn train_on<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let cfg = EstimatorConfig::default();
    let chain = measured_chain(rt, cfg)?;
    let store_all_mem = chain.store_all_memory();
    // default budget: 75% of store-all (short chains — quickstart is 5
    // stages — have no feasible persistent schedule much below that;
    // --memory or --memory-frac override)
    let frac = args.f64("memory-frac", 0.75);
    let memory = args.u64("memory", (store_all_mem as f64 * frac) as u64);
    println!(
        "measured chain: ideal {:.1} µs/iter, store-all {}, budget {}",
        chain.ideal_time(),
        fmt_bytes(store_all_mem),
        fmt_bytes(memory)
    );
    let sched = pick_schedule(args, &chain, memory)?;
    describe(&chain, &sched, Some(memory), "µs")?;

    let steps = args.usize("steps", 100);
    let lr = args.f64("lr", 0.05) as f32;
    let n_batches = args.usize("batches", 8);
    let log_every = args.usize("log-every", 10);
    let data = SyntheticData::generate(&rt.manifest, n_batches, 7)?;
    let mut trainer = Trainer::new(rt, sched, lr, Some(memory), 42)?;
    let logs = trainer.train(&data, steps, log_every, |log| {
        println!(
            "step {:>5}  loss {:.6}  {:.1} ms/step  peak {}",
            log.step,
            log.loss,
            log.step_time_s * 1e3,
            fmt_bytes(log.peak_bytes)
        );
    })?;
    let first = logs.first().map(|l| l.loss).unwrap_or(f32::NAN);
    let last = mean_loss(&logs, 10);
    println!("final loss (mean of last 10): {last:.6} (from {first:.6})");
    let peak = logs.iter().map(|l| l.peak_bytes).max().unwrap_or(0);
    println!(
        "peak memory {} within budget {} (ledger-enforced); loss decreased: {}",
        fmt_bytes(peak),
        fmt_bytes(memory),
        last < first
    );
    if let Some(out) = args.opt_str("out") {
        let mut f = std::fs::File::create(out)?;
        writeln!(f, "step,loss,step_time_s,peak_bytes")?;
        for l in &logs {
            writeln!(f, "{},{},{},{}", l.step, l.loss, l.step_time_s, l.peak_bytes)?;
        }
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    with_backend!(args, compare_on)
}

fn compare_on<B: Backend>(rt: &Runtime<B>, args: &Args) -> Result<()> {
    let cfg = EstimatorConfig::default();
    let chain = measured_chain(rt, cfg)?;
    let points = args.usize("points", 6);
    let reps = args.usize("reps", 3);
    let batch = rt.manifest.input_shape[0] as u64;
    let data = SyntheticData::<B::Tensor>::generate(&rt.manifest, 2, 7)?;
    let hi = chain.store_all_memory();
    let lo = chain.min_memory_hint();
    let mut rows: Vec<(String, String, u64, f64)> = Vec::new();

    let mut run_measured = |name: String, param: String, sched: &Schedule| -> Result<()> {
        let mut ex = chainckpt::executor::Executor::new(rt, 1)?;
        let loss_stage = rt.manifest.stages.len() - 1;
        ex.set_data_param(loss_stage, &data.targets[0])?;
        // warmup + timed medians
        let mut times = Vec::new();
        let mut peak = 0;
        for r in 0..reps + 1 {
            let res = ex.run(sched, &data.inputs[0], None)?;
            peak = res.peak_bytes;
            if r > 0 {
                times.push(res.elapsed_s);
            }
        }
        let t = chainckpt::util::median(&mut times);
        println!(
            "{:<12} {:>12} peak {:>12} {:>8.1} ms/iter {:>8.2} im/s",
            name,
            param,
            fmt_bytes(peak),
            t * 1e3,
            batch as f64 / t
        );
        rows.push((name, param, peak, batch as f64 / t));
        Ok(())
    };

    run_measured("pytorch".into(), "-".into(), &store_all_schedule(&chain))?;
    for k in paper_segment_sweep(chain.len() - 1).into_iter().take(points) {
        run_measured("sequential".into(), format!("{k} segs"), &periodic_schedule(&chain, k))?;
    }
    // One DP table per mode serves the whole budget sweep. The planner
    // discretizes against the top budget, so a sub-budget point only sees
    // `S·m/hi` of the grid — double the paper's S=500 to keep low-budget
    // rows at least as precise as the old per-budget solves were at
    // mid-sweep (still ≥3× less DP work than per-budget tables).
    let budgets: Vec<u64> =
        (1..=points as u64).map(|i| lo + (hi - lo) * i / points as u64).collect();
    let sweep_slots = 2 * DEFAULT_SLOTS;
    let t0 = std::time::Instant::now();
    let opt_planner = Planner::new(&chain, hi, sweep_slots, Mode::Full);
    let rev_planner = Planner::new(&chain, hi, sweep_slots, Mode::AdRevolve);
    let opt_scheds = opt_planner.sweep(&budgets);
    let rev_scheds = rev_planner.sweep(&budgets);
    println!(
        "planned {} budgets from 2 DP tables in {:.2} s",
        budgets.len(),
        t0.elapsed().as_secs_f64()
    );
    for ((&m, s_opt), s_rev) in budgets.iter().zip(opt_scheds).zip(rev_scheds) {
        if let Some(s) = s_opt {
            run_measured("optimal".into(), fmt_bytes(m), &s)?;
        }
        if let Some(s) = s_rev {
            run_measured("revolve".into(), fmt_bytes(m), &s)?;
        }
    }
    if let Some(out) = args.opt_str("out") {
        let mut f = std::fs::File::create(out)?;
        writeln!(f, "strategy,param,peak_bytes,throughput_img_s")?;
        for (n, p, peak, thr) in &rows {
            writeln!(f, "{n},{p},{peak},{thr}")?;
        }
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.str("out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let which = args.str("fig", "all");
    let figs: Vec<u32> = if which == "all" || which == FLAG_SET {
        (3..=13).collect()
    } else {
        vec![which.parse().context("--fig must be 3..13 or 'all'")?]
    };
    let mut all_panels = Vec::new();
    for f in figs {
        let t0 = std::time::Instant::now();
        let panels = figures::figure(f);
        let path = out_dir.join(format!("figure{f}.csv"));
        std::fs::write(&path, figures::to_csv(&panels))?;
        let gain = figures::summary_gain(&panels);
        println!(
            "figure {f}: {} panels → {} ({:.1} s){}",
            panels.len(),
            path.display(),
            t0.elapsed().as_secs_f64(),
            gain.map(|g| format!("  avg optimal-vs-sequential gain: +{:.1} %", 100.0 * g))
                .unwrap_or_default()
        );
        all_panels.extend(panels);
    }
    if let Some(g) = figures::summary_gain(&all_panels) {
        println!(
            "SUMMARY over {} panels: optimal beats best sequential by {:.1} % on average (paper: 17.2 %)",
            all_panels.len(),
            100.0 * g
        );
        let path = out_dir.join("summary.csv");
        let mut s = String::from("chain,batch,gain_pct,seq_img_s,opt_img_s\n");
        for p in &all_panels {
            if let Ok((gain, seq, opt)) = figures::optimal_vs_sequential(p) {
                s.push_str(&format!(
                    "{},{},{:.2},{:.3},{:.3}\n",
                    p.chain_name,
                    p.batch,
                    100.0 * gain,
                    seq,
                    opt
                ));
            }
        }
        std::fs::write(&path, s)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = chainckpt::service::ServiceConfig {
        addr: format!("{}:{}", args.str("addr", "127.0.0.1"), args.u64("port", 8080)),
        workers: args.usize("threads", 0), // 0 = one per core
        queue_depth: args.usize("queue", 64),
        slots: args.usize("slots", DEFAULT_SLOTS),
        ..Default::default()
    };
    let server = chainckpt::service::serve(cfg)?;
    println!("planning service listening on http://{}", server.addr());
    println!("endpoints: POST /solve /sweep /simulate · GET /chains /stats /healthz");
    println!("try: curl -s http://{}/chains", server.addr());
    server.join();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "solve" => cmd_solve(&args),
        "simulate" => cmd_simulate(&args),
        "estimate" => cmd_estimate(&args),
        "train" => cmd_train(&args),
        "compare" => cmd_compare(&args),
        "figures" => cmd_figures(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}
