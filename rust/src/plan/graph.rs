//! Graph lowering: compile a schedule solved for a [`GraphSpec`] into
//! the same slot-addressed [`ExecPlan`] IR as the chain lowering — but
//! under multi-consumer liveness, so a skip value occupies one arena
//! slot from its materialization to its *last* consumer instead of being
//! billed into every checkpoint it crosses.
//!
//! The heavy lifting is [`crate::graph::bind`]: it validates the
//! schedule on the fused chain, binds every read to the materialization
//! it consumes, and computes the refcounted peak. This pass translates
//! its [`Mat`](crate::graph::Mat)/[`OpBind`](crate::graph::OpBind)
//! tables into [`Value`]/[`Step`] rows (adding the per-op `o_f`/`o_b`
//! transients, exactly like the chain analysis) and reuses the chain
//! slot assigner verbatim. On a chain-shaped graph the result is
//! byte-identical to [`super::lower`] on the node chain.
//!
//! These plans are for **accounting and arena sizing only** — the pooled
//! executor does not replay them. Its binding loop assumes the chain read
//! layout (one activation per forward, `[a, ā, δ]` per backward) and
//! rejects anything else; a multi-predecessor node here emits `[preds…,
//! ā, δ]` with several activation reads, which would need multi-input
//! kernels no backend provides. Execution of graph presets goes through
//! the fused chain (whose kernels absorb the skip adds).

use crate::graph::{GraphSpec, MatKind};
use crate::simulator::SimError;
use crate::solver::{Op, Schedule};

use super::liveness::{Item, Step, Value};
use super::{slots, ExecPlan};

/// Fused-stage item for a graph materialization (stage `ℓ` = topo node
/// `ℓ-1`; the graph input and its gradient take stage 0).
fn item_of(kind: MatKind) -> Item {
    match kind {
        MatKind::Input => Item::A(0),
        MatKind::A(u) => Item::A(u as u32 + 1),
        MatKind::Abar(u) => Item::Abar(u as u32 + 1),
        MatKind::Delta(u) => Item::Delta(u as u32 + 1),
        MatKind::DeltaInput => Item::Delta(0),
    }
}

/// Compile `schedule` against `g`: graph binding, transient insertion,
/// slot assignment. `peak_bytes` is the multi-consumer peak — equal to
/// [`simulate_graph`](crate::graph::simulate_graph)'s `graph_peak`, and
/// to the chain [`lower`](super::lower) peak when `g` is a chain. Fails
/// exactly where the fused-chain simulator would.
///
/// Step read order follows the chain convention (activations first):
/// forwards read `[preds…]`, `B^ℓ` reads `[preds…, ā^ℓ, δ^ℓ]` — a node
/// with several predecessors simply has several activation reads.
pub fn lower_graph(g: &GraphSpec, schedule: &Schedule) -> Result<ExecPlan, SimError> {
    let b = crate::graph::bind(g, schedule)?;
    let node_chain = g.node_chain();

    let mut values: Vec<Value> = b
        .mats
        .iter()
        .map(|m| Value {
            item: item_of(m.kind),
            bytes: m.bytes,
            birth: m.birth.unwrap_or(0),
            death: m.death,
            initial: m.birth.is_none(),
            slot: 0,
        })
        .collect();

    let mut steps: Vec<Step> = Vec::with_capacity(schedule.ops.len());
    for (i, (ob, &op)) in b.ops.iter().zip(&schedule.ops).enumerate() {
        let mut reads = ob.reads.clone();
        if matches!(op, Op::Bwd(_)) && reads.len() >= 2 {
            // bind() records `[δ, ā, preds…]`; rotate into `[preds…, ā, δ]`
            reads.rotate_left(2);
            let k = reads.len();
            reads.swap(k - 2, k - 1);
        }
        let tbytes = match op {
            Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) => node_chain.of(l as usize),
            Op::Bwd(l) => node_chain.ob(l as usize),
            Op::DropA(_) => 0,
        };
        let mut frees = ob.frees.clone();
        let mut transient = None;
        if tbytes > 0 {
            let id = values.len();
            values.push(Value {
                item: Item::Transient(op.stage()),
                bytes: tbytes,
                birth: i,
                death: Some(i),
                initial: false,
                slot: 0,
            });
            transient = Some(id);
            frees.push(id);
        }
        steps.push(Step { op, reads, writes: ob.writes.clone(), frees, transient });
    }

    let (slot_table, arena_bytes) = slots::assign(&mut values, &steps);
    Ok(ExecPlan {
        steps,
        values,
        slots: slot_table,
        arena_bytes,
        peak_bytes: b.report.graph_peak,
        input: b.input,
        seed: b.seed,
        delta0: b.delta0,
        chain_len: g.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{simulate_graph, Node};
    use crate::solver::{store_all_schedule, Mode};

    fn nd(name: &str, wa: u64, wabar: u64) -> Node {
        Node::new(name, 1.0, 2.0, wa, wabar)
    }

    fn diamond() -> GraphSpec {
        GraphSpec::new(
            "diamond",
            vec![nd("a", 100, 120), nd("b", 80, 90), nd("c", 60, 60), nd("loss", 4, 4)],
            vec![(0, 1), (0, 2), (1, 2), (2, 3)],
            32,
        )
        .unwrap()
    }

    #[test]
    fn graph_plan_peak_is_the_multi_consumer_verdict() {
        let g = diamond();
        for sched in [
            store_all_schedule(&g.to_chain()),
            crate::graph::solve_graph(&g, g.to_chain().store_all_memory() + 32, 300, Mode::Full)
                .unwrap()
                .schedule,
        ] {
            let plan = lower_graph(&g, &sched).unwrap();
            let rep = simulate_graph(&g, &sched).unwrap();
            assert_eq!(plan.peak_bytes, rep.graph_peak);
            assert!(plan.peak_bytes < rep.fused.peak_bytes, "skips billed once");
            assert!(plan.arena_bytes >= plan.peak_bytes);
            assert_eq!(plan.op_count(), sched.ops.len());
            assert_eq!(plan.chain_len, g.len());
            // δ^0 is the result and survives the schedule
            assert_eq!(plan.values[plan.delta0].item, Item::Delta(0));
            assert_eq!(plan.values[plan.delta0].death, None);
        }
    }

    #[test]
    fn chain_shaped_graph_lowers_identically_to_the_chain_path() {
        let g = GraphSpec::new(
            "c",
            vec![nd("a", 100, 250), nd("b", 50, 120), nd("loss", 4, 4)],
            vec![(0, 1), (1, 2)],
            64,
        )
        .unwrap();
        let chain = g.node_chain();
        let sched = store_all_schedule(&chain);
        let gp = lower_graph(&g, &sched).unwrap();
        let cp = super::super::lower(&chain, &sched).unwrap();
        assert_eq!(gp.peak_bytes, cp.peak_bytes);
        assert_eq!(gp.arena_bytes, cp.arena_bytes);
        assert_eq!(gp.values.len(), cp.values.len());
        assert_eq!(gp.steps.len(), cp.steps.len());
        for (a, b) in gp.steps.iter().zip(&cp.steps) {
            assert_eq!(a.op, b.op);
            assert_eq!(a.reads.len(), b.reads.len());
        }
    }

    #[test]
    fn backward_reads_follow_the_chain_argument_order() {
        let g = diamond();
        let sched = store_all_schedule(&g.to_chain());
        let plan = lower_graph(&g, &sched).unwrap();
        for step in &plan.steps {
            if let Op::Bwd(_) = step.op {
                let k = step.reads.len();
                assert!(matches!(plan.values[step.reads[k - 1]].item, Item::Delta(_)));
                assert!(matches!(plan.values[step.reads[k - 2]].item, Item::Abar(_)));
                for &r in &step.reads[..k - 2] {
                    assert!(matches!(plan.values[r].item, Item::A(_) | Item::Abar(_)));
                }
            }
        }
        // node c's backward reads two activation predecessors (a and b)
        let b3 = plan
            .steps
            .iter()
            .find(|s| s.op == Op::Bwd(3))
            .expect("store-all runs every backward");
        assert_eq!(b3.reads.len(), 4, "two preds + ā + δ");
    }

    #[test]
    fn graph_lowering_rejects_what_the_fused_simulator_rejects() {
        use crate::solver::{Schedule, StrategyKind};
        let g = diamond();
        let bogus = Schedule::new(vec![Op::Bwd(2)], StrategyKind::Optimal, 0.0);
        let mine = lower_graph(&g, &bogus).unwrap_err();
        let sim = crate::simulator::simulate(&g.to_chain(), &bogus).unwrap_err();
        assert_eq!(mine, sim);
    }
}
