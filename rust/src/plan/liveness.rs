//! Liveness analysis: one symbolic Table-1 replay of a schedule that
//! turns implicit residency into an explicit value table.
//!
//! Every tensor instance a schedule ever materializes — each `a^ℓ`,
//! `ā^ℓ`, `δ^ℓ` *per (re)computation*, plus the per-op transient blob
//! (`o_f` / `o_b`) — becomes one [`Value`] with a birth step, a death
//! step (its last use, after which the storage is provably reusable) and
//! a byte size from the chain's cost model. The per-op transition is
//! [`MemState::apply`] — the *same* function [`crate::simulator::simulate`]
//! replays — so the death points coincide exactly with Table 1's free
//! semantics and the accumulated peak is byte-identical to the
//! simulator's verdict by construction.
//!
//! `DropA` is subsumed: it contributes a [`Step`] with an empty
//! read/write set whose only effect is an explicit free — exactly what
//! every *other* op's last-use frees already look like in this IR.

use crate::chain::Chain;
use crate::simulator::{MemState, SeqCheck, SimError};
use crate::solver::{Op, Schedule};

/// Index into [`super::ExecPlan::values`].
pub type ValueId = usize;

/// What a [`Value`] holds, in the paper's notation. Stage indices are
/// 1-based like [`Op`]; `A(0)` is the chain input, `Delta(L+1)` the loss
/// backward's scalar seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Item {
    /// A standalone activation `a^ℓ` (output of `F∅`/`Fck`).
    A(u32),
    /// A full checkpoint `ā^ℓ` (output of `Fall`; contains `a^ℓ`).
    Abar(u32),
    /// A gradient `δ^ℓ` (output of `B^{ℓ+1}`).
    Delta(u32),
    /// The transient working set of one op on stage `ℓ` (`o_f`/`o_b`):
    /// born and dead within a single step.
    Transient(u32),
}

impl Item {
    /// Paper-notation label (`a^3`, `ā^2`, `δ^1`, `tmp^4`).
    pub fn label(&self) -> String {
        match *self {
            Item::A(l) => format!("a^{l}"),
            Item::Abar(l) => format!("ā^{l}"),
            Item::Delta(l) => format!("δ^{l}"),
            Item::Transient(l) => format!("tmp^{l}"),
        }
    }

    /// The 1-based stage index this item belongs to (0 for `a^0`/`δ^0`).
    pub fn stage(&self) -> u32 {
        match *self {
            Item::A(l) | Item::Abar(l) | Item::Delta(l) | Item::Transient(l) => l,
        }
    }
}

impl std::fmt::Display for Item {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// One tensor instance with its exactly-known lifetime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Value {
    pub item: Item,
    /// Cost-model bytes (`ω_a` / `ω_ā` / `ω_δ` / `o_f` / `o_b`).
    pub bytes: u64,
    /// Step index that writes this value (0 for the initial `{a^0,
    /// δ^{L+1}}` pair, which is live before the first step — see
    /// [`Value::initial`]).
    pub birth: usize,
    /// Step index after which the storage is free again; `None` for
    /// values still live when the schedule ends (`δ^0`).
    pub death: Option<usize>,
    /// Live from before step 0 (`a^0` and the `δ^{L+1}` seed).
    pub initial: bool,
    /// Arena slot this value is placed in (filled by the slot-assignment
    /// pass; indexes [`super::ExecPlan::slots`]).
    pub slot: usize,
}

/// One schedule op with its resolved value bindings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Step {
    pub op: Op,
    /// Values read, in the executor's argument order: forwards read
    /// `[a^{ℓ-1}]`, `B^ℓ` reads `[a^{ℓ-1}, ā^ℓ, δ^ℓ]`, `drop` reads
    /// nothing. An `a` read may resolve to an [`Item::Abar`] value — the
    /// consumer then reads the checkpoint's leading `a` component.
    pub reads: Vec<ValueId>,
    /// Values this op writes: `[a^ℓ]` / `[ā^ℓ]` / `[δ^{ℓ-1}]`.
    pub writes: Vec<ValueId>,
    /// Values whose storage is released once this step completes (their
    /// last use), including the op's own transient. Physical buffers stay
    /// intact *during* the step — the ledger's "δ replaces a" accounting
    /// is a byte-count convention, not an aliasing license.
    pub frees: Vec<ValueId>,
    /// The op's transient working set, when the stage declares one
    /// (`o_f`/`o_b` > 0). Also listed in `frees`.
    pub transient: Option<ValueId>,
}

/// Everything the liveness replay derives; consumed by [`super::lower`].
pub(crate) struct Analysis {
    pub values: Vec<Value>,
    pub steps: Vec<Step>,
    /// Byte-identical to `simulate(chain, schedule)?.peak_bytes`.
    pub peak_bytes: u64,
    pub input: ValueId,
    pub seed: ValueId,
    pub delta0: ValueId,
}

/// The `a^ℓ` a consumer reads: the standalone value if resident, else
/// the live checkpoint containing it.
fn resolve_a(cur_a: &[Option<ValueId>], cur_abar: &[Option<ValueId>], l: usize) -> ValueId {
    cur_a[l]
        .or(if l >= 1 { cur_abar[l] } else { None })
        .expect("apply validated a-readability")
}

/// Record a value written at step `i` and mark it live in its class map.
fn birth(
    values: &mut Vec<Value>,
    item: Item,
    bytes: u64,
    i: usize,
    live: &mut [Option<ValueId>],
    l: usize,
) -> ValueId {
    let id = values.len();
    values.push(Value { item, bytes, birth: i, death: None, initial: false, slot: 0 });
    debug_assert!(live[l].is_none(), "apply rejected the duplicate store");
    live[l] = Some(id);
    id
}

/// Mark the live value of a class dead at step `i` (its last use).
fn death(values: &mut [Value], i: usize, live: &mut [Option<ValueId>], l: usize) -> ValueId {
    let id = live[l].take().expect("apply freed a resident item");
    values[id].death = Some(i);
    id
}

pub(crate) fn analyze(chain: &Chain, schedule: &Schedule) -> Result<Analysis, SimError> {
    let n = chain.len();
    let mut st = MemState::initial(chain);
    let mut values = vec![
        Value {
            item: Item::A(0),
            bytes: chain.wa(0),
            birth: 0,
            death: None,
            initial: true,
            slot: 0,
        },
        Value {
            item: Item::Delta(n as u32),
            bytes: chain.wdelta(n),
            birth: 0,
            death: None,
            initial: true,
            slot: 0,
        },
    ];
    let (input, seed) = (0usize, 1usize);

    // live value per item class, mirroring `st`'s resident flags
    let mut cur_a: Vec<Option<ValueId>> = vec![None; n + 1];
    let mut cur_abar: Vec<Option<ValueId>> = vec![None; n + 1]; // indexed by ℓ, entry 0 unused
    let mut cur_delta: Vec<Option<ValueId>> = vec![None; n + 1];
    cur_a[0] = Some(input);
    cur_delta[n] = Some(seed);

    let mut seq = SeqCheck::new(n);
    let mut steps: Vec<Step> = Vec::with_capacity(schedule.ops.len());

    for (i, &op) in schedule.ops.iter().enumerate() {
        // the shared sequence-level + single-op transitions — the same
        // two calls simulate() makes, so validity cannot drift
        seq.observe(op, i)?;
        let eff = st.apply(chain, op, i)?;

        // resolve reads against the *pre-op* value maps (apply validated
        // readability, so the lookups cannot fail)
        let mut step = Step {
            op,
            reads: Vec::new(),
            writes: Vec::new(),
            frees: Vec::new(),
            transient: None,
        };
        match op {
            Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) => {
                step.reads.push(resolve_a(&cur_a, &cur_abar, l as usize - 1));
            }
            Op::Bwd(l) => {
                let l = l as usize;
                step.reads.push(resolve_a(&cur_a, &cur_abar, l - 1));
                step.reads.push(cur_abar[l].expect("apply validated ā"));
                step.reads.push(cur_delta[l].expect("apply validated δ"));
            }
            Op::DropA(_) => {}
        }

        // births
        if let Some(l) = eff.stored_a {
            let id = birth(&mut values, Item::A(l as u32), chain.wa(l), i, &mut cur_a, l);
            step.writes.push(id);
        }
        if let Some(l) = eff.stored_abar {
            let id =
                birth(&mut values, Item::Abar(l as u32), chain.wabar(l), i, &mut cur_abar, l);
            step.writes.push(id);
        }
        if let Some(l) = eff.stored_delta {
            let id =
                birth(&mut values, Item::Delta(l as u32), chain.wdelta(l), i, &mut cur_delta, l);
            step.writes.push(id);
        }

        // deaths (last uses, explicit from here on)
        if let Some(l) = eff.freed_delta {
            step.frees.push(death(&mut values, i, &mut cur_delta, l));
        }
        if let Some(l) = eff.freed_abar {
            step.frees.push(death(&mut values, i, &mut cur_abar, l));
        }
        if let Some(l) = eff.freed_a {
            step.frees.push(death(&mut values, i, &mut cur_a, l));
        }

        // the op's transient working set lives only inside this step
        let tbytes = match op {
            Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) => chain.of(l as usize),
            Op::Bwd(l) => chain.ob(l as usize),
            Op::DropA(_) => 0,
        };
        if tbytes > 0 {
            let id = values.len();
            values.push(Value {
                item: Item::Transient(op.stage()),
                bytes: tbytes,
                birth: i,
                death: Some(i),
                initial: false,
                slot: 0,
            });
            step.transient = Some(id);
            step.frees.push(id);
        }

        steps.push(step);
    }

    seq.finish(&st)?;
    let delta0 = cur_delta[0].expect("finish() guaranteed δ^0 is resident");

    Ok(Analysis { values, steps, peak_bytes: st.peak, input, seed, delta0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::solver::{store_all_schedule, StrategyKind};

    fn toy() -> Chain {
        Chain::new(
            "toy",
            vec![
                Stage::new("s1", 1.0, 2.0, 100, 250).with_overheads(16, 24),
                Stage::new("s2", 3.0, 4.0, 50, 120),
                Stage::new("loss", 0.5, 0.5, 4, 4),
            ],
            80,
        )
    }

    #[test]
    fn store_all_liveness_matches_simulate() {
        let c = toy();
        let s = store_all_schedule(&c);
        let a = analyze(&c, &s).unwrap();
        let rep = crate::simulator::simulate(&c, &s).unwrap();
        assert_eq!(a.peak_bytes, rep.peak_bytes);
        assert_eq!(a.steps.len(), s.ops.len());
        // the initial pair is live from the start; δ^0 never dies
        assert!(a.values[a.input].initial && a.values[a.seed].initial);
        assert_eq!(a.values[a.delta0].item, Item::Delta(0));
        assert_eq!(a.values[a.delta0].death, None);
        // every non-final value has an explicit death at or after birth
        for v in &a.values {
            if let Some(d) = v.death {
                assert!(d >= v.birth, "{}: death {d} < birth {}", v.item, v.birth);
            }
        }
        // stage 1 declares transients → its ops carry transient values
        let t = a.steps[0].transient.expect("stage 1 has o_f > 0");
        assert_eq!(a.values[t].item, Item::Transient(1));
        assert_eq!((a.values[t].birth, a.values[t].death), (0, Some(0)));
    }

    #[test]
    fn invalid_sequences_are_rejected_like_the_simulator() {
        let c = toy();
        for ops in [
            vec![Op::FwdNoSave(2)],                             // missing a^1
            vec![Op::FwdAll(1), Op::FwdAll(2), Op::FwdAll(3)],  // incomplete
            vec![Op::FwdNoSave(9)],                             // out of range
        ] {
            let s = Schedule::new(ops, StrategyKind::Optimal, 0.0);
            let mine = analyze(&c, &s).err().expect("invalid");
            let sim = crate::simulator::simulate(&c, &s).err().expect("invalid");
            assert_eq!(mine, sim);
        }
    }

    #[test]
    fn drop_a_becomes_an_explicit_free_step() {
        // Fck^1 stores a^1; dropping it before any use is a pure free.
        let c = toy();
        let ops = vec![
            Op::FwdCk(1),
            Op::DropA(1),
            Op::FwdAll(1),
            Op::FwdAll(2),
            Op::FwdAll(3),
            Op::Bwd(3),
            Op::Bwd(2),
            Op::Bwd(1),
        ];
        let s = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        let a = analyze(&c, &s).unwrap();
        let drop = &a.steps[1];
        assert!(drop.reads.is_empty() && drop.writes.is_empty());
        assert_eq!(drop.frees.len(), 1);
        assert_eq!(a.values[drop.frees[0]].item, Item::A(1));
        assert_eq!(a.values[drop.frees[0]].death, Some(1));
        let rep = crate::simulator::simulate(&c, &s).unwrap();
        assert_eq!(a.peak_bytes, rep.peak_bytes);
    }
}
