//! Lowered execution plans: compile a solved [`Schedule`] + [`Chain`]
//! into a slot-addressed IR the executor can replay with zero steady-state
//! allocations.
//!
//! The paper's Table 1 gives every tensor a schedule ever materializes an
//! exactly known lifetime — yet a naive replay rediscovers none of it,
//! allocating fresh buffers op by op. Because the schedule is *static*,
//! memory placement can be static too. Lowering runs once per
//! `(chain, schedule)` pair and produces an [`ExecPlan`]:
//!
//! 1. **Liveness** ([`Value`], [`Step`]): one symbolic replay resolves
//!    every read to the concrete value it consumes and turns Table 1's
//!    implicit residency rules into explicit birth/death points — the
//!    `drop a^ℓ` op dissolves into the same explicit frees every other
//!    last use gets. The replay drives the *simulator's own* transition
//!    function, so validity and accounting cannot drift.
//! 2. **Slot assignment** ([`Slot`]): values with disjoint lifetimes
//!    share a reusable arena slot with a fixed byte offset;
//!    [`ExecPlan::arena_bytes`] is the whole iteration's physical
//!    footprint, known before any tensor exists.
//! 3. **Plan-time peak** ([`ExecPlan::peak_bytes`]): byte-identical to
//!    [`simulate`](crate::simulator::simulate)'s verdict for the same
//!    schedule, by construction — the executor no longer needs a
//!    per-iteration ledger walk.
//!
//! The executor side ([`crate::executor::Executor::lower`]) binds an
//! `ExecPlan` to a compiled runtime: slots become ranges of one pooled
//! f32 arena owned across iterations, and the native backend's in-place
//! kernels write straight into them.
//!
//! Graphs lower through the same IR: [`lower_graph`] compiles a schedule
//! solved for a [`crate::graph::GraphSpec`] under multi-consumer
//! liveness, so skip values hold one slot until their last consumer.
//! Graph plans are planning artifacts — they size arenas and report the
//! multi-consumer peak, but they are **not executable**: a
//! multi-predecessor backward reads `[preds…, ā, δ]`, and no backend has
//! multi-input kernels. [`Executor::lower`](crate::executor::Executor::lower)
//! works from the chain lowering and rejects variable-arity read
//! layouts; graph presets execute through their fused chain.
//!
//! ```
//! use chainckpt::chain::{Chain, Stage};
//! use chainckpt::plan::lower;
//! use chainckpt::simulator::simulate;
//! use chainckpt::solver::store_all_schedule;
//!
//! let chain = Chain::new(
//!     "demo",
//!     vec![
//!         Stage::new("s1", 1.0, 2.0, 100, 250),
//!         Stage::new("s2", 1.0, 2.0, 50, 120),
//!         Stage::new("loss", 0.1, 0.1, 4, 4),
//!     ],
//!     80,
//! );
//! let schedule = store_all_schedule(&chain);
//! let plan = lower(&chain, &schedule)?;
//!
//! // the plan-time peak is the simulator's verdict, byte for byte
//! assert_eq!(plan.peak_bytes, simulate(&chain, &schedule)?.peak_bytes);
//! // and the arena (which keeps kernel inputs/outputs disjoint) covers it
//! assert!(plan.arena_bytes >= plan.peak_bytes);
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```

mod graph;
mod liveness;
mod slots;

pub use graph::lower_graph;
pub use liveness::{Item, Step, Value, ValueId};
pub use slots::Slot;

use crate::chain::Chain;
use crate::simulator::SimError;
use crate::solver::Schedule;

/// A schedule compiled against a chain: every op with resolved value
/// bindings, every value with its lifetime and arena slot, and the two
/// numbers the runtime needs before any tensor exists — the physical
/// arena size and the Table-1 peak.
///
/// Built by [`lower`]; replayed by
/// [`Executor::run_lowered`](crate::executor::Executor::run_lowered) and
/// served by the planning daemon's `POST /lower`.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    /// One entry per schedule op, in order (including `drop` steps, which
    /// bind no kernel).
    pub steps: Vec<Step>,
    /// Every tensor instance the schedule materializes.
    pub values: Vec<Value>,
    /// The reusable arena regions; `values[v].slot` indexes this table.
    pub slots: Vec<Slot>,
    /// Total arena footprint: Σ slot sizes. Always ≥ `peak_bytes` — the
    /// arena keeps an op's inputs and outputs physically disjoint where
    /// the paper's accounting lets the output "replace" an input.
    pub arena_bytes: u64,
    /// Table-1 peak of the schedule — byte-identical to
    /// [`simulate`](crate::simulator::simulate) on the same inputs.
    pub peak_bytes: u64,
    /// The initial `a^0` value (the executor copies the batch input here).
    pub input: ValueId,
    /// The initial `δ^{L+1}` seed value (set to 1.0 each iteration).
    pub seed: ValueId,
    /// The final `δ^0` value (the input gradient).
    pub delta0: ValueId,
    /// `L+1` of the chain this plan was lowered against.
    pub chain_len: usize,
}

impl ExecPlan {
    /// Number of ops (= schedule length, `drop` steps included).
    pub fn op_count(&self) -> usize {
        self.steps.len()
    }

    /// Values placed in slot `s`, for inspection/serialization.
    pub fn slot_values(&self, s: usize) -> impl Iterator<Item = (ValueId, &Value)> {
        self.values.iter().enumerate().filter(move |(_, v)| v.slot == s)
    }
}

/// Compile `schedule` against `chain`: liveness analysis, slot
/// assignment, plan-time peak. Fails exactly where
/// [`simulate`](crate::simulator::simulate) would, with the same
/// [`SimError`].
pub fn lower(chain: &Chain, schedule: &Schedule) -> Result<ExecPlan, SimError> {
    let mut a = liveness::analyze(chain, schedule)?;
    let (slots, arena_bytes) = slots::assign(&mut a.values, &a.steps);
    Ok(ExecPlan {
        steps: a.steps,
        values: a.values,
        slots,
        arena_bytes,
        peak_bytes: a.peak_bytes,
        input: a.input,
        seed: a.seed,
        delta0: a.delta0,
        chain_len: chain.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::simulator::simulate;
    use crate::solver::{periodic_schedule, solve, store_all_schedule, Mode};

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300).with_overheads(8, 12))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    #[test]
    fn peak_matches_simulator_across_strategies() {
        let c = toy(7);
        let mut schedules = vec![store_all_schedule(&c), periodic_schedule(&c, 3)];
        let hi = c.store_all_memory() + c.wa0;
        for m in [hi / 2, (hi * 3) / 4, hi] {
            if let Some(s) = solve(&c, m, 300, Mode::Full) {
                schedules.push(s);
            }
        }
        assert!(schedules.len() > 2, "at least one DP budget must be feasible");
        for sched in &schedules {
            let plan = lower(&c, sched).unwrap();
            let rep = simulate(&c, sched).unwrap();
            assert_eq!(plan.peak_bytes, rep.peak_bytes, "{}", sched.strategy);
            assert!(plan.arena_bytes >= plan.peak_bytes);
            assert_eq!(plan.op_count(), sched.ops.len());
            assert_eq!(plan.chain_len, c.len());
        }
    }

    #[test]
    fn lower_rejects_what_simulate_rejects() {
        use crate::solver::{Op, StrategyKind};
        let c = toy(3);
        let bogus = Schedule::new(vec![Op::Bwd(2)], StrategyKind::Optimal, 0.0);
        assert_eq!(lower(&c, &bogus).unwrap_err(), simulate(&c, &bogus).unwrap_err());
    }

    #[test]
    fn slot_table_is_consistent() {
        let c = toy(5);
        let plan = lower(&c, &store_all_schedule(&c)).unwrap();
        for v in &plan.values {
            assert!(v.slot < plan.slots.len());
            assert!(v.bytes <= plan.slots[v.slot].bytes);
        }
        // offsets tile [0, arena)
        let mut end = 0;
        for s in &plan.slots {
            assert_eq!(s.offset, end);
            end += s.bytes;
        }
        assert_eq!(end, plan.arena_bytes);
        // every slot hosts at least one value
        for s in 0..plan.slots.len() {
            assert!(plan.slot_values(s).next().is_some(), "empty slot {s}");
        }
    }
}
