//! Arena slot assignment: map every [`Value`] to a reusable buffer slot
//! with a fixed byte offset, so a whole training iteration runs over one
//! preallocated arena.
//!
//! The assignment walks the steps in schedule order with a free-slot
//! list. At a value's birth it claims the free slot whose size fits
//! tightest (growing the largest free slot when none is big enough —
//! reuse beats a fresh allocation, since a slot's final size is the max
//! over its occupants); at its death the slot returns to the free list.
//! Deaths are processed *after* the step's births: an op's inputs and
//! outputs never share storage, even where the ledger's Table-1
//! accounting says the output "replaces" an input byte-for-byte — that
//! convention is about counting, not aliasing, and the kernels really do
//! read their inputs while writing outputs.
//!
//! The greedy policy is deterministic and linear; it is not claimed
//! optimal (weighted interval packing is NP-hard), but for Table-1
//! schedules — where recomputed activations recur at identical sizes —
//! it reuses essentially perfectly, and the resulting
//! `arena_bytes = Σ slot sizes` always dominates the simulator peak.

use super::liveness::{Step, Value};

/// One reusable arena region.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Slot {
    /// Max byte size over every value placed here.
    pub bytes: u64,
    /// Fixed byte offset inside the arena.
    pub offset: u64,
}

/// Assign every value a slot (written into `Value::slot`) and return the
/// slot table plus the arena size in bytes.
pub(crate) fn assign(values: &mut [Value], steps: &[Step]) -> (Vec<Slot>, u64) {
    let mut slots: Vec<Slot> = Vec::new();
    let mut free: Vec<usize> = Vec::new();

    let mut place = |slots: &mut Vec<Slot>, free: &mut Vec<usize>, v: &mut Value| {
        // tightest free slot that fits; else the largest free slot grows;
        // else a fresh slot (ties broken by lowest id — deterministic)
        let fitting = free
            .iter()
            .enumerate()
            .filter(|&(_, &s)| slots[s].bytes >= v.bytes)
            .min_by_key(|&(_, &s)| (slots[s].bytes, s));
        let chosen = fitting.or_else(|| {
            free.iter().enumerate().max_by_key(|&(_, &s)| (slots[s].bytes, std::cmp::Reverse(s)))
        });
        let slot = match chosen {
            Some((fi, &s)) => {
                free.swap_remove(fi);
                slots[s].bytes = slots[s].bytes.max(v.bytes);
                s
            }
            None => {
                slots.push(Slot { bytes: v.bytes, offset: 0 });
                slots.len() - 1
            }
        };
        v.slot = slot;
    };

    // the initial pair ({a^0, δ^{L+1}}) is live before any step
    let initial: Vec<usize> =
        (0..values.len()).filter(|&id| values[id].initial).collect();
    for id in initial {
        place(&mut slots, &mut free, &mut values[id]);
    }

    for (i, step) in steps.iter().enumerate() {
        // births: the transient first (mirrors the ledger's charge order),
        // then the op's stored outputs
        for &id in step.transient.iter().chain(&step.writes) {
            debug_assert_eq!(values[id].birth, i);
            place(&mut slots, &mut free, &mut values[id]);
        }
        // deaths release storage only after the step completes
        for &id in &step.frees {
            debug_assert_eq!(values[id].death, Some(i));
            free.push(values[id].slot);
        }
    }

    // fixed offsets: slots packed back-to-back in creation order
    let mut offset = 0u64;
    for s in &mut slots {
        s.offset = offset;
        offset += s.bytes;
    }
    (slots, offset)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::liveness::analyze;
    use crate::chain::{Chain, Stage};
    use crate::solver::{periodic_schedule, store_all_schedule};

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    fn check(chain: &Chain, sched: &crate::solver::Schedule) -> (Vec<Slot>, u64, u64) {
        let mut a = analyze(chain, sched).unwrap();
        let (slots, arena) = assign(&mut a.values, &a.steps);
        // no two simultaneously-live values share a slot
        for (i, v) in a.values.iter().enumerate() {
            for w in &a.values[i + 1..] {
                if v.slot != w.slot {
                    continue;
                }
                let v_end = v.death.unwrap_or(usize::MAX);
                let w_end = w.death.unwrap_or(usize::MAX);
                let v_start = if v.initial { 0 } else { v.birth };
                let w_start = if w.initial { 0 } else { w.birth };
                // overlap (inclusive: frees happen after the step) only
                // allowed when one is strictly dead before the other born
                assert!(
                    v_end < w_start || w_end < v_start,
                    "{} [{v_start},{v_end}] and {} [{w_start},{w_end}] share slot {}",
                    v.item,
                    w.item,
                    v.slot
                );
            }
        }
        // every value fits its slot; offsets tile the arena exactly
        for v in &a.values {
            assert!(v.bytes <= slots[v.slot].bytes);
        }
        let total: u64 = slots.iter().map(|s| s.bytes).sum();
        assert_eq!(total, arena);
        for w in slots.windows(2) {
            assert_eq!(w[0].offset + w[0].bytes, w[1].offset);
        }
        (slots, arena, a.peak_bytes)
    }

    #[test]
    fn store_all_gets_one_slot_per_live_value() {
        let c = toy(5);
        let (slots, arena, peak) = check(&c, &store_all_schedule(&c));
        assert!(arena >= peak, "arena {arena} < peak {peak}");
        assert!(!slots.is_empty());
    }

    #[test]
    fn recomputation_reuses_slots() {
        // a 2-segment periodic schedule recomputes segment activations:
        // the arena must stay well below the store-all arena
        let c = toy(8);
        let (_, arena_ckpt, peak_ckpt) = check(&c, &periodic_schedule(&c, 4));
        let (_, arena_all, _) = check(&c, &store_all_schedule(&c));
        assert!(arena_ckpt < arena_all, "{arena_ckpt} !< {arena_all}");
        assert!(arena_ckpt >= peak_ckpt);
    }
}
