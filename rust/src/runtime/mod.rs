//! Backend-generic runtime: compiles a manifest's signatures once and
//! serves executables to the replay loop.
//!
//! One [`Runtime`] owns a [`Backend`] handle plus one compiled
//! [`StageExecutable`] per distinct signature. Signatures are shared
//! between same-shape stages (the manifest deduplicates), so compilation
//! cost is paid once per distinct shape — the paper's "computed once
//! before training" phase.
//!
//! The runtime is generic over the engine:
//!
//! * [`Runtime::native`] / [`Runtime::native_preset`] — the pure-Rust
//!   engine; manifests may be generated in-process, no artifacts needed.
//! * [`Runtime::load`] / [`Runtime::from_manifest`] — the PJRT path over
//!   AOT HLO-text artifacts (see [`crate::backend::pjrt`]).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::backend::{Backend, NativeBackend, PjrtBackend, StageExecutable};
use crate::chain::manifest::Manifest;

pub use crate::backend::Entry;

/// Compiled signature registry bound to a tensor engine.
pub struct Runtime<B: Backend> {
    pub backend: B,
    pub manifest: Manifest,
    exes: HashMap<String, B::Stage>,
}

impl Runtime<PjrtBackend> {
    /// Load a manifest directory and compile its HLO artifacts with PJRT.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        Self::from_manifest(manifest)
    }

    /// Compile an already-parsed manifest with PJRT.
    pub fn from_manifest(manifest: Manifest) -> Result<Self> {
        Self::with_backend(PjrtBackend::new()?, manifest)
    }
}

impl Runtime<NativeBackend> {
    /// Compile a manifest with the pure-Rust engine.
    pub fn native(manifest: Manifest) -> Result<Self> {
        Self::with_backend(NativeBackend, manifest)
    }

    /// Build a named in-process preset chain (`quickstart` / `default` /
    /// `wide`, mirroring `python/compile/model.py`) on the native engine.
    pub fn native_preset(preset: &str) -> Result<Self> {
        Self::native(crate::backend::native::presets::preset(preset)?)
    }
}

impl<B: Backend> Runtime<B> {
    /// Compile every distinct signature of `manifest` on `backend`.
    pub fn with_backend(backend: B, manifest: Manifest) -> Result<Self> {
        let mut exes = HashMap::new();
        for sig in manifest.signatures.keys() {
            let exe = backend
                .compile(&manifest, sig)
                .with_context(|| format!("compiling signature {sig} on {}", backend.name()))?;
            exes.insert(sig.clone(), exe);
        }
        Ok(Runtime { backend, manifest, exes })
    }

    /// The compiled executable of one signature. Errors (with the known
    /// signature set for context) instead of panicking on a bad name.
    pub fn executable(&self, sig: &str) -> Result<&B::Stage> {
        self.exes.get(sig).with_context(|| {
            let mut known: Vec<&str> = self.exes.keys().map(String::as_str).collect();
            known.sort_unstable();
            format!(
                "unknown executable signature '{sig}' on {} backend (compiled: {})",
                self.backend.name(),
                known.join(", ")
            )
        })
    }

    /// Execute one entry point of a signature. `args` in manifest order;
    /// the output tuple is returned decomposed into positional tensors.
    pub fn execute(&self, sig: &str, entry: Entry, args: &[&B::Tensor]) -> Result<Vec<B::Tensor>> {
        self.executable(sig)?
            .entry(entry, args)
            .with_context(|| format!("executing {sig}/{}", entry.name()))
    }

    /// Number of compiled executables (one per distinct signature; each
    /// carries all three entry points).
    pub fn executable_count(&self) -> usize {
        self.exes.len()
    }

    /// Signature name of stage `stage_index` (0-based).
    pub fn stage_sig(&self, stage_index: usize) -> &str {
        &self.manifest.stages[stage_index].sig
    }
}
