//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! One [`Runtime`] owns the PJRT CPU client plus one compiled executable
//! per `(signature, entry)` pair. Signatures are shared between same-shape
//! stages (the manifest deduplicates), so compilation cost is paid once
//! per distinct shape — the paper's "computed once before training" phase.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids. See python/compile/aot.py.

mod literal;

pub use literal::{lit_from_vec, lit_scalar, lit_to_vec, lit_zeros};

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::chain::manifest::Manifest;

/// Entry points every stage signature exposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Entry {
    /// `(θ…, a_in) → (a_out,)` — used by both `F∅` and `Fck`.
    Fwd,
    /// `(θ…, a_in) → (a_out, ā-extras…)` — `Fall`.
    FwdAll,
    /// `(θ…, a_in, ā…, δ_out) → (δ_in, ∂θ…)` — `B`.
    Bwd,
}

impl Entry {
    pub fn name(&self) -> &'static str {
        match self {
            Entry::Fwd => "fwd",
            Entry::FwdAll => "fwd_all",
            Entry::Bwd => "bwd",
        }
    }
}

/// Compiled artifact registry bound to a PJRT client.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    exes: HashMap<(String, Entry), PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load a manifest directory, compiling every `(signature, entry)`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        Self::from_manifest(manifest)
    }

    pub fn from_manifest(manifest: Manifest) -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for sig in manifest.signatures.keys() {
            for entry in [Entry::Fwd, Entry::FwdAll, Entry::Bwd] {
                let path = manifest.hlo_path(sig, entry.name());
                let proto = HloModuleProto::from_text_file(&path)
                    .with_context(|| format!("parsing {}", path.display()))?;
                let comp = XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {sig}/{}", entry.name()))?;
                exes.insert((sig.clone(), entry), exe);
            }
        }
        Ok(Runtime { client, manifest, exes })
    }

    pub fn executable(&self, sig: &str, entry: Entry) -> &PjRtLoadedExecutable {
        &self.exes[&(sig.to_string(), entry)]
    }

    /// Execute one entry point. `args` in manifest order; the tuple output
    /// is decomposed into positional [`Literal`]s.
    pub fn execute(&self, sig: &str, entry: Entry, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(&(sig.to_string(), entry))
            .with_context(|| format!("unknown executable {sig}/{}", entry.name()))?;
        let outs = exe
            .execute::<&Literal>(args)
            .with_context(|| format!("executing {sig}/{}", entry.name()))?;
        let mut result = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {sig}/{}", entry.name()))?;
        // aot.py lowers with return_tuple=True: always a tuple root.
        let parts = result.decompose_tuple().context("decomposing result tuple")?;
        Ok(parts)
    }

    /// Number of compiled executables (3 × distinct signatures).
    pub fn executable_count(&self) -> usize {
        self.exes.len()
    }

    /// Signature name of stage `stage_index` (0-based).
    pub fn stage_sig(&self, stage_index: usize) -> &str {
        &self.manifest.stages[stage_index].sig
    }
}
