//! Readiness-based connection IO: one thread multiplexes every client
//! socket through `poll(2)`, so concurrent keep-alive connections cost a
//! file descriptor and a parser buffer each — not a parked thread.
//!
//! The previous accept loop handed each connection to a pool worker that
//! *blocked* in `read_request` between requests, capping live clients at
//! `workers + queue_depth`. This module inverts that: the event thread
//! owns all sockets, feeds raw bytes to the incremental
//! [`RequestParser`](super::http::RequestParser), and hands only
//! *complete* requests to the bounded worker pool. Workers never touch a
//! socket — they compute the [`Response`] and push it back through
//! [`Shared`], waking the loop via a self-pipe. 10k idle keep-alive
//! clients therefore pin 10k fds and zero threads.
//!
//! Backpressure is preserved at both ends: a connection with a request
//! in flight is not polled for reads (its kernel receive buffer fills —
//! TCP pushback, one request per connection at a time), and a full
//! worker queue hands the job back ([`ThreadPool::try_execute`]) to be
//! retried next tick instead of blocking the event thread.
//!
//! std-only like the rest of the crate: the `poll(2)` binding is a
//! seven-line `extern "C"` shim against the platform libc the process
//! already links, not a dependency.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::http::{self, RecvError, Request, RequestParser, Response};
use super::pool::{Job, ThreadPool};
use super::routes;
use super::ServiceState;

// ---------------------------------------------------------------------------
// poll(2) shim
// ---------------------------------------------------------------------------

/// `struct pollfd` (poll(2)); layout fixed by the C ABI.
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

extern "C" {
    /// poll(2) from the libc the binary already links — the std runtime
    /// pulls it in, so no crate and no extra linkage is needed.
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
}

/// Block until an fd is ready or `timeout_ms` passes, retrying EINTR.
fn poll_ready(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

// ---------------------------------------------------------------------------
// Worker → event-loop handoff
// ---------------------------------------------------------------------------

/// A finished request: the response plus the connection it belongs to.
pub(crate) struct Completion {
    token: u64,
    resp: Response,
    keep_alive: bool,
}

/// The mailbox between pool workers and the event thread. Workers
/// [`push`](Shared::push) completions and tickle the self-pipe; the
/// loop drains both each tick.
pub(crate) struct Shared {
    done: Mutex<Vec<Completion>>,
    /// Write side of the self-pipe (nonblocking: a full pipe already
    /// means a wake is pending, so short writes are ignored).
    wake_tx: UnixStream,
}

impl Shared {
    pub(crate) fn new(wake_tx: UnixStream) -> Shared {
        let _ = wake_tx.set_nonblocking(true);
        Shared { done: Mutex::new(Vec::new()), wake_tx }
    }

    /// Interrupt the event thread's `poll` (shutdown, completions).
    pub(crate) fn wake(&self) {
        let _ = (&self.wake_tx).write(&[1]);
    }

    fn push(&self, c: Completion) {
        self.done.lock().unwrap_or_else(|p| p.into_inner()).push(c);
        self.wake();
    }

    fn drain(&self) -> Vec<Completion> {
        std::mem::take(&mut *self.done.lock().unwrap_or_else(|p| p.into_inner()))
    }
}

// ---------------------------------------------------------------------------
// Per-connection state
// ---------------------------------------------------------------------------

struct Conn {
    stream: TcpStream,
    parser: RequestParser,
    /// Outbound bytes (response framing), drained by `flush_out`.
    out: Vec<u8>,
    written: usize,
    /// A request is with the worker pool; reads pause (TCP backpressure)
    /// until its completion lands.
    inflight: bool,
    /// Deliver `out`, then drop the connection.
    close_after_write: bool,
    /// Peer EOF seen; no further reads.
    read_closed: bool,
    /// Last successful read/write/completion — the idle-timeout clock.
    last_activity: Instant,
    /// Wall-clock bound on finishing the *current* partial request
    /// ([`http::MAX_REQUEST_TIME`]); `None` between requests, so idle
    /// keep-alive connections are governed by the idle timeout alone.
    req_deadline: Option<Instant>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            parser: RequestParser::new(),
            out: Vec::new(),
            written: 0,
            inflight: false,
            close_after_write: false,
            read_closed: false,
            last_activity: Instant::now(),
            req_deadline: None,
        }
    }

    /// Queue a terminal error response: deliver it, then close.
    fn queue_close(&mut self, resp: Response) {
        self.out.extend_from_slice(&resp.to_bytes(false));
        self.close_after_write = true;
        self.read_closed = true;
        self.req_deadline = None;
    }
}

/// Drain the socket's readable bytes into the parser. `false` = fatal
/// socket error, drop the connection.
fn read_some(conn: &mut Conn) -> bool {
    let mut buf = [0u8; 16 << 10];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.read_closed = true;
                return true;
            }
            Ok(n) => {
                conn.last_activity = Instant::now();
                conn.parser.feed(&buf[..n]);
                if n < buf.len() {
                    return true; // likely drained; poll re-signals if not
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Write as much of `out` as the socket accepts. `false` = fatal error.
fn flush_out(conn: &mut Conn) -> bool {
    while conn.written < conn.out.len() {
        match conn.stream.write(&conn.out[conn.written..]) {
            Ok(0) => return false,
            Ok(n) => {
                conn.written += n;
                conn.last_activity = Instant::now();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    conn.out.clear();
    conn.written = 0;
    true
}

/// Try to complete one request from the parser. Framing errors become a
/// terminal 400/413 written straight from the loop — they never reach
/// `routes::handle`, so `/stats` counts stay request-exact, matching the
/// blocking reader's behavior byte for byte.
fn advance(conn: &mut Conn) -> Option<Request> {
    if conn.inflight || conn.close_after_write {
        return None;
    }
    match conn.parser.poll() {
        Ok(Some(req)) => {
            conn.req_deadline = None;
            conn.inflight = true;
            Some(req)
        }
        Ok(None) => {
            if conn.parser.take_interim_100() {
                conn.out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
            }
            if conn.parser.in_progress() {
                if conn.req_deadline.is_none() {
                    conn.req_deadline = Some(Instant::now() + http::MAX_REQUEST_TIME);
                }
            } else {
                conn.req_deadline = None;
            }
            None
        }
        Err(RecvError::TooLarge(msg)) => {
            conn.queue_close(Response::error(413, msg));
            None
        }
        Err(RecvError::Malformed(msg)) => {
            conn.queue_close(Response::error(400, format!("malformed request: {msg}")));
            None
        }
        Err(RecvError::Closed) => {
            // the push parser never reports Closed, but stay total
            conn.queue_close(Response::error(400, "malformed request: connection closed"));
            None
        }
    }
}

/// Package a parsed request as a pool job that computes the response and
/// mails it back through `shared`.
fn make_job(
    token: u64,
    req: Request,
    state: &Arc<ServiceState>,
    shared: &Arc<Shared>,
) -> Job {
    let state = Arc::clone(state);
    let shared = Arc::clone(shared);
    let keep_alive = req.keep_alive();
    Box::new(move || {
        let resp = routes::handle(&req, &state);
        shared.push(Completion { token, resp, keep_alive });
    })
}

/// Milliseconds until the earliest connection deadline (idle timeout or
/// in-progress request deadline), capped at one second; near-zero when
/// rejected jobs are waiting for a pool slot.
fn next_timeout_ms(
    conns: &HashMap<u64, Conn>,
    read_timeout: Duration,
    jobs_waiting: bool,
    stopping: bool,
) -> i32 {
    let now = Instant::now();
    let until = |t: Instant| t.saturating_duration_since(now).as_millis().min(1000) as i32;
    let mut ms: i32 = 1000;
    if jobs_waiting || stopping {
        ms = ms.min(20);
    }
    for conn in conns.values() {
        if let Some(d) = conn.req_deadline {
            ms = ms.min(until(d));
        }
        if !conn.inflight {
            ms = ms.min(until(conn.last_activity + read_timeout));
        }
    }
    ms.max(0)
}

/// How long a stopping loop keeps delivering in-flight responses before
/// dropping the remaining connections.
const DRAIN_GRACE: Duration = Duration::from_secs(1);

/// The event thread: owns the listener, every connection, and the worker
/// pool (dropping the pool on exit joins the workers). Runs until `stop`
/// is set and in-flight responses have drained (or the grace expires).
pub(crate) fn run(
    listener: TcpListener,
    pool: ThreadPool,
    state: Arc<ServiceState>,
    shared: Arc<Shared>,
    wake_rx: UnixStream,
    read_timeout: Duration,
    stop: Arc<AtomicBool>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let _ = wake_rx.set_nonblocking(true);
    let wake_fd = wake_rx.as_raw_fd();
    let listener_fd = listener.as_raw_fd();

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token: u64 = 0;
    let mut pending_jobs: Vec<Job> = Vec::new();
    let mut drain_deadline: Option<Instant> = None;

    loop {
        let stopping = stop.load(Ordering::SeqCst);
        if stopping {
            // deliver what's owed, drop idle connections now
            conns.retain(|_, c| c.inflight || !c.out.is_empty());
            let deadline =
                *drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_GRACE);
            if conns.is_empty() || Instant::now() >= deadline {
                break;
            }
        }

        // jobs the full pool handed back last tick
        let mut still_waiting = Vec::new();
        for job in pending_jobs.drain(..) {
            if let Err(job) = pool.try_execute(job) {
                still_waiting.push(job);
            }
        }
        pending_jobs = still_waiting;

        // --- build the poll set: [wake, listener?, conns…] ---
        let accepting = !stopping;
        let mut fds = Vec::with_capacity(conns.len() + 2);
        fds.push(PollFd { fd: wake_fd, events: POLLIN, revents: 0 });
        if accepting {
            fds.push(PollFd { fd: listener_fd, events: POLLIN, revents: 0 });
        }
        let mut tokens = Vec::with_capacity(conns.len());
        for (&token, conn) in &conns {
            let mut events = 0i16;
            if !conn.inflight && !conn.read_closed && !conn.close_after_write {
                events |= POLLIN;
            }
            if conn.written < conn.out.len() {
                events |= POLLOUT;
            }
            // zero `events` still reports POLLERR/POLLHUP
            fds.push(PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
            tokens.push(token);
        }

        let timeout =
            next_timeout_ms(&conns, read_timeout, !pending_jobs.is_empty(), stopping);
        if poll_ready(&mut fds, timeout).is_err() {
            // pathological (bad fd table, ENOMEM): back off, don't spin
            std::thread::sleep(Duration::from_millis(10));
            continue;
        }

        // --- self-pipe: swallow accumulated wake bytes ---
        if fds.first().is_some_and(|f| f.revents != 0) {
            let mut sink = [0u8; 64];
            while matches!((&wake_rx).read(&mut sink), Ok(n) if n > 0) {}
        }

        // --- completions from the worker pool ---
        for done in shared.drain() {
            // the connection may already be gone (timeout, error) —
            // the computed response is then dropped, like the old
            // worker writing to a closed socket
            if let Some(conn) = conns.get_mut(&done.token) {
                conn.out.extend_from_slice(&done.resp.to_bytes(done.keep_alive));
                conn.inflight = false;
                if !done.keep_alive {
                    conn.close_after_write = true;
                }
                conn.last_activity = Instant::now();
            }
        }

        // --- new connections ---
        if accepting && fds.get(1).is_some_and(|f| f.revents != 0) {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue; // drop this one, keep accepting
                        }
                        let _ = stream.set_nodelay(true);
                        conns.insert(next_token, Conn::new(stream));
                        next_token += 1;
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    // EMFILE & friends: leave the backlog to the kernel,
                    // retry next tick instead of spinning
                    Err(_) => break,
                }
            }
        }

        // --- per-connection IO (every conn gets a progress attempt:
        //     completions above may have queued bytes on conns whose fd
        //     reported nothing this tick) ---
        let base = if accepting { 2 } else { 1 };
        let mut dead: Vec<u64> = Vec::new();
        for (i, &token) in tokens.iter().enumerate() {
            let revents = fds.get(base + i).map_or(0, |f| f.revents);
            let Some(conn) = conns.get_mut(&token) else { continue };
            let mut alive = revents & (POLLERR | POLLNVAL) == 0;
            if alive
                && revents & (POLLIN | POLLHUP) != 0
                && !conn.read_closed
                && !conn.inflight
                && !conn.close_after_write
            {
                alive = read_some(conn);
            }
            if alive {
                if let Some(req) = advance(conn) {
                    let job = make_job(token, req, &state, &shared);
                    if let Err(job) = pool.try_execute(job) {
                        pending_jobs.push(job);
                    }
                }
                // EOF mid-request: no more bytes can complete it
                if conn.read_closed
                    && !conn.inflight
                    && !conn.close_after_write
                    && conn.parser.in_progress()
                {
                    conn.queue_close(Response::error(
                        400,
                        "malformed request: eof mid-request",
                    ));
                }
                alive = flush_out(conn);
            }
            if alive && conn.out.is_empty() {
                if conn.close_after_write {
                    alive = false; // error/close response fully delivered
                } else if conn.read_closed && !conn.inflight && !conn.parser.in_progress() {
                    alive = false; // clean keep-alive end
                }
            }
            if !alive {
                dead.push(token);
            }
        }
        for token in dead {
            conns.remove(&token);
        }

        // --- deadlines ---
        let now = Instant::now();
        let mut expired: Vec<u64> = Vec::new();
        for (&token, conn) in conns.iter_mut() {
            if conn.req_deadline.is_some_and(|d| now >= d) {
                // total-time bound on one request: the per-read idle
                // clock cannot stop a byte-at-a-time trickler
                conn.queue_close(Response::error(
                    400,
                    "malformed request: request read deadline exceeded",
                ));
                if !flush_out(conn) {
                    expired.push(token);
                }
            } else if !conn.inflight
                && now.duration_since(conn.last_activity) >= read_timeout
            {
                // idle keep-alive (or a stalled reader): close silently,
                // exactly like the blocking reader's socket timeout
                expired.push(token);
            }
        }
        for token in expired {
            conns.remove(&token);
        }
    }
    // `pool` drops here: the queue closes, workers finish and join.
    // Late completions land in `shared.done` and are dropped with it.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_mailbox_roundtrip_and_wake() {
        let (tx, rx) = UnixStream::pair().expect("socketpair");
        rx.set_nonblocking(true).expect("nonblocking");
        let shared = Shared::new(tx);
        shared.push(Completion {
            token: 7,
            resp: Response::json(200, "{}".to_string()),
            keep_alive: true,
        });
        let drained = shared.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].token, 7);
        assert!(drained[0].keep_alive);
        // the push tickled the self-pipe
        let mut sink = [0u8; 8];
        let n = (&rx).read(&mut sink).expect("wake byte present");
        assert!(n >= 1);
        // a second drain is empty
        assert!(shared.drain().is_empty());
    }

    #[test]
    fn timeout_tracks_the_nearest_deadline() {
        let conns: HashMap<u64, Conn> = HashMap::new();
        // no connections: full tick
        assert_eq!(next_timeout_ms(&conns, Duration::from_secs(30), false, false), 1000);
        // waiting jobs shrink the tick
        assert!(next_timeout_ms(&conns, Duration::from_secs(30), true, false) <= 20);
    }
}
