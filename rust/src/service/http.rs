//! Minimal HTTP/1.1 framing over `std::net` (substrate module — the
//! offline build has no hyper/axum, and the planning service needs only
//! request/response framing, keep-alive, and Content-Length bodies).
//!
//! One [`Request`] / [`Response`] pair per round-trip; connections are
//! HTTP/1.1 persistent by default (`Connection: close` opts out). The
//! module also ships a tiny blocking [`Client`] so the integration tests
//! and the loopback benchmark exercise the real wire format instead of
//! calling handlers directly.

use std::collections::BTreeMap;
use std::fmt::Display;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::util::json::{obj, Value};

/// Cap on the request line + headers (a planning request's framing is a
/// few hundred bytes; anything bigger is abuse).
pub const MAX_HEAD_BYTES: usize = 16 << 10;
/// Cap on a request body (an inline 2048-stage chain profile is ~200 KB;
/// 8 MiB leaves two orders of magnitude of headroom).
pub const MAX_BODY_BYTES: usize = 8 << 20;
/// Wall-clock bound on reading one request (head + body). The socket's
/// per-read idle timeout cannot stop a byte-at-a-time trickler — each
/// tiny read resets it — so [`read_request`] also checks this total
/// deadline between reads.
pub const MAX_REQUEST_TIME: Duration = Duration::from_secs(60);

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string (may be empty; no endpoint requires one today).
    pub query: String,
    /// Header names lowercased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    /// HTTP/1.1 default is persistent; `Connection: close` opts out.
    pub fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Why [`read_request`] could not produce a request.
#[derive(Debug)]
pub enum RecvError {
    /// Clean EOF (or idle-timeout) before the first byte of a request —
    /// the normal end of a keep-alive connection. Not an error to report.
    Closed,
    /// Syntactically invalid framing: respond 400 and close.
    Malformed(String),
    /// Head or body over the caps: respond 413 and close.
    TooLarge(String),
}

fn malformed(msg: impl Into<String>) -> RecvError {
    RecvError::Malformed(msg.into())
}

/// Read one `\n`-terminated line of at most `cap` bytes (terminator
/// included), never buffering more than that — `BufRead::read_line`
/// would grow its String without bound on a newline-free flood, which is
/// how [`MAX_HEAD_BYTES`] could otherwise be bypassed. `Ok(None)` means
/// clean EOF (or idle timeout) before the first byte.
fn read_line_capped(
    reader: &mut BufReader<TcpStream>,
    cap: usize,
    deadline: Instant,
) -> Result<Option<String>, RecvError> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        if Instant::now() >= deadline {
            return Err(malformed("request read deadline exceeded"));
        }
        let (take, done) = {
            let available = match reader.fill_buf() {
                Ok(b) => b,
                // timeout / reset: only clean if nothing was read yet
                Err(_) if line.is_empty() => return Ok(None),
                Err(e) => return Err(malformed(format!("mid-line read error: {e}"))),
            };
            if available.is_empty() {
                // EOF
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(malformed("eof mid-line"));
            }
            let (take, done) = match available.iter().position(|&b| b == b'\n') {
                Some(pos) => (pos + 1, true),
                None => (available.len(), false),
            };
            if line.len() + take > cap {
                return Err(RecvError::TooLarge(format!(
                    "request head exceeds {MAX_HEAD_BYTES} bytes"
                )));
            }
            line.extend_from_slice(&available[..take]);
            (take, done)
        };
        reader.consume(take);
        if done {
            return String::from_utf8(line)
                .map(Some)
                .map_err(|_| malformed("request head is not UTF-8"));
        }
    }
}

/// Read one request from a buffered connection. Blocks until a full
/// request arrives, the peer closes, the stream's idle read timeout
/// fires, or the [`MAX_REQUEST_TIME`] deadline passes mid-request.
pub fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Request, RecvError> {
    // the deadline clock starts at the first read *attempt*; an idle
    // keep-alive connection (blocked before its next request) is governed
    // by the socket timeout alone and ends as a clean `Closed`
    let deadline = Instant::now() + MAX_REQUEST_TIME;
    let mut head_budget = MAX_HEAD_BYTES;
    let Some(line) = read_line_capped(reader, head_budget, deadline)? else {
        // idle keep-alive end (EOF/timeout) before a request started
        return Err(RecvError::Closed);
    };
    head_budget -= line.len().min(head_budget);
    let request_line = line.trim_end_matches(['\r', '\n']).to_string();

    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(malformed(format!("bad request line '{request_line}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };

    let mut headers = BTreeMap::new();
    loop {
        let Some(hline) = read_line_capped(reader, head_budget, deadline)? else {
            return Err(malformed("eof inside headers"));
        };
        head_budget -= hline.len().min(head_budget);
        let hline = hline.trim_end_matches(['\r', '\n']);
        if hline.is_empty() {
            break;
        }
        let Some((name, value)) = hline.split_once(':') else {
            return Err(malformed(format!("bad header line '{hline}'")));
        };
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }

    // chunked framing is not implemented; without this rejection the
    // chunk stream would be misparsed as pipelined requests
    if headers.contains_key("transfer-encoding") {
        return Err(malformed(
            "Transfer-Encoding is not supported; send a Content-Length body",
        ));
    }
    let content_length = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| malformed(format!("bad Content-Length '{v}'")))?,
    };
    if content_length > MAX_BODY_BYTES {
        return Err(RecvError::TooLarge(format!(
            "body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
        )));
    }
    // standards-following clients (curl for bodies over ~1 KB) wait for
    // the interim 100 before sending the payload
    if content_length > 0
        && headers
            .get("expect")
            .is_some_and(|v| v.to_ascii_lowercase().contains("100-continue"))
    {
        let _ = reader.get_mut().write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
        let _ = reader.get_mut().flush();
    }
    // chunked body reads so the total deadline is checked between
    // syscalls (read_exact could trickle forever one byte at a time)
    let mut body = vec![0u8; content_length];
    let mut filled = 0usize;
    while filled < content_length {
        if Instant::now() >= deadline {
            return Err(malformed("request read deadline exceeded mid-body"));
        }
        match reader.read(&mut body[filled..]) {
            Ok(0) => return Err(malformed("eof mid-body")),
            Ok(n) => filled += n,
            Err(e) => {
                return Err(malformed(format!("reading {content_length}-byte body: {e}")))
            }
        }
    }

    Ok(Request { method, path, query, headers, body })
}

// ---------------------------------------------------------------------------
// Incremental parser (event loop)
// ---------------------------------------------------------------------------

/// A request head parsed out of the incremental buffer, waiting for its
/// body bytes.
#[derive(Debug)]
struct PendingHead {
    method: String,
    path: String,
    query: String,
    headers: BTreeMap<String, String>,
    content_length: usize,
}

/// Push-based counterpart of [`read_request`] for the readiness loop:
/// the caller [`RequestParser::feed`]s whatever bytes the socket had,
/// then [`RequestParser::poll`]s for complete requests — the parser
/// never blocks, never owns a socket, and keeps pipelined leftovers
/// buffered for the next poll.
///
/// The framing rules are identical to the blocking reader: same
/// [`MAX_HEAD_BYTES`]/[`MAX_BODY_BYTES`] caps, same `Transfer-Encoding`
/// rejection, same `Expect: 100-continue` handling (surfaced as
/// [`RequestParser::take_interim_100`] since the parser cannot write).
/// An error from `poll` is terminal: the connection is broken-framed and
/// must be closed after the error response.
#[derive(Debug, Default)]
pub struct RequestParser {
    buf: Vec<u8>,
    head: Option<PendingHead>,
    interim_100: bool,
    failed: bool,
}

impl RequestParser {
    pub fn new() -> RequestParser {
        RequestParser::default()
    }

    /// Buffer freshly read socket bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Mid-request? Distinguishes a clean keep-alive EOF (between
    /// requests) from a truncated one.
    pub fn in_progress(&self) -> bool {
        self.head.is_some() || !self.buf.is_empty()
    }

    /// True once per request that asked `Expect: 100-continue` with a
    /// body: the event loop writes the interim response and clears the
    /// flag by taking it.
    pub fn take_interim_100(&mut self) -> bool {
        std::mem::take(&mut self.interim_100)
    }

    /// Try to complete one request from the buffered bytes. `Ok(None)`
    /// means "need more bytes"; errors are terminal for the connection.
    pub fn poll(&mut self) -> Result<Option<Request>, RecvError> {
        if self.failed {
            return Err(malformed("parser already failed"));
        }
        match self.poll_inner() {
            Ok(v) => Ok(v),
            Err(e) => {
                self.failed = true;
                Err(e)
            }
        }
    }

    fn poll_inner(&mut self) -> Result<Option<Request>, RecvError> {
        if self.head.is_none() {
            let Some(head_end) = find_head_end(&self.buf) else {
                if self.buf.len() > MAX_HEAD_BYTES {
                    return Err(RecvError::TooLarge(format!(
                        "request head exceeds {MAX_HEAD_BYTES} bytes"
                    )));
                }
                return Ok(None);
            };
            if head_end > MAX_HEAD_BYTES {
                return Err(RecvError::TooLarge(format!(
                    "request head exceeds {MAX_HEAD_BYTES} bytes"
                )));
            }
            let head_bytes: Vec<u8> = self.buf.drain(..head_end).collect();
            let head = parse_head(&head_bytes)?;
            if head.content_length > MAX_BODY_BYTES {
                return Err(RecvError::TooLarge(format!(
                    "body of {} bytes exceeds the {MAX_BODY_BYTES}-byte cap",
                    head.content_length
                )));
            }
            if head.content_length > 0
                && head
                    .headers
                    .get("expect")
                    .is_some_and(|v| v.to_ascii_lowercase().contains("100-continue"))
            {
                self.interim_100 = true;
            }
            self.head = Some(head);
        }
        let ready = self
            .head
            .as_ref()
            .is_some_and(|h| self.buf.len() >= h.content_length);
        if !ready {
            return Ok(None);
        }
        let Some(head) = self.head.take() else {
            return Ok(None);
        };
        let body: Vec<u8> = self.buf.drain(..head.content_length).collect();
        self.interim_100 = false; // body arrived without the interim nudge
        Ok(Some(Request {
            method: head.method,
            path: head.path,
            query: head.query,
            headers: head.headers,
            body,
        }))
    }
}

/// Index one past the head terminator (`\n` + optional `\r` + `\n`), or
/// `None` while incomplete.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            match buf.get(i + 1) {
                Some(b'\n') => return Some(i + 2),
                Some(b'\r') if buf.get(i + 2) == Some(&b'\n') => return Some(i + 3),
                _ => {}
            }
        }
        i += 1;
    }
    None
}

/// Parse a complete head (request line + headers + blank line) with the
/// exact rules of [`read_request`].
fn parse_head(bytes: &[u8]) -> Result<PendingHead, RecvError> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| malformed("request head is not UTF-8"))?;
    let mut lines = text.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(malformed(format!("bad request line '{request_line}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut headers = BTreeMap::new();
    for hline in lines {
        if hline.is_empty() {
            break;
        }
        let Some((name, value)) = hline.split_once(':') else {
            return Err(malformed(format!("bad header line '{hline}'")));
        };
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }
    if headers.contains_key("transfer-encoding") {
        return Err(malformed(
            "Transfer-Encoding is not supported; send a Content-Length body",
        ));
    }
    let content_length = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| malformed(format!("bad Content-Length '{v}'")))?,
    };
    Ok(PendingHead { method, path, query, headers, content_length })
}

/// One response, always written with an explicit `Content-Length`.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub body: String,
    pub content_type: &'static str,
}

impl Response {
    /// A `200 OK` (or other status) JSON payload.
    pub fn json(status: u16, body: String) -> Response {
        Response { status, body, content_type: "application/json" }
    }

    /// A plain-text payload. The version suffix is the Prometheus text
    /// exposition format marker (`GET /metrics` is the one text route).
    pub fn text(status: u16, body: String) -> Response {
        Response { status, body, content_type: "text/plain; version=0.0.4" }
    }

    /// The service's structured error envelope:
    /// `{"error": {"code": <status>, "kind": "...", "message": "..."}}`.
    ///
    /// Protocol-level errors (bad framing, unknown route, wrong method)
    /// derive `kind` from the status so clients can always dispatch on
    /// the field; semantic handler errors go through the router's
    /// `error_response`, whose `kind` is the precise
    /// `api::ErrorKind` name instead.
    pub fn error(status: u16, message: impl Display) -> Response {
        let kind = match status {
            400 => "bad_request",
            404 => "not_found",
            405 => "method_not_allowed",
            413 => "payload_too_large",
            422 => "invalid_spec",
            _ => "internal",
        };
        let payload = obj([(
            "error",
            obj([
                ("code", Value::from(status as u64)),
                ("kind", Value::from(kind)),
                ("message", Value::from(message.to_string())),
            ]),
        )]);
        Response::json(status, payload.to_json_string())
    }

    /// The full wire form (head + body) — what the event loop queues on
    /// a connection's outbound buffer.
    pub fn to_bytes(&self, keep_alive: bool) -> Vec<u8> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        let mut out = Vec::with_capacity(head.len() + self.body.len());
        out.extend_from_slice(head.as_bytes());
        out.extend_from_slice(self.body.as_bytes());
        out
    }

    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> io::Result<()> {
        w.write_all(&self.to_bytes(keep_alive))?;
        w.flush()
    }
}

/// Reason phrases for the statuses the service emits.
fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

// ---------------------------------------------------------------------------
// Blocking client (tests, benches, ad-hoc probing)
// ---------------------------------------------------------------------------

/// A persistent (keep-alive) connection to the planning service. Each
/// [`Client::request`] is one synchronous round-trip on the same socket.
pub struct Client {
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { reader: BufReader::new(stream) })
    }

    /// Send one request, return `(status, body)`. `body = None` sends no
    /// payload (GET); `Some(json)` sends it as `application/json`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> io::Result<(u16, String)> {
        let payload = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: chainckpt\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n",
            payload.len()
        );
        let stream = self.reader.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
        stream.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> io::Result<(u16, String)> {
        let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
        let mut status_line = String::new();
        if self.reader.read_line(&mut status_line)? == 0 {
            return Err(bad("connection closed before a status line".into()));
        }
        let status: u16 = status_line
            .split_ascii_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| bad(format!("bad status line '{}'", status_line.trim())))?;
        let mut content_length = 0usize;
        loop {
            let mut hline = String::new();
            if self.reader.read_line(&mut hline)? == 0 {
                return Err(bad("connection closed inside response headers".into()));
            }
            let hline = hline.trim_end_matches(['\r', '\n']);
            if hline.is_empty() {
                break;
            }
            if let Some((name, value)) = hline.split_once(':') {
                if name.trim().eq_ignore_ascii_case("content-length") {
                    content_length = value
                        .trim()
                        .parse()
                        .map_err(|_| bad(format!("bad Content-Length '{value}'")))?;
                }
            }
        }
        let mut body = vec![0u8; content_length];
        self.reader.read_exact(&mut body)?;
        String::from_utf8(body)
            .map(|b| (status, b))
            .map_err(|_| bad("response body is not UTF-8".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Frame one canned request through a real socket pair.
    fn roundtrip(raw: &str) -> Result<Request, RecvError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let req = read_request(&mut reader);
        writer.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let req = roundtrip(
            "POST /solve?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/solve");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.body, b"{\"a\":1}");
        assert!(req.keep_alive());
    }

    #[test]
    fn connection_close_is_honored() {
        let req =
            roundtrip("GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.keep_alive());
        assert!(req.body.is_empty());
    }

    #[test]
    fn malformed_request_line_rejected() {
        assert!(matches!(roundtrip("NONSENSE\r\n\r\n"), Err(RecvError::Malformed(_))));
        assert!(matches!(
            roundtrip("GET /x SMTP/1.0\r\n\r\n"),
            Err(RecvError::Malformed(_))
        ));
    }

    #[test]
    fn chunked_transfer_encoding_rejected() {
        // unimplemented framing must be refused, not misparsed as a
        // zero-length body followed by garbage pipelined requests
        let res = roundtrip(
            "POST /solve HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n3\r\nabc\r\n0\r\n\r\n",
        );
        match res {
            Err(RecvError::Malformed(msg)) => assert!(msg.contains("Transfer-Encoding")),
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn oversized_body_rejected_without_reading_it() {
        let raw = format!(
            "POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(matches!(roundtrip(&raw), Err(RecvError::TooLarge(_))));
    }

    #[test]
    fn clean_eof_is_closed_not_malformed() {
        assert!(matches!(roundtrip(""), Err(RecvError::Closed)));
    }

    #[test]
    fn newline_free_head_flood_is_capped_not_buffered() {
        // a request line with no '\n' must hit the head cap, not grow an
        // unbounded line buffer (the write side may see a reset once the
        // server bails — ignore its errors)
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let chunk = [b'A'; 4096];
            for _ in 0..64 {
                if s.write_all(&chunk).is_err() {
                    break; // server already rejected and closed
                }
            }
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let res = read_request(&mut reader);
        assert!(matches!(res, Err(RecvError::TooLarge(_))), "{res:?}");
        drop(reader);
        writer.join().unwrap();
    }

    #[test]
    fn expect_100_continue_gets_the_interim_response() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"POST /solve HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n",
            )
            .unwrap();
            // wait for the interim response before sending the body
            let mut interim = [0u8; 25]; // "HTTP/1.1 100 Continue\r\n\r\n"
            s.read_exact(&mut interim).unwrap();
            assert!(interim.starts_with(b"HTTP/1.1 100"));
            s.write_all(b"{}").unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let req = read_request(&mut reader).unwrap();
        assert_eq!(req.body, b"{}");
        client.join().unwrap();
    }

    #[test]
    fn incremental_parser_handles_byte_at_a_time_feeding() {
        let raw = b"POST /solve?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 7\r\n\r\n{\"a\":1}";
        let mut p = RequestParser::new();
        for (i, b) in raw.iter().enumerate() {
            assert!(
                p.poll().expect("prefix must not error").is_none(),
                "complete at byte {i} of {}",
                raw.len()
            );
            p.feed(&[*b]);
        }
        let req = p.poll().unwrap().expect("full request buffered");
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/solve");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.body, b"{\"a\":1}");
        assert!(!p.in_progress());
    }

    #[test]
    fn incremental_parser_yields_pipelined_requests_in_order() {
        let mut p = RequestParser::new();
        p.feed(b"GET /healthz HTTP/1.1\r\n\r\nGET /stats HTTP/1.1\r\nConnection: close\r\n\r\n");
        let a = p.poll().unwrap().expect("first request");
        assert_eq!(a.path, "/healthz");
        assert!(a.keep_alive());
        let b = p.poll().unwrap().expect("second request");
        assert_eq!(b.path, "/stats");
        assert!(!b.keep_alive());
        assert!(p.poll().unwrap().is_none());
        assert!(!p.in_progress());
    }

    #[test]
    fn incremental_parser_enforces_the_same_caps_and_rejections() {
        // unterminated head flood
        let mut p = RequestParser::new();
        p.feed(&vec![b'A'; MAX_HEAD_BYTES + 1]);
        assert!(matches!(p.poll(), Err(RecvError::TooLarge(_))));

        // oversized declared body, rejected before any body byte
        let mut p = RequestParser::new();
        p.feed(
            format!("POST /solve HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
                .as_bytes(),
        );
        assert!(matches!(p.poll(), Err(RecvError::TooLarge(_))));

        // chunked framing refused exactly like the blocking reader
        let mut p = RequestParser::new();
        p.feed(b"POST /solve HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
        match p.poll() {
            Err(RecvError::Malformed(msg)) => assert!(msg.contains("Transfer-Encoding")),
            other => panic!("expected Malformed, got {other:?}"),
        }

        // bad request line
        let mut p = RequestParser::new();
        p.feed(b"NONSENSE\r\n\r\n");
        assert!(matches!(p.poll(), Err(RecvError::Malformed(_))));
    }

    #[test]
    fn incremental_parser_surfaces_expect_100_continue_once() {
        let mut p = RequestParser::new();
        p.feed(b"POST /solve HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n");
        assert!(p.poll().unwrap().is_none(), "body still outstanding");
        assert!(p.take_interim_100(), "interim flag raised with the head");
        assert!(!p.take_interim_100(), "taking clears it");
        p.feed(b"{}");
        let req = p.poll().unwrap().expect("body arrived");
        assert_eq!(req.body, b"{}");
        assert!(!p.take_interim_100());
    }

    #[test]
    fn error_response_is_structured_json() {
        let resp = Response::error(404, "no route GET /nope");
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("error").unwrap().get("code").unwrap().as_u64(), Some(404));
        assert_eq!(
            v.get("error").unwrap().get("message").unwrap().as_str(),
            Some("no route GET /nope")
        );
    }
}
