//! The planning service: a concurrent daemon serving optimal
//! checkpointing schedules over HTTP/1.1 + JSON (std-only, like every
//! substrate in this crate — no tokio, no hyper, no serde).
//!
//! The paper's tool answers one `(chain, budget)` query per offline run;
//! [`crate::solver::Planner`] already amortizes one DP table across every
//! budget of a chain. This module is where that amortization meets
//! *traffic*: a [`TcpListener`] accept loop feeds a bounded
//! [`pool::ThreadPool`], each request routes through [`routes`], and every
//! planning request for a chain the service has seen before — from any
//! connection, any thread — is a fingerprint-keyed table lookup instead
//! of an O(L²·S) DP fill. Single-flight building (see
//! `solver::planner::table_for`) means even a thundering herd for a cold
//! chain runs the DP exactly once.
//!
//! ```sh
//! chainckpt serve --port 8080 &
//! curl -s localhost:8080/solve -d '{
//!   "chain": {"profile": {"family": "resnet", "depth": 101,
//!             "image": 1000, "batch": 8}},
//!   "memory": "4G"}'
//! ```
//!
//! Start in-process with [`serve`]; the returned [`Server`] carries the
//! bound address (ephemeral ports supported: `--port 0`) and stops the
//! daemon on drop — the integration tests and the loopback benchmark run
//! the real wire protocol this way.

pub mod http;
pub mod pool;
pub mod routes;
pub mod wire;

use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::chain::DEFAULT_SLOTS;

/// Tunables of one daemon instance.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks an ephemeral port).
    pub addr: String,
    /// Worker threads; `0` = one per available core.
    pub workers: usize,
    /// Connections queued beyond busy workers before the accept loop
    /// blocks (kernel backlog then holds the rest).
    pub queue_depth: usize,
    /// Default DP discretization for requests that don't pass `"slots"`.
    pub slots: usize,
    /// Per-read idle timeout: a connection with no next request after
    /// this long is closed. (A single request's head+body read is
    /// additionally wall-clock-bounded by [`http::MAX_REQUEST_TIME`], so
    /// a byte-at-a-time trickler cannot pin a worker indefinitely.)
    pub read_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:8080".to_string(),
            workers: 0,
            queue_depth: 64,
            slots: DEFAULT_SLOTS,
            read_timeout: Duration::from_secs(30),
        }
    }
}

/// State shared by every worker: request-independent config + counters.
pub struct ServiceState {
    /// Default slot count for planning requests.
    pub slots: usize,
    /// Request counters and latency reservoir (`GET /stats`).
    pub stats: routes::Stats,
    /// Daemon start time (`uptime_s` in `/stats`).
    pub started: Instant,
}

/// Socket clones of every live connection, so shutdown can unblock
/// workers parked in a keep-alive read instead of waiting out the idle
/// timeout.
#[derive(Default)]
struct ConnRegistry {
    conns: Mutex<Vec<(u64, TcpStream)>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<(u64, TcpStream)>> {
        self.conns.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn register(&self, stream: &TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        if let Ok(clone) = stream.try_clone() {
            self.lock().push((id, clone));
        }
        id
    }

    fn deregister(&self, id: u64) {
        self.lock().retain(|(i, _)| *i != id);
    }

    fn shutdown_all(&self) {
        for (_, stream) in self.lock().iter() {
            // Read only: wakes workers parked on a keep-alive read while
            // letting a worker mid-request still write its response
            let _ = stream.shutdown(Shutdown::Read);
        }
    }
}

/// A running daemon. Dropping it (or calling [`Server::stop`]) shuts the
/// accept loop down and joins every worker.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    state: Arc<ServiceState>,
    registry: Arc<ConnRegistry>,
}

/// Bind and start serving in background threads; returns once the
/// listener is live (requests can be sent immediately).
pub fn serve(cfg: ServiceConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding planning service to {}", cfg.addr))?;
    let addr = listener.local_addr().context("reading bound address")?;
    let state = Arc::new(ServiceState {
        slots: cfg.slots,
        stats: routes::Stats::default(),
        started: Instant::now(),
    });
    let stop = Arc::new(AtomicBool::new(false));
    let registry = Arc::new(ConnRegistry::default());

    let accept = {
        let state = Arc::clone(&state);
        let stop = Arc::clone(&stop);
        let registry = Arc::clone(&registry);
        let workers = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        } else {
            cfg.workers
        };
        let queue_depth = cfg.queue_depth;
        let read_timeout = cfg.read_timeout;
        std::thread::Builder::new()
            .name("chainckpt-accept".to_string())
            .spawn(move || {
                // the pool lives (and dies) with the accept loop: dropping
                // it at the end drains queued connections and joins workers
                let pool = pool::ThreadPool::new("chainckpt-http", workers, queue_depth);
                for conn in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else {
                        // e.g. EMFILE under fd exhaustion: back off instead
                        // of spinning the accept thread at 100% CPU
                        std::thread::sleep(Duration::from_millis(50));
                        continue;
                    };
                    let state = Arc::clone(&state);
                    let stop = Arc::clone(&stop);
                    let registry = Arc::clone(&registry);
                    pool.execute(move || {
                        let id = registry.register(&stream);
                        handle_connection(stream, &state, read_timeout, &stop);
                        registry.deregister(id);
                    });
                }
            })
            .context("spawning the accept thread")?
    };

    Ok(Server { addr, stop, accept: Some(accept), state, registry })
}

impl Server {
    /// The bound address (resolves `--port 0` to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared daemon state (stats introspection in tests/benches).
    pub fn state(&self) -> &ServiceState {
        &self.state
    }

    /// Block the calling thread for the daemon's lifetime (the `serve`
    /// subcommand's foreground mode).
    pub fn join(mut self) {
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }

    /// Stop accepting, drain in-flight requests, join every thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock workers parked on keep-alive reads (no waiting out the
        // idle timeout), then the accept loop with a throwaway connection
        self.registry.shutdown_all();
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one connection: HTTP/1.1 keep-alive loop until the peer closes,
/// errs, times out idle, asks for `Connection: close`, or the daemon
/// shuts down (which also force-closes the socket via the registry).
fn handle_connection(
    stream: TcpStream,
    state: &ServiceState,
    read_timeout: Duration,
    stop: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(read_timeout));
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::SeqCst) {
            return; // draining: close instead of starting another read
        }
        let req = match http::read_request(&mut reader) {
            Ok(req) => req,
            Err(http::RecvError::Closed) => return,
            Err(http::RecvError::Malformed(msg)) => {
                let resp = http::Response::error(400, format!("malformed request: {msg}"));
                let _ = resp.write_to(reader.get_mut(), false);
                return;
            }
            Err(http::RecvError::TooLarge(msg)) => {
                let resp = http::Response::error(413, msg);
                let _ = resp.write_to(reader.get_mut(), false);
                return;
            }
        };
        let keep_alive = req.keep_alive();
        let resp = routes::handle(&req, state);
        if resp.write_to(reader.get_mut(), keep_alive).is_err() || !keep_alive {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    /// End-to-end smoke entirely in unit-test scope: bind an ephemeral
    /// port, one request, clean shutdown. (The full protocol matrix lives
    /// in `tests/service_integration.rs`.)
    #[test]
    fn serve_healthz_and_shutdown() {
        let server = serve(ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            ..ServiceConfig::default()
        })
        .expect("bind ephemeral port");
        let mut client = http::Client::connect(server.addr()).unwrap();
        let (status, body) = client.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        let v = Value::parse(&body).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(server.state().stats.total(), 1);
        // stop with the keep-alive connection still open: the registry
        // force-closes the socket, so this returns promptly instead of
        // waiting out the 30 s idle read timeout
        let t0 = std::time::Instant::now();
        server.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shutdown must not wait for the idle keep-alive timeout"
        );
        drop(client);
    }
}
