//! The planning service: a concurrent daemon serving optimal
//! checkpointing schedules over HTTP/1.1 + JSON (std-only, like every
//! substrate in this crate — no tokio, no hyper, no serde).
//!
//! The paper's tool answers one `(chain, budget)` query per offline run;
//! [`crate::solver::Planner`] already amortizes one DP table across every
//! budget of a chain. This module is where that amortization meets
//! *traffic*: a single [`event_loop`] thread multiplexes every client
//! socket through `poll(2)`, feeding complete requests to a bounded
//! [`pool::ThreadPool`] that routes through [`routes`]. Connections cost
//! a file descriptor, not a thread, so thousands of idle keep-alive
//! clients coexist with a handful of workers. Every planning request for
//! a chain the service has seen before — from any connection, any
//! thread — is a fingerprint-keyed table lookup instead of an O(L²·S) DP
//! fill, and with a `table_dir` configured the tables also persist
//! across restarts (`solver::persist`): a rebooted daemon reloads solved
//! tables from disk instead of re-running the DP. Single-flight building
//! (see `solver::planner::table_for`) means even a thundering herd for a
//! cold chain runs the DP exactly once.
//!
//! ```sh
//! chainckpt serve --port 8080 --table-dir /var/lib/chainckpt &
//! curl -s localhost:8080/solve -d '{
//!   "chain": {"profile": {"family": "resnet", "depth": 101,
//!             "image": 1000, "batch": 8}},
//!   "memory": "4G"}'
//! ```
//!
//! Start in-process with [`serve`]; the returned [`Server`] carries the
//! bound address (ephemeral ports supported: `--port 0`) and stops the
//! daemon on drop — the integration tests and the loopback benchmark run
//! the real wire protocol this way.

pub mod event_loop;
pub mod http;
pub mod pool;
pub mod routes;
pub mod wire;

use std::net::{SocketAddr, TcpListener};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::chain::DEFAULT_SLOTS;

/// Tunables of one daemon instance.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks an ephemeral port).
    pub addr: String,
    /// Worker threads; `0` = one per available core.
    pub workers: usize,
    /// Jobs queued beyond busy workers; the event loop holds further
    /// complete requests itself (and stops reading their connections), so
    /// the queue bounds *compute* backlog, not connection count.
    pub queue_depth: usize,
    /// Default DP discretization for requests that don't pass `"slots"`.
    pub slots: usize,
    /// Idle timeout: a connection with no in-progress request and no
    /// traffic for this long is closed. (A single request's head+body
    /// read is additionally wall-clock-bounded by
    /// [`http::MAX_REQUEST_TIME`], so a byte-at-a-time trickler cannot
    /// pin a connection indefinitely.)
    pub read_timeout: Duration,
    /// Directory for the persistent DP-table store (`solver::persist`).
    /// `None` disables the disk tier: tables then live only in the
    /// in-process LRU and die with the daemon.
    pub table_dir: Option<PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:8080".to_string(),
            workers: 0,
            queue_depth: 64,
            slots: DEFAULT_SLOTS,
            read_timeout: Duration::from_secs(30),
            table_dir: None,
        }
    }
}

/// State shared by every worker: request-independent config + counters.
pub struct ServiceState {
    /// Default slot count for planning requests.
    pub slots: usize,
    /// Request counters and latency reservoir (`GET /stats`).
    pub stats: routes::Stats,
    /// Daemon start time (`uptime_s` in `/stats`).
    pub started: Instant,
}

/// A running daemon. Dropping it (or calling [`Server::stop`]) shuts the
/// event loop down and joins every thread.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    event: Option<JoinHandle<()>>,
    state: Arc<ServiceState>,
    shared: Arc<event_loop::Shared>,
}

/// Bind and start serving in background threads; returns once the
/// listener is live (requests can be sent immediately).
pub fn serve(cfg: ServiceConfig) -> Result<Server> {
    // the disk tier is planner-global (one process, one planner cache):
    // configure it before the first request can race a table build
    crate::solver::set_table_dir(cfg.table_dir.clone());
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding planning service to {}", cfg.addr))?;
    let addr = listener.local_addr().context("reading bound address")?;
    let state = Arc::new(ServiceState {
        slots: cfg.slots,
        stats: routes::Stats::default(),
        started: Instant::now(),
    });
    let stop = Arc::new(AtomicBool::new(false));

    let workers = if cfg.workers == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    } else {
        cfg.workers
    };
    let pool = pool::ThreadPool::new("chainckpt-http", workers, cfg.queue_depth)
        .context("spawning the worker pool")?;
    // self-pipe: workers (and shutdown) interrupt the event thread's poll
    let (wake_tx, wake_rx) =
        UnixStream::pair().context("creating the event-loop wake pipe")?;
    let shared = Arc::new(event_loop::Shared::new(wake_tx));

    let event = {
        let state = Arc::clone(&state);
        let stop = Arc::clone(&stop);
        let shared = Arc::clone(&shared);
        let read_timeout = cfg.read_timeout;
        std::thread::Builder::new()
            .name("chainckpt-eventloop".to_string())
            .spawn(move || {
                // the pool lives (and dies) with the event loop: run()
                // drops it on exit, draining queued jobs and joining
                // workers
                event_loop::run(listener, pool, state, shared, wake_rx, read_timeout, stop);
            })
            .context("spawning the event-loop thread")?
    };

    Ok(Server { addr, stop, event: Some(event), state, shared })
}

impl Server {
    /// The bound address (resolves `--port 0` to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared daemon state (stats introspection in tests/benches).
    pub fn state(&self) -> &ServiceState {
        &self.state
    }

    /// Block the calling thread for the daemon's lifetime (the `serve`
    /// subcommand's foreground mode).
    pub fn join(mut self) {
        if let Some(handle) = self.event.take() {
            let _ = handle.join();
        }
    }

    /// Stop accepting, drain in-flight requests, join every thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // interrupt the poll; the loop then stops accepting, delivers
        // in-flight responses (bounded grace), and exits
        self.shared.wake();
        if let Some(handle) = self.event.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    /// End-to-end smoke entirely in unit-test scope: bind an ephemeral
    /// port, one request, clean shutdown. (The full protocol matrix lives
    /// in `tests/service_integration.rs` and `tests/service_event_loop.rs`.)
    #[test]
    fn serve_healthz_and_shutdown() {
        let server = serve(ServiceConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            ..ServiceConfig::default()
        })
        .expect("bind ephemeral port");
        let mut client = http::Client::connect(server.addr()).unwrap();
        let (status, body) = client.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        let v = Value::parse(&body).unwrap();
        assert_eq!(v.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(server.state().stats.total(), 1);
        // stop with the keep-alive connection still open: the event loop
        // drops idle connections immediately on stop, so this returns
        // promptly instead of waiting out the 30 s idle timeout
        let t0 = std::time::Instant::now();
        server.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "shutdown must not wait for the idle keep-alive timeout"
        );
        drop(client);
    }
}
