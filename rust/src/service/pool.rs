//! Bounded worker pool for the planning service (substrate module — no
//! tokio/rayon offline; plain `std::thread` + `mpsc::sync_channel`, the
//! same no-dependency threading discipline as `solver::planner::sweep`).
//!
//! The queue is *bounded*, and callers choose their backpressure:
//! [`ThreadPool::execute`] blocks the submitting thread until a slot
//! frees (the original accept-loop discipline), while
//! [`ThreadPool::try_execute`] hands the job straight back on a full
//! queue — the shape the event loop needs, since it must never block
//! its readiness thread on worker availability.

use std::io;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A unit of work for the pool — boxed so submitters can hand jobs back
/// and forth (see [`ThreadPool::try_execute`]).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    /// `None` once the pool is shutting down (drop closes the channel).
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `workers` threads consuming from a queue of `queue_depth`
    /// pending jobs. Worker counts are clamped to ≥ 1. Fails only when
    /// the OS refuses to spawn a thread; already-spawned workers are
    /// joined on the way out (the channel closes with the partial pool).
    pub fn new(name: &str, workers: usize, queue_depth: usize) -> io::Result<ThreadPool> {
        let (tx, rx) = sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers.max(1));
        for i in 0..workers.max(1) {
            let rx = Arc::clone(&rx);
            let spawned = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || worker_loop(&rx));
            match spawned {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    drop(tx); // close the channel so partial workers exit
                    for handle in handles {
                        let _ = handle.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(ThreadPool { tx: Some(tx), workers: handles })
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; blocks while the queue is full (bounded backpressure).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            // send only fails if every worker died, which `worker_loop`
            // prevents by catching job panics; drop the job in that case
            let _ = tx.send(Box::new(job));
        }
    }

    /// Submit a job without blocking: on a full queue (or a shut-down
    /// pool) the job comes back as `Err` so the caller can retry later.
    pub fn try_execute(&self, job: Job) -> Result<(), Job> {
        let Some(tx) = &self.tx else { return Err(job) };
        match tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(job)) | Err(TrySendError::Disconnected(job)) => Err(job),
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // Hold the lock only to *receive* — the Rust-book pattern: one
        // idle worker parks in recv, the rest park on the mutex, and a
        // running job holds neither.
        let job = {
            let rx = rx.lock().unwrap_or_else(|p| p.into_inner());
            rx.recv()
        };
        match job {
            Ok(job) => {
                // A panicking handler must not shrink the pool: catch it,
                // log it, keep serving (the connection just closes).
                if let Err(panic) = catch_unwind(AssertUnwindSafe(job)) {
                    let msg = panic
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| panic.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".into());
                    eprintln!("[service] worker job panicked: {msg}");
                }
            }
            Err(_) => break, // channel closed: pool is shutting down
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx = None; // close the channel; workers drain the queue, then exit
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    fn pool(name: &str, workers: usize, depth: usize) -> ThreadPool {
        ThreadPool::new(name, workers, depth).expect("spawning test pool")
    }

    #[test]
    fn runs_all_jobs_across_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = pool("t", 4, 2);
            assert_eq!(pool.workers(), 4);
            for _ in 0..64 {
                let counter = Arc::clone(&counter);
                pool.execute(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop joins the workers after the queue drains
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn a_panicking_job_does_not_kill_the_pool() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = pool("t", 1, 4);
            pool.execute(|| panic!("boom"));
            // give the lone worker time to survive the panic
            std::thread::sleep(Duration::from_millis(20));
            let counter = Arc::clone(&counter);
            pool.execute(move || {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = pool("t", 0, 0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn try_execute_returns_the_job_when_the_queue_is_full() {
        let gate = Arc::new(AtomicUsize::new(0));
        let pool = pool("t", 1, 1);
        // occupy the lone worker…
        let g = Arc::clone(&gate);
        pool.execute(move || {
            while g.load(Ordering::SeqCst) == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        // …and fill the 1-slot queue (may need a beat for the worker to
        // pick up the first job)
        let mut queued = false;
        for _ in 0..100 {
            if pool.try_execute(Box::new(|| {})).is_ok() {
                queued = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(queued, "queue slot never freed");
        // now both worker and queue are busy: the job must come back
        let ran = Arc::new(AtomicUsize::new(0));
        let r = Arc::clone(&ran);
        let job: Job = Box::new(move || {
            r.fetch_add(1, Ordering::SeqCst);
        });
        let job = pool.try_execute(job).expect_err("full queue must reject");
        gate.store(1, Ordering::SeqCst); // release the worker
        // the returned job is still runnable — resubmit until it lands
        let mut job = Some(job);
        for _ in 0..1000 {
            match pool.try_execute(job.take().expect("job present")) {
                Ok(()) => break,
                Err(back) => {
                    job = Some(back);
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        drop(pool);
        assert_eq!(ran.load(Ordering::SeqCst), 1, "returned job must run when resubmitted");
    }
}
