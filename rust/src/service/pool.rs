//! Bounded worker pool for the planning service (substrate module — no
//! tokio/rayon offline; plain `std::thread` + `mpsc::sync_channel`, the
//! same no-dependency threading discipline as `solver::planner::sweep`).
//!
//! The queue is *bounded*: when every worker is busy and the backlog is
//! full, [`ThreadPool::execute`] blocks the submitting thread (the accept
//! loop), which is exactly the backpressure a loopback daemon wants —
//! the kernel's listen backlog holds new connections instead of this
//! process buffering unbounded closures.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    /// `None` once the pool is shutting down (drop closes the channel).
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `workers` threads consuming from a queue of `queue_depth`
    /// pending jobs. Worker counts are clamped to ≥ 1.
    pub fn new(name: &str, workers: usize, queue_depth: usize) -> ThreadPool {
        let (tx, rx) = sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawning a pool worker thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job; blocks while the queue is full (bounded backpressure).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        if let Some(tx) = &self.tx {
            // send only fails if every worker died, which `worker_loop`
            // prevents by catching job panics; drop the job in that case
            let _ = tx.send(Box::new(job));
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // Hold the lock only to *receive* — the Rust-book pattern: one
        // idle worker parks in recv, the rest park on the mutex, and a
        // running job holds neither.
        let job = {
            let rx = rx.lock().unwrap_or_else(|p| p.into_inner());
            rx.recv()
        };
        match job {
            Ok(job) => {
                // A panicking handler must not shrink the pool: catch it,
                // log it, keep serving (the connection just closes).
                if let Err(panic) = catch_unwind(AssertUnwindSafe(job)) {
                    let msg = panic
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| panic.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".into());
                    eprintln!("[service] worker job panicked: {msg}");
                }
            }
            Err(_) => break, // channel closed: pool is shutting down
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx = None; // close the channel; workers drain the queue, then exit
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn runs_all_jobs_across_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new("t", 4, 2);
            assert_eq!(pool.workers(), 4);
            for _ in 0..64 {
                let counter = Arc::clone(&counter);
                pool.execute(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop joins the workers after the queue drains
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn a_panicking_job_does_not_kill_the_pool() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new("t", 1, 4);
            pool.execute(|| panic!("boom"));
            // give the lone worker time to survive the panic
            std::thread::sleep(Duration::from_millis(20));
            let counter = Arc::clone(&counter);
            pool.execute(move || {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = ThreadPool::new("t", 0, 0);
        assert_eq!(pool.workers(), 1);
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }
}
