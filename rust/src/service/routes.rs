//! Request routing and endpoint handlers of the planning service.
//!
//! | route            | what it answers                                     |
//! |------------------|-----------------------------------------------------|
//! | `POST /solve`    | chain + budget → optimal schedule + predicted cost  |
//! | `POST /sweep`    | chain + budget list → per-budget costs, one DP table|
//! | `POST /simulate` | chain + op sequence → simulator peak/cost verdict   |
//! | `POST /lower`    | chain + budget (or op sequence) → lowered plan:     |
//! |                  | slot table, arena size, plan-time peak              |
//! | `POST /prewarm`  | admin: pre-fill the table cache (and disk store)    |
//! | `GET  /chains`   | built-in profiles and native presets, by name       |
//! | `GET  /stats`    | request counters, latency percentiles, cache stats  |
//! | `GET  /metrics`  | Prometheus text exposition of the process registry  |
//! | `GET  /healthz`  | liveness probe                                      |
//!
//! Error contract: malformed JSON → `400`, semantically invalid input →
//! `422`, unknown route → `404`, wrong method on a known path → `405`,
//! broken server-side invariant → `500` — all with the structured
//! `{"error": {...}}` envelope and **without** dropping the connection.
//! Handler errors are kind-tagged [`api::Error`](Error)s; the status
//! comes from the single [`ErrorKind::http_status`](crate::api::ErrorKind)
//! table (previously this file tagged server-side failures by message
//! *prefix*, because the vendored anyhow cannot downcast).

use std::collections::BTreeMap;
use std::time::Instant;

use super::http::{Request, Response};
use super::wire;
use super::ServiceState;
use crate::api::{
    ChainSpec, Context, Error, MemBytes, PlanRequest, Result, PRESET_FLOPS_PER_US,
};
use crate::backend::native::presets;
use crate::chain::profiles;
use crate::simulator::simulate;
use crate::solver::{cache_stats, Mode, Schedule, StrategyKind};
use crate::telemetry::{self, Counter, Window};
use crate::util::json::{obj, Value};

/// Dispatch one request, recording per-route counters and latency.
pub fn handle(req: &Request, state: &ServiceState) -> Response {
    let t0 = Instant::now();
    let (route, resp) = dispatch(req, state);
    state.stats.record(route, resp.status, t0.elapsed().as_micros() as u64);
    resp
}

/// The single route table — `(method, path, label)`. Dispatch, the 405
/// known-path check, and the `/stats` counter keys all derive from it.
const ROUTES: &[(&str, &str, &str)] = &[
    ("POST", "/solve", "solve"),
    ("POST", "/sweep", "sweep"),
    ("POST", "/simulate", "simulate"),
    ("POST", "/lower", "lower"),
    ("POST", "/prewarm", "prewarm"),
    ("GET", "/chains", "chains"),
    ("GET", "/stats", "stats"),
    ("GET", "/metrics", "metrics"),
    ("GET", "/healthz", "healthz"),
];

fn dispatch(req: &Request, state: &ServiceState) -> (&'static str, Response) {
    let (m, p) = (req.method.as_str(), req.path.as_str());
    let Some(&(_, _, label)) = ROUTES.iter().find(|(rm, rp, _)| *rm == m && *rp == p) else {
        if let Some(&(want, _, _)) = ROUTES.iter().find(|(_, rp, _)| *rp == p) {
            return (
                "method_not_allowed",
                Response::error(405, format!("{p} expects {want}, got {m}")),
            );
        }
        return ("not_found", Response::error(404, format!("no route {m} {p}")));
    };
    let resp = match label {
        "solve" => with_json_body(req, |body| solve(body, state)),
        "sweep" => with_json_body(req, |body| sweep(body, state)),
        "simulate" => with_json_body(req, |body| simulate_ops(body)),
        "lower" => with_json_body(req, |body| lower(body, state)),
        "prewarm" => with_json_body(req, |body| prewarm(body, state)),
        "chains" => ok(chains()),
        "stats" => ok(stats(state)),
        "metrics" => Response::text(200, telemetry::registry().prometheus_text()),
        "healthz" => ok(obj([("ok", Value::Bool(true))])),
        other => Response::error(500, format!("route '{other}' has no handler")),
    };
    (label, resp)
}

fn ok(v: Value) -> Response {
    Response::json(200, v.to_json_string())
}

/// Render a kind-tagged facade error as the service's error envelope:
/// the HTTP status comes straight from [`ErrorKind::http_status`]
/// (one table — no message sniffing), and the kind's stable name rides
/// along as `"kind"` so clients can dispatch without parsing messages.
///
/// [`ErrorKind::http_status`]: crate::api::ErrorKind::http_status
fn error_response(err: &Error) -> Response {
    let status = err.kind().http_status();
    let payload = obj([(
        "error",
        obj([
            ("code", Value::from(status as u64)),
            ("kind", Value::from(err.kind().as_str())),
            ("message", Value::from(format!("{err:#}"))),
        ]),
    )]);
    Response::json(status, payload.to_json_string())
}

/// Parse the body as JSON (`400` on syntax errors), run the handler; a
/// handler error's status is its [`ErrorKind`](crate::api::ErrorKind)
/// through [`error_response`], with the full context chain as the
/// message.
fn with_json_body(req: &Request, handler: impl FnOnce(&Value) -> Result<Value>) -> Response {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) if !t.trim().is_empty() => t,
        Ok(_) => return Response::error(400, "empty body: expected a JSON object"),
        Err(_) => return Response::error(400, "body is not valid UTF-8"),
    };
    let body = match Value::parse(text) {
        Ok(v) => v,
        Err(e) => return Response::error(400, format!("invalid JSON: {e}")),
    };
    match handler(&body) {
        Ok(v) => ok(v),
        Err(e) => error_response(&e),
    }
}

// ---------------------------------------------------------------------------
// POST /solve
// ---------------------------------------------------------------------------

fn solve(body: &Value, state: &ServiceState) -> Result<Value> {
    let spec = ChainSpec::from_json(body.get("chain").context("missing 'chain'")?)?;
    let memory = wire::parse_bytes(body.get("memory").context("missing 'memory'")?, "memory")?;
    let slots = wire::parse_slots(body, state.slots)?;
    let mode = wire::parse_mode(body)?;

    // Exactly `cmd_solve`'s call pattern (both go through the facade): a
    // plan at the requested budget, answering that budget. Same chain +
    // budget + slots across connections share one cached DP table.
    let plan = PlanRequest::new(spec, memory).slots(slots).mode(mode).plan()?;
    let chain = plan.chain();
    let mut out = BTreeMap::new();
    out.insert("chain".to_string(), Value::from(chain.name.clone()));
    out.insert("chain_len".to_string(), Value::from(chain.len()));
    out.insert("budget".to_string(), Value::from(memory.get()));
    out.insert("slots".to_string(), Value::from(slots));
    if let Some((lo, hi)) = plan.feasible_range() {
        out.insert(
            "feasible_range".to_string(),
            obj([("min", Value::from(lo.get())), ("max", Value::from(hi.get()))]),
        );
    }
    match plan.schedule_at(memory) {
        None => {
            // an infeasible budget is a *finding*, not a request error:
            // the response stays 200 with `feasible: false`
            out.insert("feasible".to_string(), Value::Bool(false));
        }
        Some(sched) => {
            out.insert("feasible".to_string(), Value::Bool(true));
            // the simulator independently verifies what we hand out; a
            // failure is ErrorKind::Internal → 500 (a solver bug, not a
            // bad request)
            let rep = plan.verify(&sched)?;
            out.insert("schedule".to_string(), wire::schedule_to_json(&sched));
            out.insert("simulated".to_string(), wire::report_to_json(&rep));
            out.insert("ideal_time".to_string(), Value::from(chain.ideal_time()));
        }
    }
    Ok(Value::Obj(out))
}

// ---------------------------------------------------------------------------
// POST /sweep
// ---------------------------------------------------------------------------

fn sweep(body: &Value, state: &ServiceState) -> Result<Value> {
    let spec = ChainSpec::from_json(body.get("chain").context("missing 'chain'")?)?;
    let budgets = wire::parse_budgets(body)?;
    let slots = wire::parse_slots(body, state.slots)?;
    let mode = wire::parse_mode(body)?;
    let include_ops = matches!(body.get("include_ops"), Some(Value::Bool(true)));

    // one plan at the sweep's top budget = one shared DP table for
    // every point (the acceptance criterion this endpoint exists for).
    // Reconstruction is serial on purpose — `Plan::sweep`'s scoped
    // threads would oversubscribe the CPU when several pool workers run
    // sweeps at once, and each point is only O(L) anyway (≤ MAX_BUDGETS).
    let top =
        *budgets.iter().max().ok_or_else(|| Error::internal("budgets validated non-empty"))?;
    let plan = PlanRequest::new(spec, top).slots(slots).mode(mode).plan()?;
    let chain = plan.chain();
    let schedules: Vec<_> = budgets.iter().map(|&m| plan.schedule_at(m)).collect();

    let points: Vec<Value> = budgets
        .iter()
        .zip(&schedules)
        .map(|(&m, sched)| {
            let mut pt = BTreeMap::new();
            pt.insert("budget".to_string(), Value::from(m.get()));
            match sched {
                None => {
                    pt.insert("feasible".to_string(), Value::Bool(false));
                }
                Some(s) => {
                    pt.insert("feasible".to_string(), Value::Bool(true));
                    pt.insert("predicted_time".to_string(), Value::from(s.predicted_time));
                    pt.insert("op_count".to_string(), Value::from(s.ops.len()));
                    if include_ops {
                        pt.insert(
                            "ops".to_string(),
                            Value::Arr(
                                s.ops.iter().map(|op| Value::from(op.to_string())).collect(),
                            ),
                        );
                    }
                }
            }
            Value::Obj(pt)
        })
        .collect();

    let mut out = BTreeMap::new();
    out.insert("chain".to_string(), Value::from(chain.name.clone()));
    out.insert("chain_len".to_string(), Value::from(chain.len()));
    out.insert("slots".to_string(), Value::from(slots));
    out.insert("top_budget".to_string(), Value::from(top.get()));
    out.insert(
        "feasible_range".to_string(),
        match plan.feasible_range() {
            Some((lo, hi)) => {
                obj([("min", Value::from(lo.get())), ("max", Value::from(hi.get()))])
            }
            None => Value::Null,
        },
    );
    out.insert("points".to_string(), Value::Arr(points));
    Ok(Value::Obj(out))
}

// ---------------------------------------------------------------------------
// POST /simulate
// ---------------------------------------------------------------------------

fn simulate_ops(body: &Value) -> Result<Value> {
    let chain = wire::parse_chain(body.get("chain").context("missing 'chain'")?)?;
    let ops = wire::parse_ops(body)?;
    let budget: Option<MemBytes> = match body.get("memory") {
        None => None,
        Some(v) => Some(wire::parse_bytes(v, "memory")?),
    };
    let sched = Schedule::new(ops, StrategyKind::Optimal, 0.0);

    let mut out = BTreeMap::new();
    out.insert("chain".to_string(), Value::from(chain.name.clone()));
    match simulate(&chain, &sched) {
        Ok(rep) => {
            out.insert("valid".to_string(), Value::Bool(true));
            out.insert("simulated".to_string(), wire::report_to_json(&rep));
            if let Some(m) = budget {
                out.insert("budget".to_string(), Value::from(m.get()));
                out.insert(
                    "within_budget".to_string(),
                    Value::Bool(rep.peak_bytes <= m.get()),
                );
            }
        }
        Err(e) => {
            // an invalid op sequence is a *finding*, not a request error
            out.insert("valid".to_string(), Value::Bool(false));
            out.insert("error".to_string(), Value::from(e.to_string()));
        }
    }
    Ok(Value::Obj(out))
}

// ---------------------------------------------------------------------------
// POST /lower
// ---------------------------------------------------------------------------

/// Lower a schedule against a chain and return the slot IR: the slot
/// table (offsets, sizes, per-slot value lifetimes), the arena size, and
/// the plan-time peak (byte-identical to `/simulate` on the same ops).
/// The schedule comes from an explicit `"ops"` array when present,
/// otherwise from solving `"memory"` (+ optional `"slots"`/`"strategy"`)
/// exactly like `/solve`.
fn lower(body: &Value, state: &ServiceState) -> Result<Value> {
    let spec = ChainSpec::from_json(body.get("chain").context("missing 'chain'")?)?;
    // `"verify": true` additionally runs the static plan verifier
    // (analysis/verify.rs) over the lowered plan and attaches its verdict.
    let run_verifier = matches!(body.get("verify"), Some(Value::Bool(true)));
    let mut out = BTreeMap::new();

    if body.get("ops").is_some() {
        // explicit sequence: lowering failure is a *finding* (like
        // /simulate's invalid verdict), not a request error; an optional
        // "memory" gets the same within_budget verdict /simulate gives
        let ops = wire::parse_ops(body)?;
        let budget = match body.get("memory") {
            None => None,
            Some(v) => Some(wire::parse_bytes(v, "memory")?),
        };
        let chain = spec.resolve()?;
        out.insert("chain".to_string(), Value::from(chain.name.clone()));
        out.insert("chain_len".to_string(), Value::from(chain.len()));
        let sched = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        match crate::plan::lower(&chain, &sched) {
            Ok(plan) => {
                out.insert("valid".to_string(), Value::Bool(true));
                if let Some(m) = budget {
                    out.insert("budget".to_string(), Value::from(m.get()));
                    out.insert(
                        "within_budget".to_string(),
                        Value::Bool(plan.peak_bytes <= m.get()),
                    );
                }
                out.insert("plan".to_string(), wire::plan_to_json(&plan));
                if run_verifier {
                    let verdict = crate::analysis::verify_counted(&plan);
                    out.insert("verdict".to_string(), wire::verdict_to_json(&verdict));
                }
            }
            Err(e) => {
                out.insert("valid".to_string(), Value::Bool(false));
                out.insert("error".to_string(), Value::from(e.to_string()));
            }
        }
        return Ok(Value::Obj(out));
    }

    let memory = wire::parse_bytes(
        body.get("memory").context("missing 'memory' (or an explicit 'ops' array)")?,
        "memory",
    )?;
    let slots = wire::parse_slots(body, state.slots)?;
    let mode = wire::parse_mode(body)?;
    let plan = PlanRequest::new(spec, memory).slots(slots).mode(mode).plan()?;
    let chain = plan.chain();
    out.insert("chain".to_string(), Value::from(chain.name.clone()));
    out.insert("chain_len".to_string(), Value::from(chain.len()));
    out.insert("budget".to_string(), Value::from(memory.get()));
    match plan.schedule_at(memory) {
        None => {
            out.insert("feasible".to_string(), Value::Bool(false));
        }
        Some(sched) => {
            out.insert("feasible".to_string(), Value::Bool(true));
            // a solver schedule that fails to lower is a solver bug:
            // ErrorKind::Internal → 500, mirroring /solve's verify
            let lowered = plan.lower_schedule(&sched)?;
            out.insert("schedule".to_string(), wire::schedule_to_json(&sched));
            out.insert("plan".to_string(), wire::plan_to_json(&lowered));
            if run_verifier {
                let verdict = crate::analysis::verify_counted(&lowered);
                out.insert("verdict".to_string(), wire::verdict_to_json(&verdict));
            }
        }
    }
    Ok(Value::Obj(out))
}

// ---------------------------------------------------------------------------
// POST /prewarm
// ---------------------------------------------------------------------------

/// Chains a prewarm sweep may enumerate in one request; each one costs a
/// DP fill per strategy, so the cap keeps an admin typo from queueing
/// hours of work.
const MAX_PREWARM_CHAINS: usize = 64;

/// Admin endpoint: solve the DP for a catalog of chains *now*, at each
/// chain's store-all top budget, so later traffic — and, with a
/// `--table-dir`, later *processes* — hits the table cache instead of
/// paying the fill. `{}` prewarms every native preset under both
/// strategies; `"chains"` (array of chain specs), `"slots"`, and
/// `"strategy"` narrow the sweep.
fn prewarm(body: &Value, state: &ServiceState) -> Result<Value> {
    let slots = wire::parse_slots(body, state.slots)?;
    let modes: Vec<Mode> = if body.get("strategy").is_some() {
        vec![wire::parse_mode(body)?]
    } else {
        vec![Mode::Full, Mode::AdRevolve]
    };
    let specs: Vec<ChainSpec> = match body.get("chains") {
        None => presets::NAMES.iter().map(|&name| ChainSpec::preset(name)).collect(),
        Some(Value::Arr(items)) => {
            if items.len() > MAX_PREWARM_CHAINS {
                return Err(Error::invalid(format!(
                    "'chains' lists {} entries; the prewarm cap is {MAX_PREWARM_CHAINS}",
                    items.len()
                )));
            }
            items.iter().map(ChainSpec::from_json).collect::<Result<_>>()?
        }
        Some(other) => {
            return Err(Error::invalid(format!(
                "'chains' must be an array of chain specs, got {}",
                other.to_json_string()
            )))
        }
    };

    let mut entries = Vec::new();
    let mut warmed = 0u64;
    for spec in &specs {
        for &mode in &modes {
            let strategy = match mode {
                Mode::Full => "optimal",
                Mode::AdRevolve => "revolve",
            };
            let mut entry = BTreeMap::new();
            entry.insert("strategy".to_string(), Value::from(strategy));
            entry.insert("slots".to_string(), Value::from(slots));
            // top budget = the chain's store-all peak + resident input:
            // the largest budget any sweep can ask, so the one table
            // answers everything below it
            let outcome = spec.resolve().and_then(|chain| {
                let top = MemBytes::new(chain.store_all_memory() + chain.wa0);
                entry.insert("chain".to_string(), Value::from(chain.name.clone()));
                entry.insert("top_budget".to_string(), Value::from(top.get()));
                PlanRequest::new(spec.clone(), top).slots(slots).mode(mode).plan()
            });
            match outcome {
                Ok(_) => {
                    warmed += 1;
                    entry.insert("ok".to_string(), Value::Bool(true));
                }
                Err(e) => {
                    entry.insert("ok".to_string(), Value::Bool(false));
                    entry.insert("error".to_string(), Value::from(format!("{e:#}")));
                }
            }
            entries.push(Value::Obj(entry));
        }
    }
    Ok(obj([
        ("warmed", Value::from(warmed)),
        ("entries", Value::Arr(entries)),
        (
            "table_dir",
            match crate::solver::table_dir() {
                Some(dir) => Value::from(dir.display().to_string()),
                None => Value::Null,
            },
        ),
    ]))
}

// ---------------------------------------------------------------------------
// GET /chains
// ---------------------------------------------------------------------------

fn chains() -> Value {
    let families: Vec<Value> = profiles::FAMILIES
        .iter()
        .map(|f| {
            let depths: Vec<Value> = profiles::supported_depths(f)
                .iter()
                .map(|&d| Value::from(d as u64))
                .collect();
            obj([
                ("family", Value::from(*f)),
                ("depths", Value::Arr(depths)),
                (
                    "spec",
                    Value::from(r#"{"profile": {"family": …, "depth": …, "image": …, "batch": …}}"#),
                ),
            ])
        })
        .collect();

    let preset_list: Vec<Value> = presets::NAMES
        .iter()
        .filter_map(|&name| {
            let manifest = presets::preset(name).ok()?;
            let chain = manifest.to_chain_analytic(PRESET_FLOPS_PER_US);
            Some(obj([
                ("name", Value::from(name)),
                ("stages", Value::from(manifest.stages.len())),
                ("param_count", Value::from(manifest.param_count)),
                ("store_all_bytes", Value::from(chain.store_all_memory())),
                ("spec", Value::from(r#"{"preset": …}"#)),
            ]))
        })
        .collect();

    obj([
        ("profiles", Value::Arr(families)),
        ("presets", Value::Arr(preset_list)),
    ])
}

// ---------------------------------------------------------------------------
// GET /stats
// ---------------------------------------------------------------------------

fn stats(state: &ServiceState) -> Value {
    let cache = cache_stats();
    let planner_cache = obj([
        ("lookups", Value::from(cache.lookups)),
        ("hits", Value::from(cache.hits)),
        ("builds", Value::from(cache.builds)),
        ("evictions", Value::from(cache.evictions)),
        ("coalesced", Value::from(cache.coalesced)),
        ("entries", Value::from(cache.entries)),
        ("bytes", Value::from(cache.bytes)),
    ]);
    let mut out = state.stats.snapshot();
    if let Value::Obj(map) = &mut out {
        map.insert("planner_cache".to_string(), planner_cache);
        map.insert(
            "uptime_s".to_string(),
            Value::from(state.started.elapsed().as_secs_f64()),
        );
    }
    out
}

// ---------------------------------------------------------------------------
// Stats registry
// ---------------------------------------------------------------------------

/// How many of the most recent request latencies the percentile window
/// keeps (a ring buffer — bounded memory under sustained traffic).
const LATENCY_WINDOW: usize = 4096;

/// Every counter label `record` can be called with: the route labels of
/// [`ROUTES`] plus the two rejection labels dispatch can return.
const STAT_LABELS: [&str; 11] = [
    "solve",
    "sweep",
    "simulate",
    "lower",
    "prewarm",
    "chains",
    "stats",
    "metrics",
    "healthz",
    "method_not_allowed",
    "not_found",
];

/// Thread-safe request counters + latency reservoir for `GET /stats`,
/// built from the lock-free [`telemetry`] instruments (the hand-rolled
/// mutex-and-`Vec` percentile code this replaced lives on only in git).
///
/// Counters are **per-instance** — each server answers `/stats` for its
/// own traffic, which is what the integration tests assert — while
/// [`Stats::record`] also mirrors every observation into the
/// process-global [`telemetry::Registry`] so `GET /metrics` exposes
/// service totals alongside solver and executor families.
pub struct Stats {
    by_route: [Counter; STAT_LABELS.len()],
    status_2xx: Counter,
    status_4xx: Counter,
    status_5xx: Counter,
    total: Counter,
    latency_us: Window,
}

impl Default for Stats {
    fn default() -> Stats {
        Stats {
            by_route: std::array::from_fn(|_| Counter::new()),
            status_2xx: Counter::new(),
            status_4xx: Counter::new(),
            status_5xx: Counter::new(),
            total: Counter::new(),
            latency_us: Window::new(LATENCY_WINDOW),
        }
    }
}

impl Stats {
    pub fn record(&self, route: &'static str, status: u16, elapsed_us: u64) {
        if let Some(i) = STAT_LABELS.iter().position(|&l| l == route) {
            self.by_route[i].inc();
        }
        let reg = telemetry::registry();
        reg.service_requests.inc();
        reg.service_latency_us.observe(elapsed_us);
        match status {
            200..=299 => {
                self.status_2xx.inc();
                reg.service_responses_2xx.inc();
            }
            400..=499 => {
                self.status_4xx.inc();
                reg.service_responses_4xx.inc();
            }
            _ => {
                self.status_5xx.inc();
                reg.service_responses_5xx.inc();
            }
        }
        self.total.inc();
        self.latency_us.record(elapsed_us);
    }

    /// Requests handled so far (all routes).
    pub fn total(&self) -> u64 {
        self.total.get()
    }

    pub fn snapshot(&self) -> Value {
        // same JSON shape as ever: routes appear only once hit, and the
        // percentiles are Null until the first sample lands
        let requests: BTreeMap<String, Value> = STAT_LABELS
            .iter()
            .zip(&self.by_route)
            .filter(|(_, c)| c.get() > 0)
            .map(|(l, c)| (l.to_string(), Value::from(c.get())))
            .collect();
        let pcts = self.latency_us.percentiles(&[0.50, 0.90, 0.99]);
        let pct = |i: usize| -> Value {
            if self.latency_us.is_empty() {
                Value::Null
            } else {
                Value::from(pcts[i])
            }
        };
        obj([
            ("requests", Value::Obj(requests)),
            ("total", Value::from(self.total.get())),
            (
                "responses",
                obj([
                    ("2xx", Value::from(self.status_2xx.get())),
                    ("4xx", Value::from(self.status_4xx.get())),
                    ("5xx", Value::from(self.status_5xx.get())),
                ]),
            ),
            (
                "latency_us",
                obj([
                    ("window", Value::from(self.latency_us.len())),
                    ("p50", pct(0)),
                    ("p90", pct(1)),
                    ("p99", pct(2)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_responses_key_status_off_the_kind_table() {
        // one table, no message sniffing: an "internal error"-prefixed
        // *message* no longer matters, only the kind does
        let e = Error::invalid("internal error: just a weird client string");
        let resp = error_response(&e);
        assert_eq!(resp.status, 422);
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(v.get("error").unwrap().get("code").unwrap().as_u64(), Some(422));
        assert_eq!(
            v.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("invalid_spec")
        );

        let e = Error::internal("solver produced an invalid schedule").context("handling /solve");
        let resp = error_response(&e);
        assert_eq!(resp.status, 500);
        let v = Value::parse(&resp.body).unwrap();
        assert_eq!(
            v.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("internal")
        );
        let msg = v.get("error").unwrap().get("message").unwrap().as_str().unwrap();
        assert!(msg.contains("handling /solve") && msg.contains("invalid schedule"));
    }

    #[test]
    fn stats_percentiles_and_counters() {
        let stats = Stats::default();
        for i in 0..100u64 {
            stats.record("solve", 200, i + 1); // 1..=100 µs
        }
        stats.record("not_found", 404, 5);
        let v = stats.snapshot();
        assert_eq!(v.get("total").unwrap().as_u64(), Some(101));
        assert_eq!(
            v.get("requests").unwrap().get("solve").unwrap().as_u64(),
            Some(100)
        );
        assert_eq!(
            v.get("responses").unwrap().get("4xx").unwrap().as_u64(),
            Some(1)
        );
        let p50 = v.get("latency_us").unwrap().get("p50").unwrap().as_u64().unwrap();
        assert!((40..=60).contains(&p50), "p50 = {p50}");
        let p99 = v.get("latency_us").unwrap().get("p99").unwrap().as_u64().unwrap();
        assert!(p99 >= 95, "p99 = {p99}");
    }

    #[test]
    fn latency_window_is_bounded() {
        let stats = Stats::default();
        for i in 0..(LATENCY_WINDOW as u64 + 500) {
            stats.record("solve", 200, i);
        }
        let v = stats.snapshot();
        assert_eq!(
            v.get("latency_us").unwrap().get("window").unwrap().as_u64(),
            Some(LATENCY_WINDOW as u64)
        );
        assert_eq!(v.get("total").unwrap().as_u64(), Some(LATENCY_WINDOW as u64 + 500));
        assert_eq!(stats.total(), LATENCY_WINDOW as u64 + 500);
    }

    #[test]
    fn metrics_route_serves_the_prometheus_exposition() {
        // dispatch-level smoke: the route table knows /metrics and the
        // payload is the registry's text format (full parser-level
        // validation lives in tests/telemetry_properties.rs)
        let resp = Response::text(200, telemetry::registry().prometheus_text());
        assert_eq!(resp.status, 200);
        assert!(resp.content_type.starts_with("text/plain"));
        assert!(resp.body.contains("# TYPE chainckpt_service_requests_total counter"));
        assert!(STAT_LABELS.len() == ROUTES.len() + 2, "labels cover routes + rejections");
        assert!(ROUTES.iter().any(|&(m, p, l)| (m, p, l) == ("GET", "/metrics", "metrics")));
    }
}
