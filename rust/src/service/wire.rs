//! Wire types of the planning service: JSON ⇄ domain conversions, all
//! validation up front so route handlers never panic on client input.
//!
//! The `"chain"` field of `/solve`, `/sweep`, `/simulate` is the facade's
//! chain-spec wire form — see [`ChainSpec::from_json`] for the grammar
//! (`profile` / `preset` / `graph` / inline `stages` / on-disk
//! `manifest`). Chain
//! construction and validation live entirely in [`crate::api`]; this
//! module only covers the service-specific fields (budgets, slots,
//! strategy, op tokens) and response serialization. Every parser returns
//! a kind-tagged [`api::Error`](crate::api::Error), which the router maps
//! to an HTTP status through [`crate::api::ErrorKind::http_status`].

use std::collections::BTreeMap;

use crate::api::{ChainSpec, Context, Error, MemBytes, Mode, Result};
use crate::chain::Chain;
use crate::plan::ExecPlan;
use crate::simulator::SimReport;
use crate::solver::{Op, Schedule};
use crate::util::json::{obj, Value};

/// Slot-axis cap, bounding per-request DP time (paper uses S = 500).
pub const MAX_SLOTS: usize = 2000;
/// Budget-list cap for `/sweep`.
pub const MAX_BUDGETS: usize = 512;

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Parse the `"chain"` field of a request body into a resolved [`Chain`]
/// (spec grammar and validation: [`ChainSpec::from_json`]).
pub fn parse_chain(spec: &Value) -> Result<Chain> {
    ChainSpec::from_json(spec)?.resolve()
}

/// A byte size: a JSON number, or a string with the facade's
/// `K`/`M`/`G`(`B`/`iB`) suffixes (`"512M"`, `"1.5GiB"`). Must be ≥ 1
/// (the discretization needs a nonzero budget).
pub fn parse_bytes(v: &Value, what: &str) -> Result<MemBytes> {
    let n = match v {
        // `< 2^64` (== u64::MAX as f64): a huge JSON number must be
        // rejected like the equivalent suffix string, not saturated to
        // u64::MAX by the cast
        Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < u64::MAX as f64 => {
            MemBytes::new(*n as u64)
        }
        Value::Str(s) => MemBytes::parse(s).with_context(|| format!("'{what}'"))?,
        other => {
            return Err(Error::invalid(format!(
                "'{what}' must be a non-negative integer byte count below 2^64 \
                 or a size string, got {other:?}"
            )))
        }
    };
    if n.get() == 0 {
        return Err(Error::invalid(format!("'{what}' must be ≥ 1 byte")));
    }
    Ok(n)
}

/// The `"strategy"` field: `"optimal"` (default) or `"revolve"`.
pub fn parse_mode(body: &Value) -> Result<Mode> {
    match body.get("strategy").and_then(|v| v.as_str()).unwrap_or("optimal") {
        "optimal" => Ok(Mode::Full),
        "revolve" => Ok(Mode::AdRevolve),
        s => Err(Error::invalid(format!("unknown strategy '{s}' (optimal|revolve)"))),
    }
}

/// The `"slots"` field, validated against [`MAX_SLOTS`].
pub fn parse_slots(body: &Value, default: usize) -> Result<usize> {
    let slots = match body.get("slots") {
        None => default,
        Some(v) => v.as_usize().context("'slots' must be a positive integer")?,
    };
    if !(10..=MAX_SLOTS).contains(&slots) {
        return Err(Error::invalid(format!("'slots' = {slots} out of range (10..={MAX_SLOTS})")));
    }
    Ok(slots)
}

/// The `"budgets"` field of `/sweep`: an explicit array of byte sizes.
pub fn parse_budgets(body: &Value) -> Result<Vec<MemBytes>> {
    let arr = body
        .get("budgets")
        .and_then(|v| v.as_arr())
        .context("'budgets' must be an array of byte sizes")?;
    if arr.is_empty() {
        return Err(Error::invalid("'budgets' must not be empty"));
    }
    if arr.len() > MAX_BUDGETS {
        return Err(Error::invalid(format!(
            "{} budgets exceed the {MAX_BUDGETS}-budget cap",
            arr.len()
        )));
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| parse_bytes(v, &format!("budgets[{i}]")))
        .collect()
}

// ---------------------------------------------------------------------------
// Op tokens (the `/simulate` body and `/solve` responses)
// ---------------------------------------------------------------------------

/// Parse one op in the paper's compact notation, as emitted by
/// [`Op`]'s `Display` (`F∅^3`, `Fck^1`, `Fall^5`, `B^5`, `drop a^2`).
/// `F0^3` is accepted as an ASCII alias for `F∅^3`.
pub fn parse_op(token: &str) -> Result<Op> {
    let (kind, stage) = token
        .rsplit_once('^')
        .with_context(|| format!("op '{token}': expected '<kind>^<stage>'"))?;
    let l: u32 = stage
        .parse()
        .ok()
        .filter(|l| *l >= 1)
        .with_context(|| format!("op '{token}': bad stage index '{stage}'"))?;
    match kind {
        "F∅" | "F0" => Ok(Op::FwdNoSave(l)),
        "Fck" => Ok(Op::FwdCk(l)),
        "Fall" => Ok(Op::FwdAll(l)),
        "B" => Ok(Op::Bwd(l)),
        "drop a" => Ok(Op::DropA(l)),
        k => Err(Error::invalid(format!(
            "op '{token}': unknown kind '{k}' (F∅/F0, Fck, Fall, B, drop a)"
        ))),
    }
}

/// The `"ops"` array of `/simulate`.
pub fn parse_ops(body: &Value) -> Result<Vec<Op>> {
    let arr = body
        .get("ops")
        .and_then(|v| v.as_arr())
        .context("'ops' must be an array of op tokens like \"Fck^1\"")?;
    if arr.is_empty() {
        return Err(Error::invalid("'ops' must not be empty"));
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| {
            let tok = v.as_str().with_context(|| format!("ops[{i}] must be a string"))?;
            parse_op(tok).with_context(|| format!("ops[{i}]"))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Serialize an op sequence as compact-notation tokens — the exact
/// inverse of [`parse_op`], so `/solve`, `/simulate` and `/lower` speak
/// one alphabet (`parse ∘ print = id`, tested below).
pub fn ops_to_json(ops: &[Op]) -> Value {
    Value::Arr(ops.iter().map(|op| Value::from(op.to_string())).collect())
}

/// Serialize a schedule: strategy label, solver-predicted time, and the
/// op sequence as compact-notation tokens (parseable by [`parse_op`],
/// byte-identical to what `chainckpt solve --show-ops` prints per op).
pub fn schedule_to_json(sched: &Schedule) -> Value {
    let mut obj = BTreeMap::new();
    obj.insert("strategy".to_string(), Value::from(sched.strategy.to_string()));
    obj.insert("predicted_time".to_string(), Value::from(sched.predicted_time));
    obj.insert("op_count".to_string(), Value::from(sched.ops.len()));
    obj.insert("ops".to_string(), ops_to_json(&sched.ops));
    Value::Obj(obj)
}

/// Serialize a lowered [`ExecPlan`] for `POST /lower`: the headline
/// numbers (plan-time peak, arena size) plus the full slot table — every
/// slot's byte offset/size and the values (with lifetimes) placed in it.
pub fn plan_to_json(plan: &ExecPlan) -> Value {
    let slots: Vec<Value> = plan
        .slots
        .iter()
        .enumerate()
        .map(|(s, slot)| {
            let values: Vec<Value> = plan
                .slot_values(s)
                .map(|(_, v)| {
                    obj([
                        ("item", Value::from(v.item.label())),
                        ("bytes", Value::from(v.bytes)),
                        ("birth", Value::from(v.birth)),
                        (
                            "death",
                            v.death.map(|d| Value::from(d)).unwrap_or(Value::Null),
                        ),
                    ])
                })
                .collect();
            obj([
                ("slot", Value::from(s)),
                ("offset", Value::from(slot.offset)),
                ("bytes", Value::from(slot.bytes)),
                ("values", Value::Arr(values)),
            ])
        })
        .collect();
    obj([
        ("op_count", Value::from(plan.op_count())),
        ("value_count", Value::from(plan.values.len())),
        ("slot_count", Value::from(plan.slots.len())),
        ("peak_bytes", Value::from(plan.peak_bytes)),
        ("arena_bytes", Value::from(plan.arena_bytes)),
        ("slots", Value::Arr(slots)),
    ])
}

/// Serialize a static-verifier verdict (`POST /lower` with
/// `"verify": true`).
pub fn verdict_to_json(verdict: &crate::analysis::Verdict) -> Value {
    let violations: Vec<Value> = verdict
        .violations
        .iter()
        .map(|v| {
            obj([
                ("kind", Value::from(v.kind.label())),
                ("step", v.step.map(Value::from).unwrap_or(Value::Null)),
                ("value", v.value.map(Value::from).unwrap_or(Value::Null)),
                ("detail", Value::from(v.detail.as_str())),
            ])
        })
        .collect();
    obj([
        ("clean", Value::Bool(verdict.is_clean())),
        ("recomputed_peak", Value::from(verdict.recomputed_peak)),
        ("steps_checked", Value::from(verdict.steps_checked)),
        ("values_checked", Value::from(verdict.values_checked)),
        ("violations", Value::Arr(violations)),
    ])
}

/// Serialize a simulator verdict.
pub fn report_to_json(rep: &SimReport) -> Value {
    let mut obj = BTreeMap::new();
    obj.insert("makespan".to_string(), Value::from(rep.makespan));
    obj.insert("peak_bytes".to_string(), Value::from(rep.peak_bytes));
    obj.insert("ops".to_string(), Value::from(rep.ops));
    obj.insert("recomputed_forwards".to_string(), Value::from(rep.recomputed_forwards));
    Value::Obj(obj)
}

/// Serialize a measured-vs-predicted [`DriftReport`] — per-op-kind rows
/// plus the peak and makespan joins (the same numbers
/// [`DriftReport::summary`] prints for `chainckpt compare`).
///
/// [`DriftReport`]: crate::telemetry::DriftReport
/// [`DriftReport::summary`]: crate::telemetry::DriftReport::summary
pub fn drift_to_json(drift: &crate::telemetry::DriftReport) -> Value {
    let kinds: Vec<Value> = drift
        .kinds
        .iter()
        .map(|k| {
            obj([
                ("kind", Value::from(k.kind.label())),
                ("ops", Value::from(k.ops)),
                ("predicted_us", Value::from(k.predicted_us)),
                ("measured_us", Value::from(k.measured_us)),
                ("ratio", Value::from(k.ratio)),
            ])
        })
        .collect();
    obj([
        ("kinds", Value::Arr(kinds)),
        ("predicted_peak_bytes", Value::from(drift.predicted_peak_bytes)),
        ("measured_peak_bytes", Value::from(drift.measured_peak_bytes)),
        ("peak_exact", Value::Bool(drift.peak_exact())),
        ("predicted_time_us", Value::from(drift.predicted_time_us)),
        ("measured_time_us", Value::from(drift.measured_time_us)),
        ("time_ratio", Value::from(drift.time_ratio)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ErrorKind;
    use crate::solver::StrategyKind;

    #[test]
    fn chain_field_delegates_to_the_facade() {
        // full spec-grammar coverage lives in api::spec's tests; this
        // checks the wire plumbs through and keeps the kind tags
        let spec = Value::parse(r#"{"preset": "quickstart"}"#).unwrap();
        assert_eq!(parse_chain(&spec).unwrap().len(), 5);
        let spec = Value::parse(r#"{"graph": "residual"}"#).unwrap();
        assert_eq!(parse_chain(&spec).unwrap().len(), 7);
        let spec = Value::parse(r#"{"profile": {"family": "alexnet"}}"#).unwrap();
        assert_eq!(parse_chain(&spec).unwrap_err().kind(), ErrorKind::UnknownChain);
        let spec = Value::parse(r#"{}"#).unwrap();
        assert_eq!(parse_chain(&spec).unwrap_err().kind(), ErrorKind::InvalidSpec);
    }

    #[test]
    fn bytes_accept_numbers_and_suffix_strings() {
        assert_eq!(
            parse_bytes(&Value::parse("1024").unwrap(), "m").unwrap(),
            MemBytes::new(1024)
        );
        assert_eq!(
            parse_bytes(&Value::parse("\"512M\"").unwrap(), "m").unwrap(),
            MemBytes::new(512 << 20)
        );
        assert_eq!(
            parse_bytes(&Value::parse("\"512MiB\"").unwrap(), "m").unwrap(),
            MemBytes::new(512 << 20)
        );
        // 1e300 and 2^64 would saturate the f64→u64 cast to u64::MAX —
        // they must be rejected like their suffix-string equivalents
        for bad in ["0", "1.5", "\"x\"", "1e300", "18446744073709551616"] {
            let err = parse_bytes(&Value::parse(bad).unwrap(), "m").unwrap_err();
            assert_eq!(err.kind(), ErrorKind::InvalidSpec, "{bad}");
        }
    }

    #[test]
    fn op_tokens_round_trip_display_for_all_five_variants() {
        // parse ∘ print = id over the whole alphabet: every Op variant,
        // a spread of stage indices (1-digit, multi-digit, u32::MAX) —
        // so /solve, /simulate and /lower provably speak one language
        for l in (1u32..=64).chain([999, 4096, u32::MAX]) {
            for op in [Op::FwdNoSave(l), Op::FwdCk(l), Op::FwdAll(l), Op::Bwd(l), Op::DropA(l)]
            {
                assert_eq!(parse_op(&op.to_string()).unwrap(), op, "{op}");
            }
        }
        assert_eq!(parse_op("F0^7").unwrap(), Op::FwdNoSave(7)); // ASCII alias
        assert!(parse_op("Fck^0").is_err());
        assert!(parse_op("Fck").is_err());
        assert!(parse_op("X^1").is_err());
        assert!(parse_op("drop a^0").is_err());
    }

    #[test]
    fn ops_to_json_is_the_exact_inverse_of_parse_ops() {
        let ops = vec![
            Op::FwdCk(1),
            Op::FwdNoSave(2),
            Op::FwdAll(12),
            Op::Bwd(12),
            Op::DropA(1),
        ];
        let body = obj([("ops", ops_to_json(&ops))]);
        assert_eq!(parse_ops(&body).unwrap(), ops);
    }

    #[test]
    fn plan_json_carries_the_slot_table() {
        use crate::chain::{Chain, Stage};
        let chain = Chain::new(
            "t",
            vec![Stage::new("s1", 1.0, 1.0, 10, 25), Stage::new("loss", 1.0, 1.0, 4, 4)],
            8,
        );
        let sched = crate::solver::store_all_schedule(&chain);
        let plan = crate::plan::lower(&chain, &sched).unwrap();
        let v = plan_to_json(&plan);
        assert_eq!(v.get("peak_bytes").unwrap().as_u64(), Some(plan.peak_bytes));
        assert_eq!(v.get("arena_bytes").unwrap().as_u64(), Some(plan.arena_bytes));
        let slots = v.get("slots").unwrap().as_arr().unwrap();
        assert_eq!(slots.len(), plan.slots.len());
        // every slot row lists at least one value with a lifetime
        for s in slots {
            assert!(!s.get("values").unwrap().as_arr().unwrap().is_empty());
        }
    }

    #[test]
    fn schedule_json_tokens_reparse() {
        let sched = Schedule::new(
            vec![Op::FwdCk(1), Op::FwdAll(2), Op::Bwd(2), Op::Bwd(1)],
            StrategyKind::Optimal,
            3.25,
        );
        let v = schedule_to_json(&sched);
        assert_eq!(v.get("predicted_time").unwrap().as_f64(), Some(3.25));
        assert_eq!(v.get("op_count").unwrap().as_usize(), Some(4));
        let tokens = v.get("ops").unwrap().as_arr().unwrap();
        let back: Vec<Op> =
            tokens.iter().map(|t| parse_op(t.as_str().unwrap()).unwrap()).collect();
        assert_eq!(back, sched.ops);
    }
}
