//! Wire types of the planning service: JSON ⇄ domain conversions, all
//! validation up front so route handlers never panic on client input.
//!
//! A **chain spec** (the `"chain"` field of `/solve`, `/sweep`,
//! `/simulate`) takes one of three forms:
//!
//! * `{"profile": {"family": "resnet", "depth": 101, "image": 1000,
//!   "batch": 8}}` — an analytic profile from [`crate::chain::profiles`].
//!   Identical parameters fingerprint to the same DP table, so repeated
//!   traffic for a topology is served from the planner cache.
//! * `{"preset": "default"}` — a native-backend transformer preset
//!   ([`crate::backend::native::presets`]) with analytic roofline
//!   timings, so a client can plan the exact chains `train` executes
//!   without shipping a profile.
//! * `{"stages": [{"uf": …, "ub": …, "wa": …, "wabar": …}, …],
//!   "input_bytes": …}` — an inline measured profile (e.g. from
//!   `estimate` output on the client's own hardware).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::backend::native::presets;
use crate::chain::{profiles, Chain, Stage};
use crate::simulator::SimReport;
use crate::solver::{Mode, Op, Schedule};
use crate::util::json::Value;
use crate::util::parse_size;

/// Stage cap for inline chains: bounds DP time (O(L²·S) per table) so one
/// request cannot pin a worker for minutes.
pub const MAX_STAGES: usize = 2048;
/// Slot-axis cap, for the same reason (paper uses S = 500).
pub const MAX_SLOTS: usize = 2000;
/// Budget-list cap for `/sweep`.
pub const MAX_BUDGETS: usize = 512;
/// FLOP/µs assumed when deriving analytic timings for `"preset"` chains
/// (a mid-range single-core rate for the native engine; only the
/// *relative* stage durations shape the schedule).
pub const PRESET_FLOPS_PER_US: f64 = 5.0e3;

// ---------------------------------------------------------------------------
// Requests
// ---------------------------------------------------------------------------

/// Parse the `"chain"` field of a request body.
pub fn parse_chain(spec: &Value) -> Result<Chain> {
    if let Some(profile) = spec.get("profile") {
        return chain_from_profile(profile);
    }
    if let Some(preset) = spec.get("preset") {
        let name = preset.as_str().context("'preset' must be a string")?;
        let manifest = presets::preset(name)?;
        return Ok(manifest.to_chain_analytic(PRESET_FLOPS_PER_US));
    }
    if spec.get("stages").is_some() {
        return chain_from_stages(spec);
    }
    bail!("chain spec needs one of 'profile', 'preset', or 'stages'")
}

fn chain_from_profile(p: &Value) -> Result<Chain> {
    let family = p
        .get("family")
        .and_then(|v| v.as_str())
        .context("profile needs a string 'family' (resnet/densenet/inception/vgg)")?
        .to_string();
    let depth = match p.get("depth") {
        None => *profiles::supported_depths(&family).first().unwrap_or(&0),
        Some(v) => {
            let d = v.as_u64().context("'depth' must be a non-negative integer")?;
            // no silent u32 wrap: 2^32+18 must not alias depth 18
            u32::try_from(d).ok().with_context(|| format!("'depth' = {d} out of range"))?
        }
    };
    let image = p.get("image").map_or(Ok(224), |v| {
        v.as_u64().context("'image' must be a non-negative integer")
    })?;
    let batch = p.get("batch").map_or(Ok(4), |v| {
        v.as_u64().context("'batch' must be a non-negative integer")
    })?;
    if !(32..=4096).contains(&image) {
        bail!("'image' = {image} out of range (32..=4096)");
    }
    if !(1..=1024).contains(&batch) {
        bail!("'batch' = {batch} out of range (1..=1024)");
    }
    profiles::try_by_name(&family, depth, image, batch).with_context(|| {
        format!(
            "unknown profile family '{family}' or unsupported depth {depth} \
             (families: {}; e.g. resnet depths {:?})",
            profiles::FAMILIES.join("/"),
            profiles::supported_depths("resnet"),
        )
    })
}

fn chain_from_stages(spec: &Value) -> Result<Chain> {
    let stages_json = spec
        .get("stages")
        .and_then(|v| v.as_arr())
        .context("'stages' must be an array")?;
    if stages_json.is_empty() {
        bail!("'stages' must not be empty");
    }
    if stages_json.len() > MAX_STAGES {
        bail!("{} stages exceed the {MAX_STAGES}-stage cap", stages_json.len());
    }
    let wa0 = spec
        .get("input_bytes")
        .context("inline chains need 'input_bytes' (bytes of the chain input a^0)")?
        .as_u64()
        .context("'input_bytes' must be a non-negative integer")?;
    let name = spec
        .get("name")
        .and_then(|v| v.as_str())
        .unwrap_or("inline")
        .to_string();

    let mut stages = Vec::with_capacity(stages_json.len());
    for (i, s) in stages_json.iter().enumerate() {
        let num = |key: &str| -> Result<f64> {
            let v = s
                .get(key)
                .with_context(|| format!("stage {i}: missing '{key}'"))?
                .as_f64()
                .with_context(|| format!("stage {i}: '{key}' must be a number"))?;
            if !v.is_finite() || v < 0.0 {
                bail!("stage {i}: '{key}' = {v} must be finite and ≥ 0");
            }
            Ok(v)
        };
        let bytes = |key: &str| -> Result<u64> {
            s.get(key)
                .with_context(|| format!("stage {i}: missing '{key}'"))?
                .as_u64()
                .with_context(|| format!("stage {i}: '{key}' must be a non-negative integer"))
        };
        let opt_bytes = |key: &str, default: u64| -> Result<u64> {
            match s.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .with_context(|| format!("stage {i}: '{key}' must be a non-negative integer")),
            }
        };
        let (uf, ub) = (num("uf")?, num("ub")?);
        let (wa, wabar) = (bytes("wa")?, bytes("wabar")?);
        if wabar < wa {
            bail!("stage {i}: wabar = {wabar} < wa = {wa} (ā must include a)");
        }
        let stage_name = s
            .get("name")
            .and_then(|v| v.as_str())
            .map(String::from)
            .unwrap_or_else(|| format!("s{}", i + 1));
        let stage = Stage::new(stage_name, uf, ub, wa, wabar)
            .with_overheads(opt_bytes("of", 0)?, opt_bytes("ob", 0)?)
            .with_delta_size(opt_bytes("wd", wa)?);
        stages.push(stage);
    }
    Ok(Chain::new(name, stages, wa0))
}

/// A byte size: a JSON number, or a string with the CLI's `K`/`M`/`G`
/// suffixes (`"512M"`). Must be ≥ 1 (the discretization needs a nonzero
/// budget).
pub fn parse_bytes(v: &Value, what: &str) -> Result<u64> {
    let n = match v {
        Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => *n as u64,
        Value::Str(s) => {
            parse_size(s).with_context(|| format!("'{what}': bad size string '{s}'"))?
        }
        other => bail!("'{what}' must be a byte count or a size string, got {other:?}"),
    };
    if n == 0 {
        bail!("'{what}' must be ≥ 1 byte");
    }
    Ok(n)
}

/// The `"strategy"` field: `"optimal"` (default) or `"revolve"`.
pub fn parse_mode(body: &Value) -> Result<Mode> {
    match body.get("strategy").and_then(|v| v.as_str()).unwrap_or("optimal") {
        "optimal" => Ok(Mode::Full),
        "revolve" => Ok(Mode::AdRevolve),
        s => bail!("unknown strategy '{s}' (optimal|revolve)"),
    }
}

/// The `"slots"` field, validated against [`MAX_SLOTS`].
pub fn parse_slots(body: &Value, default: usize) -> Result<usize> {
    let slots = match body.get("slots") {
        None => default,
        Some(v) => v.as_usize().context("'slots' must be a positive integer")?,
    };
    if !(10..=MAX_SLOTS).contains(&slots) {
        bail!("'slots' = {slots} out of range (10..={MAX_SLOTS})");
    }
    Ok(slots)
}

/// The `"budgets"` field of `/sweep`: an explicit array of byte sizes.
pub fn parse_budgets(body: &Value) -> Result<Vec<u64>> {
    let arr = body
        .get("budgets")
        .and_then(|v| v.as_arr())
        .context("'budgets' must be an array of byte sizes")?;
    if arr.is_empty() {
        bail!("'budgets' must not be empty");
    }
    if arr.len() > MAX_BUDGETS {
        bail!("{} budgets exceed the {MAX_BUDGETS}-budget cap", arr.len());
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| parse_bytes(v, &format!("budgets[{i}]")))
        .collect()
}

// ---------------------------------------------------------------------------
// Op tokens (the `/simulate` body and `/solve` responses)
// ---------------------------------------------------------------------------

/// Parse one op in the paper's compact notation, as emitted by
/// [`Op`]'s `Display` (`F∅^3`, `Fck^1`, `Fall^5`, `B^5`, `drop a^2`).
/// `F0^3` is accepted as an ASCII alias for `F∅^3`.
pub fn parse_op(token: &str) -> Result<Op> {
    let (kind, stage) = token
        .rsplit_once('^')
        .with_context(|| format!("op '{token}': expected '<kind>^<stage>'"))?;
    let l: u32 = stage
        .parse()
        .ok()
        .filter(|l| *l >= 1)
        .with_context(|| format!("op '{token}': bad stage index '{stage}'"))?;
    match kind {
        "F∅" | "F0" => Ok(Op::FwdNoSave(l)),
        "Fck" => Ok(Op::FwdCk(l)),
        "Fall" => Ok(Op::FwdAll(l)),
        "B" => Ok(Op::Bwd(l)),
        "drop a" => Ok(Op::DropA(l)),
        k => bail!("op '{token}': unknown kind '{k}' (F∅/F0, Fck, Fall, B, drop a)"),
    }
}

/// The `"ops"` array of `/simulate`.
pub fn parse_ops(body: &Value) -> Result<Vec<Op>> {
    let arr = body
        .get("ops")
        .and_then(|v| v.as_arr())
        .context("'ops' must be an array of op tokens like \"Fck^1\"")?;
    if arr.is_empty() {
        bail!("'ops' must not be empty");
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| {
            let tok = v.as_str().with_context(|| format!("ops[{i}] must be a string"))?;
            parse_op(tok).with_context(|| format!("ops[{i}]"))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Serialize a schedule: strategy label, solver-predicted time, and the
/// op sequence as compact-notation tokens (parseable by [`parse_op`],
/// byte-identical to what `chainckpt solve --show-ops` prints per op).
pub fn schedule_to_json(sched: &Schedule) -> Value {
    let ops: Vec<Value> = sched.ops.iter().map(|op| Value::from(op.to_string())).collect();
    let mut obj = BTreeMap::new();
    obj.insert("strategy".to_string(), Value::from(sched.strategy.to_string()));
    obj.insert("predicted_time".to_string(), Value::from(sched.predicted_time));
    obj.insert("op_count".to_string(), Value::from(sched.ops.len()));
    obj.insert("ops".to_string(), Value::Arr(ops));
    Value::Obj(obj)
}

/// Serialize a simulator verdict.
pub fn report_to_json(rep: &SimReport) -> Value {
    let mut obj = BTreeMap::new();
    obj.insert("makespan".to_string(), Value::from(rep.makespan));
    obj.insert("peak_bytes".to_string(), Value::from(rep.peak_bytes));
    obj.insert("ops".to_string(), Value::from(rep.ops));
    obj.insert("recomputed_forwards".to_string(), Value::from(rep.recomputed_forwards));
    Value::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::StrategyKind;

    #[test]
    fn profile_spec_round_trips_to_a_chain() {
        let spec = Value::parse(
            r#"{"profile": {"family": "resnet", "depth": 18, "image": 224, "batch": 8}}"#,
        )
        .unwrap();
        let chain = parse_chain(&spec).unwrap();
        assert_eq!(chain.name, "resnet18-i224-b8");
        assert_eq!(chain.len(), profiles::resnet(18, 224, 8).len());
    }

    #[test]
    fn profile_defaults_fill_in() {
        let spec = Value::parse(r#"{"profile": {"family": "vgg"}}"#).unwrap();
        assert!(parse_chain(&spec).is_ok());
    }

    #[test]
    fn bad_profiles_are_errors_not_panics() {
        for body in [
            r#"{"profile": {"family": "alexnet"}}"#,
            r#"{"profile": {"family": "resnet", "depth": 51}}"#,
            // 2^32 + 18: a u32 wrap would alias depth 18
            r#"{"profile": {"family": "resnet", "depth": 4294967314}}"#,
            r#"{"profile": {"family": "resnet", "depth": 50, "image": 4}}"#,
            r#"{"profile": {"family": "resnet", "depth": 50, "batch": 0}}"#,
            r#"{"preset": "nope"}"#,
            r#"{}"#,
        ] {
            let spec = Value::parse(body).unwrap();
            assert!(parse_chain(&spec).is_err(), "{body}");
        }
    }

    #[test]
    fn preset_spec_builds_the_native_geometry() {
        let spec = Value::parse(r#"{"preset": "quickstart"}"#).unwrap();
        let chain = parse_chain(&spec).unwrap();
        assert_eq!(chain.len(), 5); // dense + attn + mlp + dense + loss
    }

    #[test]
    fn inline_stages_spec() {
        let spec = Value::parse(
            r#"{"name": "mini", "input_bytes": 400,
                "stages": [
                  {"uf": 1.0, "ub": 2.0, "wa": 100, "wabar": 250},
                  {"name": "loss", "uf": 0.5, "ub": 0.5, "wa": 4, "wabar": 4, "of": 8}
                ]}"#,
        )
        .unwrap();
        let chain = parse_chain(&spec).unwrap();
        assert_eq!(chain.name, "mini");
        assert_eq!(chain.len(), 2);
        assert_eq!(chain.wa0, 400);
        assert_eq!(chain.wabar(1), 250);
        assert_eq!(chain.of(2), 8);
        assert_eq!(chain.stages[1].name, "loss");
    }

    #[test]
    fn inline_stage_validation() {
        // wabar < wa must be a structured error, not Stage::new's panic
        let spec = Value::parse(
            r#"{"input_bytes": 1, "stages": [{"uf": 1, "ub": 1, "wa": 10, "wabar": 5}]}"#,
        )
        .unwrap();
        let err = parse_chain(&spec).unwrap_err();
        assert!(format!("{err:#}").contains("wabar"), "{err:#}");
    }

    #[test]
    fn bytes_accept_numbers_and_suffix_strings() {
        assert_eq!(parse_bytes(&Value::parse("1024").unwrap(), "m").unwrap(), 1024);
        assert_eq!(
            parse_bytes(&Value::parse("\"512M\"").unwrap(), "m").unwrap(),
            512 << 20
        );
        assert!(parse_bytes(&Value::parse("0").unwrap(), "m").is_err());
        assert!(parse_bytes(&Value::parse("1.5").unwrap(), "m").is_err());
        assert!(parse_bytes(&Value::parse("\"x\"").unwrap(), "m").is_err());
    }

    #[test]
    fn op_tokens_round_trip_display() {
        let ops = [
            Op::FwdNoSave(2),
            Op::FwdCk(1),
            Op::FwdAll(5),
            Op::Bwd(5),
            Op::DropA(3),
        ];
        for op in ops {
            assert_eq!(parse_op(&op.to_string()).unwrap(), op, "{op}");
        }
        assert_eq!(parse_op("F0^7").unwrap(), Op::FwdNoSave(7)); // ASCII alias
        assert!(parse_op("Fck^0").is_err());
        assert!(parse_op("Fck").is_err());
        assert!(parse_op("X^1").is_err());
    }

    #[test]
    fn schedule_json_tokens_reparse() {
        let sched = Schedule::new(
            vec![Op::FwdCk(1), Op::FwdAll(2), Op::Bwd(2), Op::Bwd(1)],
            StrategyKind::Optimal,
            3.25,
        );
        let v = schedule_to_json(&sched);
        assert_eq!(v.get("predicted_time").unwrap().as_f64(), Some(3.25));
        assert_eq!(v.get("op_count").unwrap().as_usize(), Some(4));
        let tokens = v.get("ops").unwrap().as_arr().unwrap();
        let back: Vec<Op> =
            tokens.iter().map(|t| parse_op(t.as_str().unwrap()).unwrap()).collect();
        assert_eq!(back, sched.ops);
    }
}
