//! Byte-accurate memory state for Table 1 semantics.
//!
//! Tracks which items — `a^ℓ`, `ā^ℓ`, `δ^ℓ` — are resident, the current
//! byte total and the running peak. The paper's convention `ā^ℓ ⊇ a^ℓ`
//! is honored: `a^ℓ` is *readable* whenever either the standalone tensor
//! or the full checkpoint is stored, and consuming ops only free the
//! standalone copy (a taped `ā^{ℓ-1}` survives until its own `B^{ℓ-1}`).
//!
//! [`MemState::apply`] is the one transition function for a single
//! Table 1 op: precondition checks, transient peak charge, stores and
//! frees. Both [`crate::simulator::simulate`] and the lowering pass in
//! [`crate::plan`] drive it, so the simulator's verdict and the lowered
//! plan's liveness/peak can never drift apart.
//!
//! Standalone activations carry a **consumer count**: a stored `a^ℓ`
//! stays resident until [`MemState::consume_a`] has been called once per
//! planned consumer. On a chain every value has exactly one consumer
//! (the default for [`MemState::store_a`]), which reproduces Table 1's
//! replace-on-read semantics bit for bit; the graph replay in
//! [`crate::graph`] stores values with their true fan-out via
//! [`MemState::store_a_counted`], so a skip-connection input survives
//! until its *last* consumer and is freed exactly there.

use crate::chain::Chain;
use crate::solver::Op;

/// Why a sequence is invalid at some operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// An op named a stage outside `1..=L+1` (malformed input, e.g. a
    /// hand-written `/simulate` or `/lower` request).
    StageOutOfRange { op_index: usize, l: u32 },
    /// An op needed `a^ℓ` (readable) and it was absent.
    MissingActivation { op_index: usize, l: u32 },
    /// `B^ℓ` needed `δ^ℓ` or `ā^ℓ` and it was absent.
    MissingBackwardInput { op_index: usize, l: u32, what: &'static str },
    /// An op produced an item that is already resident (schedules must not
    /// double-store; this catches solver bugs early).
    DuplicateStore { op_index: usize, item: String },
    /// `B^ℓ` executed more than once.
    DuplicateBackward { op_index: usize, l: u32 },
    /// The sequence ended without producing `δ^0`.
    IncompleteBackward,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::StageOutOfRange { op_index, l } => {
                write!(f, "op #{op_index}: stage {l} outside the chain")
            }
            SimError::MissingActivation { op_index, l } => {
                write!(f, "op #{op_index}: a^{l} not resident")
            }
            SimError::MissingBackwardInput { op_index, l, what } => {
                write!(f, "op #{op_index}: B^{l} missing {what}")
            }
            SimError::DuplicateStore { op_index, item } => {
                write!(f, "op #{op_index}: {item} already resident")
            }
            SimError::DuplicateBackward { op_index, l } => {
                write!(f, "op #{op_index}: B^{l} executed twice")
            }
            SimError::IncompleteBackward => write!(f, "sequence ended without δ^0"),
        }
    }
}

impl std::error::Error for SimError {}

/// What one [`MemState::apply`] transition did to the resident set, in
/// terms the caller can act on (the simulator ignores it; the lowering
/// pass turns it into value births/deaths).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpEffect {
    /// `a^ℓ` newly stored (F∅ / Fck).
    pub stored_a: Option<usize>,
    /// `ā^ℓ` newly stored (Fall).
    pub stored_abar: Option<usize>,
    /// `δ^ℓ` newly stored (B^{ℓ+1}).
    pub stored_delta: Option<usize>,
    /// Standalone `a^ℓ` freed (the F∅ input, B's `a^{ℓ-1}`, DropA's
    /// target). `None` when the read went through a taped `ā`.
    pub freed_a: Option<usize>,
    /// `ā^ℓ` freed (by its `B^ℓ`).
    pub freed_abar: Option<usize>,
    /// `δ^ℓ` freed (by its `B^ℓ`).
    pub freed_delta: Option<usize>,
}

/// Sequence-level invariants shared by [`crate::simulator::simulate`]
/// and the lowering pass: each `B^ℓ` executes at most once, and a
/// complete sequence must end having produced `δ^0` with every backward
/// done. ([`MemState::apply`] owns the *per-op* rules; this owns the
/// whole-walk rules — both callers drive both, so neither can drift.)
#[derive(Debug, Clone)]
pub struct SeqCheck {
    bwd_done: Vec<bool>,
}

impl SeqCheck {
    pub fn new(chain_len: usize) -> Self {
        SeqCheck { bwd_done: vec![false; chain_len + 1] }
    }

    /// Call before [`MemState::apply`]: rejects a repeated `B^ℓ` (checked
    /// ahead of the transition, which would misreport it as a missing
    /// `δ^ℓ`) and records the execution. Out-of-range stages pass
    /// through — `apply` reports those with the right op index.
    pub fn observe(&mut self, op: Op, op_index: usize) -> Result<(), SimError> {
        if let Op::Bwd(l) = op {
            if let Some(done) = self.bwd_done.get_mut(l as usize) {
                if *done {
                    return Err(SimError::DuplicateBackward { op_index, l });
                }
                *done = true;
            }
        }
        Ok(())
    }

    /// Call after the walk: the sequence must have computed `δ^0` by
    /// executing every `B^ℓ`.
    pub fn finish(&self, st: &MemState) -> Result<(), SimError> {
        if !st.has_delta(0) || !self.bwd_done[1..].iter().all(|&b| b) {
            return Err(SimError::IncompleteBackward);
        }
        Ok(())
    }
}

/// Resident-set tracker. Indices: `a`/`delta` over `0..=L+1`, `abar` over
/// `1..=L+1` (stored at `l-1`).
#[derive(Debug, Clone)]
pub struct MemState {
    a: Vec<bool>,
    /// Remaining consuming reads of the standalone `a^ℓ` (meaningful only
    /// while `a[ℓ]`). `1` is the chain default (replace-on-read); larger
    /// values model multi-consumer fan-out; `0` marks a value no consumer
    /// manages (freed only by `DropA` or end of sequence).
    a_left: Vec<u32>,
    abar: Vec<bool>,
    delta: Vec<bool>,
    wa: Vec<u64>,
    wd: Vec<u64>,
    wabar: Vec<u64>,
    pub current: u64,
    pub peak: u64,
}

impl MemState {
    /// Initial state of a full iteration: `{a^0, δ^{L+1}}` resident
    /// (the DP's outer call assumes both stored; `δ^{L+1}` is the scalar
    /// seed of the loss backward).
    pub fn initial(chain: &Chain) -> Self {
        let n = chain.len();
        let wa: Vec<u64> = (0..=n).map(|l| chain.wa(l)).collect();
        let wd: Vec<u64> = (0..=n).map(|l| chain.wdelta(l)).collect();
        let wabar: Vec<u64> = (1..=n).map(|l| chain.wabar(l)).collect();
        let mut st = MemState {
            a: vec![false; n + 1],
            a_left: vec![0; n + 1],
            abar: vec![false; n],
            delta: vec![false; n + 1],
            wa,
            wd,
            wabar,
            current: 0,
            peak: 0,
        };
        st.a[0] = true;
        st.a_left[0] = 1;
        st.delta[n] = true;
        st.current = st.wa[0] + st.wd[n]; // input + δ^{L+1} seed
        st.peak = st.current;
        st
    }

    pub fn n(&self) -> usize {
        self.abar.len()
    }

    /// `a^ℓ` readable: standalone or inside `ā^ℓ`.
    pub fn a_readable(&self, l: usize) -> bool {
        self.a[l] || (l >= 1 && self.abar[l - 1])
    }

    pub fn has_a(&self, l: usize) -> bool {
        self.a[l]
    }

    pub fn has_abar(&self, l: usize) -> bool {
        self.abar[l - 1]
    }

    pub fn has_delta(&self, l: usize) -> bool {
        self.delta[l]
    }

    /// Record a transient high-water mark: `current + extra` bytes live
    /// during an op (inputs + freshly allocated outputs + overhead).
    pub fn touch_peak(&mut self, extra: u64) {
        self.peak = self.peak.max(self.current + extra);
    }

    /// Apply one Table 1 op: precondition checks, the transient peak
    /// charge, then the stores/frees of the op's row — exactly the
    /// accounting [`crate::simulator::simulate`] reports. Sequence-level
    /// invariants (each `B^ℓ` at most once, completeness) are the
    /// caller's job; this is the single-op transition only.
    pub fn apply(&mut self, chain: &Chain, op: Op, op_index: usize) -> Result<OpEffect, SimError> {
        let n = self.n();
        let stage = op.stage();
        if stage == 0 || stage as usize > n {
            return Err(SimError::StageOutOfRange { op_index, l: stage });
        }
        let mut eff = OpEffect::default();
        match op {
            Op::FwdNoSave(l) | Op::FwdCk(l) => {
                let l = l as usize;
                if !self.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index, l: l as u32 - 1 });
                }
                // inputs + new output + transient overhead live together
                self.touch_peak(chain.wa(l) + chain.of(l));
                self.store_a(l)
                    .map_err(|item| SimError::DuplicateStore { op_index, item })?;
                eff.stored_a = Some(l);
                if matches!(op, Op::FwdNoSave(_)) && self.consume_a(l - 1) {
                    eff.freed_a = Some(l - 1); // F∅ replaces its input
                }
            }
            Op::FwdAll(l) => {
                let l = l as usize;
                if !self.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index, l: l as u32 - 1 });
                }
                self.touch_peak(chain.wabar(l) + chain.of(l));
                self.store_abar(l)
                    .map_err(|item| SimError::DuplicateStore { op_index, item })?;
                eff.stored_abar = Some(l);
            }
            Op::Bwd(l) => {
                let l = l as usize;
                if !self.has_delta(l) {
                    return Err(SimError::MissingBackwardInput {
                        op_index,
                        l: l as u32,
                        what: "δ",
                    });
                }
                if !self.has_abar(l) {
                    return Err(SimError::MissingBackwardInput {
                        op_index,
                        l: l as u32,
                        what: "ā",
                    });
                }
                if !self.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index, l: l as u32 - 1 });
                }
                // Paper's Table 1 accounting: the output δ^{ℓ-1} *replaces*
                // a^{ℓ-1} (ω_δ = ω_a) rather than transiently coexisting —
                // this matches m_all's backward term ω_δ^s + ω_ā^s + o_b^s.
                self.touch_peak(chain.ob(l));
                self.free_delta(l);
                self.free_abar(l);
                eff.freed_delta = Some(l);
                eff.freed_abar = Some(l);
                if self.consume_a(l - 1) {
                    eff.freed_a = Some(l - 1);
                }
                self.store_delta(l - 1)
                    .map_err(|item| SimError::DuplicateStore { op_index, item })?;
                eff.stored_delta = Some(l - 1);
            }
            Op::DropA(l) => {
                let l = l as usize;
                if !self.has_a(l) {
                    return Err(SimError::MissingActivation { op_index, l: l as u32 });
                }
                self.free_a_if_standalone(l);
                eff.freed_a = Some(l);
            }
        }
        Ok(eff)
    }

    /// Store `a^ℓ` with the chain default of exactly one consumer.
    pub fn store_a(&mut self, l: usize) -> Result<(), String> {
        self.store_a_counted(l, 1)
    }

    /// Store `a^ℓ` with an explicit planned-consumer count (the graph
    /// replay's fan-out). `0` makes the value sticky: no
    /// [`Self::consume_a`] will free it.
    pub fn store_a_counted(&mut self, l: usize, consumers: u32) -> Result<(), String> {
        if self.a[l] {
            return Err(format!("a^{l}"));
        }
        self.a[l] = true;
        self.a_left[l] = consumers;
        self.current += self.wa[l];
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    /// Adjust the remaining-consumer count of a resident `a^ℓ` (used to
    /// seed the graph input's true fan-out after [`Self::initial`]).
    pub fn set_consumers(&mut self, l: usize, consumers: u32) {
        debug_assert!(self.a[l], "a^{l} must be resident to set consumers");
        self.a_left[l] = consumers;
    }

    /// Register one consuming read of the standalone `a^ℓ`: decrements
    /// the remaining-consumer count and frees the value when it reaches
    /// zero. Reads through a taped `ā^ℓ`, of absent values, or of sticky
    /// (count-0) values are no-ops. Returns whether the standalone copy
    /// was freed — with the chain's one-consumer default this is exactly
    /// the old replace-on-read free.
    pub fn consume_a(&mut self, l: usize) -> bool {
        if !self.a[l] || self.a_left[l] == 0 {
            return false;
        }
        self.a_left[l] -= 1;
        if self.a_left[l] == 0 {
            self.a[l] = false;
            self.current -= self.wa[l];
            true
        } else {
            false
        }
    }

    pub fn store_abar(&mut self, l: usize) -> Result<(), String> {
        if self.abar[l - 1] {
            return Err(format!("ā^{l}"));
        }
        self.abar[l - 1] = true;
        self.current += self.wabar[l - 1];
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    pub fn store_delta(&mut self, l: usize) -> Result<(), String> {
        if self.delta[l] {
            return Err(format!("δ^{l}"));
        }
        self.delta[l] = true;
        self.current += self.wd[l];
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    /// Free the standalone `a^ℓ` if (and only if) it is resident — taped
    /// copies inside `ā^ℓ` are not touched. Returns whether a standalone
    /// copy was actually freed.
    pub fn free_a_if_standalone(&mut self, l: usize) -> bool {
        if self.a[l] {
            self.a[l] = false;
            self.a_left[l] = 0;
            self.current -= self.wa[l];
            true
        } else {
            false
        }
    }

    pub fn free_abar(&mut self, l: usize) {
        debug_assert!(self.abar[l - 1]);
        self.abar[l - 1] = false;
        self.current -= self.wabar[l - 1];
    }

    pub fn free_delta(&mut self, l: usize) {
        debug_assert!(self.delta[l]);
        self.delta[l] = false;
        self.current -= self.wd[l];
    }

    /// Resident items, for diagnostics.
    pub fn resident(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (l, &p) in self.a.iter().enumerate() {
            if p {
                out.push(format!("a^{l}"));
            }
        }
        for (i, &p) in self.abar.iter().enumerate() {
            if p {
                out.push(format!("ā^{}", i + 1));
            }
        }
        for (l, &p) in self.delta.iter().enumerate() {
            if p {
                out.push(format!("δ^{l}"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;

    fn chain() -> Chain {
        Chain::new(
            "t",
            vec![Stage::new("s1", 1.0, 1.0, 10, 25), Stage::new("loss", 1.0, 1.0, 4, 4)],
            8,
        )
    }

    #[test]
    fn initial_holds_input_and_seed() {
        let st = MemState::initial(&chain());
        assert!(st.a_readable(0));
        assert!(st.has_delta(2));
        assert_eq!(st.current, 8 + 4);
    }

    #[test]
    fn abar_makes_a_readable() {
        let mut st = MemState::initial(&chain());
        st.store_abar(1).unwrap();
        assert!(st.a_readable(1));
        assert!(!st.has_a(1));
        st.free_a_if_standalone(1); // no-op: only the taped copy exists
        assert!(st.a_readable(1));
        assert_eq!(st.current, 12 + 25);
    }

    #[test]
    fn duplicate_store_rejected() {
        let mut st = MemState::initial(&chain());
        st.store_a(1).unwrap();
        assert!(st.store_a(1).is_err());
    }

    #[test]
    fn multi_consumer_values_survive_until_last_read() {
        let mut st = MemState::initial(&chain());
        let base = st.current;
        st.store_a_counted(1, 3).unwrap();
        assert_eq!(st.current, base + 10);
        assert!(!st.consume_a(1), "2 consumers left");
        assert!(!st.consume_a(1), "1 consumer left");
        assert!(st.has_a(1));
        assert!(st.consume_a(1), "last consumer frees");
        assert!(!st.has_a(1));
        assert_eq!(st.current, base);
        // sticky values (count 0) ignore consume but yield to a force free
        st.store_a_counted(1, 0).unwrap();
        assert!(!st.consume_a(1));
        assert!(st.has_a(1));
        assert!(st.free_a_if_standalone(1));
        assert_eq!(st.current, base);
    }

    #[test]
    fn peak_tracks_transients() {
        let mut st = MemState::initial(&chain());
        let base = st.current;
        st.touch_peak(100);
        assert_eq!(st.peak, base + 100);
        assert_eq!(st.current, base);
    }

    #[test]
    fn apply_reports_stores_and_frees() {
        let c = chain();
        let mut st = MemState::initial(&c);
        let eff = st.apply(&c, Op::FwdNoSave(1), 0).unwrap();
        assert_eq!(eff.stored_a, Some(1));
        assert_eq!(eff.freed_a, Some(0)); // F∅ replaced its input
        let eff = st.apply(&c, Op::FwdAll(2), 1).unwrap();
        assert_eq!(eff.stored_abar, Some(2));
        assert_eq!(eff.freed_a, None); // Fall keeps its input
        let eff = st.apply(&c, Op::Bwd(2), 2).unwrap();
        assert_eq!(eff.stored_delta, Some(1));
        assert_eq!((eff.freed_delta, eff.freed_abar, eff.freed_a), (Some(2), Some(2), Some(1)));
    }

    #[test]
    fn apply_rejects_out_of_range_stages() {
        let c = chain();
        let mut st = MemState::initial(&c);
        assert_eq!(
            st.apply(&c, Op::FwdNoSave(9), 0),
            Err(SimError::StageOutOfRange { op_index: 0, l: 9 })
        );
    }
}
