//! Byte-accurate memory state for Table 1 semantics.
//!
//! Tracks which items — `a^ℓ`, `ā^ℓ`, `δ^ℓ` — are resident, the current
//! byte total and the running peak. The paper's convention `ā^ℓ ⊇ a^ℓ`
//! is honored: `a^ℓ` is *readable* whenever either the standalone tensor
//! or the full checkpoint is stored, and consuming ops only free the
//! standalone copy (a taped `ā^{ℓ-1}` survives until its own `B^{ℓ-1}`).

use crate::chain::Chain;

/// Why a sequence is invalid at some operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// An op needed `a^ℓ` (readable) and it was absent.
    MissingActivation { op_index: usize, l: u32 },
    /// `B^ℓ` needed `δ^ℓ` or `ā^ℓ` and it was absent.
    MissingBackwardInput { op_index: usize, l: u32, what: &'static str },
    /// An op produced an item that is already resident (schedules must not
    /// double-store; this catches solver bugs early).
    DuplicateStore { op_index: usize, item: String },
    /// `B^ℓ` executed more than once.
    DuplicateBackward { op_index: usize, l: u32 },
    /// The sequence ended without producing `δ^0`.
    IncompleteBackward,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::MissingActivation { op_index, l } => {
                write!(f, "op #{op_index}: a^{l} not resident")
            }
            SimError::MissingBackwardInput { op_index, l, what } => {
                write!(f, "op #{op_index}: B^{l} missing {what}")
            }
            SimError::DuplicateStore { op_index, item } => {
                write!(f, "op #{op_index}: {item} already resident")
            }
            SimError::DuplicateBackward { op_index, l } => {
                write!(f, "op #{op_index}: B^{l} executed twice")
            }
            SimError::IncompleteBackward => write!(f, "sequence ended without δ^0"),
        }
    }
}

impl std::error::Error for SimError {}

/// Resident-set tracker. Indices: `a`/`delta` over `0..=L+1`, `abar` over
/// `1..=L+1` (stored at `l-1`).
#[derive(Debug, Clone)]
pub struct MemState {
    a: Vec<bool>,
    abar: Vec<bool>,
    delta: Vec<bool>,
    wa: Vec<u64>,
    wd: Vec<u64>,
    wabar: Vec<u64>,
    pub current: u64,
    pub peak: u64,
}

impl MemState {
    /// Initial state of a full iteration: `{a^0, δ^{L+1}}` resident
    /// (the DP's outer call assumes both stored; `δ^{L+1}` is the scalar
    /// seed of the loss backward).
    pub fn initial(chain: &Chain) -> Self {
        let n = chain.len();
        let wa: Vec<u64> = (0..=n).map(|l| chain.wa(l)).collect();
        let wd: Vec<u64> = (0..=n).map(|l| chain.wdelta(l)).collect();
        let wabar: Vec<u64> = (1..=n).map(|l| chain.wabar(l)).collect();
        let mut st = MemState {
            a: vec![false; n + 1],
            abar: vec![false; n],
            delta: vec![false; n + 1],
            wa,
            wd,
            wabar,
            current: 0,
            peak: 0,
        };
        st.a[0] = true;
        st.delta[n] = true;
        st.current = st.wa[0] + st.wd[n]; // input + δ^{L+1} seed
        st.peak = st.current;
        st
    }

    pub fn n(&self) -> usize {
        self.abar.len()
    }

    /// `a^ℓ` readable: standalone or inside `ā^ℓ`.
    pub fn a_readable(&self, l: usize) -> bool {
        self.a[l] || (l >= 1 && self.abar[l - 1])
    }

    pub fn has_a(&self, l: usize) -> bool {
        self.a[l]
    }

    pub fn has_abar(&self, l: usize) -> bool {
        self.abar[l - 1]
    }

    pub fn has_delta(&self, l: usize) -> bool {
        self.delta[l]
    }

    /// Record a transient high-water mark: `current + extra` bytes live
    /// during an op (inputs + freshly allocated outputs + overhead).
    pub fn touch_peak(&mut self, extra: u64) {
        self.peak = self.peak.max(self.current + extra);
    }

    pub fn store_a(&mut self, l: usize) -> Result<(), String> {
        if self.a[l] {
            return Err(format!("a^{l}"));
        }
        self.a[l] = true;
        self.current += self.wa[l];
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    pub fn store_abar(&mut self, l: usize) -> Result<(), String> {
        if self.abar[l - 1] {
            return Err(format!("ā^{l}"));
        }
        self.abar[l - 1] = true;
        self.current += self.wabar[l - 1];
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    pub fn store_delta(&mut self, l: usize) -> Result<(), String> {
        if self.delta[l] {
            return Err(format!("δ^{l}"));
        }
        self.delta[l] = true;
        self.current += self.wd[l];
        self.peak = self.peak.max(self.current);
        Ok(())
    }

    /// Free the standalone `a^ℓ` if (and only if) it is resident — taped
    /// copies inside `ā^ℓ` are not touched.
    pub fn free_a_if_standalone(&mut self, l: usize) {
        if self.a[l] {
            self.a[l] = false;
            self.current -= self.wa[l];
        }
    }

    pub fn free_abar(&mut self, l: usize) {
        debug_assert!(self.abar[l - 1]);
        self.abar[l - 1] = false;
        self.current -= self.wabar[l - 1];
    }

    pub fn free_delta(&mut self, l: usize) {
        debug_assert!(self.delta[l]);
        self.delta[l] = false;
        self.current -= self.wd[l];
    }

    /// Resident items, for diagnostics.
    pub fn resident(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (l, &p) in self.a.iter().enumerate() {
            if p {
                out.push(format!("a^{l}"));
            }
        }
        for (i, &p) in self.abar.iter().enumerate() {
            if p {
                out.push(format!("ā^{}", i + 1));
            }
        }
        for (l, &p) in self.delta.iter().enumerate() {
            if p {
                out.push(format!("δ^{l}"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;

    fn chain() -> Chain {
        Chain::new(
            "t",
            vec![Stage::new("s1", 1.0, 1.0, 10, 25), Stage::new("loss", 1.0, 1.0, 4, 4)],
            8,
        )
    }

    #[test]
    fn initial_holds_input_and_seed() {
        let st = MemState::initial(&chain());
        assert!(st.a_readable(0));
        assert!(st.has_delta(2));
        assert_eq!(st.current, 8 + 4);
    }

    #[test]
    fn abar_makes_a_readable() {
        let mut st = MemState::initial(&chain());
        st.store_abar(1).unwrap();
        assert!(st.a_readable(1));
        assert!(!st.has_a(1));
        st.free_a_if_standalone(1); // no-op: only the taped copy exists
        assert!(st.a_readable(1));
        assert_eq!(st.current, 12 + 25);
    }

    #[test]
    fn duplicate_store_rejected() {
        let mut st = MemState::initial(&chain());
        st.store_a(1).unwrap();
        assert!(st.store_a(1).is_err());
    }

    #[test]
    fn peak_tracks_transients() {
        let mut st = MemState::initial(&chain());
        let base = st.current;
        st.touch_peak(100);
        assert_eq!(st.peak, base + 100);
        assert_eq!(st.current, base);
    }
}
