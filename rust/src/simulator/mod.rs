//! Discrete replay of a [`Schedule`] against a [`Chain`]: validity,
//! byte-accurate peak memory, makespan (§3.1's definitions, verbatim).
//!
//! This module is the ground truth of the whole crate: every solver's
//! output is replayed here (property tests), and the figure harness uses
//! the reported `(peak, makespan)` pairs as the paper's plot coordinates.
//! The executor mirrors these exact semantics against real PJRT buffers.

mod memory;

pub use memory::{MemState, OpEffect, SeqCheck, SimError};

use crate::chain::Chain;
use crate::solver::{Op, Schedule};

/// Outcome of a valid replay.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Σ op durations (same unit as the chain's `u_f`/`u_b`).
    pub makespan: f64,
    /// Highest number of bytes simultaneously live (incl. transients).
    pub peak_bytes: u64,
    /// Total operations executed.
    pub ops: usize,
    /// Forward ops beyond the minimum `L+1` (recompute overhead).
    pub recomputed_forwards: usize,
}

impl SimReport {
    /// Throughput in items per time-unit for a given batch size.
    pub fn throughput(&self, batch: u64) -> f64 {
        batch as f64 / self.makespan
    }
}

/// Replay `schedule` over `chain` from `{a^0, δ^{L+1}}`; checks every
/// Table 1 precondition and that the sequence computes `δ^0` with each
/// `B^ℓ` exactly once.
///
/// The per-op transition (precondition checks, peak charge, stores and
/// frees) is [`MemState::apply`], and the sequence-level invariants
/// (each `B^ℓ` once, completeness) are [`SeqCheck`] — both shared
/// verbatim with the lowering pass in [`crate::plan`], so a lowered
/// plan's validity, liveness and plan-time peak can never drift from
/// this replay.
pub fn simulate(chain: &Chain, schedule: &Schedule) -> Result<SimReport, SimError> {
    let n = chain.len();
    let mut st = MemState::initial(chain);
    let mut seq = SeqCheck::new(n);
    let mut makespan = 0.0f64;
    let mut fwd_ops = 0usize;

    for (i, &op) in schedule.ops.iter().enumerate() {
        seq.observe(op, i)?;
        st.apply(chain, op, i)?;
        match op {
            Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) => {
                makespan += chain.uf(l as usize);
                fwd_ops += 1;
            }
            Op::Bwd(l) => makespan += chain.ub(l as usize),
            Op::DropA(_) => {} // free (0 time)
        }
    }
    seq.finish(&st)?;

    Ok(SimReport {
        makespan,
        peak_bytes: st.peak,
        ops: schedule.ops.len(),
        recomputed_forwards: fwd_ops.saturating_sub(n),
    })
}

/// Convenience: simulate and also check a byte budget.
pub fn simulate_within(chain: &Chain, schedule: &Schedule, memory: u64) -> Option<SimReport> {
    simulate(chain, schedule).ok().filter(|r| r.peak_bytes <= memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::solver::{store_all_schedule, Schedule, StrategyKind};

    fn toy() -> Chain {
        Chain::new(
            "toy",
            vec![
                Stage::new("s1", 1.0, 2.0, 100, 250),
                Stage::new("s2", 3.0, 4.0, 50, 120),
                Stage::new("loss", 0.5, 0.5, 4, 4),
            ],
            80,
        )
    }

    #[test]
    fn store_all_replays_clean() {
        let c = toy();
        let r = simulate(&c, &store_all_schedule(&c)).unwrap();
        assert_eq!(r.makespan, c.ideal_time());
        assert_eq!(r.recomputed_forwards, 0);
        // peak ≥ input + all ā + δ seed
        assert!(r.peak_bytes >= 80 + 250 + 120 + 4 + 4);
    }

    #[test]
    fn paper_example_sequence_is_valid() {
        // §3.1's example for L=4:
        // Fck^1 F∅^2 Fck^3 Fall^4 Fall^5 B^5 B^4 Fall^3 B^3 Fall^1 Fall^2 B^2 B^1
        let stages: Vec<Stage> =
            (1..=5).map(|i| Stage::new(format!("s{i}"), 1.0, 1.0, 10, 20)).collect();
        let c = Chain::new("l4", stages, 10);
        let ops = vec![
            Op::FwdCk(1),
            Op::FwdNoSave(2),
            Op::FwdCk(3),
            Op::FwdAll(4),
            Op::FwdAll(5),
            Op::Bwd(5),
            Op::Bwd(4),
            Op::FwdAll(3),
            Op::Bwd(3),
            Op::FwdAll(1),
            Op::FwdAll(2),
            Op::Bwd(2),
            Op::Bwd(1),
        ];
        let s = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        let r = simulate(&c, &s).unwrap();
        assert_eq!(r.recomputed_forwards, 3); // F1, F2, F3 run twice... minus?
        assert_eq!(r.ops, 13);
    }

    #[test]
    fn missing_activation_detected() {
        let c = toy();
        let s = Schedule::new(vec![Op::FwdNoSave(2)], StrategyKind::Optimal, 0.0);
        assert!(matches!(
            simulate(&c, &s),
            Err(SimError::MissingActivation { op_index: 0, l: 1 })
        ));
    }

    #[test]
    fn backward_without_tape_detected() {
        let c = toy();
        let s = Schedule::new(
            vec![Op::FwdCk(1), Op::FwdCk(2), Op::FwdCk(3), Op::Bwd(3)],
            StrategyKind::Optimal,
            0.0,
        );
        assert!(matches!(
            simulate(&c, &s),
            Err(SimError::MissingBackwardInput { what: "ā", .. })
        ));
    }

    #[test]
    fn incomplete_backward_detected() {
        let c = toy();
        let s = Schedule::new(
            vec![Op::FwdAll(1), Op::FwdAll(2), Op::FwdAll(3), Op::Bwd(3)],
            StrategyKind::Optimal,
            0.0,
        );
        assert_eq!(simulate(&c, &s), Err(SimError::IncompleteBackward));
    }

    #[test]
    fn fwd_nosave_frees_input() {
        // After F∅^1 the input a^0 must be gone: peak of a long F∅ sweep
        // stays bounded by two consecutive activations.
        let stages: Vec<Stage> =
            (1..=5).map(|i| Stage::new(format!("s{i}"), 1.0, 1.0, 10, 10)).collect();
        let c = Chain::new("sweep", stages, 10);
        let mut ops: Vec<Op> = (1..=5).map(|l| Op::FwdNoSave(l)).collect();
        // make it a full (invalid-at-end) sequence? No — check peak only.
        ops.truncate(5);
        let s = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        // IncompleteBackward expected, but peak can still be reasoned about
        // via a manual state walk:
        let mut st = MemState::initial(&c);
        for l in 1..=5usize {
            st.touch_peak(c.wa(l) + c.of(l));
            st.store_a(l).unwrap();
            st.free_a_if_standalone(l - 1);
        }
        // resident: a^5 + δ^5 seed; peak: 2 activations + seed
        assert_eq!(st.current, 10 + 10);
        assert_eq!(st.peak, 10 + 10 + 10);
        let _ = s;
    }
}
