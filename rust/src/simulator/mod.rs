//! Discrete replay of a [`Schedule`] against a [`Chain`]: validity,
//! byte-accurate peak memory, makespan (§3.1's definitions, verbatim).
//!
//! This module is the ground truth of the whole crate: every solver's
//! output is replayed here (property tests), and the figure harness uses
//! the reported `(peak, makespan)` pairs as the paper's plot coordinates.
//! The executor mirrors these exact semantics against real PJRT buffers.

mod memory;

pub use memory::{MemState, SimError};

use crate::chain::Chain;
use crate::solver::{Op, Schedule};

/// Outcome of a valid replay.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Σ op durations (same unit as the chain's `u_f`/`u_b`).
    pub makespan: f64,
    /// Highest number of bytes simultaneously live (incl. transients).
    pub peak_bytes: u64,
    /// Total operations executed.
    pub ops: usize,
    /// Forward ops beyond the minimum `L+1` (recompute overhead).
    pub recomputed_forwards: usize,
}

impl SimReport {
    /// Throughput in items per time-unit for a given batch size.
    pub fn throughput(&self, batch: u64) -> f64 {
        batch as f64 / self.makespan
    }
}

/// Replay `schedule` over `chain` from `{a^0, δ^{L+1}}`; checks every
/// Table 1 precondition and that the sequence computes `δ^0` with each
/// `B^ℓ` exactly once.
pub fn simulate(chain: &Chain, schedule: &Schedule) -> Result<SimReport, SimError> {
    let n = chain.len();
    let mut st = MemState::initial(chain);
    let mut makespan = 0.0f64;
    let mut bwd_done = vec![false; n + 1];
    let mut fwd_ops = 0usize;

    for (i, &op) in schedule.ops.iter().enumerate() {
        match op {
            Op::FwdNoSave(l) => {
                let l = l as usize;
                if !st.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index: i, l: l as u32 - 1 });
                }
                // inputs + new output + transient overhead live together
                st.touch_peak(chain.wa(l) + chain.of(l));
                st.store_a(l)
                    .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                st.free_a_if_standalone(l - 1); // F∅ replaces its input
                makespan += chain.uf(l);
                fwd_ops += 1;
            }
            Op::FwdCk(l) => {
                let l = l as usize;
                if !st.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index: i, l: l as u32 - 1 });
                }
                st.touch_peak(chain.wa(l) + chain.of(l));
                st.store_a(l)
                    .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                makespan += chain.uf(l);
                fwd_ops += 1;
            }
            Op::FwdAll(l) => {
                let l = l as usize;
                if !st.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index: i, l: l as u32 - 1 });
                }
                st.touch_peak(chain.wabar(l) + chain.of(l));
                st.store_abar(l)
                    .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                makespan += chain.uf(l);
                fwd_ops += 1;
            }
            Op::Bwd(l) => {
                let l = l as usize;
                if bwd_done[l] {
                    return Err(SimError::DuplicateBackward { op_index: i, l: l as u32 });
                }
                if !st.has_delta(l) {
                    return Err(SimError::MissingBackwardInput {
                        op_index: i,
                        l: l as u32,
                        what: "δ",
                    });
                }
                if !st.has_abar(l) {
                    return Err(SimError::MissingBackwardInput {
                        op_index: i,
                        l: l as u32,
                        what: "ā",
                    });
                }
                if !st.a_readable(l - 1) {
                    return Err(SimError::MissingActivation { op_index: i, l: l as u32 - 1 });
                }
                // Paper's Table 1 accounting: the output δ^{ℓ-1} *replaces*
                // a^{ℓ-1} (ω_δ = ω_a) rather than transiently coexisting —
                // this matches m_all's backward term ω_δ^s + ω_ā^s + o_b^s.
                st.touch_peak(chain.ob(l));
                st.free_delta(l);
                st.free_abar(l);
                st.free_a_if_standalone(l - 1);
                st.store_delta(l - 1)
                    .map_err(|item| SimError::DuplicateStore { op_index: i, item })?;
                bwd_done[l] = true;
                makespan += chain.ub(l);
            }
            Op::DropA(l) => {
                let l = l as usize;
                if !st.has_a(l) {
                    return Err(SimError::MissingActivation { op_index: i, l: l as u32 });
                }
                st.free_a_if_standalone(l);
            }
        }
    }

    if !st.has_delta(0) || !bwd_done[1..=n].iter().all(|&b| b) {
        return Err(SimError::IncompleteBackward);
    }

    Ok(SimReport {
        makespan,
        peak_bytes: st.peak,
        ops: schedule.ops.len(),
        recomputed_forwards: fwd_ops.saturating_sub(n),
    })
}

/// Convenience: simulate and also check a byte budget.
pub fn simulate_within(chain: &Chain, schedule: &Schedule, memory: u64) -> Option<SimReport> {
    simulate(chain, schedule).ok().filter(|r| r.peak_bytes <= memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::solver::{store_all_schedule, Schedule, StrategyKind};

    fn toy() -> Chain {
        Chain::new(
            "toy",
            vec![
                Stage::new("s1", 1.0, 2.0, 100, 250),
                Stage::new("s2", 3.0, 4.0, 50, 120),
                Stage::new("loss", 0.5, 0.5, 4, 4),
            ],
            80,
        )
    }

    #[test]
    fn store_all_replays_clean() {
        let c = toy();
        let r = simulate(&c, &store_all_schedule(&c)).unwrap();
        assert_eq!(r.makespan, c.ideal_time());
        assert_eq!(r.recomputed_forwards, 0);
        // peak ≥ input + all ā + δ seed
        assert!(r.peak_bytes >= 80 + 250 + 120 + 4 + 4);
    }

    #[test]
    fn paper_example_sequence_is_valid() {
        // §3.1's example for L=4:
        // Fck^1 F∅^2 Fck^3 Fall^4 Fall^5 B^5 B^4 Fall^3 B^3 Fall^1 Fall^2 B^2 B^1
        let stages: Vec<Stage> =
            (1..=5).map(|i| Stage::new(format!("s{i}"), 1.0, 1.0, 10, 20)).collect();
        let c = Chain::new("l4", stages, 10);
        let ops = vec![
            Op::FwdCk(1),
            Op::FwdNoSave(2),
            Op::FwdCk(3),
            Op::FwdAll(4),
            Op::FwdAll(5),
            Op::Bwd(5),
            Op::Bwd(4),
            Op::FwdAll(3),
            Op::Bwd(3),
            Op::FwdAll(1),
            Op::FwdAll(2),
            Op::Bwd(2),
            Op::Bwd(1),
        ];
        let s = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        let r = simulate(&c, &s).unwrap();
        assert_eq!(r.recomputed_forwards, 3); // F1, F2, F3 run twice... minus?
        assert_eq!(r.ops, 13);
    }

    #[test]
    fn missing_activation_detected() {
        let c = toy();
        let s = Schedule::new(vec![Op::FwdNoSave(2)], StrategyKind::Optimal, 0.0);
        assert!(matches!(
            simulate(&c, &s),
            Err(SimError::MissingActivation { op_index: 0, l: 1 })
        ));
    }

    #[test]
    fn backward_without_tape_detected() {
        let c = toy();
        let s = Schedule::new(
            vec![Op::FwdCk(1), Op::FwdCk(2), Op::FwdCk(3), Op::Bwd(3)],
            StrategyKind::Optimal,
            0.0,
        );
        assert!(matches!(
            simulate(&c, &s),
            Err(SimError::MissingBackwardInput { what: "ā", .. })
        ));
    }

    #[test]
    fn incomplete_backward_detected() {
        let c = toy();
        let s = Schedule::new(
            vec![Op::FwdAll(1), Op::FwdAll(2), Op::FwdAll(3), Op::Bwd(3)],
            StrategyKind::Optimal,
            0.0,
        );
        assert_eq!(simulate(&c, &s), Err(SimError::IncompleteBackward));
    }

    #[test]
    fn fwd_nosave_frees_input() {
        // After F∅^1 the input a^0 must be gone: peak of a long F∅ sweep
        // stays bounded by two consecutive activations.
        let stages: Vec<Stage> =
            (1..=5).map(|i| Stage::new(format!("s{i}"), 1.0, 1.0, 10, 10)).collect();
        let c = Chain::new("sweep", stages, 10);
        let mut ops: Vec<Op> = (1..=5).map(|l| Op::FwdNoSave(l)).collect();
        // make it a full (invalid-at-end) sequence? No — check peak only.
        ops.truncate(5);
        let s = Schedule::new(ops, StrategyKind::Optimal, 0.0);
        // IncompleteBackward expected, but peak can still be reasoned about
        // via a manual state walk:
        let mut st = MemState::initial(&c);
        for l in 1..=5usize {
            st.touch_peak(c.wa(l) + c.of(l));
            st.store_a(l).unwrap();
            st.free_a_if_standalone(l - 1);
        }
        // resident: a^5 + δ^5 seed; peak: 2 activations + seed
        assert_eq!(st.current, 10 + 10);
        assert_eq!(st.peak, 10 + 10 + 10);
        let _ = s;
    }
}
