//! Exhaustive schedule search (Dijkstra over memory states) for *small*
//! chains — the test oracle for the DP.
//!
//! Explores every valid operation sequence of the Table 1 model, including
//! **non-persistent** ones (early drops of checkpointed values), so it
//! computes the true optimum the paper's §4.1 shows persistent schedules
//! cannot always reach. Exponential in chain length; intended for chains
//! of ≤ ~8 stages inside tests.
//!
//! State: which `a^ℓ` / `ā^ℓ` are resident plus the current `δ` position
//! (every valid sequence holds exactly one `δ` at a time: `B^ℓ` turns
//! `δ^ℓ` into `δ^{ℓ-1}`). Costs are op durations; memory feasibility is
//! checked per transition with the simulator's accounting (forwards hold
//! input+output, backwards swap `δ^{ℓ-1}` in place of `a^{ℓ-1}`).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::chain::Chain;

const MAX_STAGES: usize = 12;

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
struct State {
    a: u16,    // bit l → a^l resident, l ∈ 0..=n
    abar: u16, // bit (l-1) → ā^l resident, l ∈ 1..=n
    delta: u8, // current δ position, n..=0
}

struct Search<'c> {
    chain: &'c Chain,
    n: usize,
    memory: u64,
}

impl<'c> Search<'c> {
    fn mem_of(&self, st: &State) -> u64 {
        let mut m = 0;
        for l in 0..=self.n {
            if st.a >> l & 1 == 1 {
                m += self.chain.wa(l);
            }
        }
        for l in 1..=self.n {
            if st.abar >> (l - 1) & 1 == 1 {
                m += self.chain.wabar(l);
            }
        }
        m + self.chain.wdelta(st.delta as usize)
    }

    fn a_readable(&self, st: &State, l: usize) -> bool {
        (st.a >> l & 1 == 1) || (l >= 1 && st.abar >> (l - 1) & 1 == 1)
    }

    /// Enumerate `(next_state, op_cost)` for all valid ops, respecting the
    /// memory limit (both the during-op peak and the resulting state).
    fn successors(&self, st: &State, cur_mem: u64, out: &mut Vec<(State, f64)>) {
        out.clear();
        let n = self.n;
        for l in 1..=n {
            let has_a = st.a >> l & 1 == 1;
            let has_abar = st.abar >> (l - 1) & 1 == 1;
            let input_standalone = st.a >> (l - 1) & 1 == 1;
            if self.a_readable(st, l - 1) {
                // forwards: input + output live together + overhead
                let peak = cur_mem + self.chain.wa(l) + self.chain.of(l);
                if !has_a && !has_abar && peak <= self.memory {
                    // Fck^l (keep input)
                    let mut s = *st;
                    s.a |= 1 << l;
                    out.push((s, self.chain.uf(l)));
                    // F∅^l (consume standalone input) — differs only if
                    // the input was standalone
                    if input_standalone {
                        let mut s2 = s;
                        s2.a &= !(1 << (l - 1));
                        out.push((s2, self.chain.uf(l)));
                    }
                }
                let peak_all = cur_mem + self.chain.wabar(l) + self.chain.of(l);
                if !has_abar && !has_a && peak_all <= self.memory {
                    // Fall^l
                    let mut s = *st;
                    s.abar |= 1 << (l - 1);
                    out.push((s, self.chain.uf(l)));
                }
            }
            // B^l
            if st.delta as usize == l && has_abar && self.a_readable(st, l - 1) {
                let peak = cur_mem + self.chain.ob(l);
                if peak <= self.memory {
                    let mut s = *st;
                    s.delta = (l - 1) as u8;
                    s.abar &= !(1 << (l - 1));
                    s.a &= !(1 << (l - 1)); // δ^{l-1} replaces a^{l-1}
                    out.push((s, self.chain.ub(l)));
                }
            }
            // free drops (non-persistent moves)
            if has_a {
                let mut s = *st;
                s.a &= !(1 << l);
                out.push((s, 0.0));
            }
            if has_abar {
                let mut s = *st;
                s.abar &= !(1 << (l - 1));
                out.push((s, 0.0));
            }
        }
    }
}

/// True optimal cost over **all** valid schedules (persistent or not), or
/// `None` if no schedule fits in `memory`. Panics on chains longer than
/// [`MAX_STAGES`] (state space is exponential).
pub fn exhaustive_optimal(chain: &Chain, memory: u64) -> Option<f64> {
    let n = chain.len();
    assert!(n <= MAX_STAGES, "exhaustive search is for tiny chains (≤ {MAX_STAGES})");
    let search = Search { chain, n, memory };

    let start = State { a: 1, abar: 0, delta: n as u8 };
    if search.mem_of(&start) > memory {
        return None;
    }
    let mut dist: HashMap<State, f64> = HashMap::new();
    let mut heap: BinaryHeap<(Reverse<u64>, State)> = BinaryHeap::new();
    // f64 keys in the heap via total-order bits (costs are non-negative)
    let key = |c: f64| Reverse(c.to_bits());
    dist.insert(start, 0.0);
    heap.push((key(0.0), start));
    let mut succ = Vec::new();

    while let Some((Reverse(bits), st)) = heap.pop() {
        let d = f64::from_bits(bits);
        if st.delta == 0 {
            return Some(d);
        }
        if dist.get(&st).is_some_and(|&best| d > best) {
            continue;
        }
        let cur_mem = search.mem_of(&st);
        search.successors(&st, cur_mem, &mut succ);
        let moves = std::mem::take(&mut succ);
        for &(ns, cost) in &moves {
            let nd = d + cost;
            if dist.get(&ns).is_none_or(|&best| nd < best) {
                dist.insert(ns, nd);
                heap.push((key(nd), ns));
            }
        }
        succ = moves;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::solver::{solve, Mode};

    fn tiny(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), i as f64, 2.0 * i as f64, 8 * i as u64, 12 * i as u64))
            .collect();
        stages.push(Stage::new("loss", 0.5, 0.5, 4, 4));
        Chain::new("tiny", stages, 8)
    }

    #[test]
    fn matches_ideal_with_plentiful_memory() {
        let c = tiny(4);
        let m = 10 * (c.store_all_memory() + c.wa0);
        assert_eq!(exhaustive_optimal(&c, m), Some(c.ideal_time()));
    }

    #[test]
    fn infeasible_when_starved() {
        let c = tiny(4);
        assert_eq!(exhaustive_optimal(&c, 8), None);
    }

    #[test]
    fn never_worse_than_the_persistent_dp() {
        // the exhaustive optimum ranges over a superset of schedules
        for seed in 0..12u64 {
            let mut rng = crate::util::Rng::new(seed);
            let n = 2 + rng.below(3) as usize;
            let mut stages: Vec<Stage> = (0..n)
                .map(|i| {
                    let wa = 4 * (1 + rng.below(8));
                    let ratio = 1 + rng.below(3);
                    Stage::new(
                        format!("s{i}"),
                        1.0 + rng.below(9) as f64,
                        1.0 + rng.below(9) as f64,
                        wa,
                        wa * ratio,
                    )
                })
                .collect();
            stages.push(Stage::new("loss", 0.5, 0.5, 4, 4));
            let c = Chain::new("rnd", stages, 4 * (1 + rng.below(8)));
            let lo = c.min_memory_hint();
            let hi = c.store_all_memory() + c.wa0;
            for i in 1..=3u64 {
                let m = lo + (hi - lo) * i / 3;
                let exact = exhaustive_optimal(&c, m);
                // exact discretization: slots = m (1 byte each) is too slow;
                // use a fine grid and allow the DP the rounding slack
                let dp = solve(&c, m, 1000, Mode::Full);
                if let (Some(e), Some(d)) = (exact, dp) {
                    assert!(
                        e <= d.predicted_time + 1e-9,
                        "seed {seed} m={m}: exhaustive {e} > DP {}",
                        d.predicted_time
                    );
                }
            }
        }
    }
}
