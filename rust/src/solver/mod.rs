//! Schedule solvers: the paper's optimal persistent DP and the three
//! comparison strategies of §5.3.
//!
//! | strategy     | paper name   | function |
//! |--------------|--------------|----------|
//! | store-all    | **PyTorch**  | [`store_all_schedule`] |
//! | periodic     | **sequential** (`checkpoint_sequential`) | [`periodic_schedule`] |
//! | AD optimum   | **revolve**  | [`revolve_schedule`] |
//! | this paper   | **optimal**  | [`optimal_schedule`] |
//!
//! For a *single* budget, [`solve`] (or the [`optimal_schedule`] /
//! [`revolve_schedule`] conveniences) is the entry point. For a budget
//! *sweep* over one chain — figures, `compare`, capacity planning — build
//! one [`Planner`] at the top budget and query it per budget: the DP
//! table is filled once and shared (see the [`planner`] module docs).
//!
//! These are the solver-layer substrate. Application code — the CLI, the
//! planning service, benches, library consumers — goes through
//! [`crate::api`] (`ChainSpec → PlanRequest → Plan`), which wraps this
//! module and is the only place outside it that constructs a [`Planner`].

mod exhaustive;
mod optimal;
mod periodic;
pub mod persist;
pub mod planner;
mod sequence;
mod store_all;

pub use exhaustive::exhaustive_optimal;
pub use optimal::{
    solve, solve_table, solve_table_dense, solve_table_dense_with_workers,
    solve_table_with_workers, try_solve_table, try_solve_table_with_workers, Decision, DpTable,
    Mode, MAX_TABLE_BYTES,
};
pub use periodic::{paper_segment_sweep, periodic_schedule, segment_bounds};
pub use planner::{
    cache_stats, clear_cache, set_table_dir, table_dir, Planner, PlannerCacheStats,
};
pub use sequence::{Op, Schedule, StrategyKind};
pub use store_all::store_all_schedule;

use crate::chain::{Chain, DEFAULT_SLOTS};

/// The paper's optimal persistent schedule (Theorem 1 / Algorithms 1–2)
/// for a byte budget `memory`, with the default S=500 discretization.
pub fn optimal_schedule(chain: &Chain, memory: u64) -> Option<Schedule> {
    solve(chain, memory, DEFAULT_SLOTS, Mode::Full)
}

/// The heterogeneous-AD `revolve` baseline ([13], and [14] Appendix C):
/// checkpoints layer inputs only; tapes each stage immediately before its
/// backward.
pub fn revolve_schedule(chain: &Chain, memory: u64) -> Option<Schedule> {
    solve(chain, memory, DEFAULT_SLOTS, Mode::AdRevolve)
}
