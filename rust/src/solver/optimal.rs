//! The paper's optimal persistent dynamic program (§4.2, Theorem 1,
//! Algorithms 1–2), plus the `revolve` restriction used as a baseline.
//!
//! `C_BP(s,t,m)` is the optimal time to back-propagate the sub-chain
//! `s..=t` with `m` memory slots, given `a^{s-1}` and `δ^t` resident
//! (`a^{s-1}` charged *outside* `m`). Two ways to start:
//!
//! * `Fck^s` then `F∅` up to some `s'`: checkpoint `a^{s-1}`, sweep to
//!   `a^{s'-1}`, solve `(s',t)` with `m − ω_a^{s'-1}`, then `(s,s'-1)`
//!   with `m` — the classic AD split, generalized to heterogeneous sizes.
//! * `Fall^s`: tape stage `s` entirely (`ā^s`), solve `(s+1,t)` with
//!   `m − ω_ā^s`, then run `B^s` directly. This branch is the paper's new
//!   operation — unavailable in the AD literature — and is what lets the
//!   optimal strategy exploit *large* memories.
//!
//! [`Mode::AdRevolve`] disables the second branch for `t > s`, which is
//! exactly the "revolve" comparator of §5.3 (heterogeneous AD optimum,
//! storing only layer inputs, taping right before each backward).

use super::sequence::{Op, Schedule};
use crate::chain::{Chain, DiscreteChain};

/// Decision markers packed into the DP table.
const DEC_INFEASIBLE: u16 = 0;
const DEC_ALL: u16 = 1;
// k >= 2 encodes the checkpoint split s' = s + (k - 1).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Full model of the paper (both branches).
    Full,
    /// AD model: `Fall` only immediately before its backward (revolve).
    AdRevolve,
}

/// Packed triangular DP table: cost and decision for every `(s, t, m)`.
pub struct DpTable {
    n: usize,
    slots: usize,
    cost: Vec<f64>,
    dec: Vec<u16>,
}

impl DpTable {
    fn new(n: usize, slots: usize) -> Self {
        let pairs = n * (n + 1) / 2;
        DpTable {
            n,
            slots,
            cost: vec![f64::INFINITY; pairs * (slots + 1)],
            dec: vec![DEC_INFEASIBLE; pairs * (slots + 1)],
        }
    }

    /// Triangular pair index for 1-based `s ≤ t`.
    #[inline]
    fn pair(&self, s: usize, t: usize) -> usize {
        debug_assert!(1 <= s && s <= t && t <= self.n);
        (t - 1) * t / 2 + (s - 1)
    }

    #[inline]
    fn idx(&self, s: usize, t: usize, m: u32) -> usize {
        self.pair(s, t) * (self.slots + 1) + m as usize
    }

    #[inline]
    pub fn cost(&self, s: usize, t: usize, m: u32) -> f64 {
        self.cost[self.idx(s, t, m)]
    }

    /// Number of stages `L+1` the table covers.
    pub fn stages(&self) -> usize {
        self.n
    }

    /// Upper bound of the table's slot axis (budgets `0..=slots`).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Approximate heap footprint, used by the planner cache's byte budget.
    pub fn mem_bytes(&self) -> usize {
        self.cost.len() * (std::mem::size_of::<f64>() + std::mem::size_of::<u16>())
    }

    /// Cost row of one `(s, t)` cell: contiguous over the m axis.
    #[inline]
    fn row(&self, s: usize, t: usize) -> &[f64] {
        let base = self.pair(s, t) * (self.slots + 1);
        &self.cost[base..base + self.slots + 1]
    }

    /// Write a whole `(s, t)` cell at once (parallel fill writeback).
    fn write_row(&mut self, s: usize, t: usize, cost: &[f64], dec: &[u16]) {
        let base = self.pair(s, t) * (self.slots + 1);
        self.cost[base..base + self.slots + 1].copy_from_slice(cost);
        self.dec[base..base + self.slots + 1].copy_from_slice(dec);
    }

    #[inline]
    fn dec(&self, s: usize, t: usize, m: u32) -> u16 {
        self.dec[self.idx(s, t, m)]
    }

    #[inline]
    fn set(&mut self, s: usize, t: usize, m: u32, cost: f64, dec: u16) {
        let i = self.idx(s, t, m);
        self.cost[i] = cost;
        self.dec[i] = dec;
    }
}

/// Full DP solve over a discretized chain. The table covers every
/// `(s, t, m)`, so one solve supports reconstruction at any budget `≤ M`.
///
/// Uses every available core for the wavefront fill; see
/// [`solve_table_with_workers`] for an explicit worker count (the
/// regression suite pins `workers = 1` to prove the parallel fill is
/// bit-identical to the serial one).
pub fn solve_table(dc: &DiscreteChain, mode: Mode) -> DpTable {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    solve_table_with_workers(dc, mode, workers)
}

/// [`solve_table`] with a pinned worker count. `workers <= 1` forces the
/// serial fill; larger counts chunk each anti-diagonal across scoped
/// threads. The result is bit-identical regardless of `workers`: cells
/// on one diagonal depend only on strictly shorter sub-chains, each cell
/// is computed in isolation ([`fill_cell`]), and the writeback order is
/// the deterministic diagonal order either way.
pub fn solve_table_with_workers(dc: &DiscreteChain, mode: Mode, workers: usize) -> DpTable {
    let n = dc.len();
    let slots = dc.slots;
    let mut tab = DpTable::new(n, slots);

    // Prefix sums of u_f for O(1) Σ u_f^{s..s'-1}.
    let mut uf_prefix = vec![0.0f64; n + 1];
    for l in 1..=n {
        uf_prefix[l] = uf_prefix[l - 1] + dc.uf_s(l);
    }

    // Base case (eq. 1): C(s,s,m) = u_f + u_b  iff  m ≥ m_all^{s,s}.
    for s in 1..=n {
        let need = m_all(dc, s, s);
        let cost = dc.uf_s(s) + dc.ub_s(s);
        for m in 0..=slots as u32 {
            if m >= need {
                tab.set(s, s, m, cost, DEC_ALL);
            }
        }
    }

    // General case by increasing sub-chain length d = t - s (eq. 2).
    // Cells on one diagonal depend only on strictly shorter sub-chains,
    // so each diagonal is filled in parallel (scoped threads; no rayon in
    // the offline build) and written back serially. The per-cell kernel
    // iterates m *innermost over contiguous rows* — the dominant loop is
    // two streaming adds + a compare over slot-indexed slices.
    for d in 1..n {
        let cells: Vec<usize> = ((d + 1)..=n).collect(); // t values; s = t - d
        let results: Vec<(usize, Vec<f64>, Vec<u16>)> = if cells.len() < 2 || workers < 2 {
            cells
                .iter()
                .map(|&t| {
                    let (c, dec) = fill_cell(&tab, dc, &uf_prefix, t - d, t, mode);
                    (t, c, dec)
                })
                .collect()
        } else {
            let chunk = cells.len().div_ceil(workers);
            std::thread::scope(|scope| {
                let tab_ref = &tab;
                let uf_ref = &uf_prefix;
                let handles: Vec<_> = cells
                    .chunks(chunk)
                    .map(|part| {
                        scope.spawn(move || {
                            part.iter()
                                .map(|&t| {
                                    let (c, dec) =
                                        fill_cell(tab_ref, dc, uf_ref, t - d, t, mode);
                                    (t, c, dec)
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            })
        };
        for (t, cost, dec) in results {
            tab.write_row(t - d, t, &cost, &dec);
        }
    }
    tab
}

/// Fill one `(s, t)` cell across the whole m axis (eq. 2).
///
/// Infinity propagates through the adds, so no explicit feasibility
/// branches are needed in the inner loops: `∞ < best` is always false.
fn fill_cell(
    tab: &DpTable,
    dc: &DiscreteChain,
    uf_prefix: &[f64],
    s: usize,
    t: usize,
    mode: Mode,
) -> (Vec<f64>, Vec<u16>) {
    let slots = dc.slots;
    let mut best = vec![f64::INFINITY; slots + 1];
    let mut dec = vec![DEC_INFEASIBLE; slots + 1];

    // C1: Fck^s, F∅^{s+1..s'-1}, recurse (s',t) with m−ω_a^{s'-1} and
    // (s,s'-1) with m.
    let m_nosave = m_empty(dc, s, t) as usize;
    for sp in (s + 1)..=t {
        let hold = dc.wa_s(sp - 1) as usize; // a^{s'-1} stays resident
        let pre = uf_prefix[sp - 1] - uf_prefix[s - 1];
        let left = tab.row(s, sp - 1);
        let right = tab.row(sp, t);
        let code = (sp - s + 1) as u16;
        let start = m_nosave.max(hold);
        if start > slots {
            continue;
        }
        for m in start..=slots {
            let c = pre + right[m - hold] + left[m];
            if c < best[m] {
                best[m] = c;
                dec[m] = code;
            }
        }
    }

    // C2: Fall^s, recurse (s+1,t) with m−ω_ā^s, B^s. (Absent in AD mode.)
    if mode == Mode::Full {
        let m_all_st = m_all(dc, s, t) as usize;
        let habar = dc.wabar_s(s) as usize;
        let fixed = dc.uf_s(s) + dc.ub_s(s);
        let mid = tab.row(s + 1, t);
        let start = m_all_st.max(habar);
        if start <= slots {
            for m in start..=slots {
                let c = fixed + mid[m - habar];
                if c < best[m] {
                    best[m] = c;
                    dec[m] = DEC_ALL;
                }
            }
        }
    }
    (best, dec)
}

/// `m∅^{s,t}`: slots needed to sweep `F∅` from `s` to just before `t`
/// with `δ^t` resident (paper §4.2).
fn m_empty(dc: &DiscreteChain, s: usize, t: usize) -> u32 {
    let wd_t = dc.wd_s(t);
    let mut peak = wd_t + dc.wa_s(s) + dc.of_s(s);
    for j in (s + 1)..t {
        peak = peak.max(wd_t + dc.wa_s(j - 1) + dc.wa_s(j) + dc.of_s(j));
    }
    peak
}

/// `m_all^{s,t}`: slots needed to run `Fall^s` (with `δ^t` resident) and
/// later `B^s` (with `δ^s` resident).
fn m_all(dc: &DiscreteChain, s: usize, t: usize) -> u32 {
    let fwd = dc.wd_s(t) + dc.wabar_s(s) + dc.of_s(s);
    let bwd = dc.wd_s(s) + dc.wabar_s(s) + dc.ob_s(s);
    fwd.max(bwd)
}

/// Algorithm 2: reconstruct the optimal sequence from the table. Valid at
/// *any* slot budget `m`, not just the one a solve was requested at — the
/// table covers the whole `(s, t, m)` space (the planner relies on this).
pub(crate) fn reconstruct(
    tab: &DpTable,
    dc: &DiscreteChain,
    s: usize,
    t: usize,
    m: u32,
    ops: &mut Vec<Op>,
) {
    match tab.dec(s, t, m) {
        DEC_INFEASIBLE => unreachable!("reconstruct called on infeasible cell"),
        DEC_ALL if s == t => {
            ops.push(Op::FwdAll(s as u32));
            ops.push(Op::Bwd(s as u32));
        }
        DEC_ALL => {
            ops.push(Op::FwdAll(s as u32));
            reconstruct(tab, dc, s + 1, t, m - dc.wabar_s(s), ops);
            ops.push(Op::Bwd(s as u32));
        }
        k => {
            let sp = s + (k as usize - 1);
            ops.push(Op::FwdCk(s as u32));
            for j in (s + 1)..sp {
                ops.push(Op::FwdNoSave(j as u32));
            }
            reconstruct(tab, dc, sp, t, m - dc.wa_s(sp - 1), ops);
            reconstruct(tab, dc, s, sp - 1, m, ops);
        }
    }
}

/// One full solve: discretize against `memory`, fill (or fetch from the
/// planner cache) the table, reconstruct at the top budget `M − ω_a^0`.
/// Returns `None` when no persistent schedule fits.
///
/// This is now a thin compatibility wrapper over [`super::Planner`]: a
/// planner built at `memory` answers its own top budget, which is exactly
/// the historical `solve` semantics (same discretization, same table,
/// same reconstruction — and repeated solves of the same profile hit the
/// cache instead of re-running the DP). Note the footprint trade-off:
/// the table (tens of MB for long chains) may stay resident in the
/// process-global LRU cache instead of being dropped on return; call
/// [`super::clear_cache`] to reclaim it. Sweeping many budgets over one
/// chain should construct a single `Planner` instead of calling this in
/// a loop.
pub fn solve(chain: &Chain, memory: u64, slots: usize, mode: Mode) -> Option<Schedule> {
    super::Planner::new(chain, memory, slots, mode).schedule_at(memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::{Stage, DEFAULT_SLOTS};

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    #[test]
    fn unlimited_memory_is_store_all_time() {
        let c = toy(6);
        let s = solve(&c, 1 << 30, DEFAULT_SLOTS, Mode::Full).unwrap();
        assert!((s.predicted_time - c.ideal_time()).abs() < 1e-9);
        // With memory to spare the optimal schedule tapes everything:
        // no recomputation at all.
        assert_eq!(s.recomputation_ops(c.len()), 0);
        // And it is exactly Fall^1.. Fall^{L+1} B^{L+1}.. B^1.
        let n = c.len() as u32;
        for (i, op) in s.ops.iter().take(c.len()).enumerate() {
            assert_eq!(*op, Op::FwdAll(i as u32 + 1));
        }
        for (i, op) in s.ops.iter().skip(c.len()).enumerate() {
            assert_eq!(*op, Op::Bwd(n - i as u32));
        }
    }

    #[test]
    fn no_memory_is_infeasible() {
        let c = toy(4);
        assert!(solve(&c, 64, DEFAULT_SLOTS, Mode::Full).is_none());
    }

    #[test]
    fn cost_monotone_in_memory() {
        let c = toy(8);
        let lo = c.min_memory_hint();
        let hi = c.store_all_memory() + c.wa0;
        let mut last = f64::INFINITY;
        for i in 0..10 {
            let m = lo + (hi - lo) * i / 9;
            if let Some(s) = solve(&c, m, 200, Mode::Full) {
                assert!(
                    s.predicted_time <= last + 1e-9,
                    "cost must not increase with memory: {} then {}",
                    last,
                    s.predicted_time
                );
                last = s.predicted_time;
            }
        }
        assert!(last.is_finite(), "largest budget must be feasible");
    }

    #[test]
    fn tight_memory_forces_recomputation() {
        let c = toy(8);
        let m = (c.store_all_memory() + c.wa0) / 3;
        let s = solve(&c, m, DEFAULT_SLOTS, Mode::Full).unwrap();
        assert!(s.recomputation_ops(c.len()) > 0);
        assert!(s.predicted_time > c.ideal_time());
    }

    #[test]
    fn revolve_never_beats_full_model() {
        let c = toy(8);
        let lo = c.min_memory_hint() * 2;
        let hi = c.store_all_memory() + c.wa0;
        for i in 0..6 {
            let m = lo + (hi - lo) * i / 5;
            let full = solve(&c, m, 300, Mode::Full);
            let rev = solve(&c, m, 300, Mode::AdRevolve);
            if let (Some(f), Some(r)) = (full, rev) {
                assert!(
                    f.predicted_time <= r.predicted_time + 1e-9,
                    "m={m}: full {} > revolve {}",
                    f.predicted_time,
                    r.predicted_time
                );
            }
        }
    }

    #[test]
    fn revolve_recomputes_every_backward_target() {
        // In the AD model every B^ℓ is preceded by its own Fall^ℓ, so each
        // stage's forward runs at least twice (except possibly stage s of
        // the outermost base case).
        let c = toy(5);
        let s = solve(&c, c.store_all_memory() + c.wa0, 300, Mode::AdRevolve).unwrap();
        let n_fall = s.ops.iter().filter(|o| matches!(o, Op::FwdAll(_))).count();
        assert_eq!(n_fall, c.len(), "one Fall per backward");
        assert!(s.predicted_time >= c.ideal_time());
    }

    #[test]
    fn two_stage_manual_check() {
        // Chain: stage1 (uf=10, ub=1, wa=8, wabar=16), loss (uf=1, ub=1, wa=1, wabar=1),
        // input wa0=8. Unlimited memory: Fall^1 Fall^2 B^2 B^1 = 13.
        let c = Chain::new(
            "manual",
            vec![Stage::new("s1", 10.0, 1.0, 8, 16), Stage::new("loss", 1.0, 1.0, 1, 1)],
            8,
        );
        let s = solve(&c, 1 << 20, 100, Mode::Full).unwrap();
        assert_eq!(s.predicted_time, 13.0);
        assert_eq!(
            s.ops,
            vec![Op::FwdAll(1), Op::FwdAll(2), Op::Bwd(2), Op::Bwd(1)]
        );
    }

    #[test]
    fn table_supports_any_budget() {
        let c = toy(5);
        let dc = DiscreteChain::new(&c, 1 << 22, 100);
        let tab = solve_table(&dc, Mode::Full);
        let n = dc.len();
        // cost at m is non-increasing along the m axis
        let mut last = f64::INFINITY;
        for m in 0..=dc.slots as u32 {
            let cst = tab.cost(1, n, m);
            assert!(cst <= last + 1e-9);
            if cst.is_finite() {
                last = cst;
            }
        }
    }
}
