//! The paper's optimal persistent dynamic program (§4.2, Theorem 1,
//! Algorithms 1–2), plus the `revolve` restriction used as a baseline.
//!
//! `C_BP(s,t,m)` is the optimal time to back-propagate the sub-chain
//! `s..=t` with `m` memory slots, given `a^{s-1}` and `δ^t` resident
//! (`a^{s-1}` charged *outside* `m`). Two ways to start:
//!
//! * `Fck^s` then `F∅` up to some `s'`: checkpoint `a^{s-1}`, sweep to
//!   `a^{s'-1}`, solve `(s',t)` with `m − ω_a^{s'-1}`, then `(s,s'-1)`
//!   with `m` — the classic AD split, generalized to heterogeneous sizes.
//! * `Fall^s`: tape stage `s` entirely (`ā^s`), solve `(s+1,t)` with
//!   `m − ω_ā^s`, then run `B^s` directly. This branch is the paper's new
//!   operation — unavailable in the AD literature — and is what lets the
//!   optimal strategy exploit *large* memories.
//!
//! [`Mode::AdRevolve`] disables the second branch for `t > s`, which is
//! exactly the "revolve" comparator of §5.3 (heterogeneous AD optimum,
//! storing only layer inputs, taping right before each backward).
//!
//! # Frontier rows: the compressed, pruned fill
//!
//! The production fill ([`solve_table`]) exploits three structural facts
//! the dense formulation ignores:
//!
//! 1. **Thresholds are range maxima.** `m∅(s,t)` is a max over the span —
//!    [`PeakOracle`] precomputes a sparse table once so each of the O(L³)
//!    per-split queries is O(1) instead of an O(t−s) scan.
//! 2. **Cost rows are non-increasing step functions.** Along the m axis
//!    `C(s,t,·)` only ever steps *down* — an extra slot either buys a
//!    strictly better strategy or it changes nothing — and in practice it
//!    steps a handful of times (on the `t−s+2` scale of the candidate
//!    structure), far fewer than the `S+1` hard ceiling. Rows are stored
//!    run-length-compressed as sorted `(m_start, cost, decision)` runs
//!    ("frontier rows") in a diagonal-major append-only arena
//!    ([`FrontierStore`]); budgets below the first run are infeasible and
//!    each run holds to the next run's start (or to `S`). A run breaks on
//!    a change of `(cost bits, decision)` — equal adjacent costs do *not*
//!    imply equal decisions, so dedup keys on the pair. The dense
//!    accessors ([`DpTable::cost`] / [`DpTable::decision`]) are preserved
//!    on top via binary search.
//! 3. **Most splits are dominated.** A candidate split's value is bounded
//!    below by `Σu_f + min(right) + min(left)`; if that bound already
//!    fails to beat the incumbent row at the candidate's first feasible
//!    slot, no budget can make the candidate win (rows are
//!    non-increasing and updates require a *strict* improvement), so the
//!    split is skipped after O(1) work. Per-row summaries (first feasible
//!    slot, minimum cost) make the check two loads. The prune is exact —
//!    the bound uses the same `(Σu_f + right) + left` float association
//!    as the reference fill, and f64 addition is monotone — so the fast
//!    fill is **bit-identical** to [`solve_table_dense`], which retains
//!    the plain dense scan as the executable specification
//!    (`tests/dp_fill_parity.rs` pins this).
//!
//! Surviving candidates are folded into the incumbent row by a
//! breakpoint merge that costs O(runs) instead of O(S). The wavefront
//! parallelism is unchanged: each anti-diagonal's cells are computed in
//! isolation across scoped threads and appended to the arena in
//! deterministic diagonal order, so results are bit-identical for every
//! worker count (`tests/wavefront_parity.rs`).

use super::sequence::{Op, Schedule};
use crate::api::{Error, Result as ApiResult};
use crate::chain::{Chain, DiscreteChain, PeakOracle};

/// Decision markers packed into the DP table.
const DEC_INFEASIBLE: u16 = 0;
const DEC_ALL: u16 = 1;
// k >= 2 encodes the checkpoint split s' = s + (k - 1).

/// Hard ceiling on a single DP table's heap footprint. [`DpTable::try_new`]
/// rejects any `(L, S)` whose *worst-case* compressed table could exceed
/// this, so a fill that starts always finishes without exhausting memory.
pub const MAX_TABLE_BYTES: u128 = 16 << 30;

/// Bytes per frontier run: `m_start: u32` + `cost: f64` + `dec: u16`
/// (struct-of-arrays, so no padding).
const RUN_BYTES: u128 = 4 + 8 + 2;
/// Per-row overhead: one `u64` arena offset plus the `(first_m, min_cost)`
/// summary pair the dominance prune reads.
const ROW_BYTES: u128 = 8 + 4 + 8;

/// Checked narrowing for values on the u32 slot/stage axes. Every call
/// site is bounded by construction — [`DpTable::preflight`] caps stages
/// at `u16::MAX` and slot budgets live on a `u32` axis — so a failure
/// here is a solver invariant violation, not an input error.
#[inline]
fn idx32(v: u64) -> u32 {
    u32::try_from(v).unwrap_or_else(|_| panic!("index {v} exceeds the u32 slot/stage axis"))
}

/// Checked narrowing for stage indices (`≤ u16::MAX` per `preflight`).
#[inline]
fn stage32(s: usize) -> u32 {
    idx32(s as u64)
}

/// Checked narrowing for the u16 split encoding (`k = s' − s + 1 ≤ n`).
#[inline]
fn split16(k: usize) -> u16 {
    u16::try_from(k)
        .unwrap_or_else(|_| panic!("split code {k} exceeds the u16 encoding preflight admits"))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Full model of the paper (both branches).
    Full,
    /// AD model: `Fall` only immediately before its backward (revolve).
    AdRevolve,
}

/// What the optimal strategy does first for a `(s, t, m)` cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// No persistent schedule fits in `m` slots.
    Infeasible,
    /// `Fall^s`: tape stage `s`, recurse on `(s+1, t)`.
    TapeAll,
    /// `Fck^s` then `F∅` up to `s'`: checkpoint `a^{s-1}`, recurse on
    /// `(s', t)` then `(s, s'-1)`. The payload is the absolute `s'`.
    Split(usize),
}

// ---------------------------------------------------------------------------
// Storage: frontier-compressed rows (production) or dense (reference).
// ---------------------------------------------------------------------------

/// Frontier-compressed table storage: every `(s, t)` row is a sorted list
/// of `(m_start, cost, dec)` runs in one diagonal-major append-only arena.
/// `row_start[cell]..row_start[cell+1]` bounds a row's runs; cells are
/// numbered in fill order (diagonal `d = t−s` ascending, then `s`
/// ascending), which makes the parallel fill's write-back a plain append.
pub(crate) struct FrontierStore {
    pub(crate) n: usize,
    /// Arena offsets; `cells + 1` entries once the fill completes.
    pub(crate) row_start: Vec<u64>,
    pub(crate) ms: Vec<u32>,
    pub(crate) costs: Vec<f64>,
    pub(crate) decs: Vec<u16>,
    /// Per-row summaries for the O(1) dominance prune: first feasible slot
    /// (`u32::MAX` when the row is empty) and minimum (= rightmost) cost.
    pub(crate) row_first_m: Vec<u32>,
    pub(crate) row_min_cost: Vec<f64>,
}

/// A borrowed view of one row's runs.
#[derive(Clone, Copy)]
struct Runs<'a> {
    ms: &'a [u32],
    costs: &'a [f64],
    decs: &'a [u16],
}

impl<'a> Runs<'a> {
    /// Index of the run covering slot `m` (caller guarantees the row is
    /// non-empty and `m ≥ ms[0]`).
    #[inline]
    fn index_at(&self, m: u32) -> usize {
        debug_assert!(!self.ms.is_empty() && self.ms[0] <= m);
        self.ms.partition_point(|&x| x <= m) - 1
    }

    #[inline]
    fn cost_at(&self, m: u32) -> f64 {
        match self.ms.first() {
            Some(&first) if m >= first => self.costs[self.index_at(m)],
            _ => f64::INFINITY,
        }
    }

    #[inline]
    fn dec_at(&self, m: u32) -> u16 {
        match self.ms.first() {
            Some(&first) if m >= first => self.decs[self.index_at(m)],
            _ => DEC_INFEASIBLE,
        }
    }
}

impl FrontierStore {
    /// Diagonal-major cell index for 1-based `s ≤ t`.
    #[inline]
    fn cell(&self, s: usize, t: usize) -> usize {
        debug_assert!(1 <= s && s <= t && t <= self.n);
        let d = t - s;
        d * self.n - d * (d - 1) / 2 + (s - 1)
    }

    #[inline]
    fn runs(&self, s: usize, t: usize) -> Runs<'_> {
        let c = self.cell(s, t);
        let (lo, hi) = (self.row_start[c] as usize, self.row_start[c + 1] as usize);
        Runs { ms: &self.ms[lo..hi], costs: &self.costs[lo..hi], decs: &self.decs[lo..hi] }
    }

    #[inline]
    fn first_m(&self, s: usize, t: usize) -> u32 {
        self.row_first_m[self.cell(s, t)]
    }

    #[inline]
    fn min_cost(&self, s: usize, t: usize) -> f64 {
        self.row_min_cost[self.cell(s, t)]
    }

    fn with_capacity(n: usize) -> ApiResult<FrontierStore> {
        let cells = n * (n + 1) / 2;
        let mut store = FrontierStore {
            n,
            row_start: Vec::new(),
            ms: Vec::new(),
            costs: Vec::new(),
            decs: Vec::new(),
            row_first_m: Vec::new(),
            row_min_cost: Vec::new(),
        };
        let oom = |e| Error::invalid(format!("DP table row index allocation failed: {e}"));
        store.row_start.try_reserve_exact(cells + 1).map_err(oom)?;
        store.row_first_m.try_reserve_exact(cells).map_err(oom)?;
        store.row_min_cost.try_reserve_exact(cells).map_err(oom)?;
        store.row_start.push(0);
        Ok(store)
    }

    /// Append the next row in cell order. Arena growth is fallible so an
    /// unexpectedly incompressible fill degrades into a kind-tagged error
    /// instead of an allocator abort.
    fn append_row(&mut self, ms: &[u32], costs: &[f64], decs: &[u16]) -> ApiResult<()> {
        debug_assert!(ms.len() == costs.len() && ms.len() == decs.len());
        let oom = |e| Error::invalid(format!("DP table arena allocation failed: {e}"));
        self.ms.try_reserve(ms.len()).map_err(oom)?;
        self.costs.try_reserve(costs.len()).map_err(oom)?;
        self.decs.try_reserve(decs.len()).map_err(oom)?;
        self.ms.extend_from_slice(ms);
        self.costs.extend_from_slice(costs);
        self.decs.extend_from_slice(decs);
        self.row_start.push(self.ms.len() as u64);
        self.row_first_m.push(ms.first().copied().unwrap_or(u32::MAX));
        self.row_min_cost.push(costs.last().copied().unwrap_or(f64::INFINITY));
        Ok(())
    }

    fn mem_bytes(&self) -> usize {
        self.row_start.len() * 8
            + self.ms.len() * 4
            + self.costs.len() * 8
            + self.decs.len() * 2
            + self.row_first_m.len() * 4
            + self.row_min_cost.len() * 8
    }
}

/// The pre-PR dense layout: one f64 + u16 per `(s, t, m)`, kept as the
/// executable specification the compressed fill is verified against.
pub(crate) struct DenseStore {
    pub(crate) n: usize,
    pub(crate) slots: usize,
    pub(crate) cost: Vec<f64>,
    pub(crate) dec: Vec<u16>,
}

impl DenseStore {
    /// Triangular pair index for 1-based `s ≤ t`.
    #[inline]
    fn pair(&self, s: usize, t: usize) -> usize {
        debug_assert!(1 <= s && s <= t && t <= self.n);
        (t - 1) * t / 2 + (s - 1)
    }

    #[inline]
    fn idx(&self, s: usize, t: usize, m: u32) -> usize {
        self.pair(s, t) * (self.slots + 1) + m as usize
    }

    /// Cost row of one `(s, t)` cell: contiguous over the m axis.
    #[inline]
    fn row(&self, s: usize, t: usize) -> &[f64] {
        let base = self.pair(s, t) * (self.slots + 1);
        &self.cost[base..base + self.slots + 1]
    }

    /// Write a whole `(s, t)` cell at once (parallel fill writeback).
    fn write_row(&mut self, s: usize, t: usize, cost: &[f64], dec: &[u16]) {
        let base = self.pair(s, t) * (self.slots + 1);
        self.cost[base..base + self.slots + 1].copy_from_slice(cost);
        self.dec[base..base + self.slots + 1].copy_from_slice(dec);
    }

    #[inline]
    fn set(&mut self, s: usize, t: usize, m: u32, cost: f64, dec: u16) {
        let i = self.idx(s, t, m);
        self.cost[i] = cost;
        self.dec[i] = dec;
    }
}

enum Store {
    Frontier(FrontierStore),
    Dense(DenseStore),
}

/// Packed triangular DP table: cost and decision for every `(s, t, m)`.
/// Backed by frontier-compressed rows (the production fill) or the dense
/// reference layout; both answer the same point queries.
pub struct DpTable {
    n: usize,
    slots: usize,
    store: Store,
}

impl DpTable {
    /// Reject `(stages, slots)` combinations the table cannot represent:
    /// more stages than the u16 decision encoding addresses, or a
    /// worst-case compressed footprint past [`MAX_TABLE_BYTES`]. The
    /// worst case is the *provable* per-row ceiling of `S + 1` runs (run
    /// starts are distinct slot values), so a fill that passes this check
    /// can never run away — real rows are far smaller, so admission is
    /// conservative by design: a rejection is deterministic at request
    /// time instead of an allocator surprise mid-fill.
    pub fn preflight(n: usize, slots: usize) -> ApiResult<()> {
        if n == 0 {
            return Err(Error::invalid("DP table needs at least one stage"));
        }
        if n > u16::MAX as usize {
            return Err(Error::invalid(format!(
                "chain of {n} stages exceeds the solver's limit of {} \
                 (u16 split encoding)",
                u16::MAX
            )));
        }
        let cells = (n as u128) * (n as u128 + 1) / 2;
        let runs = cells * (slots as u128 + 1);
        let bytes = runs * RUN_BYTES + cells * ROW_BYTES + 8;
        if bytes > MAX_TABLE_BYTES {
            return Err(Error::invalid(format!(
                "DP table for {n} stages at {slots} slots could need \
                 ~{} MiB, over the {} MiB solver ceiling — reduce the \
                 slot count or split the chain",
                bytes >> 20,
                MAX_TABLE_BYTES >> 20
            )));
        }
        Ok(())
    }

    /// An empty frontier-compressed table for `n` stages and `slots`
    /// slots, ready for the fill. Fails (kind-tagged, maps to HTTP 422)
    /// instead of aborting when the request is beyond [`preflight`]'s
    /// capacity limits or the row index cannot be allocated.
    ///
    /// [`preflight`]: DpTable::preflight
    pub fn try_new(n: usize, slots: usize) -> ApiResult<DpTable> {
        Self::preflight(n, slots)?;
        Ok(DpTable { n, slots, store: Store::Frontier(FrontierStore::with_capacity(n)?) })
    }

    /// An infinity-initialized dense reference table (same capacity
    /// checks; the dense footprint is exact, not worst-case).
    pub fn try_new_dense(n: usize, slots: usize) -> ApiResult<DpTable> {
        if n == 0 {
            return Err(Error::invalid("DP table needs at least one stage"));
        }
        if n > u16::MAX as usize {
            return Err(Error::invalid(format!(
                "chain of {n} stages exceeds the solver's limit of {} \
                 (u16 split encoding)",
                u16::MAX
            )));
        }
        let cells = (n as u128) * (n as u128 + 1) / 2 * (slots as u128 + 1);
        if cells * 10 > MAX_TABLE_BYTES {
            return Err(Error::invalid(format!(
                "dense DP table for {n} stages at {slots} slots needs \
                 ~{} MiB, over the {} MiB solver ceiling",
                cells * 10 >> 20,
                MAX_TABLE_BYTES >> 20
            )));
        }
        let len = cells as usize;
        let mut cost = Vec::new();
        let mut dec = Vec::new();
        let oom = |e| Error::invalid(format!("dense DP table allocation failed: {e}"));
        cost.try_reserve_exact(len).map_err(oom)?;
        dec.try_reserve_exact(len).map_err(oom)?;
        cost.resize(len, f64::INFINITY);
        dec.resize(len, DEC_INFEASIBLE);
        Ok(DpTable { n, slots, store: Store::Dense(DenseStore { n, slots, cost, dec }) })
    }

    #[inline]
    pub fn cost(&self, s: usize, t: usize, m: u32) -> f64 {
        match &self.store {
            Store::Frontier(f) => f.runs(s, t).cost_at(m),
            Store::Dense(d) => d.cost[d.idx(s, t, m)],
        }
    }

    #[inline]
    fn dec_code(&self, s: usize, t: usize, m: u32) -> u16 {
        match &self.store {
            Store::Frontier(f) => f.runs(s, t).dec_at(m),
            Store::Dense(d) => d.dec[d.idx(s, t, m)],
        }
    }

    /// The optimal first move at `(s, t, m)`.
    pub fn decision(&self, s: usize, t: usize, m: u32) -> Decision {
        match self.dec_code(s, t, m) {
            DEC_INFEASIBLE => Decision::Infeasible,
            DEC_ALL => Decision::TapeAll,
            k => Decision::Split(s + k as usize - 1),
        }
    }

    /// Number of stages `L+1` the table covers.
    pub fn stages(&self) -> usize {
        self.n
    }

    /// Upper bound of the table's slot axis (budgets `0..=slots`).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Whether this table uses the frontier-compressed layout.
    pub fn is_compressed(&self) -> bool {
        matches!(self.store, Store::Frontier(_))
    }

    /// Total stored runs (frontier layout) or m-axis entries (dense) —
    /// the compression diagnostic `bench_solver` reports.
    pub fn run_count(&self) -> usize {
        match &self.store {
            Store::Frontier(f) => f.ms.len(),
            Store::Dense(d) => d.cost.len(),
        }
    }

    /// Actual heap footprint — compressed, for frontier tables — used by
    /// the planner cache's byte budget.
    pub fn mem_bytes(&self) -> usize {
        match &self.store {
            Store::Frontier(f) => f.mem_bytes(),
            Store::Dense(d) => d.cost.len() * 10,
        }
    }

    /// Algorithm 2 at the whole-chain root: the op sequence for slot
    /// budget `m` (the caller has already charged `ω_a^0`), or `None`
    /// when `(1, L+1, m)` is infeasible.
    pub fn ops_at(&self, dc: &DiscreteChain, m: u32) -> Option<Vec<Op>> {
        assert!(m as usize <= self.slots, "budget beyond the table's slot axis");
        if !self.cost(1, self.n, m).is_finite() {
            return None;
        }
        let mut ops = Vec::new();
        reconstruct(self, dc, 1, self.n, m, &mut ops);
        Some(ops)
    }

    // -- internal store access for the on-disk persistence layer
    //    (`super::persist`); not part of the public API -----------------

    pub(crate) fn store_frontier(&self) -> Option<&FrontierStore> {
        match &self.store {
            Store::Frontier(f) => Some(f),
            Store::Dense(_) => None,
        }
    }

    pub(crate) fn store_dense(&self) -> Option<&DenseStore> {
        match &self.store {
            Store::Dense(d) => Some(d),
            Store::Frontier(_) => None,
        }
    }

    /// Rebuild a table from a deserialized frontier store. The caller
    /// (the persist layer) has already validated structural invariants
    /// and the checksum; `n`/`slots` must match the store's geometry.
    pub(crate) fn from_frontier(n: usize, slots: usize, store: FrontierStore) -> DpTable {
        DpTable { n, slots, store: Store::Frontier(store) }
    }

    /// Rebuild a table from a deserialized dense store.
    pub(crate) fn from_dense(n: usize, slots: usize, store: DenseStore) -> DpTable {
        DpTable { n, slots, store: Store::Dense(store) }
    }
}

// ---------------------------------------------------------------------------
// The compressed, pruned fill.
// ---------------------------------------------------------------------------

/// Full DP solve over a discretized chain. The table covers every
/// `(s, t, m)`, so one solve supports reconstruction at any budget `≤ M`.
///
/// Uses every available core for the wavefront fill; see
/// [`solve_table_with_workers`] for an explicit worker count (the
/// regression suite pins `workers = 1` to prove the parallel fill is
/// bit-identical to the serial one). Panics on capacity errors; use
/// [`try_solve_table`] to surface them.
pub fn solve_table(dc: &DiscreteChain, mode: Mode) -> DpTable {
    try_solve_table(dc, mode).unwrap_or_else(|e| panic!("DP fill failed: {e:#}"))
}

/// [`solve_table`], but over-capacity chains return a kind-tagged
/// [`Error`] (the planning service maps it to HTTP 422) instead of
/// panicking.
pub fn try_solve_table(dc: &DiscreteChain, mode: Mode) -> ApiResult<DpTable> {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    try_solve_table_with_workers(dc, mode, workers)
}

/// [`solve_table`] with a pinned worker count. `workers <= 1` forces the
/// serial fill; larger counts chunk each anti-diagonal across scoped
/// threads. The result is bit-identical regardless of `workers`: cells
/// on one diagonal depend only on strictly shorter sub-chains, each cell
/// is computed in isolation ([`fill_cell`]), and rows are appended to the
/// arena in the deterministic diagonal order either way.
pub fn solve_table_with_workers(dc: &DiscreteChain, mode: Mode, workers: usize) -> DpTable {
    try_solve_table_with_workers(dc, mode, workers)
        .unwrap_or_else(|e| panic!("DP fill failed: {e:#}"))
}

/// Fallible form of [`solve_table_with_workers`].
pub fn try_solve_table_with_workers(
    dc: &DiscreteChain,
    mode: Mode,
    workers: usize,
) -> ApiResult<DpTable> {
    // Observability only: counters and wall-clock around the fill. No
    // instrumentation touches the float math, so bit-parity with the
    // dense reference (which stays uninstrumented) is preserved.
    let reg = crate::telemetry::registry();
    let fill_t0 = std::time::Instant::now();
    let mut cells_filled = 0u64;
    let mut prune_hits = 0u64;

    let n = dc.len();
    let slots = dc.slots;
    let mut tab = DpTable::try_new(n, slots)?;
    let Store::Frontier(store) = &mut tab.store else { unreachable!() };
    let peaks = dc.peaks();

    // Prefix sums of u_f for O(1) Σ u_f^{s..s'-1}.
    let mut uf_prefix = vec![0.0f64; n + 1];
    for l in 1..=n {
        uf_prefix[l] = uf_prefix[l - 1] + dc.uf_s(l);
    }

    // Base case (eq. 1): C(s,s,m) = u_f + u_b  iff  m ≥ m_all^{s,s} —
    // a single run (or an empty, everywhere-infeasible row).
    for s in 1..=n {
        let need = peaks.m_all(s, s);
        if u64::from(need) <= slots as u64 {
            store.append_row(&[need], &[dc.uf_s(s) + dc.ub_s(s)], &[DEC_ALL])?;
        } else {
            store.append_row(&[], &[], &[])?;
        }
        cells_filled += 1;
    }

    // General case by increasing sub-chain length d = t - s (eq. 2).
    // Cells on one diagonal depend only on strictly shorter sub-chains,
    // so each diagonal is filled in parallel (scoped threads; no rayon in
    // the offline build) and appended serially in cell order.
    for d in 1..n {
        let diag_t0 = std::time::Instant::now();
        let ts: Vec<usize> = ((d + 1)..=n).collect();
        let chunks: Vec<ChunkRows> = if ts.len() < 2 || workers < 2 {
            vec![fill_chunk(store, dc, &peaks, &uf_prefix, &ts, d, mode)]
        } else {
            let chunk = ts.len().div_ceil(workers);
            std::thread::scope(|scope| {
                let store_ref = &*store;
                let (peaks_ref, uf_ref) = (&peaks, &uf_prefix);
                let handles: Vec<_> = ts
                    .chunks(chunk)
                    .map(|part| {
                        scope.spawn(move || {
                            fill_chunk(store_ref, dc, peaks_ref, uf_ref, part, d, mode)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };
        for ch in &chunks {
            cells_filled += ch.lens.len() as u64;
            prune_hits += ch.prune_hits;
            let mut off = 0usize;
            for &len in &ch.lens {
                let end = off + len as usize;
                store.append_row(&ch.ms[off..end], &ch.costs[off..end], &ch.decs[off..end])?;
                off = end;
            }
        }
        reg.solver_diagonals.inc();
        reg.solver_diagonal_fill_us.observe(diag_t0.elapsed().as_micros() as u64);
    }
    reg.solver_cells_filled.add(cells_filled);
    reg.solver_prune_hits.add(prune_hits);
    reg.solver_runs_emitted.add(store.ms.len() as u64);
    reg.solver_fill_ns.add(fill_t0.elapsed().as_nanos() as u64);
    Ok(tab)
}

/// Rows produced by one worker's slice of an anti-diagonal, concatenated
/// (`lens[i]` runs per row, in `t` order), plus the worker's dominance-
/// prune count (summed into the telemetry registry at the serial merge
/// so workers never touch shared counters mid-fill).
struct ChunkRows {
    lens: Vec<u32>,
    ms: Vec<u32>,
    costs: Vec<f64>,
    decs: Vec<u16>,
    prune_hits: u64,
}

/// A row under construction: sorted runs with `(cost bits, dec)` dedup.
#[derive(Default)]
struct RowBuf {
    ms: Vec<u32>,
    costs: Vec<f64>,
    decs: Vec<u16>,
}

impl RowBuf {
    fn clear(&mut self) {
        self.ms.clear();
        self.costs.clear();
        self.decs.clear();
    }

    #[inline]
    fn push(&mut self, m: u32, cost: f64, dec: u16) {
        if let (Some(&lc), Some(&ld)) = (self.costs.last(), self.decs.last()) {
            if lc.to_bits() == cost.to_bits() && ld == dec {
                return; // same run continues
            }
            debug_assert!(*self.ms.last().unwrap() < m, "runs must advance");
            debug_assert!(cost <= lc, "rows are non-increasing");
        }
        self.ms.push(m);
        self.costs.push(cost);
        self.decs.push(dec);
    }

    /// Row value at slot `m` (∞ below the first run).
    fn eval(&self, m: u32) -> f64 {
        let i = self.ms.partition_point(|&x| x <= m);
        if i == 0 {
            f64::INFINITY
        } else {
            self.costs[i - 1]
        }
    }
}

/// A candidate step function under construction (uniform decision, so
/// dedup keys on cost bits alone).
#[derive(Default)]
struct CandBuf {
    ms: Vec<u32>,
    costs: Vec<f64>,
}

impl CandBuf {
    fn clear(&mut self) {
        self.ms.clear();
        self.costs.clear();
    }

    #[inline]
    fn push(&mut self, m: u32, cost: f64) {
        if let Some(&lc) = self.costs.last() {
            if lc.to_bits() == cost.to_bits() {
                return;
            }
            debug_assert!(*self.ms.last().unwrap() < m);
        }
        self.ms.push(m);
        self.costs.push(cost);
    }
}

/// Per-thread scratch reused across a chunk's cells.
#[derive(Default)]
struct Scratch {
    best: RowBuf,
    out: RowBuf,
    cand: CandBuf,
    /// Candidates discarded by the O(1) dominance bound (telemetry).
    prune_hits: u64,
}

fn fill_chunk(
    store: &FrontierStore,
    dc: &DiscreteChain,
    peaks: &PeakOracle<'_>,
    uf_prefix: &[f64],
    ts: &[usize],
    d: usize,
    mode: Mode,
) -> ChunkRows {
    let mut scratch = Scratch::default();
    let mut out = ChunkRows {
        lens: Vec::with_capacity(ts.len()),
        ms: Vec::new(),
        costs: Vec::new(),
        decs: Vec::new(),
        prune_hits: 0,
    };
    for &t in ts {
        fill_cell(store, dc, peaks, uf_prefix, t - d, t, mode, &mut scratch);
        out.lens.push(idx32(scratch.best.ms.len() as u64));
        out.ms.extend_from_slice(&scratch.best.ms);
        out.costs.extend_from_slice(&scratch.best.costs);
        out.decs.extend_from_slice(&scratch.best.decs);
    }
    out.prune_hits = scratch.prune_hits;
    out
}

/// Fill one `(s, t)` cell across the whole m axis (eq. 2), producing the
/// row in `scratch.best`. Candidates are applied in the reference fill's
/// order (splits `s' = s+1..=t` ascending, then `Fall`), each one either
/// skipped by the exact dominance bound or folded in by a breakpoint
/// merge with strict-improvement wins — so the resulting `(cost, dec)`
/// function is bit-identical to the dense scan's.
#[allow(clippy::too_many_arguments)]
fn fill_cell(
    store: &FrontierStore,
    dc: &DiscreteChain,
    peaks: &PeakOracle<'_>,
    uf_prefix: &[f64],
    s: usize,
    t: usize,
    mode: Mode,
    scratch: &mut Scratch,
) {
    let slots = dc.slots as u64;
    scratch.best.clear();

    // C1: Fck^s, F∅^{s+1..s'-1}, recurse (s',t) with m−ω_a^{s'-1} and
    // (s,s'-1) with m.
    let m_nosave = peaks.m_empty(s, t);
    for sp in (s + 1)..=t {
        let hold = dc.wa_s(sp - 1); // a^{s'-1} stays resident
        // feasibility frontier: the earliest slot where both child rows
        // exist and the sweep fits (u64 math so empty-row sentinels and
        // saturated sizes cannot wrap)
        let start = (m_nosave as u64)
            .max(hold as u64)
            .max(store.first_m(s, sp - 1) as u64)
            .max(store.first_m(sp, t) as u64 + hold as u64);
        if start > slots {
            continue;
        }
        let start = idx32(start);
        let pre = uf_prefix[sp - 1] - uf_prefix[s - 1];
        // dominance: the candidate can never drop below this bound (same
        // float association as the reference fill; f64 add is monotone),
        // and the incumbent row never rises above its value at `start` —
        // so a failed strict inequality here is a failed strict
        // inequality at every budget.
        let cand_min = (pre + store.min_cost(sp, t)) + store.min_cost(s, sp - 1);
        if !(cand_min < scratch.best.eval(start)) {
            scratch.prune_hits += 1;
            continue;
        }
        let left = store.runs(s, sp - 1);
        let right = store.runs(sp, t);
        scratch.cand.clear();
        let mut li = left.index_at(start);
        let mut ri = right.index_at(start - hold);
        let mut m = start;
        loop {
            scratch.cand.push(m, (pre + right.costs[ri]) + left.costs[li]);
            let nl = if li + 1 < left.ms.len() { left.ms[li + 1] as u64 } else { u64::MAX };
            let nr = if ri + 1 < right.ms.len() {
                right.ms[ri + 1] as u64 + hold as u64
            } else {
                u64::MAX
            };
            let nxt = nl.min(nr);
            if nxt > slots {
                break;
            }
            if nl == nxt {
                li += 1;
            }
            if nr == nxt {
                ri += 1;
            }
            m = idx32(nxt);
        }
        merge_candidate(&mut scratch.best, &mut scratch.out, &scratch.cand, split16(sp - s + 1));
    }

    // C2: Fall^s, recurse (s+1,t) with m−ω_ā^s, B^s. (Absent in AD mode.)
    if mode == Mode::Full && t > s {
        let habar = dc.wabar_s(s);
        let start = (peaks.m_all(s, t) as u64)
            .max(habar as u64)
            .max(store.first_m(s + 1, t) as u64 + habar as u64);
        if start <= slots {
            let start = idx32(start);
            let fixed = dc.uf_s(s) + dc.ub_s(s);
            let cand_min = fixed + store.min_cost(s + 1, t);
            if !(cand_min < scratch.best.eval(start)) {
                scratch.prune_hits += 1;
            } else {
                let mid = store.runs(s + 1, t);
                scratch.cand.clear();
                let mut mi = mid.index_at(start - habar);
                let mut m = start;
                loop {
                    scratch.cand.push(m, fixed + mid.costs[mi]);
                    if mi + 1 >= mid.ms.len() {
                        break;
                    }
                    let nxt = mid.ms[mi + 1] as u64 + habar as u64;
                    if nxt > slots {
                        break;
                    }
                    mi += 1;
                    m = idx32(nxt);
                }
                merge_candidate(&mut scratch.best, &mut scratch.out, &scratch.cand, DEC_ALL);
            }
        }
    }
}

/// Fold a candidate into the incumbent row: below the candidate's first
/// feasible slot the incumbent is copied verbatim; from there on, events
/// (either function's breakpoints) are walked in order and the winner at
/// each event is emitted — the candidate only on a *strict* improvement,
/// matching the reference fill's first-in-order tie-breaking.
fn merge_candidate(best: &mut RowBuf, out: &mut RowBuf, cand: &CandBuf, code: u16) {
    let start = cand.ms[0];
    out.clear();
    let mut bi = 0usize;
    while bi < best.ms.len() && best.ms[bi] < start {
        out.push(best.ms[bi], best.costs[bi], best.decs[bi]);
        bi += 1;
    }
    // `bact` = index of the incumbent run covering the current event
    let mut bact: Option<usize> = bi.checked_sub(1);
    let mut ci = 0usize;
    let mut m = start;
    loop {
        while bi < best.ms.len() && best.ms[bi] <= m {
            bact = Some(bi);
            bi += 1;
        }
        let bcost = bact.map_or(f64::INFINITY, |i| best.costs[i]);
        let ccost = cand.costs[ci];
        if ccost < bcost {
            out.push(m, ccost, code);
        } else {
            // candidate values are finite, so an incumbent run exists here
            let i = bact.expect("incumbent must cover any non-winning event");
            out.push(m, best.costs[i], best.decs[i]);
        }
        let nb = if bi < best.ms.len() { best.ms[bi] as u64 } else { u64::MAX };
        let nc = if ci + 1 < cand.ms.len() { cand.ms[ci + 1] as u64 } else { u64::MAX };
        let nxt = nb.min(nc);
        if nxt == u64::MAX {
            break;
        }
        if nc == nxt {
            ci += 1;
        }
        m = idx32(nxt);
    }
    std::mem::swap(best, out);
}

// ---------------------------------------------------------------------------
// The dense reference fill (pre-PR semantics, retained as the spec).
// ---------------------------------------------------------------------------

/// The reference dense fill: plain m-axis scans, per-cell threshold
/// re-scans, no pruning — exactly the pre-frontier semantics, kept as the
/// executable specification. `tests/dp_fill_parity.rs` pins the
/// compressed fill bit-identical to this; `bench_solver`'s L = 1000 gate
/// measures the speedup against it.
pub fn solve_table_dense(dc: &DiscreteChain, mode: Mode) -> DpTable {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    solve_table_dense_with_workers(dc, mode, workers)
}

/// [`solve_table_dense`] with a pinned worker count (same bit-identity
/// guarantee across counts as the compressed fill).
pub fn solve_table_dense_with_workers(
    dc: &DiscreteChain,
    mode: Mode,
    workers: usize,
) -> DpTable {
    let n = dc.len();
    let slots = dc.slots;
    let mut tab = DpTable::try_new_dense(n, slots)
        .unwrap_or_else(|e| panic!("dense DP fill failed: {e:#}"));
    let Store::Dense(store) = &mut tab.store else { unreachable!() };

    let mut uf_prefix = vec![0.0f64; n + 1];
    for l in 1..=n {
        uf_prefix[l] = uf_prefix[l - 1] + dc.uf_s(l);
    }

    // Base case (eq. 1): C(s,s,m) = u_f + u_b  iff  m ≥ m_all^{s,s}.
    for s in 1..=n {
        let need = m_all(dc, s, s);
        let cost = dc.uf_s(s) + dc.ub_s(s);
        for m in 0..=idx32(slots as u64) {
            if m >= need {
                store.set(s, s, m, cost, DEC_ALL);
            }
        }
    }

    for d in 1..n {
        let cells: Vec<usize> = ((d + 1)..=n).collect(); // t values; s = t - d
        let results: Vec<(usize, Vec<f64>, Vec<u16>)> = if cells.len() < 2 || workers < 2 {
            cells
                .iter()
                .map(|&t| {
                    let (c, dec) = fill_cell_dense(store, dc, &uf_prefix, t - d, t, mode);
                    (t, c, dec)
                })
                .collect()
        } else {
            let chunk = cells.len().div_ceil(workers);
            std::thread::scope(|scope| {
                let store_ref = &*store;
                let uf_ref = &uf_prefix;
                let handles: Vec<_> = cells
                    .chunks(chunk)
                    .map(|part| {
                        scope.spawn(move || {
                            part.iter()
                                .map(|&t| {
                                    let (c, dec) =
                                        fill_cell_dense(store_ref, dc, uf_ref, t - d, t, mode);
                                    (t, c, dec)
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            })
        };
        for (t, cost, dec) in results {
            store.write_row(t - d, t, &cost, &dec);
        }
    }
    tab
}

/// Fill one `(s, t)` cell across the whole m axis (eq. 2), dense form.
///
/// Infinity propagates through the adds, so no explicit feasibility
/// branches are needed in the inner loops: `∞ < best` is always false.
fn fill_cell_dense(
    store: &DenseStore,
    dc: &DiscreteChain,
    uf_prefix: &[f64],
    s: usize,
    t: usize,
    mode: Mode,
) -> (Vec<f64>, Vec<u16>) {
    let slots = dc.slots;
    let mut best = vec![f64::INFINITY; slots + 1];
    let mut dec = vec![DEC_INFEASIBLE; slots + 1];

    let m_nosave = m_empty(dc, s, t) as usize;
    for sp in (s + 1)..=t {
        let hold = dc.wa_s(sp - 1) as usize; // a^{s'-1} stays resident
        let pre = uf_prefix[sp - 1] - uf_prefix[s - 1];
        let left = store.row(s, sp - 1);
        let right = store.row(sp, t);
        let code = split16(sp - s + 1);
        let start = m_nosave.max(hold);
        if start > slots {
            continue;
        }
        for m in start..=slots {
            let c = pre + right[m - hold] + left[m];
            if c < best[m] {
                best[m] = c;
                dec[m] = code;
            }
        }
    }

    if mode == Mode::Full {
        let m_all_st = m_all(dc, s, t) as usize;
        let habar = dc.wabar_s(s) as usize;
        let fixed = dc.uf_s(s) + dc.ub_s(s);
        let mid = store.row(s + 1, t);
        let start = m_all_st.max(habar);
        if start <= slots {
            for m in start..=slots {
                let c = fixed + mid[m - habar];
                if c < best[m] {
                    best[m] = c;
                    dec[m] = DEC_ALL;
                }
            }
        }
    }
    (best, dec)
}

/// `m∅^{s,t}` by the reference O(t−s) scan (dense fill only; the
/// compressed fill uses [`PeakOracle::m_empty`], pinned equal).
fn m_empty(dc: &DiscreteChain, s: usize, t: usize) -> u32 {
    let wd_t = dc.wd_s(t);
    let mut peak = wd_t + dc.wa_s(s) + dc.of_s(s);
    for j in (s + 1)..t {
        peak = peak.max(wd_t + dc.wa_s(j - 1) + dc.wa_s(j) + dc.of_s(j));
    }
    peak
}

/// `m_all^{s,t}`: slots needed to run `Fall^s` (with `δ^t` resident) and
/// later `B^s` (with `δ^s` resident).
fn m_all(dc: &DiscreteChain, s: usize, t: usize) -> u32 {
    let fwd = dc.wd_s(t) + dc.wabar_s(s) + dc.of_s(s);
    let bwd = dc.wd_s(s) + dc.wabar_s(s) + dc.ob_s(s);
    fwd.max(bwd)
}

// ---------------------------------------------------------------------------
// Reconstruction (Algorithm 2).
// ---------------------------------------------------------------------------

/// Algorithm 2: reconstruct the optimal sequence from the table. Valid at
/// *any* slot budget `m`, not just the one a solve was requested at — the
/// table covers the whole `(s, t, m)` space (the planner relies on this).
///
/// Iterative with an explicit work stack: the recursion depth of the
/// naive form is Θ(L) (a store-all schedule nests one level per stage),
/// which overflows a thread stack at the depth-10⁴ chains the compressed
/// fill makes solvable.
pub(crate) fn reconstruct(
    tab: &DpTable,
    dc: &DiscreteChain,
    s: usize,
    t: usize,
    m: u32,
    ops: &mut Vec<Op>,
) {
    enum Task {
        Cell { s: usize, t: usize, m: u32 },
        Emit(Op),
    }
    let mut stack = vec![Task::Cell { s, t, m }];
    while let Some(task) = stack.pop() {
        let (s, t, m) = match task {
            Task::Emit(op) => {
                ops.push(op);
                continue;
            }
            Task::Cell { s, t, m } => (s, t, m),
        };
        match tab.dec_code(s, t, m) {
            DEC_INFEASIBLE => unreachable!("reconstruct called on infeasible cell"),
            DEC_ALL if s == t => {
                ops.push(Op::FwdAll(stage32(s)));
                ops.push(Op::Bwd(stage32(s)));
            }
            DEC_ALL => {
                ops.push(Op::FwdAll(stage32(s)));
                stack.push(Task::Emit(Op::Bwd(stage32(s))));
                stack.push(Task::Cell { s: s + 1, t, m: m - dc.wabar_s(s) });
            }
            k => {
                let sp = s + (k as usize - 1);
                ops.push(Op::FwdCk(stage32(s)));
                for j in (s + 1)..sp {
                    ops.push(Op::FwdNoSave(stage32(j)));
                }
                // LIFO: the (s', t) sub-problem runs first, then (s, s'-1)
                stack.push(Task::Cell { s, t: sp - 1, m });
                stack.push(Task::Cell { s: sp, t, m: m - dc.wa_s(sp - 1) });
            }
        }
    }
}

/// One full solve: discretize against `memory`, fill (or fetch from the
/// planner cache) the table, reconstruct at the top budget `M − ω_a^0`.
/// Returns `None` when no persistent schedule fits.
///
/// This is now a thin compatibility wrapper over [`super::Planner`]: a
/// planner built at `memory` answers its own top budget, which is exactly
/// the historical `solve` semantics (same discretization, same table,
/// same reconstruction — and repeated solves of the same profile hit the
/// cache instead of re-running the DP). Note the footprint trade-off:
/// the table (tens of MB for long chains) may stay resident in the
/// process-global LRU cache instead of being dropped on return; call
/// [`super::clear_cache`] to reclaim it. Sweeping many budgets over one
/// chain should construct a single `Planner` instead of calling this in
/// a loop.
pub fn solve(chain: &Chain, memory: u64, slots: usize, mode: Mode) -> Option<Schedule> {
    super::Planner::new(chain, memory, slots, mode).schedule_at(memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::{Stage, DEFAULT_SLOTS};

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    /// A deliberately heterogeneous chain (varying sizes, times, and
    /// overheads) for fill-parity checks.
    fn hetero(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (0..n)
            .map(|i| {
                let wa = 60 + 41 * ((i * i + 5) % 13) as u64;
                let wabar = wa * (1 + (i % 5) as u64);
                let uf = 1.0 + (i % 7) as f64 * 0.7;
                let mut st = Stage::new(format!("s{i}"), uf, uf * 1.6, wa, wabar);
                if i % 4 == 0 {
                    st = st.with_overheads(wa / 3, wa / 2);
                }
                st
            })
            .collect();
        stages.push(Stage::new("loss", 0.2, 0.2, 4, 4));
        Chain::new("hetero", stages, 150)
    }

    #[test]
    fn unlimited_memory_is_store_all_time() {
        let c = toy(6);
        let s = solve(&c, 1 << 30, DEFAULT_SLOTS, Mode::Full).unwrap();
        assert!((s.predicted_time - c.ideal_time()).abs() < 1e-9);
        // With memory to spare the optimal schedule tapes everything:
        // no recomputation at all.
        assert_eq!(s.recomputation_ops(c.len()), 0);
        // And it is exactly Fall^1.. Fall^{L+1} B^{L+1}.. B^1.
        let n = c.len() as u32;
        for (i, op) in s.ops.iter().take(c.len()).enumerate() {
            assert_eq!(*op, Op::FwdAll(i as u32 + 1));
        }
        for (i, op) in s.ops.iter().skip(c.len()).enumerate() {
            assert_eq!(*op, Op::Bwd(n - i as u32));
        }
    }

    #[test]
    fn no_memory_is_infeasible() {
        let c = toy(4);
        assert!(solve(&c, 64, DEFAULT_SLOTS, Mode::Full).is_none());
    }

    #[test]
    fn cost_monotone_in_memory() {
        let c = toy(8);
        let lo = c.min_memory_hint();
        let hi = c.store_all_memory() + c.wa0;
        let mut last = f64::INFINITY;
        for i in 0..10 {
            let m = lo + (hi - lo) * i / 9;
            if let Some(s) = solve(&c, m, 200, Mode::Full) {
                assert!(
                    s.predicted_time <= last + 1e-9,
                    "cost must not increase with memory: {} then {}",
                    last,
                    s.predicted_time
                );
                last = s.predicted_time;
            }
        }
        assert!(last.is_finite(), "largest budget must be feasible");
    }

    #[test]
    fn tight_memory_forces_recomputation() {
        let c = toy(8);
        let m = (c.store_all_memory() + c.wa0) / 3;
        let s = solve(&c, m, DEFAULT_SLOTS, Mode::Full).unwrap();
        assert!(s.recomputation_ops(c.len()) > 0);
        assert!(s.predicted_time > c.ideal_time());
    }

    #[test]
    fn revolve_never_beats_full_model() {
        let c = toy(8);
        let lo = c.min_memory_hint() * 2;
        let hi = c.store_all_memory() + c.wa0;
        for i in 0..6 {
            let m = lo + (hi - lo) * i / 5;
            let full = solve(&c, m, 300, Mode::Full);
            let rev = solve(&c, m, 300, Mode::AdRevolve);
            if let (Some(f), Some(r)) = (full, rev) {
                assert!(
                    f.predicted_time <= r.predicted_time + 1e-9,
                    "m={m}: full {} > revolve {}",
                    f.predicted_time,
                    r.predicted_time
                );
            }
        }
    }

    #[test]
    fn revolve_recomputes_every_backward_target() {
        // In the AD model every B^ℓ is preceded by its own Fall^ℓ, so each
        // stage's forward runs at least twice (except possibly stage s of
        // the outermost base case).
        let c = toy(5);
        let s = solve(&c, c.store_all_memory() + c.wa0, 300, Mode::AdRevolve).unwrap();
        let n_fall = s.ops.iter().filter(|o| matches!(o, Op::FwdAll(_))).count();
        assert_eq!(n_fall, c.len(), "one Fall per backward");
        assert!(s.predicted_time >= c.ideal_time());
    }

    #[test]
    fn two_stage_manual_check() {
        // Chain: stage1 (uf=10, ub=1, wa=8, wabar=16), loss (uf=1, ub=1, wa=1, wabar=1),
        // input wa0=8. Unlimited memory: Fall^1 Fall^2 B^2 B^1 = 13.
        let c = Chain::new(
            "manual",
            vec![Stage::new("s1", 10.0, 1.0, 8, 16), Stage::new("loss", 1.0, 1.0, 1, 1)],
            8,
        );
        let s = solve(&c, 1 << 20, 100, Mode::Full).unwrap();
        assert_eq!(s.predicted_time, 13.0);
        assert_eq!(
            s.ops,
            vec![Op::FwdAll(1), Op::FwdAll(2), Op::Bwd(2), Op::Bwd(1)]
        );
    }

    #[test]
    fn table_supports_any_budget() {
        let c = toy(5);
        let dc = DiscreteChain::new(&c, 1 << 22, 100);
        let tab = solve_table(&dc, Mode::Full);
        let n = dc.len();
        // cost at m is non-increasing along the m axis
        let mut last = f64::INFINITY;
        for m in 0..=dc.slots as u32 {
            let cst = tab.cost(1, n, m);
            assert!(cst <= last + 1e-9);
            if cst.is_finite() {
                last = cst;
            }
        }
    }

    #[test]
    fn compressed_fill_is_bit_identical_to_dense_reference() {
        for chain in [toy(7), hetero(11)] {
            let memory = chain.store_all_memory() + chain.wa0;
            let dc = DiscreteChain::new(&chain, memory, 90);
            for mode in [Mode::Full, Mode::AdRevolve] {
                let fast = solve_table(&dc, mode);
                let dense = solve_table_dense(&dc, mode);
                assert!(fast.is_compressed() && !dense.is_compressed());
                for t in 1..=dc.len() {
                    for s in 1..=t {
                        for m in 0..=dc.slots as u32 {
                            assert_eq!(
                                fast.cost(s, t, m).to_bits(),
                                dense.cost(s, t, m).to_bits(),
                                "{mode:?}: cost({s},{t},{m})"
                            );
                            assert_eq!(
                                fast.decision(s, t, m),
                                dense.decision(s, t, m),
                                "{mode:?}: dec({s},{t},{m})"
                            );
                        }
                    }
                }
                assert!(
                    fast.mem_bytes() < dense.mem_bytes(),
                    "{mode:?}: compressed table ({} B) must undercut dense ({} B)",
                    fast.mem_bytes(),
                    dense.mem_bytes()
                );
            }
        }
    }

    #[test]
    fn compression_is_minimal_and_rows_are_nonincreasing() {
        // the stored run count must equal exactly the number of
        // `(cost bits, decision)` transitions a dense scan observes —
        // i.e. the compression is lossless *and* canonical
        let c = hetero(14);
        let dc = DiscreteChain::new(&c, c.store_all_memory() + c.wa0, 200);
        let tab = solve_table(&dc, Mode::Full);
        let mut want_runs = 0usize;
        for t in 1..=dc.len() {
            for s in 1..=t {
                let mut last = f64::INFINITY;
                let mut prev: Option<(u64, Decision)> = None;
                for m in 0..=dc.slots as u32 {
                    let cst = tab.cost(s, t, m);
                    assert!(cst <= last, "row ({s},{t}) must be non-increasing");
                    if cst.is_finite() {
                        last = cst;
                        let cur = (cst.to_bits(), tab.decision(s, t, m));
                        if prev != Some(cur) {
                            want_runs += 1;
                            prev = Some(cur);
                        }
                    }
                }
            }
        }
        assert_eq!(tab.run_count(), want_runs, "stored runs must be the minimal set");
    }

    #[test]
    fn deep_chain_reconstruction_uses_no_recursion_depth() {
        // 400 stages under tight memory: Algorithm 2's naive recursion
        // nests a frame per stage along the split/tape spine (Θ(L) deep —
        // fatal at the depth-10⁴ chains the compressed fill targets); the
        // work-stack version uses O(1) program stack regardless of depth.
        let n = 400usize;
        let mut stages: Vec<Stage> = (0..n - 1)
            .map(|i| Stage::new(format!("s{i}"), 1.0 + (i % 3) as f64, 2.0, 64, 128))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        let c = Chain::new("deep", stages, 64);
        let memory = c.store_all_memory() + c.wa0;
        let dc = DiscreteChain::new(&c, memory, 30);
        for mode in [Mode::Full, Mode::AdRevolve] {
            let tab = solve_table(&dc, mode);
            let top = dc.top_budget().expect("input fits");
            let ops = tab.ops_at(&dc, top).expect("top budget is feasible");
            let bwds = ops.iter().filter(|o| matches!(o, Op::Bwd(_))).count();
            assert_eq!(bwds, n, "{mode:?}: every stage backpropagated exactly once");
        }
    }

    #[test]
    fn try_new_rejects_over_capacity_requests() {
        // more stages than the u16 split encoding addresses
        let err = DpTable::preflight(70_000, 100).unwrap_err();
        assert!(err.to_string().contains("70000"), "message names the stage count: {err}");
        // a worst-case footprint past the ceiling, with both L and S named
        let err = DpTable::try_new(60_000, 5_000).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("60000") && msg.contains("5000"), "names L and S: {msg}");
        // dense has a (smaller) exact bound
        assert!(DpTable::try_new_dense(20_000, 500).is_err());
        // admission is worst-case-based: depth 10⁴ passes at a coarse
        // slot axis (the bench configuration) but not at S = 500
        assert!(DpTable::preflight(10_000, 16).is_ok());
        assert!(DpTable::preflight(10_000, 500).is_err());
        // the paper's regime (L = 336, S = 500) passes comfortably
        assert!(DpTable::try_new(337, 500).is_ok());
        assert!(DpTable::try_new_dense(337, 500).is_ok());
    }
}
