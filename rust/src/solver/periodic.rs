//! The `sequential` baseline: PyTorch's `checkpoint_sequential` [1],
//! implementing the sublinear-memory idea of Chen et al. [6].
//!
//! The compute chain `1..L` is split into `k` equal-length segments. The
//! forward phase stores only each segment's *input* (`Fck` at the segment
//! head, `F∅` inside) — except the **last** segment, which is taped
//! directly (the paper: "each forward computation is thus performed
//! twice, except those of the last segment"). During the backward phase
//! each earlier segment is re-run with `Fall` from its stored input just
//! before its backwards. The loss stage `L+1` is outside the segmented
//! container and always taped.
//!
//! Non-optimality (the point of the paper's comparison): the segment
//! layout is fixed up-front, so it cannot exploit the memory that frees
//! up as later segments finish their backwards.

use super::sequence::{Op, Schedule, StrategyKind};
use crate::chain::Chain;

/// A 1-based stage index in the op alphabet's `u32`. Chain lengths are
/// validated to a few thousand stages at construction, so the conversion
/// only fails on a corrupted length — surfaced as a panic naming it.
#[inline]
fn stage32(i: usize) -> u32 {
    u32::try_from(i).unwrap_or_else(|_| panic!("stage index {i} exceeds the u32 op alphabet"))
}

/// Balanced segment boundaries: `k` contiguous segments covering `1..=l`.
/// Returns `(start, end)` pairs, 1-based inclusive.
pub fn segment_bounds(l: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1 && k <= l, "need 1 <= k <= L (got k={k}, L={l})");
    let base = l / k;
    let extra = l % k; // first `extra` segments get one more stage
    let mut out = Vec::with_capacity(k);
    let mut start = 1;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((start, start + len - 1));
        start += len;
    }
    out
}

/// Builds the `checkpoint_sequential(k)` schedule for the chain.
/// `chain.len()` includes the loss stage, which is not segmented.
pub fn periodic_schedule(chain: &Chain, segments: usize) -> Schedule {
    let n = chain.len(); // L+1
    let l = n - 1; // segmented part
    assert!(l >= 1, "chain needs at least one compute stage before the loss");
    let k = segments.clamp(1, l);
    let bounds = segment_bounds(l, k);

    let mut ops = Vec::new();
    // Forward phase: checkpoint heads of segments 1..k-1, tape the last.
    for (i, &(b, e)) in bounds.iter().enumerate() {
        if i + 1 < k {
            ops.push(Op::FwdCk(stage32(b)));
            for j in (b + 1)..=e {
                ops.push(Op::FwdNoSave(stage32(j)));
            }
        } else {
            for j in b..=e {
                ops.push(Op::FwdAll(stage32(j)));
            }
        }
    }
    // Loss stage: tape + backward.
    ops.push(Op::FwdAll(stage32(n)));
    ops.push(Op::Bwd(stage32(n)));
    // Backward of the last (already taped) segment.
    let (bk, ek) = bounds[k - 1];
    for j in (bk..=ek).rev() {
        ops.push(Op::Bwd(stage32(j)));
    }
    // Earlier segments: re-run with taping from the stored input, then backward.
    for &(b, e) in bounds[..k - 1].iter().rev() {
        for j in b..=e {
            ops.push(Op::FwdAll(stage32(j)));
        }
        for j in (b..=e).rev() {
            ops.push(Op::Bwd(stage32(j)));
        }
    }

    // Predicted time: every stage once + segments 1..k-1 forwards again.
    let recompute: f64 = bounds[..k - 1]
        .iter()
        .flat_map(|&(b, e)| (b..=e).map(|j| chain.uf(j)))
        .sum();
    let time = chain.ideal_time() + recompute;
    Schedule::new(ops, StrategyKind::Periodic, time)
}

/// The segment counts the paper sweeps: 10 values from 2 to `2√L`
/// (always including 2), deduplicated and clamped to `[1, L]`.
pub fn paper_segment_sweep(l: usize) -> Vec<usize> {
    let hi = (2.0 * (l as f64).sqrt()).round().max(2.0) as usize;
    let mut out: Vec<usize> = Vec::new();
    for i in 0..10 {
        let v = 2 + (hi.saturating_sub(2)) * i / 9;
        let v = v.clamp(1, l.max(1));
        if !out.contains(&v) {
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;

    fn toy(l: usize) -> Chain {
        let mut st: Vec<Stage> = (1..=l)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 10, 30))
            .collect();
        st.push(Stage::new("loss", 0.1, 0.1, 1, 1));
        Chain::new("toy", st, 10)
    }

    #[test]
    fn bounds_are_balanced_and_cover() {
        let b = segment_bounds(10, 3);
        assert_eq!(b, vec![(1, 4), (5, 7), (8, 10)]);
        let b = segment_bounds(6, 6);
        assert_eq!(b.len(), 6);
        assert_eq!(b[0], (1, 1));
        assert_eq!(b[5], (6, 6));
    }

    #[test]
    fn every_backward_once_every_nonlast_segment_twice() {
        let c = toy(9);
        let s = periodic_schedule(&c, 3);
        for l in 1..=c.len() as u32 {
            let n_b = s.ops.iter().filter(|o| matches!(o, Op::Bwd(x) if *x == l)).count();
            assert_eq!(n_b, 1, "B^{l} exactly once");
        }
        // segments (1,3),(4,6),(7,9): stages 1..6 run twice, 7..9 + loss once
        for l in 1..=6u32 {
            assert_eq!(s.forward_count(l), 2, "stage {l}");
        }
        for l in 7..=10u32 {
            assert_eq!(s.forward_count(l), 1, "stage {l}");
        }
    }

    #[test]
    fn single_segment_is_store_all_shaped() {
        let c = toy(4);
        let s = periodic_schedule(&c, 1);
        assert_eq!(s.recomputation_ops(c.len()), 0);
        assert!((s.predicted_time - c.ideal_time()).abs() < 1e-12);
    }

    #[test]
    fn predicted_time_counts_recompute() {
        let c = toy(9);
        let s = periodic_schedule(&c, 3);
        // 6 recomputed forwards at uf=1.0
        assert!((s.predicted_time - (c.ideal_time() + 6.0)).abs() < 1e-12);
    }

    #[test]
    fn sweep_shape() {
        let sweep = paper_segment_sweep(100);
        assert_eq!(sweep[0], 2);
        assert!(*sweep.last().unwrap() <= 20);
        assert!(sweep.len() <= 10 && sweep.len() >= 2);
        let tiny = paper_segment_sweep(3);
        assert!(tiny.iter().all(|&k| k >= 1 && k <= 3));
    }
}
