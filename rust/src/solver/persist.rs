//! On-disk persistence for solved DP tables — the planner cache's second
//! tier.
//!
//! Theorem 1's operational property is that one solved table answers
//! *every* budget at or below its top, so the expensive artifact of the
//! planning service is the table, not the query. This module makes that
//! artifact durable: a solved [`DpTable`] (frontier-compressed or dense)
//! round-trips through a versioned, fingerprint-keyed, checksummed binary
//! file, so restarts and horizontally-scaled replicas answer sweeps
//! without re-filling the DP.
//!
//! # File format (version 1, all little-endian)
//!
//! ```text
//! magic            8 B   b"CKPTDPT\0"
//! format version   u32   FORMAT_VERSION
//! mode             u8    0 = Full, 1 = AdRevolve
//! store kind       u8    0 = frontier-compressed, 1 = dense
//! padding          u16   zero
//! fingerprint      u64   planner cache key (chain timings/sizes + slots + mode)
//! n                u64   stages covered
//! slots            u64   top of the slot axis
//! payload len      u64   bytes of payload that follow
//! payload          …     store arrays, length-prefixed (see below)
//! checksum         u64   FNV-1a 64 over every preceding byte
//! ```
//!
//! The loader rejects — with a kind-tagged [`StoreError`], never a panic
//! or a silently wrong table — any file that is truncated, carries the
//! wrong magic or a stale format version, fails the checksum, or whose
//! fingerprint/mode disagree with what the planner asked for. Structural
//! invariants of the deserialized arrays (row offsets monotone, run
//! starts strictly increasing and on the slot axis, array lengths
//! consistent with the triangular cell count) are re-validated after the
//! checksum so even an adversarially consistent file cannot induce
//! out-of-bounds lookups.
//!
//! Writes go through a temporary file in the same directory followed by
//! an atomic rename, so a crash mid-write never leaves a half-table
//! where the loader would find it.

use std::fmt;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use super::optimal::{DenseStore, DpTable, FrontierStore, Mode};

/// Bump on any incompatible change to the byte layout; stale files are
/// rejected with [`StoreErrorKind::BadVersion`] and rebuilt.
pub const FORMAT_VERSION: u32 = 1;

const MAGIC: [u8; 8] = *b"CKPTDPT\0";
/// Fixed-size header: magic + version + mode + kind + pad + fingerprint
/// + n + slots + payload length.
const HEADER_BYTES: usize = 8 + 4 + 1 + 1 + 2 + 8 + 8 + 8 + 8;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Why a table file could not be read or written. Load failures are
/// always recoverable — the planner falls back to a fresh DP fill — but
/// the kind keeps telemetry and logs precise about *why* the store
/// missed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreErrorKind {
    /// Filesystem error (open/read/write/rename).
    Io,
    /// The file does not start with the table-store magic.
    BadMagic,
    /// The file's format version is not [`FORMAT_VERSION`].
    BadVersion,
    /// The file ends before its declared payload/checksum.
    Truncated,
    /// The trailing FNV-1a checksum does not match the bytes.
    BadChecksum,
    /// Fingerprint or mode in the header disagree with the request.
    Mismatch,
    /// Checksummed but structurally inconsistent payload.
    Corrupt,
}

impl StoreErrorKind {
    /// Stable snake_case tag (telemetry labels, log lines, tests).
    pub fn as_str(self) -> &'static str {
        match self {
            StoreErrorKind::Io => "io",
            StoreErrorKind::BadMagic => "bad_magic",
            StoreErrorKind::BadVersion => "bad_version",
            StoreErrorKind::Truncated => "truncated",
            StoreErrorKind::BadChecksum => "bad_checksum",
            StoreErrorKind::Mismatch => "mismatch",
            StoreErrorKind::Corrupt => "corrupt",
        }
    }
}

/// A kind-tagged table-store error.
#[derive(Debug)]
pub struct StoreError {
    kind: StoreErrorKind,
    msg: String,
}

impl StoreError {
    fn new(kind: StoreErrorKind, msg: impl Into<String>) -> StoreError {
        StoreError { kind, msg: msg.into() }
    }

    pub fn kind(&self) -> StoreErrorKind {
        self.kind
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "table store [{}]: {}", self.kind.as_str(), self.msg)
    }
}

impl std::error::Error for StoreError {}

type StoreResult<T> = Result<T, StoreError>;

// ---------------------------------------------------------------------------
// Checksum: FNV-1a 64 (std-only, stable, fast enough for tens of MB)
// ---------------------------------------------------------------------------

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

fn mode_byte(mode: Mode) -> u8 {
    match mode {
        Mode::Full => 0,
        Mode::AdRevolve => 1,
    }
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_len(out: &mut Vec<u8>, v: usize) {
    push_u64(out, v as u64);
}

/// The canonical file name for a fingerprint: `dp-<16 hex digits>.tbl`.
/// The fingerprint already covers the chain's discretized
/// timings/sizes, the slot count, and the DP mode, so one flat directory
/// holds the whole catalog.
pub fn table_file_name(fingerprint: u64) -> String {
    format!("dp-{fingerprint:016x}.tbl")
}

/// Serialize `table` into the version-1 byte format.
pub fn to_bytes(fingerprint: u64, mode: Mode, table: &DpTable) -> Vec<u8> {
    let mut payload = Vec::new();
    let kind: u8;
    if let Some(f) = table.store_frontier() {
        kind = 0;
        push_len(&mut payload, f.row_start.len());
        for &v in &f.row_start {
            push_u64(&mut payload, v);
        }
        push_len(&mut payload, f.ms.len());
        for &v in &f.ms {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &f.costs {
            push_u64(&mut payload, v.to_bits());
        }
        for &v in &f.decs {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        push_len(&mut payload, f.row_first_m.len());
        for &v in &f.row_first_m {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &f.row_min_cost {
            push_u64(&mut payload, v.to_bits());
        }
    } else if let Some(d) = table.store_dense() {
        kind = 1;
        push_len(&mut payload, d.cost.len());
        for &v in &d.cost {
            push_u64(&mut payload, v.to_bits());
        }
        for &v in &d.dec {
            payload.extend_from_slice(&v.to_le_bytes());
        }
    } else {
        unreachable!("a DpTable is always frontier or dense");
    }

    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len() + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.push(mode_byte(mode));
    out.push(kind);
    out.extend_from_slice(&0u16.to_le_bytes());
    push_u64(&mut out, fingerprint);
    push_len(&mut out, table.stages());
    push_len(&mut out, table.slots());
    push_len(&mut out, payload.len());
    out.extend_from_slice(&payload);
    let sum = fnv1a(&out);
    push_u64(&mut out, sum);
    out
}

/// Persist `table` under `dir` at its canonical file name, atomically
/// (temp file + rename). Returns the final path.
pub fn save(dir: &Path, fingerprint: u64, mode: Mode, table: &DpTable) -> StoreResult<PathBuf> {
    let io_err = |what: &str| {
        let dir = dir.display().to_string();
        move |e: std::io::Error| StoreError::new(StoreErrorKind::Io, format!("{what} {dir}: {e}"))
    };
    fs::create_dir_all(dir).map_err(io_err("creating table dir"))?;
    let bytes = to_bytes(fingerprint, mode, table);
    let final_path = dir.join(table_file_name(fingerprint));
    // unique-enough temp name: pid disambiguates racing processes; racing
    // threads in one process are already serialized by the planner's
    // single-flight build path
    let tmp_path = dir.join(format!(".{}.{}.tmp", table_file_name(fingerprint), std::process::id()));
    let mut f = fs::File::create(&tmp_path).map_err(io_err("creating temp table file in"))?;
    let write_res = f.write_all(&bytes).and_then(|()| f.sync_all());
    drop(f);
    if let Err(e) = write_res {
        let _ = fs::remove_file(&tmp_path);
        return Err(StoreError::new(
            StoreErrorKind::Io,
            format!("writing {}: {e}", tmp_path.display()),
        ));
    }
    if let Err(e) = fs::rename(&tmp_path, &final_path) {
        let _ = fs::remove_file(&tmp_path);
        return Err(StoreError::new(
            StoreErrorKind::Io,
            format!("renaming into {}: {e}", final_path.display()),
        ));
    }
    Ok(final_path)
}

// ---------------------------------------------------------------------------
// Deserialization
// ---------------------------------------------------------------------------

/// A bounds-checked little-endian reader over the payload.
struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> StoreResult<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.data.len()).ok_or_else(|| {
            StoreError::new(
                StoreErrorKind::Truncated,
                format!(
                    "payload ends at {} of {} needed",
                    self.data.len(),
                    self.pos.saturating_add(n)
                ),
            )
        })?;
        let s = &self.data[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u64(&mut self) -> StoreResult<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn len(&mut self, what: &str) -> StoreResult<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            StoreError::new(StoreErrorKind::Corrupt, format!("{what} length {v} exceeds usize"))
        })
    }

    fn u64_vec(&mut self, n: usize) -> StoreResult<Vec<u64>> {
        let b = self.take(n.checked_mul(8).ok_or_else(overflow)?)?;
        Ok(b.chunks_exact(8)
            .map(|c| {
                let mut a = [0u8; 8];
                a.copy_from_slice(c);
                u64::from_le_bytes(a)
            })
            .collect())
    }

    fn f64_vec(&mut self, n: usize) -> StoreResult<Vec<f64>> {
        Ok(self.u64_vec(n)?.into_iter().map(f64::from_bits).collect())
    }

    fn u32_vec(&mut self, n: usize) -> StoreResult<Vec<u32>> {
        let b = self.take(n.checked_mul(4).ok_or_else(overflow)?)?;
        Ok(b.chunks_exact(4)
            .map(|c| {
                let mut a = [0u8; 4];
                a.copy_from_slice(c);
                u32::from_le_bytes(a)
            })
            .collect())
    }

    fn u16_vec(&mut self, n: usize) -> StoreResult<Vec<u16>> {
        let b = self.take(n.checked_mul(2).ok_or_else(overflow)?)?;
        Ok(b.chunks_exact(2)
            .map(|c| {
                let mut a = [0u8; 2];
                a.copy_from_slice(c);
                u16::from_le_bytes(a)
            })
            .collect())
    }
}

fn overflow() -> StoreError {
    StoreError::new(StoreErrorKind::Corrupt, "array length overflows the address space")
}

fn corrupt(msg: impl Into<String>) -> StoreError {
    StoreError::new(StoreErrorKind::Corrupt, msg)
}

/// Parse and fully validate a version-1 table file image. `expect` pins
/// the fingerprint and mode the caller is looking for; any header
/// disagreement is a [`StoreErrorKind::Mismatch`].
pub fn from_bytes(data: &[u8], expect_fingerprint: u64, expect_mode: Mode) -> StoreResult<DpTable> {
    if data.len() < HEADER_BYTES + 8 {
        return Err(StoreError::new(
            StoreErrorKind::Truncated,
            format!("{} bytes is shorter than the fixed header", data.len()),
        ));
    }
    if data[..8] != MAGIC {
        return Err(StoreError::new(StoreErrorKind::BadMagic, "not a chainckpt table file"));
    }
    let mut head = Cursor { data, pos: 8 };
    let version = {
        let b = head.take(4)?;
        let mut a = [0u8; 4];
        a.copy_from_slice(b);
        u32::from_le_bytes(a)
    };
    if version != FORMAT_VERSION {
        return Err(StoreError::new(
            StoreErrorKind::BadVersion,
            format!("format version {version}, this build reads {FORMAT_VERSION}"),
        ));
    }
    let mode_b = head.take(1)?[0];
    let kind = head.take(1)?[0];
    let _pad = head.take(2)?;
    let fingerprint = head.u64()?;
    let n = head.len("stage count")?;
    let slots = head.len("slot count")?;
    let payload_len = head.len("payload")?;

    // checksum before anything payload-shaped is interpreted
    let declared_end = HEADER_BYTES.checked_add(payload_len).ok_or_else(overflow)?;
    if data.len() != declared_end + 8 {
        return Err(StoreError::new(
            StoreErrorKind::Truncated,
            format!("file is {} bytes, header declares {}", data.len(), declared_end + 8),
        ));
    }
    let mut sum_bytes = [0u8; 8];
    sum_bytes.copy_from_slice(&data[declared_end..]);
    let declared_sum = u64::from_le_bytes(sum_bytes);
    let actual_sum = fnv1a(&data[..declared_end]);
    if declared_sum != actual_sum {
        return Err(StoreError::new(
            StoreErrorKind::BadChecksum,
            format!("checksum {declared_sum:#018x} != computed {actual_sum:#018x}"),
        ));
    }

    if fingerprint != expect_fingerprint {
        return Err(StoreError::new(
            StoreErrorKind::Mismatch,
            format!("fingerprint {fingerprint:#018x}, wanted {expect_fingerprint:#018x}"),
        ));
    }
    if mode_b != mode_byte(expect_mode) {
        return Err(StoreError::new(
            StoreErrorKind::Mismatch,
            format!("DP mode byte {mode_b}, wanted {}", mode_byte(expect_mode)),
        ));
    }

    // geometry must be representable before any O(cells) allocation
    DpTable::preflight(n, slots).map_err(|e| corrupt(format!("{e:#}")))?;
    let cells = n * (n + 1) / 2;
    let slots_u64 = slots as u64;

    let mut cur = Cursor { data: &data[HEADER_BYTES..declared_end], pos: 0 };
    let table = match kind {
        0 => {
            let row_start_len = cur.len("row_start")?;
            if row_start_len != cells + 1 {
                return Err(corrupt(format!(
                    "row_start has {row_start_len} entries, {n} stages need {}",
                    cells + 1
                )));
            }
            let row_start = cur.u64_vec(row_start_len)?;
            let runs = cur.len("runs")?;
            let ms = cur.u32_vec(runs)?;
            let costs = cur.f64_vec(runs)?;
            let decs = cur.u16_vec(runs)?;
            let summaries = cur.len("row summaries")?;
            if summaries != cells {
                return Err(corrupt(format!(
                    "{summaries} row summaries for {cells} cells"
                )));
            }
            let row_first_m = cur.u32_vec(cells)?;
            let row_min_cost = cur.f64_vec(cells)?;

            // structural invariants: offsets bound the arena and are
            // monotone; run starts are strictly increasing on the slot
            // axis within every row — together these make every lookup
            // (`runs()`, `index_at`, binary search) in-bounds and sane
            if row_start.first() != Some(&0) {
                return Err(corrupt("row_start[0] must be 0"));
            }
            if row_start.last().copied() != Some(runs as u64) {
                return Err(corrupt("row_start must end at the arena length"));
            }
            for w in row_start.windows(2) {
                if w[0] > w[1] {
                    return Err(corrupt("row_start must be non-decreasing"));
                }
            }
            for c in 0..cells {
                let lo = usize::try_from(row_start[c]).map_err(|_| overflow())?;
                let hi = usize::try_from(row_start[c + 1]).map_err(|_| overflow())?;
                let row = &ms[lo..hi];
                for w in row.windows(2) {
                    if w[0] >= w[1] {
                        return Err(corrupt(format!("cell {c}: run starts must increase")));
                    }
                }
                if row.iter().any(|&m| u64::from(m) > slots_u64) {
                    return Err(corrupt(format!("cell {c}: run start beyond the slot axis")));
                }
            }

            let store =
                FrontierStore { n, row_start, ms, costs, decs, row_first_m, row_min_cost };
            DpTable::from_frontier(n, slots, store)
        }
        1 => {
            let want = cells.checked_mul(slots + 1).ok_or_else(overflow)?;
            let len = cur.len("dense cells")?;
            if len != want {
                return Err(corrupt(format!(
                    "dense payload has {len} cells, geometry needs {want}"
                )));
            }
            let cost = cur.f64_vec(len)?;
            let dec = cur.u16_vec(len)?;
            DpTable::from_dense(n, slots, DenseStore { n, slots, cost, dec })
        }
        k => return Err(corrupt(format!("unknown store kind {k}"))),
    };
    if cur.pos != cur.data.len() {
        return Err(corrupt(format!(
            "{} trailing payload bytes after the arrays",
            cur.data.len() - cur.pos
        )));
    }
    Ok(table)
}

/// Load and validate a table file. Every failure is a kind-tagged
/// [`StoreError`]; the planner treats all of them as a cache miss and
/// rebuilds.
pub fn load(path: &Path, expect_fingerprint: u64, expect_mode: Mode) -> StoreResult<DpTable> {
    let data = fs::read(path).map_err(|e| {
        StoreError::new(StoreErrorKind::Io, format!("reading {}: {e}", path.display()))
    })?;
    from_bytes(&data, expect_fingerprint, expect_mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::{Chain, DiscreteChain, Stage};

    fn table() -> (DiscreteChain, DpTable) {
        let stages = vec![
            Stage::new("s1", 1.0, 2.0, 100, 300),
            Stage::new("s2", 1.5, 2.5, 120, 260),
            Stage::new("loss", 0.1, 0.1, 4, 4),
        ];
        let chain = Chain::new("t", stages, 100);
        let dc = DiscreteChain::new(&chain, chain.store_all_memory() + chain.wa0, 60);
        let tab = super::super::solve_table(&dc, Mode::Full);
        (dc, tab)
    }

    #[test]
    fn bytes_round_trip_bit_exact() {
        let (dc, tab) = table();
        let bytes = to_bytes(42, Mode::Full, &tab);
        let back = from_bytes(&bytes, 42, Mode::Full).expect("round-trip");
        assert_eq!(back.stages(), tab.stages());
        assert_eq!(back.slots(), tab.slots());
        assert_eq!(back.run_count(), tab.run_count());
        for t in 1..=dc.len() {
            for s in 1..=t {
                for m in 0..=u32::try_from(dc.slots).unwrap() {
                    assert_eq!(back.cost(s, t, m).to_bits(), tab.cost(s, t, m).to_bits());
                    assert_eq!(back.decision(s, t, m), tab.decision(s, t, m));
                }
            }
        }
    }

    #[test]
    fn every_header_field_is_enforced() {
        let (_dc, tab) = table();
        let good = to_bytes(7, Mode::Full, &tab);

        // magic
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert_eq!(from_bytes(&bad, 7, Mode::Full).unwrap_err().kind(), StoreErrorKind::BadMagic);

        // version (re-checksum so the version check, not the checksum, fires)
        let mut bad = good.clone();
        bad[8] = 0xfe;
        let sum = fnv1a(&bad[..bad.len() - 8]);
        let at = bad.len() - 8;
        bad[at..].copy_from_slice(&sum.to_le_bytes());
        assert_eq!(
            from_bytes(&bad, 7, Mode::Full).unwrap_err().kind(),
            StoreErrorKind::BadVersion
        );

        // checksum
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        assert_eq!(
            from_bytes(&bad, 7, Mode::Full).unwrap_err().kind(),
            StoreErrorKind::BadChecksum
        );

        // truncation
        let bad = &good[..good.len() - 3];
        assert_eq!(from_bytes(bad, 7, Mode::Full).unwrap_err().kind(), StoreErrorKind::Truncated);

        // fingerprint + mode mismatches
        assert_eq!(from_bytes(&good, 8, Mode::Full).unwrap_err().kind(), StoreErrorKind::Mismatch);
        assert_eq!(
            from_bytes(&good, 7, Mode::AdRevolve).unwrap_err().kind(),
            StoreErrorKind::Mismatch
        );
    }

    #[test]
    fn save_writes_the_canonical_name_and_load_round_trips() {
        let (_dc, tab) = table();
        let dir = std::env::temp_dir().join(format!("chainckpt-persist-{}", std::process::id()));
        let path = save(&dir, 0xabcd, Mode::Full, &tab).expect("save");
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "dp-000000000000abcd.tbl");
        let back = load(&path, 0xabcd, Mode::Full).expect("load");
        assert_eq!(back.run_count(), tab.run_count());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
