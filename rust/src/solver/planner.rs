//! Plan once, answer every budget: the `Planner` solves the paper's DP a
//! single time per `(chain, slots, mode)` and then serves schedules for
//! **any** memory budget below its top by O(L) reconstruction.
//!
//! Theorem 1's table `C_BP(s, t, m)` already contains the optimal cost
//! for *every* slot budget `m`, not just the one a caller asked about —
//! the per-budget work in a sweep is only Algorithm 2's reconstruction.
//! Historically `solve` still re-discretized and re-filled the table per
//! budget, so the §5.4 figure sweeps and `compare` paid the O(L²·S)
//! (per-cell O(L)) DP ten-plus times per chain. The fix has two parts:
//!
//! 1. **Budget-independent discretization** — slot sizes depend only on
//!    the slot width `M/S` ([`DiscreteChain`] docs), so discretizing
//!    against the *top* budget of a sweep makes one table valid for all
//!    smaller budgets ([`DiscreteChain::budget_slots`] maps bytes → slots
//!    conservatively).
//! 2. **A fingerprint-keyed LRU table cache** — the discretized chain's
//!    content hash keys an `Arc<DpTable>`, so repeated solves of the same
//!    profile (across `Planner::new` *and* the [`super::solve`]
//!    compatibility wrapper) are free.
//!
//! This is the same "plan once, reuse everywhere" structure that makes
//! checkpointing planners (Checkmate, Dynamic Tensor Rematerialization)
//! cheap enough to sit in a training loop.
//!
//! # Example
//!
//! ```
//! use chainckpt::chain::profiles;
//! use chainckpt::solver::{Mode, Planner};
//!
//! let chain = profiles::resnet(18, 224, 4);
//! let top = chain.store_all_memory() + chain.wa0;
//!
//! // one DP solve…
//! let planner = Planner::new(&chain, top, 150, Mode::Full);
//!
//! // …answers a whole budget sweep by reconstruction
//! let budgets: Vec<u64> = (1..=8).map(|i| top * i / 8).collect();
//! let schedules = planner.sweep(&budgets);
//! assert!(schedules.last().unwrap().is_some(), "the top budget always fits");
//!
//! // less memory never makes the optimal schedule faster
//! let costs: Vec<f64> = schedules.iter().flatten().map(|s| s.predicted_time).collect();
//! assert!(costs.windows(2).all(|w| w[1] <= w[0] + 1e-9));
//!
//! // the feasibility frontier without re-solving anything
//! let (lo, hi) = planner.feasible_range().expect("chain fits somewhere");
//! assert!(lo <= hi);
//! assert!(planner.schedule_at(lo).is_some());
//! ```

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Instant;

use super::optimal::{reconstruct, try_solve_table, DpTable, Mode};
use super::persist;
use super::sequence::{Schedule, StrategyKind};
use crate::api::Result as ApiResult;
use crate::chain::{Chain, DiscreteChain};

/// A chain's DP solved once, able to emit the optimal persistent schedule
/// for any byte budget up to the one it was built with.
///
/// Construction runs (or fetches from the cache) the full O(L²·S) table
/// fill; every query after that is at most O(L) per budget. Budgets above
/// [`Planner::top_memory`] are clamped to it — the answer is still a
/// valid schedule, but a larger table might do better, so build the
/// planner at the largest budget you intend to ask about.
pub struct Planner {
    dc: DiscreteChain,
    table: Arc<DpTable>,
    mode: Mode,
}

impl Planner {
    /// Discretize `chain` against `top_memory` bytes with `slots` slots
    /// and solve (or fetch) the DP table for `mode`. Panics on
    /// over-capacity requests; use [`Planner::try_new`] to surface them.
    pub fn new(chain: &Chain, top_memory: u64, slots: usize, mode: Mode) -> Planner {
        Self::try_new(chain, top_memory, slots, mode)
            .unwrap_or_else(|e| panic!("planner construction failed: {e:#}"))
    }

    /// [`Planner::new`], but chains beyond the solver's capacity limits
    /// ([`DpTable::preflight`]) return a kind-tagged [`crate::api::Error`]
    /// — the planning service maps it to HTTP 422 — instead of aborting
    /// on an OOM-scale allocation.
    pub fn try_new(
        chain: &Chain,
        top_memory: u64,
        slots: usize,
        mode: Mode,
    ) -> ApiResult<Planner> {
        let dc = DiscreteChain::new(chain, top_memory, slots);
        let table = try_table_for(&dc, mode)?;
        Ok(Planner { dc, table, mode })
    }

    /// The byte budget the discretization was built against (top of the
    /// representable range).
    pub fn top_memory(&self) -> u64 {
        self.dc.top_bytes
    }

    /// The solver model this planner was built for.
    pub fn mode(&self) -> Mode {
        self.mode
    }

    /// Bytes per memory slot (`top_memory / slots`) — the granularity at
    /// which budgets are distinguished.
    pub fn slot_bytes(&self) -> f64 {
        self.dc.slot_bytes
    }

    /// The shared DP table (one `Arc` per distinct discretized chain).
    pub fn table(&self) -> &Arc<DpTable> {
        &self.table
    }

    /// The discretized chain the table was filled for.
    pub fn discrete(&self) -> &DiscreteChain {
        &self.dc
    }

    fn strategy(&self) -> StrategyKind {
        match self.mode {
            Mode::Full => StrategyKind::Optimal,
            Mode::AdRevolve => StrategyKind::Revolve,
        }
    }

    /// Top-level DP slot budget for a byte budget: whole slots in `memory`
    /// minus the always-resident chain input `ω_a^0` (Algorithm 1's `m0`).
    fn dp_budget(&self, memory: u64) -> Option<u32> {
        self.dc.budget_slots(memory).checked_sub(self.dc.wa_s(0))
    }

    /// Optimal predicted time at `memory` bytes, without reconstructing
    /// the schedule. `None` if no persistent schedule fits.
    pub fn cost_at(&self, memory: u64) -> Option<f64> {
        let b = self.dp_budget(memory)?;
        let cost = self.table.cost(1, self.dc.len(), b);
        cost.is_finite().then_some(cost)
    }

    /// The optimal persistent schedule within `memory` bytes (Algorithm 2
    /// reconstruction from the shared table). `None` if infeasible.
    pub fn schedule_at(&self, memory: u64) -> Option<Schedule> {
        let b = self.dp_budget(memory)?;
        let n = self.dc.len();
        let cost = self.table.cost(1, n, b);
        if !cost.is_finite() {
            return None;
        }
        let mut ops = Vec::new();
        reconstruct(&self.table, &self.dc, 1, n, b, &mut ops);
        Some(Schedule::new(ops, self.strategy(), cost))
    }

    /// The byte-budget feasibility interval `[min, top_memory]` this
    /// planner can serve: `min` is the smallest budget whose slot count
    /// admits a persistent schedule (found by binary search — the DP cost
    /// is monotone along the slot axis). `None` when even the top budget
    /// is infeasible.
    pub fn feasible_range(&self) -> Option<(u64, u64)> {
        let n = self.dc.len();
        let wa0 = self.dc.wa_s(0);
        // preflight bounds the slot axis well inside u32, so the
        // conversion never fails in practice; `?` keeps it total anyway
        let bmax = u32::try_from(self.dc.slots).ok()?.checked_sub(wa0)?;
        if !self.table.cost(1, n, bmax).is_finite() {
            return None;
        }
        let (mut lo, mut hi) = (0u32, bmax);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.table.cost(1, n, mid).is_finite() {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        // smallest byte budget that rounds down to `lo + wa0` whole slots
        let k = lo + wa0;
        let mut bytes = (k as f64 * self.dc.slot_bytes).ceil() as u64;
        while self.dc.budget_slots(bytes) < k {
            bytes += 1;
        }
        Some((bytes.min(self.dc.top_bytes), self.dc.top_bytes))
    }

    /// Schedules for a whole budget sweep, reconstructed in parallel from
    /// the shared table (scoped threads; no rayon in the offline build).
    /// `out[i]` corresponds to `budgets[i]` and equals
    /// `self.schedule_at(budgets[i])`.
    pub fn sweep(&self, budgets: &[u64]) -> Vec<Option<Schedule>> {
        let workers =
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        if budgets.len() < 2 || workers < 2 {
            return budgets.iter().map(|&m| self.schedule_at(m)).collect();
        }
        let chunk = budgets.len().div_ceil(workers);
        std::thread::scope(|scope| {
            let handles: Vec<_> = budgets
                .chunks(chunk)
                .map(|part| {
                    scope.spawn(move || {
                        part.iter().map(|&m| self.schedule_at(m)).collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        })
    }
}

// ---------------------------------------------------------------------------
// Fingerprint-keyed LRU cache: discretized-chain content hash → Arc<DpTable>.
// ---------------------------------------------------------------------------

/// Per-entry size cap. Sized so the figure harness's largest tables
/// (ResNet-1001 at S = 300, ~174 MB) are retained — repeated panels of
/// the same chain across figures are where caching saves whole minutes —
/// while the S = 500 ResNet-1001 worst case (~290 MB) is served but not
/// kept.
const CACHE_MAX_ENTRY_BYTES: usize = 192 << 20;
/// Total cache byte budget (LRU eviction). Big enough for both modes'
/// tables of one huge chain plus a working set of small ones; only ever
/// reached by processes that actually solve such chains, and reclaimable
/// via [`clear_cache`].
const CACHE_MAX_TOTAL_BYTES: usize = 384 << 20;
/// Entry-count backstop so pathological floods of tiny chains stay bounded.
const CACHE_MAX_ENTRIES: usize = 64;

struct CacheEntry {
    key: u64,
    bytes: usize,
    table: Arc<DpTable>,
}

struct TableCache {
    /// LRU order: least recently used first.
    entries: Vec<CacheEntry>,
    /// Fingerprints whose DP fill is currently running on some thread
    /// (single-flight: racing requests for the same chain wait instead of
    /// duplicating the O(L²·S) build — under the planning service many
    /// connections ask for the same chain at once).
    inflight: Vec<u64>,
    /// Tables completed while too large for the LRU, handed to coalesced
    /// waiters. Weak: lives only as long as some caller holds the Arc.
    handoff: Vec<(u64, Weak<DpTable>)>,
    total_bytes: usize,
    // lookups/hits/builds/evictions/coalesced live in the global
    // telemetry registry (`telemetry::registry().cache_*`), not here —
    // one set of counters feeds `cache_stats()`, `/stats`, `/metrics`,
    // and the bench snapshots alike.
}

static CACHE: Mutex<TableCache> = Mutex::new(TableCache {
    entries: Vec::new(),
    inflight: Vec::new(),
    handoff: Vec::new(),
    total_bytes: 0,
});

/// Wakes waiters parked in [`table_for`] when an in-flight build finishes.
static CACHE_CV: Condvar = Condvar::new();

/// The cache's optional second tier: a directory of persisted DP tables
/// ([`super::persist`] format). `None` (the default) disables the tier
/// entirely — lookups skip the filesystem and behave exactly as before.
static TABLE_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Point the planner cache at an on-disk table store (or detach it with
/// `None`). Process-global, like the cache itself: the service sets it
/// once at startup from `--table-dir`; benches set and clear it around
/// cold/warm arms.
pub fn set_table_dir(dir: Option<PathBuf>) {
    *TABLE_DIR.lock().unwrap_or_else(|p| p.into_inner()) = dir;
}

/// The directory currently backing the cache's disk tier, if any.
pub fn table_dir() -> Option<PathBuf> {
    TABLE_DIR.lock().unwrap_or_else(|p| p.into_inner()).clone()
}

fn lock_cache() -> std::sync::MutexGuard<'static, TableCache> {
    // the critical sections below never panic; recover anyway if a
    // panicking test poisoned the lock
    CACHE.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Content hash of everything the DP consumes: discretized sizes, exact
/// time bits, the slot axis, and the solver mode. Two chains with equal
/// fingerprints produce identical tables (64-bit collisions are treated
/// as astronomically unlikely, as usual for content-addressed caches).
fn fingerprint(dc: &DiscreteChain, mode: Mode) -> u64 {
    let mut h = DefaultHasher::new();
    dc.slots.hash(&mut h);
    matches!(mode, Mode::Full).hash(&mut h);
    dc.wa.hash(&mut h);
    dc.wd.hash(&mut h);
    dc.wabar.hash(&mut h);
    dc.of.hash(&mut h);
    dc.ob.hash(&mut h);
    for u in dc.uf.iter().chain(dc.ub.iter()) {
        u.to_bits().hash(&mut h);
    }
    h.finish()
}

/// Removes the in-flight marker (even if the build panicked) and wakes
/// every waiter so they can re-check the cache.
struct InflightGuard {
    key: u64,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        let mut cache = lock_cache();
        cache.inflight.retain(|k| *k != self.key);
        drop(cache);
        CACHE_CV.notify_all();
    }
}

/// Fetch the table for a discretized chain, filling it on a cache miss.
///
/// Misses consult the optional **disk tier** ([`set_table_dir`]) before
/// filling: a persisted table with a matching fingerprint loads in IO
/// time instead of DP time, and fresh builds are written back so later
/// processes start warm. The memory LRU stays the first tier — a disk
/// load is inserted there like any built table.
///
/// Builds are **single-flight** per fingerprint: a racing miss parks on a
/// condvar until the thread that got there first finishes its fill, then
/// takes the shared `Arc` (from the LRU, or from a weak handoff slot when
/// the table was too large to retain). The fill itself runs outside the
/// cache lock, so a long DP never blocks lookups for *other* chains.
///
/// A failed build (capacity rejection) propagates to the caller; the
/// in-flight marker is cleared on the way out ([`InflightGuard`] runs on
/// unwind and error alike), so parked waiters wake, re-check, and — with
/// nothing cached — surface the same error from their own attempt.
fn try_table_for(dc: &DiscreteChain, mode: Mode) -> ApiResult<Arc<DpTable>> {
    let reg = crate::telemetry::registry();
    let key = fingerprint(dc, mode);
    {
        let mut cache = lock_cache();
        reg.cache_lookups.inc();
        loop {
            if let Some(pos) = cache.entries.iter().position(|e| e.key == key) {
                reg.cache_hits.inc();
                let entry = cache.entries.remove(pos);
                let table = entry.table.clone();
                cache.entries.push(entry); // most recently used at the back
                return Ok(table);
            }
            if let Some(table) =
                cache.handoff.iter().find(|(k, _)| *k == key).and_then(|(_, w)| w.upgrade())
            {
                reg.cache_hits.inc();
                return Ok(table);
            }
            if cache.inflight.contains(&key) {
                reg.cache_coalesced.inc();
                cache = CACHE_CV.wait(cache).unwrap_or_else(|p| p.into_inner());
                continue; // re-check: the builder has inserted (or failed)
            }
            cache.inflight.push(key);
            break;
        }
    }
    let _guard = InflightGuard { key };
    // Tier 2: a previous process may have persisted this exact table.
    // A disk hit skips the O(L²·S) fill; a miss (or a rejected file)
    // falls through to a normal build, which is then written back so
    // the *next* cold start hits.
    let (table, built) = match load_tier2(dc, mode, key) {
        Some(table) => (table, false),
        None => {
            let table = Arc::new(try_solve_table(dc, mode)?);
            save_tier2(key, mode, &table);
            (table, true)
        }
    };
    let bytes = table.mem_bytes();
    {
        let mut cache = lock_cache();
        if built {
            reg.cache_builds.inc();
        }
        cache.handoff.retain(|(_, w)| w.strong_count() > 0);
        if bytes <= CACHE_MAX_ENTRY_BYTES && !cache.entries.iter().any(|e| e.key == key) {
            cache.entries.push(CacheEntry { key, bytes, table: table.clone() });
            cache.total_bytes += bytes;
            while cache.entries.len() > CACHE_MAX_ENTRIES
                || cache.total_bytes > CACHE_MAX_TOTAL_BYTES
            {
                let evicted = cache.entries.remove(0);
                cache.total_bytes -= evicted.bytes;
                reg.cache_evictions.inc();
            }
        } else {
            // too big for the LRU: still hand it to coalesced waiters
            cache.handoff.push((key, Arc::downgrade(&table)));
        }
    }
    // _guard drops here: clears the in-flight marker, wakes waiters
    Ok(table)
}

/// Try the persistent store for `key`. Returns `None` — counted as a
/// miss or an error, never propagated — whenever the tier is detached,
/// the file is absent, or [`persist::load`] rejects it (bad checksum,
/// stale version, foreign fingerprint, geometry that disagrees with the
/// discretized chain). The caller treats every `None` as a plain build.
fn load_tier2(dc: &DiscreteChain, mode: Mode, key: u64) -> Option<Arc<DpTable>> {
    let dir = table_dir()?;
    let reg = crate::telemetry::registry();
    let path = dir.join(persist::table_file_name(key));
    if !path.exists() {
        reg.store_misses.inc();
        return None;
    }
    let start = Instant::now();
    match persist::load(&path, key, mode) {
        Ok(table) if table.stages() == dc.len() && table.slots() == dc.slots => {
            reg.store_hits.inc();
            reg.store_load_ns.add(u64::try_from(start.elapsed().as_nanos()).unwrap_or(u64::MAX));
            Some(Arc::new(table))
        }
        Ok(_) => {
            // fingerprint collision with different geometry — treat as
            // absent rather than serve a wrong-shaped table
            reg.store_errors.inc();
            None
        }
        Err(_) => {
            reg.store_errors.inc();
            None
        }
    }
}

/// Persist a freshly built table, best-effort: a full disk or read-only
/// directory costs a counter tick, never a failed plan.
fn save_tier2(key: u64, mode: Mode, table: &DpTable) {
    let Some(dir) = table_dir() else { return };
    let reg = crate::telemetry::registry();
    match persist::save(&dir, key, mode, table) {
        Ok(_) => reg.store_writes.inc(),
        Err(_) => reg.store_errors.inc(),
    }
}

/// Counters of the shared planner table cache (monotone since process
/// start, except `entries`/`bytes` which reflect current residency).
/// The monotone counters are read from the global telemetry registry —
/// this struct is the stable snapshot shape the benches and `/stats`
/// consume; the instruments themselves live in
/// [`crate::telemetry::Registry`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannerCacheStats {
    /// Table requests (one per `Planner::new` / `solve` call).
    pub lookups: u64,
    /// Requests served without running the DP (LRU hits and coalesced
    /// waiters handed a just-built table).
    pub hits: u64,
    /// DP table fills (`lookups - hits`: builds are single-flight per
    /// fingerprint, so racing misses no longer duplicate work).
    pub builds: u64,
    /// LRU entries dropped to respect the byte/count caps.
    pub evictions: u64,
    /// Wait episodes: times a request parked behind an in-flight build of
    /// the same table instead of starting its own.
    pub coalesced: u64,
    /// Tables currently retained.
    pub entries: usize,
    /// Bytes currently retained.
    pub bytes: usize,
}

/// Snapshot the planner cache counters (shared process-wide): the
/// monotone counts come from the telemetry registry, residency from the
/// cache itself.
pub fn cache_stats() -> PlannerCacheStats {
    let reg = crate::telemetry::registry();
    let cache = lock_cache();
    PlannerCacheStats {
        lookups: reg.cache_lookups.get(),
        hits: reg.cache_hits.get(),
        builds: reg.cache_builds.get(),
        evictions: reg.cache_evictions.get(),
        coalesced: reg.cache_coalesced.get(),
        entries: cache.entries.len(),
        bytes: cache.total_bytes,
    }
}

/// Drop all retained tables and zero the counters (benchmark hygiene: the
/// baseline arm of a solve-vs-planner comparison must not hit the cache).
/// In-flight markers are left alone — a concurrent build still completes
/// and clears itself.
pub fn clear_cache() {
    let mut cache = lock_cache();
    cache.entries.clear();
    cache.handoff.clear();
    cache.total_bytes = 0;
    drop(cache);
    crate::telemetry::registry().reset_cache_counters();
}

#[cfg(test)]
mod tests {
    use super::super::solve;
    use super::*;
    use crate::chain::Stage;

    fn toy(n: usize) -> Chain {
        let mut stages: Vec<Stage> = (1..=n)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 100, 300))
            .collect();
        stages.push(Stage::new("loss", 0.1, 0.1, 4, 4));
        Chain::new("toy", stages, 100)
    }

    /// Integer slot width so planner and per-budget solves share an exact
    /// discretization grid (see tests/planner_properties.rs).
    fn aligned_top(chain: &Chain, slots: usize) -> u64 {
        (chain.store_all_memory() + chain.wa0).div_ceil(slots as u64) * slots as u64
    }

    #[test]
    fn matches_fresh_solve_on_the_slot_grid() {
        let c = toy(7);
        const S: usize = 120;
        let top = aligned_top(&c, S);
        let slot = top / S as u64;
        let planner = Planner::new(&c, top, S, Mode::Full);
        for k in [S / 5, S / 3, S / 2, 3 * S / 4, S] {
            let m = k as u64 * slot;
            let fresh = solve(&c, m, k, Mode::Full);
            let shared = planner.schedule_at(m);
            match (fresh, shared) {
                (None, None) => {}
                (Some(f), Some(p)) => {
                    assert_eq!(f.predicted_time, p.predicted_time, "k={k}");
                    assert_eq!(f.ops, p.ops, "k={k}");
                }
                (f, p) => panic!(
                    "k={k}: feasibility disagrees (fresh {:?}, planner {:?})",
                    f.is_some(),
                    p.is_some()
                ),
            }
        }
    }

    #[test]
    fn repeated_builds_share_one_table() {
        let c = toy(9);
        let top = c.store_all_memory() + c.wa0;
        let before = cache_stats();
        let a = Planner::new(&c, top, 137, Mode::Full);
        let b = Planner::new(&c, top, 137, Mode::Full);
        // Sharing is the expected fast path. The cache is process-global,
        // so concurrently-running tests can in principle evict `a`'s entry
        // between the two builds (needs 64+ insertions in the window);
        // accept that rare race, but the rebuilt table must then be
        // interchangeable, and the miss must show up in the counters.
        if Arc::ptr_eq(a.table(), b.table()) {
            let after = cache_stats();
            assert!(after.hits > before.hits, "a shared table must count as a hit");
        } else {
            let after = cache_stats();
            assert!(
                after.builds >= before.builds + 2,
                "non-shared rebuild without eviction churn: the cache never hit"
            );
            assert_eq!(
                a.schedule_at(top).map(|s| s.ops),
                b.schedule_at(top).map(|s| s.ops),
                "rebuilt table must reconstruct identically"
            );
        }
        // a different mode is always a different table
        let r = Planner::new(&c, top, 137, Mode::AdRevolve);
        assert!(!Arc::ptr_eq(a.table(), r.table()));
    }

    #[test]
    fn feasible_range_brackets_feasibility() {
        let c = toy(8);
        let top = c.store_all_memory() + c.wa0;
        let planner = Planner::new(&c, top, 200, Mode::Full);
        let (lo, hi) = planner.feasible_range().expect("roomy top must be feasible");
        assert!(lo <= hi);
        assert_eq!(hi, top);
        assert!(planner.schedule_at(lo).is_some(), "min budget must be feasible");
        if lo > 0 {
            assert!(
                planner.schedule_at(lo - 1).is_none(),
                "one byte below the min budget must be infeasible"
            );
        }
        assert!(planner.schedule_at(hi).is_some());
    }

    #[test]
    fn sweep_equals_pointwise_schedule_at() {
        let c = toy(10);
        let top = c.store_all_memory() + c.wa0;
        let planner = Planner::new(&c, top, 150, Mode::Full);
        let budgets: Vec<u64> = (0..12).map(|i| top * (i + 1) / 12).collect();
        let swept = planner.sweep(&budgets);
        assert_eq!(swept.len(), budgets.len());
        for (i, (&m, s)) in budgets.iter().zip(&swept).enumerate() {
            let direct = planner.schedule_at(m);
            assert_eq!(
                s.as_ref().map(|x| x.ops.clone()),
                direct.as_ref().map(|x| x.ops.clone()),
                "budget #{i}"
            );
        }
    }

    #[test]
    fn try_new_rejects_over_capacity_chains_without_aborting() {
        // depth 10⁴ at S = 500 would worst-case past the table ceiling;
        // the planner reports it as a kind-tagged error naming L and S
        let stages: Vec<Stage> = (0..10_000)
            .map(|i| Stage::new(format!("s{i}"), 1.0, 2.0, 64, 128))
            .collect();
        let c = Chain::new("huge", stages, 64);
        let err = Planner::try_new(&c, 1 << 30, 500, Mode::Full).unwrap_err();
        assert_eq!(err.kind(), crate::api::ErrorKind::InvalidSpec);
        let msg = format!("{err:#}");
        assert!(msg.contains("10000") && msg.contains("500"), "names L and S: {msg}");
        // the same depth is admissible at a coarse slot axis (capacity
        // check only — a real depth-10⁴ fill belongs to `bench_solver`)
        assert!(DpTable::preflight(10_000, 16).is_ok());
    }

    #[test]
    fn infeasible_when_input_exceeds_budget_slots() {
        let c = toy(4);
        let planner = Planner::new(&c, c.store_all_memory() + c.wa0, 100, Mode::Full);
        assert!(planner.schedule_at(0).is_none());
        assert!(planner.cost_at(0).is_none());
    }
}
