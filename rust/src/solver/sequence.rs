//! Schedule IR: the operation alphabet of Table 1 and the sequences built
//! from it. A [`Schedule`] is what every solver emits and what both the
//! [`crate::simulator`] and the [`crate::executor`] consume.

use std::fmt;

/// One operation of the paper's Table 1. Stage indices are 1-based
/// (`1..=L+1`; stage `L+1` is the loss).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// `F∅^ℓ`: forward without saving — `{a^{ℓ-1}} → {a^ℓ}`.
    FwdNoSave(u32),
    /// `Fck^ℓ`: forward, checkpointing the *input* — `{a^{ℓ-1}} → {a^{ℓ-1}, a^ℓ}`.
    FwdCk(u32),
    /// `Fall^ℓ`: forward, recording all intermediates — `{a^{ℓ-1}} → {a^{ℓ-1}, ā^ℓ}`.
    FwdAll(u32),
    /// `B^ℓ`: backward — `{δ^ℓ, ā^ℓ, a^{ℓ-1}} → {δ^{ℓ-1}}`.
    Bwd(u32),
    /// Explicitly discard a stored `a^ℓ` before its backward use. *Never*
    /// emitted by the solvers (their schedules are memory-persistent);
    /// exists so non-persistent schedules — like the paper's §4.1
    /// counterexample — can be expressed and simulated. Free (0 time).
    DropA(u32),
}

impl Op {
    pub fn stage(&self) -> u32 {
        match *self {
            Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) | Op::Bwd(l) | Op::DropA(l) => l,
        }
    }

    pub fn is_forward(&self) -> bool {
        matches!(self, Op::FwdNoSave(_) | Op::FwdCk(_) | Op::FwdAll(_))
    }

    /// Whether this op runs real stage compute (everything but the free
    /// `drop a^ℓ`) — the ops a lowered plan binds kernel calls to.
    pub fn is_compute(&self) -> bool {
        !matches!(self, Op::DropA(_))
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::FwdNoSave(l) => write!(f, "F∅^{l}"),
            Op::FwdCk(l) => write!(f, "Fck^{l}"),
            Op::FwdAll(l) => write!(f, "Fall^{l}"),
            Op::Bwd(l) => write!(f, "B^{l}"),
            Op::DropA(l) => write!(f, "drop a^{l}"),
        }
    }
}

/// Which solver produced a schedule (used for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    Optimal,
    Revolve,
    Periodic,
    StoreAll,
}

impl fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            StrategyKind::Optimal => "optimal",
            StrategyKind::Revolve => "revolve",
            StrategyKind::Periodic => "sequential",
            StrategyKind::StoreAll => "pytorch",
        };
        f.write_str(s)
    }
}

/// A complete computation sequence for one training iteration: computes
/// `δ^0` from `a^0` (executing every `B^ℓ` exactly once).
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub ops: Vec<Op>,
    pub strategy: StrategyKind,
    /// The solver's own makespan claim (same units as the chain's `u`).
    /// The simulator independently verifies this.
    pub predicted_time: f64,
}

impl Schedule {
    pub fn new(ops: Vec<Op>, strategy: StrategyKind, predicted_time: f64) -> Self {
        Schedule { ops, strategy, predicted_time }
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Number of forward executions of stage `ℓ` (recomputation count).
    pub fn forward_count(&self, l: u32) -> usize {
        self.ops
            .iter()
            .filter(|op| op.is_forward() && op.stage() == l)
            .count()
    }

    /// Total forward ops minus the minimum (L+1): the recomputation
    /// overhead the strategy pays for its memory savings.
    pub fn recomputation_ops(&self, chain_len: usize) -> usize {
        let fwd = self.ops.iter().filter(|op| op.is_forward()).count();
        fwd.saturating_sub(chain_len)
    }

    /// Render as the paper's compact notation, e.g.
    /// `Fck^1 F∅^2 Fck^3 Fall^4 Fall^5 B^5 B^4 …`.
    pub fn compact(&self) -> String {
        self.ops
            .iter()
            .map(|op| op.to_string())
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_paper_notation() {
        assert_eq!(Op::FwdCk(1).to_string(), "Fck^1");
        assert_eq!(Op::FwdNoSave(2).to_string(), "F∅^2");
        assert_eq!(Op::FwdAll(5).to_string(), "Fall^5");
        assert_eq!(Op::Bwd(5).to_string(), "B^5");
    }

    #[test]
    fn counts() {
        let s = Schedule::new(
            vec![Op::FwdCk(1), Op::FwdNoSave(2), Op::FwdAll(1), Op::Bwd(1)],
            StrategyKind::Optimal,
            0.0,
        );
        assert_eq!(s.forward_count(1), 2);
        assert_eq!(s.forward_count(2), 1);
        assert_eq!(s.recomputation_ops(2), 1);
    }
}
