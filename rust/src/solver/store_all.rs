//! The `pytorch` baseline: store everything, no recomputation.
//!
//! This is what autograd does by default — `Fall^1 … Fall^{L+1}` then
//! `B^{L+1} … B^1`. Fastest possible schedule, maximal memory. The figure
//! harness uses it as the rightmost point of every plot (when it fits).

use super::sequence::{Op, Schedule, StrategyKind};
use crate::chain::Chain;

/// Builds the store-all schedule. Always structurally valid; whether it
/// fits in a given memory budget is the simulator's verdict.
pub fn store_all_schedule(chain: &Chain) -> Schedule {
    let n = chain.len() as u32;
    let mut ops = Vec::with_capacity(2 * n as usize);
    for l in 1..=n {
        ops.push(Op::FwdAll(l));
    }
    for l in (1..=n).rev() {
        ops.push(Op::Bwd(l));
    }
    Schedule::new(ops, StrategyKind::StoreAll, chain.ideal_time())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;

    #[test]
    fn shape() {
        let c = Chain::new(
            "t",
            vec![Stage::new("a", 1.0, 1.0, 4, 8), Stage::new("b", 1.0, 1.0, 4, 4)],
            4,
        );
        let s = store_all_schedule(&c);
        assert_eq!(s.ops, vec![Op::FwdAll(1), Op::FwdAll(2), Op::Bwd(2), Op::Bwd(1)]);
        assert_eq!(s.predicted_time, c.ideal_time());
        assert_eq!(s.recomputation_ops(c.len()), 0);
    }
}
