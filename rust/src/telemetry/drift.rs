//! Predicted-vs-measured drift accounting — the paper's experiments
//! section as a first-class artifact.
//!
//! The DP's schedule is optimal *for the stage costs it was given*
//! (`u_f`/`u_b`) and *for the simulator's memory model*. After a real
//! replay, [`drift_report`] joins the measured per-op-kind times and
//! peak against those predictions:
//!
//! * **time**: per kind, `Σ` of the chain's `u_f`/`u_b` over the
//!   schedule's ops of that kind vs the executor's measured wall-clock.
//!   The predicted side is in the chain's own unit — microseconds for
//!   chains measured by [`crate::estimator`] (so ratios hover near 1 on
//!   the native backend), milliseconds for the paper's analytic
//!   profiles (where only relative drift across kinds is meaningful).
//! * **memory**: the simulator's `MemState` peak vs the ledger/arena
//!   peak the executor observed — byte-exact equality on the native
//!   backend is an acceptance gate, not a hope.
//!
//! [`crate::api::Plan::execute`] attaches a report to its
//! [`crate::api::ExecutionReport`]; `chainckpt compare` prints one per
//! strategy.

use crate::chain::Chain;
use crate::simulator::simulate;
use crate::solver::{Op, Schedule};

use super::OpKind;

/// Measured-vs-predicted totals for one op kind.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KindDrift {
    pub kind: OpKind,
    /// Ops of this kind actually executed (averaged over reps).
    pub ops: u64,
    /// Σ predicted cost, in the chain's time unit.
    pub predicted_us: f64,
    /// Σ measured wall-clock, microseconds.
    pub measured_us: f64,
    /// `measured / predicted` (0 when nothing was predicted).
    pub ratio: f64,
}

/// The joined drift report for one executed schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftReport {
    /// Only kinds the schedule actually contains.
    pub kinds: Vec<KindDrift>,
    /// The simulator's `MemState` peak for this chain + schedule.
    pub predicted_peak_bytes: u64,
    /// The peak the executor reported (ledger or lowered-plan peak).
    pub measured_peak_bytes: u64,
    /// Simulator makespan (chain time unit).
    pub predicted_time_us: f64,
    /// Measured makespan: Σ measured op time, microseconds.
    pub measured_time_us: f64,
    /// `measured_time_us / predicted_time_us` (0 when unpredicted).
    pub time_ratio: f64,
}

impl DriftReport {
    /// True when the executor's peak matched the simulator byte-exactly.
    pub fn peak_exact(&self) -> bool {
        self.measured_peak_bytes == self.predicted_peak_bytes
    }

    /// One-line summary for CLI output, e.g.
    /// `drift: time ×1.03 (pred 812.0 meas 836.4) · peak 18.4 KiB == simulated`.
    pub fn summary(&self) -> String {
        let peak = if self.peak_exact() {
            format!("peak {} B == simulated", self.measured_peak_bytes)
        } else {
            format!(
                "peak {} B vs simulated {} B",
                self.measured_peak_bytes, self.predicted_peak_bytes
            )
        };
        format!(
            "drift: time ×{:.3} (pred {:.1} meas {:.1} µs) · {}",
            self.time_ratio, self.predicted_time_us, self.measured_time_us, peak
        )
    }
}

/// Classify a schedule op for drift/trace purposes.
pub fn op_kind(op: Op) -> OpKind {
    match op {
        Op::FwdNoSave(_) => OpKind::FwdNoSave,
        Op::FwdCk(_) => OpKind::FwdCk,
        Op::FwdAll(_) => OpKind::FwdAll,
        Op::Bwd(_) => OpKind::Bwd,
        Op::DropA(_) => OpKind::DropA,
    }
}

/// Join measured per-kind `(count, ns)` totals and a measured peak
/// against the simulator's predictions for `chain` + `sched`. Returns
/// `None` when the schedule doesn't simulate on the chain (a drift
/// report for an invalid plan would be noise, not signal).
///
/// `measured_ops`/`measured_ns` are indexed by [`OpKind::index`] — the
/// delta of two [`super::Registry::kind_totals`] calls around the timed
/// region, divided by the rep count.
pub fn drift_report(
    chain: &Chain,
    sched: &Schedule,
    measured_ops: [u64; OpKind::COUNT],
    measured_ns: [u64; OpKind::COUNT],
    measured_peak_bytes: u64,
) -> Option<DriftReport> {
    let sim = simulate(chain, sched).ok()?;

    // Σ predicted cost per kind over the schedule's ops
    let mut predicted = [0.0f64; OpKind::COUNT];
    for &op in &sched.ops {
        let k = op_kind(op);
        match op {
            Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) => {
                predicted[k.index()] += chain.uf(l as usize);
            }
            Op::Bwd(l) => predicted[k.index()] += chain.ub(l as usize),
            Op::DropA(_) => {} // frees are modeled as instantaneous
        }
    }

    let mut kinds = Vec::new();
    let mut measured_total_us = 0.0f64;
    for k in OpKind::ALL {
        let i = k.index();
        let measured_us = measured_ns[i] as f64 / 1_000.0;
        measured_total_us += measured_us;
        if measured_ops[i] == 0 && predicted[i] == 0.0 {
            continue;
        }
        let ratio = if predicted[i] > 0.0 { measured_us / predicted[i] } else { 0.0 };
        kinds.push(KindDrift {
            kind: k,
            ops: measured_ops[i],
            predicted_us: predicted[i],
            measured_us,
            ratio,
        });
    }

    let time_ratio =
        if sim.makespan > 0.0 { measured_total_us / sim.makespan } else { 0.0 };
    Some(DriftReport {
        kinds,
        predicted_peak_bytes: sim.peak_bytes,
        measured_peak_bytes,
        predicted_time_us: sim.makespan,
        measured_time_us: measured_total_us,
        time_ratio,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Stage;
    use crate::solver::{store_all_schedule, StrategyKind};

    fn toy() -> Chain {
        let stage = |uf, ub| Stage {
            name: String::new(),
            uf,
            ub,
            wa: 100,
            wabar: 200,
            wd: 100,
            of: 50,
            ob: 50,
        };
        Chain::new("toy", vec![stage(10.0, 20.0), stage(30.0, 40.0)], 100)
    }

    #[test]
    fn joins_predictions_against_measured_totals() {
        let chain = toy();
        let sched = store_all_schedule(&chain);
        let sim = simulate(&chain, &sched).unwrap();

        // pretend every op was measured at exactly 2× its prediction
        // (predictions are µs here, so ns = us·1000)
        let mut ops = [0u64; OpKind::COUNT];
        let mut ns = [0u64; OpKind::COUNT];
        for &op in &sched.ops {
            let k = op_kind(op);
            ops[k.index()] += 1;
            let pred = match op {
                Op::FwdNoSave(l) | Op::FwdCk(l) | Op::FwdAll(l) => chain.uf(l as usize),
                Op::Bwd(l) => chain.ub(l as usize),
                Op::DropA(_) => 0.0,
            };
            ns[k.index()] += (pred * 2.0 * 1_000.0) as u64;
        }

        let report = drift_report(&chain, &sched, ops, ns, sim.peak_bytes).unwrap();
        assert!(report.peak_exact());
        assert_eq!(report.predicted_peak_bytes, sim.peak_bytes);
        assert!((report.time_ratio - 2.0).abs() < 1e-9, "ratio {}", report.time_ratio);
        for kd in &report.kinds {
            if kd.predicted_us > 0.0 {
                assert!((kd.ratio - 2.0).abs() < 1e-9, "{:?}", kd);
            }
        }
        assert_eq!(sched.strategy, StrategyKind::StoreAll);
        // store-all on an L=2 chain: 1×FwdCk, 1×FwdAll, 2×Bwd — all present
        assert!(report.kinds.iter().any(|k| k.kind == OpKind::FwdAll));
        assert!(report.kinds.iter().any(|k| k.kind == OpKind::Bwd));
        // the one-liner mentions both halves of the join
        let s = report.summary();
        assert!(s.contains("time ×") && s.contains("peak"), "{s}");
    }

    #[test]
    fn invalid_schedule_yields_none() {
        let chain = toy();
        // Bwd before any forward: the simulator rejects this sequence
        let sched = Schedule::new(vec![Op::Bwd(2)], StrategyKind::StoreAll, 0.0);
        assert!(drift_report(&chain, &sched, [0; 5], [0; 5], 0).is_none());
    }
}
