//! Crate-wide telemetry: one process-global metrics [`Registry`], a span
//! [`trace`]r for executor replays, and the predicted-vs-measured
//! [`drift`] report — std-only, zero dependencies, lock-free on every
//! hot path.
//!
//! The paper's claim is quantitative: the DP's schedule is optimal *for
//! the measured stage costs* `u_f`/`u_b` and the simulated peak. This
//! module closes the loop the paper's experiments section runs by hand:
//!
//! * the **registry** ([`registry`]) absorbs every counter that used to
//!   live in a bespoke corner — the planner's table-cache stats, the DP
//!   fill's internals (cells filled, frontier runs emitted,
//!   dominance-prune hits, per-diagonal fill time), the executor's
//!   replay (per-op-kind kernel time, recomputed forwards, arena
//!   high-watermark), the native backend's tensor allocations, and the
//!   service's request/latency counts. Instruments are plain atomics
//!   ([`Counter`], [`Gauge`]) and fixed-bucket [`Histogram`]s: recording
//!   is a handful of relaxed atomic RMWs, never a lock.
//! * the **tracer** ([`trace`]) records `(op, stage, t_start, t_end,
//!   bytes)` spans during `Executor::run`/`run_lowered` into a bounded
//!   ring buffer and dumps them as Chrome trace-event JSON
//!   (Perfetto-compatible) — `chainckpt train --trace out.json`.
//!   Disabled cost is one relaxed atomic load per op.
//! * the **drift report** ([`drift::DriftReport`]) joins measured per-op
//!   times against the simulator's predicted costs and peak —
//!   [`crate::api::Plan::execute`] returns it, `chainckpt compare`
//!   prints it.
//!
//! `GET /metrics` on the planning service serves the registry in
//! Prometheus text exposition format ([`Registry::prometheus_text`]);
//! benches embed [`Registry::snapshot`] in their `BENCH_*.json`.

pub mod drift;
pub mod trace;

pub use drift::{drift_report, DriftReport, KindDrift};
pub use trace::{
    chrome_trace_json, trace_enabled, trace_record, trace_start, trace_stop, SpanEvent,
    DEFAULT_TRACE_CAPACITY,
};

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::util::json::{obj, Value};

// ---------------------------------------------------------------------------
// Instrument primitives
// ---------------------------------------------------------------------------

/// A monotonically increasing counter (one relaxed atomic RMW per
/// record; `reset` exists for the planner cache's `clear_cache`, which
/// the benches use to isolate measurements).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// A last-value-or-maximum gauge (arena high-watermarks, ledger peaks).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if larger (high-watermark semantics).
    #[inline]
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// A fixed-bucket histogram with Prometheus `le` semantics: an
/// observation equal to a bound lands in that bound's bucket. Bounds are
/// a static, strictly increasing slice; one extra bucket catches
/// everything above the last bound (`+Inf`). Recording is three relaxed
/// atomic RMWs — no lock, no allocation.
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [u64],
    buckets: Vec<AtomicU64>, // bounds.len() + 1 (the +Inf bucket), non-cumulative
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &'static [u64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds,
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    #[inline]
    pub fn observe(&self, value: u64) {
        // first bound ≥ value (== bounds.len() → the +Inf bucket)
        let idx = self.bounds.partition_point(|&b| b < value);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn bounds(&self) -> &'static [u64] {
        self.bounds
    }

    /// Cumulative per-bucket counts in bound order, ending with the
    /// `+Inf` bucket (whose value equals [`Histogram::count`] when no
    /// observation races the read).
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.buckets
            .iter()
            .map(|b| {
                acc += b.load(Ordering::Relaxed);
                acc
            })
            .collect()
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

/// A bounded sliding window of samples answering *exact* percentiles —
/// the shared replacement for the service's hand-rolled latency
/// reservoir. Recording is lock-free (a slot index from one relaxed
/// `fetch_add`, one relaxed store); reading sorts a copy of the window.
#[derive(Debug)]
pub struct Window {
    slots: Vec<AtomicU64>,
    next: AtomicU64, // total observations ever; the slot is next % capacity
}

impl Window {
    pub fn new(capacity: usize) -> Window {
        assert!(capacity > 0, "a window needs at least one slot");
        Window {
            slots: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            next: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, value: u64) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) as usize % self.slots.len();
        self.slots[i].store(value, Ordering::Relaxed);
    }

    /// Samples currently held (saturates at the capacity).
    pub fn len(&self) -> usize {
        (self.next.load(Ordering::Relaxed) as usize).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// For each quantile `q ∈ [0, 1]`: the sample at rank
    /// `round((len-1)·q)` of the sorted window — the exact-percentile
    /// formula the `/stats` endpoint has always used. All zeros when the
    /// window is empty.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<u64> {
        let n = self.len();
        if n == 0 {
            return vec![0; qs.len()];
        }
        let mut samples: Vec<u64> =
            self.slots[..n].iter().map(|s| s.load(Ordering::Relaxed)).collect();
        samples.sort_unstable();
        qs.iter().map(|q| samples[((n - 1) as f64 * q).round() as usize]).collect()
    }
}

// ---------------------------------------------------------------------------
// Op kinds (shared by the executor instrumentation, tracer, and drift)
// ---------------------------------------------------------------------------

/// The five operation kinds of the paper's Table 1 — the granularity at
/// which the executor is timed and drift is reported.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    FwdNoSave,
    FwdCk,
    FwdAll,
    Bwd,
    DropA,
}

impl OpKind {
    pub const COUNT: usize = 5;
    pub const ALL: [OpKind; OpKind::COUNT] =
        [OpKind::FwdNoSave, OpKind::FwdCk, OpKind::FwdAll, OpKind::Bwd, OpKind::DropA];

    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            OpKind::FwdNoSave => "fwd_nosave",
            OpKind::FwdCk => "fwd_ck",
            OpKind::FwdAll => "fwd_all",
            OpKind::Bwd => "bwd",
            OpKind::DropA => "drop_a",
        }
    }

    pub fn is_forward(self) -> bool {
        matches!(self, OpKind::FwdNoSave | OpKind::FwdCk | OpKind::FwdAll)
    }
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// Bucket bounds (µs) for per-diagonal DP fill times: sub-ms wavefronts
/// up through multi-second diagonals on depth-10⁴ chains.
const DIAGONAL_FILL_US_BOUNDS: &[u64] =
    &[10, 50, 100, 500, 1_000, 5_000, 10_000, 50_000, 100_000, 500_000, 1_000_000];

/// Bucket bounds (µs) for service request latency.
const LATENCY_US_BOUNDS: &[u64] =
    &[50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 1_000_000];

/// Every instrument in the crate, grouped by subsystem. One instance
/// per process ([`registry`]); all fields are public so instrumentation
/// sites record without accessor ceremony.
pub struct Registry {
    // --- planner table cache (solver/planner.rs) ---
    pub cache_lookups: Counter,
    pub cache_hits: Counter,
    pub cache_builds: Counter,
    pub cache_evictions: Counter,
    pub cache_coalesced: Counter,
    // --- persistent table store, the cache's second tier (solver/persist.rs) ---
    pub store_hits: Counter,
    pub store_misses: Counter,
    pub store_writes: Counter,
    pub store_errors: Counter,
    pub store_load_ns: Counter,
    // --- DP fill internals (solver/optimal.rs, frontier fill) ---
    pub solver_cells_filled: Counter,
    pub solver_runs_emitted: Counter,
    pub solver_prune_hits: Counter,
    pub solver_diagonals: Counter,
    pub solver_fill_ns: Counter,
    pub solver_diagonal_fill_us: Histogram,
    // --- executor replay (executor/{mod,lowered}.rs) ---
    pub exec_op_count: [Counter; OpKind::COUNT],
    pub exec_op_ns: [Counter; OpKind::COUNT],
    pub exec_recomputed_forwards: Counter,
    pub exec_runs: Counter,
    pub exec_arena_high_watermark_bytes: Gauge,
    pub exec_peak_bytes: Gauge,
    // --- native backend ---
    pub native_tensor_allocs: Counter,
    // --- service (mirrored from every per-instance routes::Stats) ---
    pub service_requests: Counter,
    pub service_responses_2xx: Counter,
    pub service_responses_4xx: Counter,
    pub service_responses_5xx: Counter,
    pub service_latency_us: Histogram,
    // --- plan verifier (analysis/verify.rs) ---
    pub verifier_runs: Counter,
    pub verifier_clean: Counter,
    pub verifier_violations: Counter,
}

impl Registry {
    fn new() -> Registry {
        Registry {
            cache_lookups: Counter::new(),
            cache_hits: Counter::new(),
            cache_builds: Counter::new(),
            cache_evictions: Counter::new(),
            cache_coalesced: Counter::new(),
            store_hits: Counter::new(),
            store_misses: Counter::new(),
            store_writes: Counter::new(),
            store_errors: Counter::new(),
            store_load_ns: Counter::new(),
            solver_cells_filled: Counter::new(),
            solver_runs_emitted: Counter::new(),
            solver_prune_hits: Counter::new(),
            solver_diagonals: Counter::new(),
            solver_fill_ns: Counter::new(),
            solver_diagonal_fill_us: Histogram::new(DIAGONAL_FILL_US_BOUNDS),
            exec_op_count: std::array::from_fn(|_| Counter::new()),
            exec_op_ns: std::array::from_fn(|_| Counter::new()),
            exec_recomputed_forwards: Counter::new(),
            exec_runs: Counter::new(),
            exec_arena_high_watermark_bytes: Gauge::new(),
            exec_peak_bytes: Gauge::new(),
            native_tensor_allocs: Counter::new(),
            service_requests: Counter::new(),
            service_responses_2xx: Counter::new(),
            service_responses_4xx: Counter::new(),
            service_responses_5xx: Counter::new(),
            service_latency_us: Histogram::new(LATENCY_US_BOUNDS),
            verifier_runs: Counter::new(),
            verifier_clean: Counter::new(),
            verifier_violations: Counter::new(),
        }
    }

    /// One executed op of `kind` taking `ns` nanoseconds.
    #[inline]
    pub fn record_op(&self, kind: OpKind, ns: u64) {
        self.exec_op_count[kind.index()].inc();
        self.exec_op_ns[kind.index()].add(ns);
    }

    /// Per-kind `(count, ns)` totals. The measured side of a drift
    /// report is the delta of two of these around a timed region.
    pub fn kind_totals(&self) -> ([u64; OpKind::COUNT], [u64; OpKind::COUNT]) {
        (
            std::array::from_fn(|i| self.exec_op_count[i].get()),
            std::array::from_fn(|i| self.exec_op_ns[i].get()),
        )
    }

    /// Zero the planner-cache counters — `solver::clear_cache`'s
    /// counter half, so benches keep their exact-count assertions. The
    /// disk-tier counters reset too: cold/warm bench arms isolate their
    /// store traffic the same way they isolate hits and builds.
    pub fn reset_cache_counters(&self) {
        for c in [
            &self.cache_lookups,
            &self.cache_hits,
            &self.cache_builds,
            &self.cache_evictions,
            &self.cache_coalesced,
            &self.store_hits,
            &self.store_misses,
            &self.store_writes,
            &self.store_errors,
            &self.store_load_ns,
        ] {
            c.reset();
        }
    }

    /// A point-in-time JSON snapshot, grouped by subsystem — embedded in
    /// every `BENCH_*.json` so gates reference telemetry instead of
    /// re-deriving it.
    pub fn snapshot(&self) -> Value {
        let lookups = self.cache_lookups.get();
        let hits = self.cache_hits.get();
        let cells = self.solver_cells_filled.get();
        let prune_hits = self.solver_prune_hits.get();
        let ops: Vec<(&str, Value)> = OpKind::ALL
            .iter()
            .map(|&k| {
                (
                    k.label(),
                    obj([
                        ("count", Value::from(self.exec_op_count[k.index()].get())),
                        ("ns", Value::from(self.exec_op_ns[k.index()].get())),
                    ]),
                )
            })
            .collect();
        let mut ops_obj = std::collections::BTreeMap::new();
        for (k, v) in ops {
            ops_obj.insert(k.to_string(), v);
        }
        obj([
            (
                "planner_cache",
                obj([
                    ("lookups", Value::from(lookups)),
                    ("hits", Value::from(hits)),
                    ("builds", Value::from(self.cache_builds.get())),
                    ("evictions", Value::from(self.cache_evictions.get())),
                    ("coalesced", Value::from(self.cache_coalesced.get())),
                    (
                        "hit_rate",
                        Value::from(if lookups > 0 { hits as f64 / lookups as f64 } else { 0.0 }),
                    ),
                ]),
            ),
            (
                "table_store",
                obj([
                    ("hits", Value::from(self.store_hits.get())),
                    ("misses", Value::from(self.store_misses.get())),
                    ("writes", Value::from(self.store_writes.get())),
                    ("errors", Value::from(self.store_errors.get())),
                    ("load_ns", Value::from(self.store_load_ns.get())),
                ]),
            ),
            (
                "solver",
                obj([
                    ("cells_filled", Value::from(cells)),
                    ("runs_emitted", Value::from(self.solver_runs_emitted.get())),
                    ("prune_hits", Value::from(prune_hits)),
                    (
                        // prune hits per filled cell: how many split
                        // candidates the dominance check discarded in O(1)
                        "prune_hits_per_cell",
                        Value::from(if cells > 0 { prune_hits as f64 / cells as f64 } else { 0.0 }),
                    ),
                    ("diagonals", Value::from(self.solver_diagonals.get())),
                    ("fill_ns", Value::from(self.solver_fill_ns.get())),
                ]),
            ),
            (
                "executor",
                obj([
                    ("ops", Value::Obj(ops_obj)),
                    ("recomputed_forwards", Value::from(self.exec_recomputed_forwards.get())),
                    ("runs", Value::from(self.exec_runs.get())),
                    (
                        "arena_high_watermark_bytes",
                        Value::from(self.exec_arena_high_watermark_bytes.get()),
                    ),
                    ("peak_bytes", Value::from(self.exec_peak_bytes.get())),
                ]),
            ),
            ("native", obj([("tensor_allocs", Value::from(self.native_tensor_allocs.get()))])),
            (
                "service",
                obj([
                    ("requests", Value::from(self.service_requests.get())),
                    (
                        "responses",
                        obj([
                            ("2xx", Value::from(self.service_responses_2xx.get())),
                            ("4xx", Value::from(self.service_responses_4xx.get())),
                            ("5xx", Value::from(self.service_responses_5xx.get())),
                        ]),
                    ),
                ]),
            ),
            (
                "verifier",
                obj([
                    ("runs", Value::from(self.verifier_runs.get())),
                    ("clean", Value::from(self.verifier_clean.get())),
                    ("violations", Value::from(self.verifier_violations.get())),
                ]),
            ),
        ])
    }

    /// The registry in Prometheus text exposition format (version
    /// 0.0.4): `# HELP`/`# TYPE` per family, `_total` counters,
    /// cumulative `_bucket{le=…}`/`_sum`/`_count` histograms. Served by
    /// `GET /metrics` on the planning service.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::with_capacity(4096);
        counter_line(
            &mut out,
            "chainckpt_planner_cache_lookups_total",
            "DP-table cache lookups.",
            self.cache_lookups.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_planner_cache_hits_total",
            "DP-table cache hits (LRU or single-flight handoff).",
            self.cache_hits.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_planner_cache_builds_total",
            "DP tables actually filled.",
            self.cache_builds.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_planner_cache_evictions_total",
            "DP tables evicted from the cache.",
            self.cache_evictions.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_planner_cache_coalesced_total",
            "Lookups that waited on an in-flight build instead of duplicating it.",
            self.cache_coalesced.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_table_store_hits_total",
            "DP tables loaded from the persistent on-disk store.",
            self.store_hits.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_table_store_misses_total",
            "Disk-store lookups that found no usable table file.",
            self.store_misses.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_table_store_writes_total",
            "DP tables persisted to the on-disk store.",
            self.store_writes.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_table_store_errors_total",
            "Rejected or failed store files (corruption, IO).",
            self.store_errors.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_table_store_load_nanoseconds_total",
            "Wall-clock nanoseconds spent loading stored tables.",
            self.store_load_ns.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_solver_cells_filled_total",
            "DP cells filled by the frontier fill.",
            self.solver_cells_filled.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_solver_runs_emitted_total",
            "Frontier runs stored (compressed row segments).",
            self.solver_runs_emitted.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_solver_prune_hits_total",
            "Split candidates discarded by the exact dominance prune.",
            self.solver_prune_hits.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_solver_diagonals_total",
            "Anti-diagonal wavefronts filled.",
            self.solver_diagonals.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_solver_fill_nanoseconds_total",
            "Wall-clock nanoseconds spent in DP fills.",
            self.solver_fill_ns.get(),
        );
        histogram_lines(
            &mut out,
            "chainckpt_solver_diagonal_fill_us",
            "Per-anti-diagonal fill time, microseconds.",
            &self.solver_diagonal_fill_us,
        );
        // one family, labeled per op kind
        let _ = writeln!(
            out,
            "# HELP chainckpt_executor_ops_total Executed schedule operations by kind."
        );
        let _ = writeln!(out, "# TYPE chainckpt_executor_ops_total counter");
        for k in OpKind::ALL {
            let _ = writeln!(
                out,
                "chainckpt_executor_ops_total{{kind=\"{}\"}} {}",
                k.label(),
                self.exec_op_count[k.index()].get()
            );
        }
        let _ = writeln!(
            out,
            "# HELP chainckpt_executor_op_nanoseconds_total Wall-clock nanoseconds per op kind."
        );
        let _ = writeln!(out, "# TYPE chainckpt_executor_op_nanoseconds_total counter");
        for k in OpKind::ALL {
            let _ = writeln!(
                out,
                "chainckpt_executor_op_nanoseconds_total{{kind=\"{}\"}} {}",
                k.label(),
                self.exec_op_ns[k.index()].get()
            );
        }
        counter_line(
            &mut out,
            "chainckpt_executor_recomputed_forwards_total",
            "Forward ops re-run beyond the first pass (checkpointing's price).",
            self.exec_recomputed_forwards.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_executor_runs_total",
            "Complete schedule replays.",
            self.exec_runs.get(),
        );
        gauge_line(
            &mut out,
            "chainckpt_executor_arena_high_watermark_bytes",
            "Largest lowered arena bound so far.",
            self.exec_arena_high_watermark_bytes.get(),
        );
        gauge_line(
            &mut out,
            "chainckpt_executor_peak_bytes",
            "Largest ledger/plan peak observed in a replay.",
            self.exec_peak_bytes.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_native_tensor_allocs_total",
            "Tensors allocated by the native backend.",
            self.native_tensor_allocs.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_service_requests_total",
            "HTTP requests handled by the planning service.",
            self.service_requests.get(),
        );
        let _ = writeln!(
            out,
            "# HELP chainckpt_service_responses_total HTTP responses by status class."
        );
        let _ = writeln!(out, "# TYPE chainckpt_service_responses_total counter");
        for (class, c) in [
            ("2xx", &self.service_responses_2xx),
            ("4xx", &self.service_responses_4xx),
            ("5xx", &self.service_responses_5xx),
        ] {
            let _ = writeln!(
                out,
                "chainckpt_service_responses_total{{class=\"{class}\"}} {}",
                c.get()
            );
        }
        histogram_lines(
            &mut out,
            "chainckpt_service_latency_us",
            "Request latency, microseconds.",
            &self.service_latency_us,
        );
        counter_line(
            &mut out,
            "chainckpt_verifier_runs_total",
            "Static plan verifications performed.",
            self.verifier_runs.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_verifier_clean_total",
            "Verifications that returned a clean verdict.",
            self.verifier_clean.get(),
        );
        counter_line(
            &mut out,
            "chainckpt_verifier_violations_total",
            "Violations reported across all verifications.",
            self.verifier_violations.get(),
        );
        out
    }
}

fn counter_line(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {value}");
}

fn gauge_line(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {value}");
}

fn histogram_lines(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let cumulative = h.cumulative();
    for (bound, count) in h.bounds().iter().zip(&cumulative) {
        let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {count}");
    }
    // the +Inf bucket is the last cumulative entry by construction
    let inf = cumulative.last().copied().unwrap_or(0);
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {inf}");
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-global registry. First call initializes (one
/// allocation); every later call is a single atomic load.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new();
        g.set(10);
        g.record_max(5); // lower → no change
        assert_eq!(g.get(), 10);
        g.record_max(99);
        assert_eq!(g.get(), 99);
    }

    #[test]
    fn histogram_le_bucket_selection() {
        let h = Histogram::new(&[10, 20, 30]);
        h.observe(10); // == bound → that bucket (le semantics)
        h.observe(11); // → le=20
        h.observe(30); // == last bound → le=30
        h.observe(31); // → +Inf
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 82);
        assert_eq!(h.cumulative(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn window_wraps_and_answers_exact_percentiles() {
        let w = Window::new(8);
        for v in 1..=8u64 {
            w.record(v);
        }
        assert_eq!(w.len(), 8);
        // rank round(7·0.5) = 4 → sorted[4] = 5
        assert_eq!(w.percentiles(&[0.0, 0.5, 1.0]), vec![1, 5, 8]);
        w.record(100); // overwrites the oldest slot
        assert_eq!(w.len(), 8);
        assert_eq!(w.percentiles(&[1.0]), vec![100]);
    }

    #[test]
    fn registry_is_one_instance_and_exposes_prometheus_text() {
        let a = registry() as *const Registry;
        let b = registry() as *const Registry;
        assert_eq!(a, b);
        let text = registry().prometheus_text();
        for family in [
            "chainckpt_planner_cache_lookups_total",
            "chainckpt_solver_prune_hits_total",
            "chainckpt_executor_ops_total",
            "chainckpt_service_latency_us_bucket",
        ] {
            assert!(text.contains(family), "missing family {family} in:\n{text}");
        }
        // the snapshot mirrors the same groups
        let snap = registry().snapshot();
        for key in ["planner_cache", "table_store", "solver", "executor", "native", "service"] {
            assert!(snap.get(key).is_some(), "snapshot missing group {key}");
        }
    }
}
