//! Span tracer: a bounded ring buffer of `(op, stage, t_start, t_end,
//! bytes)` events recorded during executor replays, dumped as Chrome
//! trace-event JSON so a schedule opens directly in Perfetto or
//! `chrome://tracing`.
//!
//! The hot-path contract: when tracing is off, [`trace_enabled`] is one
//! relaxed atomic load and nothing else runs. When on, each op takes one
//! short mutex-guarded ring write — acceptable because tracing is an
//! explicitly requested diagnostic (`--trace FILE`), never the measured
//! configuration (the executor bench gates the *disabled* overhead).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{obj, Value};

/// One completed executor operation, timestamps in microseconds since
/// the `trace_start` call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Op-kind label (one of [`super::OpKind::label`]'s values).
    pub name: &'static str,
    /// 1-based stage index (0 for whole-run spans).
    pub stage: u32,
    pub t_start_us: u64,
    pub t_end_us: u64,
    /// Bytes materialized by the op (activation/gradient output size).
    pub bytes: u64,
}

/// Ring capacity when the caller doesn't choose one: enough for a full
/// replay of a depth-10⁴ chain with heavy recomputation (~4·L ops) with
/// room to spare, at 40 B/event ≈ 2.6 MiB.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

static TRACE_ENABLED: AtomicBool = AtomicBool::new(false);
static TRACER: Mutex<Option<TracerInner>> = Mutex::new(None);

struct TracerInner {
    epoch: Instant,
    events: Vec<SpanEvent>,
    cap: usize,
    head: usize, // next overwrite slot once the ring is full
    dropped: u64,
}

/// One relaxed load — the only cost instrumentation pays when tracing
/// is off.
#[inline]
pub fn trace_enabled() -> bool {
    TRACE_ENABLED.load(Ordering::Relaxed)
}

/// Arm the tracer with a ring of `capacity` events (the epoch for all
/// timestamps is now). A second call discards any buffered events and
/// restarts the epoch.
pub fn trace_start(capacity: usize) {
    let mut guard = TRACER.lock().unwrap();
    *guard = Some(TracerInner {
        epoch: Instant::now(),
        events: Vec::with_capacity(capacity.max(1)),
        cap: capacity.max(1),
        head: 0,
        dropped: 0,
    });
    drop(guard);
    TRACE_ENABLED.store(true, Ordering::Relaxed);
}

/// Disarm the tracer and return the buffered events in chronological
/// order (plus how many older events the ring overwrote). A no-op
/// `(empty, 0)` if tracing was never started.
pub fn trace_stop() -> (Vec<SpanEvent>, u64) {
    TRACE_ENABLED.store(false, Ordering::Relaxed);
    let mut guard = TRACER.lock().unwrap();
    match guard.take() {
        None => (Vec::new(), 0),
        Some(inner) => {
            let TracerInner { events, cap, head, dropped, .. } = inner;
            if events.len() < cap || head == 0 {
                (events, dropped)
            } else {
                // ring wrapped: oldest surviving event sits at `head`
                let mut ordered = Vec::with_capacity(events.len());
                ordered.extend_from_slice(&events[head..]);
                ordered.extend_from_slice(&events[..head]);
                (ordered, dropped)
            }
        }
    }
}

/// Record one completed span. Callers gate on [`trace_enabled`] first;
/// this re-checks under the lock so a span finishing as the tracer is
/// stopped is simply dropped instead of resurrecting a stale ring.
pub fn trace_record(name: &'static str, stage: u32, t_start: Instant, t_end: Instant, bytes: u64) {
    let mut guard = TRACER.lock().unwrap();
    let Some(inner) = guard.as_mut() else {
        return;
    };
    let t_start_us = t_start.saturating_duration_since(inner.epoch).as_micros() as u64;
    let t_end_us = t_end.saturating_duration_since(inner.epoch).as_micros() as u64;
    let ev = SpanEvent { name, stage, t_start_us, t_end_us, bytes };
    if inner.events.len() < inner.cap {
        inner.events.push(ev);
    } else {
        inner.events[inner.head] = ev;
        inner.head = (inner.head + 1) % inner.cap;
        inner.dropped += 1;
    }
}

/// Serialize spans as Chrome trace-event JSON: an object with a
/// `traceEvents` array of complete (`"ph":"X"`) events, timestamps and
/// durations in microseconds — the format Perfetto and
/// `chrome://tracing` load directly.
pub fn chrome_trace_json(events: &[SpanEvent]) -> String {
    let items: Vec<Value> = events
        .iter()
        .map(|ev| {
            obj([
                ("name", Value::from(ev.name)),
                ("cat", Value::from("executor")),
                ("ph", Value::from("X")),
                ("ts", Value::from(ev.t_start_us)),
                ("dur", Value::from(ev.t_end_us.saturating_sub(ev.t_start_us))),
                ("pid", Value::from(1u64)),
                ("tid", Value::from(1u64)),
                (
                    "args",
                    obj([
                        ("stage", Value::from(ev.stage as u64)),
                        ("bytes", Value::from(ev.bytes)),
                    ]),
                ),
            ])
        })
        .collect();
    obj([
        ("traceEvents", Value::from(items)),
        ("displayTimeUnit", Value::from("ms")),
    ])
    .to_json_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    // The tracer is process-global; run the lifecycle scenarios in one
    // test body so parallel test threads can't interleave arm/disarm.
    #[test]
    fn tracer_lifecycle_ring_wrap_and_json() {
        // disabled by default, stop without start is a no-op
        assert!(!trace_enabled());
        assert_eq!(trace_stop(), (Vec::new(), 0));

        // records land in order; timestamps are relative to the epoch
        trace_start(8);
        assert!(trace_enabled());
        let t0 = Instant::now();
        trace_record("fwd_ck", 1, t0, t0 + Duration::from_micros(5), 64);
        trace_record("bwd", 2, t0 + Duration::from_micros(5), t0 + Duration::from_micros(9), 128);
        let (events, dropped) = trace_stop();
        assert!(!trace_enabled());
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].name, "fwd_ck");
        assert_eq!(events[1].stage, 2);
        assert!(events[0].t_start_us <= events[0].t_end_us);
        assert_eq!(events[1].t_end_us - events[1].t_start_us, 4);

        // a full ring overwrites oldest-first and reports the drops
        trace_start(3);
        let t0 = Instant::now();
        for i in 0..5u32 {
            trace_record("fwd_nosave", i, t0, t0, 0);
        }
        let (events, dropped) = trace_stop();
        assert_eq!(dropped, 2);
        assert_eq!(events.iter().map(|e| e.stage).collect::<Vec<_>>(), vec![2, 3, 4]);

        // the JSON dump parses and carries the trace-event fields
        let json = chrome_trace_json(&[SpanEvent {
            name: "fwd_all",
            stage: 3,
            t_start_us: 10,
            t_end_us: 25,
            bytes: 4096,
        }]);
        let v = crate::util::json::Value::parse(&json).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].get("ts").unwrap().as_u64(), Some(10));
        assert_eq!(evs[0].get("dur").unwrap().as_u64(), Some(15));
        assert_eq!(evs[0].get("args").unwrap().get("bytes").unwrap().as_u64(), Some(4096));
    }
}
